"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each function computes the mathematically identical result with plain
jnp ops (fp32 accumulation, same masking semantics) — tests sweep shapes
and dtypes asserting allclose against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q/k/v: (B, L, H, hd) heads pre-expanded."""
    B, L, H, hd = q.shape
    scale = hd ** -0.5
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qp = jnp.arange(L)[:, None]
    kp = jnp.arange(k.shape[1])[None, :]
    rel = qp - kp
    mask = jnp.ones_like(rel, dtype=bool)
    if causal:
        mask &= rel >= 0
    if window > 0:
        mask &= rel < window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention_ref(q, k, v, kv_mask):
    """q: (B,1,H,hd); k/v: (B,S,Hkv,hd); kv_mask: (B,S)."""
    B, _, H, hd = q.shape
    Hkv = k.shape[2]
    rep = H // Hkv
    kx = jnp.repeat(k, rep, axis=2).astype(jnp.float32)
    vx = jnp.repeat(v, rep, axis=2).astype(jnp.float32)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), kx) * hd ** -0.5
    s = jnp.where(kv_mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vx)
    return out.astype(q.dtype)


def paged_decode_attention_ref(q, k_pages, v_pages, block_table, lengths,
                               k_scale=None, v_scale=None):
    """q: (B,1,H,hd); k_pages/v_pages: (P,ps,Hkv,hd);
    block_table: (B,n) int32 page ids; lengths: (B,) int32 live tokens.

    Gathers each row's pages into a contiguous (B, n*ps, Hkv, hd) view
    (position p of row b lives at page block_table[b, p//ps], offset
    p%ps) and reduces to the contiguous oracle with an
    ``arange < length`` validity mask.

    ``k_scale``/``v_scale``: optional (P, ps, Hkv) float32 per-row
    absmax scales for quantized (int8/fp8) pools — dequantized after
    the gather, mirroring the Pallas kernel's in-kernel dequant.
    """
    P, ps = k_pages.shape[:2]
    bt = jnp.clip(block_table, 0, P - 1)
    B, n = bt.shape
    k = k_pages[bt].reshape(B, n * ps, *k_pages.shape[2:])
    v = v_pages[bt].reshape(B, n * ps, *v_pages.shape[2:])
    if k_scale is not None:
        Hkv = k_scale.shape[-1]
        k = k.astype(jnp.float32) * \
            k_scale[bt].reshape(B, n * ps, Hkv)[..., None]
        v = v.astype(jnp.float32) * \
            v_scale[bt].reshape(B, n * ps, Hkv)[..., None]
    mask = jnp.arange(n * ps)[None, :] < lengths[:, None]
    return decode_attention_ref(q, k, v, mask)


def xmodal_score_ref(token_embs, mask, visual_feats, text_feats):
    """Eq. 8-9 oracle — mirrors repro.core.scoring.cross_modal_consistency."""

    def norm(x):
        return x / jnp.maximum(
            jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-8)

    tok = norm(token_embs.astype(jnp.float32))
    vis = norm(visual_feats.astype(jnp.float32))
    txt = norm(text_feats.astype(jnp.float32))
    m = mask.astype(jnp.float32)
    sim_tv = jnp.einsum("bld,bnd->bln", tok, vis)
    term1 = jnp.sum(jnp.mean(sim_tv, axis=-1) * m, axis=-1) \
        / jnp.maximum(jnp.sum(m, axis=-1), 1.0)
    sim_rt = jnp.einsum("brd,bnd->brn", txt, vis)
    term2 = jnp.mean(jnp.max(sim_rt, axis=-1), axis=-1)
    return 0.5 * (term1 + term2)


def moe_dispatch_ref(idx, x):
    """idx: (G, E, C) int32 token ids (-1 empty); x: (G, g, d).
    Einsum-equivalent gather reference."""
    valid = idx >= 0
    G, E, C = idx.shape
    d = x.shape[-1]
    out = x[jnp.arange(G)[:, None, None], jnp.maximum(idx, 0)]  # (G,E,C,d)
    return jnp.where(valid[..., None], out, 0.0).astype(x.dtype)


def moe_combine_ref(slot_idx, gates, expert_out):
    """slot_idx: (G, g, k) flat E*C slots (-1 dropped); gates: (G, g, k);
    expert_out: (G, E, C, d)."""
    G, E, C, d = expert_out.shape
    flat = expert_out.reshape(G, E * C, d).astype(jnp.float32)
    rows = flat[jnp.arange(G)[:, None, None], jnp.maximum(slot_idx, 0)]
    w = jnp.where(slot_idx >= 0, gates, 0.0).astype(jnp.float32)
    return jnp.sum(rows * w[..., None], axis=2)
