from repro.sampling.samplers import (  # noqa: F401
    apply_repetition_penalty,
    process_logits,
    sample_token,
)
