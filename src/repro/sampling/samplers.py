"""Token-level samplers and logit processors.

Matches the paper's decoding setup (§3.2): temperature, top-p, top-k,
min-p, repetition penalty. All processors are pure (B, V) -> (B, V)
functions that jit and compose; ``sample_token`` is the single entry point
used by the serving engine.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import SamplingConfig

NEG_INF = -1e30


def apply_temperature(logits, temperature: float):
    if temperature <= 0.0:
        return logits  # greedy handled by caller
    return logits / temperature


def apply_top_k(logits, k: int):
    """Keep exactly the k highest logits per row, mask the rest.

    ``jax.lax.top_k`` (O(V log k), no full sort) picks the survivors;
    ties at the kth value are broken toward lower token ids, so exactly
    k tokens survive even when the kth value is duplicated.
    """
    if k <= 0 or k >= logits.shape[-1]:
        return logits
    shape = logits.shape
    flat = logits.reshape(-1, shape[-1])
    _, idx = jax.lax.top_k(flat, k)
    rows = jnp.arange(flat.shape[0])[:, None]
    keep = jnp.zeros(flat.shape, bool).at[rows, idx].set(True)
    return jnp.where(keep.reshape(shape), logits, NEG_INF)


def apply_top_p(logits, p: float):
    if p >= 1.0 or p <= 0.0:
        return logits
    sorted_logits = jnp.sort(logits, axis=-1)[..., ::-1]
    probs = jax.nn.softmax(sorted_logits, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    # keep tokens until cumulative prob exceeds p (always keep the top-1)
    cutoff_mask = cum - probs > p
    cutoff_logit = jnp.min(
        jnp.where(cutoff_mask, jnp.inf, sorted_logits), axis=-1, keepdims=True)
    return jnp.where(logits < cutoff_logit, NEG_INF, logits)


def apply_min_p(logits, min_p: float):
    if min_p <= 0.0:
        return logits
    probs = jax.nn.softmax(logits, axis=-1)
    top = jnp.max(probs, axis=-1, keepdims=True)
    return jnp.where(probs < min_p * top, NEG_INF, logits)


def apply_repetition_penalty(logits, token_counts, penalty: float):
    """HF-style: seen tokens' positive logits divided by `penalty`,
    negative multiplied. token_counts: (B, V) counts of emitted tokens."""
    if penalty == 1.0:
        return logits
    seen = token_counts > 0
    return jnp.where(seen,
                     jnp.where(logits > 0, logits / penalty, logits * penalty),
                     logits)


def process_logits(logits, cfg: SamplingConfig, token_counts=None, bias=None):
    """Compose processors in the standard order. ``bias`` is the CAMD
    Eq. 16 mixture guidance (per-row (B, V) additive logits)."""
    if token_counts is not None:
        logits = apply_repetition_penalty(logits, token_counts,
                                          cfg.repetition_penalty)
    if bias is not None:
        logits = logits + bias
    logits = apply_temperature(logits, cfg.temperature)
    logits = apply_top_k(logits, cfg.top_k)
    logits = apply_top_p(logits, cfg.top_p)
    logits = apply_min_p(logits, cfg.min_p)
    return logits


def decode_step_key(base_key, step):
    """PRNG key for global decode step ``step``.

    The serving engine's fused loop derives per-step keys by *folding* the
    step index into one base key instead of threading a split chain
    through the loop carry — so the sampled stream at step t is a pure
    function of (base_key, t), independent of how many steps each
    ``lax.while_loop`` launch covers. This is what makes macro_steps=1 and
    macro_steps=32 decode bit-identical token streams.
    """
    return jax.random.fold_in(base_key, step)


def sample_token_batch(keys, logits, cfg: SamplingConfig, bias=None,
                       greedy=None):
    """Sample n first tokens from ONE shared logits row with n keys.

    keys: (n, key_dim); logits: (1, V); bias: optional (1, V); greedy:
    optional (1,) bool. Returns (tokens (n,), logprobs (n,)). Logit
    processing is shared — it is a pure function of the (single) row, so
    it runs once and only the categorical draw is vmapped over the keys.
    Per-key results stay identical to n separate ``sample_token`` calls;
    the serving engine uses this to admit a whole round of candidates at
    once.
    """
    proc = process_logits(logits, cfg, None, bias)
    logp = jax.nn.log_softmax(proc, axis=-1)
    arg = jnp.argmax(logits, axis=-1)

    def draw(k):
        sampled = jax.random.categorical(k, proc, axis=-1)
        if greedy is None:
            tok = sampled if cfg.temperature > 0 else arg
        else:
            tok = jnp.where(greedy, arg, sampled)
        lp = jnp.take_along_axis(logp, tok[:, None], axis=-1)[:, 0]
        return tok.astype(jnp.int32), lp

    tok, lp = jax.vmap(draw)(keys)
    return tok[:, 0], lp[:, 0]


def sample_token(key, logits, cfg: SamplingConfig, token_counts=None,
                 bias=None, greedy=None):
    """Returns (token (B,), logprob (B,)) — logprob of the *sampled* token
    under the processed distribution (used for S_gen, Eq. 7).

    ``greedy``: optional (B,) bool — rows decoded greedily (temperature 0).
    """
    proc = process_logits(logits, cfg, token_counts, bias)
    logp = jax.nn.log_softmax(proc, axis=-1)
    sampled = jax.random.categorical(key, proc, axis=-1)
    arg = jnp.argmax(logits, axis=-1)
    if greedy is None:
        tok = sampled if cfg.temperature > 0 else arg
    else:
        tok = jnp.where(greedy, arg, sampled)
    lp = jnp.take_along_axis(logp, tok[:, None], axis=-1)[:, 0]
    return tok.astype(jnp.int32), lp


def speculative_accept(base_key, step0, logits, draft, cfg: SamplingConfig,
                       *, token_counts, bias, greedy, eos_id, n_tok, limit,
                       active, greedy_static: bool = False):
    """Accept a prefix of a drafted token block, target distribution
    preserved (Leviathan-style rejection sampling, vectorized over B).

    The target forward fed block tokens ``[d_0, d_1, .., d_{K-1}]`` where
    ``d_0`` is the pending last token and ``d_1..d_{K-1}`` = ``draft``;
    ``logits[:, i]`` is the target's next-token distribution after
    ``d_i``. Position i emits one token t_{i+1}:

    * greedy rows take the raw argmax and keep going iff it equals the
      next drafted token — emitted streams are byte-identical to the
      sequential greedy loop by construction.
    * sampled rows accept ``d_{i+1}`` with probability p(d_{i+1}) under
      the PROCESSED target distribution (the n-gram draft is
      deterministic, q = delta_d, so the textbook min(1, p/q) rule
      reduces to p), otherwise sample from the residual (p with the
      draft token masked, renormalized). The emitted marginal is exactly
      p — distribution-preserving, though not stream-preserving: RNG
      consumption differs from the sequential loop.

    Emission stops after the first rejection, a missing draft (d = -1),
    EOS, or the per-slot token limit; the repetition-penalty counts fold
    in the accepted prefix as it grows so later positions see exactly
    the sequential processor state.

    logits: (B, K, V) fp32; draft: (B, K-1) int32, -1 = no proposal.
    Returns ``(tokens (B, K), logps (B, K), emit (B, K) bool,
    counts (B, V), n_tok' (B,), stopped (B,))`` — ``emit[:, i]`` marks
    positions that actually emitted; tokens past the first non-emitting
    position are padding. ``stopped`` marks rows whose candidate hit
    EOS / the limit inside this block.

    ``greedy_static=True`` (a trace-time promise that every row is
    greedy) takes a fully vectorized path: the greedy token is the raw
    argmax — independent of the repetition-penalty counts — so the whole
    accept chain collapses to a prefix scan over K positions instead of
    K sequential copies of the processing stack. Emitted tokens and
    logprobs are identical to the general path.
    """
    B, K, V = logits.shape
    if greedy_static:
        return _speculative_accept_greedy(logits, draft, cfg,
                                          token_counts=token_counts,
                                          bias=bias, eos_id=eos_id,
                                          n_tok=n_tok, limit=limit,
                                          active=active)
    neg = jnp.full((B,), -1, jnp.int32)
    alive = active
    counts = token_counts
    n = n_tok
    stopped = jnp.zeros((B,), bool)
    toks, lps, emits = [], [], []
    for i in range(K):
        lg = logits[:, i]
        proc = process_logits(lg, cfg, counts, bias)
        logp = jax.nn.log_softmax(proc, axis=-1)
        arg = jnp.argmax(lg, axis=-1).astype(jnp.int32)
        key = decode_step_key(base_key, step0 + i)
        d = draft[:, i] if i < K - 1 else neg
        has_d = d >= 0
        d_safe = jnp.maximum(d, 0)
        # acceptance draw + residual resample (the residual reduces to
        # the plain processed distribution when there is no draft, which
        # also covers the final free-sample position)
        p = jax.nn.softmax(proc, axis=-1)
        p_d = jnp.take_along_axis(p, d_safe[:, None], axis=-1)[:, 0]
        u = jax.random.uniform(jax.random.fold_in(key, 1), (B,))
        acc = has_d & (u < p_d)
        drop_d = (jnp.arange(V)[None, :] == d_safe[:, None]) & has_d[:, None]
        resampled = jax.random.categorical(
            key, jnp.where(drop_d, NEG_INF, proc), axis=-1).astype(jnp.int32)
        tok = jnp.where(greedy, arg, jnp.where(acc, d_safe, resampled))
        tok = tok.astype(jnp.int32)
        lp = jnp.take_along_axis(logp, tok[:, None], axis=-1)[:, 0]
        cont = jnp.where(greedy, has_d & (arg == d), acc)
        emit = alive
        n = n + emit.astype(jnp.int32)
        stop = emit & ((tok == eos_id) | (n >= limit))
        stopped = stopped | stop
        alive = alive & cont & ~stop
        counts = counts + jax.nn.one_hot(tok, V) * emit.astype(
            jnp.float32)[:, None]
        toks.append(tok)
        lps.append(lp)
        emits.append(emit)
    return (jnp.stack(toks, axis=1), jnp.stack(lps, axis=1),
            jnp.stack(emits, axis=1), counts, n, stopped)


def _speculative_accept_greedy(logits, draft, cfg: SamplingConfig, *,
                               token_counts, bias, eos_id, n_tok, limit,
                               active):
    """All-greedy ``speculative_accept``: one vectorized prefix scan.

    Greedy emits the raw argmax at every position, so the token choices
    are independent of the sequential count/alive chain; the chain only
    decides WHERE emission stops, and — because emission is always a
    prefix of the block — the position-i count state has the closed form
    ``counts0 + exclusive-cumsum(one_hot(emitted tokens))``.
    """
    B, K, V = logits.shape
    toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)        # (B, K)
    # continue past position i iff the draft predicted its argmax
    d = jnp.concatenate([draft, jnp.full((B, 1), -1, jnp.int32)], axis=1)
    cont = (d >= 0) & (toks == d)
    pos_i = jnp.arange(K)[None, :]
    stop_cond = (toks == eos_id) | (n_tok[:, None] + pos_i + 1
                                    >= limit[:, None])
    ok = cont & ~stop_cond
    # emit[:, i] <=> active and every position j < i continued
    blocked = jnp.cumsum(~ok, axis=1)
    emit = active[:, None] & jnp.concatenate(
        [jnp.ones((B, 1), bool), blocked[:, :-1] == 0], axis=1)
    emitf = emit.astype(jnp.float32)
    oh = jax.nn.one_hot(toks, V) * emitf[:, :, None]            # (B, K, V)
    pre = jnp.cumsum(oh, axis=1) - oh                           # exclusive
    counts_i = token_counts[:, None] + pre
    proc = process_logits(logits, cfg, counts_i,
                          bias[:, None] if bias is not None else None)
    logp = jax.nn.log_softmax(proc, axis=-1)
    lps = jnp.take_along_axis(logp, toks[:, :, None], axis=-1)[:, :, 0]
    counts = token_counts + jnp.sum(oh, axis=1)
    n = n_tok + jnp.sum(emit, axis=1).astype(jnp.int32)
    stopped = jnp.any(emit & stop_cond, axis=1)
    return toks, lps, emit, counts, n, stopped
