"""Sharding-rule unit tests + a small-mesh distributed integration test.

The 4-device mesh variant runs in a subprocess (forced host devices must
be set before jax initializes, and the main test process already owns the
single CPU device).
"""
import subprocess
import sys

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.config import INPUT_SHAPES
from repro.configs import get_config
from repro.distributed import sharding as shd
from repro.models import build_model


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)
        self.size = int(np.prod(list(shape.values())))


MESH1 = _FakeMesh({"data": 16, "model": 16})
MESH2 = _FakeMesh({"pod": 2, "data": 16, "model": 16})


def _specs(arch, mesh=MESH1):
    cfg = get_config(arch)
    model = build_model(cfg)
    p_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    return cfg, p_shapes, shd.param_specs(cfg, p_shapes, mesh)


def test_dense_rules_qwen3():
    cfg, shapes, specs = _specs("qwen3-0.6b")
    s = specs["super"][0]
    # col-parallel: wq output dim on model; FSDP on d
    assert s["attn"]["wq"]["kernel"] == P(None, "data", "model")
    # row-parallel: wo contracting dim on model, output dim replicated
    # (FSDP on the output dim batch-gathers the residual — §Perf iter 12)
    assert s["attn"]["wo"]["kernel"] == P(None, "model", None)
    # vocab over model only (never FSDP — see sharding.py comment)
    assert specs["embed"]["table"] == P("model", None)
    # norms replicated
    assert specs["final_norm"]["scale"] == P()


def test_divisibility_fallback_yi():
    """yi-34b: 56 q heads not divisible by model=16 ⇒ head dim of wq stays
    unsharded... but d_model FSDP still applies; d_ff 20480 divides."""
    cfg, shapes, specs = _specs("yi-34b")
    s = specs["super"][0]
    wq = s["attn"]["wq"]["kernel"]       # (d, 56*128=7168) 7168%16==0 -> model ok
    assert wq == P(None, "data", "model")
    wk = s["attn"]["wk"]["kernel"]       # (d, 8*128=1024): 1024%16==0
    assert wk == P(None, "data", "model")


def test_mqa_granite34b_cache_context_parallel():
    cfg = get_config("granite-34b")
    model = build_model(cfg)
    cache = jax.eval_shape(lambda: model.make_cache(128, 32768))
    specs = shd.cache_specs(cfg, cache, MESH1)
    kv = specs["super"][0]["k"]
    # kv=1 head can't shard ⇒ sequence dim context-parallel over model
    assert kv == P(None, "data", "model", None, None)


def test_moe_expert_sharding():
    cfg, shapes, specs = _specs("kimi-k2-1t-a32b")
    s = specs["super"][0]["moe"]
    assert s["w_gate"] == P(None, "data", None, "model")
    assert s["w_down"] == P(None, "data", "model", None)
    # granite-moe: 40 experts % 16 != 0 -> expert dim replicated
    cfg2, _, specs2 = _specs("granite-moe-3b-a800m")
    assert specs2["super"][0]["moe"]["w_gate"] == P(None, None, None, None) \
        or specs2["super"][0]["moe"]["w_gate"][1] is None


def test_multipod_dp_axes():
    assert shd.dp_axes(MESH2) == ("pod", "data")
    cfg = get_config("kimi-k2-1t-a32b")
    model = build_model(cfg)
    p_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = shd.param_specs(cfg, p_shapes, MESH2)
    # experts 384 % 32 == 0 -> sharded over both pod and data
    assert specs["super"][0]["moe"]["w_gate"][1] == ("pod", "data")


def test_serve_cache_specs_paged_pool():
    """Serving cache rules: paged pools shard on the PAGE axis over the
    data shards, block tables / pos on the decode batch; a serving mesh
    without a 'model' axis replicates head dims instead of raising."""
    cfg = get_config("qwen3-0.6b").reduced()
    model = build_model(cfg)
    cache = jax.eval_shape(
        lambda: model.make_paged_cache(8, 128, np.float32, page_size=16,
                                       num_pages=64))
    specs = shd.cache_specs(cfg, cache, MESH1)
    kp = specs["super"][0]["k_pages"]           # (n_super, P, ps, Hkv, hd)
    assert kp[1] in ("data", ("data",)) and kp[0] is None
    # batch 8 % data 16 != 0: block table / pos fall back to replicated
    assert specs["block_table"] == P(None, None)
    # 4-wide serving mesh (no 'model' axis — must replicate, not raise):
    # page axis AND decode-batch leaves shard on data
    serve_mesh = _FakeMesh({"data": 4})
    specs_dp = shd.cache_specs(cfg, cache, serve_mesh)
    assert specs_dp["super"][0]["k_pages"][1] in ("data", ("data",))
    assert specs_dp["block_table"][0] in ("data", ("data",))
    assert specs_dp["pos"][0] in ("data", ("data",))


def test_engine_state_specs_batch_sharding():
    """Every non-cache EngineState leaf shards on its leading (slot)
    dim; indivisible batch replicates."""
    import collections
    St = collections.namedtuple("St", ["cache", "last_token", "bias"])
    cache = {"pos": jax.ShapeDtypeStruct((8,), np.int32)}
    st = St(cache=cache,
            last_token=jax.ShapeDtypeStruct((8,), np.int32),
            bias=jax.ShapeDtypeStruct((8, 64), np.float32))
    mesh = _FakeMesh({"data": 4, "model": 1})
    cfg = get_config("qwen3-0.6b").reduced()
    specs = shd.engine_state_specs(cfg, st, mesh)
    assert specs.last_token[0] in ("data", ("data",))
    assert specs.bias == P("data", None) or \
        specs.bias[0] in ("data", ("data",))
    # 8 slots don't divide a 3-shard mesh: replicate, don't raise
    specs3 = shd.engine_state_specs(cfg, st, _FakeMesh({"data": 3}))
    assert specs3.last_token == P(None)


def test_batch_specs():
    shape = INPUT_SHAPES["train_4k"]
    batch = {"tokens": jax.ShapeDtypeStruct((256, 4096), np.int32),
             "labels": jax.ShapeDtypeStruct((256, 4096), np.int32)}
    specs = shd.batch_specs(shape, batch, MESH1)
    assert specs["tokens"][0] in ("data", ("data",))
    # batch=1 (long_500k) cannot shard
    b1 = {"token": jax.ShapeDtypeStruct((1,), np.int32)}
    specs1 = shd.batch_specs(INPUT_SHAPES["long_500k"], b1, MESH1)
    assert specs1["token"] == P(None)


DISTRIBUTED_SNIPPET = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.models import build_model
from repro.distributed import sharding as shd
from repro.launch.mesh import make_local_mesh
from repro.training.train_loop import make_train_step
from repro.training.optimizer import init_opt_state
from repro.config import TrainConfig

mesh = make_local_mesh((2, 2), ("data", "model"))
cfg = get_config("qwen3-0.6b").reduced().with_overrides(
    dtype="float32", vocab_size=512)
model = build_model(cfg, jnp.float32)
params = model.init(jax.random.PRNGKey(0))
opt = init_opt_state(params)
toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab_size)
batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}

step = make_train_step(model, TrainConfig(remat=True))
# single-device reference
p_ref, _, m_ref = jax.jit(step)(params, opt, batch)

p_spec = shd.param_specs(cfg, jax.eval_shape(lambda: params), mesh)
o_spec = shd.opt_state_specs(cfg, jax.eval_shape(lambda: opt), mesh)
from repro.config import INPUT_SHAPES
b_spec = shd.batch_specs(INPUT_SHAPES["train_4k"], batch, mesh)
sh = lambda t, s: jax.device_put(t, jax.tree.map(
    lambda x: NamedSharding(mesh, x), s,
    is_leaf=lambda x: isinstance(x, P)))
with mesh:
    p_d, o_d, b_d = sh(params, p_spec), sh(opt, o_spec), sh(batch, b_spec)
    p_new, o_new, m = jax.jit(step)(p_d, o_d, b_d)
print("LOSS", float(m["loss"]), float(m_ref["loss"]))
np.testing.assert_allclose(float(m["loss"]), float(m_ref["loss"]),
                           rtol=2e-3)
d = max(float(jnp.abs(a - b).max()) for a, b in
        zip(jax.tree.leaves(p_ref), jax.tree.leaves(jax.device_get(p_new))))
assert d < 2e-3, d
print("DISTRIBUTED_OK")
"""


@pytest.mark.slow
def test_distributed_train_step_matches_single_device():
    """2x2 mesh train step must reproduce the single-device step."""
    r = subprocess.run([sys.executable, "-c", DISTRIBUTED_SNIPPET],
                       capture_output=True, text=True, timeout=540,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu"})
    assert "DISTRIBUTED_OK" in r.stdout, r.stdout + r.stderr
