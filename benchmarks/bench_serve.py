"""Serving throughput — macro-step fused decode vs the per-token loop —
plus the coverage-aware traffic-scheduler scenario.

Measures the engine-level win of the device-resident decode loop
(``ServeEngine(macro_steps=K)``, a ``lax.while_loop`` over K
decode+sample+CAMD steps with pre-staged page frontiers) against the
legacy host loop (``macro_steps=0``): tokens/sec, wall-clock, and —
the quantity the refactor exists to shrink — host synchronizations per
generated token.

Grid: macro-step K ∈ {0 (per-token loop), 1, 8, 32} × impl ∈ {xla, paged}
× mode ∈ {camd, best_of_n}. Each cell warms up once (jit compile +
first-run allocation on a throwaway request batch), then times a fresh
request batch on the same engine so compiled functions are reused.
Every cell completes the same token work (fixed CAMD round budget, no
early eos, uniform bucketed prefill), so tokens/sec and us/token are
comparable across the grid; page size and the default K come from a
committed ``BENCH_autotune.json`` when present (``autotune.load_tuned``).

The **quantized scenario** serves the trained chain-oracle workload
greedily under kv_dtype ∈ {auto, fp32, int8, fp8†}: oracle accuracy per
storage mode, true resident-KV bytes (values + scales), and the
tolerance-0 stream identity (fp32 == auto byte-identical). †fp8 only
where the jax build has float8_e4m3fn.

The **speculative scenario** decodes a shared-prefix greedy workload
with the n-gram draft + block-verify loop on (``spec_k=4``) and off on
a deep-cache model, asserting byte-identical streams and recording the
decode-throughput speedup (gated at 1.5x by ``check_regression``) in a
``speculative`` section.

The **scheduler scenario** trains a small LM on the arithmetic-chain
oracle task, builds heavy-tailed traffic (Pareto-distributed chain
difficulty — many easy, few hard — over a shared page-aligned prompt
preamble) and serves the SAME workload under ``fifo`` and ``coverage``
policies at an equal global token budget, reporting oracle accuracy,
easy/hard token allocation, starvation, and prefix-cache reuse in a
``scheduler`` section of ``BENCH_serve.json``.

The **sharded scenario** (multi-device runtimes only — on CPU force
host devices with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``)
serves the same workload on a single device and on an N-way data
mesh (``ServeEngine(mesh=...)``: decode batch sharded on the data axis,
KV page pool on the page axis) and records throughput plus the hard
invariant — byte-identical token streams — in a ``sharded`` section.
Single-device runtimes record the section as skipped.

The **open-loop scenario** serves the heavy-tailed shared-prefix
workload through the async streaming front-end under Poisson and bursty
arrival processes (offered at 0.7x the measured closed-loop capacity),
recording SLO metrics — p50/p99 TTFT from scheduled arrival, p50/p99
per-output-token latency (both also bucketed by prompt length), goodput
at an adaptive TTFT SLO, and tokens/s at saturation — plus a
cancellation cell asserting the abort path returns every page, slot,
and byte of scheduler commitment, in an ``open_loop`` section.

The **multimodal scenario** serves a shared-image heavy-tailed workload
(most requests ask about the SAME hot image over a shared prompt
preamble — the retrieval/chat pattern image-prefix caching exists for)
through the vision-language engine with the image prefix cache off and
on, recording the image-prefix cache hit rate, vision-tower encode vs
feature-memo counts, prefill tokens actually computed, and TTFT with
and without image reuse — plus the deterministic gates: dense, paged,
and paged+cache streams byte-identical, the shared image must hit, and
the reuse cell must compute strictly fewer prefill tokens
(``multimodal`` section).

The **chunked-prefill scenario** saturates a small greedy engine with
short prompts and queues long prompts behind them, then serves the SAME
workload with chunked prefill off (``prefill_chunk=0``) and on (the
autotuned chunk size): with chunking on, the long prompts' page-aligned
chunks run while they are still *queued* — prefill overlaps the shorts'
decode instead of serializing after it — so the long-prompt TTFT bucket
must improve while streams stay byte-identical and decode tokens/s
stays within the regression tolerance (``chunked_prefill`` section).

Writes ``BENCH_serve.json``; ``--smoke`` runs a reduced grid for CI and
``--sections grid,open_loop`` limits the run to named sections.

  python -m benchmarks.bench_serve [--smoke] [--sections a,b,...]
"""
from __future__ import annotations

import json
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.config import (CAMDConfig, ModelConfig, PagedKVConfig,
                          SamplingConfig, TrainConfig)
from repro.data import ChainTask, lm_batches
from repro.data.synthetic import SEP
from repro.models import build_model
from repro.serving import Request, ServeEngine
from repro.training import train


def _bench_model():
    cfg = ModelConfig(
        name="bench-serve-lm", family="dense", num_layers=4, d_model=256,
        num_heads=4, num_kv_heads=2, d_ff=768, vocab_size=512,
        head_dim=64, tie_embeddings=True, dtype="float32")
    model = build_model(cfg, jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _submit(eng, cfg, n, uid0=0, seed=0, plen=12):
    rng = np.random.default_rng(seed)
    for i in range(n):
        eng.submit(Request(uid=uid0 + i, prompt=rng.integers(
            2, cfg.vocab_size, plen).astype(np.int32)))


def _assert_clean(eng):
    """Every timed cell must start from zeroed telemetry. Cells reuse
    one warm engine (recompiling per cell would put jit time on the
    clock), and an earlier version hand-reset an ad-hoc subset of
    counters — sched_stats()/kv_stats() numbers silently carried over
    from the warmup into the recorded rows. ``ServeEngine.reset_stats``
    now owns the full counter list; this asserts nothing leaks through.
    """
    assert eng.total_tokens == 0 and eng.total_steps == 0
    assert eng.macro_launches == 0 and eng.host_syncs == 0
    assert eng.spec_drafted == 0 and eng.spec_accepted == 0
    s = eng.sched_stats()
    assert s["admitted_candidates"] == 0 and s["prefill_calls"] == 0
    assert s["cancelled_requests"] == 0
    if eng.paged:
        k = eng.kv_stats()
        assert k["frontier_staged"] == 0 and k["frontier_peak_stage"] == 0


def _run_cell(cfg, model, params, *, impl, mode, macro_steps, requests,
              max_new, reps=3, page_size=16):
    """One equal-work grid cell.

    Every cell completes the IDENTICAL number of tokens, so tokens/sec
    is comparable across the whole grid: bucketed prefill is on for all
    K (an earlier version disabled it at K=0, which changed admission
    batching, hence sampled streams, hence early stopping — the
    committed baseline once compared 256-token cells against 192-token
    ones); min_samples pins CAMD to its full round budget; eos is an
    out-of-vocab id so no candidate stops early."""
    eng = ServeEngine(
        model, params, slots=8, cache_len=128,
        sampling=SamplingConfig(max_new_tokens=max_new, temperature=0.8),
        camd=CAMDConfig(samples_per_round=4, max_rounds=2, min_samples=8),
        mode=mode, n_candidates=4, max_new_tokens=max_new,
        eos_id=cfg.vocab_size,
        impl=impl, paged_kv=PagedKVConfig(page_size=page_size),
        macro_steps=macro_steps,
        bucket_prefill=True,
        seed=0)
    # warmup: compile every jitted fn on a throwaway batch of the SAME
    # size as the timed one (prefill buckets / admission widths are
    # shape-specialized — a mismatch would put recompiles on the clock)
    _submit(eng, cfg, requests, uid0=10_000, seed=1)
    eng.run()
    # best-of-reps: shared CI containers jitter wall clock by integer
    # factors between consecutive identical runs, so a single timed batch
    # regularly mis-ranks cells (the committed baseline once recorded the
    # paged macro-step loop "slower" than the per-token loop this way).
    # The max rate over identical-prompt batches is the stable statistic.
    best_rate, min_wall = 0.0, float("inf")
    for rep in range(reps):
        eng.reset_stats()
        _assert_clean(eng)
        _submit(eng, cfg, requests, uid0=1000 * (rep + 1), seed=2)
        t0 = time.perf_counter()
        eng.run()
        wall = time.perf_counter() - t0
        best_rate = max(best_rate, eng.total_tokens / max(wall, 1e-9))
        min_wall = min(min_wall, wall)
    return {
        "impl": impl,
        "mode": mode,
        "macro_steps": macro_steps,
        "reps": reps,
        "wall_s": min_wall,
        "tokens": eng.total_tokens,
        "device_steps": eng.total_steps,
        "tokens_per_s": best_rate,
        "us_per_token": 1e6 / max(best_rate, 1e-9),
        "host_syncs": eng.host_syncs,
        "syncs_per_token": eng.host_syncs / max(eng.total_tokens, 1),
        "macro_launches": eng.macro_launches,
    }


# ---------------------------------------------------------------------------
# Speculative scenario: n-gram draft + block verify vs sequential greedy
# ---------------------------------------------------------------------------

def _spec_model():
    """Small deep-cache model for the speculative scenario: decode cost
    is attention/KV-dominated, the regime speculation amortizes (the
    block verify reads the KV cache once per ~spec_k tokens instead of
    once per token)."""
    cfg = ModelConfig(
        name="bench-spec-lm", family="dense", num_layers=2, d_model=128,
        num_heads=4, num_kv_heads=2, d_ff=384, vocab_size=256,
        head_dim=32, tie_embeddings=True, dtype="float32")
    model = build_model(cfg, jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _run_spec_cell(model, params, *, impl, spec_k, requests, max_new,
                   cache_len, reps):
    """One speculative cell: greedy decode of a shared repetitive prompt.

    Greedy streams must be byte-identical spec on/off, so top-p /
    repetition-penalty are disabled — greedy emits the raw argmax and
    the nucleus sort would only burn time in both engines without
    touching the output."""
    eng = ServeEngine(
        model, params, slots=8, cache_len=cache_len,
        sampling=SamplingConfig(max_new_tokens=max_new, temperature=0.0,
                                top_p=1.0, repetition_penalty=1.0),
        mode="greedy", n_candidates=1, max_new_tokens=max_new, eos_id=1,
        impl=impl, paged_kv=PagedKVConfig(page_size=16),
        macro_steps=8, spec_k=spec_k, seed=0)

    def submit(uid0):
        # shared-prefix workload: every request decodes the same
        # repeated-token prompt (n-gram fuel from position 0)
        for i in range(requests):
            eng.submit(Request(uid=uid0 + i,
                               prompt=np.full(12, 7, np.int32)))

    submit(10_000)
    eng.run()                                  # warmup / compile
    best_rate, min_wall, streams = 0.0, float("inf"), None
    for rep in range(reps):
        eng.reset_stats()
        _assert_clean(eng)
        submit(1000 * (rep + 1))
        t0 = time.perf_counter()
        res = eng.run()
        wall = time.perf_counter() - t0
        best_rate = max(best_rate, eng.total_tokens / max(wall, 1e-9))
        min_wall = min(min_wall, wall)
        if rep == 0:
            streams = [[int(t) for t in r.tokens]
                       for r in sorted(res, key=lambda r: r.uid)]
    row = {
        "impl": impl,
        "spec_k": spec_k,
        "wall_s": min_wall,
        "tokens": eng.total_tokens,
        "device_steps": eng.total_steps,
        "tokens_per_s": best_rate,
        "drafted": eng.spec_drafted,
        "accepted": eng.spec_accepted,
        "acceptance": eng.spec_accepted / max(eng.spec_drafted, 1),
    }
    return row, streams


def run_speculative_scenario(smoke: bool = False) -> dict:
    """Greedy decode with the n-gram draft + block verify loop on vs
    off: streams must be byte-identical; decode throughput should gain
    >= 1.5x on the shared-prefix workload (gated by check_regression)."""
    cfg, model, params = _spec_model()
    del cfg
    requests = 6
    max_new, cache_len, reps = (240, 384, 2) if smoke else (360, 512, 3)
    rows, headline = [], {"equal_outputs": True}
    for impl in ["xla", "paged"]:
        base_row, base_streams = _run_spec_cell(
            model, params, impl=impl, spec_k=0, requests=requests,
            max_new=max_new, cache_len=cache_len, reps=reps)
        spec_row, spec_streams = _run_spec_cell(
            model, params, impl=impl, spec_k=4, requests=requests,
            max_new=max_new, cache_len=cache_len, reps=reps)
        same = base_streams == spec_streams
        headline["equal_outputs"] &= same
        speedup = spec_row["tokens_per_s"] / max(base_row["tokens_per_s"],
                                                 1e-9)
        headline[f"speedup_{impl}"] = speedup
        headline[f"acceptance_{impl}"] = spec_row["acceptance"]
        rows += [base_row, spec_row]
        print(f"spec   {impl:6s} k=4: {base_row['tokens_per_s']:8.1f} -> "
              f"{spec_row['tokens_per_s']:8.1f} tok/s ({speedup:.2f}x), "
              f"accept {spec_row['acceptance']:.0%}, "
              f"streams {'identical' if same else 'DIVERGED'}")
    return {"requests": requests, "max_new": max_new,
            "cache_len": cache_len, "rows": rows, "headline": headline}


# ---------------------------------------------------------------------------
# Sharded scenario: N-way mesh vs single device, identical streams
# ---------------------------------------------------------------------------

def _stream_digest(results):
    return [(r.uid, r.tokens.tolist(), r.tokens_spent, r.n_candidates)
            for r in sorted(results, key=lambda r: r.uid)]


def _run_sharded_cell(cfg, model, params, *, impl, mesh, requests, max_new,
                      macro_steps=8):
    eng = ServeEngine(
        model, params, slots=8, cache_len=128,
        sampling=SamplingConfig(max_new_tokens=max_new, temperature=0.8),
        camd=CAMDConfig(samples_per_round=4, max_rounds=2, min_samples=4),
        mode="camd", n_candidates=4, max_new_tokens=max_new, eos_id=1,
        impl=impl, paged_kv=PagedKVConfig(page_size=16),
        macro_steps=macro_steps, mesh=mesh, seed=0)
    _submit(eng, cfg, requests, uid0=10_000, seed=1)      # warmup/compile
    eng.run()
    eng.reset_stats()              # report measured traffic only
    _assert_clean(eng)
    _submit(eng, cfg, requests, uid0=0, seed=2)
    t0 = time.perf_counter()
    res = eng.run()
    wall = time.perf_counter() - t0
    row = {
        "impl": impl,
        "dp": eng.dp,
        "wall_s": wall,
        "tokens": eng.total_tokens,
        "tokens_per_s": eng.total_tokens / max(wall, 1e-9),
        "macro_launches": eng.macro_launches,
    }
    if eng.paged:
        row["admitted_per_shard"] = \
            eng.sched_stats().get("admitted_per_shard", {})
    return row, _stream_digest(res)


def run_sharded_scenario(smoke: bool = False) -> dict:
    """Single-device vs mesh-sharded serving on the same workload: the
    streams must be byte-identical; throughput is recorded per impl."""
    n_dev = jax.device_count()
    if n_dev < 2:
        return {"skipped": f"single {jax.default_backend()} device — set "
                           f"XLA_FLAGS=--xla_force_host_platform_device_"
                           f"count=8 to exercise the mesh path"}
    from repro.launch.mesh import make_serve_mesh
    dp = max(d for d in (2, 4, 8) if d <= n_dev)          # slots=8 divisible
    mesh = make_serve_mesh(dp)
    cfg, model, params = _bench_model()
    requests, max_new = (3, 16) if smoke else (6, 32)
    rows, identical = [], True
    for impl in ["xla", "paged"]:
        base_row, base_streams = _run_sharded_cell(
            cfg, model, params, impl=impl, mesh=None,
            requests=requests, max_new=max_new)
        mesh_row, mesh_streams = _run_sharded_cell(
            cfg, model, params, impl=impl, mesh=mesh,
            requests=requests, max_new=max_new)
        same = base_streams == mesh_streams
        identical &= same
        rows += [base_row, mesh_row]
        print(f"sharded {impl:6s} dp={dp}: "
              f"{base_row['tokens_per_s']:8.1f} -> "
              f"{mesh_row['tokens_per_s']:8.1f} tok/s, "
              f"streams {'identical' if same else 'DIVERGED'}")
    return {"devices": n_dev, "dp": dp, "backend": jax.default_backend(),
            "rows": rows, "streams_identical": identical}


# ---------------------------------------------------------------------------
# Scheduler scenario: heavy-tailed difficulty at an equal global budget
# ---------------------------------------------------------------------------

CHAIN_BASE = 16

_CHAIN_MODELS: dict = {}    # steps -> (cfg, model, params); the scheduler
                            # and quantized scenarios share one training run


def _train_chain_model(steps: int):
    if steps in _CHAIN_MODELS:
        return _CHAIN_MODELS[steps]
    cfg = ModelConfig(
        name="bench-sched-lm", family="dense", num_layers=2, d_model=128,
        num_heads=4, num_kv_heads=2, d_ff=384, vocab_size=64, head_dim=32,
        tie_embeddings=True, dtype="float32")
    model = build_model(cfg, jnp.float32)
    data = ({"tokens": jnp.asarray(b["tokens"]),
             "labels": jnp.asarray(b["labels"])}
            for b in lm_batches(cfg.vocab_size, 16, 48, seed=0,
                                base=CHAIN_BASE, max_chain=3))
    params, _, _ = train(
        model, TrainConfig(total_steps=steps, warmup_steps=steps // 10,
                           learning_rate=3e-3, remat=False),
        data, steps=steps, log_every=steps)
    _CHAIN_MODELS[steps] = (cfg, model, params)
    return cfg, model, params


def _heavy_tail_requests(task: ChainTask, n: int, seed: int = 7):
    """Pareto difficulty mix (many chain_len 0, few 3) over a shared
    page-aligned-ish preamble of solved segments (in-distribution for
    the trained LM, and 14 tokens => one full page at page_size 8 for
    the prefix cache to reuse)."""
    rng = np.random.default_rng(seed)

    def seg(k):
        p, ans, _ = task.sample(rng, chain_len=k)
        return np.concatenate([p, [ans, SEP]])

    preamble = np.concatenate([seg(2), seg(2)]).astype(np.int32)
    reqs = []
    for _ in range(n):
        k = min(3, int(rng.pareto(1.0)))
        p, ans, _ = task.sample(rng, chain_len=k)
        reqs.append((np.concatenate([preamble, p]).astype(np.int32),
                     int(ans), int(k)))
    return reqs


def _serve_policy(model, params, reqs, *, policy, budget):
    eng = ServeEngine(
        model, params, slots=4, cache_len=64,
        sampling=SamplingConfig(temperature=1.0, top_p=0.95,
                                repetition_penalty=1.0, max_new_tokens=3),
        camd=CAMDConfig(samples_per_round=2, max_rounds=4, min_samples=2,
                        delta=0.05, score_scale=3.0, lambda_c=0.2,
                        guidance_strength=0.5),
        mode="camd", n_candidates=8, eos_id=1, max_new_tokens=3,
        impl="paged", paged_kv=PagedKVConfig(page_size=8),
        sched_policy=policy, global_budget=budget, prefix_cache=True,
        seed=0)
    for i, (p, _ans, _k) in enumerate(reqs):
        eng.submit(Request(uid=i, prompt=p))
    res = {r.uid: r for r in eng.run()}
    acc = float(np.mean([
        len(res[i].tokens) > 0 and int(res[i].tokens[0]) == reqs[i][1]
        for i in range(len(reqs))]))
    easy_ids = [i for i in range(len(reqs)) if reqs[i][2] <= 1]
    hard_ids = [i for i in range(len(reqs)) if reqs[i][2] >= 2]
    served_easy = [res[i].tokens_spent for i in easy_ids
                   if res[i].tokens_spent > 0]
    row = {
        "policy": policy,
        "global_budget": budget,
        "accuracy": acc,
        "total_tokens": eng.total_tokens,
        "easy_tokens": int(sum(res[i].tokens_spent for i in easy_ids)),
        "hard_tokens": int(sum(res[i].tokens_spent for i in hard_ids)),
        "easy_tokens_per_served": float(np.mean(served_easy))
        if served_easy else 0.0,
        "served": int(sum(res[i].tokens_spent > 0 for i in res)),
        "sched": eng.sched_stats(),
        "prefix_cache": eng.kv_stats().get("prefix_cache"),
    }
    return row


def run_scheduler_scenario(smoke: bool = False) -> dict:
    """fifo vs coverage on heavy-tailed traffic at equal token budget."""
    steps = 240 if smoke else 300
    n_req = 12 if smoke else 16
    cfg, model, params = _train_chain_model(steps)
    reqs = _heavy_tail_requests(ChainTask(base=CHAIN_BASE), n_req)
    # unbudgeted fifo reference sets the equal budget for the comparison
    ref = _serve_policy(model, params, reqs, policy="fifo", budget=0)
    budget = max(2, int(0.72 * ref["total_tokens"]))
    rows = [ref]
    for policy in ("fifo", "coverage"):
        row = _serve_policy(model, params, reqs, policy=policy,
                            budget=budget)
        rows.append(row)
        print(f"sched {policy:9s} @ budget {budget}: "
              f"acc={row['accuracy']:.3f} "
              f"easy/served={row['easy_tokens_per_served']:.1f} "
              f"starved={row['sched']['starved']}")
    out = {
        "n_requests": n_req,
        "difficulty_mix": [k for _, _, k in reqs],
        "train_steps": steps,
        "equal_budget": budget,
        "rows": rows,
    }
    fifo_b = next(r for r in rows[1:] if r["policy"] == "fifo")
    cov_b = next(r for r in rows[1:] if r["policy"] == "coverage")
    out["headline"] = {
        "accuracy_fifo": fifo_b["accuracy"],
        "accuracy_coverage": cov_b["accuracy"],
        "easy_per_served_fifo": fifo_b["easy_tokens_per_served"],
        "easy_per_served_coverage": cov_b["easy_tokens_per_served"],
        "coverage_beats_fifo":
            cov_b["accuracy"] >= fifo_b["accuracy"] and
            cov_b["easy_tokens_per_served"] <
            fifo_b["easy_tokens_per_served"],
    }
    return out


# ---------------------------------------------------------------------------
# Quantized-KV scenario: int8/fp8 pools vs fp32 on a trained oracle task
# ---------------------------------------------------------------------------

def _serve_quantized(model, params, reqs, *, kv_dtype):
    """Greedy CAMD-engine serve of the chain-oracle workload against one
    KV storage mode; accuracy is exact-match on the oracle answer, so a
    quantization-induced quality loss is directly visible."""
    eng = ServeEngine(
        model, params, slots=4, cache_len=64,
        sampling=SamplingConfig(temperature=0.0, top_p=1.0,
                                repetition_penalty=1.0, max_new_tokens=3),
        mode="greedy", n_candidates=1, eos_id=1, max_new_tokens=3,
        impl="paged", paged_kv=PagedKVConfig(page_size=8,
                                             kv_dtype=kv_dtype),
        macro_steps=8, seed=0)
    for i, (p, _ans, _k) in enumerate(reqs):
        eng.submit(Request(uid=i, prompt=p))
    res = {r.uid: r for r in eng.run()}
    acc = float(np.mean([
        len(res[i].tokens) > 0 and int(res[i].tokens[0]) == reqs[i][1]
        for i in range(len(reqs))]))
    s = eng.kv_stats()
    return {
        "kv_dtype": kv_dtype,
        "accuracy": acc,
        "bytes_per_page": s["bytes_per_page"],
        "peak_kv_bytes": s["peak_kv_bytes"],
        "dense_equiv_bytes": s["dense_equiv_bytes"],
    }, [[int(t) for t in res[i].tokens] for i in range(len(reqs))]


def run_quantized_scenario(smoke: bool = False) -> dict:
    """Quantized paged-KV storage modes on the trained chain-oracle
    workload (shared with the scheduler scenario): greedy accuracy per
    kv_dtype, true resident-KV bytes, and the tolerance-0 stream
    identity (fp32 == auto on an fp32 engine). check_regression gates
    int8 bytes <= 0.55x fp32 and the accuracy delta."""
    from repro.models.attention import FP8_DTYPE
    steps = 240 if smoke else 300
    n_req = 12 if smoke else 16
    cfg, model, params = _train_chain_model(steps)
    del cfg
    reqs = _heavy_tail_requests(ChainTask(base=CHAIN_BASE), n_req)
    dtypes = ["auto", "fp32", "int8"] + (["fp8"] if FP8_DTYPE else [])
    rows, streams = [], {}
    for kvd in dtypes:
        row, st = _serve_quantized(model, params, reqs, kv_dtype=kvd)
        rows.append(row)
        streams[kvd] = st
        print(f"quant  {kvd:5s}: acc={row['accuracy']:.3f} "
              f"bytes/page={row['bytes_per_page']} "
              f"peak={row['peak_kv_bytes']}")
    by = {r["kv_dtype"]: r for r in rows}
    headline = {
        "fp32_identical_to_auto": streams["fp32"] == streams["auto"],
        "accuracy_fp32": by["fp32"]["accuracy"],
        "accuracy_int8": by["int8"]["accuracy"],
        "accuracy_delta_int8": by["fp32"]["accuracy"]
        - by["int8"]["accuracy"],
        "bytes_ratio_int8": by["int8"]["bytes_per_page"]
        / by["fp32"]["bytes_per_page"],
        "resident_ratio_int8": by["int8"]["peak_kv_bytes"]
        / max(by["fp32"]["peak_kv_bytes"], 1),
    }
    if "fp8" in by:
        headline["accuracy_fp8"] = by["fp8"]["accuracy"]
        headline["bytes_ratio_fp8"] = by["fp8"]["bytes_per_page"] \
            / by["fp32"]["bytes_per_page"]
    return {"n_requests": n_req, "train_steps": steps, "rows": rows,
            "headline": headline}


# ---------------------------------------------------------------------------
# Open-loop scenario: SLO metrics under Poisson / bursty arrivals
# ---------------------------------------------------------------------------

def _open_loop_engine(model, params, *, max_new):
    """Greedy paged engine for the open-loop cells. Greedy streams are
    schedule-invariant (one deterministic candidate per request), so the
    open-loop runs — whatever admission order the arrival process
    produces — must reproduce the closed-loop reference streams
    byte-for-byte. eos is out-of-vocab so every request emits exactly
    ``max_new`` tokens (equal work across cells)."""
    return ServeEngine(
        model, params, slots=4, cache_len=64,
        sampling=SamplingConfig(temperature=0.0, top_p=1.0,
                                repetition_penalty=1.0,
                                max_new_tokens=max_new),
        mode="greedy", n_candidates=1, eos_id=model.cfg.vocab_size,
        max_new_tokens=max_new,
        impl="paged", paged_kv=PagedKVConfig(page_size=8),
        prefix_cache=True, macro_steps=4, seed=0)


def run_open_loop_scenario(smoke: bool = False) -> dict:
    """Open-loop arrivals over the heavy-tailed shared-prefix workload.

    A closed-loop reference run (all requests pre-staged) measures
    capacity and pins the golden streams; open-loop cells then offer the
    SAME requests as a Poisson and a bursty arrival process at 0.7x the
    measured capacity (queueing counts against TTFT), plus a saturation
    cell (every arrival at t=0) for tokens/s under full queueing and a
    cancellation cell (a third of the clients disconnect after their
    first streamed token) asserting the abort path leaks nothing."""
    from repro.serving.traffic import (ARRIVALS, poisson_arrivals,
                                       run_open_loop)
    steps = 240 if smoke else 300
    n_req = 10 if smoke else 16
    max_new = 8 if smoke else 16
    cfg, model, params = _train_chain_model(steps)
    del cfg
    prompts = [p for p, _ans, _k in
               _heavy_tail_requests(ChainTask(base=CHAIN_BASE), n_req)]
    eng = _open_loop_engine(model, params, max_new=max_new)

    def reqs(uid0):
        return [Request(uid=uid0 + i, prompt=p)
                for i, p in enumerate(prompts)]

    for r in reqs(10_000):                    # warmup / compile
        eng.submit(r)
    eng.run()
    eng.reset_stats()
    _assert_clean(eng)
    for r in reqs(0):                         # closed-loop reference
        eng.submit(r)
    t0 = time.perf_counter()
    ref = {r.uid: [int(t) for t in r.tokens] for r in eng.run()
           if r.uid < 10_000}
    closed_wall = time.perf_counter() - t0
    closed_rate = n_req / max(closed_wall, 1e-9)
    closed_tok_s = eng.total_tokens / max(closed_wall, 1e-9)
    # adaptive SLO: 4x the closed-loop per-request wall, floored at
    # 250ms — machine-relative, so the gate survives slow CI containers
    slo_ms = max(250.0, 4e3 * closed_wall / n_req)
    rate = 0.7 * closed_rate
    rows, match_all, completed_all = [], True, True
    for name in ("poisson", "bursty", "saturation"):
        uid0 = {"poisson": 1000, "bursty": 2000, "saturation": 3000}[name]
        arr = np.zeros(n_req) if name == "saturation" \
            else ARRIVALS[name](rate, n_req, seed=11)
        eng.reset_stats()
        _assert_clean(eng)
        traces, metrics = run_open_loop(eng, reqs(uid0), arr,
                                        slo_ttft_ms=slo_ms,
                                        length_buckets=(18,))
        same = all(ref[tr.uid - uid0] ==
                   [int(t) for t in eng.result(tr.uid).tokens]
                   for tr in traces)
        match_all &= same
        completed_all &= metrics["completed"] == n_req
        rows.append({"arrival": name, "rate_rps": rate,
                     "streams_match": same, **metrics})
        print(f"open   {name:10s}: ttft p99 {metrics['ttft_p99_ms']:7.1f}ms"
              f"  goodput {metrics['goodput_rps']:.2f} rps"
              f"  {metrics['tokens_per_s']:7.1f} tok/s"
              f"  streams {'identical' if same else 'DIVERGED'}")
    # cancellation cell: every third client disconnects after its first
    # streamed token; afterwards the engine must hold NOTHING beyond the
    # resident prefix cache — no leaked pages, slots, or commitment
    cancel_uids = tuple(4000 + i for i in range(0, n_req, 3))
    eng.reset_stats()
    _assert_clean(eng)
    traces, metrics = run_open_loop(
        eng, reqs(4000), poisson_arrivals(rate, n_req, seed=13),
        slo_ttft_ms=slo_ms, cancel_uids=cancel_uids, cancel_after_tokens=1,
        length_buckets=(18,))
    survivors_match = all(
        ref[tr.uid - 4000] == [int(t) for t in eng.result(tr.uid).tokens]
        for tr in traces if not tr.cancelled)
    resident = len(eng.pool.prefix._nodes) if eng.pool.prefix else 0
    eng.pool.check()
    no_leaks = (eng.scheduler.committed == 0
                and eng.pool.in_use == resident
                and all(eng._slot_req[s] == -1 for s in range(eng.B))
                and metrics["cancelled"] == len(cancel_uids))
    match_all &= survivors_match
    rows.append({"arrival": "poisson+cancel", "rate_rps": rate,
                 "streams_match": survivors_match, "no_leaks": no_leaks,
                 **metrics})
    print(f"open   cancel    : {metrics['cancelled']} aborted, "
          f"{'no leaks' if no_leaks else 'LEAKED STATE'}")
    pois = rows[0]
    return {
        "n_requests": n_req, "max_new": max_new, "train_steps": steps,
        "slo_ttft_ms": slo_ms, "offered_rate_rps": rate,
        "closed_loop": {"wall_s": closed_wall,
                        "requests_per_s": closed_rate,
                        "tokens_per_s": closed_tok_s},
        "rows": rows,
        "headline": {
            "streams_match_closed_loop": match_all,
            "completed_all": completed_all,
            "no_leaks_after_cancel": no_leaks,
            "ttft_p99_ms": pois["ttft_p99_ms"],
            "ttft_by_bucket": pois["ttft_by_bucket"],
            "tpot_p99_ms": pois["tpot_p99_ms"],
            "goodput_rps": pois["goodput_rps"],
            "tokens_per_s_saturation": rows[2]["tokens_per_s"],
            "tokens_per_s_closed": closed_tok_s,
        },
    }


# ---------------------------------------------------------------------------
# Multimodal scenario: shared-image heavy-tailed traffic, image-prefix reuse
# ---------------------------------------------------------------------------

def _vlm_model():
    """Reduced vision-language model (llava-family): a real vision tower
    feeding image-token embeddings through the engine's prefill path."""
    from repro.configs import get_config
    cfg = get_config("llava_1_5_7b").reduced().with_overrides(
        dtype="float32")
    model = build_model(cfg, jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _vlm_image(cfg, idx):
    v = cfg.vision
    rng = np.random.default_rng(1000 + idx)
    return rng.standard_normal(
        (v.image_h, v.image_w, v.channels)).astype(np.float32)


def _multimodal_requests(cfg, n, seed=9):
    """Heavy-tailed image popularity over a shared prompt preamble:
    Pareto-distributed image ids (most requests share image 0, a short
    tail brings fresh ones) with a common 24-token preamble and a short
    per-request question tail — exactly the shape where the content-hash
    image prefix cache converts repeat images into skipped prefill."""
    rng = np.random.default_rng(seed)
    preamble = rng.integers(2, cfg.vocab_size, 24).astype(np.int32)
    reqs = []
    for _ in range(n):
        img_id = min(int(rng.pareto(1.0)), 3)
        tail = rng.integers(2, cfg.vocab_size,
                            int(rng.integers(2, 6))).astype(np.int32)
        reqs.append((np.concatenate([preamble, tail]).astype(np.int32),
                     img_id))
    return reqs, len({im for _, im in reqs})


def _run_multimodal_cell(cfg, model, params, reqs, *, impl, prefix_cache,
                         max_new, uid0):
    """One multimodal cell: greedy open-loop serve (all arrivals at t=0)
    of the shared-image workload. Greedy + fifo means every cell must
    stream byte-identically whatever the cache/impl — the image arm of
    the paged differential discipline. Returns (row, streams) keyed by
    request index. Image-encode counters are read after submit (the
    vision tower runs at submit time): on a cold engine they pin the
    tower-encode vs feature-memo split deterministically."""
    from repro.serving.traffic import run_open_loop
    images = {im: _vlm_image(cfg, im) for _, im in reqs}
    eng = ServeEngine(
        model, params, slots=4, cache_len=128,
        sampling=SamplingConfig(temperature=0.0, top_p=1.0,
                                repetition_penalty=1.0,
                                max_new_tokens=max_new),
        mode="greedy", n_candidates=1, max_new_tokens=max_new,
        eos_id=cfg.vocab_size,
        impl=impl, paged_kv=PagedKVConfig(page_size=8),
        prefix_cache=prefix_cache, macro_steps=4, seed=0)

    def mk(base):
        return [Request(uid=base + i, prompt=p, image=images[im])
                for i, (p, im) in enumerate(reqs)]

    for r in mk(uid0 + 10_000):               # warmup / compile
        eng.submit(r)
    cold_encodes, cold_hits = eng.image_encodes, eng.image_feat_hits
    eng.run()
    eng.reset_stats()
    _assert_clean(eng)
    traces, metrics = run_open_loop(eng, mk(uid0), np.zeros(len(reqs)),
                                    slo_ttft_ms=1e9)
    streams = {tr.uid - uid0: [int(t) for t in eng.result(tr.uid).tokens]
               for tr in traces}
    pc = None
    if eng.paged:
        eng.pool.check()
        pc = eng.kv_stats().get("prefix_cache")
    row = {
        "impl": impl,
        "prefix_cache": bool(prefix_cache),
        "image_encodes_cold": cold_encodes,
        "image_feat_hits_cold": cold_hits,
        "prefill_tokens": eng.prefill_tokens,
        "image_prefix": pc,
        **metrics,
    }
    return row, streams


def run_multimodal_scenario(smoke: bool = False) -> dict:
    """Shared-image heavy-tailed traffic through the vision-language
    engine: dense vs paged vs paged+image-prefix-cache. All three cells
    must stream byte-identically (greedy); the cache cell must hit on
    the shared image and compute strictly fewer prefill tokens; TTFT
    with/without image reuse is recorded (wall-clock, not gated)."""
    cfg, model, params = _vlm_model()
    n_req, max_new = (8, 8) if smoke else (12, 16)
    reqs, n_imgs = _multimodal_requests(cfg, n_req)
    cells = [("xla", False), ("paged", False), ("paged", True)]
    rows, streams = [], {}
    for i, (impl, pc) in enumerate(cells):
        row, st = _run_multimodal_cell(
            cfg, model, params, reqs, impl=impl, prefix_cache=pc,
            max_new=max_new, uid0=100_000 * (i + 1))
        rows.append(row)
        streams[(impl, pc)] = st
        hits = (row["image_prefix"] or {}).get("hit_tokens", 0)
        print(f"mmodal {impl:6s} cache={'on ' if pc else 'off'}: "
              f"prefill {row['prefill_tokens']:4d} tok  "
              f"hit_tokens {hits:4d}  "
              f"ttft p50 {row['ttft_p50_ms']:6.1f}ms  "
              f"{row['tokens_per_s']:7.1f} tok/s")
    off = next(r for r in rows if r["impl"] == "paged"
               and not r["prefix_cache"])
    on = next(r for r in rows if r["prefix_cache"])
    pc = on["image_prefix"] or {}
    identical = (streams[("xla", False)] == streams[("paged", False)]
                 == streams[("paged", True)])
    headline = {
        "streams_identical": identical,
        "n_requests": n_req,
        "distinct_images": n_imgs,
        "image_encodes_cold": on["image_encodes_cold"],
        "image_feat_hits_cold": on["image_feat_hits_cold"],
        "image_prefix_hits": pc.get("hits", 0),
        "image_prefix_hit_tokens": pc.get("hit_tokens", 0),
        "image_prefix_hit_rate": pc.get("hits", 0)
        / max(pc.get("probes", 0), 1),
        "prefill_tokens_no_reuse": off["prefill_tokens"],
        "prefill_tokens_reuse": on["prefill_tokens"],
        "prefill_reuse_savings": 1.0 - on["prefill_tokens"]
        / max(off["prefill_tokens"], 1),
        "ttft_p50_no_reuse_ms": off["ttft_p50_ms"],
        "ttft_p50_reuse_ms": on["ttft_p50_ms"],
        "ttft_p99_no_reuse_ms": off["ttft_p99_ms"],
        "ttft_p99_reuse_ms": on["ttft_p99_ms"],
        "ttft_reuse_improvement": off["ttft_p50_ms"]
        / max(on["ttft_p50_ms"], 1e-9),
    }
    return {"n_requests": n_req, "max_new": max_new,
            "distinct_images": n_imgs,
            "image_tokens": cfg.num_evidence_tokens,
            "rows": rows, "headline": headline}


# ---------------------------------------------------------------------------
# Chunked-prefill scenario: long-prompt TTFT under short-prompt load
# ---------------------------------------------------------------------------

CHUNK_BUCKETS = (32, 96)      # prompt-length buckets: lt32 / 32to96 / ge96


def _mixed_length_prompts(n_long, n_short, *, vocab, long_len=1024,
                          short_len=8, seed=5):
    """Head-of-line workload for the chunked-prefill A/B: ``n_long``
    long prompts listed FIRST, then ``n_short`` short prompts behind
    them. All arrivals at t=0 and every request fits in a slot, so fifo
    admission pins the order and the only variable is whether the short
    prompts' admission (and everyone's first token) must wait for the
    long prompts' monolithic prefills — with chunking on, the longs are
    budget-paced chunk jobs and the shorts are admitted around them."""
    rng = np.random.default_rng(seed)
    return [rng.integers(2, vocab, long_len).astype(np.int32)
            for _ in range(n_long)] + \
           [rng.integers(2, vocab, short_len).astype(np.int32)
            for _ in range(n_short)]


def _run_chunked_cell(model, params, prompts, *, chunk, max_new,
                      slo_ms=1e9, uid0=0):
    """One chunked-prefill cell: greedy open-loop serve of ``prompts``
    (everything offered at t=0) with ``prefill_chunk=chunk``. Returns
    (row, streams) — streams keyed by request index so cells with
    different uid bases compare directly. Greedy + fifo means the token
    streams must be byte-identical for every chunk size including 0."""
    from repro.serving.traffic import run_open_loop
    cache_len = -(-(max(len(p) for p in prompts) + max_new) // 64) * 64
    eng = ServeEngine(
        model, params, slots=len(prompts), cache_len=cache_len,
        sampling=SamplingConfig(temperature=0.0, top_p=1.0,
                                repetition_penalty=1.0,
                                max_new_tokens=max_new),
        mode="greedy", n_candidates=1, max_new_tokens=max_new,
        eos_id=model.cfg.vocab_size,
        impl="paged", paged_kv=PagedKVConfig(page_size=8),
        macro_steps=4, prefill_chunk=chunk, seed=0)
    for i, p in enumerate(prompts):               # warmup / compile
        eng.submit(Request(uid=uid0 + 10_000 + i, prompt=p))
    eng.run()
    eng.reset_stats()
    _assert_clean(eng)
    reqs = [Request(uid=uid0 + i, prompt=p) for i, p in enumerate(prompts)]
    traces, metrics = run_open_loop(
        eng, reqs, np.zeros(len(reqs)), slo_ttft_ms=slo_ms,
        length_buckets=CHUNK_BUCKETS)
    streams = {tr.uid - uid0: [int(t) for t in eng.result(tr.uid).tokens]
               for tr in traces}
    eng.pool.check()
    s = eng.sched_stats()
    row = {
        "prefill_chunk": chunk,
        "chunk_calls": s.get("chunk_calls", 0),
        "chunk_tokens": s.get("chunk_tokens", 0),
        "prefill_calls": s["prefill_calls"],
        **metrics,
    }
    return row, streams


def run_chunked_prefill_scenario(smoke: bool = False, *,
                                 chunk: int = 256) -> dict:
    """Chunked prefill off vs on on the head-of-line workload.

    Streams must be byte-identical (greedy + fifo). With chunking on,
    the short-prompt (lt32) TTFT bucket must improve sharply — shorts
    stop queueing behind whole-prompt prefills — while the long-prompt
    (ge96) p50 improves (the first long finishes its own prefill before
    the others' rather than after) and its p99 plus decode tokens/s stay
    within the regression tolerance (the budget-paced tail long pays a
    bounded pacing cost on a serial backend)."""
    cfg, model, params = _spec_model()
    # the long prompts stay 1024 tokens even in smoke: the head-of-line
    # effect the gates measure scales with prefill cost, and 512-token
    # longs on the tiny model drown it in per-chunk dispatch overhead
    n_long, n_short, long_len, max_new = \
        (2, 4, 1024, 12) if smoke else (2, 4, 1024, 24)
    # at least two chunks per long prompt, whatever autotune picked
    chunk = min(chunk, long_len // 2)
    prompts = _mixed_length_prompts(n_long, n_short, vocab=cfg.vocab_size,
                                    long_len=long_len)
    rows, streams = [], {}
    for c in (0, chunk):
        row, st = _run_chunked_cell(model, params, prompts, chunk=c,
                                    max_new=max_new, uid0=c * 1000)
        rows.append(row)
        streams[c] = st
        b = row["ttft_by_bucket"]
        print(f"chunk  c={c:<3d}: long p50/p99 "
              f"{b['ge96']['p50_ms']:6.1f}/{b['ge96']['p99_ms']:6.1f}ms  "
              f"short p99 {b['lt32']['p99_ms']:6.1f}ms  "
              f"{row['tokens_per_s']:7.1f} tok/s  "
              f"{row['chunk_calls']} chunk calls")
    off = next(r for r in rows if r["prefill_chunk"] == 0)
    on = next(r for r in rows if r["prefill_chunk"] == chunk)

    def bucket(row, name, q):
        return row["ttft_by_bucket"][name][q]

    headline = {
        "prefill_chunk": chunk,
        "streams_identical": streams[0] == streams[chunk],
        "chunk_calls": on["chunk_calls"],
        "chunk_tokens": on["chunk_tokens"],
        "ttft_p99_short_off_ms": bucket(off, "lt32", "p99_ms"),
        "ttft_p99_short_on_ms": bucket(on, "lt32", "p99_ms"),
        "ttft_short_improvement": bucket(off, "lt32", "p99_ms")
        / max(bucket(on, "lt32", "p99_ms"), 1e-9),
        "ttft_p50_long_off_ms": bucket(off, "ge96", "p50_ms"),
        "ttft_p50_long_on_ms": bucket(on, "ge96", "p50_ms"),
        "ttft_p99_long_off_ms": bucket(off, "ge96", "p99_ms"),
        "ttft_p99_long_on_ms": bucket(on, "ge96", "p99_ms"),
        "ttft_long_p99_ratio": bucket(on, "ge96", "p99_ms")
        / max(bucket(off, "ge96", "p99_ms"), 1e-9),
        "tokens_per_s_off": off["tokens_per_s"],
        "tokens_per_s_on": on["tokens_per_s"],
        "decode_ratio": on["tokens_per_s"] / max(off["tokens_per_s"],
                                                 1e-9),
    }
    return {"n_long": n_long, "n_short": n_short, "long_len": long_len,
            "max_new": max_new, "length_buckets": list(CHUNK_BUCKETS),
            "rows": rows, "headline": headline}


ALL_SECTIONS = ("grid", "speculative", "scheduler", "quantized", "sharded",
                "open_loop", "chunked_prefill", "multimodal")


def run(smoke: bool = False, sections=None) -> dict:
    cfg, model, params = _bench_model()
    from benchmarks.autotune import load_tuned
    tuned = load_tuned()["serve"]
    sections = tuple(sections) if sections else ALL_SECTIONS
    unknown = set(sections) - set(ALL_SECTIONS)
    if unknown:
        raise SystemExit(f"unknown bench sections {sorted(unknown)}; "
                         f"choose from {ALL_SECTIONS}")
    if smoke:
        impls, modes, ks = ["xla", "paged"], ["camd"], [0, 8]
        requests, max_new = 3, 16
    else:
        impls, modes, ks = ["xla", "paged"], ["camd", "best_of_n"], \
            [0, 1, 8, 32]
        requests, max_new = 6, 32
    # a committed autotune artifact shifts the default operating point
    if tuned["macro_steps"] not in ks:
        ks = sorted(ks + [tuned["macro_steps"]])
    out = {"config": {"smoke": smoke, "requests": requests,
                      "max_new": max_new, "slots": 8,
                      "page_size": tuned["page_size"],
                      "tuned": tuned,
                      "backend": jax.default_backend(),
                      "jax_version": jax.__version__,
                      "sections": list(sections)}}
    rows = []
    if "grid" in sections:
        for impl in impls:
            for mode in modes:
                for k in ks:
                    row = _run_cell(cfg, model, params, impl=impl,
                                    mode=mode, macro_steps=k,
                                    requests=requests, max_new=max_new,
                                    page_size=tuned["page_size"])
                    rows.append(row)
                    print(f"{impl:6s} {mode:10s} K={k:<3d} "
                          f"{row['tokens_per_s']:9.1f} tok/s  "
                          f"{row['syncs_per_token']:.4f} syncs/tok  "
                          f"wall {row['wall_s']:.2f}s")
        # headline: fused-vs-legacy speedup per (impl, mode)
        speedups = {}
        for impl in impls:
            for mode in modes:
                base = next(r for r in rows if r["impl"] == impl
                            and r["mode"] == mode
                            and r["macro_steps"] == ks[0])
                best = max((r for r in rows if r["impl"] == impl
                            and r["mode"] == mode),
                           key=lambda r: r["tokens_per_s"])
                speedups[f"{impl}/{mode}"] = {
                    "best_k": best["macro_steps"],
                    "tokens_per_s_legacy": base["tokens_per_s"],
                    "tokens_per_s_best": best["tokens_per_s"],
                    "speedup": best["tokens_per_s"]
                    / max(base["tokens_per_s"], 1e-9),
                    "sync_reduction":
                        base["syncs_per_token"]
                        / max(best["syncs_per_token"], 1e-9),
                }
        out["rows"], out["speedups"] = rows, speedups
    if "speculative" in sections:
        out["speculative"] = run_speculative_scenario(smoke)
    if "scheduler" in sections:
        out["scheduler"] = run_scheduler_scenario(smoke)
    if "quantized" in sections:
        out["quantized"] = run_quantized_scenario(smoke)
    if "sharded" in sections:
        out["sharded"] = run_sharded_scenario(smoke)
    if "open_loop" in sections:
        out["open_loop"] = run_open_loop_scenario(smoke)
    if "chunked_prefill" in sections:
        out["chunked_prefill"] = run_chunked_prefill_scenario(
            smoke, chunk=tuned["prefill_chunk"] or 256)
    if "multimodal" in sections:
        out["multimodal"] = run_multimodal_scenario(smoke)
    with open("BENCH_serve.json", "w") as f:
        json.dump(out, f, indent=2)
    print("wrote BENCH_serve.json")
    # cross-cell comparability: every grid cell must have completed the
    # same token work, or tokens/sec columns are not comparable
    for mode in (modes if "grid" in sections else []):
        per_mode = {r["tokens"] for r in rows if r["mode"] == mode}
        assert len(per_mode) == 1, \
            f"unequal completed-token work across {mode} cells: {per_mode}"
    if smoke:
        _smoke_asserts(out)
    return out


def _smoke_asserts(out: dict) -> None:
    """CI sanity on whichever sections ran."""
    if "rows" in out:
        rows = out["rows"]
        # the fused path must actually amortize host syncs
        fused = [r for r in rows if r["macro_steps"] >= 8]
        legacy = [r for r in rows if r["macro_steps"] == 0]
        assert all(r["tokens"] > 0 for r in rows)
        assert min(f["syncs_per_token"] for f in fused) < \
            min(l["syncs_per_token"] for l in legacy), \
            "macro-step loop did not reduce host syncs per token"
    if "speculative" in out:
        # speculation must not change greedy output, and must actually
        # pay for its verify width on the shared-prefix workload
        sh = out["speculative"]["headline"]
        assert sh["equal_outputs"], "speculative greedy streams diverged"
        for impl in ("xla", "paged"):
            assert sh[f"speedup_{impl}"] >= 1.5, \
                f"speculative speedup below 1.5x on {impl}: " \
                f"{sh[f'speedup_{impl}']:.2f}"
    if "scheduler" in out:
        # at equal budget, coverage-aware traffic scheduling must
        # match-or-beat fifo on quality (one request of sampling slack —
        # the trained-LM comparison is stochastic and CI's jax is
        # unpinned) while spending strictly fewer tokens per served easy
        # request, with the prefix cache actually reusing KV
        scheduler = out["scheduler"]
        h = scheduler["headline"]
        slack = 1.0 / scheduler["n_requests"]
        assert h["accuracy_coverage"] + slack >= h["accuracy_fifo"], h
        assert h["easy_per_served_coverage"] < h["easy_per_served_fifo"], h
        cov = next(r for r in scheduler["rows"][1:]
                   if r["policy"] == "coverage")
        assert cov["prefix_cache"]["hits"] > 0
        assert cov["total_tokens"] <= scheduler["equal_budget"]
    if "quantized" in out:
        # quantized KV: fp32 mode is a byte-identical no-op, int8 halves
        # (better) resident bytes and keeps oracle accuracy
        qh = out["quantized"]["headline"]
        assert qh["fp32_identical_to_auto"], \
            "kv_dtype=fp32 changed the serve trace on an fp32 engine"
        assert qh["bytes_ratio_int8"] <= 0.55, qh
        q_slack = 1.0 / out["quantized"]["n_requests"]
        assert qh["accuracy_delta_int8"] <= q_slack, qh
    if "sharded" in out and "skipped" not in out["sharded"]:
        # when the runtime has a mesh to shard over, sharding must be a
        # pure placement decision: byte-identical streams
        assert out["sharded"]["streams_identical"], out["sharded"]
    if "open_loop" in out:
        # open-loop arrivals reorder admission but greedy streams are
        # schedule-invariant; cancellation must leak nothing
        oh = out["open_loop"]["headline"]
        assert oh["streams_match_closed_loop"], oh
        assert oh["completed_all"], oh
        assert oh["no_leaks_after_cancel"], oh
        # bucketed TTFT must cover every completed request
        for row in out["open_loop"]["rows"]:
            if "ttft_by_bucket" in row:
                assert sum(b["n"] for b in row["ttft_by_bucket"].values()) \
                    == row["completed"], row
    if "chunked_prefill" in out:
        # chunking must be a pure latency optimization: byte-identical
        # greedy streams, and the chunk machinery must actually run
        ch = out["chunked_prefill"]["headline"]
        assert ch["streams_identical"], \
            "chunked prefill changed greedy token streams"
        assert ch["chunk_calls"] > 0 and ch["chunk_tokens"] > 0, ch
    if "multimodal" in out:
        # image prefill must be a pure storage/caching decision: dense,
        # paged, and paged+cache greedy streams byte-identical; the
        # shared hot image must actually hit and skip prefill work
        mh = out["multimodal"]["headline"]
        assert mh["streams_identical"], \
            "multimodal streams diverged across dense/paged/cache cells"
        assert mh["image_encodes_cold"] == mh["distinct_images"], mh
        assert mh["image_feat_hits_cold"] == \
            mh["n_requests"] - mh["distinct_images"], mh
        assert mh["image_prefix_hit_tokens"] > 0, mh
        assert mh["prefill_tokens_reuse"] < mh["prefill_tokens_no_reuse"], mh


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--sections", default=None,
                    help="comma list from %s (default: all)"
                    % ",".join(ALL_SECTIONS))
    a = ap.parse_args()
    run(smoke=a.smoke,
        sections=a.sections.split(",") if a.sections else None)
