"""PagePool invariants: alloc/free conservation, refcounted sharing
(the CoW prompt-page mechanism), misuse detection, sharded subpools
(mesh-parallel serving), the min-tick-heap prefix eviction, and the
byte-budgeted residency ceiling (``kv_byte_budget``)."""
import numpy as np
import pytest

from repro.serving.page_pool import (PagePool, PagePoolError,
                                     prefix_page_keys)


def test_alloc_free_conservation():
    pool = PagePool(17, 16)
    a = pool.alloc(5)
    b = pool.alloc(3)
    assert len(set(a) | set(b)) == 8          # all distinct
    assert 0 not in a + b                      # quarantine never handed out
    assert pool.in_use == 8 and pool.free_pages == 8
    pool.check()
    pool.free(a)
    assert pool.in_use == 3 and pool.free_pages == 13
    pool.check()
    pool.free(b)
    assert pool.in_use == 0 and pool.free_pages == 16
    pool.check()


def test_freed_pages_are_reusable():
    pool = PagePool(5, 16)                     # 4 allocatable
    a = pool.alloc(4)
    with pytest.raises(PagePoolError):
        pool.alloc(1)                          # exhausted
    pool.free(a[:2])
    assert sorted(pool.alloc(2)) == sorted(a[:2])
    pool.check()


def test_share_refcounts():
    """Prompt pages shared across R candidates survive R-1 frees — the
    conservation CoW relies on."""
    pool = PagePool(10, 16)
    prompt = pool.alloc(2)                     # request hold
    for _ in range(3):                         # 3 candidates share
        pool.share(prompt)
    assert all(pool.refcount(p) == 4 for p in prompt)
    for _ in range(3):
        pool.free(prompt)                      # candidates finish
    assert pool.in_use == 2                    # request hold keeps them live
    pool.check()
    pool.free(prompt)                          # request done
    assert pool.in_use == 0
    pool.check()


def test_double_free_raises():
    pool = PagePool(10, 16)
    a = pool.alloc(1)
    pool.free(a)
    with pytest.raises(PagePoolError):
        pool.free(a)
    pool.check()


def test_share_unallocated_raises():
    pool = PagePool(10, 16)
    with pytest.raises(PagePoolError):
        pool.share([3])


def test_free_reserved_raises():
    pool = PagePool(10, 16)
    with pytest.raises(PagePoolError):
        pool.free([0])


def test_max_in_use_high_water():
    pool = PagePool(10, 16)
    a = pool.alloc(6)
    pool.free(a)
    pool.alloc(2)
    assert pool.max_in_use == 6
    assert pool.live_tokens_capacity() == 2 * 16


# ---------------------------------------------------------------------------
# sharded subpools (mesh-parallel serving)
# ---------------------------------------------------------------------------

def test_sharded_alloc_stays_in_shard_range():
    pool = PagePool(16, 8, num_shards=4)       # 4 pages per shard, 3 usable
    for s in range(4):
        pages = pool.alloc(3, shard=s)
        assert all(pool.shard_of(p) == s for p in pages)
        assert all(p != pool.quarantine_page(s) for p in pages)
    pool.check()


def test_sharded_capacity_is_shard_local():
    """A full shard cannot borrow from another — its slots could not
    address foreign pages locally."""
    pool = PagePool(16, 8, num_shards=2)
    a = pool.alloc(7, shard=0)                 # shard 0 exhausted
    with pytest.raises(PagePoolError):
        pool.alloc(1, shard=0)
    assert pool.free_pages_in(1) == 7          # shard 1 untouched
    pool.free(a[:2])
    assert pool.free_pages_in(0) == 2          # frees route home by id
    pool.check()


def test_sharded_quarantine_and_reserved():
    pool = PagePool(12, 8, num_shards=3)
    assert [pool.quarantine_page(s) for s in range(3)] == [0, 4, 8]
    for s in range(3):
        with pytest.raises(PagePoolError):
            pool.free([pool.quarantine_page(s)])


def test_sharded_frontier_accounting_per_shard():
    pool = PagePool(12, 8, num_shards=2)
    f0 = pool.stage_frontier(2, shard=0)
    f1 = pool.stage_frontier(3, shard=1)
    pool.return_frontier(f0[1:] + f1[2:])
    st = pool.stats()
    assert st["shards"][0] == {"free": 4, "frontier_staged": 2,
                               "frontier_returned": 1}
    assert st["shards"][1] == {"free": 3, "frontier_staged": 3,
                               "frontier_returned": 1}
    pool.free([f0[0]] + f1[:2])
    pool.check()
    assert pool.in_use == 0


def test_sharded_indivisible_raises():
    with pytest.raises(PagePoolError):
        PagePool(10, 8, num_shards=4)


# ---------------------------------------------------------------------------
# min-tick-heap prefix eviction (lazy deletion)
# ---------------------------------------------------------------------------

def _chain(pool, tokens, ps):
    keys = prefix_page_keys(tokens, ps)
    pages = pool.alloc(len(keys))
    pool.prefix.insert(keys, pages)
    pool.free(pages)                           # cache-only
    return keys, pages


def test_heap_evicts_lru_chain_deep_end_first():
    """The heap must reproduce the scan's order: least-recently-used
    chain first, leaf before parent (prefix-closure)."""
    pool = PagePool(17, 4, prefix_cache=True)
    ka, pa = _chain(pool, np.arange(2, 10), 4)     # older chain: 2 pages
    kb, pb = _chain(pool, np.arange(20, 28), 4)    # newer chain: 2 pages
    assert pool.prefix.evict(2) == 2
    # chain a evicted entirely (leaf then parent), chain b untouched
    assert set(pool.prefix._nodes) == set(kb)
    assert pool.prefix.evict(10) == 2              # drains b as well
    assert pool.in_use == 0
    pool.check()


def test_heap_touch_refreshes_victim_order():
    pool = PagePool(17, 4, prefix_cache=True)
    ka, _ = _chain(pool, np.arange(2, 10), 4)
    kb, _ = _chain(pool, np.arange(20, 28), 4)
    held = pool.prefix.match_and_hold(ka)          # touch a (now newest)
    pool.free(held)
    pool.prefix.evict(2)
    assert set(pool.prefix._nodes) == set(ka)      # b went first
    pool.check()


def test_heap_skips_held_pages_without_losing_them():
    """Entries popped while a request still holds their page must be
    re-pushed, not dropped — they become evictable again later."""
    pool = PagePool(9, 4, prefix_cache=True)
    ka, _ = _chain(pool, np.arange(2, 10), 4)
    held = pool.prefix.match_and_hold(ka)          # request hold pins both
    assert pool.prefix.evict(2) == 0
    assert set(pool.prefix._nodes) == set(ka)
    pool.free(held)
    assert pool.prefix.evict(2) == 2               # stash was re-pushed
    assert pool.in_use == 0
    pool.check()


def test_heap_compaction_bounds_memory():
    """Lazy deletion must not grow the heaps with total probes: heavy
    touch traffic on a pressure-free pool stays bounded by live nodes,
    and eviction still works after compaction."""
    for shards in (1, 2):
        pool = PagePool(16 if shards == 2 else 17, 4, prefix_cache=True,
                        num_shards=shards)
        keys = prefix_page_keys(np.arange(2, 14), 4)       # 3 full pages
        pages = pool.alloc(3, 0)
        pool.prefix.insert(keys, pages)
        pool.free(pages)
        for _ in range(5000):
            pool.free(pool.prefix.match_and_hold(keys))
        assert len(pool.prefix._heap) <= 64 + 4 * 3
        for h in pool.prefix._heap_sh:
            assert len(h) <= 64 + 4 * 3
        assert pool.prefix.evict(3) == 3
        pool.check()
        assert pool.in_use == 0


def test_sharded_eviction_filter():
    """evict(shard=) only takes pages of that shard's id range."""
    pool = PagePool(16, 4, num_shards=2, prefix_cache=True)
    ka = prefix_page_keys(np.arange(2, 10), 4)
    pa = pool.alloc(2, shard=0)
    pool.prefix.insert(ka, pa)
    pool.free(pa)
    kb = prefix_page_keys(np.arange(20, 28), 4)
    pb = pool.alloc(2, shard=1)
    pool.prefix.insert(kb, pb)
    pool.free(pb)
    assert pool.evictable(0) == 2 and pool.evictable(1) == 2
    assert pool.prefix.evict(4, shard=1) == 2      # only shard 1's pages
    assert set(pool.prefix._nodes) == set(ka)
    pool.check()


# ---------------------------------------------------------------------------
# byte-budgeted residency (kv_byte_budget)
# ---------------------------------------------------------------------------

BPP = 64                                           # test bytes-per-page


def test_byte_budget_evicts_cached_pages_on_pressure():
    """Crossing the ceiling drains cached-only chains LRU-first and the
    evictions land on the budget_evictions counter."""
    pool = PagePool(17, 4, prefix_cache=True, kv_byte_budget=2 * BPP)
    pool.set_bytes_per_page(BPP)                   # budget: 2 pages
    ka, _ = _chain(pool, np.arange(2, 10), 4)      # 2 cached pages: fits
    assert pool.resident_kv_bytes == 2 * BPP
    assert pool.budget_evictions == 0
    kb, _ = _chain(pool, np.arange(20, 28), 4)     # +2 pages: over budget
    assert pool.resident_kv_bytes <= pool.kv_byte_budget
    assert pool.budget_evictions == 2
    assert set(pool.prefix._nodes) == set(kb)      # LRU chain a went first
    pool.check()


def test_byte_budget_never_evicts_live_holds():
    """Live request holds may push residency over the ceiling; the
    enforced invariant is resident <= budget OR evictable() == 0, and
    enforcement fires as soon as the hold drops."""
    pool = PagePool(17, 4, prefix_cache=True, kv_byte_budget=1 * BPP)
    pool.set_bytes_per_page(BPP)
    ka = prefix_page_keys(np.arange(2, 10), 4)
    pa = pool.alloc(2)
    pool.prefix.insert(ka, pa)                     # cached AND still held
    assert pool.resident_kv_bytes > pool.kv_byte_budget
    assert pool.evictable() == 0
    pool.free(pa)                                  # hold drops: enforce
    assert pool.resident_kv_bytes <= pool.kv_byte_budget
    pool.check()


def test_byte_budget_inactive_without_bytes_per_page():
    """Until the engine reports bytes_per_page the budget cannot be
    expressed in pages and must not evict anything."""
    pool = PagePool(17, 4, prefix_cache=True, kv_byte_budget=1)
    _chain(pool, np.arange(2, 10), 4)
    assert pool.resident_kv_bytes == 0             # bpp unknown
    assert pool.over_budget_pages() == 0
    assert pool.budget_evictions == 0
    pool.check()


def _run_budget_ops(ops, budget_pages):
    """Random alloc/insert/free/touch traffic against a byte budget.
    After EVERY mutation the pool must satisfy the budget invariant
    (resident <= budget, or nothing cached-only remains to evict) and
    the structural self-check."""
    pool = PagePool(33, 4, prefix_cache=True,
                    kv_byte_budget=budget_pages * BPP)
    pool.set_bytes_per_page(BPP)
    held = []

    def invariant():
        assert (pool.resident_kv_bytes <= pool.kv_byte_budget
                or pool.evictable() == 0), \
            (pool.resident_kv_bytes, pool.kv_byte_budget, pool.evictable())
        pool.check()

    for kind, val in ops:
        base = 100 * (val + 2)                     # distinct token ranges
        toks = np.arange(base, base + 8)
        if kind == "chain":                        # cache-only 2-page chain
            if pool.free_pages + pool.evictable() < 2:
                continue
            _chain(pool, toks, 4)
        elif kind == "hold":                       # live 2-page chain
            if pool.free_pages + pool.evictable() < 2:
                continue
            pages = pool.alloc(2)
            pool.prefix.insert(prefix_page_keys(toks, 4), pages)
            held.append(pages)
        elif kind == "release":
            if held:
                pool.free(held.pop(val % len(held)))
        elif kind == "touch":                      # LRU refresh on a hit
            got = pool.prefix.match_and_hold(prefix_page_keys(toks, 4))
            if got:
                pool.free(got)
        invariant()
    for pages in held:
        pool.free(pages)
    invariant()


_BUDGET_OP = [("chain", 0), ("hold", 1), ("chain", 2), ("release", 0),
              ("touch", 0), ("chain", 3), ("hold", 4), ("touch", 2),
              ("release", 1), ("chain", 5)]

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                # no-hypothesis lane
    st = None

if st is not None:
    @settings(max_examples=25, deadline=None)
    @given(ops=st.lists(
               st.tuples(st.sampled_from(["chain", "hold", "release",
                                          "touch"]),
                         st.integers(0, 5)),
               min_size=0, max_size=12),
           budget_pages=st.integers(1, 5))
    def test_byte_budget_invariant_under_random_traffic(ops, budget_pages):
        _run_budget_ops(ops, budget_pages)
else:
    @pytest.mark.parametrize("budget_pages", [1, 2, 5])
    def test_byte_budget_invariant_under_random_traffic(budget_pages):
        _run_budget_ops(_BUDGET_OP, budget_pages)
