"""Paper Figure 2 — the motivating experiment (§3.2).

Fixed best-of-N (N ∈ {1,2,4,8,16,32}; pass@256 as the coverage upper
bound) vs the three adaptive stopping rules and CAMD, on a mixed
difficulty population (easy mass + heavy tail — the MathVista stand-in:
"chart/geometry recognition" easy cases vs long-chain visual reasoning).
Reports accuracy vs average tokens/samples — the Pareto frontier the
paper claims for adaptive allocation — plus the per-difficulty-bucket
sample allocation (paper: ~2-3 samples on easy, expands to 32 on hard).
"""
from __future__ import annotations

import numpy as np

from benchmarks.camd_sim import run_adaptive_rule, run_camd, run_fixed_n
from repro.config import CAMDConfig
from repro.data.tasks import SimulatedDecoder


def mixed_population(sim: SimulatedDecoder, n: int, easy_frac: float = 0.55):
    n_easy = int(n * easy_frac)
    easy = sim.rng.uniform(0.55, 0.95, size=n_easy)
    hard = sim.sample_difficulty(n - n_easy)
    return np.concatenate([easy, hard])


def run(n_instances: int = 800, seed: int = 0, verbose: bool = True):
    sim = SimulatedDecoder(tail="heavy", alpha=0.4, seed=seed,
                           score_gap=2.5, score_noise=0.5)
    diffs = mixed_population(sim, n_instances)
    rows = []

    for N in (1, 2, 4, 8, 16, 32):
        rows.append((f"fixed_bo{N}", run_fixed_n(sim, diffs, N, select="best")))
    rows.append(("upper_pass@256", run_fixed_n(sim, diffs, 256, select="oracle")))
    for rule in ("threshold", "bayes", "ei"):
        rows.append((f"adaptive_{rule}", run_adaptive_rule(sim, diffs, rule)))

    # calibration per §5.1 ("normalized on the validation set"):
    # score_scale=1.5 fitted on a held-out population (seed 99).
    camd_cfg = CAMDConfig(samples_per_round=2, max_rounds=16, min_samples=2,
                          max_clusters=8, delta=0.05, score_scale=1.5)
    camd_out = run_camd(sim, diffs, camd_cfg, seed=seed)
    rows.append(("camd", camd_out))

    results = []
    for name, out in rows:
        rec = {"name": name,
               "accuracy": float(np.mean(out["accuracy"])),
               "avg_tokens": float(np.mean(out["tokens"])),
               "avg_samples": float(np.mean(out["samples"]))}
        results.append(rec)
        if verbose:
            print(f"  {name:>18}: acc={rec['accuracy']:.3f} "
                  f"tokens={rec['avg_tokens']:7.1f} "
                  f"samples={rec['avg_samples']:5.2f}")

    # adaptive allocation by difficulty bucket (paper's qualitative claim)
    easy_mask = diffs >= 0.5
    alloc = {
        "easy_avg_samples": float(np.mean(camd_out["samples"][easy_mask])),
        "hard_avg_samples": float(np.mean(camd_out["samples"][~easy_mask])),
        "easy_accuracy": float(np.mean(camd_out["accuracy"][easy_mask])),
        "hard_accuracy": float(np.mean(camd_out["accuracy"][~easy_mask])),
    }
    if verbose:
        print(f"  allocation: easy={alloc['easy_avg_samples']:.2f} samples "
              f"(acc {alloc['easy_accuracy']:.3f}), "
              f"hard={alloc['hard_avg_samples']:.2f} samples "
              f"(acc {alloc['hard_accuracy']:.3f})")

    # claims:
    by = {r["name"]: r for r in results}
    camd = by["camd"]
    # (1) Pareto: the cheapest fixed-N matching CAMD accuracy costs more.
    fixed = [by[f"fixed_bo{N}"] for N in (1, 2, 4, 8, 16, 32)]
    matching = [f for f in fixed if f["accuracy"] >= camd["accuracy"] - 0.005]
    cheapest = min((f["avg_tokens"] for f in matching), default=np.inf)
    claim_pareto = camd["avg_tokens"] < cheapest
    # (2) adaptive allocation: easy instances get ≤ ~3 samples, hard ≥ 3× more.
    claim_alloc = alloc["easy_avg_samples"] <= 4.0 and \
        alloc["hard_avg_samples"] >= 2.5 * alloc["easy_avg_samples"]
    if verbose:
        print(f"  claim[CAMD Pareto-dominates fixed-N]: {claim_pareto} "
              f"(cheapest matching fixed-N tokens: {cheapest:.0f})")
        print(f"  claim[adaptive allocation easy<=4, hard>=2.5x]: {claim_alloc}")
    return {"rows": results, "allocation": alloc,
            "claims": {"pareto": bool(claim_pareto),
                       "allocation": bool(claim_alloc)}}


if __name__ == "__main__":
    run()
