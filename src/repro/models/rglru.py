"""RecurrentGemma / Griffin recurrent block (RG-LRU, arXiv:2402.19427).

TPU adaptation: the linear recurrence h_t = a_t h_{t-1} + b_t is evaluated
with `lax.associative_scan` (log-depth on the VPU) for train/prefill and a
single fused elementwise step for decode. Gates and projections are dense
matmuls outside the scan so the MXU work is batched.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import dense, dense_init

_C = 8.0  # RG-LRU decay sharpness constant from the paper


def _width(cfg: ModelConfig) -> int:
    return cfg.rglru.lru_width or cfg.d_model


def rglru_init(key, cfg: ModelConfig, dtype=jnp.float32):
    w = _width(cfg)
    W = cfg.rglru.conv_width
    d = cfg.d_model
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    return {
        "w_x": dense_init(k1, d, w, dtype),
        "w_gate": dense_init(k2, d, w, dtype),
        "conv_w": (jax.random.normal(k3, (W, w)) * W ** -0.5).astype(dtype),
        "conv_b": jnp.zeros((w,), dtype=dtype),
        "w_a": dense_init(k4, w, w, dtype),        # recurrence gate
        "w_i": dense_init(k5, w, w, dtype),        # input gate
        "lam": (jax.random.uniform(jax.random.fold_in(k4, 1), (w,),
                                   minval=0.9, maxval=0.999)),
        "out_proj": dense_init(k6, w, d, dtype),
    }


def _gates(params, x):
    """x: (..., w) conv output. Returns (log_a, gated_input) in fp32."""
    x32 = x.astype(jnp.float32)
    r = jax.nn.sigmoid(dense(params["w_a"], x).astype(jnp.float32))
    i = jax.nn.sigmoid(dense(params["w_i"], x).astype(jnp.float32))
    # a = exp(-c * softplus(Λ) * r)
    log_a = -_C * jax.nn.softplus(params["lam"].astype(jnp.float32)) * r
    a2 = jnp.exp(2.0 * log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a2, 1e-9)) * (i * x32)
    return log_a, b


def rglru_prefill(params, cfg: ModelConfig, u, lengths=None) -> Tuple[jax.Array, Dict]:
    """u: (B, L, d). Returns (out (B,L,d), state).

    ``lengths``: optional (B,) int32 true per-row lengths for
    right-padded batched prefill. Padded steps become the identity
    recurrence (log_a=0, b=0) so h at the last padded position equals h
    at the row's last real position — allclose-exact vs a per-row
    prefill (the associative-scan tree shape still depends on the
    padded L). Per-row outputs beyond lengths-1 are garbage.
    """
    w = _width(cfg)
    W = cfg.rglru.conv_width
    B, L, _ = u.shape
    x_in = dense(params["w_x"], u)
    gate = jax.nn.gelu(dense(params["w_gate"], u))
    pad = jnp.zeros((B, W - 1, w), x_in.dtype)
    x_pad = jnp.concatenate([pad, x_in], axis=1)
    conv = sum(x_pad[:, i:i + L] * params["conv_w"][i] for i in range(W))
    conv = conv + params["conv_b"]

    log_a, b = _gates(params, conv)                    # (B,L,w) fp32
    if lengths is not None:
        valid = (jnp.arange(L)[None, :] < lengths[:, None])[..., None]
        log_a = jnp.where(valid, log_a, 0.0)
        b = jnp.where(valid, b, 0.0)

    def combine(left, right):
        la_l, h_l = left
        la_r, h_r = right
        return la_l + la_r, jnp.exp(la_r) * h_l + h_r

    _, h = jax.lax.associative_scan(combine, (log_a, b), axis=1)
    y = (h.astype(u.dtype) * gate)
    out = dense(params["out_proj"], y)
    if lengths is None:
        conv_state = x_pad[:, L:L + W - 1]
    else:
        # input j sits at x_pad position j + W - 1: gather each row's
        # last W-1 real inputs (short rows pick up the left zero-pad).
        idx = lengths[:, None] + jnp.arange(W - 1)[None, :]
        conv_state = jnp.take_along_axis(x_pad, idx[:, :, None], axis=1)
    state = {"h": h[:, -1], "conv": conv_state}
    return out, state


def make_rglru_state(cfg: ModelConfig, batch: int, dtype):
    w = _width(cfg)
    W = cfg.rglru.conv_width
    return {"h": jnp.zeros((batch, w), jnp.float32),
            "conv": jnp.zeros((batch, W - 1, w), dtype)}


def rglru_decode(params, cfg: ModelConfig, u, state) -> Tuple[jax.Array, Dict]:
    """u: (B, 1, d). One recurrent step."""
    W = cfg.rglru.conv_width
    x_in = dense(params["w_x"], u)                     # (B,1,w)
    gate = jax.nn.gelu(dense(params["w_gate"], u))
    window = jnp.concatenate([state["conv"], x_in], axis=1)   # (B,W,w)
    conv = jnp.einsum("bwc,wc->bc", window, params["conv_w"]) + params["conv_b"]
    log_a, b = _gates(params, conv)                    # (B,w)
    h = jnp.exp(log_a) * state["h"] + b
    y = (h[:, None].astype(u.dtype) * gate)
    out = dense(params["out_proj"], y)
    return out, {"h": h, "conv": window[:, 1:]}
