"""Property tests of the attention substrate's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need hypothesis;
# a bare interpreter must still collect the suite (module-level skip)
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.models.attention import NEG_INF, sdpa
from repro.models.layers import apply_rope


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10**6), Hq=st.sampled_from([2, 4]),
       Hkv=st.sampled_from([1, 2]))
def test_sdpa_grouped_equals_expanded(seed, Hq, Hkv):
    """Grouped-GQA math == explicitly expanded heads."""
    key = jax.random.PRNGKey(seed)
    B, L, hd = 2, 24, 16
    q = jax.random.normal(key, (B, L, Hq, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, L, Hkv, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, L, Hkv, hd))
    out = sdpa(q, k, v, causal=True)
    rep = Hq // Hkv
    out_exp = sdpa(q, jnp.repeat(k, rep, 2), jnp.repeat(v, rep, 2),
                   causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_exp),
                               rtol=2e-5, atol=2e-5)


def test_sdpa_chunked_equals_unchunked():
    key = jax.random.PRNGKey(0)
    B, L, H, hd = 1, 64, 2, 16
    q = jax.random.normal(key, (B, L, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, L, H, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, L, H, hd))
    a = sdpa(q, k, v, causal=True, chunk=16)
    b = sdpa(q, k, v, causal=True, chunk=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-5, atol=2e-5)


def test_window_equals_full_when_window_covers():
    """window >= L must equal full causal attention; a small window must
    differ (the mask actually does something)."""
    key = jax.random.PRNGKey(1)
    B, L, H, hd = 1, 32, 2, 16
    q = jax.random.normal(key, (B, L, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, L, H, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, L, H, hd))
    full = sdpa(q, k, v, causal=True, window=0)
    wide = sdpa(q, k, v, causal=True, window=L + 5)
    narrow = sdpa(q, k, v, causal=True, window=4)
    np.testing.assert_allclose(np.asarray(full), np.asarray(wide),
                               rtol=1e-6)
    assert float(jnp.abs(full - narrow).max()) > 1e-3


def test_causality():
    """Perturbing future tokens must not change past outputs."""
    key = jax.random.PRNGKey(2)
    B, L, H, hd = 1, 16, 2, 8
    q = jax.random.normal(key, (B, L, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, L, H, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, L, H, hd))
    out1 = sdpa(q, k, v, causal=True)
    k2 = k.at[:, 10:].set(99.0)
    v2 = v.at[:, 10:].set(-99.0)
    out2 = sdpa(q, k2, v2, causal=True)
    np.testing.assert_allclose(np.asarray(out1[:, :10]),
                               np.asarray(out2[:, :10]), rtol=1e-5)
    assert float(jnp.abs(out1[:, 10:] - out2[:, 10:]).max()) > 1e-3


def test_rope_relative_position_invariance():
    """RoPE dot products depend only on relative distance."""
    hd = 32
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (1, 1, 1, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 1, 1, hd))

    def dot_at(pq, pk):
        qr = apply_rope(q, jnp.asarray([[pq]]), 10000.0)
        kr = apply_rope(k, jnp.asarray([[pk]]), 10000.0)
        return float(jnp.sum(qr * kr))

    a = dot_at(5, 3)
    b = dot_at(105, 103)
    np.testing.assert_allclose(a, b, rtol=1e-4)
    c = dot_at(5, 0)
    assert abs(a - c) > 1e-5  # different distance -> different score
