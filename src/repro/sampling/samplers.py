"""Token-level samplers and logit processors.

Matches the paper's decoding setup (§3.2): temperature, top-p, top-k,
min-p, repetition penalty. All processors are pure (B, V) -> (B, V)
functions that jit and compose; ``sample_token`` is the single entry point
used by the serving engine.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import SamplingConfig

NEG_INF = -1e30


def apply_temperature(logits, temperature: float):
    if temperature <= 0.0:
        return logits  # greedy handled by caller
    return logits / temperature


def apply_top_k(logits, k: int):
    if k <= 0:
        return logits
    kth = jnp.sort(logits, axis=-1)[..., -k][..., None]
    return jnp.where(logits < kth, NEG_INF, logits)


def apply_top_p(logits, p: float):
    if p >= 1.0 or p <= 0.0:
        return logits
    sorted_logits = jnp.sort(logits, axis=-1)[..., ::-1]
    probs = jax.nn.softmax(sorted_logits, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    # keep tokens until cumulative prob exceeds p (always keep the top-1)
    cutoff_mask = cum - probs > p
    cutoff_logit = jnp.min(
        jnp.where(cutoff_mask, jnp.inf, sorted_logits), axis=-1, keepdims=True)
    return jnp.where(logits < cutoff_logit, NEG_INF, logits)


def apply_min_p(logits, min_p: float):
    if min_p <= 0.0:
        return logits
    probs = jax.nn.softmax(logits, axis=-1)
    top = jnp.max(probs, axis=-1, keepdims=True)
    return jnp.where(probs < min_p * top, NEG_INF, logits)


def apply_repetition_penalty(logits, token_counts, penalty: float):
    """HF-style: seen tokens' positive logits divided by `penalty`,
    negative multiplied. token_counts: (B, V) counts of emitted tokens."""
    if penalty == 1.0:
        return logits
    seen = token_counts > 0
    return jnp.where(seen,
                     jnp.where(logits > 0, logits / penalty, logits * penalty),
                     logits)


def process_logits(logits, cfg: SamplingConfig, token_counts=None, bias=None):
    """Compose processors in the standard order. ``bias`` is the CAMD
    Eq. 16 mixture guidance (per-row (B, V) additive logits)."""
    if token_counts is not None:
        logits = apply_repetition_penalty(logits, token_counts,
                                          cfg.repetition_penalty)
    if bias is not None:
        logits = logits + bias
    logits = apply_temperature(logits, cfg.temperature)
    logits = apply_top_k(logits, cfg.top_k)
    logits = apply_top_p(logits, cfg.top_p)
    logits = apply_min_p(logits, cfg.min_p)
    return logits


def decode_step_key(base_key, step):
    """PRNG key for global decode step ``step``.

    The serving engine's fused loop derives per-step keys by *folding* the
    step index into one base key instead of threading a split chain
    through the loop carry — so the sampled stream at step t is a pure
    function of (base_key, t), independent of how many steps each
    ``lax.while_loop`` launch covers. This is what makes macro_steps=1 and
    macro_steps=32 decode bit-identical token streams.
    """
    return jax.random.fold_in(base_key, step)


def sample_token_batch(keys, logits, cfg: SamplingConfig, bias=None,
                       greedy=None):
    """Sample n first tokens from ONE shared logits row with n keys.

    keys: (n, key_dim); logits: (1, V); bias: optional (1, V); greedy:
    optional (1,) bool. Returns (tokens (n,), logprobs (n,)). vmap over
    the keys keeps per-key results identical to n separate
    ``sample_token`` calls while costing a single dispatch — the serving
    engine uses this to admit a whole round of candidates at once.
    """
    tok, lp = jax.vmap(
        lambda k: sample_token(k, logits, cfg, bias=bias, greedy=greedy)
    )(keys)
    return tok[:, 0], lp[:, 0]


def sample_token(key, logits, cfg: SamplingConfig, token_counts=None,
                 bias=None, greedy=None):
    """Returns (token (B,), logprob (B,)) — logprob of the *sampled* token
    under the processed distribution (used for S_gen, Eq. 7).

    ``greedy``: optional (B,) bool — rows decoded greedily (temperature 0).
    """
    proc = process_logits(logits, cfg, token_counts, bias)
    logp = jax.nn.log_softmax(proc, axis=-1)
    sampled = jax.random.categorical(key, proc, axis=-1)
    arg = jnp.argmax(logits, axis=-1)
    if greedy is None:
        tok = sampled if cfg.temperature > 0 else arg
    else:
        tok = jnp.where(greedy, arg, sampled)
    lp = jnp.take_along_axis(logp, tok[:, None], axis=-1)[:, 0]
    return tok.astype(jnp.int32), lp
