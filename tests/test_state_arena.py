"""StateArena unit tests: the fixed-stride recurrent-state allocator
must mirror PagePool's disciplines — refcounted holds, shard-local LIFO
free lists, fail-fast misuse errors, exact conservation."""
import pytest

from repro.serving.state_arena import StateArena, StateArenaError


def test_alloc_free_roundtrip():
    a = StateArena(8)
    rows = a.alloc(3)
    assert len(rows) == len(set(rows)) == 3
    assert a.in_use == 3 and a.free_rows == 5
    a.free(rows)
    assert a.in_use == 0 and a.free_rows == 8
    a.check()


def test_lifo_reuse():
    a = StateArena(8)
    r1 = a.alloc(1)
    a.free(r1)
    r2 = a.alloc(1)
    assert r1 == r2          # most-recently-freed row comes back first


def test_share_refcounting():
    a = StateArena(4)
    rows = a.alloc(2)
    a.share(rows)
    a.free(rows)
    assert a.in_use == 2     # second reference still holds
    a.free(rows)
    assert a.in_use == 0
    a.check()


def test_double_free_raises():
    a = StateArena(4)
    rows = a.alloc(1)
    a.free(rows)
    with pytest.raises(StateArenaError):
        a.free(rows)


def test_free_out_of_range_raises():
    a = StateArena(4)
    with pytest.raises(StateArenaError):
        a.free([7])


def test_share_of_free_row_raises():
    a = StateArena(4)
    with pytest.raises(StateArenaError):
        a.share([0])


def test_over_alloc_raises():
    a = StateArena(4)
    a.alloc(3)
    with pytest.raises(StateArenaError):
        a.alloc(2)


def test_shards_are_local():
    a = StateArena(8, num_shards=2)
    assert a.rows_per_shard == 4
    r0 = a.alloc(2, shard=0)
    r1 = a.alloc(2, shard=1)
    assert all(a.shard_of(r) == 0 for r in r0)
    assert all(a.shard_of(r) == 1 for r in r1)
    assert a.free_rows_in(0) == 2 and a.free_rows_in(1) == 2
    # shard capacity is not fungible: shard 0 can't fund 3 more
    with pytest.raises(StateArenaError):
        a.alloc(3, shard=0)
    a.free(r0)
    a.free(r1)
    a.check()


def test_best_shard_balances():
    a = StateArena(8, num_shards=2)
    a.alloc(2, shard=0)
    assert a.best_shard() == 1


def test_invalid_sizing():
    with pytest.raises(ValueError):
        StateArena(0)
    with pytest.raises(ValueError):
        StateArena(7, num_shards=2)   # not a shard multiple


def test_stats_and_reset():
    a = StateArena(8)
    rows = a.alloc(4)
    a.free(rows[:2])
    s = a.stats()
    assert s["alloc_count"] == 4 and s["free_count"] == 2
    assert s["max_in_use"] == 4 and s["in_use"] == 2
    a.reset_stats()
    s = a.stats()
    assert s["alloc_count"] == 0 and s["free_count"] == 0
    assert s["max_in_use"] == 2    # occupancy is state, not a counter
    a.free(rows[2:])
    a.check()


def test_conservation_audit_catches_corruption():
    a = StateArena(4)
    a.alloc(1)
    a._free[0].append(0)          # corrupt: held row also on free list
    with pytest.raises(StateArenaError):
        a.check()
