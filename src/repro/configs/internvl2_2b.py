"""internvl2-2b — InternVL2 2B VLM (InternViT-300M + InternLM2-1.8B).

[arXiv:2404.16821]: language backbone 24L, d_model=2048, 16 q heads,
GQA kv=8, d_ff=8192, vocab 92553. The InternViT vision encoder + MLP
projector is a STUB: ``input_specs`` provides precomputed patch embeddings
(256 tokens per image tile after pixel-shuffle) already projected to
d_model.
"""
from repro.config import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=92553,
    block_pattern=(ATTN,),
    mlp_activation="swiglu",
    num_evidence_tokens=256,      # ViT patch embeddings per image
    evidence_dim=2048,
    source="arXiv:2404.16821",
)
