"""Training step + loop.

``make_train_step`` builds the pure (params, opt_state, batch) -> step
function that the launcher jits under a mesh with in/out shardings (see
``repro.distributed.partition``); the same function runs single-device in
tests and examples.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
from typing import Any, Callable, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, TrainConfig
from repro.models.model import Model
from repro.training.loss import total_loss
from repro.training.optimizer import OptState, adamw_update, init_opt_state


def make_loss_fn(model: Model, train_cfg: TrainConfig):
    def loss_fn(params, batch):
        logits, _, aux = model.forward(
            params, batch["tokens"], batch.get("evidence"),
            remat=train_cfg.remat, unroll=train_cfg.unroll)
        ne = model.cfg.num_evidence_tokens
        if ne and not model.cfg.is_encoder_decoder:
            logits = logits[:, ne:]           # loss over text positions only
        loss, metrics = total_loss(
            logits, batch["labels"], aux,
            moe_aux_weight=(model.cfg.moe.aux_loss_weight
                            if model.cfg.moe else 0.0))
        return loss, metrics

    return loss_fn


def make_train_step(model: Model, train_cfg: TrainConfig
                    ) -> Callable[..., Tuple[Any, OptState, Dict]]:
    loss_fn = make_loss_fn(model, train_cfg)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    k = train_cfg.microbatches

    def train_step(params, opt_state: OptState, batch):
        if k <= 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            # gradient accumulation: scan over k microbatches — bounds
            # activation memory at 1/k of the global batch (the trick that
            # brings trillion-param train steps under the HBM line).
            micro = jax.tree.map(
                lambda x: x.reshape((k, x.shape[0] // k) + x.shape[1:]), batch)

            def body(acc, mb):
                (l, m), g = grad_fn(params, mb)
                acc_g, acc_m = acc
                acc_g = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32) / k, acc_g, g)
                acc_m = jax.tree.map(lambda a, b: a + b / k, acc_m, m)
                return (acc_g, acc_m), None

            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            mb0 = jax.tree.map(lambda x: x[0], micro)
            (l0, m0), g0 = grad_fn(params, mb0)
            acc0 = (jax.tree.map(lambda g: g.astype(jnp.float32) / k, g0),
                    jax.tree.map(lambda m: m / k, m0))
            rest = jax.tree.map(lambda x: x[1:], micro)
            (grads, metrics), _ = jax.lax.scan(body, acc0, rest)
        params, opt_state, opt_metrics = adamw_update(
            train_cfg, params, grads, opt_state)
        return params, opt_state, {**metrics, **opt_metrics}

    return train_step


def train(model: Model, train_cfg: TrainConfig, data: Iterator[Dict],
          *, params=None, steps: Optional[int] = None,
          log_every: int = 10, callback=None):
    """Single-host training loop (examples / integration tests)."""
    steps = steps or train_cfg.total_steps
    if params is None:
        params = model.init(jax.random.PRNGKey(train_cfg.seed))
    opt_state = init_opt_state(params)
    step_fn = jax.jit(make_train_step(model, train_cfg))
    history = []
    t0 = time.time()
    for i in range(steps):
        batch = next(data)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if i % log_every == 0 or i == steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = i
            m["elapsed_s"] = time.time() - t0
            history.append(m)
            if callback:
                callback(m)
    return params, opt_state, history
