"""Per-architecture smoke tests (deliverable f).

For every assigned architecture: instantiate the REDUCED variant of the
same family (2-3 layers, d_model<=512, <=4 experts), run one forward and
one train step on CPU, assert output shapes and no NaNs; then exercise the
prefill+decode path and check it matches the full forward exactly.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import TrainConfig
from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models import build_model
from repro.training import init_opt_state, make_train_step


def _reduced(name):
    return get_config(name).reduced().with_overrides(dtype="float32")


def _inputs(cfg, B=2, L=24, seed=0):
    kt, ke = jax.random.split(jax.random.PRNGKey(seed))
    toks = jax.random.randint(kt, (B, L), 0, cfg.vocab_size)
    ev = None
    if cfg.num_evidence_tokens:
        ev = jax.random.normal(ke, (B, cfg.num_evidence_tokens,
                                    cfg.evidence_dim))
    return toks, ev


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_forward_shapes_no_nan(arch):
    cfg = _reduced(arch)
    model = build_model(cfg, jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    toks, ev = _inputs(cfg)
    logits, hidden, aux = model.forward(params, toks, ev)
    L_out = toks.shape[1] + (cfg.num_evidence_tokens
                             if (cfg.num_evidence_tokens
                                 and not cfg.is_encoder_decoder) else 0)
    assert logits.shape == (2, L_out, cfg.vocab_size)
    assert hidden.shape[:2] == (2, L_out)
    assert not bool(jnp.isnan(logits).any())
    for v in aux.values():
        assert not bool(jnp.isnan(v).any())


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_one_train_step(arch):
    cfg = _reduced(arch)
    model = build_model(cfg, jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    toks, ev = _inputs(cfg)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    if ev is not None:
        batch["evidence"] = ev
    step = jax.jit(make_train_step(model, TrainConfig(remat=True)))
    new_params, new_opt, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(new_opt.step) == 1
    # params actually moved
    delta = max(float(jnp.abs(a - b).max())
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(new_params)))
    assert delta > 0


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_decode_matches_forward(arch):
    cfg = _reduced(arch)
    model = build_model(cfg, jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    B, Lp, n_dec = 2, 12, 3
    toks, ev = _inputs(cfg, B, Lp + n_dec)
    logits_full, _, _ = model.forward(params, toks, ev)
    offs = cfg.num_evidence_tokens if (cfg.num_evidence_tokens and
                                       not cfg.is_encoder_decoder) else 0
    cache = model.make_cache(B, Lp + n_dec + offs, jnp.float32)
    lg, hid, cache = model.prefill(params, toks[:, :Lp], cache, ev)
    np.testing.assert_allclose(np.asarray(lg),
                               np.asarray(logits_full[:, offs + Lp - 1]),
                               rtol=2e-4, atol=2e-4)
    for t in range(n_dec):
        lg, hid, cache = model.decode_step(params, toks[:, Lp + t], cache)
        np.testing.assert_allclose(
            np.asarray(lg), np.asarray(logits_full[:, offs + Lp + t]),
            rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "mamba2-780m",
                                  "recurrentgemma-2b",
                                  "seamless-m4t-large-v2"])
def test_unroll_matches_scan(arch):
    cfg = _reduced(arch)
    model = build_model(cfg, jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    toks, ev = _inputs(cfg, 2, 16)
    a, _, _ = model.forward(params, toks, ev, unroll=False)
    b, _, _ = model.forward(params, toks, ev, unroll=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)


def test_full_configs_match_assignment():
    spec = {
        "granite-moe-3b-a800m": dict(num_layers=32, d_model=1536, num_heads=24,
                                     num_kv_heads=8, vocab_size=49155),
        "seamless-m4t-large-v2": dict(num_layers=24, d_model=1024,
                                      num_heads=16, num_kv_heads=16,
                                      d_ff=8192, vocab_size=256206),
        "qwen2.5-32b": dict(num_layers=64, d_model=5120, num_heads=40,
                            num_kv_heads=8, d_ff=27648, vocab_size=152064),
        "mamba2-780m": dict(num_layers=48, d_model=1536, vocab_size=50280),
        "qwen3-0.6b": dict(num_layers=28, d_model=1024, num_heads=16,
                           num_kv_heads=8, d_ff=3072, vocab_size=151936),
        "yi-34b": dict(num_layers=60, d_model=7168, num_heads=56,
                       num_kv_heads=8, d_ff=20480, vocab_size=64000),
        "granite-34b": dict(num_layers=88, d_model=6144, num_heads=48,
                            num_kv_heads=1, d_ff=24576, vocab_size=49152),
        "kimi-k2-1t-a32b": dict(num_layers=61, d_model=7168, num_heads=64,
                                num_kv_heads=8, vocab_size=163840),
        "recurrentgemma-2b": dict(num_layers=26, d_model=2560, num_heads=10,
                                  d_ff=7680, vocab_size=256000),
        "internvl2-2b": dict(num_layers=24, d_model=2048, num_heads=16,
                             num_kv_heads=8, d_ff=8192, vocab_size=92553),
    }
    for arch, fields in spec.items():
        cfg = get_config(arch)
        for k, v in fields.items():
            assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)
    assert get_config("granite-moe-3b-a800m").moe.num_experts == 40
    assert get_config("granite-moe-3b-a800m").moe.top_k == 8
    assert get_config("kimi-k2-1t-a32b").moe.num_experts == 384
    assert get_config("kimi-k2-1t-a32b").moe.top_k == 8
    assert get_config("mamba2-780m").ssm.state_dim == 128
    assert get_config("qwen2.5-32b").qkv_bias
    assert get_config("qwen3-0.6b").qk_norm
    assert get_config("seamless-m4t-large-v2").is_encoder_decoder
    # kimi is genuinely trillion-scale
    assert get_config("kimi-k2-1t-a32b").num_params() > 0.9e12
    assert get_config("kimi-k2-1t-a32b").active_params() < 40e9
