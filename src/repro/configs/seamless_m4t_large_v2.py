"""seamless-m4t-large-v2 — Meta SeamlessM4T v2 large (text/speech enc-dec).

[arXiv:2308.11596]: 24L decoder (+24L encoder), d_model=1024, 16 heads
(kv=16 i.e. MHA), d_ff=8192, vocab 256206. Multimodal: the speech frontend
(mel + conformer conv) is a stub; ``input_specs`` supplies precomputed frame
embeddings consumed by the encoder.
"""
from repro.config import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    block_pattern=(ATTN,),
    mlp_activation="gelu",
    is_encoder_decoder=True,
    num_encoder_layers=24,
    num_evidence_tokens=512,      # precomputed audio frame embeddings
    evidence_dim=1024,
    source="arXiv:2308.11596",
)
