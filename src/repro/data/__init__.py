from repro.data.synthetic import evidence_batch, lm_batches  # noqa: F401
from repro.data.tasks import ChainTask, SimulatedDecoder  # noqa: F401
