"""Numerics for the paper's theoretical framework (§4.1).

Implements coverage C(K), residual risk Δ(K), δ-coverage sample size N_δ
(Def. 4.1), difficulty-distribution samplers for the three tail classes of
Theorem 4.2, tail-exponent estimation from empirical Δ(K) decay, and the
K*(ε) budget rule of Eq. 6. These are used by the property tests and by
``benchmarks/bench_theory.py`` to validate Theorem 4.2 empirically.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Basic coverage quantities (Eq. 2-4)
# ---------------------------------------------------------------------------

def coverage(K, s):
    """C(K) = E_s[1 - (1-s)^K] for samples s (vector) — Eq. 2."""
    K = jnp.asarray(K, jnp.float32)
    return jnp.mean(1.0 - jnp.power(1.0 - s, K[..., None]), axis=-1)


def residual_risk(K, s):
    """Δ(K) = E_s[(1-s)^K] — Eq. 3."""
    K = jnp.asarray(K, jnp.float32)
    return jnp.mean(jnp.power(1.0 - s, K[..., None]), axis=-1)


def n_delta(s, delta: float):
    """Def. 4.1: minimal trials for 1-δ coverage of an instance with
    success probability s."""
    s = jnp.clip(s, 1e-12, 1.0 - 1e-12)
    return jnp.ceil(jnp.log(delta) / jnp.log1p(-s))


# ---------------------------------------------------------------------------
# Difficulty distributions G(s) per Theorem 4.2 tail classes
# ---------------------------------------------------------------------------

def sample_heavy_tail(key, n: int, alpha: float = 0.5):
    """g(s) ~ alpha * s^(alpha-1) on (0,1): heavy (polynomial) lower tail.
    CDF G(s) = s^alpha -> inverse sampling s = U^(1/alpha)."""
    u = jax.random.uniform(key, (n,), minval=1e-12)
    return jnp.power(u, 1.0 / alpha)


def sample_stretched_exp(key, n: int, c: float = 1.0, theta: float = 1.0):
    """log Pr(s <= eps) ~ -c * eps^-theta: stretched-exponential lower tail.
    Inverse sampling from G(s) = exp(-c s^-theta) (normalized on (0,1])."""
    z = np.exp(-c)  # G(1)
    u = jax.random.uniform(key, (n,), minval=1e-30) * z
    return jnp.power(-jnp.log(u) / c, -1.0 / theta).clip(0.0, 1.0)


def sample_light_tail(key, n: int, lo: float = 0.2, hi: float = 0.9):
    """Truncated support: G([0, lo]) = 0 — light/truncated tail class."""
    return jax.random.uniform(key, (n,), minval=lo, maxval=hi)


# ---------------------------------------------------------------------------
# Theorem 4.2 asymptotics + estimation
# ---------------------------------------------------------------------------

def heavy_tail_rate(K, alpha: float, kappa: float = 1.0):
    """Δ(K) ~ κ Γ(α) K^{-α} (slowly varying ℓ ≡ 1)."""
    import math
    return kappa * math.gamma(alpha) * jnp.power(jnp.asarray(K, jnp.float32), -alpha)


def fit_power_law(Ks, deltas):
    """Least-squares fit of log Δ = -α log K + c. Returns (alpha, c)."""
    x = np.log(np.asarray(Ks, dtype=np.float64))
    y = np.log(np.maximum(np.asarray(deltas, dtype=np.float64), 1e-300))
    A = np.stack([x, np.ones_like(x)], axis=1)
    coef, *_ = np.linalg.lstsq(A, y, rcond=None)
    return -coef[0], coef[1]


def fit_exponential(Ks, deltas):
    """Fit log Δ = -c K + b. Returns (c, b)."""
    x = np.asarray(Ks, dtype=np.float64)
    y = np.log(np.maximum(np.asarray(deltas, dtype=np.float64), 1e-300))
    A = np.stack([x, np.ones_like(x)], axis=1)
    coef, *_ = np.linalg.lstsq(A, y, rcond=None)
    return -coef[0], coef[1]


def k_star(epsilon: float, r_irr: float, tail: str, *, alpha: float = 0.5,
           kappa: float = 1.0, theta: float = 1.0) -> float:
    """Eq. 6: minimal sampling budget to push total risk below ε."""
    import math
    margin = epsilon - r_irr
    if margin <= 0:
        return float("inf")
    if tail == "heavy":
        return (kappa * math.gamma(alpha) / margin) ** (1.0 / alpha)
    if tail == "stretched":
        return math.log(1.0 / margin) ** ((theta + 1.0) / theta)
    if tail == "light":
        return math.log(1.0 / margin)
    raise ValueError(tail)
