"""Chunked prefill + prefill/decode disaggregation.

The contract under test: splitting a prompt's prefill into fixed-size
page-aligned chunks — at the model level (``Model.prefill_chunked``) or
inside the serving engine (``ServeEngine(prefill_chunk=...)``) — is a
*scheduling* decision, never an output decision. Greedy token streams
must be byte-identical for every chunk size including the degenerate
ones (chunk-of-one rounds up to a page; a chunk covering the prompt
disables chunking), across traffic policies, the prefix cache,
speculation, and the chunk-token budget. Mid-prefill cancellation must
return every chunk page, and disaggregated prefill (chunk jobs pinned
to a shard range of the page axis, decode slots reading cross-shard)
must also be stream-invariant.

Multi-chunk model-level logits are compared with a tight tolerance, not
bitwise: XLA reduction order varies with matmul shapes, so a 3-chunk
split of a 2-layer fp32 model drifts ~1e-6 from the whole prefill while
the argmax (and therefore every greedy stream) is unchanged.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from conftest import _mk_engine, _request
from repro.config import PagedKVConfig

MAX_NEW = 6
PROMPT_LENS = (50, 6, 33, 80, 12, 64)


def _mk(model, params, *, eos, **kw):
    kw.setdefault("mode", "greedy")
    kw.setdefault("macro_steps", 2)
    kw.setdefault("slots", 4)
    kw.setdefault("cache_len", 128)
    kw.setdefault("impl", "paged")
    kw.setdefault("paged_kv", PagedKVConfig(page_size=8))
    return _mk_engine(model, params, max_new=MAX_NEW, eos_id=eos, **kw)


def _prompts(cfg, lens=PROMPT_LENS, seed=3):
    rng = np.random.default_rng(seed)
    return [rng.integers(2, cfg.vocab_size, n).astype(np.int32)
            for n in lens]


def _streams(eng, prompts, uid0=0):
    for i, p in enumerate(prompts):
        eng.submit(_request(uid0 + i, p))
    res = sorted(eng.run(), key=lambda r: r.uid)
    return [tuple(np.asarray(r.tokens).tolist()) for r in res]


def _drained(eng):
    eng.pool.check()
    resident = len(eng.pool.prefix._nodes) if eng.pool.prefix else 0
    assert eng.pool.in_use == resident
    assert not eng._chunking
    assert eng.scheduler.committed == 0


# ---------------------------------------------------------------------------
# model level: chunked == whole prefill
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_model_chunked_prefill_matches_whole(tiny_model, chunk):
    cfg, model, params = tiny_model
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(2, cfg.vocab_size, (2, 13)), jnp.int32)
    lg_w, h_w, cache_w = model.prefill(
        params, toks, model.make_cache(2, 32))
    lg_c, h_c, cache_c = model.prefill_chunked(
        params, toks, model.make_cache(2, 32), chunk)
    np.testing.assert_allclose(np.asarray(lg_c), np.asarray(lg_w),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(h_c), np.asarray(h_w),
                               atol=1e-5, rtol=1e-5)
    assert np.array_equal(np.argmax(lg_c, -1), np.argmax(lg_w, -1))
    for a, b in zip(jax.tree_util.tree_leaves(cache_c),
                    jax.tree_util.tree_leaves(cache_w)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=1e-5, rtol=1e-5)


def test_model_chunked_prefill_degenerate_is_exact(tiny_model):
    """chunk=0 and chunk >= L take the whole-prefill path: bitwise."""
    cfg, model, params = tiny_model
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(2, cfg.vocab_size, (1, 9)), jnp.int32)
    lg_w, _, _ = model.prefill(params, toks, model.make_cache(1, 16))
    for chunk in (0, 9, 64):
        lg_c, _, _ = model.prefill_chunked(
            params, toks, model.make_cache(1, 16), chunk)
        assert np.array_equal(np.asarray(lg_c), np.asarray(lg_w)), chunk


# ---------------------------------------------------------------------------
# engine level: greedy stream identity across the chunk grid
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("page_size", [8, 16])
@pytest.mark.parametrize("chunk", [1, 16, 64, 128])
def test_chunked_streams_byte_identical(tiny_model, chunk, page_size):
    """chunk=1 rounds up to one page; chunk=128 exceeds every prompt
    (chunking never engages); 16/64 exercise multi-chunk jobs. All four
    must reproduce the unchunked engine's streams byte-for-byte."""
    cfg, model, params = tiny_model
    pk = PagedKVConfig(page_size=page_size)
    ref = _streams(_mk(model, params, eos=cfg.vocab_size, paged_kv=pk),
                   _prompts(cfg))
    eng = _mk(model, params, eos=cfg.vocab_size, paged_kv=pk,
              prefill_chunk=chunk)
    got = _streams(eng, _prompts(cfg))
    assert got == ref, f"chunk={chunk} ps={page_size} diverged"
    s = eng.sched_stats()
    if chunk < max(PROMPT_LENS):
        assert s["chunk_calls"] > 0 and s["chunk_tokens"] > 0
    else:
        assert s["chunk_calls"] == 0
    _drained(eng)


def test_chunk_budget_paces_but_preserves_streams(tiny_model):
    """A budget smaller than the chunk size stretches prefill across
    more turns without changing a single token."""
    cfg, model, params = tiny_model
    ref = _streams(_mk(model, params, eos=cfg.vocab_size), _prompts(cfg))
    eng = _mk(model, params, eos=cfg.vocab_size, prefill_chunk=16,
              prefill_chunk_budget=8)
    assert _streams(eng, _prompts(cfg)) == ref
    assert eng.chunk_budget == 8
    _drained(eng)


@pytest.mark.parametrize("policy", ["fifo", "coverage"])
def test_chunked_streams_identical_per_policy(tiny_model, policy):
    """``prefill_order`` may reorder chunk jobs (coverage ranks by
    difficulty prior + progress) but greedy streams are admission-order
    invariant, so both policies must match their own unchunked runs."""
    cfg, model, params = tiny_model
    kw = dict(eos=cfg.vocab_size, sched_policy=policy)
    ref = _streams(_mk(model, params, **kw), _prompts(cfg))
    eng = _mk(model, params, prefill_chunk=16, **kw)
    assert _streams(eng, _prompts(cfg)) == ref, policy
    _drained(eng)


def test_chunked_with_prefix_cache_identity_and_hits(tiny_model):
    """Chunk jobs probe the prefix cache at job-open time (a full-page
    hit becomes the job's already-resident head) and the final chunk
    seeds new entries. Jobs probe once when opened, so hits need a
    second wave whose prefixes wave one already seeded — within one
    wave all jobs open before any seeds. Streams must match the
    unchunked prefix-cache engine wave for wave."""
    cfg, model, params = tiny_model
    prompts = _prompts(cfg, lens=(40, 40, 40, 37), seed=5)
    for p in prompts[1:]:
        p[:32] = prompts[0][:32]             # 4 shared full pages at ps=8
    kw = dict(eos=cfg.vocab_size, prefix_cache=True)
    ref_eng = _mk(model, params, **kw)
    eng = _mk(model, params, prefill_chunk=16, **kw)
    for uid0 in (0, 100):                    # wave 2 re-sends the prompts
        assert _streams(eng, prompts, uid0=uid0) == \
            _streams(ref_eng, prompts, uid0=uid0), uid0
    assert eng.kv_stats()["prefix_cache"]["hits"] > 0
    _drained(eng)


def test_chunked_with_speculation_identity(tiny_model):
    """Chunked prefill composes with the n-gram draft + block-verify
    decode loop: greedy streams stay byte-identical."""
    cfg, model, params = tiny_model
    prompts = [np.full(n, 7, np.int32) for n in (40, 9, 33)]
    kw = dict(eos=cfg.vocab_size, macro_steps=4, spec_k=4)
    ref = _streams(_mk(model, params, **kw), prompts)
    eng = _mk(model, params, prefill_chunk=16, **kw)
    assert _streams(eng, prompts) == ref
    assert eng.sched_stats()["chunk_calls"] > 0
    _drained(eng)


def test_xla_impl_quietly_ignores_chunking(tiny_model):
    """The dense xla cache has no pages to chunk into; the engine must
    degrade to whole-prompt prefill, not crash or diverge."""
    cfg, model, params = tiny_model
    kw = dict(eos=cfg.vocab_size, impl="xla", cache_len=96)
    ref = _streams(_mk(model, params, **kw), _prompts(cfg))
    eng = _mk(model, params, prefill_chunk=16, **kw)
    assert not eng.chunked
    assert _streams(eng, _prompts(cfg)) == ref


# ---------------------------------------------------------------------------
# cancellation mid-prefill: every chunk page comes back
# ---------------------------------------------------------------------------

def test_cancel_mid_chunking_returns_pages(tiny_model):
    """The long prompt is submitted while shorts are decoding, with one
    slot left free (``pump`` only runs admission passes when a slot is
    free), so its chunk job is budget-paced — one 16-token chunk per
    turn — and a cancel lands mid-job with pages held."""
    cfg, model, params = tiny_model
    eng = _mk(model, params, eos=cfg.vocab_size, slots=3,
              prefill_chunk=16, prefill_chunk_budget=16)
    shorts = _prompts(cfg, lens=(6, 7), seed=1)
    long_p = _prompts(cfg, lens=(96,), seed=2)[0]
    for i, p in enumerate(shorts):
        eng.submit(_request(i, p))
    eng.pump()                               # shorts admitted and live
    eng.submit(_request(99, long_p))
    eng.pump()                               # one budget turn of chunks
    assert 99 in eng._chunking, "long prompt should be mid-chunking"
    held = list(eng._chunking[99]["pages"])
    assert held, "no chunk pages held yet"
    assert eng.cancel(99)
    eng.run()
    assert eng.result(99).cancelled
    for uid in range(len(shorts)):
        assert len(eng.result(uid).tokens) == MAX_NEW
    _drained(eng)
    assert all(eng.pool.refcount(p) == 0 for p in held)


def test_finalize_starved_frees_chunk_pages(tiny_model):
    """Terminal starvation (global token budget exhausted) with a job
    mid-chunking must free the half-prefilled chunk pages and finalize
    the request as starved, not leak or hang."""
    cfg, model, params = tiny_model
    eng = _mk(model, params, eos=cfg.vocab_size, prefill_chunk=16)
    long_p = _prompts(cfg, lens=(96,), seed=4)[0]
    req = _request(7, long_p)
    eng.submit(req)
    eng._start_chunk_job(req)
    assert eng._run_chunk(7, eng._chunking[7]) > 0
    held = list(eng._chunking[7]["pages"])
    assert held
    eng._finalize_starved()
    assert not eng._chunking
    assert 7 in eng.starved_uids
    assert len(eng.result(7).tokens) == 0
    _drained(eng)
    assert all(eng.pool.refcount(p) == 0 for p in held)


# ---------------------------------------------------------------------------
# disaggregation: prefill shard range, decode reads cross-shard
# ---------------------------------------------------------------------------

def test_prefill_shard_ids_pure():
    from repro.distributed.sharding import prefill_shard_ids
    assert prefill_shard_ids(4, 2) == (0, 1)
    assert prefill_shard_ids(4, 0) == (0, 1, 2, 3)
    assert prefill_shard_ids(2, 2) == (0, 1)
    with pytest.raises(AssertionError):
        prefill_shard_ids(2, 3)


def test_disaggregation_requires_paged(tiny_model):
    cfg, model, params = tiny_model
    with pytest.raises(AssertionError):
        _mk(model, params, eos=cfg.vocab_size, impl="xla",
            prefill_shards=1)


@pytest.mark.skipif(jax.device_count() < 2,
                    reason="disaggregation needs >= 2 devices (set "
                           "XLA_FLAGS=--xla_force_host_platform_device_"
                           "count=8 on CPU)")
def test_disaggregated_prefill_streams_identical(tiny_model):
    """prefill_shards=k pins chunk-job pages to shards [0, k); decode
    slots on every shard must read them (GSPMD cross-shard gathers)
    byte-identically to the non-disaggregated engine."""
    from repro.launch.mesh import make_serve_mesh
    cfg, model, params = tiny_model
    dp = 2
    mesh = make_serve_mesh(dp)
    prompts = _prompts(cfg, lens=(50, 6, 33, 44), seed=7)
    kw = dict(eos=cfg.vocab_size, slots=4, cache_len=128)
    ref = _streams(_mk(model, params, **kw), prompts)
    plain = _streams(_mk(model, params, mesh=mesh, **kw), prompts)
    eng = _mk(model, params, mesh=mesh, prefill_chunk=16,
              prefill_shards=1, **kw)

    seen_shards = []
    orig = eng._run_chunk

    def spy(uid, job):
        seen_shards.append(job["shard"])
        for p in job["pages"]:
            assert eng.pool.shard_of(p) == job["shard"]
        return orig(uid, job)

    eng._run_chunk = spy
    got = _streams(eng, prompts)
    assert plain == ref
    assert got == ref, "disaggregated streams diverged"
    assert seen_shards and set(seen_shards) == {0}, \
        "chunk jobs escaped the prefill shard range"
    _drained(eng)
