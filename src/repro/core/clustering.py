"""Online semantic clustering of candidate answers (paper Eq. 13).

The paper calls an external LLM to compute pairwise similarities and
cluster; a serving framework cannot block a decode round on a second LLM,
so we cluster mean-pooled answer embeddings with a cosine threshold
(default 0.85 — the paper's own clustering threshold; its dedup uses 0.9).
See DESIGN.md §6.

The cluster table has a fixed capacity M (mask semantics) so the whole
update jits and vmaps over requests. Centroids are running means; a new
candidate either joins its nearest cluster (cos >= threshold) or opens a
new one; when the table is full it joins the nearest regardless.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class ClusterTable(NamedTuple):
    centroids: jax.Array     # (M, d) running-mean embeddings (unnormalized)
    sizes: jax.Array         # (M,) float32 member counts
    score_lse: jax.Array     # (M,) logsumexp of member evidence scores
    n_clusters: jax.Array    # () int32


def make_table(max_clusters: int, emb_dim: int) -> ClusterTable:
    return ClusterTable(
        centroids=jnp.zeros((max_clusters, emb_dim), jnp.float32),
        sizes=jnp.zeros((max_clusters,), jnp.float32),
        score_lse=jnp.full((max_clusters,), -jnp.inf, jnp.float32),
        n_clusters=jnp.zeros((), jnp.int32),
    )


def _cos(a, b, eps=1e-8):
    a = a / (jnp.linalg.norm(a, axis=-1, keepdims=True) + eps)
    b = b / (jnp.linalg.norm(b, axis=-1, keepdims=True) + eps)
    return a @ b.T


def assign_one(table: ClusterTable, emb, score, valid, threshold: float
               ) -> Tuple[ClusterTable, jax.Array]:
    """Assign one candidate. emb: (d,), score: (), valid: () bool.

    Returns (new_table, cluster_index (int32, -1 if invalid)).
    """
    M = table.centroids.shape[0]
    active = jnp.arange(M) < table.n_clusters
    sims = _cos(emb[None, :], table.centroids)[0]                 # (M,)
    sims = jnp.where(active, sims, -jnp.inf)
    best = jnp.argmax(sims)
    best_sim = sims[best]
    table_full = table.n_clusters >= M
    join = (best_sim >= threshold) | (table_full & (table.n_clusters > 0))
    idx = jnp.where(join, best, table.n_clusters).astype(jnp.int32)
    idx = jnp.minimum(idx, M - 1)

    one = jax.nn.one_hot(idx, M)
    new_sizes = table.sizes + one * valid
    # running-mean centroid
    new_cent = jnp.where(
        (one[:, None] > 0) & valid,
        (table.centroids * table.sizes[:, None] + emb[None, :] * one[:, None])
        / jnp.maximum(new_sizes[:, None], 1.0),
        table.centroids)
    new_lse = jnp.where(one > 0,
                        jnp.logaddexp(table.score_lse, score),
                        table.score_lse)
    new_lse = jnp.where(valid, new_lse, table.score_lse)
    new_n = jnp.where(valid & ~join, table.n_clusters + 1, table.n_clusters)
    new_n = jnp.minimum(new_n, M)
    out = ClusterTable(
        centroids=jnp.where(valid, new_cent, table.centroids),
        sizes=jnp.where(valid, new_sizes, table.sizes),
        score_lse=new_lse,
        n_clusters=new_n)
    return out, jnp.where(valid, idx, -1)


def assign_batch(table: ClusterTable, embs, scores, valids, threshold: float
                 ) -> Tuple[ClusterTable, jax.Array]:
    """Sequentially assign a round of R candidates (lax.scan)."""

    def body(tb, inp):
        e, s, v = inp
        tb, idx = assign_one(tb, e, s, v, threshold)
        return tb, idx

    table, idxs = jax.lax.scan(body, table, (embs, scores, valids))
    return table, idxs


def posterior_weights(table: ClusterTable) -> jax.Array:
    """Eq. 14: p̂_k = Σ_{i∈C_k} exp(S_i) / Σ_all exp(S_i).

    Computed from the per-cluster score logsumexp accumulators, so CAMD
    state is O(M) — no candidate list retained on device.
    """
    M = table.score_lse.shape[0]
    active = jnp.arange(M) < table.n_clusters
    lse = jnp.where(active, table.score_lse, -jnp.inf)
    total = jax.nn.logsumexp(lse)
    return jnp.where(active, jnp.exp(lse - total), 0.0)
