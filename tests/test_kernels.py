"""Per-kernel correctness sweeps: Pallas (interpret=True) vs jnp oracles.

Shapes sweep ragged lengths (block padding paths), GQA group sizes, and
dtypes; allclose tolerances are dtype-dependent.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.xmodal_score import xmodal_score

TOLS = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
        jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape).astype(dtype)


@pytest.mark.parametrize("L", [64, 128, 200, 384])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 96)])
def test_flash_attention_sweep(L, dtype, causal, window):
    B, H, hd = 2, 2, 64
    k0 = jax.random.PRNGKey(L + window)
    q = _rand(k0, (B, L, H, hd), dtype)
    k = _rand(jax.random.fold_in(k0, 1), (B, L, H, hd), dtype)
    v = _rand(jax.random.fold_in(k0, 2), (B, L, H, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          blk_q=128, blk_k=128, interpret=True)
    exp = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), **TOLS[dtype])


@pytest.mark.parametrize("S", [128, 256, 300, 1024])
@pytest.mark.parametrize("Hkv,H", [(1, 4), (2, 8), (4, 4)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(S, Hkv, H, dtype):
    B, hd = 2, 64
    k0 = jax.random.PRNGKey(S + H)
    q = _rand(k0, (B, 1, H, hd), dtype)
    k = _rand(jax.random.fold_in(k0, 1), (B, S, Hkv, hd), dtype)
    v = _rand(jax.random.fold_in(k0, 2), (B, S, Hkv, hd), dtype)
    mask = jax.random.bernoulli(jax.random.fold_in(k0, 3), 0.75, (B, S))
    mask = mask.at[:, :2].set(True)  # never fully masked
    out = decode_attention(q, k, v, mask, blk_s=128, interpret=True)
    exp = ref.decode_attention_ref(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), **TOLS[dtype])


@pytest.mark.parametrize("L,Nv,Nt", [(64, 32, 16), (130, 100, 50),
                                     (256, 128, 128), (37, 12, 5)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_xmodal_score_sweep(L, Nv, Nt, dtype):
    B, d = 2, 32
    k0 = jax.random.PRNGKey(L + Nv)
    tok = _rand(k0, (B, L, d), dtype)
    vis = _rand(jax.random.fold_in(k0, 1), (B, Nv, d), dtype)
    txt = _rand(jax.random.fold_in(k0, 2), (B, Nt, d), dtype)
    mask = (jax.random.uniform(jax.random.fold_in(k0, 3), (B, L)) > 0.2)
    mask = mask.at[:, 0].set(True)
    out = xmodal_score(tok, mask, vis, txt, blk=128, interpret=True)
    exp = ref.xmodal_score_ref(tok, mask, vis, txt)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=3e-2 if dtype == jnp.bfloat16 else 1e-5,
                               atol=3e-2 if dtype == jnp.bfloat16 else 1e-5)


def test_xmodal_matches_core_scoring():
    """The kernel oracle and repro.core.scoring must agree (same Eq. 8-9)."""
    from repro.core.scoring import cross_modal_consistency
    B, L, Nv, Nt, d = 2, 50, 20, 10, 16
    k0 = jax.random.PRNGKey(0)
    tok = jax.random.normal(k0, (B, L, d))
    vis = jax.random.normal(jax.random.fold_in(k0, 1), (B, Nv, d))
    txt = jax.random.normal(jax.random.fold_in(k0, 2), (B, Nt, d))
    mask = jnp.ones((B, L))
    a = cross_modal_consistency(tok, mask, vis, txt)
    b = ref.xmodal_score_ref(tok, mask, vis, txt)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5)
