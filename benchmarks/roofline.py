"""Roofline report (deliverable g): reads the dry-run JSONs and emits the
per-(arch × shape) three-term roofline table for EXPERIMENTS.md.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

RESULTS = os.path.join(os.path.dirname(__file__), "results")

COLS = ("arch", "shape", "mesh", "bottleneck")


def load(mesh: str = "pod16x16", results_dir: str = RESULTS) -> List[Dict]:
    recs = []
    for f in sorted(glob.glob(f"{results_dir}/dryrun_{mesh}_*.json")):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def fmt_table(recs: List[Dict]) -> str:
    header = ("| arch | shape | compute_ms | memory_ms | collective_ms | "
              "bottleneck | useful_flops | fits 16GB | note |")
    sep = "|" + "---|" * 9
    lines = [header, sep]
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    for r in sorted(recs, key=lambda r: (r["arch"], order.get(r["shape"], 9))):
        note = "windowed-variant" if r.get("window_variant") else ""
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']*1e3:.2f} | "
            f"{r['memory_s']*1e3:.2f} | {r['collective_s']*1e3:.2f} | "
            f"{r['bottleneck'].replace('_s','')} | "
            f"{r['useful_flops_ratio']:.3f} | "
            f"{'yes' if r['fits_16gb_hbm'] else 'NO'} | {note} |")
    return "\n".join(lines)


def summarize(recs: List[Dict]) -> Dict:
    out = {"n": len(recs)}
    bn = {}
    for r in recs:
        bn[r["bottleneck"]] = bn.get(r["bottleneck"], 0) + 1
    out["bottlenecks"] = bn
    out["fits"] = sum(1 for r in recs if r["fits_16gb_hbm"])
    return out


def run(verbose: bool = True):
    recs = load()
    if not recs:
        if verbose:
            print("  (no dry-run results yet — run repro.launch.dryrun)")
        return {"n": 0}
    if verbose:
        print(fmt_table(recs))
        print(summarize(recs))
    return {"records": recs, "summary": summarize(recs)}


if __name__ == "__main__":
    run()
