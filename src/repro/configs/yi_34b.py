"""yi-34b — 01.AI Yi-34B, llama-architecture GQA.

[arXiv:2403.04652]: 60L, d_model=7168, 56 q heads, GQA kv=8, d_ff=20480,
vocab 64000.
"""
from repro.config import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="yi-34b",
    family="dense",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    rope_theta=5e6,
    block_pattern=(ATTN,),
    mlp_activation="swiglu",
    source="arXiv:2403.04652",
)
