"""granite-34b — IBM Granite 34B Code, llama-architecture with MQA (kv=1).

[arXiv:2405.04324]: 88L, d_model=6144, 48 q heads, MQA kv=1, d_ff=24576,
vocab 49152.
"""
from repro.config import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="granite-34b",
    family="dense",
    num_layers=88,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    block_pattern=(ATTN,),
    mlp_activation="gelu",        # granite code models use gelu MLP
    tie_embeddings=True,
    source="arXiv:2405.04324",
)
