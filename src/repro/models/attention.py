"""Grouped-query attention: init, prefill, and cached decode.

Two execution paths:
  * ``impl="xla"``  — pure-jnp blockwise attention (scan over query chunks,
    online softmax-free since each chunk sees the full K). Used on CPU, in
    the multi-pod dry-run, and as the oracle for the Pallas kernels.
  * ``impl="pallas"`` — the TPU flash-attention / flash-decode kernels in
    ``repro.kernels`` (validated in interpret mode on CPU).

Supports GQA/MQA (num_kv_heads < num_heads), QKV bias (qwen2.5), qk-norm
(qwen3), RoPE, causal masking, and sliding windows (``window > 0``).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import apply_rope, dense, dense_init, rmsnorm_headwise

NEG_INF = -1e30


def attn_init(key, cfg: ModelConfig, dtype=jnp.float32):
    hd = cfg.resolved_head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": dense_init(kq, cfg.d_model, cfg.num_heads * hd, dtype, bias=cfg.qkv_bias),
        "wk": dense_init(kk, cfg.d_model, cfg.num_kv_heads * hd, dtype, bias=cfg.qkv_bias),
        "wv": dense_init(kv, cfg.d_model, cfg.num_kv_heads * hd, dtype, bias=cfg.qkv_bias),
        "wo": dense_init(ko, cfg.num_heads * hd, cfg.d_model, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype=dtype)
        p["k_norm"] = jnp.ones((hd,), dtype=dtype)
    return p


def _project_qkv(params, cfg: ModelConfig, x, positions, rope: bool = True):
    B, L, _ = x.shape
    hd = cfg.resolved_head_dim
    q = dense(params["wq"], x).reshape(B, L, cfg.num_heads, hd)
    k = dense(params["wk"], x).reshape(B, L, cfg.num_kv_heads, hd)
    v = dense(params["wv"], x).reshape(B, L, cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        q = rmsnorm_headwise(params["q_norm"], q, cfg.norm_eps)
        k = rmsnorm_headwise(params["k_norm"], k, cfg.norm_eps)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _expand_kv(k, num_heads: int):
    """(B, S, Hkv, hd) -> (B, S, Hq, hd) by repeating groups."""
    B, S, Hkv, hd = k.shape
    rep = num_heads // Hkv
    if rep == 1:
        return k
    return jnp.repeat(k, rep, axis=2)


def sdpa(q, k, v, *, causal: bool, window: int = 0,
         q_offset: int = 0, kv_mask=None, chunk: int = 512):
    """Blockwise GQA scaled-dot-product attention (XLA path).

    q: (B, Lq, Hq, hd); k/v: (B, Lk, Hkv, hd) with Hq % Hkv == 0. The
    query-head groups share their kv head through einsum batch dims — the
    expanded K/V are NEVER materialized. This matters twice: it halves+
    HBM traffic, and under context-parallel (S-sharded) KV caches it keeps
    GSPMD on the sharded-S attention plan (a `repeat` to Hq heads made the
    partitioner re-shard the whole cache to partial-axis head sharding —
    a measured 2.15 GB/layer/token all-gather on qwen3 decode_32k).

    ``q_offset``: absolute position of q[0] relative to k[0] (for decode /
    chunked prefill). ``kv_mask``: optional key-validity mask — (B, Lk)
    shared across queries, or (B, Lq, Lk) per-query (speculative block
    verification, where query i may attend a different prefix).
    Scans over query chunks so the Lq×Lk score matrix never materializes
    for long sequences.
    """
    B, Lq, Hq, hd = q.shape
    Lk, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = hd ** -0.5
    kv_positions = jnp.arange(Lk)

    def attend_chunk(q_chunk, pos0):
        # q_chunk: (B,C,Hq,hd); pos0: absolute position of its first query.
        # K/V stay in their storage dtype — fp32 happens in the MXU
        # accumulator (preferred_element_type), not as a materialized
        # fp32 copy of the whole cache (which doubles decode HBM traffic).
        C = q_chunk.shape[1]
        qg = q_chunk.reshape(B, C, Hkv, G, hd)
        scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                            preferred_element_type=jnp.float32) * scale
        q_pos = pos0 + jnp.arange(C) + q_offset
        rel = q_pos[:, None] - kv_positions[None, :]           # (C,Lk)
        mask = jnp.ones_like(rel, dtype=bool)
        if causal:
            mask &= rel >= 0
        if window > 0:
            mask &= rel < window
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        if kv_mask is not None:
            if kv_mask.ndim == 3:
                m = jax.lax.dynamic_slice_in_dim(kv_mask, pos0, C, axis=1)
                scores = jnp.where(m[:, None, None], scores, NEG_INF)
            else:
                scores = jnp.where(kv_mask[:, None, None, None, :], scores,
                                   NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)                # (B,Hkv,G,C,Lk)
        out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v,
                         preferred_element_type=jnp.float32)
        return out.reshape(B, C, Hq, hd).astype(q.dtype)

    if Lq <= chunk:
        return attend_chunk(q, 0)
    assert Lq % chunk == 0, (Lq, chunk)
    n = Lq // chunk
    qs = q.reshape(B, n, chunk, Hq, hd).transpose(1, 0, 2, 3, 4)

    # checkpoint each chunk: the backward pass recomputes the chunk's
    # score matrix instead of saving all n chunks' (C, Lk) scores — peak
    # activation memory stays O(C·Lk) instead of O(Lq·Lk).
    attend_ckpt = jax.checkpoint(attend_chunk, static_argnums=())

    def body(_, inp):
        i, q_chunk = inp
        return None, attend_ckpt(q_chunk, i * chunk)

    _, outs = jax.lax.scan(body, None, (jnp.arange(n), qs))
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, Lq, Hq, hd)


def attn_prefill(params, cfg: ModelConfig, x, positions, *, window: int = 0,
                 impl: str = "xla", cross_kv=None, causal: bool = True,
                 kv_mask=None, ctx_kv=None, q_offset=0):
    """Full-sequence attention. Returns (out, (k, v)) for cache seeding.

    ``cross_kv``: optional (k, v) from an encoder — if given, performs
    cross-attention (no causal mask, no rope on q/k mismatch handled by
    caller passing rope=False-projected kv).

    ``kv_mask``: optional (B, L) key-validity mask for length-bucketed
    batched prefill (rows right-padded to the bucket length). Causal
    masking already keeps every *real* position exact under right-padding
    (position i < L only attends j <= i < L), so the mask is defensive —
    it additionally pins the pad positions' outputs. The Pallas flash
    kernel has no mask argument; bucketed prefill on the pallas impl
    relies on causality alone (real rows identical either way).

    ``ctx_kv``: optional (k, v) of already-computed *self*-attention
    context occupying absolute positions [0, q_offset) — the
    continuation-prefill path for cross-request prefix-cache hits. The
    suffix queries (at absolute positions ``positions``, rope applied
    there) attend causally over [context; new]. Only the NEW (k, v) is
    returned for cache seeding — the context already lives in the KV
    pool. Always runs the XLA sdpa (the flash kernel has no context
    argument; KV values are impl-independent so the cache stays exact).
    """
    B, L, _ = x.shape
    if cross_kv is not None:
        hd = cfg.resolved_head_dim
        q = dense(params["wq"], x).reshape(B, L, cfg.num_heads, hd)
        k, v = cross_kv
        out = sdpa(q, k, v, causal=False)
        out = dense(params["wo"], out.reshape(B, L, -1))
        return out, (k, v)
    q, k, v = _project_qkv(params, cfg, x, positions)
    if ctx_kv is not None:
        kc = jnp.concatenate([ctx_kv[0].astype(k.dtype), k], axis=1)
        vc = jnp.concatenate([ctx_kv[1].astype(v.dtype), v], axis=1)
        out = sdpa(q, _expand_kv(kc, cfg.num_heads),
                   _expand_kv(vc, cfg.num_heads),
                   causal=causal, window=window, q_offset=q_offset)
        out = dense(params["wo"], out.reshape(B, L, -1))
        return out, (k, v)
    if impl == "pallas":
        from repro.kernels import ops
        out = ops.flash_attention(q, _expand_kv(k, cfg.num_heads),
                                  _expand_kv(v, cfg.num_heads),
                                  causal=causal, window=window)
    else:
        # PREFILL/TRAIN: expand kv heads to Hq. The grouped-GQA form is
        # essential for decode (it keeps GSPMD on the S-sharded cache
        # plan) but in training it backfires: with Hkv < model-axis the
        # partitioner resolves the grouped einsum by ALL-GATHERING THE
        # BATCH (measured: 90 GB/dev temp on qwen3 train). Expanded heads
        # shard cleanly over "model"; XLA fuses the broadcast, so no real
        # HBM cost on TPU. (§Perf iteration 12.)
        out = sdpa(q, _expand_kv(k, cfg.num_heads),
                   _expand_kv(v, cfg.num_heads),
                   causal=causal, window=window, kv_mask=kv_mask)
    out = dense(params["wo"], out.reshape(B, L, -1))
    return out, (k, v)


# ---------------------------------------------------------------------------
# Cached decode
# ---------------------------------------------------------------------------

def make_kv_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype):
    hd = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, cache_len, cfg.num_kv_heads, hd), dtype=dtype),
        "v": jnp.zeros((batch, cache_len, cfg.num_kv_heads, hd), dtype=dtype),
    }


def cache_write(cache, k_new, v_new, pos, valid=None):
    """Ring-buffer write of one token at absolute position ``pos``.

    k_new/v_new: (B, 1, Hkv, hd); pos: (B,) int32 per-row positions
    (continuous batching — each slot may be at a different depth).
    ``valid``: optional (B,) bool — rows with valid=False write nothing
    (speculative block positions past a slot's token limit).

    Implemented as an iota-compare SELECT over the sequence dim rather
    than a scatter: a per-row scatter into a context-parallel (S-sharded)
    cache triggers GSPMD's "involuntary full rematerialization" — the
    whole cache is all-gathered every step (measured 2.15 GB/layer/token
    on qwen3 decode_32k). The select is elementwise, so each sequence
    shard updates locally; XLA fuses it into an in-place update.
    """
    B, S = cache["k"].shape[:2]
    idx = jnp.mod(pos, S)                                  # (B,)
    hit = jnp.arange(S)[None, :] == idx[:, None]           # (B, S)
    if valid is not None:
        hit &= valid[:, None]
    m = hit[:, :, None, None]
    k = jnp.where(m, k_new, cache["k"])
    v = jnp.where(m, v_new, cache["v"])
    return {"k": k, "v": v}


def cache_write_block(cache, k_new, v_new, pos, valid=None):
    """Ring-buffer write of S consecutive tokens in ONE select.

    k_new/v_new: (B, S, Hkv, hd) for absolute positions pos..pos+S-1.
    A Python loop of S ``cache_write`` calls materializes S full-cache
    intermediates inside a jitted loop body; writing the block at once
    keeps it to one. Same select-not-scatter rationale as
    ``cache_write`` (context-parallel shards update locally), and the
    written values are bit-identical to the sequential loop — each ring
    slot takes its value straight from ``k_new``.
    """
    B, Sc = cache["k"].shape[:2]
    S = k_new.shape[1]
    slot = jnp.arange(Sc)[None, :]
    # which block offset (if any) lands on this ring slot
    s_idx = jnp.mod(slot - pos[:, None], Sc)               # (B, Sc)
    hit = s_idx < S
    gidx = jnp.clip(s_idx, 0, S - 1)
    if valid is not None:
        hit &= jnp.take_along_axis(valid, gidx, axis=1)
    ks = jnp.take_along_axis(k_new, gidx[:, :, None, None], axis=1)
    vs = jnp.take_along_axis(v_new, gidx[:, :, None, None], axis=1)
    m = hit[:, :, None, None]
    return {"k": jnp.where(m, ks, cache["k"]),
            "v": jnp.where(m, vs, cache["v"])}


# ---------------------------------------------------------------------------
# Quantized KV storage (int8 / fp8-e4m3 pools with per-row absmax scales)
# ---------------------------------------------------------------------------

# ``--kv-dtype`` names accepted by the serving stack. "auto" keeps the
# engine's parameter dtype (the historical behaviour — byte-identical
# streams); fp32/bf16 store pages in that dtype with no scales; int8/fp8
# store quantized pages plus per-row scale tensors.
KV_DTYPES = ("auto", "fp32", "bf16", "int8", "fp8")

# fp8-e4m3 where the jax build ships it (0.4.x+ on all backends); None
# keeps "fp8" rejected with a clear error instead of an AttributeError.
FP8_DTYPE = getattr(jnp, "float8_e4m3fn", None)

_QMAX = {"int8": 127.0, "fp8": 448.0}   # e4m3 finite max


def kv_storage_dtype(kv_dtype: str, dtype):
    """Resolve a ``--kv-dtype`` name to (storage dtype, quantized?)."""
    if kv_dtype in ("auto", "", None):
        return dtype, False
    if kv_dtype == "fp32":
        return jnp.float32, False
    if kv_dtype == "bf16":
        return jnp.bfloat16, False
    if kv_dtype == "int8":
        return jnp.int8, True
    if kv_dtype == "fp8":
        if FP8_DTYPE is None:
            raise ValueError(
                "kv_dtype='fp8' needs jnp.float8_e4m3fn, which this jax "
                "build does not provide — use 'int8' instead")
        return FP8_DTYPE, True
    raise ValueError(f"kv_dtype must be one of {KV_DTYPES}, got {kv_dtype!r}")


def _qmax_for(qdtype) -> float:
    return _QMAX["int8"] if jnp.dtype(qdtype) == jnp.dtype(jnp.int8) \
        else _QMAX["fp8"]


def kv_quantize(x, qdtype):
    """Absmax-quantize KV vectors to ``qdtype`` (int8 or fp8-e4m3).

    x: (..., hd). Returns (q (..., hd) qdtype, scale (...) float32) with
    one scale per trailing head-dim row — the granularity at which the
    paged pools are written (one (page, slot, kv-head) row per token), so
    an incremental decode append never requantizes its page neighbours
    and the roundtrip error stays <= 1/2 scale ULP unconditionally.
    All-zero rows quantize to zeros exactly (the scale floor only guards
    the division)."""
    xf = x.astype(jnp.float32)
    qmax = _qmax_for(qdtype)
    amax = jnp.max(jnp.abs(xf), axis=-1)
    scale = jnp.maximum(amax, 1e-30) / qmax
    y = xf / scale[..., None]
    if jnp.dtype(qdtype) == jnp.dtype(jnp.int8):
        q = jnp.clip(jnp.round(y), -qmax, qmax).astype(jnp.int8)
    else:
        q = y.astype(qdtype)
    return q, scale.astype(jnp.float32)


def kv_dequantize(q, scale):
    """Inverse of ``kv_quantize``: q (..., hd) qdtype × scale (...) ->
    float32 values."""
    return q.astype(jnp.float32) * scale[..., None].astype(jnp.float32)


def paged_pool_page_axis(ndim: int) -> int:
    """Index of the *page* axis in a paged-pool leaf.

    ``make_paged_kv_cache`` emits (P, ps, Hkv, hd); the serving cache
    stacks same-kind layers into (n_super, P, ps, Hkv, hd) super
    entries. Under mesh-parallel serving the pool is sharded on exactly
    this axis (``distributed.sharding.serve cache specs``), with shard
    boundaries matching the host allocator's per-shard page-id ranges —
    a slot that only references its own shard's pages keeps the decode
    gather and ``paged_cache_write``'s scatter shard-local."""
    assert ndim in (4, 5), ndim
    return ndim - 4


def make_paged_kv_cache(cfg: ModelConfig, num_pages: int, page_size: int,
                        dtype, kv_dtype: str = "auto"):
    """A shared pool of KV pages (no batch axis — slots reference pages
    through a block table). Page 0 is conventionally the quarantine page
    idle slots write into; allocators should never hand it out (sharded
    pools reserve one quarantine page per shard — see
    ``serving.page_pool.PagePool.quarantine_page``).

    ``kv_dtype`` selects the storage mode (``KV_DTYPES``): quantized
    modes (int8/fp8) carry per-(page, slot, kv-head) absmax scale
    tensors ``k_scale``/``v_scale`` of shape (P, ps, Hkv) float32 next
    to the page values — pages and their scales travel together, so
    CoW prefix-shared pages share scales for free and the write paths
    scatter both in one pass.

    Sharding contract: the pool may be sharded on the page axis (axis
    ``paged_pool_page_axis``; scale leaves are page-major too) across
    the serving mesh's data shards. Page ids in block tables stay
    GLOBAL — locality comes from the host allocator handing each slot
    pages from its own shard's range, not from renumbering."""
    hd = cfg.resolved_head_dim
    sdtype, quantized = kv_storage_dtype(kv_dtype, dtype)
    cache = {
        "k_pages": jnp.zeros((num_pages, page_size, cfg.num_kv_heads, hd),
                             dtype=sdtype),
        "v_pages": jnp.zeros((num_pages, page_size, cfg.num_kv_heads, hd),
                             dtype=sdtype),
    }
    if quantized:
        shape = (num_pages, page_size, cfg.num_kv_heads)
        cache["k_scale"] = jnp.zeros(shape, jnp.float32)
        cache["v_scale"] = jnp.zeros(shape, jnp.float32)
    return cache


def paged_cache_write(cache, k_new, v_new, pos, block_table, valid=None):
    """Write one token into the page pool through the block table.

    k_new/v_new: (B, 1, Hkv, hd); pos: (B,) absolute positions;
    block_table: (B, n_pages) int32. Token at position p of row b lands
    in page ``block_table[b, p // ps]`` at offset ``p % ps``.

    This is a per-row scatter — unlike ``cache_write``'s select, it is
    NOT safe under a context-parallel (S-sharded) cache. Under the
    serving mesh the pool is sharded on the *page* axis instead: the
    scatter stays correct for any page id (GSPMD routes each row's
    update to the owning shard), and stays *local* whenever the host
    allocator keeps a slot's pages in its own shard's id range (the
    sharded ``PagePool`` guarantees this for tail + frontier pages).
    Rows whose pos has run past the table (idle slots) clamp to the
    last logical page; their block-table row should point at their
    shard's quarantine page. ``valid``: optional (B,) bool — rows with
    valid=False are dropped outright (written nowhere, not even the
    quarantine page), which is what speculative block verification
    needs for positions past a slot's token limit.
    """
    P, ps = cache["k_pages"].shape[:2]
    n_pages = block_table.shape[1]
    logical = jnp.clip(pos // ps, 0, n_pages - 1)                  # (B,)
    page = jnp.take_along_axis(block_table, logical[:, None], axis=1)[:, 0]
    page = jnp.clip(page, 0, P - 1)
    if valid is not None:
        page = jnp.where(valid, page, -1)
    off = jnp.mod(pos, ps)
    if "k_scale" in cache:
        qd = cache["k_pages"].dtype
        kq, ks = kv_quantize(k_new[:, 0], qd)      # (B,Hkv,hd), (B,Hkv)
        vq, vs = kv_quantize(v_new[:, 0], qd)
        return {"k_pages": cache["k_pages"].at[page, off].set(kq,
                                                              mode="drop"),
                "v_pages": cache["v_pages"].at[page, off].set(vq,
                                                              mode="drop"),
                "k_scale": cache["k_scale"].at[page, off].set(ks,
                                                              mode="drop"),
                "v_scale": cache["v_scale"].at[page, off].set(vs,
                                                              mode="drop")}
    k = cache["k_pages"].at[page, off].set(
        k_new[:, 0].astype(cache["k_pages"].dtype), mode="drop")
    v = cache["v_pages"].at[page, off].set(
        v_new[:, 0].astype(cache["v_pages"].dtype), mode="drop")
    return {"k_pages": k, "v_pages": v}


def paged_cache_write_block(cache, k_new, v_new, pos, block_table,
                            valid=None):
    """Write S consecutive tokens through the block table in ONE scatter.

    k_new/v_new: (B, S, Hkv, hd) for absolute positions pos..pos+S-1;
    ``valid``: optional (B, S). Block positions are distinct, so the
    (page, offset) targets never collide and the batched scatter is
    bit-identical to S sequential ``paged_cache_write`` calls — without
    S full-pool intermediates inside the decode loop body. Sharding
    story is unchanged (same per-row scatter, page-axis sharded pool).
    """
    P, ps = cache["k_pages"].shape[:2]
    n_pages = block_table.shape[1]
    S = k_new.shape[1]
    p = pos[:, None] + jnp.arange(S, dtype=pos.dtype)[None, :]   # (B, S)
    logical = jnp.clip(p // ps, 0, n_pages - 1)
    page = jnp.take_along_axis(block_table, logical, axis=1)
    page = jnp.clip(page, 0, P - 1)
    if valid is not None:
        page = jnp.where(valid, page, -1)
    off = jnp.mod(p, ps)
    if "k_scale" in cache:
        qd = cache["k_pages"].dtype
        kq, ks = kv_quantize(k_new, qd)          # (B,S,Hkv,hd), (B,S,Hkv)
        vq, vs = kv_quantize(v_new, qd)
        return {"k_pages": cache["k_pages"].at[page, off].set(kq,
                                                              mode="drop"),
                "v_pages": cache["v_pages"].at[page, off].set(vq,
                                                              mode="drop"),
                "k_scale": cache["k_scale"].at[page, off].set(ks,
                                                              mode="drop"),
                "v_scale": cache["v_scale"].at[page, off].set(vs,
                                                              mode="drop")}
    k = cache["k_pages"].at[page, off].set(
        k_new.astype(cache["k_pages"].dtype), mode="drop")
    v = cache["v_pages"].at[page, off].set(
        v_new.astype(cache["v_pages"].dtype), mode="drop")
    return {"k_pages": k, "v_pages": v}


def gather_paged_kv(cache, block_table):
    """Gather each row's pages into a contiguous (B, n*ps, Hkv, hd) K/V
    view, dequantizing quantized pools (int8/fp8 + scales -> float32).
    The XLA fallback for the paged Pallas kernel's block-table reads."""
    P = cache["k_pages"].shape[0]
    bt = jnp.clip(block_table, 0, P - 1)
    B = bt.shape[0]
    k = cache["k_pages"][bt].reshape(B, -1, *cache["k_pages"].shape[2:])
    v = cache["v_pages"][bt].reshape(B, -1, *cache["v_pages"].shape[2:])
    if "k_scale" in cache:
        Hkv = cache["k_scale"].shape[-1]
        k = kv_dequantize(k, cache["k_scale"][bt].reshape(B, -1, Hkv))
        v = kv_dequantize(v, cache["v_scale"][bt].reshape(B, -1, Hkv))
    return k, v


def attn_decode_paged(params, cfg: ModelConfig, x, cache, pos, block_table,
                      *, impl: str = "xla"):
    """One-token attention against a paged cache.

    cache: {"k_pages", "v_pages"} pool from ``make_paged_kv_cache``;
    block_table: (B, n_pages) int32. Windowed attention is not paged
    (its dense ring is already bounded by the window).

    The XLA path gathers the row's pages into a contiguous
    (B, n_pages*ps, Hkv, hd) view and runs the exact same ``sdpa`` with
    the exact same validity mask as the dense ring path (for
    pos < cache_len the ring mask reduces to ``slot <= pos``), so its
    outputs are bit-identical to ``attn_decode`` on a dense cache — the
    property the serving regression tests pin down.
    """
    B = x.shape[0]
    positions = pos[:, None].astype(jnp.int32)              # (B,1)
    q, k_new, v_new = _project_qkv(params, cfg, x, positions)
    cache = paged_cache_write(cache, k_new, v_new, pos, block_table)
    lengths = pos + 1
    if impl == "pallas":
        from repro.kernels import ops
        out = ops.paged_decode_attention(q, cache["k_pages"],
                                         cache["v_pages"], block_table,
                                         lengths,
                                         k_scale=cache.get("k_scale"),
                                         v_scale=cache.get("v_scale"))
    else:
        k, v = gather_paged_kv(cache, block_table)
        kv_mask = jnp.arange(k.shape[1])[None, :] < lengths[:, None]
        out = sdpa(q, k, v, causal=False, kv_mask=kv_mask)
    return dense(params["wo"], out.reshape(B, 1, -1)), cache


def attn_decode(params, cfg: ModelConfig, x, cache, pos, *, window: int = 0,
                impl: str = "xla", cross_kv=None, block_table=None):
    """One-token attention against the cache.

    x: (B, 1, d); pos: (B,) int32 — per-row absolute position of the new
    token (rows may be at different depths under continuous batching).
    Returns (out (B,1,d), new_cache). A cache holding "k_pages" routes
    to the paged path (``block_table`` required).
    """
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    if cross_kv is not None:
        q = dense(params["wq"], x).reshape(B, 1, cfg.num_heads, hd)
        k, v = cross_kv
        out = sdpa(q, k, v, causal=False)
        return dense(params["wo"], out.reshape(B, 1, -1)), cache

    if "k_pages" in cache:
        assert window == 0, "windowed attention layers are not paged"
        assert block_table is not None, "paged cache needs a block table"
        return attn_decode_paged(params, cfg, x, cache, pos, block_table,
                                 impl=impl)

    positions = pos[:, None].astype(jnp.int32)          # (B,1)
    q, k_new, v_new = _project_qkv(params, cfg, x, positions)
    cache = cache_write(cache, k_new, v_new, pos)
    S = cache["k"].shape[1]
    slot = jnp.arange(S)
    # slot i holds absolute position p with p ≡ i (mod S), p <= pos,
    # p > pos - S (ring buffer semantics).
    slot_pos = pos[:, None] - jnp.mod(pos[:, None] - slot[None, :], S)  # (B,S)
    valid = slot_pos >= 0
    if window > 0:
        valid &= slot_pos > pos[:, None] - window
    kv_mask = valid
    if impl == "pallas":
        from repro.kernels import ops
        out = ops.decode_attention(q, cache["k"], cache["v"], kv_mask)
    else:
        out = sdpa(q, cache["k"], cache["v"], causal=False, kv_mask=kv_mask)
    return dense(params["wo"], out.reshape(B, 1, -1)), cache


def attn_decode_block(params, cfg: ModelConfig, x, cache, pos, *,
                      impl: str = "xla", block_table=None, valid=None):
    """Score a short block of S tokens against the cache (speculative
    verification).

    x: (B, S, d) — block token i sits at absolute position ``pos + i``
    (pos: (B,) int32, per-row). ``valid``: optional (B, S) — invalid
    positions' KV writes are dropped entirely and their outputs are
    garbage the caller must ignore (drafted positions past a slot's
    token limit). Returns (out (B, S, d), new_cache); ``cache["pos"]``
    bookkeeping is the caller's job (the engine commits only the
    accepted prefix).

    Full attention only (window == 0): per-query masks reproduce the
    single-token decode masks exactly — query i sees absolute positions
    <= pos + i — so on-path logits are bit-comparable to S sequential
    ``attn_decode`` calls. Always runs the XLA ``sdpa``: like the
    prefix-cache suffix prefill, the flash kernels are single-query and
    verification numerics are impl-independent.
    """
    B, S, _ = x.shape
    positions = pos[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
    q, k_new, v_new = _project_qkv(params, cfg, x, positions)
    if valid is None:
        valid = jnp.ones((B, S), bool)
    if "k_pages" in cache:
        assert block_table is not None, "paged cache needs a block table"
        cache = paged_cache_write_block(cache, k_new, v_new, pos,
                                        block_table, valid=valid)
        k, v = gather_paged_kv(cache, block_table)
        kv_mask = jnp.arange(k.shape[1])[None, None, :] < \
            (positions + 1)[:, :, None]                    # (B, S, Lk)
        out = sdpa(q, k, v, causal=False, kv_mask=kv_mask)
    else:
        cache = cache_write_block(cache, k_new, v_new, pos, valid=valid)
        Sc = cache["k"].shape[1]
        slot = jnp.arange(Sc)
        # same ring semantics as attn_decode, per query position
        slot_pos = positions[:, :, None] - jnp.mod(
            positions[:, :, None] - slot[None, None, :], Sc)   # (B, S, Sc)
        kv_mask = slot_pos >= 0
        out = sdpa(q, cache["k"], cache["v"], causal=False, kv_mask=kv_mask)
    return dense(params["wo"], out.reshape(B, S, -1)), cache


def prefill_into_cache(cache, k, v, lengths: Optional[int] = None):
    """Seed a cache with prefill K/V. Assumes prefill length <= cache len.

    k/v: (B, L, Hkv, hd). If L == cache length this is a copy; if shorter,
    writes at the front (positions 0..L-1 — consistent with ring indexing
    as long as pos < S).
    """
    S = cache["k"].shape[1]
    L = k.shape[1]
    if L == S:
        return {"k": k, "v": v}
    if L > S:  # windowed cache shorter than the prefill: keep the tail,
        # placed at its ring positions.
        tail_k, tail_v = k[:, L - S:], v[:, L - S:]
        roll = jnp.mod(jnp.arange(S) - (L - S), S)
        inv = jnp.argsort(roll)
        del inv
        # position p lives at slot p % S: build by scatter of tail positions
        pos = jnp.arange(L - S, L)
        slots = jnp.mod(pos, S)
        new_k = cache["k"].at[:, slots].set(tail_k)
        new_v = cache["v"].at[:, slots].set(tail_v)
        return {"k": new_k, "v": new_v}
    new_k = jax.lax.dynamic_update_slice(cache["k"], k, (0, 0, 0, 0))
    new_v = jax.lax.dynamic_update_slice(cache["v"], v, (0, 0, 0, 0))
    return {"k": new_k, "v": new_v}
