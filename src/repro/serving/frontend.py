"""Async streaming front-end for ``ServeEngine``.

Request stream in, token stream out: ``submit`` enqueues a request into
the engine between macro-step launches, ``stream`` yields its tokens as
the engine emits them, ``cancel`` aborts it mid-stream (pages returned,
slot freed, scheduler commitment refunded — see
``ServeEngine.cancel``), and ``result`` resolves to the request's final
``Result``.

Design: one cooperative asyncio task (``_pump_loop``) owns the engine.
Each iteration runs exactly one ``ServeEngine.pump()`` — one macro
launch plus its host-side fold — then drains the engine's stream-event
and completion feeds into per-request ``asyncio.Queue``s and yields the
event loop, so client coroutines (arrival timers, stream consumers,
cancellers) run *between* launches. jax dispatch stays single-threaded
(the donated-buffer decode state is not thread-safe), which also makes
cancellation race-free by construction: a ``cancel`` always lands at a
step boundary, exactly where the engine applies it.

Token streams are **incremental** (per-launch deltas, riding the launch
sync — zero extra host syncs) when the engine decodes a single greedy
candidate per request; multi-candidate modes (camd/best_of_n/self_
consistency) choose their answer only at completion, so their streams
deliver the chosen candidate's tokens when the request finishes. In
both cases the stream's concatenation is byte-identical to the
synchronous ``run()`` result (pinned by ``tests/test_async_frontend``).

TTFT under load: with chunked prefill on (``ServeEngine(prefill_
chunk=...)``) the pump loop interleaves at most one chunk budget of
prefill work per launch, so a long prompt no longer monopolizes the
engine between macro steps — short requests' first tokens (and the
long request's own TTFT, which starts at its *final* chunk rather
than a monolithic whole-prompt prefill) stop queueing behind
whole-prompt prefills.
"""
from __future__ import annotations

import asyncio
from typing import Dict, Optional, Set

import numpy as np

from repro.serving.engine import Request, Result, ServeEngine

_DONE = object()          # stream-termination sentinel


class AsyncServeFrontend:
    """Asyncio front-end over one ``ServeEngine`` (macro-step loop).

    Usage::

        async with AsyncServeFrontend(engine) as fe:
            await fe.submit(Request(uid=0, prompt=...))
            async for tok in fe.stream(0):
                ...
            res = await fe.result(0)
    """

    def __init__(self, engine: ServeEngine, *, stream_tokens: bool = True):
        if engine.macro_steps <= 0:
            raise ValueError(
                "AsyncServeFrontend drives the fused macro-step loop; "
                "construct the engine with macro_steps >= 1")
        self.engine = engine
        # incremental per-launch deltas only make sense when the single
        # candidate IS the answer; other modes pick at completion
        self._incremental = bool(stream_tokens) \
            and engine.mode == "greedy" and engine.n_candidates == 1
        engine.stream_tokens = self._incremental
        self._queues: Dict[int, asyncio.Queue] = {}
        self._futs: Dict[int, asyncio.Future] = {}
        self._closed: Set[int] = set()
        self._wake: Optional[asyncio.Event] = None
        self._task: Optional[asyncio.Task] = None
        self._error: Optional[BaseException] = None

    # -- lifecycle ------------------------------------------------------
    async def start(self) -> "AsyncServeFrontend":
        if self._task is None:
            self._wake = asyncio.Event()
            self._task = asyncio.create_task(self._pump_loop())
        return self

    async def close(self) -> None:
        task, self._task = self._task, None
        if task is not None:
            task.cancel()
            try:
                await task
            except asyncio.CancelledError:
                pass
        # leave the engine reusable for synchronous run(): nothing left
        # to drain the stream feed once the front-end is gone
        self.engine.stream_tokens = False
        self.engine.stream_events.clear()

    async def __aenter__(self) -> "AsyncServeFrontend":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.close()

    # -- client API -----------------------------------------------------
    async def submit(self, req: Request) -> int:
        """Enqueue a request; admission happens at the next pump."""
        self._require_ok()
        self.engine.submit(req)
        self._queues[req.uid] = asyncio.Queue()
        self._futs[req.uid] = asyncio.get_running_loop().create_future()
        self._wake.set()
        return req.uid

    async def stream(self, uid: int):
        """Async iterator of the request's output tokens (ints). Ends
        when the request completes or is cancelled; already-emitted
        tokens are always delivered."""
        q = self._queues[uid]
        while True:
            tok = await q.get()
            if tok is _DONE:
                return
            yield tok

    async def result(self, uid: int) -> Result:
        """The request's final ``Result`` (``cancelled=True`` if it was
        aborted)."""
        return await self._futs[uid]

    async def cancel(self, uid: int) -> bool:
        """Abort ``uid``: closes its stream immediately (queued tokens
        still deliverable) and tears its engine state down at the next
        step boundary — frontier pages returned, slot freed, scheduler
        commitment refunded."""
        ok = self.engine.cancel(uid)
        self._close_stream(uid)
        if self._wake is not None:
            self._wake.set()       # deferred teardown needs a pump
        return ok

    async def join(self) -> None:
        """Wait until every submitted request has a result."""
        if self._futs:
            await asyncio.gather(*self._futs.values())

    # -- pump -----------------------------------------------------------
    async def _pump_loop(self) -> None:
        try:
            while True:
                if self.engine.has_work():
                    self.engine.pump()
                    self._dispatch()
                    # one event-loop turn between launches: arrivals,
                    # stream consumers and cancels run here
                    await asyncio.sleep(0)
                else:
                    self._dispatch()   # flush direct-cancel completions
                    self._wake.clear()
                    if self.engine.has_work():
                        continue       # raced with a submit
                    await self._wake.wait()
        except asyncio.CancelledError:
            raise
        except BaseException as e:     # surface on every waiter
            self._error = e
            self._fail_all(e)

    def _dispatch(self) -> None:
        eng = self.engine
        for uid, _cand, toks in eng.drain_stream_events():
            q = self._queues.get(uid)
            if q is None or uid in self._closed:
                continue
            for t in np.asarray(toks).tolist():
                q.put_nowait(int(t))
        for uid in eng.pop_finished():
            fut = self._futs.get(uid)
            if fut is None:
                continue               # finished outside this front-end
            res = eng.result(uid)
            if not fut.done():
                fut.set_result(res)
            q = self._queues.get(uid)
            if q is not None and uid not in self._closed \
                    and not self._incremental and not res.cancelled:
                for t in np.asarray(res.tokens).tolist():
                    q.put_nowait(int(t))
            self._close_stream(uid)

    # -- internals ------------------------------------------------------
    def _close_stream(self, uid: int) -> None:
        if uid in self._closed:
            return
        self._closed.add(uid)
        q = self._queues.get(uid)
        if q is not None:
            q.put_nowait(_DONE)

    def _fail_all(self, e: BaseException) -> None:
        for fut in self._futs.values():
            if not fut.done():
                fut.set_exception(e)
        for uid in list(self._queues):
            self._close_stream(uid)

    def _require_ok(self) -> None:
        if self._error is not None:
            raise RuntimeError("serving pump failed") from self._error
        if self._task is None:
            raise RuntimeError("front-end not started "
                               "(use 'async with' or await start())")
