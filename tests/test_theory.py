"""Property tests of the paper's theoretical framework (§4.1, Theorem 4.2).

These are the *validation of the paper's own claims*: coverage
monotonicity, the δ-coverage bound of Def. 4.1, and the three tail-class
decay rates of Theorem 4.2, checked numerically at scale.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need hypothesis;
# a bare interpreter must still collect the suite (module-level skip)
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import theory


def test_coverage_monotone_and_complement():
    key = jax.random.PRNGKey(0)
    s = theory.sample_heavy_tail(key, 20000, alpha=0.5)
    Ks = jnp.array([1, 2, 4, 8, 16, 32, 64])
    cov = theory.coverage(Ks, s)
    res = theory.residual_risk(Ks, s)
    np.testing.assert_allclose(np.asarray(cov + res), 1.0, rtol=1e-6)
    assert bool(jnp.all(jnp.diff(cov) > 0)), "coverage must increase with K"


@settings(max_examples=30, deadline=None)
@given(s=st.floats(0.01, 0.95), delta=st.floats(0.001, 0.2))
def test_n_delta_guarantee(s, delta):
    """Def. 4.1: N_δ trials give >= 1-δ coverage; N_δ - 1 do not."""
    n = float(theory.n_delta(jnp.asarray(s), delta))
    assert 1.0 - (1.0 - s) ** n >= 1.0 - delta - 1e-9
    if n > 1:
        assert 1.0 - (1.0 - s) ** (n - 1) < 1.0 - delta + 1e-9


def test_theorem_42_heavy_tail_power_law():
    """Heavy tail g(s)~αs^(α-1): Δ(K) ~ κΓ(α)K^(-α) — fitted exponent
    must recover α."""
    for alpha in (0.4, 0.7):
        s = theory.sample_heavy_tail(jax.random.PRNGKey(1), 400000, alpha)
        Ks = np.array([4, 8, 16, 32, 64, 128, 256])
        deltas = np.asarray(theory.residual_risk(jnp.asarray(Ks), s))
        fitted, _ = theory.fit_power_law(Ks, deltas)
        assert abs(fitted - alpha) < 0.12, (alpha, fitted)
        # the predicted constant matches too: for g(s) = α s^(α-1) the
        # Theorem 4.2 prefactor is κ = α (exact: Δ(K) = αB(α, K+1)).
        pred = np.asarray(theory.heavy_tail_rate(Ks, alpha, kappa=alpha))
        ratio = deltas / pred
        assert 0.8 < np.median(ratio) < 1.25


def test_theorem_42_light_tail_exponential():
    """Truncated tail: Δ(K) <= C' e^(-c'K) — log-residual is linear in K
    and the power-law fit is clearly worse than the exponential one."""
    s = theory.sample_light_tail(jax.random.PRNGKey(2), 200000, lo=0.2)
    Ks = np.array([1, 2, 4, 8, 16, 24, 32])
    deltas = np.asarray(theory.residual_risk(jnp.asarray(Ks), s))
    c, b = theory.fit_exponential(Ks, deltas)
    assert c > 0.15, "light tail must decay exponentially"
    pred = np.exp(b - c * Ks)
    rel = np.abs(np.log(pred) - np.log(deltas))
    assert rel.max() < 0.7


def test_theorem_42_ordering():
    """At equal K, residual risk: heavy > stretched > light (tail mass)."""
    n = 200000
    keys = jax.random.split(jax.random.PRNGKey(3), 3)
    sh = theory.sample_heavy_tail(keys[0], n, 0.5)
    se = theory.sample_stretched_exp(keys[1], n)
    sl = theory.sample_light_tail(keys[2], n)
    K = jnp.asarray([64])
    dh = float(theory.residual_risk(K, sh)[0])
    de = float(theory.residual_risk(K, se)[0])
    dl = float(theory.residual_risk(K, sl)[0])
    assert dh > de > dl


def test_k_star_scaling():
    """Eq. 6: heavy-tail budgets blow up polynomially in 1/ε, light tails
    logarithmically."""
    heavy = [theory.k_star(e, 0.0, "heavy", alpha=0.5) for e in (0.1, 0.01)]
    light = [theory.k_star(e, 0.0, "light") for e in (0.1, 0.01)]
    assert heavy[1] / heavy[0] > 50      # (1/ε)^2 ⇒ 100×
    assert light[1] / light[0] < 3       # log ⇒ 2×
    assert theory.k_star(0.05, 0.1, "heavy") == float("inf")  # ε < R_irr
