"""Host-side KV page-pool allocator for the paged serving path.

The device side holds a single shared pool of KV pages per attention
layer (``models.attention.make_paged_kv_cache``); this class owns the
*ids*: which pages are free, and how many holders reference each live
page. Reference counting is what makes candidate prefill cheap — a
request's R candidates `share()` the prompt's full pages and only copy
the partially-filled tail page (copy-on-write at the first diverging
token), so prompt KV is resident once per request, not once per
candidate.

Page 0 is reserved as the quarantine page: idle slots' block tables
point at it and their dead writes land there. It is never allocated and
never freed.

All methods raise on misuse (double free, free of an unallocated page,
over-allocation) rather than corrupting the table — the serving tests
lean on these invariants.
"""
from __future__ import annotations

from typing import Iterable, List

import numpy as np


class PagePoolError(RuntimeError):
    pass


class PagePool:
    def __init__(self, num_pages: int, page_size: int, *, reserved: int = 1):
        if num_pages <= reserved:
            raise PagePoolError(f"pool of {num_pages} pages has no "
                                f"allocatable pages (reserved={reserved})")
        self.num_pages = num_pages
        self.page_size = page_size
        self.reserved = reserved
        # LIFO free list: recently freed pages are re-used first (their
        # contents are hot in cache and get overwritten anyway).
        self._free: List[int] = list(range(num_pages - 1, reserved - 1, -1))
        self._refs = np.zeros(num_pages, np.int64)
        self.max_in_use = 0
        # frontier accounting (macro-step serving): pages handed out ahead
        # of the device loop and how many came back unconsumed.
        self.frontier_staged = 0
        self.frontier_returned = 0

    # ------------------------------------------------------------------
    @property
    def in_use(self) -> int:
        """Pages currently referenced by at least one holder."""
        return int(np.count_nonzero(self._refs))

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def refcount(self, page: int) -> int:
        return int(self._refs[page])

    def live_tokens_capacity(self) -> int:
        return self.in_use * self.page_size

    # ------------------------------------------------------------------
    def alloc(self, n: int = 1) -> List[int]:
        """Take ``n`` fresh pages (refcount 1 each)."""
        if n < 0:
            raise PagePoolError(f"alloc({n})")
        if n > len(self._free):
            raise PagePoolError(
                f"out of KV pages: need {n}, have {len(self._free)} free of "
                f"{self.num_pages} (in use: {self.in_use}) — raise num_pages "
                f"or reduce slots/cache_len")
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._refs[p] = 1
        self.max_in_use = max(self.max_in_use, self.in_use)
        return pages

    def share(self, pages: Iterable[int]):
        """Add one holder to each page (prompt pages shared by a new
        candidate)."""
        for p in pages:
            if self._refs[p] <= 0:
                raise PagePoolError(f"share of unallocated page {p}")
            self._refs[p] += 1

    def free(self, pages: Iterable[int]):
        """Drop one holder from each page; pages reaching zero return to
        the free list (this is what lets an early-stopped easy request
        immediately fund a hard one)."""
        for p in pages:
            if p < self.reserved:
                raise PagePoolError(f"free of reserved page {p}")
            if self._refs[p] <= 0:
                raise PagePoolError(f"double free of page {p}")
            self._refs[p] -= 1
            if self._refs[p] == 0:
                self._free.append(p)

    # ------------------------------------------------------------------
    # Page frontiers (macro-step decode)
    # ------------------------------------------------------------------
    def stage_frontier(self, n: int) -> List[int]:
        """Reserve ``n`` pages for a slot's decode *frontier*: the pages
        the device-resident macro-step loop may advance into without host
        intervention. Staged pages are ordinary allocations (refcount 1) —
        the caller writes their ids into the (B, F) frontier array before
        launch and, after the macro-step returns, keeps the consumed
        prefix and hands the rest back via ``return_frontier``."""
        pages = self.alloc(n)
        self.frontier_staged += n
        return pages

    def return_frontier(self, pages: Iterable[int]):
        """Return staged-but-unconsumed frontier pages (slot finished or
        the macro-step early-exited before crossing into them)."""
        pages = list(pages)
        self.free(pages)
        self.frontier_returned += len(pages)

    # ------------------------------------------------------------------
    def check(self):
        """Conservation invariant: every non-reserved page is either on
        the free list (ref 0) or held (ref > 0), never both/neither."""
        free = set(self._free)
        if len(free) != len(self._free):
            raise PagePoolError("free list contains duplicates")
        for p in range(self.reserved, self.num_pages):
            held = self._refs[p] > 0
            if held == (p in free):
                raise PagePoolError(
                    f"page {p} violates conservation (refs={self._refs[p]}, "
                    f"on_free_list={p in free})")
        if any(p < self.reserved for p in free):
            raise PagePoolError("reserved page on the free list")

    def stats(self) -> dict:
        return {
            "num_pages": self.num_pages,
            "page_size": self.page_size,
            "in_use": self.in_use,
            "free": self.free_pages,
            "max_in_use": self.max_in_use,
            "frontier_staged": self.frontier_staged,
            "frontier_returned": self.frontier_returned,
        }
