"""Pallas TPU kernels for the framework's compute hot spots.

flash_attention   prefill attention (online softmax, causal/window)
decode_attention  flash-decode vs long KV caches (GQA-grouped HBM reads)
paged_decode_attention
                  flash-decode through a block table of KV pages
                  (scalar-prefetch indexed; HBM traffic ∝ live tokens)
xmodal_score      fused Eq. 8-9 cross-modal consistency reductions
moe_dispatch      gather-based MoE dispatch/combine — the O(k)/token
                  TPU-native replacement for the O(E*C)/token capacity
                  einsum (EXPERIMENTS.md §Perf backlog item, realized)

``ops`` — jit'd wrappers (TPU kernel / interpret / jnp-ref dispatch);
``ref`` — pure-jnp oracles used by the test sweeps.
"""
from repro.kernels import ops, ref  # noqa: F401
