"""Data pipeline + oracle task tests."""
import numpy as np

from repro.data import ChainTask, SimulatedDecoder, evidence_batch, lm_batches
from repro.data.synthetic import OFF, QRY


def test_lm_batches_shapes_and_vocab():
    it = lm_batches(512, 4, 32, seed=0)
    b = next(it)
    assert b["tokens"].shape == (4, 32)
    assert b["labels"].shape == (4, 32)
    assert b["tokens"].max() < 512 and b["tokens"].min() >= 0
    # labels are next-token shifted
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_evidence_batch_unit_norm():
    rng = np.random.default_rng(0)
    ev = evidence_batch(rng, 3, 8, 16)
    np.testing.assert_allclose(np.linalg.norm(ev, axis=-1), 1.0, rtol=1e-4)


def test_chain_task_oracle():
    task = ChainTask(base=16)
    rng = np.random.default_rng(1)
    for _ in range(50):
        prompt, ans, k = task.sample(rng)
        assert prompt[-1] == QRY
        assert task.check(prompt, np.asarray([ans]))
        assert not task.check(prompt, np.asarray([ans + 1]))


def test_simulated_decoder_statistics():
    """Per-trial correctness rate must track the drawn difficulty s."""
    sim = SimulatedDecoder(tail="heavy", seed=0)
    for s in (0.1, 0.5, 0.9):
        out = sim.trial(s, k=5000)
        assert abs(out["correct"].mean() - s) < 0.03
    # embeddings of equal answers are close; of different answers, far
    out = sim.trial(0.5, k=200)
    same = out["answer"][:, None] == out["answer"][None, :]
    emb = out["emb"] / np.linalg.norm(out["emb"], axis=-1, keepdims=True)
    sims = emb @ emb.T
    assert sims[same].mean() > 0.95
    assert sims[~same].mean() < 0.5
    # scores separate correct from wrong on average
    assert (out["score"][out["correct"]].mean()
            > out["score"][~out["correct"]].mean())


def test_simulated_difficulty_tails():
    sim_h = SimulatedDecoder(tail="heavy", alpha=0.5, seed=1)
    sim_l = SimulatedDecoder(tail="light", seed=1)
    sh = sim_h.sample_difficulty(100000)
    sl = sim_l.sample_difficulty(100000)
    # heavy tail has much more mass at tiny s
    assert (sh < 0.01).mean() > 0.05
    assert (sl < 0.01).mean() == 0.0
