"""HLO-text analysis: collective-byte accounting for the roofline.

``cost_analysis()`` has no collective term, so we parse the compiled
module text and sum the result-shape bytes of every collective op
(all-gather totals count post-gather bytes; this upper-bounds link bytes
by the ring-transfer total, which is the standard roofline convention).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "collective-broadcast")

# e.g.:  %all-gather.3 = bf16[16,1024]{1,0} all-gather(%param.1), ...
_LINE = re.compile(
    r"=\s+(?:\()?([a-z0-9]+)\[([0-9,]*)\][^\s]*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute|"
    r"collective-broadcast)(?:-start|-done)?\(")
# tuple-result collectives:  %x = (bf16[..], bf16[..]) all-to-all(...)
_TUPLE_LINE = re.compile(
    r"=\s+\(([^)]*)\)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute|"
    r"collective-broadcast)(?:-start|-done)?\(")
_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Returns {collective_op: total_result_bytes} + {"total": sum} and
    per-op counts under "count:<op>"."""
    out: Dict[str, int] = defaultdict(int)
    seen_ids = set()
    for line in hlo_text.splitlines():
        if not any(c in line for c in COLLECTIVES):
            continue
        if "-done(" in line:      # async pairs: count the start only
            continue
        m = _LINE.search(line)
        if m:
            dtype, dims, op = m.groups()
            out[op] += _shape_bytes(dtype, dims)
            out[f"count:{op}"] += 1
            continue
        m = _TUPLE_LINE.search(line)
        if m:
            shapes, op = m.groups()
            for dm in _SHAPE.finditer(shapes):
                out[op] += _shape_bytes(*dm.groups())
            out[f"count:{op}"] += 1
    out["total"] = sum(v for k, v in out.items() if not k.startswith("count:"))
    return dict(out)


def op_histogram(hlo_text: str, ops=("fusion", "dot", "convolution",
                                     "scatter", "gather", "reshape",
                                     "transpose", "copy")) -> Dict[str, int]:
    """Rough count of selected op kinds (remat/redundancy smoke signal)."""
    hist: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = re.search(r"=\s+(?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^\s]*)\s+([a-z-]+)\(",
                      line)
        if m and m.group(1) in ops:
            hist[m.group(1)] += 1
    return dict(hist)
