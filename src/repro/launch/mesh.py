"""Production mesh definitions (TPU v5e target).

Defined as FUNCTIONS so importing this module never touches jax device
state — the dry-run must set XLA_FLAGS before the first jax call.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5 makes mesh axis types explicit; 0.4.x is Auto-only.
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - version-dependent
    AxisType = None


def _make_mesh(shape, axes):
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = 256 chips, ("data", "model").
    Multi-pod:  (2, 16, 16) = 512 chips, ("pod", "data", "model")."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_local_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU-device tests (requires forced host devices)."""
    return _make_mesh(shape, axes)


def make_serve_mesh(dp: int = 0, *, model: int = 1):
    """Serving mesh: ``dp`` data shards x ``model`` tensor-parallel
    ranks over the first ``dp * model`` local devices (a mesh need not
    cover every device — the CI lane forces 8 host devices and shards
    4-wide). ``dp=0`` takes every device not claimed by ``model``.

    Prefill/decode disaggregation (``ServeEngine(prefill_shards=k)``)
    is a *logical* split of this mesh's data axis: prompt/chunk pages
    land on the first ``k`` shards' page subpools, decode slots on all
    shards read them cross-shard (see
    ``distributed.sharding.prefill_shard_ids``) — no separate mesh.

    On CPU, multi-device serving needs forced host devices, e.g.::

        XLA_FLAGS=--xla_force_host_platform_device_count=8
    """
    import numpy as np
    from jax.sharding import Mesh

    devs = jax.devices()
    if dp <= 0:
        dp = max(1, len(devs) // model)
    n = dp * model
    if n > len(devs):
        raise ValueError(
            f"serve mesh wants {dp}x{model}={n} devices, have "
            f"{len(devs)} (on CPU set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n})")
    return Mesh(np.asarray(devs[:n]).reshape(dp, model), ("data", "model"))
