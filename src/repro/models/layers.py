"""Shared building blocks: norms, RoPE, MLPs, embeddings.

Parameters are plain pytrees (nested dicts of jnp arrays). Every init
function takes an explicit PRNG key and dtype; every apply function is a
pure function of (params, inputs).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rmsnorm(params, x, eps: float = 1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def rmsnorm_headwise(scale, x, eps: float = 1e-6):
    """qk-norm: normalize the last (head) dim with a shared scale."""
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., L, H, hd); positions: broadcastable to (..., L)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                    # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., L, hd/2)
    cos = jnp.cos(angles)[..., None, :]                    # (..., L, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Dense / MLP
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32, bias: bool = False,
               scale: Optional[float] = None):
    if scale is None:
        scale = d_in ** -0.5
    p = {"kernel": (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)}
    if bias:
        p["bias"] = jnp.zeros((d_out,), dtype=dtype)
    return p


def dense(params, x):
    y = x @ params["kernel"]
    if "bias" in params:
        y = y + params["bias"]
    return y


def _activation(name: str):
    return {"swiglu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


def mlp_init(key, d_model: int, d_ff: int, activation: str, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    if activation == "swiglu":
        return {
            "w_gate": dense_init(k1, d_model, d_ff, dtype),
            "w_up": dense_init(k2, d_model, d_ff, dtype),
            "w_down": dense_init(k3, d_ff, d_model, dtype),
        }
    return {
        "w_in": dense_init(k1, d_model, d_ff, dtype),
        "w_out": dense_init(k2, d_ff, d_model, dtype),
    }


def mlp(params, x, activation: str):
    act = _activation(activation)
    if "w_gate" in params:
        return dense(params["w_down"], act(dense(params["w_gate"], x)) * dense(params["w_up"], x))
    return dense(params["w_out"], act(dense(params["w_in"], x)))


# ---------------------------------------------------------------------------
# Embedding
# ---------------------------------------------------------------------------

def embed_init(key, vocab: int, d: int, dtype=jnp.float32):
    return {"table": (jax.random.normal(key, (vocab, d)) * d ** -0.5).astype(dtype)}


def embed(params, tokens):
    return jnp.take(params["table"], tokens, axis=0)


def unembed(params, x, tied_table=None):
    table = tied_table if tied_table is not None else params["kernel"]
    if tied_table is not None:
        return x @ table.T
    return x @ table
