"""qwen2.5-32b — Qwen2.5 32B dense.

[hf:Qwen/Qwen2.5-0.5B family card]: 64L, d_model=5120, 40 q heads, GQA kv=8,
d_ff=27648, vocab 152064, QKV bias.
"""
from repro.config import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=27648,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1e6,
    block_pattern=(ATTN,),
    mlp_activation="swiglu",
    source="hf:Qwen/Qwen2.5-0.5B",
)
