"""Recurrent / hybrid family serving: state-kind dispatch, the
fixed-stride state arena, and byte-identity of engine streams against
the per-request legacy loop.

The differential discipline mirrors the attention family's paged-vs-
dense suite: for each recurrent architecture, the slot-scheduled
macro-step engine must produce byte-identical token streams to a
1-slot legacy (macro_steps=0) engine — the per-request fallback path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serving import Request, ServeEngine
from repro.serving.state_arena import StateArena  # noqa: F401

ARCHS = ["mamba2_780m", "recurrentgemma_2b"]


@pytest.fixture(scope="session", params=ARCHS)
def recurrent_model(request):
    cfg = get_config(request.param).reduced().with_overrides(dtype="float32")
    model = build_model(cfg, jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _prompts(cfg, n=5, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(2, cfg.vocab_size,
                         size=int(rng.integers(4, 12))).astype(np.int32)
            for _ in range(n)]


def _engine(model, params, **kw):
    defaults = dict(slots=4, cache_len=64, mode="greedy",
                    max_new_tokens=8, impl="xla", macro_steps=4, seed=0)
    defaults.update(kw)
    return ServeEngine(model, params, **defaults)


def test_state_kind_dispatch():
    kinds = {
        "mamba2_780m": "recurrent",
        "recurrentgemma_2b": "hybrid",
        "qwen3_0_6b": "kv",
        "llava_1_5_7b": "kv",
    }
    for arch, want in kinds.items():
        cfg = get_config(arch).reduced()
        model = build_model(cfg, jnp.float32)
        caps = model.capabilities()
        assert model.state_kind == want, arch
        assert caps["state_kind"] == want
        if want != "kv":
            assert not caps["has_pageable_layers"]
            assert not caps["supports_prefix_cache"]


def test_paged_impl_rejected(recurrent_model):
    cfg, model, params = recurrent_model
    with pytest.raises(ValueError, match="pageable"):
        ServeEngine(model, params, slots=2, cache_len=64, impl="paged",
                    macro_steps=2)


def test_engine_owns_arena(recurrent_model):
    cfg, model, params = recurrent_model
    eng = _engine(model, params)
    assert eng.arena is not None and eng._arena_buf is not None
    assert eng.state_kind in ("recurrent", "hybrid")
    s = eng.arena_stats()
    assert s["state_kind"] == eng.state_kind
    assert s["bytes_per_row"] > 0
    # kv engines own no arena
    kcfg = get_config("qwen3_0_6b").reduced()
    kmodel = build_model(kcfg, jnp.float32)
    keng = ServeEngine(kmodel, kmodel.init(jax.random.PRNGKey(0)),
                       slots=2, cache_len=64, macro_steps=2)
    assert keng.arena is None and keng.arena_stats() == {}


def test_stream_identical_to_legacy_fallback(recurrent_model):
    """Slot-scheduled macro-step serving over the arena must stream
    byte-identically to the 1-slot per-request legacy loop."""
    cfg, model, params = recurrent_model
    prompts = _prompts(cfg)

    def run(slots, macro):
        eng = _engine(model, params, slots=slots, macro_steps=macro)
        for i, p in enumerate(prompts):
            eng.submit(Request(uid=i, prompt=p))
        out = {r.uid: r.tokens for r in eng.run()}
        if eng.arena is not None:
            eng.arena.check()
            assert eng.arena.in_use == 0, eng.arena.stats()
        return out

    a = run(4, 4)
    b = run(1, 0)
    for uid in a:
        np.testing.assert_array_equal(a[uid], b[uid])


def test_macro_step_invariance(recurrent_model):
    cfg, model, params = recurrent_model
    prompts = _prompts(cfg, n=4, seed=1)

    def run(macro):
        eng = _engine(model, params, macro_steps=macro)
        for i, p in enumerate(prompts):
            eng.submit(Request(uid=i, prompt=p))
        return {r.uid: r.tokens for r in eng.run()}

    a, b = run(1), run(6)
    for uid in a:
        np.testing.assert_array_equal(a[uid], b[uid])


def test_arena_conservation_under_cancellation(recurrent_model):
    cfg, model, params = recurrent_model
    prompts = _prompts(cfg, n=6, seed=2)
    eng = _engine(model, params, slots=2)
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p))
    eng._begin()
    eng.cancel(1)            # live or pending
    eng.cancel(5)            # queued, not yet prefilled
    while eng._step():
        pass
    results = {r.uid: r for r in (eng._result(u) for u in eng._reqs)}
    assert results[1].cancelled or results[1].tokens.size >= 0
    eng.arena.check()
    assert eng.arena.in_use == 0, eng.arena.stats()
    assert eng.arena.alloc_count == eng.arena.free_count


def test_arena_bounds_prefill_ahead(recurrent_model):
    """Prefill-ahead may never outgrow the arena: rows in use stay
    bounded by the arena size however many requests queue."""
    cfg, model, params = recurrent_model
    eng = _engine(model, params, slots=2)
    prompts = _prompts(cfg, n=12, seed=3)
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p))
    res = eng.run()
    assert len(res) == len(prompts)
    s = eng.arena_stats()
    assert s["max_in_use"] <= eng.arena.num_rows
    assert s["in_use"] == 0
    eng.arena.check()


def test_masked_prefill_matches_per_row(recurrent_model):
    """Batched prefill with ``lengths=`` must match per-row prefill on
    logits and every recurrent-state leaf (allclose — chunk/scan shapes
    differ with padded length, so bit-identity is out of scope and
    ``supports_bucketed_prefill`` stays False). Local-attention KV ring
    slots beyond a short row's ``pos`` are excluded: batched prefill
    writes pads there that decode's validity mask rejects."""
    cfg, model, params = recurrent_model
    assert not model.supports_bucketed_prefill
    rng = np.random.default_rng(4)
    L, B = 12, 3
    lens = np.array([12, 7, 4], np.int32)
    toks = np.zeros((B, L), np.int32)
    for i, n in enumerate(lens):
        toks[i, :n] = rng.integers(2, cfg.vocab_size, n)

    cache_b = model.make_cache(B, 32)
    lg_b, _, cache_b = model.prefill(params, jnp.asarray(toks), cache_b,
                                     lengths=jnp.asarray(lens))
    for i, n in enumerate(lens):
        cache_1 = model.make_cache(1, 32)
        lg_1, _, cache_1 = model.prefill(
            params, jnp.asarray(toks[i:i + 1, :n]), cache_1)
        np.testing.assert_allclose(np.asarray(lg_b[i]), np.asarray(lg_1[0]),
                                   rtol=2e-4, atol=2e-4)

        def pick(tree, leaf_name):
            out = []
            for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
                names = [p.key for p in path
                         if isinstance(p, jax.tree_util.DictKey)]
                if leaf_name in names:
                    out.append((path, leaf))
            return out

        for name in ("ssd", "conv", "h"):
            big = pick(cache_b, name)
            one = pick(cache_1, name)
            assert len(big) == len(one)
            for (pb, lb), (_, l1) in zip(big, one):
                ax = 1 if any(
                    isinstance(p, jax.tree_util.DictKey) and
                    p.key in ("super", "self") for p in pb) else 0
                row = np.take(np.asarray(lb), i, axis=ax)
                ref = np.take(np.asarray(l1), 0, axis=ax)
                np.testing.assert_allclose(row, ref, rtol=2e-4, atol=2e-4,
                                           err_msg=f"{name} row {i}")
        np.testing.assert_array_equal(
            np.asarray(cache_b["pos"])[i], np.asarray(cache_1["pos"])[0])
