"""Quantized paged-KV correctness.

Three layers of guarantee, mirroring ``test_paged_attention.py``'s
shape sweep (page sizes {16, 64, 128} x GQA group sizes):

1. quantize->dequantize roundtrip error is bounded by half the stored
   absmax scale (the int8 grid ULP) — property-tested with hypothesis
   when available, plus a deterministic seed sweep that always runs;
2. int8/fp8 paged decode attention (in-kernel dequant, interpret mode
   AND the jnp ref) matches the fp32 oracle within documented tolerance;
3. quantized cache writes land values AND scales at the block-table
   target, and the block-write equals sequential single writes.

Plus the ``debug_validate`` corruption path: out-of-range live page ids
raise instead of being silently clipped.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import ops, ref
from repro.kernels.paged_decode_attention import (paged_decode_attention,
                                                 validate_block_table)
from repro.models import attention as attn_lib
from repro.models.attention import (FP8_DTYPE, kv_dequantize, kv_quantize,
                                    kv_storage_dtype)

# int8 absmax on unit-normal data: per-element error <= scale/2 where
# scale = amax/127; attention output is a convex combination of v rows
# scaled by ~unit weights, so output error stays well under these.
INT8_TOLS = dict(rtol=6e-2, atol=6e-2)
# fp8-e4m3 has a 3-bit mantissa: relative error <= 2^-4 per element.
FP8_TOLS = dict(rtol=9e-2, atol=9e-2)

QDTYPES = [jnp.int8] + ([FP8_DTYPE] if FP8_DTYPE is not None else [])


def _setup(key, B, H, Hkv, hd, P, ps, n_pages, seed=0):
    q = jax.random.normal(key, (B, 1, H, hd))
    kp = jax.random.normal(jax.random.fold_in(key, 1), (P, ps, Hkv, hd))
    vp = jax.random.normal(jax.random.fold_in(key, 2), (P, ps, Hkv, hd))
    rng = np.random.default_rng(seed)
    perm = rng.permutation(P - 1) + 1          # page 0 = quarantine
    assert B * n_pages <= P - 1
    bt = jnp.asarray(perm[:B * n_pages].reshape(B, n_pages), jnp.int32)
    return q, kp, vp, bt


# ---------------------------------------------------------------------------
# 1. roundtrip bound
# ---------------------------------------------------------------------------

def _roundtrip_bound(x, qdtype):
    """|dequant(quant(x)) - x| <= half the quantization step, elementwise.

    int8: the grid step is exactly ``scale`` (absmax/127), so round-to-
    nearest lands within scale/2 (+ float slack). fp8-e4m3: the step at
    magnitude |y| is |y| * 2^-3, so the bound is |x|/16 + scale slack
    for the subnormal tail.
    """
    q, scale = kv_quantize(x, qdtype)
    err = jnp.abs(kv_dequantize(q, scale) - x.astype(jnp.float32))
    s = scale[..., None]
    if jnp.dtype(qdtype) == jnp.dtype(jnp.int8):
        bound = 0.5 * s * (1 + 1e-5) + 1e-12
    else:
        bound = jnp.abs(x.astype(jnp.float32)) / 16.0 + s * 2e-2
    assert bool(jnp.all(err <= bound)), \
        f"max excess {float(jnp.max(err - bound)):.3e}"


@pytest.mark.parametrize("qdtype", QDTYPES, ids=lambda d: jnp.dtype(d).name)
@pytest.mark.parametrize("seed", range(8))
def test_roundtrip_bounded_by_scale(qdtype, seed):
    key = jax.random.PRNGKey(seed)
    scale_pow = jax.random.uniform(jax.random.fold_in(key, 1),
                                   (4, 8, 2, 1), minval=-6.0, maxval=6.0)
    x = jax.random.normal(key, (4, 8, 2, 16)) * 10.0 ** scale_pow
    _roundtrip_bound(x, qdtype)


def test_roundtrip_edge_cases():
    """Zeros, single-element spikes, and denormal-scale rows all stay
    in-bound (the scale floor keeps 0-rows exactly 0)."""
    x = jnp.zeros((2, 4, 1, 8))
    q, scale = kv_quantize(x, jnp.int8)
    assert bool(jnp.all(kv_dequantize(q, scale) == 0.0))
    spike = jnp.zeros((1, 1, 1, 8)).at[0, 0, 0, 3].set(1e4)
    _roundtrip_bound(spike, jnp.int8)
    _roundtrip_bound(jnp.full((1, 1, 1, 8), 1e-20), jnp.int8)


try:
    import hypothesis  # noqa: F401
    _HYP = True
except ImportError:
    _HYP = False

if _HYP:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=40, deadline=None)
    @given(seed=st.integers(0, 10 ** 6),
           rows=st.integers(1, 6), hd=st.sampled_from([4, 16, 64]),
           log_mag=st.floats(-8.0, 8.0),
           qi=st.integers(0, len(QDTYPES) - 1))
    def test_roundtrip_bounded_property(seed, rows, hd, log_mag, qi):
        key = jax.random.PRNGKey(seed)
        x = jax.random.normal(key, (rows, 3, 2, hd)) * 10.0 ** log_mag
        _roundtrip_bound(x, QDTYPES[qi])


# ---------------------------------------------------------------------------
# 2. quantized paged decode vs fp32 oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("ps", [16, 64, 128])
@pytest.mark.parametrize("Hkv,H", [(1, 4), (2, 8), (4, 4)])
def test_int8_paged_kernel_matches_fp32_oracle(ps, Hkv, H):
    B, hd, n_pages = 3, 64, 4
    P = B * n_pages + 2
    key = jax.random.PRNGKey(ps + H)
    q, kp, vp, bt = _setup(key, B, H, Hkv, hd, P, ps, n_pages)
    lengths = jnp.asarray([1, (n_pages - 1) * ps + ps // 2 + 1, n_pages * ps],
                          jnp.int32)
    exp = ref.paged_decode_attention_ref(q, kp, vp, bt, lengths)
    kq, ks = kv_quantize(kp, jnp.int8)
    vq, vs = kv_quantize(vp, jnp.int8)
    for out in (
        paged_decode_attention(q, kq, vq, bt, lengths, k_scale=ks,
                               v_scale=vs, interpret=True),
        ref.paged_decode_attention_ref(q, kq, vq, bt, lengths, k_scale=ks,
                                       v_scale=vs),
    ):
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   **INT8_TOLS)


@pytest.mark.skipif(FP8_DTYPE is None,
                    reason="jax build lacks float8_e4m3fn")
@pytest.mark.parametrize("ps", [16, 64])
def test_fp8_paged_kernel_matches_fp32_oracle(ps):
    B, H, Hkv, hd, n_pages = 3, 8, 2, 64, 4
    P = B * n_pages + 2
    key = jax.random.PRNGKey(ps)
    q, kp, vp, bt = _setup(key, B, H, Hkv, hd, P, ps, n_pages)
    lengths = jnp.asarray([1, (n_pages - 1) * ps + ps // 2 + 1, n_pages * ps],
                          jnp.int32)
    exp = ref.paged_decode_attention_ref(q, kp, vp, bt, lengths)
    kq, ks = kv_quantize(kp, FP8_DTYPE)
    vq, vs = kv_quantize(vp, FP8_DTYPE)
    out = paged_decode_attention(q, kq, vq, bt, lengths, k_scale=ks,
                                 v_scale=vs, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), **FP8_TOLS)


def test_ops_dispatch_passes_scales(monkeypatch):
    """ops.paged_decode_attention must dequantize in BOTH kernel modes —
    a ref/interpret result without scales would be garbage-scaled."""
    B, H, Hkv, hd, ps, n_pages = 2, 4, 2, 32, 16, 2
    P = B * n_pages + 1
    key = jax.random.PRNGKey(3)
    q, kp, vp, bt = _setup(key, B, H, Hkv, hd, P, ps, n_pages)
    # make scale-dropping visible: blow V magnitudes up 100x (output is
    # linear in v, so missing dequant is a ~100x error; scaling k would
    # sharpen softmax into an argmax and make the check brittle instead)
    vp = vp * 100.0
    lengths = jnp.asarray([ps + 1, 2 * ps], jnp.int32)
    exp = ref.paged_decode_attention_ref(q, kp, vp, bt, lengths)
    kq, ks = kv_quantize(kp, jnp.int8)
    vq, vs = kv_quantize(vp, jnp.int8)
    for mode in ("ref", "interpret"):
        monkeypatch.setenv("REPRO_KERNEL_MODE", mode)
        out = ops.paged_decode_attention(q, kq, vq, bt, lengths,
                                         k_scale=ks, v_scale=vs)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=6e-2, atol=6.0)  # atol ~ 100x scale


# ---------------------------------------------------------------------------
# 3. quantized cache writes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("ps", [16, 64])
def test_quantized_cache_write_layout(ps):
    """Quantized paged_cache_write lands dequantizable values at
    bt[b, p//ps] offset p%ps, scales alongside, idle rows quarantine."""
    cfg = get_config("qwen3-0.6b").reduced()
    P = 8
    cache = attn_lib.make_paged_kv_cache(cfg, P, ps, jnp.float32,
                                         kv_dtype="int8")
    assert set(cache) == {"k_pages", "v_pages", "k_scale", "v_scale"}
    assert cache["k_pages"].dtype == jnp.int8
    assert cache["k_scale"].shape == (P, ps, cfg.num_kv_heads)
    B = 3
    Hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    bt = jnp.asarray([[1, 2, 3, 4], [5, 6, 7, 0], [0, 0, 0, 0]], jnp.int32)
    pos = jnp.asarray([ps + 3, 2 * ps - 1, 10 ** 6], jnp.int32)
    k_new = jax.random.normal(jax.random.PRNGKey(0), (B, 1, Hkv, hd))
    new = attn_lib.paged_cache_write(cache, k_new, k_new + 1.0, pos, bt)
    deq_k = kv_dequantize(new["k_pages"], new["k_scale"])
    deq_v = kv_dequantize(new["v_pages"], new["v_scale"])
    np.testing.assert_allclose(np.asarray(deq_k[2, 3]),
                               np.asarray(k_new[0, 0]), **INT8_TOLS)
    np.testing.assert_allclose(np.asarray(deq_v[6, ps - 1]),
                               np.asarray(k_new[1, 0] + 1.0), **INT8_TOLS)
    touched = np.nonzero(np.asarray(
        jnp.any(new["k_pages"] != 0, axis=(1, 2, 3))))[0].tolist()
    assert set(touched) <= {0, 2, 6}


def test_quantized_block_write_equals_sequential():
    """One S-token block scatter == S single-token writes, bit-exact,
    for values AND scales (per-slot scales make this possible — a
    per-page scale would requantize neighbors on every append)."""
    cfg = get_config("qwen3-0.6b").reduced()
    ps, P, S, B = 16, 8, 5, 2
    Hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    cache = attn_lib.make_paged_kv_cache(cfg, P, ps, jnp.float32,
                                         kv_dtype="int8")
    bt = jnp.asarray([[1, 2, 3], [4, 5, 6]], jnp.int32)
    pos = jnp.asarray([ps - 2, 3], jnp.int32)   # row 0 crosses a page
    key = jax.random.PRNGKey(7)
    kb = jax.random.normal(key, (B, S, Hkv, hd))
    vb = jax.random.normal(jax.random.fold_in(key, 1), (B, S, Hkv, hd))
    blk = attn_lib.paged_cache_write_block(cache, kb, vb, pos, bt)
    seq = cache
    for s in range(S):
        seq = attn_lib.paged_cache_write(seq, kb[:, s:s + 1], vb[:, s:s + 1],
                                         pos + s, bt)
    for leaf in ("k_pages", "v_pages", "k_scale", "v_scale"):
        np.testing.assert_array_equal(np.asarray(blk[leaf]),
                                      np.asarray(seq[leaf]))


def test_gather_paged_kv_dequantizes():
    cfg = get_config("qwen3-0.6b").reduced()
    ps, P = 16, 6
    Hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    cache = attn_lib.make_paged_kv_cache(cfg, P, ps, jnp.float32,
                                         kv_dtype="int8")
    bt = jnp.asarray([[1, 2]], jnp.int32)
    kb = jax.random.normal(jax.random.PRNGKey(2), (1, 2 * ps, Hkv, hd))
    new = attn_lib.paged_cache_write_block(
        cache, kb, kb * 2.0, jnp.zeros((1,), jnp.int32), bt)
    k, v = attn_lib.gather_paged_kv(new, bt)
    assert k.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(k), np.asarray(kb), **INT8_TOLS)
    np.testing.assert_allclose(np.asarray(v), np.asarray(kb * 2.0),
                               **INT8_TOLS)


# ---------------------------------------------------------------------------
# kv_storage_dtype validation
# ---------------------------------------------------------------------------

def test_kv_storage_dtype_resolution():
    assert kv_storage_dtype("auto", jnp.bfloat16) == (jnp.bfloat16, False)
    assert kv_storage_dtype("fp32", jnp.bfloat16) == (jnp.float32, False)
    assert kv_storage_dtype("bf16", jnp.float32) == (jnp.bfloat16, False)
    assert kv_storage_dtype("int8", jnp.float32) == (jnp.int8, True)
    with pytest.raises(ValueError, match="kv_dtype"):
        kv_storage_dtype("int4", jnp.float32)
    if FP8_DTYPE is not None:
        assert kv_storage_dtype("fp8", jnp.float32) == (FP8_DTYPE, True)


# ---------------------------------------------------------------------------
# debug_validate: corruption raises instead of silent clipping
# ---------------------------------------------------------------------------

def test_debug_validate_catches_corruption():
    B, H, Hkv, hd, ps, n_pages = 2, 4, 2, 32, 16, 3
    P = B * n_pages + 1
    key = jax.random.PRNGKey(0)
    q, kp, vp, bt = _setup(key, B, H, Hkv, hd, P, ps, n_pages)
    lengths = jnp.asarray([n_pages * ps, ps + 2], jnp.int32)
    # clean table validates and runs
    ops.paged_decode_attention(q, kp, vp, bt, lengths, debug_validate=True)

    # corrupt a LIVE logical page of row 0 -> must raise, naming the row
    bad = bt.at[0, 1].set(P + 13)
    with pytest.raises(ValueError, match=r"\(0, 1, "):
        ops.paged_decode_attention(q, kp, vp, bad, lengths,
                                   debug_validate=True)
    with pytest.raises(ValueError):
        validate_block_table(np.asarray(bt.at[1, 0].set(-2)),
                             np.asarray(lengths), P, ps)

    # corruption BEYOND the live length is dead space — allowed (idle
    # rows legitimately point everything at quarantine)
    dead = bt.at[1, 2].set(P + 13)          # row 1 live only to ps+2
    out = ops.paged_decode_attention(q, kp, vp, dead, lengths,
                                     debug_validate=True)
    exp = ops.paged_decode_attention(q, kp, vp, bt, lengths)
    # row 1's output unaffected by the dead-page id (clip semantics)
    np.testing.assert_allclose(np.asarray(out[1]), np.asarray(exp[1]),
                               rtol=1e-5, atol=1e-5)


def test_silent_clip_would_have_corrupted():
    """The failure mode debug_validate exists for: without validation, an
    out-of-range live page id silently clips to the last pool page and
    returns a plausible—but wrong—result."""
    B, H, Hkv, hd, ps, n_pages = 1, 4, 2, 32, 16, 2
    P = 4
    key = jax.random.PRNGKey(5)
    q, kp, vp, bt = _setup(key, B, H, Hkv, hd, P, ps, n_pages)
    lengths = jnp.asarray([n_pages * ps], jnp.int32)
    bad = bt.at[0, 1].set(P + 7)
    good = ops.paged_decode_attention(q, kp, vp, bt, lengths)
    wrong = ops.paged_decode_attention(q, kp, vp, bad, lengths)
    assert np.isfinite(np.asarray(wrong)).all()
    assert not np.allclose(np.asarray(wrong), np.asarray(good), atol=1e-3)
