"""Sharding-aware checkpointing: host-side npz payload + JSON tree spec.

Works for any pytree (params, optimizer state, CAMD state). Arrays are
gathered to host before saving; on restore, the caller re-shards by
feeding the tree through its usual ``device_put``/pjit path. bfloat16 is
round-tripped via a uint16 view (npz has no native bf16).
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> Tuple[Dict[str, np.ndarray], Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    payload = {}
    kinds = []
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype == jnp.bfloat16:
            payload[f"leaf_{i}"] = arr.view(np.uint16)
            kinds.append("bfloat16")
        else:
            payload[f"leaf_{i}"] = arr
            kinds.append(str(arr.dtype))
    return payload, (treedef, kinds)


def save_checkpoint(path: str, tree, step: int = 0) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    payload, (treedef, kinds) = _flatten(tree)
    np.savez(path + ".npz", **payload)
    spec = {"treedef": str(treedef), "kinds": kinds, "step": step,
            "n_leaves": len(kinds)}
    with open(path + ".json", "w") as f:
        json.dump(spec, f)


def load_checkpoint(path: str, like_tree) -> Tuple[Any, int]:
    """Restore into the structure of ``like_tree`` (shapes must match)."""
    with open(path + ".json") as f:
        spec = json.load(f)
    data = np.load(path + ".npz")
    leaves, treedef = jax.tree_util.tree_flatten(like_tree)
    assert len(leaves) == spec["n_leaves"], "checkpoint/tree mismatch"
    out = []
    for i, (leaf, kind) in enumerate(zip(leaves, spec["kinds"])):
        arr = data[f"leaf_{i}"]
        if kind == "bfloat16":
            arr = arr.view(jnp.bfloat16)
        assert arr.shape == leaf.shape, (i, arr.shape, leaf.shape)
        out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), spec["step"]
