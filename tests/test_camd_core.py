"""Unit + property tests for the CAMD core modules (Eq. 7-16)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need hypothesis;
# a bare interpreter must still collect the suite (module-level skip)
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.config import CAMDConfig
from repro.core import clustering, controller, posterior, scoring


# ---------------------------------------------------------------------------
# scoring (Eq. 7-12)
# ---------------------------------------------------------------------------

def test_generation_confidence_masking():
    lp = jnp.array([[-1.0, -2.0, -100.0], [-3.0, -3.0, -3.0]])
    mask = jnp.array([[1, 1, 0], [1, 1, 1]])
    out = scoring.generation_confidence(lp, mask)
    np.testing.assert_allclose(np.asarray(out), [-1.5, -3.0], rtol=1e-6)


def test_coherence_bounds_and_perfect_case():
    h = jnp.ones((1, 5, 8))
    mask = jnp.ones((1, 5))
    out = scoring.reasoning_coherence(h, mask)
    np.testing.assert_allclose(np.asarray(out), 1.0, rtol=1e-5)
    h2 = jax.random.normal(jax.random.PRNGKey(0), (4, 10, 8))
    out2 = scoring.reasoning_coherence(h2, jnp.ones((4, 10)))
    assert bool(jnp.all(out2 <= 1.0)) and bool(jnp.all(out2 >= -1.0))


def test_evidence_score_lambda_weights():
    """Eq. 12 composition: alignment/coherence terms scale with λ."""
    k = jax.random.PRNGKey(1)
    lp = -jnp.ones((2, 6))
    mask = jnp.ones((2, 6))
    h = jax.random.normal(k, (2, 6, 8))
    tok = jax.random.normal(jax.random.fold_in(k, 1), (2, 6, 8))
    vis = jax.random.normal(jax.random.fold_in(k, 2), (2, 4, 8))
    s0 = scoring.evidence_weighted_score(lp, mask, lambda_g=0, lambda_c=0,
                                         hidden=h, token_embs=tok,
                                         visual_feats=vis)
    np.testing.assert_allclose(np.asarray(s0), -1.0, rtol=1e-6)
    s1 = scoring.evidence_weighted_score(lp, mask, lambda_g=0.9, lambda_c=0.7,
                                         hidden=h, token_embs=tok,
                                         visual_feats=vis)
    align = scoring.cross_modal_consistency(tok, mask, vis, tok)
    coh = scoring.reasoning_coherence(h, mask)
    np.testing.assert_allclose(np.asarray(s1),
                               np.asarray(-1.0 + 0.9 * align + 0.7 * coh),
                               rtol=1e-5)


# ---------------------------------------------------------------------------
# clustering (Eq. 13)
# ---------------------------------------------------------------------------

def test_clustering_groups_similar_candidates():
    tb = clustering.make_table(8, 4)
    base = jnp.array([1.0, 0.0, 0.0, 0.0])
    other = jnp.array([0.0, 1.0, 0.0, 0.0])
    embs = jnp.stack([base, base * 2.0, other, base + 0.01, other * 0.5])
    scores = jnp.zeros(5)
    valid = jnp.ones(5, bool)
    tb, idx = clustering.assign_batch(tb, embs, scores, valid, 0.85)
    idx = np.asarray(idx)
    assert idx[0] == idx[1] == idx[3]       # scaled/near copies cluster
    assert idx[2] == idx[4] and idx[2] != idx[0]
    assert int(tb.n_clusters) == 2


def test_clustering_table_overflow_joins_nearest():
    tb = clustering.make_table(2, 4)
    eye = jnp.eye(4)
    embs = jnp.concatenate([eye[:3], eye[:1]], axis=0)
    tb, idx = clustering.assign_batch(tb, embs, jnp.zeros(4),
                                      jnp.ones(4, bool), 0.9)
    assert int(tb.n_clusters) == 2          # capped at M
    assert idx[3] == idx[0]                  # overflow joined its twin


def test_posterior_weights_eq14():
    """p̂_k must equal softmax-mass of member scores per cluster."""
    tb = clustering.make_table(4, 2)
    embs = jnp.array([[1.0, 0], [1, 0.01], [0, 1.0]])
    scores = jnp.array([2.0, 1.0, 0.0])
    tb, idx = clustering.assign_batch(tb, embs, scores, jnp.ones(3, bool), 0.85)
    p = np.asarray(clustering.posterior_weights(tb))
    e = np.exp([2.0, 1.0, 0.0])
    expect_c0 = (e[0] + e[1]) / e.sum()
    np.testing.assert_allclose(p[np.asarray(idx)[0]], expect_c0, rtol=1e-5)
    np.testing.assert_allclose(p.sum(), 1.0, rtol=1e-5)


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 30), st.integers(0, 10**6))
def test_posterior_weights_always_simplex(n, seed):
    key = jax.random.PRNGKey(seed)
    embs = jax.random.normal(key, (n, 8))
    scores = jax.random.normal(jax.random.fold_in(key, 1), (n,)) * 3
    tb = clustering.make_table(6, 8)
    tb, _ = clustering.assign_batch(tb, embs, scores, jnp.ones(n, bool), 0.8)
    p = np.asarray(clustering.posterior_weights(tb))
    assert np.all(p >= -1e-7)
    np.testing.assert_allclose(p.sum(), 1.0, rtol=1e-4)


# ---------------------------------------------------------------------------
# posterior / Dirichlet / mixture (Eq. 14-16)
# ---------------------------------------------------------------------------

def test_dirichlet_update_accumulates():
    tb = clustering.make_table(4, 2)
    embs = jnp.array([[1.0, 0], [0, 1.0]])
    tb, _ = clustering.assign_batch(tb, embs, jnp.array([1.0, 1.0]),
                                    jnp.ones(2, bool), 0.85)
    alpha = jnp.full((4,), 0.5)
    a1, pi = posterior.dirichlet_update(alpha, tb)
    assert float(jnp.sum(a1)) > float(jnp.sum(alpha))
    np.testing.assert_allclose(float(pi.sum()), 1.0, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(pi[:2]), 0.5, atol=1e-5)


def test_mixture_bias_prefers_majority_cluster_tokens():
    pi = jnp.array([0.9, 0.1, 0.0, 0.0])
    hist = jnp.zeros((4, 16)).at[0, 3].set(10.0).at[1, 7].set(10.0)
    bias = posterior.mixture_logit_bias(pi, hist)
    assert float(bias[3]) > float(bias[7]) > float(bias[11])


def test_coverage_stop_rule():
    tb = clustering.make_table(4, 2)
    # one dominant cluster of three high scorers vs a stray
    embs = jnp.array([[1.0, 0]] * 3 + [[0, 1.0]])
    scores = jnp.array([3.0, 3.0, 3.0, -4.0])
    tb, _ = clustering.assign_batch(tb, embs, scores, jnp.ones(4, bool), 0.85)
    stop, p = posterior.coverage_reached(tb, jnp.asarray(4), delta=0.05,
                                         min_samples=2)
    assert bool(stop) and float(p) > 0.95
    stop2, _ = posterior.coverage_reached(tb, jnp.asarray(1), delta=0.05,
                                          min_samples=2)
    assert not bool(stop2)                   # min_samples gate


# ---------------------------------------------------------------------------
# controller state machine
# ---------------------------------------------------------------------------

def _round(scores, embs, cfg, uids0=0):
    n = len(scores)
    return controller.RoundInputs(
        scores=jnp.asarray(scores, jnp.float32),
        embs=jnp.asarray(embs, jnp.float32),
        token_counts=jnp.zeros((n, 32)),
        lengths=jnp.full((n,), 10, jnp.int32),
        valid=jnp.ones((n,), bool),
        uids=jnp.arange(uids0, uids0 + n, dtype=jnp.int32))


def test_controller_stops_on_consensus_continues_on_dissent():
    cfg = CAMDConfig(max_clusters=8, min_samples=3, delta=0.05, max_rounds=10)
    base = np.array([1.0, 0, 0, 0], np.float32)
    # consensus: three identical high-scoring answers
    st1 = controller.init_state(cfg, 4, 32)
    st1, _ = controller.round_update(cfg, st1, _round(
        [2.0, 2.0, 2.0], [base, base, base], cfg))
    assert bool(st1.stopped) and float(st1.p_star) >= 0.95
    # dissent: three orthogonal equal-scoring answers
    st2 = controller.init_state(cfg, 4, 32)
    st2, _ = controller.round_update(cfg, st2, _round(
        [1.0, 1.0, 1.0], np.eye(4, dtype=np.float32)[:3], cfg))
    assert not bool(st2.stopped) and float(st2.p_star) < 0.5


def test_controller_tracks_best_and_budget():
    cfg = CAMDConfig(max_clusters=8, min_samples=10, max_rounds=10)
    st = controller.init_state(cfg, 4, 32)
    st, _ = controller.round_update(cfg, st, _round(
        [0.5, 2.5, 1.0], np.eye(4, dtype=np.float32)[:3], cfg))
    assert int(st.best_uid) == 1
    assert int(st.k_t) == 3
    assert int(st.tokens_spent) == 30
    st, _ = controller.round_update(cfg, st, _round(
        [3.0, 0.0, 0.0], np.eye(4, dtype=np.float32)[:3], cfg, uids0=3))
    assert int(st.best_uid) == 3
    assert int(st.k_t) == 6


def test_controller_max_rounds_forces_stop():
    cfg = CAMDConfig(max_clusters=8, min_samples=100, max_rounds=2)
    st = controller.init_state(cfg, 4, 32)
    for i in range(2):
        st, _ = controller.round_update(cfg, st, _round(
            [0.1], [np.eye(4, dtype=np.float32)[i % 4]], cfg, uids0=i))
    assert bool(st.stopped)


def test_stopped_state_frozen():
    cfg = CAMDConfig(max_clusters=8, min_samples=1, delta=0.5, max_rounds=10)
    st = controller.init_state(cfg, 4, 32)
    st, _ = controller.round_update(cfg, st, _round(
        [5.0, 5.0], [np.array([1., 0, 0, 0])] * 2, cfg))
    assert bool(st.stopped)
    k_before = int(st.k_t)
    st2, bias = controller.round_update(cfg, st, _round(
        [9.0], [np.array([0., 1, 0, 0])], cfg, uids0=10))
    assert int(st2.k_t) == k_before          # no further accounting
    assert float(jnp.abs(bias).max()) == 0.0  # guidance off


# ---------------------------------------------------------------------------
# §3.2 adaptive-stop baselines
# ---------------------------------------------------------------------------

def test_threshold_stop():
    stop, rounds = posterior.threshold_stop(
        jnp.asarray(0.95), jnp.asarray(0.9), jnp.asarray(0), tau=0.9, patience=3)
    assert bool(stop)
    stop2, rounds2 = posterior.threshold_stop(
        jnp.asarray(0.5), jnp.asarray(0.5), jnp.asarray(2), tau=0.9, patience=3)
    assert bool(stop2) and int(rounds2) == 3  # patience exhausted


def test_beta_bernoulli_stop():
    stop, mf = posterior.beta_bernoulli_stop(
        jnp.asarray(19.0), jnp.asarray(20.0), delta=0.1)
    assert bool(stop)
    stop2, _ = posterior.beta_bernoulli_stop(
        jnp.asarray(1.0), jnp.asarray(20.0), delta=0.1)
    assert not bool(stop2)


def test_expected_improvement_stop():
    stop, ei = posterior.expected_improvement_stop(
        jnp.asarray(10.0), jnp.asarray(0.0), jnp.asarray(0.01),
        jnp.asarray(100.0), cost_per_token=1e-3)
    assert bool(stop)   # best far above mean -> no expected gain
    stop2, _ = posterior.expected_improvement_stop(
        jnp.asarray(0.0), jnp.asarray(1.0), jnp.asarray(1.0),
        jnp.asarray(1.0), cost_per_token=1e-5)
    assert not bool(stop2)
