"""Paged decode-attention correctness: Pallas (interpret) vs jnp oracle,
paged oracle vs contiguous oracle, and the paged cache-write layout.

Sweeps page sizes {16, 64, 128}, ragged live lengths, and GQA group
sizes — the block-padding and masking paths the serving engine leans on.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import ref
from repro.kernels.paged_decode_attention import paged_decode_attention
from repro.models import attention as attn_lib

TOLS = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
        jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def _setup(key, B, H, Hkv, hd, P, ps, n_pages, dtype, seed=0):
    q = jax.random.normal(key, (B, 1, H, hd)).astype(dtype)
    kp = jax.random.normal(jax.random.fold_in(key, 1),
                           (P, ps, Hkv, hd)).astype(dtype)
    vp = jax.random.normal(jax.random.fold_in(key, 2),
                           (P, ps, Hkv, hd)).astype(dtype)
    rng = np.random.default_rng(seed)
    # rows reference disjoint random pages, like a fragmented live pool
    perm = rng.permutation(P - 1) + 1          # page 0 = quarantine
    assert B * n_pages <= P - 1
    bt = jnp.asarray(perm[:B * n_pages].reshape(B, n_pages), jnp.int32)
    return q, kp, vp, bt


@pytest.mark.parametrize("ps", [16, 64, 128])
@pytest.mark.parametrize("Hkv,H", [(1, 4), (2, 8), (4, 4)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_kernel_matches_oracle(ps, Hkv, H, dtype):
    B, hd, n_pages = 3, 64, 4
    P = B * n_pages + 2
    key = jax.random.PRNGKey(ps + H)
    q, kp, vp, bt = _setup(key, B, H, Hkv, hd, P, ps, n_pages, dtype)
    # ragged: one-token row, mid-page row, exactly-full row
    lengths = jnp.asarray([1, (n_pages - 1) * ps + ps // 2 + 1, n_pages * ps],
                          jnp.int32)
    out = paged_decode_attention(q, kp, vp, bt, lengths, interpret=True)
    exp = ref.paged_decode_attention_ref(q, kp, vp, bt, lengths)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), **TOLS[dtype])


def test_paged_oracle_matches_contiguous_oracle():
    """Gathering the block table must reproduce dense decode attention
    exactly (fp32, <=1e-4): pages laid out contiguously == dense cache."""
    B, H, Hkv, hd, ps, n_pages = 2, 8, 2, 64, 16, 6
    S = ps * n_pages
    key = jax.random.PRNGKey(0)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, Hkv, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, Hkv, hd))
    q = jax.random.normal(key, (B, 1, H, hd))
    lengths = jnp.asarray([S // 3, S], jnp.int32)
    mask = jnp.arange(S)[None, :] < lengths[:, None]
    dense = ref.decode_attention_ref(q, k, v, mask)

    # identity layout: row b's page i is pool page b*n_pages + i
    kp = k.reshape(B * n_pages, ps, Hkv, hd)
    vp = v.reshape(B * n_pages, ps, Hkv, hd)
    bt = jnp.arange(B * n_pages, dtype=jnp.int32).reshape(B, n_pages)
    paged = ref.paged_decode_attention_ref(q, kp, vp, bt, lengths)
    np.testing.assert_allclose(np.asarray(paged), np.asarray(dense),
                               rtol=1e-4, atol=1e-4)
    kern = paged_decode_attention(q, kp, vp, bt, lengths, interpret=True)
    np.testing.assert_allclose(np.asarray(kern), np.asarray(dense),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("ps", [16, 64])
def test_paged_cache_write_layout(ps):
    """paged_cache_write must land token at pos p in page bt[b, p//ps],
    offset p%ps — and idle rows (table row = quarantine) must never
    corrupt live pages."""
    cfg = get_config("qwen3-0.6b").reduced()
    P, n_pages = 8, 4
    cache = attn_lib.make_paged_kv_cache(cfg, P, ps, jnp.float32)
    B = 3
    Hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    bt = jnp.asarray([[1, 2, 3, 4], [5, 6, 7, 0], [0, 0, 0, 0]], jnp.int32)
    pos = jnp.asarray([ps + 3, 2 * ps - 1, 10 ** 6], jnp.int32)  # row 2 idle
    k_new = jax.random.normal(jax.random.PRNGKey(0), (B, 1, Hkv, hd))
    new = attn_lib.paged_cache_write(cache, k_new, k_new + 1.0, pos, bt)
    np.testing.assert_array_equal(
        np.asarray(new["k_pages"][2, 3]), np.asarray(k_new[0, 0]))
    np.testing.assert_array_equal(
        np.asarray(new["k_pages"][6, ps - 1]), np.asarray(k_new[1, 0]))
    np.testing.assert_array_equal(
        np.asarray(new["v_pages"][6, ps - 1]), np.asarray(k_new[1, 0] + 1.0))
    # idle row clamps to its table (all-quarantine) — only page 0 dirtied
    touched = np.nonzero(np.asarray(
        jnp.any(new["k_pages"] != 0.0, axis=(1, 2, 3))))[0].tolist()
    assert set(touched) <= {0, 2, 6}
