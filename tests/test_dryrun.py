"""Dry-run path smoke test (slow): one (arch × shape) through the real
512-device production-mesh lower+compile in a subprocess."""
import subprocess
import sys

import pytest


@pytest.mark.slow
def test_dryrun_one_combo():
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "qwen3-0.6b", "--shape", "decode_32k",
         "--mesh", "single", "--no-costs", "--out", ""],
        capture_output=True, text=True, timeout=540,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert "All dry-run combinations lowered and compiled successfully." \
        in r.stdout, r.stdout[-2000:] + r.stderr[-2000:]
    assert "bottleneck=" in r.stdout


def test_collective_bytes_parser():
    from repro.utils.hlo import collective_bytes
    hlo = """
  %ag = bf16[16,1024]{1,0} all-gather(%p), replica_groups={}
  %ar.1 = f32[256]{0} all-reduce(%x), to_apply=%sum
  %a2a = (f32[8,4]{1,0}, f32[8,4]{1,0}) all-to-all(%y, %z)
  %cp = u32[128]{0} collective-permute(%w), source_target_pairs={{0,1}}
  %start = bf16[64]{0} all-reduce-start(%v)
  %done = bf16[64]{0} all-reduce-done(%start)
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 16 * 1024 * 2
    assert out["all-reduce"] == 256 * 4 + 64 * 2  # start counted, done not
    assert out["all-to-all"] == 2 * 8 * 4 * 4
    assert out["collective-permute"] == 128 * 4
    assert out["total"] == sum(
        v for k, v in out.items() if not k.startswith("count:") and k != "total")
