from repro.training.checkpoint import load_checkpoint, save_checkpoint  # noqa: F401
from repro.training.loss import cross_entropy, total_loss  # noqa: F401
from repro.training.optimizer import (  # noqa: F401
    OptState,
    adamw_update,
    init_opt_state,
    learning_rate,
)
from repro.training.train_loop import make_loss_fn, make_train_step, train  # noqa: F401
