"""Distributed training launcher.

    python -m repro.launch.train --arch qwen3-0.6b --steps 100 \
        --mesh 2x2 --batch 8 --seq 128

On real hardware the mesh comes from the slice topology; on CPU pass
``--devices N`` to force host devices (must be the first thing the
process does, so it is handled here before importing jax).
"""
import argparse
import os
import sys


def _parse():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--mesh", default="1x1", help="DATAxMODEL, e.g. 4x2")
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host devices (CPU testing)")
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-test-sized variant")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default="")
    return ap.parse_args()


def main():
    args = _parse()
    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    from repro.config import INPUT_SHAPES, TrainConfig
    from repro.configs import get_config
    from repro.data import lm_batches
    from repro.distributed import sharding as shd
    from repro.launch.mesh import make_local_mesh
    from repro.models import build_model
    from repro.training import (init_opt_state, make_train_step,
                                save_checkpoint)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    cfg = cfg.with_overrides(dtype="float32")
    model = build_model(cfg, jnp.float32)
    tc = TrainConfig(total_steps=args.steps, warmup_steps=args.steps // 10,
                     learning_rate=args.lr, microbatches=args.microbatches)
    params = model.init(jax.random.PRNGKey(tc.seed))
    opt = init_opt_state(params)
    step = make_train_step(model, tc)

    dshape = tuple(int(x) for x in args.mesh.split("x"))
    data = lm_batches(cfg.vocab_size, args.batch, args.seq, seed=0)

    if dshape == (1, 1):
        step = jax.jit(step)
        put = lambda t, s: t  # noqa: E731
    else:
        mesh = make_local_mesh(dshape, ("data", "model"))
        p_spec = shd.param_specs(cfg, jax.eval_shape(lambda: params), mesh)
        o_spec = shd.opt_state_specs(cfg, jax.eval_shape(lambda: opt), mesh)

        def put(t, spec):
            return jax.device_put(t, jax.tree.map(
                lambda s: NamedSharding(mesh, s), spec,
                is_leaf=lambda x: isinstance(x, P)))

        params = put(params, p_spec)
        opt = put(opt, o_spec)
        step = jax.jit(step)
        mesh.__enter__()

    import time
    t0 = time.time()
    for i in range(args.steps):
        b = next(data)
        batch = {"tokens": jnp.asarray(b["tokens"]),
                 "labels": jnp.asarray(b["labels"])}
        params, opt, m = step(params, opt, batch)
        if i % max(args.steps // 10, 1) == 0 or i == args.steps - 1:
            print(f"step {i:>5} loss={float(m['loss']):.4f} "
                  f"acc={float(m['accuracy']):.3f} "
                  f"({time.time()-t0:.1f}s)", flush=True)
    if args.ckpt:
        save_checkpoint(args.ckpt, jax.device_get(params), step=args.steps)
        print(f"saved {args.ckpt}.npz")


if __name__ == "__main__":
    main()
