import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape)
on the production meshes; record memory / cost / collective analysis.

This is how the distribution config is proven coherent without hardware:
512 placeholder host devices stand in for 2 pods × 256 v5e chips;
``.lower().compile()`` runs the full GSPMD partitioner, so sharding
mismatches, unsupported collectives, and compile-time OOMs surface as
hard failures here.

Cost methodology: XLA's cost_analysis counts a `lax.scan` body ONCE, and
production models scan over layers. The structural check therefore
compiles the FULL-depth scanned model (memory analysis is exact — scan
reuses buffers), while FLOPs / bytes / collective bytes are measured on
shallow UNROLLED variants at 1× and 2× the block pattern and extrapolated
linearly in depth (the per-layer delta is exact; embed/unembed/loss
overhead is captured by the 1× point).

Usage:
  python -m repro.launch.dryrun --arch all --shape all --mesh both
  python -m repro.launch.dryrun --arch kimi-k2-1t-a32b --shape train_4k
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import INPUT_SHAPES, ModelConfig, ShapeConfig, TrainConfig
from repro.configs import ASSIGNED_ARCHS, get_config
from repro.distributed import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.training.optimizer import init_opt_state
from repro.training.train_loop import make_train_step
from repro.utils import hlo as hlo_utils

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12      # bf16
HBM_BW = 819e9           # bytes/s
ICI_BW = 50e9            # bytes/s/link
HBM_BYTES = 16e9

# long-context variant: pure full-attention archs run long_500k with a
# sliding-window ring cache (DESIGN.md §5); sub-quadratic archs run native.
LONG_CONTEXT_WINDOW = 4096
# >100B-param models use bf16 optimizer state (DESIGN.md; kimi-k2)
BF16_OPT_THRESHOLD = 100e9
# >30B-param trainers use gradient accumulation to bound activations
MICROBATCH_THRESHOLD = 30e9
MICROBATCHES = 8


def resolve_config(arch: str, shape: ShapeConfig) -> ModelConfig:
    cfg = get_config(arch)
    if shape.name == "long_500k" and shape.mode == "decode":
        needs_window = cfg.attn_window == 0 and cfg.family in (
            "dense", "moe", "vlm", "audio")
        if needs_window:
            cfg = cfg.with_overrides(attn_window=LONG_CONTEXT_WINDOW)
    return cfg


def build_step(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
               unroll: bool = False, microbatches: int = None):
    """Returns (fn, arg_shapes tuple, in_sharding_specs tuple)."""
    model = build_model(cfg)
    specs = model.input_specs(shape)
    p_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_spec = shd.param_specs(cfg, p_shapes, mesh)

    if shape.mode == "train":
        opt_dtype = jnp.bfloat16 if cfg.num_params() > BF16_OPT_THRESHOLD \
            else jnp.float32
        mb = microbatches if microbatches is not None else (
            MICROBATCHES if cfg.num_params() > MICROBATCH_THRESHOLD else 1)
        tc = TrainConfig(remat=True, unroll=unroll, microbatches=mb)
        step = make_train_step(model, tc)
        o_shapes = jax.eval_shape(lambda p: init_opt_state(p, opt_dtype),
                                  p_shapes)
        o_spec = shd.opt_state_specs(cfg, o_shapes, mesh)
        batch = dict(specs)
        b_spec = shd.batch_specs(shape, batch, mesh)
        return step, (p_shapes, o_shapes, batch), (p_spec, o_spec, b_spec)

    if shape.mode == "prefill":
        cache_len = model.cache_len(shape.seq_len)
        c_shapes = jax.eval_shape(
            lambda: model.make_cache(shape.global_batch, cache_len))
        c_spec = shd.cache_specs(cfg, c_shapes, mesh)
        tok = specs["tokens"]
        t_spec = shd.batch_specs(shape, {"tokens": tok}, mesh)["tokens"]
        ev = specs.get("evidence")
        if ev is not None:
            e_spec = shd.batch_specs(shape, {"evidence": ev}, mesh)["evidence"]

            def fn(params, tokens, cache, evidence):
                return model.prefill(params, tokens, cache, evidence,
                                     unroll=unroll)

            return fn, (p_shapes, tok, c_shapes, ev), \
                (p_spec, t_spec, c_spec, e_spec)

        def fn(params, tokens, cache):
            return model.prefill(params, tokens, cache, unroll=unroll)

        return fn, (p_shapes, tok, c_shapes), (p_spec, t_spec, c_spec)

    # decode
    tok = specs["token"]
    c_shapes = specs["cache"]
    c_spec = shd.cache_specs(cfg, c_shapes, mesh)
    t_spec = shd.batch_specs(shape, {"token": tok}, mesh)["token"]

    def fn(params, token, cache):
        return model.decode_step(params, token, cache, unroll=unroll)

    return fn, (p_shapes, tok, c_shapes), (p_spec, t_spec, c_spec)


def _compile(cfg, shape, mesh, *, unroll=False, microbatches=None):
    fn, shapes, specs = build_step(cfg, shape, mesh, unroll=unroll,
                                   microbatches=microbatches)
    in_sh = jax.tree.map(
        lambda s: jax.sharding.NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    with mesh:
        lowered = jax.jit(fn, in_shardings=in_sh).lower(*shapes)
        compiled = lowered.compile()
    return compiled


def _extract(compiled) -> Dict[str, float]:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):   # jax <= 0.4.x: one dict per device
        cost = cost[0] if cost else {}
    text = compiled.as_text()
    coll = hlo_utils.collective_bytes(text)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": float(coll.get("total", 0)),
        "coll_detail": {k: v for k, v in coll.items() if k != "total"},
    }


def measure_costs(arch: str, shape: ShapeConfig, mesh) -> Dict[str, Any]:
    """Per-layer cost extrapolation from 1× / 2×-pattern unrolled models.

    Microbatched trainers are measured at ONE microbatch (mb=1, batch/k)
    and scaled by k — per-step FLOPs/bytes are linear in tokens, and the
    ×k repeat of per-microbatch weight gathers is thereby counted
    honestly."""
    import dataclasses as _dc
    base = resolve_config(arch, shape)
    P = len(base.block_pattern)
    scale = 1
    if shape.mode == "train" and base.num_params() > MICROBATCH_THRESHOLD:
        scale = MICROBATCHES
        shape = _dc.replace(shape,
                            global_batch=shape.global_batch // MICROBATCHES)
    pts = []
    for mult in (1, 2):
        over = {"num_layers": P * mult}
        if base.is_encoder_decoder:
            over["num_encoder_layers"] = max(
                1, round(base.num_encoder_layers * P * mult / base.num_layers))
        cfg_small = base.with_overrides(**over)
        pts.append(_extract(_compile(cfg_small, shape, mesh, unroll=True,
                                     microbatches=1)))
    layers_equiv = base.num_layers / P
    out = {}
    for k in ("flops", "bytes", "coll"):
        delta = pts[1][k] - pts[0][k]
        out[k] = (pts[0][k] + max(delta, 0.0) * (layers_equiv - 1)) * scale
        out[f"{k}_per_layerblock"] = delta * scale
    out["coll_detail_1x"] = pts[0]["coll_detail"]
    out["coll_detail_2x"] = pts[1]["coll_detail"]
    return out


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS = 6·N_active·D (train) / 2·N_active·D (fwd-only)."""
    n = cfg.active_params()
    if shape.mode == "train":
        return 6.0 * n * shape.seq_len * shape.global_batch
    if shape.mode == "prefill":
        return 2.0 * n * shape.seq_len * shape.global_batch
    return 2.0 * n * shape.global_batch  # one token per sequence


def run_one(arch: str, shape_name: str, multi_pod: bool,
            out_dir: str = "benchmarks/results", verbose: bool = True,
            with_costs: bool = True) -> Dict[str, Any]:
    from repro.distributed.context import set_batch_axes
    set_batch_axes(("pod", "data") if multi_pod else ("data",))
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    n_chips = mesh.size
    cfg = resolve_config(arch, shape)

    # 1) structural check: FULL depth, scanned — must lower AND compile.
    t0 = time.time()
    compiled = _compile(cfg, shape, mesh)
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    raw = _extract(compiled)

    # 2) roofline costs: per-layer extrapolation from unrolled shallow runs.
    costs = measure_costs(arch, shape, mesh) if with_costs else raw

    flops_dev = costs["flops"]
    bytes_dev = costs["bytes"]
    coll_dev = costs["coll"]
    mf = model_flops(cfg, shape)
    terms = {"compute_s": flops_dev / PEAK_FLOPS,
             "memory_s": bytes_dev / HBM_BW,
             "collective_s": coll_dev / ICI_BW}
    bottleneck = max(terms, key=terms.get)
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "chips": n_chips, "mode": shape.mode, "status": "ok",
        "compile_s": round(t_compile, 2),
        "hlo_flops_per_dev": flops_dev,
        "hlo_bytes_per_dev": bytes_dev,
        "collective_bytes_per_dev": coll_dev,
        "scan_raw": raw,
        **{k: float(v) for k, v in terms.items()},
        "bottleneck": bottleneck,
        "model_flops_total": mf,
        "model_flops_per_dev": mf / n_chips,
        "useful_flops_ratio": (mf / n_chips) / flops_dev if flops_dev else 0.0,
        "argument_bytes_per_dev": mem.argument_size_in_bytes,
        "output_bytes_per_dev": mem.output_size_in_bytes,
        "temp_bytes_per_dev": mem.temp_size_in_bytes,
        "fits_16gb_hbm": (mem.argument_size_in_bytes
                          + mem.temp_size_in_bytes) < HBM_BYTES,
        "params_total": cfg.num_params(),
        "params_active": cfg.active_params(),
        "window_variant": cfg.attn_window != get_config(arch).attn_window,
    }
    if with_costs:
        rec["cost_detail"] = {k: v for k, v in costs.items()
                              if k.startswith("coll_detail") or
                              k.endswith("per_layerblock")}
    if verbose:
        print(f"[{arch} × {shape_name} × {mesh_name}] "
              f"compile={t_compile:.1f}s flops/dev={flops_dev:.3e} "
              f"bytes/dev={bytes_dev:.3e} coll/dev={coll_dev:.3e} "
              f"bottleneck={bottleneck}")
        print(f"  memory_analysis: arg={mem.argument_size_in_bytes/1e9:.2f}GB "
              f"temp={mem.temp_size_in_bytes/1e9:.2f}GB "
              f"out={mem.output_size_in_bytes/1e9:.2f}GB "
              f"fits16GB={rec['fits_16gb_hbm']}")
        print(f"  roofline: compute={terms['compute_s']*1e3:.2f}ms "
              f"memory={terms['memory_s']*1e3:.2f}ms "
              f"collective={terms['collective_s']*1e3:.2f}ms "
              f"useful_flops_ratio={rec['useful_flops_ratio']:.3f}")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        fname = f"{out_dir}/dryrun_{mesh_name}_{arch}_{shape_name}.json"
        with open(fname, "w") as f:
            json.dump(rec, f, indent=2, default=str)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="benchmarks/results")
    ap.add_argument("--no-costs", action="store_true",
                    help="structural compile only (skip cost extrapolation)")
    ap.add_argument("--continue-on-error", action="store_true")
    args = ap.parse_args()

    archs = ASSIGNED_ARCHS if args.arch == "all" else args.arch.split(",")
    shapes = list(INPUT_SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = []
    for multi in meshes:
        for arch in archs:
            for shp in shapes:
                try:
                    run_one(arch, shp, multi, out_dir=args.out,
                            with_costs=not args.no_costs)
                except Exception as e:  # noqa: BLE001
                    failures.append((arch, shp, multi, repr(e)))
                    print(f"FAILED [{arch} × {shp} × multi={multi}]: {e}")
                    traceback.print_exc()
                    if not args.continue_on_error:
                        raise
    if failures:
        print(f"\n{len(failures)} failures:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print("\nAll dry-run combinations lowered and compiled successfully.")


if __name__ == "__main__":
    main()
