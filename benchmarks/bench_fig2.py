"""Paper Figure 2 — the motivating experiment (§3.2).

Fixed best-of-N (N ∈ {1,2,4,8,16,32}; pass@256 as the coverage upper
bound) vs the three adaptive stopping rules and CAMD, on a mixed
difficulty population (easy mass + heavy tail — the MathVista stand-in:
"chart/geometry recognition" easy cases vs long-chain visual reasoning).
Reports accuracy vs average tokens/samples — the Pareto frontier the
paper claims for adaptive allocation — plus the per-difficulty-bucket
sample allocation (paper: ~2-3 samples on easy, expands to 32 on hard).
"""
from __future__ import annotations

import numpy as np

from benchmarks.camd_sim import run_adaptive_rule, run_camd, run_fixed_n
from repro.config import CAMDConfig
from repro.data.tasks import SimulatedDecoder


def mixed_population(sim: SimulatedDecoder, n: int, easy_frac: float = 0.55):
    n_easy = int(n * easy_frac)
    easy = sim.rng.uniform(0.55, 0.95, size=n_easy)
    hard = sim.sample_difficulty(n - n_easy)
    return np.concatenate([easy, hard])


def run(n_instances: int = 800, seed: int = 0, verbose: bool = True):
    sim = SimulatedDecoder(tail="heavy", alpha=0.4, seed=seed,
                           score_gap=2.5, score_noise=0.5)
    diffs = mixed_population(sim, n_instances)
    rows = []

    for N in (1, 2, 4, 8, 16, 32):
        rows.append((f"fixed_bo{N}", run_fixed_n(sim, diffs, N, select="best")))
    rows.append(("upper_pass@256", run_fixed_n(sim, diffs, 256, select="oracle")))
    for rule in ("threshold", "bayes", "ei"):
        rows.append((f"adaptive_{rule}", run_adaptive_rule(sim, diffs, rule)))

    # calibration per §5.1 ("normalized on the validation set"):
    # score_scale=1.5 fitted on a held-out population (seed 99).
    camd_cfg = CAMDConfig(samples_per_round=2, max_rounds=16, min_samples=2,
                          max_clusters=8, delta=0.05, score_scale=1.5)
    camd_out = run_camd(sim, diffs, camd_cfg, seed=seed)
    rows.append(("camd", camd_out))

    results = []
    for name, out in rows:
        rec = {"name": name,
               "accuracy": float(np.mean(out["accuracy"])),
               "avg_tokens": float(np.mean(out["tokens"])),
               "avg_samples": float(np.mean(out["samples"]))}
        results.append(rec)
        if verbose:
            print(f"  {name:>18}: acc={rec['accuracy']:.3f} "
                  f"tokens={rec['avg_tokens']:7.1f} "
                  f"samples={rec['avg_samples']:5.2f}")

    # adaptive allocation by difficulty bucket (paper's qualitative claim)
    easy_mask = diffs >= 0.5
    alloc = {
        "easy_avg_samples": float(np.mean(camd_out["samples"][easy_mask])),
        "hard_avg_samples": float(np.mean(camd_out["samples"][~easy_mask])),
        "easy_accuracy": float(np.mean(camd_out["accuracy"][easy_mask])),
        "hard_accuracy": float(np.mean(camd_out["accuracy"][~easy_mask])),
    }
    if verbose:
        print(f"  allocation: easy={alloc['easy_avg_samples']:.2f} samples "
              f"(acc {alloc['easy_accuracy']:.3f}), "
              f"hard={alloc['hard_avg_samples']:.2f} samples "
              f"(acc {alloc['hard_accuracy']:.3f})")

    # claims:
    by = {r["name"]: r for r in results}
    camd = by["camd"]
    # (1) Pareto: the cheapest fixed-N matching CAMD accuracy costs more.
    fixed = [by[f"fixed_bo{N}"] for N in (1, 2, 4, 8, 16, 32)]
    matching = [f for f in fixed if f["accuracy"] >= camd["accuracy"] - 0.005]
    cheapest = min((f["avg_tokens"] for f in matching), default=np.inf)
    claim_pareto = camd["avg_tokens"] < cheapest
    # (2) adaptive allocation: easy instances get ≤ ~3 samples, hard ≥ 3× more.
    claim_alloc = alloc["easy_avg_samples"] <= 4.0 and \
        alloc["hard_avg_samples"] >= 2.5 * alloc["easy_avg_samples"]
    if verbose:
        print(f"  claim[CAMD Pareto-dominates fixed-N]: {claim_pareto} "
              f"(cheapest matching fixed-N tokens: {cheapest:.0f})")
        print(f"  claim[adaptive allocation easy<=4, hard>=2.5x]: {claim_alloc}")
    # --- serving-memory corollary of Fig. 2 -------------------------------
    # CAMD's adaptive allocation only pays off at the engine if decode KV
    # is resident per *live* token. Translate the sim's per-instance token
    # spend into resident-KV bytes under the dense slots×cache_len layout
    # vs the paged pool (page_size granularity), per request on average.
    kv = kv_residency(camd_out, page_size=16, cache_len=512, prompt_len=64)
    if verbose:
        print(f"  kv-residency (camd spend): paged={kv['paged_bytes_per_req']:,.0f} "
              f"B/req vs dense={kv['dense_bytes_per_req']:,.0f} B/req "
              f"({kv['dense_bytes_per_req']/max(kv['paged_bytes_per_req'],1):.1f}x)")
    kv_dtype_rows = kv_residency_by_dtype(page_size=16)
    if verbose:
        for row in kv_dtype_rows:
            print(f"  kv-residency L={row['seq_len']:>6}: "
                  + "  ".join(f"{n}={row[f'bytes_{n}'] / 1e6:8.2f}MB"
                              for n in KV_BYTES_PER_TOKEN))
    return {"rows": results, "allocation": alloc, "kv_residency": kv,
            "kv_residency_by_dtype": kv_dtype_rows,
            "claims": {"pareto": bool(claim_pareto),
                       "allocation": bool(claim_alloc)}}


#: per-layer KV bytes/token for each paged storage mode: k+v leaves x
#: Hkv=8 heads x (hd=64 values at the dtype's width, + a 4-byte fp32
#: absmax scale per (token, head) for the quantized modes).
KV_BYTES_PER_TOKEN = {
    "fp32": 2 * 8 * 64 * 4,
    "bf16": 2 * 8 * 64 * 2,
    "int8": 2 * 8 * (64 * 1 + 4),
    "fp8": 2 * 8 * (64 * 1 + 4),
}


def kv_residency_by_dtype(*, page_size: int = 16,
                          seq_lens=(128, 512, 2048, 8192, 32768)):
    """Resident-KV bytes vs sequence length per storage dtype — the
    quantized-pool corollary: paged residency already scales with live
    tokens; int8/fp8 shrink the constant by ~3.8x vs fp32 (scales
    included), independent of sequence length."""
    rows = []
    for L in seq_lens:
        pages = int(np.ceil(L / page_size))
        row = {"seq_len": L, "pages": pages}
        for name, bpt in KV_BYTES_PER_TOKEN.items():
            row[f"bytes_{name}"] = int(pages * page_size * bpt)
        rows.append(row)
    return rows


def kv_residency(camd_out, *, page_size: int, cache_len: int,
                 prompt_len: int, bytes_per_token: int = 2 * 2 * 8 * 64):
    """Resident-KV accounting for the simulated CAMD spend.

    Dense layout: every candidate slot pins ``cache_len`` tokens of KV.
    Paged layout: a candidate pins its prompt pages (shared per request)
    plus its generated tokens rounded up to ``page_size``.
    ``bytes_per_token`` defaults to one qwen3-ish layer (k+v, fp16-ish,
    8 kv heads x 64 head dim) — scale by num_layers for absolute numbers.
    """
    samples = np.asarray(camd_out["samples"], np.float64)
    tokens = np.asarray(camd_out["tokens"], np.float64)
    gen_per_cand = tokens / np.maximum(samples, 1.0)
    pages = np.ceil(prompt_len / page_size) + \
        samples * np.ceil(gen_per_cand / page_size)
    paged_tokens = pages * page_size
    dense_tokens = samples * cache_len
    return {
        "paged_bytes_per_req": float(np.mean(paged_tokens) * bytes_per_token),
        "dense_bytes_per_req": float(np.mean(dense_tokens) * bytes_per_token),
    }


def engine_microbench(verbose: bool = True, steps_tokens: int = 8):
    """Tiny real-engine paged-vs-contiguous comparison: µs/token and
    resident-KV bytes on the reduced qwen3 arch. Not part of ``run()`` —
    it compiles a model; invoke via ``--engine``."""
    import time

    import jax
    import jax.numpy as jnp

    from repro.config import PagedKVConfig, SamplingConfig
    from repro.configs import get_config
    from repro.models import build_model
    from repro.serving import Request, ServeEngine

    cfg = get_config("qwen3-0.6b").reduced().with_overrides(dtype="float32")
    model = build_model(cfg, jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    rows = []
    for impl in ("xla", "paged"):
        eng = ServeEngine(
            model, params, slots=8, cache_len=128,
            sampling=SamplingConfig(max_new_tokens=steps_tokens),
            mode="best_of_n", n_candidates=4,
            max_new_tokens=steps_tokens, eos_id=1, impl=impl,
            paged_kv=PagedKVConfig(page_size=16), seed=0)
        rng = np.random.default_rng(0)
        # warmup batch: first run() pays prefill/step jit compilation
        # (seconds) — time only the second, steady-state batch.
        for i in range(4):
            eng.submit(Request(uid=i, prompt=rng.integers(
                2, cfg.vocab_size, 8).astype(np.int32)))
        eng.run()
        tok0 = eng.total_tokens
        for i in range(4, 8):
            eng.submit(Request(uid=i, prompt=rng.integers(
                2, cfg.vocab_size, 8).astype(np.int32)))
        t0 = time.perf_counter()
        eng.run()
        dt = time.perf_counter() - t0
        us_tok = dt / max(eng.total_tokens - tok0, 1) * 1e6
        resident = eng.kv_stats()["peak_kv_bytes"] if eng.paged else \
            eng.B * eng.cache_len * 2 * cfg.num_kv_heads * \
            cfg.resolved_head_dim * 4 * cfg.num_layers
        rows.append((impl, us_tok, resident))
        if verbose:
            print(f"  engine[{impl}]: {us_tok:.0f} us/token, "
                  f"peak resident KV {resident:,} B")
    return rows


if __name__ == "__main__":
    import sys
    run()
    if "--engine" in sys.argv:
        engine_microbench()
