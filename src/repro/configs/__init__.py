"""Architecture config registry.

Every assigned architecture (plus the paper's own base models) is a module
in this package exporting ``CONFIG``. ``get_config(name)`` is the public
lookup used by launchers, the dry-run, and tests; ``--arch <id>`` flags
resolve here.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.config import ModelConfig

# module name -> arch id (module names can't contain '.', '-')
_MODULES = {
    "granite_moe_3b_a800m": "granite-moe-3b-a800m",
    "seamless_m4t_large_v2": "seamless-m4t-large-v2",
    "qwen2_5_32b": "qwen2.5-32b",
    "mamba2_780m": "mamba2-780m",
    "qwen3_0_6b": "qwen3-0.6b",
    "yi_34b": "yi-34b",
    "granite_34b": "granite-34b",
    "kimi_k2_1t_a32b": "kimi-k2-1t-a32b",
    "recurrentgemma_2b": "recurrentgemma-2b",
    "internvl2_2b": "internvl2-2b",
    # the paper's own evaluation backbone (LLaVA-1.5-7B's LM side)
    "llava_1_5_7b": "llava-1.5-7b",
}

_BY_NAME: Dict[str, ModelConfig] = {}


def _load() -> None:
    if _BY_NAME:
        return
    for mod, name in _MODULES.items():
        m = importlib.import_module(f"repro.configs.{mod}")
        cfg: ModelConfig = m.CONFIG
        assert cfg.name == name, (cfg.name, name)
        _BY_NAME[name] = cfg


def get_config(name: str) -> ModelConfig:
    """Look up a config by arch id ("llava-1.5-7b") or module name
    ("llava_1_5_7b") — CLI flags accept either spelling."""
    _load()
    if name in _MODULES:
        name = _MODULES[name]
    if name not in _BY_NAME:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_BY_NAME)}")
    return _BY_NAME[name]


def list_configs() -> List[str]:
    _load()
    return sorted(_BY_NAME)


# public view for the config-zoo smoke test: every shipped config module.
MODULE_NAMES = tuple(sorted(_MODULES))


ASSIGNED_ARCHS = [
    "granite-moe-3b-a800m",
    "seamless-m4t-large-v2",
    "qwen2.5-32b",
    "mamba2-780m",
    "qwen3-0.6b",
    "yi-34b",
    "granite-34b",
    "kimi-k2-1t-a32b",
    "recurrentgemma-2b",
    "internvl2-2b",
]
