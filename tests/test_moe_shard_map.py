"""shard_map expert-parallel MoE: must match the dense oracle (subprocess
with 4 forced host devices)."""
import subprocess
import sys

import pytest

SNIPPET = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.config import ATTN, ModelConfig, MoEConfig
from repro.models.moe import moe_init, moe_apply_dense
from repro.models.moe_shard_map import moe_apply_shard_map
from repro.launch.mesh import make_local_mesh

cfg = ModelConfig(
    name="t", family="moe", num_layers=1, d_model=32, num_heads=2,
    num_kv_heads=1, d_ff=48, vocab_size=64, head_dim=32,
    block_pattern=(ATTN,), mlp_activation="swiglu",
    moe=MoEConfig(num_experts=8, top_k=2, expert_d_ff=48,
                  num_shared_experts=1, capacity_factor=8.0),
    dtype="float32")
params = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (64, 32))
ref = moe_apply_dense(params, cfg, x)

mesh = make_local_mesh((4,), ("data",))
xd = jax.device_put(x, NamedSharding(mesh, P("data", None)))
pd = dict(params)
for kk in ("w_gate", "w_up", "w_down"):
    pd[kk] = jax.device_put(params[kk], NamedSharding(mesh, P("data", None, None)))
with mesh:
    out, aux = jax.jit(
        lambda p, x: moe_apply_shard_map(p, cfg, x, mesh))(pd, xd)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                           rtol=3e-4, atol=3e-4)
assert float(aux["moe_drop_frac"]) == 0.0

# 2-axis: experts over data, per-expert f over model (+psum)
mesh2 = make_local_mesh((2, 2), ("data", "model"))
xd2 = jax.device_put(x, NamedSharding(mesh2, P("data", None)))
pd2 = dict(params)
for kk in ("w_gate", "w_up"):
    pd2[kk] = jax.device_put(params[kk], NamedSharding(mesh2, P("data", None, "model")))
pd2["w_down"] = jax.device_put(params["w_down"], NamedSharding(mesh2, P("data", "model", None)))
with mesh2:
    out2, aux2 = jax.jit(lambda p, x: moe_apply_shard_map(
        p, cfg, x, mesh2, model_axis="model"))(pd2, xd2)
np.testing.assert_allclose(np.asarray(out2), np.asarray(ref),
                           rtol=3e-4, atol=3e-4)
print("SHARDMAP_MOE_OK")
"""


@pytest.mark.slow
def test_shard_map_moe_matches_dense_oracle():
    r = subprocess.run([sys.executable, "-c", SNIPPET],
                       capture_output=True, text=True, timeout=540,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu"})
    assert "SHARDMAP_MOE_OK" in r.stdout, r.stdout[-1500:] + r.stderr[-3000:]
