"""Token-level samplers and logit processors.

Matches the paper's decoding setup (§3.2): temperature, top-p, top-k,
min-p, repetition penalty. All processors are pure (B, V) -> (B, V)
functions that jit and compose; ``sample_token`` is the single entry point
used by the serving engine.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import SamplingConfig

NEG_INF = -1e30


def apply_temperature(logits, temperature: float):
    if temperature <= 0.0:
        return logits  # greedy handled by caller
    return logits / temperature


def apply_top_k(logits, k: int):
    if k <= 0:
        return logits
    kth = jnp.sort(logits, axis=-1)[..., -k][..., None]
    return jnp.where(logits < kth, NEG_INF, logits)


def apply_top_p(logits, p: float):
    if p >= 1.0 or p <= 0.0:
        return logits
    sorted_logits = jnp.sort(logits, axis=-1)[..., ::-1]
    probs = jax.nn.softmax(sorted_logits, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    # keep tokens until cumulative prob exceeds p (always keep the top-1)
    cutoff_mask = cum - probs > p
    cutoff_logit = jnp.min(
        jnp.where(cutoff_mask, jnp.inf, sorted_logits), axis=-1, keepdims=True)
    return jnp.where(logits < cutoff_logit, NEG_INF, logits)


def apply_min_p(logits, min_p: float):
    if min_p <= 0.0:
        return logits
    probs = jax.nn.softmax(logits, axis=-1)
    top = jnp.max(probs, axis=-1, keepdims=True)
    return jnp.where(probs < min_p * top, NEG_INF, logits)


def apply_repetition_penalty(logits, token_counts, penalty: float):
    """HF-style: seen tokens' positive logits divided by `penalty`,
    negative multiplied. token_counts: (B, V) counts of emitted tokens."""
    if penalty == 1.0:
        return logits
    seen = token_counts > 0
    return jnp.where(seen,
                     jnp.where(logits > 0, logits / penalty, logits * penalty),
                     logits)


def process_logits(logits, cfg: SamplingConfig, token_counts=None, bias=None):
    """Compose processors in the standard order. ``bias`` is the CAMD
    Eq. 16 mixture guidance (per-row (B, V) additive logits)."""
    if token_counts is not None:
        logits = apply_repetition_penalty(logits, token_counts,
                                          cfg.repetition_penalty)
    if bias is not None:
        logits = logits + bias
    logits = apply_temperature(logits, cfg.temperature)
    logits = apply_top_k(logits, cfg.top_k)
    logits = apply_top_p(logits, cfg.top_p)
    logits = apply_min_p(logits, cfg.min_p)
    return logits


def sample_token(key, logits, cfg: SamplingConfig, token_counts=None,
                 bias=None, greedy=None):
    """Returns (token (B,), logprob (B,)) — logprob of the *sampled* token
    under the processed distribution (used for S_gen, Eq. 7).

    ``greedy``: optional (B,) bool — rows decoded greedily (temperature 0).
    """
    proc = process_logits(logits, cfg, token_counts, bias)
    logp = jax.nn.log_softmax(proc, axis=-1)
    sampled = jax.random.categorical(key, proc, axis=-1)
    arg = jnp.argmax(logits, axis=-1)
    if greedy is None:
        tok = sampled if cfg.temperature > 0 else arg
    else:
        tok = jnp.where(greedy, arg, sampled)
    lp = jnp.take_along_axis(logp, tok[:, None], axis=-1)[:, 0]
    return tok.astype(jnp.int32), lp
