from repro.serving.engine import EngineState, Request, Result, ServeEngine  # noqa: F401
from repro.serving.frontend import AsyncServeFrontend  # noqa: F401
from repro.serving.page_pool import (PagePool, PagePoolError,  # noqa: F401
                                     PrefixCache, prefix_page_keys)
from repro.serving.state_arena import StateArena, StateArenaError  # noqa: F401
from repro.serving.scheduler import (CoverageScheduler,  # noqa: F401
                                     FifoScheduler, NewWork, RoundWork,
                                     Scheduler, SchedulerContext,
                                     make_scheduler)
from repro.serving.traffic import (RequestTrace, bursty_arrivals,  # noqa: F401
                                   drive_open_loop, poisson_arrivals,
                                   run_open_loop, slo_metrics)
