"""Model-level Pallas integration: forward/decode with impl="pallas"
(interpret mode) must match the XLA path."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model


@pytest.fixture(autouse=True)
def _interpret_mode(monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_MODE", "interpret")


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "granite-34b"])
def test_pallas_forward_matches_xla(arch):
    cfg = get_config(arch).reduced().with_overrides(dtype="float32")
    model = build_model(cfg, jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0,
                              cfg.vocab_size)
    ref, _, _ = model.forward(params, toks, impl="xla")
    out, _, _ = model.forward(params, toks, impl="pallas")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_pallas_decode_matches_xla():
    cfg = get_config("qwen3-0.6b").reduced().with_overrides(dtype="float32")
    model = build_model(cfg, jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    B, Lp = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, Lp + 2), 0,
                              cfg.vocab_size)
    outs = {}
    for impl in ("xla", "pallas"):
        cache = model.make_cache(B, Lp + 2, jnp.float32)
        lg, _, cache = model.prefill(params, toks[:, :Lp], cache, impl=impl)
        seq = [lg]
        for t in range(2):
            lg, _, cache = model.decode_step(params, toks[:, Lp + t], cache,
                                             impl=impl)
            seq.append(lg)
        outs[impl] = np.stack([np.asarray(x) for x in seq])
    np.testing.assert_allclose(outs["pallas"], outs["xla"],
                               rtol=2e-4, atol=2e-4)
