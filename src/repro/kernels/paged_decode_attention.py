"""Paged flash-decode Pallas TPU kernel: one query token vs. KV *pages*.

The contiguous flash-decode kernel (``decode_attention.py``) streams a
dense ``(B, S, Hkv, hd)`` cache; its HBM traffic scales with
``slots × cache_len`` even when most slots hold short, early-stopped CAMD
candidates. This kernel instead reads KV through a **block table**: the
cache is a shared pool of ``(P, page_size, Hkv, hd)`` pages and each
batch row names its pages in ``block_table[b, i]``. HBM traffic scales
with *live* tokens — the roofline term that dominates decode.

Mechanics: the block table and per-row live lengths arrive as
scalar-prefetch operands (``pltpu.PrefetchScalarGridSpec``) so the page
index feeds the BlockSpec index map — the DMA engine fetches exactly the
page ``block_table[b, i]`` for grid step ``(b, h, i)``. Page-index is the
minor-most grid dim; running max/sum/acc live in VMEM scratch exactly
like the contiguous kernel, so fully-masked trailing pages wash out of
the online softmax (alpha underflows to 0 when a real max arrives;
garbage from a masked-prefix page is erased the same way).

GQA-aware like ``_decode_kernel``: the G query heads of one kv head form
the sublane dim of the score matmul, so each page is read once per
group, not once per query head.

**Quantized pools** (int8 / fp8-e4m3): when per-row scale tensors
``k_scale``/``v_scale`` of shape (P, page_size, Hkv) accompany the
pages, dequantization happens *inside* the kernel — the scale block
rides the same ``bt[b, i]`` index map as its page, one fp32 multiply
per (slot, head-dim) tile on the VPU, and the layout change stays
invisible above the kernel. HBM traffic per live token drops from
``2*hd*4`` bytes (fp32) to ``2*(hd + 4)`` (int8 values + one fp32
scale per kv head), a ~3.8x cut at hd=64.

The kernel's memory block size IS the page size — ``benchmarks/
autotune.py`` sweeps it (with the contiguous kernels' blk_q/blk_k/blk_s
and the engine's macro-step K) and ships the best configuration in
``BENCH_autotune.json``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def validate_block_table(block_table, lengths, num_pages: int,
                         page_size: int):
    """Host-side guard against block-table corruption.

    The kernel (and the jnp oracle) clip page ids into [0, P-1], which
    turns an out-of-range id into wrong-but-plausible attention output.
    This check raises instead: every *live* entry (logical page i with
    ``i * page_size < lengths[b]``) must hold a page id in [0, P-1].
    Entries past the live length may point anywhere — they are masked.

    Host-side by construction (``np.asarray`` on a tracer raises), so it
    runs in tests and interpret-mode harnesses, never inside a jitted
    serving step — pass ``debug_validate=True`` to the public entry
    points to enable it.
    """
    bt = np.asarray(block_table)
    ln = np.asarray(lengths)
    n = bt.shape[1]
    live = np.arange(n)[None, :] * page_size < ln[:, None]
    bad = live & ((bt < 0) | (bt >= num_pages))
    if bad.any():
        rows, cols = np.nonzero(bad)
        culprits = [(int(r), int(c), int(bt[r, c]))
                    for r, c in zip(rows[:8], cols[:8])]
        raise ValueError(
            f"block table references out-of-range page ids (pool has "
            f"{num_pages} pages): (row, logical_page, page_id) = "
            f"{culprits}" + (" ..." if len(rows) > 8 else ""))


def _paged_decode_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, *rest,
                         scale: float, page_size: int, n_pages: int,
                         quantized: bool):
    if quantized:
        ks_ref, vs_ref, o_ref, m_scr, l_scr, acc_scr = rest
    else:
        o_ref, m_scr, l_scr, acc_scr = rest
    b = pl.program_id(0)
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale            # (G, hd)
    k = k_ref[0, :, 0, :].astype(jnp.float32)              # (ps, hd)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    if quantized:
        # per-(slot, kv-head) fp32 scales: (1, ps, 1) blocks -> (ps, 1)
        k = k * ks_ref[0]
        v = v * vs_ref[0]
    length = len_ref[b]
    # token j of logical page i sits at absolute position i*ps + j; only
    # positions below the row's live length attend. (>=2D iota for TPU.)
    pos = i * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, page_size), 1)
    valid = pos < length                                   # (1, ps)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (G, ps)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc = acc_scr[...] * alpha + jax.lax.dot(p, v)
    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(i == n_pages - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-20)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _paged_decode_call(q, k_pages, v_pages, block_table, lengths,
                       k_scale, v_scale, *, interpret: bool):
    B, _, H, hd = q.shape
    P, ps, Hkv, _ = k_pages.shape
    n_pages = block_table.shape[1]
    G = H // Hkv
    scale = hd ** -0.5
    quantized = k_scale is not None
    qg = q[:, 0].reshape(B, Hkv, G, hd)
    bt = jnp.clip(block_table, 0, P - 1).astype(jnp.int32)
    ln = lengths.astype(jnp.int32)

    kernel = functools.partial(_paged_decode_kernel, scale=scale,
                               page_size=ps, n_pages=n_pages,
                               quantized=quantized)
    page_spec = pl.BlockSpec((1, ps, 1, hd),
                             lambda b, h, i, bt, ln: (bt[b, i], 0, h, 0))
    in_specs = [
        pl.BlockSpec((1, 1, G, hd), lambda b, h, i, bt, ln: (b, h, 0, 0)),
        page_spec,
        page_spec,
    ]
    operands = [bt, ln, qg, k_pages, v_pages]
    if quantized:
        # scale blocks ride the same block-table index map as their page
        scale_spec = pl.BlockSpec(
            (1, ps, 1), lambda b, h, i, bt, ln: (bt[b, i], 0, h))
        in_specs += [scale_spec, scale_spec]
        operands += [k_scale, v_scale]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                 # block table + lengths
        grid=(B, Hkv, n_pages),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, G, hd),
                               lambda b, h, i, bt, ln: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, hd), q.dtype),
        interpret=interpret,
    )(*operands)
    return out.reshape(B, 1, H, hd)


def paged_decode_attention(q, k_pages, v_pages, block_table, lengths, *,
                           k_scale=None, v_scale=None,
                           interpret: bool = False,
                           debug_validate: bool = False):
    """q: (B, 1, H, hd); k_pages/v_pages: (P, page_size, Hkv, hd);
    block_table: (B, n_pages) int32 page ids per row (entries past the
    live length may point anywhere valid — they are masked); lengths:
    (B,) int32 live token count per row (>= 1).

    ``k_scale``/``v_scale``: optional (P, page_size, Hkv) float32
    per-row absmax scales for quantized (int8/fp8) pools — pass both or
    neither; dequantization happens inside the kernel.

    ``debug_validate``: host-side assert that every live block-table
    entry is in range (see ``validate_block_table``) instead of the
    silent clip — concrete (non-traced) inputs only.

    Returns (B, 1, H, hd).
    """
    assert (k_scale is None) == (v_scale is None)
    if debug_validate:
        validate_block_table(block_table, lengths, k_pages.shape[0],
                             k_pages.shape[1])
    return _paged_decode_call(q, k_pages, v_pages, block_table, lengths,
                              k_scale, v_scale, interpret=interpret)
