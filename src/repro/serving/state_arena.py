"""Fixed-stride slot-state arena: the recurrent-family sibling of PagePool.

Recurrent and hybrid architectures carry O(1)-per-slot decode state
(SSD state + conv tails, RG-LRU h + conv, windowed KV rings) instead of
O(context) pageable KV. The serving engine still wants the PagePool
disciplines for the *prompt* copies of that state — a bounded number of
prefilled-but-not-yet-admitted rows, refcounted holds, exact
conservation at teardown, and telemetry — so this arena manages integer
row ids of a fixed-size device-side state buffer exactly the way
PagePool manages page ids of the KV pools: per-shard LIFO free lists,
refcounts, fail-fast errors on double-free/over-alloc, and a ``check()``
conservation audit.

The arena itself is host-side bookkeeping only. The device buffer it
indexes is a ``model.make_cache(num_rows, ...)`` pytree owned by the
engine; a "row" is index ``r`` along every leaf's batch axis.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np


class StateArenaError(RuntimeError):
    """Misuse of the arena: double free, freeing an unallocated row,
    over-allocation beyond a shard's capacity."""


class StateArena:
    """Refcounted fixed-stride row allocator with shard-local free lists."""

    def __init__(self, num_rows: int, num_shards: int = 1):
        if num_rows <= 0 or num_shards <= 0 or num_rows % num_shards:
            raise ValueError(
                f"num_rows={num_rows} must be a positive multiple of "
                f"num_shards={num_shards}")
        self.num_rows = num_rows
        self.num_shards = num_shards
        self.rows_per_shard = num_rows // num_shards
        # LIFO free lists (pop/append at the tail) keep recently-freed
        # rows hot, mirroring PagePool.
        self._free: List[List[int]] = [
            list(range(s * self.rows_per_shard,
                       (s + 1) * self.rows_per_shard))[::-1]
            for s in range(num_shards)]
        self._ref = np.zeros(num_rows, np.int64)
        self.alloc_count = 0
        self.free_count = 0
        self.max_in_use = 0
        self.sizing_stalls = 0   # times the engine deferred prefill on 0 free

    # -- queries ------------------------------------------------------------
    @property
    def free_rows(self) -> int:
        return sum(len(f) for f in self._free)

    def free_rows_in(self, shard: int) -> int:
        return len(self._free[shard])

    @property
    def in_use(self) -> int:
        return self.num_rows - self.free_rows

    def shard_of(self, row: int) -> int:
        return row // self.rows_per_shard

    def best_shard(self) -> int:
        """The shard with the most free rows (load-balancing default)."""
        return int(max(range(self.num_shards),
                       key=lambda s: len(self._free[s])))

    # -- alloc/free ---------------------------------------------------------
    def alloc(self, n: int, shard: int = 0) -> List[int]:
        if n < 0:
            raise ValueError(f"alloc({n})")
        if len(self._free[shard]) < n:
            raise StateArenaError(
                f"shard {shard} has {len(self._free[shard])} free state "
                f"rows, need {n} (arena: {self.num_rows} rows over "
                f"{self.num_shards} shards)")
        rows = [self._free[shard].pop() for _ in range(n)]
        for r in rows:
            self._ref[r] = 1
        self.alloc_count += n
        self.max_in_use = max(self.max_in_use, self.in_use)
        return rows

    def share(self, rows: List[int]) -> None:
        """Add a reference to already-held rows."""
        for r in rows:
            if self._ref[r] <= 0:
                raise StateArenaError(f"share of free state row {r}")
            self._ref[r] += 1

    def free(self, rows: List[int]) -> None:
        """Drop one reference per row; rows hitting zero return to their
        shard's free list."""
        for r in rows:
            if not (0 <= r < self.num_rows):
                raise StateArenaError(f"free of out-of-range row {r}")
            if self._ref[r] <= 0:
                raise StateArenaError(f"double free of state row {r}")
            self._ref[r] -= 1
            if self._ref[r] == 0:
                self._free[self.shard_of(r)].append(r)
                self.free_count += 1

    # -- invariants / telemetry ---------------------------------------------
    def check(self) -> None:
        """Conservation audit: every row is exactly once free or held,
        free lists are duplicate-free and shard-local."""
        seen = set()
        for s, fl in enumerate(self._free):
            for r in fl:
                if r in seen:
                    raise StateArenaError(f"row {r} on a free list twice")
                seen.add(r)
                if self.shard_of(r) != s:
                    raise StateArenaError(
                        f"row {r} (shard {self.shard_of(r)}) on shard "
                        f"{s}'s free list")
                if self._ref[r] != 0:
                    raise StateArenaError(
                        f"free-listed row {r} has refcount {self._ref[r]}")
        held = int((self._ref > 0).sum())
        if held + len(seen) != self.num_rows:
            raise StateArenaError(
                f"conservation violated: {held} held + {len(seen)} free "
                f"!= {self.num_rows} rows")

    def stats(self) -> Dict[str, object]:
        return {
            "num_rows": self.num_rows,
            "num_shards": self.num_shards,
            "free_rows": self.free_rows,
            "in_use": self.in_use,
            "max_in_use": self.max_in_use,
            "alloc_count": self.alloc_count,
            "free_count": self.free_count,
            "sizing_stalls": self.sizing_stalls,
            "free_per_shard": [len(f) for f in self._free],
        }

    def reset_stats(self) -> None:
        """Zero the counters (same ownership contract as
        ``PagePool.reset_stats``): occupancy is state, not a counter —
        ``max_in_use`` restarts from the current occupancy."""
        self.alloc_count = 0
        self.free_count = 0
        self.sizing_stalls = 0
        self.max_in_use = self.in_use
