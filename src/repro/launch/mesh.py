"""Production mesh definitions (TPU v5e target).

Defined as FUNCTIONS so importing this module never touches jax device
state — the dry-run must set XLA_FLAGS before the first jax call.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5 makes mesh axis types explicit; 0.4.x is Auto-only.
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - version-dependent
    AxisType = None


def _make_mesh(shape, axes):
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = 256 chips, ("data", "model").
    Multi-pod:  (2, 16, 16) = 512 chips, ("pod", "data", "model")."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_local_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU-device tests (requires forced host devices)."""
    return _make_mesh(shape, axes)
