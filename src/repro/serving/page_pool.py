"""Host-side KV page-pool allocator for the paged serving path.

The device side holds a single shared pool of KV pages per attention
layer (``models.attention.make_paged_kv_cache``); this class owns the
*ids*: which pages are free, and how many holders reference each live
page. Reference counting is what makes candidate prefill cheap — a
request's R candidates `share()` the prompt's full pages and only copy
the partially-filled tail page (copy-on-write at the first diverging
token), so prompt KV is resident once per request, not once per
candidate.

**Sharded pools** (``num_shards > 1``, mesh-parallel serving): the page
id space is split into ``num_shards`` contiguous ranges, one per data
shard of the device mesh — the device-side pool arrays are sharded on
the page axis with the same boundaries, so a slot that only references
its own shard's pages keeps every gather/scatter shard-local. Each
shard has its OWN free list, frontier staging, and quarantine page;
``alloc``/``stage_frontier`` take the target shard, while ``free``/
``share`` route by page id. Capacity is shard-local by construction: a
full shard cannot borrow pages from another (its slots could not
address them locally), which is exactly the accounting the serving
scheduler's admission control mirrors.

The optional **cross-request prefix cache** (``prefix_cache=True``)
generalizes that sharing across requests and across time: page-aligned
prompt prefixes are content-hashed into a chain (page i's key commits to
pages 0..i's tokens, radix-tree style), and the cache itself holds one
refcount on each registered page so finished requests' prompt KV stays
resident. A later request whose prompt starts with the same bytes
shares those pages CoW — its prefill skips them entirely. Cached-only
pages (refcount 1, held by nobody but the cache) are *evictable*:
``alloc`` reclaims them LRU-leaf-first under pool pressure, so the
cache can never starve live traffic. Victim selection is a min-tick
heap with lazy deletion (O(log n) per eviction), not a scan.

The first ``reserved`` pages of every shard are quarantine pages: idle
slots' block tables point at their shard's quarantine page and their
dead writes land there. They are never allocated and never freed (for
the historical single-shard pool this is page 0).

All methods raise on misuse (double free, free of an unallocated page,
over-allocation, cross-shard alloc) rather than corrupting the table —
the serving tests lean on these invariants.
"""
from __future__ import annotations

import hashlib
import heapq
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np


class PagePoolError(RuntimeError):
    pass


def prefix_page_keys(tokens, page_size: int) -> List[str]:
    """Content-hash chain over the page-aligned prefix of ``tokens``:
    key[i] = H(key[i-1] || tokens[i*ps:(i+1)*ps]), so equal keys imply
    equal prompt bytes for the whole prefix up to and including page i.
    Only *full* pages get keys (the partial tail page is per-candidate
    CoW, never shared)."""
    toks = np.ascontiguousarray(np.asarray(tokens, np.int64))
    keys: List[str] = []
    prev = b""
    for i in range(len(toks) // page_size):
        d = hashlib.sha256(
            prev + toks[i * page_size:(i + 1) * page_size].tobytes()).digest()
        keys.append(d.hex())
        prev = d
    return keys


class _Node:
    __slots__ = ("page", "parent", "children", "tick")

    def __init__(self, page: int, parent: Optional[str], tick: int):
        self.page = page
        self.parent = parent
        self.children = 0
        self.tick = tick


class PrefixCache:
    """Content-hash chain -> resident KV page map (see module docstring).

    The cache holds exactly one pool refcount per registered page; the
    pool stays the single source of truth for page liveness. Invariants
    (checked by ``PagePool.check``): every cached page has refcount >= 1,
    and every node's parent is cached (chains are prefix-closed, which
    LRU *leaf-first* eviction preserves)."""

    def __init__(self, pool: "PagePool"):
        self.pool = pool
        self._nodes: Dict[str, _Node] = {}
        self._tick = 0
        # Min-tick victim heaps: (tick, key) entries with lazy deletion.
        # Every LRU touch pushes a fresh entry; ``evict`` pops and
        # discards entries whose tick no longer matches the node (stale)
        # or whose node is gone. This replaces the O(nodes) leaf scan —
        # each eviction is O(log heap) amortized, which matters once
        # caches grow past a few thousand pages. Sharded pools keep one
        # heap PER SHARD alongside the global one (entries duplicated,
        # lazy deletion resolves both) so shard-filtered eviction stays
        # logarithmic instead of draining-and-restashing foreign shards'
        # entries on every pressured alloc.
        self._heap: List[Tuple[int, str]] = []
        self._heap_sh: List[List[Tuple[int, str]]] = \
            [[] for _ in range(pool.num_shards)] if pool.num_shards > 1 \
            else []
        self._evictable_memo = None
        self.probes = 0        # lookup calls
        self.hits = 0          # pages reused across requests
        self.misses = 0        # lookups that fell short of a full hit
        self.hit_tokens = 0    # prefill tokens skipped
        self.insertions = 0
        self.evictions = 0

    @property
    def cached_pages(self) -> int:
        return len(self._nodes)

    def _push(self, tick: int, key: str, page: int):
        heapq.heappush(self._heap, (tick, key))
        if self._heap_sh:
            heapq.heappush(self._heap_sh[self.pool.shard_of(page)],
                           (tick, key))
        # Lazy deletion leaves one stale tuple per touch; without pool
        # pressure evict() never pops them, so a long-running server
        # would grow the heaps with total probes, not cached pages.
        # Rebuild from live nodes once stale entries dominate — O(nodes)
        # amortized over >= 3x that many pushes.
        if len(self._heap) > 64 + 4 * len(self._nodes):
            self._compact()

    def _compact(self):
        live = [(node.tick, k) for k, node in self._nodes.items()]
        self._heap = list(live)
        heapq.heapify(self._heap)
        if self._heap_sh:
            for s in range(len(self._heap_sh)):
                h = [(t, k) for t, k in live
                     if self.pool.shard_of(self._nodes[k].page) == s]
                heapq.heapify(h)
                self._heap_sh[s] = h

    def _touch(self, key: str, node: _Node):
        node.tick = self._tick
        self._push(self._tick, key, node.page)

    def match_and_hold(self, keys: Sequence[str]) -> List[int]:
        """Pages of the longest cached prefix of ``keys``, with one
        holder added per page (the caller's request hold) and the chain
        LRU-touched. Empty list on a complete miss."""
        self._tick += 1
        self.probes += 1
        pages: List[int] = []
        for k in keys:
            node = self._nodes.get(k)
            if node is None:
                break
            pages.append(node.page)
        if len(pages) < len(keys):
            self.misses += 1
        if not pages:
            return []
        self.pool.share(pages)
        for k in keys[:len(pages)]:
            self._touch(k, self._nodes[k])
        self.hits += len(pages)
        self.hit_tokens += len(pages) * self.pool.page_size
        return pages

    def insert(self, keys: Sequence[str], pages: Sequence[int]):
        """Register ``pages`` under ``keys`` (chain order, equal length).
        New nodes take one cache hold; already-cached keys keep their
        existing page (two requests that raced the same prefix keep the
        first writer's pages — the loser's stay private to it)."""
        assert len(keys) == len(pages), (len(keys), len(pages))
        self._tick += 1
        parent: Optional[str] = None
        for k, page in zip(keys, pages):
            node = self._nodes.get(k)
            if node is None:
                self.pool.share([page])
                node = _Node(int(page), parent, self._tick)
                self._nodes[k] = node
                self._push(self._tick, k, node.page)
                if parent is not None:
                    self._nodes[parent].children += 1
                self.insertions += 1
            else:
                self._touch(k, node)
            parent = k
        # registration makes these pages cache-resident: under a byte
        # budget the oldest cached chains pay for the newest
        self.pool.enforce_byte_budget()

    # -- eviction -------------------------------------------------------
    def _reclaimable_blocked(self) -> set:
        """Keys that cannot be evicted: pages some request still holds,
        plus all their ancestors (evicting an ancestor would break the
        chain under a live descendant)."""
        blocked: set = set()
        for k, node in self._nodes.items():
            if self.pool.refcount(node.page) > 1:
                p: Optional[str] = k
                while p is not None and p not in blocked:
                    blocked.add(p)
                    p = self._nodes[p].parent
        return blocked

    def evictable_pages(self, shard: Optional[int] = None) -> int:
        """Pages the cache could hand back to the pool right now
        (optionally: only pages living in ``shard``'s id range).
        Memoized on the pool's mutation counter — the admission path
        calls this per decision, and the blocked-set walk is O(nodes)."""
        key = (self.pool.mutations, self._tick, len(self._nodes))
        if self._evictable_memo is None or self._evictable_memo[0] != key:
            blocked = self._reclaimable_blocked()
            per_shard = np.zeros(self.pool.num_shards, np.int64)
            for k, node in self._nodes.items():
                if k not in blocked:
                    per_shard[self.pool.shard_of(node.page)] += 1
            self._evictable_memo = (key, per_shard)
        per_shard = self._evictable_memo[1]
        return int(per_shard.sum() if shard is None else per_shard[shard])

    def _evict_node(self, key: str, node: _Node):
        self._nodes.pop(key)
        if node.parent is not None and node.parent in self._nodes:
            parent = self._nodes[node.parent]
            parent.children -= 1
            if parent.children == 0:
                # the parent just became a leaf: it is the next-oldest
                # victim of this chain (same LRU tick — chains are
                # touched root-to-leaf together), so make sure a live
                # heap entry exists even if its old one was popped
                self._push(parent.tick, node.parent, parent.page)
        self.pool.free([node.page])
        self.evictions += 1

    def evict(self, n: int, shard: Optional[int] = None) -> int:
        """Free up to ``n`` cached pages, least-recently-used leaves
        first (a leaf eviction exposes its parent as the next leaf —
        chains shrink from the deep end, staying prefix-closed). With
        ``shard``, only pages in that shard's id range are considered —
        served from that shard's own heap, so one loaded shard's
        pressure never pays to sift through its siblings' entries."""
        heap = self._heap_sh[shard] if shard is not None and self._heap_sh \
            else self._heap
        freed = 0
        stash: List[Tuple[int, str]] = []
        # evicting frees pages, and pool.free hooks byte-budget
        # enforcement — which would re-enter THIS heap walk. Hold the
        # pool's enforcement latch for the duration.
        prev, self.pool._enforcing = self.pool._enforcing, True
        try:
            while freed < n and heap:
                tick, key = heapq.heappop(heap)
                node = self._nodes.get(key)
                if node is None or node.tick != tick:
                    continue                   # stale lazy-deletion entry
                if node.children > 0 or self.pool.refcount(node.page) > 1 \
                        or (shard is not None and
                            self.pool.shard_of(node.page) != shard):
                    stash.append((tick, key))  # alive but not evictable now
                    continue
                self._evict_node(key, node)
                freed += 1
        finally:
            self.pool._enforcing = prev
        for entry in stash:
            heapq.heappush(heap, entry)
        return freed

    def drop_all(self):
        """Release every cache hold (tests / shutdown). Pages still held
        by live requests survive with their remaining holders."""
        prev, self.pool._enforcing = self.pool._enforcing, True
        try:
            for node in self._nodes.values():
                self.pool.free([node.page])
        finally:
            self.pool._enforcing = prev
        self._nodes.clear()
        self._heap.clear()
        for h in self._heap_sh:
            h.clear()

    def reset_stats(self) -> None:
        """Zero hit/miss telemetry only — the cached chains themselves
        (and their refcounts) are engine state, not counters, and stay
        resident so later cells still benefit from earlier prefills."""
        self.probes = self.hits = self.misses = 0
        self.hit_tokens = self.insertions = self.evictions = 0

    def stats(self) -> dict:
        return {
            "probes": self.probes, "hits": self.hits,
            "misses": self.misses, "hit_tokens": self.hit_tokens,
            "cached_pages": self.cached_pages,
            "insertions": self.insertions, "evictions": self.evictions,
        }


class PagePool:
    def __init__(self, num_pages: int, page_size: int, *, reserved: int = 1,
                 prefix_cache: bool = False, num_shards: int = 1,
                 kv_byte_budget: int = 0):
        if num_shards < 1:
            raise PagePoolError(f"num_shards={num_shards}")
        if num_pages % num_shards:
            raise PagePoolError(
                f"pool of {num_pages} pages not divisible into "
                f"{num_shards} shards")
        self.pages_per_shard = num_pages // num_shards
        if self.pages_per_shard <= reserved:
            raise PagePoolError(
                f"pool of {num_pages} pages has no allocatable pages "
                f"(reserved={reserved} per shard x {num_shards} shards)")
        self.num_pages = num_pages
        self.page_size = page_size
        self.reserved = reserved
        self.num_shards = num_shards
        # Per-shard LIFO free lists: recently freed pages are re-used
        # first (their contents are hot in cache and get overwritten
        # anyway). Initial pop order is ascending from the shard's first
        # allocatable page — identical to the historical single-shard
        # pool for num_shards == 1.
        self._free_sh: List[List[int]] = [
            list(range(lo + self.pages_per_shard - 1, lo + reserved - 1, -1))
            for lo in range(0, num_pages, self.pages_per_shard)]
        self._refs = np.zeros(num_pages, np.int64)
        self.max_in_use = 0
        # bumped on every refcount mutation (memo key for the prefix
        # cache's evictable-page computation)
        self.mutations = 0
        # frontier accounting (macro-step serving): pages handed out ahead
        # of the device loop and how many came back unconsumed.
        self.frontier_staged = 0
        self.frontier_returned = 0
        # largest single staging request: with speculative decoding a
        # slot's per-launch budget grows to macro_steps * spec_k tokens,
        # so this is the number to watch when sizing the pool
        self.frontier_peak_stage = 0
        self._frontier_staged_sh = np.zeros(num_shards, np.int64)
        self._frontier_returned_sh = np.zeros(num_shards, np.int64)
        # cross-request prefix cache (None when disabled)
        self.prefix: Optional[PrefixCache] = \
            PrefixCache(self) if prefix_cache else None
        # Byte-budgeted residency: once the engine reports bytes_per_page
        # (quantized values + scale tensors — the kv_stats() definition),
        # every refcount mutation that could leave resident KV above the
        # ceiling drains cached-only prefix pages through the eviction
        # heaps until it fits or nothing evictable remains. Live holds
        # are never evicted, so under heavy live traffic residency may
        # exceed the budget — the enforced invariant is
        # ``resident <= budget OR evictable() == 0``.
        self.kv_byte_budget = int(kv_byte_budget)
        self.bytes_per_page = 0          # set via set_bytes_per_page
        self.budget_evictions = 0
        self._enforcing = False

    # ------------------------------------------------------------------
    @property
    def in_use(self) -> int:
        """Pages currently referenced by at least one holder."""
        return int(np.count_nonzero(self._refs))

    @property
    def free_pages(self) -> int:
        return sum(len(f) for f in self._free_sh)

    def free_pages_in(self, shard: int) -> int:
        return len(self._free_sh[shard])

    def shard_of(self, page: int) -> int:
        return int(page) // self.pages_per_shard

    def quarantine_page(self, shard: int = 0) -> int:
        """The reserved page idle slots of ``shard`` point their block
        tables at (their dead writes land there, shard-locally)."""
        return shard * self.pages_per_shard

    def _is_reserved(self, page: int) -> bool:
        return page % self.pages_per_shard < self.reserved

    def refcount(self, page: int) -> int:
        return int(self._refs[page])

    def live_tokens_capacity(self) -> int:
        return self.in_use * self.page_size

    # ------------------------------------------------------------------
    # Byte-budgeted residency (prefix-cache ceiling)
    # ------------------------------------------------------------------
    def set_bytes_per_page(self, bpp: int) -> None:
        """Engine callback once the device cache exists: true resident
        bytes per page (quantized values + scale tensors, summed over
        every paged layer — the ``kv_stats()`` definition). Activates
        ``kv_byte_budget`` enforcement and applies it immediately."""
        self.bytes_per_page = int(bpp)
        self.enforce_byte_budget()

    @property
    def resident_kv_bytes(self) -> int:
        """Bytes held by in-use pages (0 until bytes_per_page is set)."""
        return self.in_use * self.bytes_per_page

    def over_budget_pages(self) -> int:
        """Pages that must leave residency to meet the byte budget."""
        if not (self.kv_byte_budget and self.bytes_per_page):
            return 0
        over = self.resident_kv_bytes - self.kv_byte_budget
        return -(-over // self.bytes_per_page) if over > 0 else 0

    def enforce_byte_budget(self) -> int:
        """Evict cached-only prefix pages (LRU-leaf-first, through the
        lazy-deletion heaps) until resident KV bytes fall under
        ``kv_byte_budget``, or nothing cached remains evictable. Called
        after every alloc/free/insert; re-entrant calls from the
        eviction's own frees are no-ops. Returns pages evicted."""
        if self._enforcing or self.prefix is None:
            return 0
        n = self.over_budget_pages()
        if n == 0:
            return 0
        self._enforcing = True
        try:
            freed = self.prefix.evict(n)
        finally:
            self._enforcing = False
        self.budget_evictions += freed
        return freed

    # ------------------------------------------------------------------
    def evictable(self, shard: Optional[int] = None) -> int:
        """Pages reclaimable from the prefix cache under pool pressure
        (admission-control headroom beyond the free list), optionally
        restricted to one shard's id range."""
        if self.prefix is None:
            return 0
        return self.prefix.evictable_pages(shard)

    def ensure_free(self, n: int, shard: Optional[int] = None):
        """Evict cached-only pages until the free list holds at least
        ``n`` pages (of ``shard``, when given). The serving engine calls
        this after every admission so reservations are always backed by
        *actually free* pages — evictable pages counted at admission
        time could otherwise be re-pinned by a later prefix-cache hit,
        turning reservation-backed frontier staging into a mid-decode
        failure."""
        have = self.free_pages if shard is None else self.free_pages_in(shard)
        if n <= have:
            return
        if self.prefix is not None:
            self.prefix.evict(n - have, shard)
            have = self.free_pages if shard is None \
                else self.free_pages_in(shard)
        if n > have:
            raise PagePoolError(
                f"cannot secure {n} free pages ({have} free, "
                f"{self.evictable(shard)} evictable of {self.num_pages}"
                f"{'' if shard is None else f', shard {shard}'})")

    def alloc(self, n: int = 1, shard: int = 0) -> List[int]:
        """Take ``n`` fresh pages (refcount 1 each) from ``shard``'s
        range. Under pressure, cached-only prefix pages of that shard
        are evicted LRU-first to cover the request before giving up."""
        if n < 0:
            raise PagePoolError(f"alloc({n})")
        if not 0 <= shard < self.num_shards:
            raise PagePoolError(f"alloc on unknown shard {shard}")
        free = self._free_sh[shard]
        if n > len(free) and self.prefix is not None:
            self.prefix.evict(n - len(free),
                              shard if self.num_shards > 1 else None)
        if n > len(free):
            raise PagePoolError(
                f"out of KV pages: need {n}, have {len(free)} free of "
                f"{self.pages_per_shard} in shard {shard} "
                f"(pool in use: {self.in_use}/{self.num_pages}) — raise "
                f"num_pages or reduce slots/cache_len")
        pages = [free.pop() for _ in range(n)]
        for p in pages:
            self._refs[p] = 1
        self.mutations += 1
        self.max_in_use = max(self.max_in_use, self.in_use)
        self.enforce_byte_budget()
        return pages

    def share(self, pages: Iterable[int]):
        """Add one holder to each page (prompt pages shared by a new
        candidate)."""
        for p in pages:
            if self._refs[p] <= 0:
                raise PagePoolError(f"share of unallocated page {p}")
            self._refs[p] += 1
        self.mutations += 1

    def free(self, pages: Iterable[int]):
        """Drop one holder from each page; pages reaching zero return to
        their OWN shard's free list (this is what lets an early-stopped
        easy request immediately fund a hard one — on the same shard)."""
        for p in pages:
            if self._is_reserved(p):
                raise PagePoolError(f"free of reserved page {p}")
            if self._refs[p] <= 0:
                raise PagePoolError(f"double free of page {p}")
            self._refs[p] -= 1
            if self._refs[p] == 0:
                self._free_sh[self.shard_of(p)].append(p)
        self.mutations += 1
        # a dropped request hold may have just unblocked cached pages
        # (or their ancestors) the budget was waiting to reclaim
        self.enforce_byte_budget()

    # ------------------------------------------------------------------
    # Page frontiers (macro-step decode)
    # ------------------------------------------------------------------
    def stage_frontier(self, n: int, shard: int = 0) -> List[int]:
        """Reserve ``n`` pages of ``shard`` for a slot's decode
        *frontier*: the pages the device-resident macro-step loop may
        advance into without host intervention. Staged pages are
        ordinary allocations (refcount 1) — the caller writes their ids
        into the (B, F) frontier array before launch and, after the
        macro-step returns, keeps the consumed prefix and hands the rest
        back via ``return_frontier``. Staging from the slot's own shard
        keeps the device-side block-table advance shard-local."""
        pages = self.alloc(n, shard)
        self.frontier_staged += n
        self.frontier_peak_stage = max(self.frontier_peak_stage, n)
        self._frontier_staged_sh[shard] += n
        return pages

    def return_frontier(self, pages: Iterable[int]):
        """Return staged-but-unconsumed frontier pages (slot finished or
        the macro-step early-exited before crossing into them)."""
        pages = list(pages)
        self.free(pages)
        self.frontier_returned += len(pages)
        for p in pages:
            self._frontier_returned_sh[self.shard_of(p)] += 1

    # ------------------------------------------------------------------
    def check(self):
        """Conservation invariant: every non-reserved page is either on
        its own shard's free list (ref 0) or held (ref > 0), never
        both/neither; no free list holds another shard's pages."""
        free_all = set()
        for s, fl in enumerate(self._free_sh):
            fs = set(fl)
            if len(fs) != len(fl):
                raise PagePoolError(f"shard {s} free list has duplicates")
            for p in fs:
                if self.shard_of(p) != s:
                    raise PagePoolError(
                        f"page {p} on shard {s} free list but belongs to "
                        f"shard {self.shard_of(p)}")
                if self._is_reserved(p):
                    raise PagePoolError(f"reserved page {p} on free list")
            free_all |= fs
        for p in range(self.num_pages):
            if self._is_reserved(p):
                continue
            held = self._refs[p] > 0
            if held == (p in free_all):
                raise PagePoolError(
                    f"page {p} violates conservation (refs={self._refs[p]}, "
                    f"on_free_list={p in free_all})")
        if self.prefix is not None:
            for k, node in self.prefix._nodes.items():
                if self._refs[node.page] <= 0:
                    raise PagePoolError(
                        f"prefix cache maps {k[:8]} to dead page {node.page}")
                if node.parent is not None and \
                        node.parent not in self.prefix._nodes:
                    raise PagePoolError(
                        f"prefix chain broken at {k[:8]} (parent evicted)")

    def reset_stats(self) -> None:
        """Zero frontier/high-water telemetry for engine reuse across
        bench cells. Allocation state (refcounts, free lists, prefix
        chains) is untouched; ``max_in_use`` restarts from the CURRENT
        occupancy so resident prefix-cache pages stay visible."""
        self.frontier_staged = self.frontier_returned = 0
        self.frontier_peak_stage = 0
        self._frontier_staged_sh[:] = 0
        self._frontier_returned_sh[:] = 0
        self.max_in_use = self.in_use
        self.budget_evictions = 0
        if self.prefix is not None:
            self.prefix.reset_stats()

    def stats(self) -> dict:
        s = {
            "num_pages": self.num_pages,
            "page_size": self.page_size,
            "in_use": self.in_use,
            "free": self.free_pages,
            "max_in_use": self.max_in_use,
            "frontier_staged": self.frontier_staged,
            "frontier_returned": self.frontier_returned,
            "frontier_peak_stage": self.frontier_peak_stage,
        }
        if self.kv_byte_budget:
            s["kv_byte_budget"] = self.kv_byte_budget
            s["budget_evictions"] = self.budget_evictions
            if self.bytes_per_page:
                s["resident_kv_bytes"] = self.resident_kv_bytes
        if self.num_shards > 1:
            s["num_shards"] = self.num_shards
            s["shards"] = [{
                "free": self.free_pages_in(i),
                "frontier_staged": int(self._frontier_staged_sh[i]),
                "frontier_returned": int(self._frontier_returned_sh[i]),
            } for i in range(self.num_shards)]
        if self.prefix is not None:
            s["prefix_cache"] = self.prefix.stats()
        return s
