"""Paged serving path: equivalence with the contiguous engine, pool
accounting proportional to live tokens, and page lifecycle under slot
recycling (the acceptance bar for the paged KV subsystem).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import _mk_engine as _mk_base, _submit
from repro.config import PagedKVConfig, SamplingConfig
from repro.configs import get_config
from repro.models import build_model
from repro.serving import Request, ServeEngine

PAGE = PagedKVConfig(page_size=16)


def _mk_engine(model, params, **kw):
    kw.setdefault("n_candidates", 4)
    return _mk_base(model, params, **kw)


@pytest.mark.parametrize("mode", ["camd", "best_of_n"])
def test_paged_byte_identical_to_contiguous(small_model, mode):
    """The paged XLA path gathers pages into the same contiguous view the
    dense ring holds, so under a fixed seed the two engines must emit
    byte-identical tokens and identical accounting."""
    cfg, model, params = small_model
    res = {}
    for impl in ("xla", "paged"):
        eng = _mk_engine(model, params, mode=mode, impl=impl, paged_kv=PAGE)
        _submit(eng, cfg, 4)
        res[impl] = sorted(eng.run(), key=lambda r: r.uid)
        if impl == "paged":
            eng.pool.check()
            assert eng.pool.in_use == 0          # everything returned
    for a, b in zip(res["xla"], res["paged"]):
        np.testing.assert_array_equal(a.tokens, b.tokens)
        assert a.tokens_spent == b.tokens_spent
        assert a.rounds == b.rounds
        assert a.n_candidates == b.n_candidates


def test_resident_kv_proportional_to_live_tokens(small_model):
    """Pool accounting: a single greedy request must peak at exactly
    ceil((prompt+new)/page_size) pages — resident KV scales with live
    tokens, not with slots × cache_len."""
    cfg, model, params = small_model
    ps = PAGE.page_size
    eng = _mk_engine(model, params, mode="greedy", impl="paged",
                     paged_kv=PAGE, slots=6)
    plen, max_new = 6, 8
    _submit(eng, cfg, 1, plen=plen)
    (r,) = eng.run()
    expect_pages = -((plen + max_new) // -ps)    # ceil
    assert eng.pool.max_in_use == expect_pages
    stats = eng.kv_stats()
    assert stats["peak_kv_bytes"] == expect_pages * stats["bytes_per_page"]
    assert stats["peak_kv_bytes"] < stats["dense_equiv_bytes"]
    assert stats["resident_kv_bytes"] == 0       # drained after run


def test_candidates_share_prompt_pages(small_model):
    """R candidates of one request must hold the full prompt pages once
    (refcounted), copying only the partial tail page — the CoW saving."""
    cfg, model, params = small_model
    ps = PAGE.page_size
    plen = 2 * ps + 3                            # 2 full pages + tail of 3
    eng = _mk_engine(model, params, mode="best_of_n", n_candidates=4,
                     impl="paged", paged_kv=PAGE, cache_len=64,
                     max_new_tokens=4,
                     sampling=SamplingConfig(max_new_tokens=4,
                                             temperature=0.8))
    _submit(eng, cfg, 1, plen=plen)
    eng._schedule()                              # admit without stepping
    info = next(iter(eng._reqs.values()))
    assert len(info["prompt_pages"]) == 2
    n_live = sum(1 for s in range(eng.B) if eng._slot_req[s] >= 0)
    assert n_live == 4
    for p in info["prompt_pages"]:
        assert eng.pool.refcount(p) == 1 + n_live   # request hold + cands
    # 2 shared + one private tail each, nothing else
    assert eng.pool.in_use == 2 + n_live
    eng.run()
    eng.pool.check()
    assert eng.pool.in_use == 0


def test_paged_pool_funds_queued_requests(small_model):
    """A pool far smaller than slots × cache_len still serves a queue of
    requests: freed pages from finished candidates fund the next ones."""
    cfg, model, params = small_model
    ps = PAGE.page_size
    # 6 slots x 4 pages/slot dense-equivalent would be 24 pages + 1; give 13
    eng = _mk_engine(model, params, mode="camd", impl="paged",
                     paged_kv=PagedKVConfig(page_size=ps, num_pages=13))
    _submit(eng, cfg, 6)
    res = eng.run()
    assert len(res) == 6
    eng.pool.check()
    assert eng.pool.in_use == 0
    assert eng.pool.max_in_use <= 12


def test_backpressure_under_prompt_page_holds(small_model):
    """A pending request's prompt-page hold must not crash admission of
    queued requests (regression: pool exhaustion between rounds) — the
    engine queues instead, and everything still completes."""
    cfg, model, params = small_model
    eng = ServeEngine(
        model, params, slots=2, cache_len=128,
        sampling=SamplingConfig(max_new_tokens=8, temperature=0.8),
        mode="best_of_n", n_candidates=4, max_new_tokens=8, eos_id=1,
        impl="paged", paged_kv=PagedKVConfig(page_size=16), seed=0)
    _submit(eng, cfg, 3, plen=64)   # 4 prompt pages pinned per request
    res = eng.run()
    assert sorted(r.uid for r in res) == [0, 1, 2]
    assert all(r.n_candidates == 4 for r in res)
    eng.pool.check()
    assert eng.pool.in_use == 0
    assert eng._reserved == 0


def test_impossible_pool_raises_sizing_error(small_model):
    """A pool that can never fit one candidate fails fast with a sizing
    error instead of spinning or corrupting pages."""
    cfg, model, params = small_model
    eng = _mk_engine(model, params, mode="greedy", impl="paged",
                     paged_kv=PagedKVConfig(page_size=16, num_pages=2),
                     cache_len=64)
    _submit(eng, cfg, 1, plen=20)
    with pytest.raises(RuntimeError, match="cannot admit"):
        eng.run()


def test_paged_pallas_kernel_path_runs(small_model):
    """impl="paged_pallas" (block-table kernel via ops dispatch) completes
    the same workload with plausible outputs."""
    cfg, model, params = small_model
    eng = _mk_engine(model, params, mode="camd", impl="paged_pallas",
                     paged_kv=PAGE)
    _submit(eng, cfg, 3)
    res = eng.run()
    assert len(res) == 3
    for r in res:
        assert np.isfinite(r.best_score)
        assert len(r.tokens) >= 1
    eng.pool.check()
    assert eng.pool.in_use == 0


def test_paged_vlm_evidence(small_model):
    """Evidence tokens extend the prompt span; the paged path must account
    for them identically to the contiguous path."""
    cfg = get_config("internvl2-2b").reduced().with_overrides(dtype="float32")
    model = build_model(cfg, jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    res = {}
    for impl in ("xla", "paged"):
        eng = _mk_engine(model, params, mode="camd", impl=impl,
                         paged_kv=PAGE, slots=4)
        rng = np.random.default_rng(0)
        for i in range(2):
            ev = rng.standard_normal((cfg.num_evidence_tokens,
                                      cfg.evidence_dim)).astype(np.float32)
            eng.submit(Request(uid=i, prompt=rng.integers(
                2, cfg.vocab_size, 6).astype(np.int32), evidence=ev))
        res[impl] = sorted(eng.run(), key=lambda r: r.uid)
        if impl == "paged":
            eng.pool.check()
            assert eng.pool.in_use == 0
    for a, b in zip(res["xla"], res["paged"]):
        np.testing.assert_array_equal(a.tokens, b.tokens)
