"""Plug-and-play CAMD rescoring (the paper's §5.1 deployment mode).

The paper applies CAMD as a wrapper that "requires only the candidate
outputs at decoding checkpoints" — i.e. candidates may come from ANY
decoder (an external engine, beam search, a different model). This module
is that mode: given a prompt and K candidate token sequences, one
teacher-forced forward pass per batch computes every Eq. 7-12 ingredient
(token log-probs, hidden states, token embeddings), scores the
candidates, folds them into a CAMD state, and returns the
coverage-stop / best-candidate / mixture-bias decision.

The cross-modal term uses the fused Pallas ``xmodal_score`` kernel on
TPU (jnp oracle elsewhere).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import CAMDConfig
from repro.core import controller as ctrl
from repro.core import scoring
from repro.models.model import Model


def teacher_forced_stats(model: Model, params, prompt, candidates, mask,
                         evidence=None, *, impl: str = "xla"):
    """One forward over [prompt ++ candidate] per candidate.

    prompt: (Lp,) int32; candidates: (K, Lc) int32 (right-padded);
    mask: (K, Lc) 1=real token. Returns per-candidate
    (token_logprobs (K, Lc), hidden (K, Lc, d), token_embs (K, Lc, d)).
    """
    K, Lc = candidates.shape
    Lp = prompt.shape[0]
    toks = jnp.concatenate(
        [jnp.broadcast_to(prompt[None], (K, Lp)), candidates], axis=1)
    ev = None
    if evidence is not None:
        ev = jnp.broadcast_to(evidence[None], (K,) + evidence.shape)
    logits, hidden, _ = model.forward(params, toks, ev, impl=impl)
    ne = model.cfg.num_evidence_tokens
    offs = ne if (ne and evidence is not None
                  and not model.cfg.is_encoder_decoder) else 0
    # logits at position p predict token p+1: candidate token j (absolute
    # position Lp+j) is predicted by logits at offs+Lp+j-1.
    pred = logits[:, offs + Lp - 1: offs + Lp + Lc - 1]
    logp = jax.nn.log_softmax(pred.astype(jnp.float32), axis=-1)
    token_lp = jnp.take_along_axis(
        logp, candidates[..., None].astype(jnp.int32), axis=-1)[..., 0]
    cand_hidden = hidden[:, offs + Lp: offs + Lp + Lc]
    table = params["embed"]["table"]
    token_embs = jnp.take(table, candidates, axis=0).astype(jnp.float32)
    return token_lp * mask, cand_hidden, token_embs


def rescore_candidates(model: Model, params, cfg: CAMDConfig, prompt,
                       candidates, mask, evidence=None, *,
                       impl: str = "xla") -> Dict[str, jax.Array]:
    """Eq. 7-12 evidence-weighted scores for externally-generated
    candidates. Returns dict with per-candidate terms + total scores."""
    token_lp, hidden, token_embs = teacher_forced_stats(
        model, params, prompt, candidates, mask, evidence, impl=impl)
    s_gen = scoring.generation_confidence(token_lp, mask)
    s_coh = scoring.reasoning_coherence(hidden, mask)
    if evidence is not None and model.cfg.num_evidence_tokens:
        evproj = evidence.astype(jnp.float32)
        if "evidence_proj" in params:
            from repro.models.layers import dense
            evproj = dense(jax.tree.map(lambda x: x.astype(jnp.float32),
                                        params["evidence_proj"]), evproj)
        vis = jnp.broadcast_to(evproj[None], (candidates.shape[0],)
                               + evproj.shape)
        txt = jnp.take(params["embed"]["table"], prompt,
                       axis=0).astype(jnp.float32)
        txt = jnp.broadcast_to(txt[None], (candidates.shape[0],) + txt.shape)
        s_align = scoring.cross_modal_consistency(
            token_embs, mask, vis, txt, impl=impl)
    else:
        s_align = jnp.zeros_like(s_gen)
    total = s_gen + cfg.lambda_g * s_align + cfg.lambda_c * s_coh
    return {"score": total, "s_gen": s_gen, "s_align": s_align,
            "s_coh": s_coh, "hidden_mean": _masked_mean(hidden, mask)}


def _masked_mean(h, mask):
    m = mask.astype(jnp.float32)[..., None]
    return jnp.sum(h.astype(jnp.float32) * m, axis=1) / \
        jnp.maximum(jnp.sum(m, axis=1), 1.0)


def camd_wrap(model: Model, params, cfg: CAMDConfig, prompt, candidates,
              mask, evidence=None, *, state: Optional[ctrl.CAMDState] = None,
              uids=None, impl: str = "xla"
              ) -> Tuple[ctrl.CAMDState, Dict[str, Any]]:
    """One CAMD checkpoint over a round of external candidates.

    Returns (state, decision) where decision carries stop/p_star/best_uid
    and the Eq. 16 mixture bias for the next round.
    """
    K = candidates.shape[0]
    if state is None:
        state = ctrl.init_state(cfg, model.cfg.d_model, model.cfg.vocab_size)
    if uids is None:
        uids = jnp.arange(K, dtype=jnp.int32)
    res = rescore_candidates(model, params, cfg, prompt, candidates, mask,
                             evidence, impl=impl)
    counts = jax.vmap(
        lambda c, m: jnp.zeros(model.cfg.vocab_size).at[c].add(m)
    )(candidates, mask.astype(jnp.float32))
    inp = ctrl.RoundInputs(
        scores=res["score"],
        embs=res["hidden_mean"],
        token_counts=counts,
        lengths=jnp.sum(mask, axis=-1).astype(jnp.int32),
        valid=jnp.any(mask > 0, axis=-1),
        uids=jnp.asarray(uids, jnp.int32))
    state, bias = ctrl.round_update(cfg, state, inp)
    decision = {
        "stop": state.stopped, "p_star": state.p_star,
        "best_uid": state.best_uid, "bias": bias, "scores": res["score"],
        "terms": {k: res[k] for k in ("s_gen", "s_align", "s_coh")},
    }
    return state, decision
