"""Expert-parallel MoE under shard_map with explicit all-to-alls.

The GSPMD capacity-einsum path (``moe_apply``) lets the partitioner
choose the collectives; this module is the manual-choreography
alternative for large expert counts (EXPERIMENTS.md §Perf backlog,
realized): tokens are sharded over the "data" axis, experts are sharded
over the same axis (E_loc = E/D per device), and the dispatch is

    local sort/scatter  →  all_to_all  →  local expert FFN
                        →  all_to_all  →  local gather/combine

so per-token dispatch work is O(k log k) (vs O(E·C) for the one-hot
einsum) and the only cross-device traffic is the two all-to-alls of the
actually-routed activations.

Per-(source-device, expert) capacity C_s bounds the static buffer shapes;
tokens beyond capacity fall back to the residual (standard dropping).
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.config import ModelConfig
from repro.models.layers import mlp
from repro.models.moe import _capacity


def _local_dispatch(x_loc, gate_idx, gate_vals, E: int, C_s: int):
    """Sort/scatter tokens into per-expert send slots (one device).

    x_loc: (T, d); gate_idx/vals: (T, k). Returns
    (send (E, C_s, d), slot (T*k,) flat send-slot per pair or -1).
    """
    T, k = gate_idx.shape
    d = x_loc.shape[-1]
    eid = gate_idx.reshape(-1)
    order = jnp.argsort(eid, stable=True)
    eid_sorted = eid[order]
    counts = jnp.bincount(eid, length=E)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(T * k) - starts[eid_sorted]
    keep = rank < C_s
    slot_sorted = jnp.where(keep, eid_sorted * C_s + rank, -1)
    slot = jnp.zeros((T * k,), jnp.int32).at[order].set(
        slot_sorted.astype(jnp.int32))
    token_of_pair = jnp.arange(T * k) // k
    send = jnp.zeros((E * C_s, d), x_loc.dtype).at[jnp.maximum(slot, 0)].set(
        jnp.where((slot >= 0)[:, None], x_loc[token_of_pair], 0.0))
    return send.reshape(E, C_s, d), slot


def moe_apply_shard_map(params, cfg: ModelConfig, x, mesh, *,
                        data_axis: str = "data",
                        model_axis: str = None,
                        capacity_factor: float = None
                        ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: (T, d) GLOBAL tokens (sharded over data_axis by the caller's
    in_shardings). Expert weights must be sharded over data_axis on dim 0
    (and, when ``model_axis`` is given, over the per-expert hidden dim f —
    tensor parallelism inside each expert, combined with a psum).
    Returns (out (T, d), aux)."""
    e = cfg.moe
    cf = capacity_factor or e.capacity_factor
    E, k = e.num_experts, e.top_k
    D = mesh.shape[data_axis]
    assert E % D == 0, (E, D)
    E_loc = E // D
    T = x.shape[0]
    T_loc = T // D
    C_s = _capacity(T_loc, k, E, cf)

    def local_fn(x_loc, router, w_gate, w_up, w_down, shared):
        # x_loc: (T_loc, d); w_*: (E_loc, ...) local expert shards.
        logits = x_loc.astype(jnp.float32) @ router                 # (T,E)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, gate_idx = jax.lax.top_k(probs, k)
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(-1, keepdims=True), 1e-9)

        send, slot = _local_dispatch(x_loc, gate_idx, gate_vals, E, C_s)
        # (E, C_s, d) -> (D, E_loc, C_s, d): split experts by owner device
        send = send.reshape(D, E_loc, C_s, send.shape[-1])
        # all_to_all over data: dim0 (dest device) <-> source device
        recv = jax.lax.all_to_all(send, data_axis, split_axis=0,
                                  concat_axis=0, tiled=False)
        # recv: (D, E_loc, C_s, d) — rows from every source device for MY
        # local experts. Fold sources into the capacity dim:
        exp_in = recv.transpose(1, 0, 2, 3).reshape(E_loc, D * C_s, -1)

        act = {"swiglu": jax.nn.silu, "gelu": jax.nn.gelu,
               "relu": jax.nn.relu}[cfg.mlp_activation]
        if cfg.mlp_activation == "swiglu":
            h = act(jnp.einsum("ecd,edf->ecf", exp_in, w_gate)) \
                * jnp.einsum("ecd,edf->ecf", exp_in, w_up)
        else:
            h = act(jnp.einsum("ecd,edf->ecf", exp_in, w_gate))
        exp_out = jnp.einsum("ecf,efd->ecd", h, w_down)
        if model_axis is not None:
            # per-expert tensor parallelism: f is sharded — combine partials
            exp_out = jax.lax.psum(exp_out, model_axis)

        # return path: symmetric all_to_all back to the source devices
        back = exp_out.reshape(E_loc, D, C_s, -1).transpose(1, 0, 2, 3)
        mine = jax.lax.all_to_all(back, data_axis, split_axis=0,
                                  concat_axis=0, tiled=False)
        flat_out = mine.reshape(E * C_s, -1)                        # my sends

        y_pair = jnp.where((slot >= 0)[:, None],
                           flat_out[jnp.maximum(slot, 0)], 0.0)
        gates_pair = gate_vals.reshape(-1)
        out = jnp.sum((y_pair * gates_pair[:, None]).reshape(T_loc, k, -1),
                      axis=1).astype(x_loc.dtype)
        if shared is not None:
            out = out + mlp(shared, x_loc, cfg.mlp_activation)

        frac = jnp.mean(jax.nn.one_hot(gate_idx[:, 0], E), axis=0)
        frac = jax.lax.pmean(frac, data_axis)
        meanp = jax.lax.pmean(jnp.mean(probs, axis=0), data_axis)
        drop = jax.lax.pmean(jnp.mean((slot < 0).astype(jnp.float32)),
                             data_axis)
        aux = {"moe_lb_loss": E * jnp.sum(frac * meanp),
               "moe_z_loss": jax.lax.pmean(
                   jnp.mean(jax.nn.logsumexp(logits, -1) ** 2), data_axis),
               "moe_drop_frac": drop}
        return out, aux

    shared = params.get("shared")
    w_up = params.get("w_up")
    m = model_axis
    w_in_spec = P(data_axis, None, m)          # f sharded over model if set
    w_out_spec = P(data_axis, m, None)
    in_specs = (P(data_axis, None), P(), w_in_spec,
                (w_in_spec if w_up is not None else P()),
                w_out_spec,
                jax.tree.map(lambda _: P(), shared) if shared is not None
                else P())
    out_specs = (P(data_axis, None),
                 {"moe_lb_loss": P(), "moe_z_loss": P(),
                  "moe_drop_frac": P()})
    fn = shard_map(local_fn, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs, check_rep=False)
    return fn(x, params["router"]["kernel"], params["w_gate"],
              w_up if w_up is not None else jnp.zeros(()),
              params["w_down"], shared)
