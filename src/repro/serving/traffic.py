"""Open-loop traffic: arrival processes, SLO metric math, and the
asyncio driver that feeds an ``AsyncServeFrontend``.

Closed-loop benchmarking (pre-staged batches, ``ServeEngine.run``)
measures *capacity*; production serving is an **open-loop** arrival
process — requests arrive on their own clock whether or not the engine
is keeping up, so queueing delay compounds under load. The helpers here
make that measurable:

* ``poisson_arrivals`` / ``bursty_arrivals`` — deterministic (seeded)
  arrival-time generators. Bursty is an on/off-modulated Poisson
  process (a two-state MMPP): ON periods arrive ``burst``× faster than
  the mean rate, OFF periods are silent, with duty cycle chosen so the
  long-run mean rate matches ``rate``.
* ``drive_open_loop`` — submits each request at its *scheduled* arrival
  time, consumes its token stream, and records a ``RequestTrace``.
  Open-loop semantics: TTFT is measured from the scheduled arrival, so
  time spent queueing behind a saturated engine counts against the SLO
  (this is precisely what closed-loop numbers hide).
* ``slo_metrics`` — pure trace → metrics math (p50/p99 TTFT, p50/p99
  per-output-token latency, goodput at a TTFT SLO, tokens/s), unit-
  tested against hand-built fake-clock traces.

This module deliberately imports no jax: the metric math and arrival
generators run anywhere (including jax-less tooling), and the driver
only touches the front-end's public coroutines.
"""
from __future__ import annotations

import asyncio
import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np


@dataclasses.dataclass
class RequestTrace:
    """Per-request timeline, all times in seconds on the driver clock
    (t=0 at ``drive_open_loop`` start)."""
    uid: int
    t_arrival: float                 # scheduled arrival (open-loop)
    t_submit: float = 0.0            # when submit actually ran
    t_first: Optional[float] = None  # first stream output seen
    t_done: Optional[float] = None   # result available
    n_tokens: int = 0                # chosen candidate's tokens
    prompt_len: int = 0              # prompt tokens (TTFT bucketing)
    cancelled: bool = False


# ---------------------------------------------------------------------------
# arrival processes (seeded, deterministic)
# ---------------------------------------------------------------------------

def poisson_arrivals(rate: float, n: int, seed: int = 0) -> np.ndarray:
    """``n`` absolute arrival times (s) of a Poisson process of ``rate``
    requests/s: iid exponential inter-arrivals, cumulatively summed."""
    if rate <= 0:
        return np.zeros(n, np.float64)
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate, size=n))


def bursty_arrivals(rate: float, n: int, seed: int = 0, *,
                    burst: float = 4.0, on_frac: float = 0.25,
                    period_s: Optional[float] = None) -> np.ndarray:
    """On/off-modulated Poisson arrivals with long-run mean ``rate``.

    The process alternates ON windows (arrival rate ``rate * burst``)
    and OFF windows (silent). ``on_frac`` is the ON duty cycle; the
    default ``burst=4, on_frac=0.25`` makes ON exactly 4× the mean rate
    with 75% silence — the same offered load as Poisson, concentrated.
    ``period_s`` is one ON+OFF cycle (default: the time 8 mean-rate
    arrivals take, so a run of ``n`` requests sees several bursts)."""
    if rate <= 0:
        return np.zeros(n, np.float64)
    if burst * on_frac <= 0:
        raise ValueError(f"burst={burst}, on_frac={on_frac}")
    rng = np.random.default_rng(seed)
    period = period_s if period_s is not None else 8.0 / rate
    on_len = period * on_frac
    out = np.empty(n, np.float64)
    t = 0.0              # position inside the current ON window
    cycle = 0
    for i in range(n):
        t += rng.exponential(1.0 / (rate * burst))
        while t >= on_len:
            t -= on_len
            cycle += 1
        out[i] = cycle * period + t
    return out


ARRIVALS: Dict[str, Callable[..., np.ndarray]] = {
    "poisson": poisson_arrivals,
    "bursty": bursty_arrivals,
}


# ---------------------------------------------------------------------------
# SLO metric math (pure, fake-clock testable)
# ---------------------------------------------------------------------------

def percentile(xs: Sequence[float], q: float) -> float:
    """Deterministic linear-interpolation percentile (numpy's default
    'linear' method, pinned here so the SLO gates never drift with a
    numpy version change). ``q`` in [0, 100]."""
    arr = np.sort(np.asarray(list(xs), np.float64))
    if arr.size == 0:
        return float("nan")
    if arr.size == 1:
        return float(arr[0])
    pos = (q / 100.0) * (arr.size - 1)
    lo = int(np.floor(pos))
    hi = min(lo + 1, arr.size - 1)
    frac = pos - lo
    return float(arr[lo] * (1.0 - frac) + arr[hi] * frac)


def _bucket_label(b: int, bounds: Sequence[int]) -> str:
    """Human-stable bucket names: "lt64", "64to256", "ge256"."""
    if b == 0:
        return f"lt{bounds[0]}"
    if b == len(bounds):
        return f"ge{bounds[-1]}"
    return f"{bounds[b - 1]}to{bounds[b]}"


def slo_metrics(traces: Sequence[RequestTrace], *, slo_ttft_ms: float,
                span_s: Optional[float] = None,
                length_buckets: Sequence[int] = ()) -> Dict[str, object]:
    """SLO summary of an open-loop run.

    TTFT = first stream output minus *scheduled arrival* (queueing
    counts). TPOT = (t_done - t_first) / (n_tokens - 1) for requests
    with >= 2 tokens. Goodput = completed requests meeting the TTFT SLO
    per second of span; ``tokens_per_s`` counts completed requests'
    tokens over the same span. Cancelled requests are excluded from the
    latency distributions but reported.

    ``length_buckets``: ascending prompt-length boundaries (e.g.
    ``(64, 256)``) adding ``ttft_by_bucket`` — per-prompt-length-bucket
    TTFT percentiles keyed "lt64"/"64to256"/"ge256" — so a long-prompt
    tail improvement (chunked prefill's whole point) is visible instead
    of averaged away."""
    done = [t for t in traces
            if not t.cancelled and t.t_done is not None
            and t.t_first is not None]
    ttft_ms = [(t.t_first - t.t_arrival) * 1e3 for t in done]
    tpot_ms = [(t.t_done - t.t_first) / (t.n_tokens - 1) * 1e3
               for t in done if t.n_tokens >= 2]
    if span_s is None:
        t_end = max((t.t_done for t in done), default=0.0)
        t_start = min((t.t_arrival for t in traces), default=0.0)
        span_s = max(t_end - t_start, 1e-9)
    good = sum(1 for ms in ttft_ms if ms <= slo_ttft_ms)
    out: Dict[str, object] = {
        "completed": len(done),
        "cancelled": sum(1 for t in traces if t.cancelled),
        "span_s": span_s,
        "slo_ttft_ms": slo_ttft_ms,
        "ttft_p50_ms": percentile(ttft_ms, 50),
        "ttft_p99_ms": percentile(ttft_ms, 99),
        "tpot_p50_ms": percentile(tpot_ms, 50),
        "tpot_p99_ms": percentile(tpot_ms, 99),
        "goodput_rps": good / span_s,
        "good_requests": good,
        "tokens_per_s": sum(t.n_tokens for t in done) / span_s,
    }
    if length_buckets:
        bounds = list(length_buckets)
        assert bounds == sorted(bounds) and len(set(bounds)) == len(bounds), \
            f"length_buckets must be strictly ascending: {bounds}"
        by: Dict[str, List[float]] = {}
        for t in done:
            b = int(np.searchsorted(bounds, t.prompt_len, side="right"))
            by.setdefault(_bucket_label(b, bounds), []).append(
                (t.t_first - t.t_arrival) * 1e3)
        out["ttft_by_bucket"] = {
            label: {"n": len(xs),
                    "p50_ms": percentile(xs, 50),
                    "p99_ms": percentile(xs, 99)}
            for label, xs in sorted(by.items())}
    return out


# ---------------------------------------------------------------------------
# the open-loop driver
# ---------------------------------------------------------------------------

async def drive_open_loop(frontend, requests: Sequence,
                          arrivals: Sequence[float], *,
                          clock: Callable[[], float] = time.monotonic,
                          cancel_uids: Sequence[int] = (),
                          cancel_after_tokens: int = 1,
                          ) -> List[RequestTrace]:
    """Submit each request at its scheduled arrival time, stream its
    tokens, and return one ``RequestTrace`` per request (input order).

    ``cancel_uids`` requests are aborted after ``cancel_after_tokens``
    streamed tokens (or immediately on completion if the stream closes
    first) — the client-disconnect path under real traffic. The
    front-end must already be started."""
    assert len(requests) == len(arrivals)
    t0 = clock()
    cancel_set = set(cancel_uids)
    traces = [RequestTrace(uid=r.uid, t_arrival=float(a),
                           prompt_len=len(r.prompt))
              for r, a in zip(requests, arrivals)]

    async def one(req, tr: RequestTrace):
        delay = tr.t_arrival - (clock() - t0)
        if delay > 0:
            await asyncio.sleep(delay)
        await frontend.submit(req)
        tr.t_submit = clock() - t0
        seen = 0
        async for _tok in frontend.stream(req.uid):
            now = clock() - t0
            if tr.t_first is None:
                tr.t_first = now
            seen += 1
            if req.uid in cancel_set and seen >= cancel_after_tokens:
                await frontend.cancel(req.uid)
        res = await frontend.result(req.uid)
        tr.t_done = clock() - t0
        tr.n_tokens = int(len(res.tokens))
        tr.cancelled = bool(res.cancelled)
        if tr.t_first is None and not tr.cancelled:
            # non-incremental mode delivered the whole result at once
            tr.t_first = tr.t_done
        return tr

    await asyncio.gather(*[one(r, t) for r, t in zip(requests, traces)])
    return traces


def run_open_loop(engine, requests: Sequence, arrivals: Sequence[float],
                  *, slo_ttft_ms: float, cancel_uids: Sequence[int] = (),
                  cancel_after_tokens: int = 1,
                  length_buckets: Sequence[int] = ()):
    """Synchronous wrapper: build a front-end on ``engine``, drive the
    open-loop schedule, and return ``(traces, metrics)``."""
    from repro.serving.frontend import AsyncServeFrontend

    async def main():
        async with AsyncServeFrontend(engine) as fe:
            return await drive_open_loop(
                fe, requests, arrivals, cancel_uids=cancel_uids,
                cancel_after_tokens=cancel_after_tokens)

    traces = asyncio.run(main())
    return traces, slo_metrics(traces, slo_ttft_ms=slo_ttft_ms,
                               length_buckets=length_buckets)
