"""Serving engine integration tests: slot scheduling, CAMD rounds, modes.

Model/engine setup comes from the shared conftest fixtures
(``small_model``, ``_mk_engine``, ``_submit``)."""
import jax
import jax.numpy as jnp
import numpy as np

from conftest import _mk_engine, _submit
from repro.config import CAMDConfig
from repro.configs import get_config
from repro.models import build_model
from repro.serving import Request


def test_camd_mode_runs_all_requests(small_model):
    cfg, model, params = small_model
    eng = _mk_engine(model, params, mode="camd")
    _submit(eng, cfg, 5)
    res = eng.run()
    assert len(res) == 5
    for r in res:
        assert r.n_candidates >= 2
        assert 1 <= r.rounds <= 2
        assert len(r.tokens) >= 1
        assert r.tokens_spent == sum(c["n"] for c in r.candidates)


def test_greedy_mode_single_candidate(small_model):
    cfg, model, params = small_model
    eng = _mk_engine(model, params, mode="greedy")
    _submit(eng, cfg, 3)
    res = eng.run()
    for r in res:
        assert r.n_candidates == 1


def test_greedy_deterministic(small_model):
    cfg, model, params = small_model
    outs = []
    for seed in (0, 1):
        eng = _mk_engine(model, params, mode="greedy", seed=seed)
        _submit(eng, cfg, 2, seed=7)
        outs.append([r.tokens.tolist() for r in sorted(eng.run(),
                                                       key=lambda r: r.uid)])
    assert outs[0] == outs[1], "greedy must not depend on sampler rng"


def test_best_of_n_exact_budget(small_model):
    cfg, model, params = small_model
    eng = _mk_engine(model, params, mode="best_of_n", n_candidates=4)
    _submit(eng, cfg, 3)
    res = eng.run()
    for r in res:
        assert r.n_candidates == 4
        best = max(r.candidates, key=lambda c: c["score"])
        assert r.tokens.tolist() == best["tokens"].tolist()


def test_self_consistency_runs(small_model):
    cfg, model, params = small_model
    eng = _mk_engine(model, params, mode="self_consistency", n_candidates=4)
    _submit(eng, cfg, 2)
    res = eng.run()
    for r in res:
        assert r.n_candidates == 4


def test_slot_reuse_under_small_slot_count(small_model):
    """More requests than slots: continuous batching must still finish all."""
    cfg, model, params = small_model
    eng = _mk_engine(model, params, mode="camd", slots=4)
    _submit(eng, cfg, 6)
    res = eng.run()
    assert len(res) == 6
    assert all(r.n_candidates >= 2 for r in res)


def test_adaptive_spends_fewer_tokens_than_fixed_on_easy(small_model):
    """The paper's core efficiency claim at engine level: when candidates
    agree (easy instance ⇒ coverage reached in round 1), CAMD spends fewer
    tokens than fixed best-of-N with the same per-round width."""
    cfg, model, params = small_model
    camd_kw = dict(camd=CAMDConfig(samples_per_round=2, max_rounds=4,
                                   min_samples=2, max_clusters=8,
                                   cluster_threshold=0.0))  # everything clusters
    eng_a = _mk_engine(model, params, mode="camd", **camd_kw)
    _submit(eng_a, cfg, 3)
    res_a = eng_a.run()
    eng_f = _mk_engine(model, params, mode="best_of_n", n_candidates=8)
    _submit(eng_f, cfg, 3)
    res_f = eng_f.run()
    toks_a = sum(r.tokens_spent for r in res_a)
    toks_f = sum(r.tokens_spent for r in res_f)
    assert toks_a < toks_f
    assert all(r.stopped_early for r in res_a)


def test_vlm_engine_with_evidence():
    cfg = get_config("internvl2-2b").reduced().with_overrides(dtype="float32")
    model = build_model(cfg, jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    eng = _mk_engine(model, params, mode="camd", slots=4, cache_len=64)
    rng = np.random.default_rng(0)
    for i in range(2):
        ev = rng.standard_normal((cfg.num_evidence_tokens,
                                  cfg.evidence_dim)).astype(np.float32)
        eng.submit(Request(uid=i, prompt=rng.integers(
            2, cfg.vocab_size, 6).astype(np.int32), evidence=ev))
    res = eng.run()
    assert len(res) == 2
    for r in res:
        assert np.isfinite(r.best_score)
