"""Traffic-level scheduling policies for the serving engine.

CAMD's compute-allocation logic (more samples for hard instances, fewer
for easy) historically lived only *inside* a request — the round-based
coverage stop in ``core.controller``. Across requests, admission was
plain FIFO slot-filling, so under multi-request traffic easy requests
hog decode slots while the heavy tail queues: exactly the
compute-difficulty mismatch the paper exists to fix.

This module lifts coverage-awareness to the traffic level. The engine
delegates every admission decision (which queued request gets free
slots, which pending round runs next, how many candidates, and each
candidate's token limit) to a ``Scheduler``:

``fifo``
    The seam policy: reproduces the pre-refactor engine loop decision
    for decision, so its token streams are bit-identical to the
    pre-scheduler engine (pinned by the differential test suite).

``coverage``
    Between macro-step launches, ranks pending work by posterior
    coverage deficit ``max(0, (1 - delta) - p_star)`` plus the expected
    marginal gain of one more round (``posterior.expected_improvement_
    stop``'s EI, the paper's rule (iii)), ages queued work so nothing
    starves, and declines rounds whose expected gain no longer pays for
    their tokens. With a ``global_budget`` it enforces a *stream-wide*
    token budget by worst-case commitment accounting: a candidate is
    only admitted with a per-candidate token ``limit`` the remaining
    budget can cover, so the budget is a hard invariant, not advisory.

Both policies speak to the engine through the small ``SchedulerContext``
facade, so they are unit-testable against fakes (see
``tests/test_scheduler_properties.py``).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class NewWork:
    """A prefilled queued request awaiting first admission.

    ``prompt_len`` and ``evidence_entropy`` are difficulty *priors*: a
    new request has no posterior yet (no candidates, no p_star), so the
    coverage policy ranks unobserved work by what the prompt alone
    reveals — longer prompts carry more conditioning to satisfy, and a
    diffuse prompt-to-evidence attachment (high normalized entropy of
    the token-evidence alignment) marks grounding ambiguity. Both
    default to 0 so fakes and non-coverage policies are unaffected."""
    uid: int
    arrival: int                 # submit order (FIFO tiebreak)
    want: int                    # candidates the mode wants per round
    prompt_len: int = 0          # tokens in the prompt (difficulty prior)
    evidence_entropy: float = 0.0  # normalized [0,1] alignment entropy


@dataclasses.dataclass
class PrefillWork:
    """A queued request mid chunked prefill (or awaiting its first
    chunk). The engine asks the policy to order these each pump turn —
    the chunk-token budget goes to the top-ranked jobs first."""
    uid: int
    arrival: int                 # submit order (FIFO tiebreak)
    prompt_len: int              # total prompt tokens
    prefilled: int = 0           # chunk tokens already in the page pool
    evidence_entropy: float = 0.0


@dataclasses.dataclass
class RoundWork:
    """A request whose last round completed and wants another."""
    uid: int
    arrival: int
    want: int
    rounds: int                  # rounds already completed
    p_star: float                # latest posterior coverage estimate
    delta: float                 # target residual risk (1 - coverage)
    best_score: float
    scores: List[float]          # all candidate scores seen so far
    mean_len: float              # mean tokens per finished candidate


class SchedulerContext:
    """What a policy may observe and do. The engine implements this
    (``ServeEngine._sched_ctx``); property tests implement fakes.

    Under mesh-parallel serving (``num_shards > 1``) slots and KV pages
    are partitioned across data shards: slot ``s`` lives on shard
    ``s // (slots / num_shards)`` and can only be backed by that shard's
    page subpool. Policies stay shard-oblivious — ``affordable`` is the
    shard-local capacity gate: the engine walks the exact free slots an
    admission of ``want`` candidates would occupy (ascending order, the
    same order ``admit_*`` assigns) and counts the longest prefix each
    slot's OWN shard can fund, so budget commitment and coverage ranking
    automatically respect shard-local capacity."""

    max_new: int
    num_shards: int = 1

    def free_slots(self) -> int:
        raise NotImplementedError

    def queued_new(self) -> List[NewWork]:
        """Prefilled queued requests, arrival order."""
        raise NotImplementedError

    def pending_rounds(self) -> List[RoundWork]:
        """Requests with ``pending_round`` set, table order."""
        raise NotImplementedError

    def affordable(self, uid: int, want: int, limit: int) -> int:
        """Paged-pool admission gate (non-paged engines: ``want``)."""
        raise NotImplementedError

    def admit_new(self, uid: int, take: int, limit: int) -> None:
        raise NotImplementedError

    def admit_round(self, uid: int, take: int, limit: int) -> None:
        raise NotImplementedError

    def finish_request(self, uid: int) -> None:
        """Finalize a request with the candidates it already has
        (coverage policy's EI-decline)."""
        raise NotImplementedError


class Scheduler:
    """Base: worst-case token-budget accounting shared by all policies.

    ``committed`` is the sum of live candidates' token *limits* (the
    most they can still emit); ``spent`` is what finished candidates
    actually emitted. Admission only proceeds when
    ``spent + committed + take * limit <= global_budget``, and a
    finished candidate releases its whole limit, so

        spent <= global_budget            (the stream-wide invariant)

    holds at every instant — an early-stopped easy candidate's unspent
    commitment immediately funds queued work. ``global_budget=0``
    disables budgeting entirely (the bit-identity configuration).

    Speculative decoding does not change this accounting: a slot may
    *verify* up to spec_k tokens per device step, but the device-side
    limit check truncates emission at exactly the granted ``limit``
    (over-drafted tokens past the limit are discarded before they
    count), and frontier staging for the wider per-launch advance is
    capped at the slot's own commitment. The worst case the admission
    check reserves against — ``limit`` emitted tokens per candidate —
    is therefore identical with speculation on or off.
    """

    name = "base"

    def __init__(self, *, global_budget: int = 0):
        self.global_budget = int(global_budget)
        self.committed = 0
        self.spent = 0
        self.admitted_candidates = 0
        self.declined_rounds = 0
        self.cancelled_candidates = 0
        # per-shard admission telemetry (mesh-parallel serving): the
        # engine reports each admitted candidate's slot shard so skewed
        # placement (one shard's pool saturating while others idle) is
        # visible in sched_stats without a device readback
        self.admitted_per_shard: Dict[int, int] = {}

    # -- budget ---------------------------------------------------------
    def remaining(self) -> Optional[int]:
        if not self.global_budget:
            return None
        return self.global_budget - self.spent - self.committed

    def grant(self, want: int, max_new: int) -> Tuple[int, int]:
        """Largest (take, per-candidate limit) the budget covers.

        Limits are never granted below 2: a candidate emits one token at
        admission and at least one decode step runs before the on-device
        limit check, so ``limit=1`` would overshoot its commitment."""
        if not self.global_budget:
            return want, max_new
        rem = self.remaining()
        if want <= 0 or rem < 2:
            return 0, 0
        take = min(want, rem // 2)            # >= 2 tokens per candidate
        limit = min(max_new, rem // take)
        return take, limit

    def commit(self, take: int, limit: int):
        self.committed += take * limit
        self.admitted_candidates += take

    def on_finish(self, uid: int, n_tokens: int, limit: int):
        """A candidate finished having emitted ``n_tokens <= limit``."""
        self.committed -= limit
        self.spent += n_tokens
        assert self.committed >= 0, (uid, n_tokens, limit)

    def on_cancel(self, uid: int, n_tokens: int, limit: int):
        """A live candidate was aborted mid-flight: its worst-case
        commitment is refunded exactly like a finish, and the tokens it
        did emit count as spent — the compute is burned either way, so
        the ``spent <= global_budget`` invariant is unchanged."""
        self.on_finish(uid, n_tokens, limit)
        self.cancelled_candidates += 1

    def reset_stats(self) -> None:
        """Zero telemetry counters for engine reuse across bench cells.

        Budget LEDGERS (``spent``/``committed``) are accounting state —
        resetting them would let a reused engine overspend its stream
        budget — so they survive; only observability counters reset."""
        self.admitted_candidates = 0
        self.declined_rounds = 0
        self.cancelled_candidates = 0
        self.admitted_per_shard = {}

    def note_shard_admission(self, shards) -> None:
        """Engine callback: one entry per admitted candidate, the data
        shard of the slot it landed on."""
        for s in shards:
            self.admitted_per_shard[int(s)] = \
                self.admitted_per_shard.get(int(s), 0) + 1

    def exhausted(self) -> bool:
        """No admission can ever be funded again (terminal-drain check:
        only meaningful when nothing is live, i.e. committed == 0).
        Mirrors ``grant``'s minimum viable grant of 2 tokens."""
        rem = self.remaining()
        return rem is not None and rem < 2

    def stats(self) -> Dict[str, float]:
        s = {
            "policy": self.name,
            "global_budget": self.global_budget,
            "spent": self.spent,
            "committed": self.committed,
            "admitted_candidates": self.admitted_candidates,
            "declined_rounds": self.declined_rounds,
            "cancelled_candidates": self.cancelled_candidates,
        }
        if self.admitted_per_shard:
            s["admitted_per_shard"] = {
                str(k): v for k, v in sorted(self.admitted_per_shard.items())}
        return s

    # -- policy ---------------------------------------------------------
    def schedule(self, ctx: SchedulerContext) -> None:
        raise NotImplementedError

    def prefill_order(self, items: List[PrefillWork]) -> List[PrefillWork]:
        """Order chunked-prefill jobs for the engine's per-turn
        chunk-token budget. Base/fifo: arrival order — the head-of-line
        request's prefill completes first, so admission order (and
        therefore fifo's token streams) matches the unchunked engine
        exactly."""
        return sorted(items, key=lambda w: w.arrival)


class FifoScheduler(Scheduler):
    """The pre-refactor engine loop, verbatim: queued requests first (in
    arrival order, head-of-line blocking on paged backpressure), then
    pending rounds in request-table order. With ``global_budget=0`` the
    decisions — and therefore the token streams — are bit-identical to
    the pre-scheduler engine."""

    name = "fifo"

    def schedule(self, ctx: SchedulerContext) -> None:
        while ctx.free_slots() > 0:
            queued = ctx.queued_new()
            if not queued:
                break
            head = queued[0]
            take = min(head.want, ctx.free_slots())
            take, limit = self.grant(take, ctx.max_new)
            if take > 0:
                take = ctx.affordable(head.uid, take, limit)
            if take <= 0:
                break                      # wait, keep queue order
            self.commit(take, limit)
            ctx.admit_new(head.uid, take, limit)
        for item in ctx.pending_rounds():
            if ctx.free_slots() <= 0:
                break
            take = min(item.want, ctx.free_slots())
            take, limit = self.grant(take, ctx.max_new)
            if take > 0:
                take = ctx.affordable(item.uid, take, limit)
            if take <= 0:
                continue
            self.commit(take, limit)
            ctx.admit_round(item.uid, take, limit)


class CoverageScheduler(Scheduler):
    """Coverage-aware continuous batching.

    Priority of a pending round = coverage deficit + EI of one more
    sample + aging; priority of a new request = ``new_request_priority``
    + ``difficulty_weight`` * difficulty-prior + aging, where the prior
    ranks *unobserved* requests by prompt length and evidence-alignment
    entropy (see ``NewWork``/``_difficulty``) instead of sharing one
    flat prior. The default puts new requests above any continuing round
    (deficit <= 1 and EI is clamped to 1): a request's FIRST round buys
    far more residual-risk reduction than a hard request's n-th, so
    under budget pressure breadth beats depth — the saved depth comes
    from declining low-gain rounds, not from starving the queue. Aging
    grows without bound with every pass an item is skipped, so every
    queued item is eventually the top-priority item — the no-starvation
    guarantee the property suite pins down.

    Rounds whose expected marginal gain no longer pays for their tokens
    (``posterior.expected_improvement_stop``, the paper's rule (iii))
    are *declined*: the request finalizes with the candidates it has,
    and the tokens it would have burned fund the heavy tail instead.

    Under a global budget the policy also **fair-shares width**: when the
    remaining budget cannot fund a full-width round for every pending
    work item, per-item candidate counts shrink (down to 1) so the
    budget covers *every* request shallowly rather than the queue prefix
    deeply — residual risk concentrates in unserved requests far more
    than in narrow rounds. This is the traffic-level analogue of the
    paper's coverage argument and is what beats FIFO at equal budget on
    heavy-tailed traffic (see ``benchmarks/bench_serve.py``).
    """

    name = "coverage"

    def __init__(self, *, global_budget: int = 0, aging_rate: float = 0.25,
                 new_request_priority: float = 2.5, ei_weight: float = 1.0,
                 ei_cost_per_token: float = 1e-4, min_rounds: int = 1,
                 decline_low_gain: bool = True,
                 difficulty_weight: float = 0.5,
                 difficulty_len_scale: float = 64.0):
        super().__init__(global_budget=global_budget)
        self.aging_rate = aging_rate
        self.new_request_priority = new_request_priority
        self.ei_weight = ei_weight
        self.ei_cost_per_token = ei_cost_per_token
        self.min_rounds = min_rounds
        self.decline_low_gain = decline_low_gain
        self.difficulty_weight = difficulty_weight
        self.difficulty_len_scale = difficulty_len_scale
        self._wait: Dict[Tuple[str, int], int] = {}
        self.max_wait_seen = 0

    # -- priorities -----------------------------------------------------
    def _ei(self, item: RoundWork) -> Tuple[float, bool]:
        """Expected improvement of one more sample and whether the
        paper's rule-(iii) stop (EI below its token cost) triggers.

        Closed-form host-float mirror of
        ``posterior.expected_improvement_stop`` (normal approximation of
        the score distribution) — this runs between every macro-step
        launch, so it must not pay per-call jax dispatch."""
        scores = np.asarray(item.scores, np.float64)
        if scores.size < 2:
            return 1.0, False              # too little evidence to stop
        std = max(float(scores.std()), 1e-6)
        z = (float(scores.mean()) - item.best_score) / std
        phi = math.exp(-0.5 * z * z) / math.sqrt(2.0 * math.pi)
        Phi = 0.5 * (1.0 + math.erf(z / math.sqrt(2.0)))
        ei = std * (z * Phi + phi)
        stop = ei < self.ei_cost_per_token * max(item.mean_len, 1.0)
        return ei, stop

    def _difficulty(self, w: NewWork) -> float:
        """Prompt-level difficulty prior in [0, 1) for *unobserved*
        requests (no posterior yet). Saturating prompt-length term —
        ``len_scale`` tokens is the half-difficulty point — averaged
        with the normalized evidence-alignment entropy computed at
        prefill (0 for text-only requests). Harder ranks first: a hard
        request's first round buys more residual-risk reduction, and
        admitting it early gives its later rounds time inside the same
        budget window."""
        lp = w.prompt_len / (w.prompt_len + self.difficulty_len_scale) \
            if w.prompt_len > 0 else 0.0
        ent = min(max(w.evidence_entropy, 0.0), 1.0)
        return 0.5 * (lp + ent)

    def _priority(self, kind: str, item, ei: float = 0.0) -> float:
        wait = self._wait.get((kind, item.uid), 0)
        age = self.aging_rate * wait
        if kind == "new":
            return self.new_request_priority \
                + self.difficulty_weight * self._difficulty(item) + age
        deficit = max(0.0, (1.0 - item.delta) - item.p_star)
        return deficit + self.ei_weight * min(ei, 1.0) + age

    # -- policy ---------------------------------------------------------
    def schedule(self, ctx: SchedulerContext) -> None:
        items: List[Tuple[str, object, float]] = []
        for w in ctx.queued_new():
            items.append(("new", w, self._priority("new", w)))
        for r in ctx.pending_rounds():
            ei, stop = self._ei(r)
            if self.decline_low_gain and r.rounds >= self.min_rounds \
                    and stop:
                self.declined_rounds += 1
                self._wait.pop(("round", r.uid), None)
                ctx.finish_request(r.uid)
                continue
            items.append(("round", r, self._priority("round", r, ei)))
        items.sort(key=lambda t: (-t[2], t[1].arrival))
        left = len(items)
        for kind, w, _prio in items:
            key = (kind, w.uid)
            if ctx.free_slots() <= 0:
                self._bump(key)
                continue
            take = min(w.want, ctx.free_slots())
            rem = self.remaining()
            share = None
            if rem is not None:
                # fair-share width AND depth: don't let this item's round
                # starve the items behind it of even a shallow round —
                # cap its candidate count and its per-candidate token
                # limit to this item's share of the remaining budget
                fair = max(1, rem // max(left * ctx.max_new, 1))
                take = min(take, fair)
                share = max(2, rem // max(left, 1))
            left -= 1
            take, limit = self.grant(take, ctx.max_new)
            if share is not None and take > 0:
                limit = max(2, min(limit, share // take))
            if take > 0:
                take = ctx.affordable(w.uid, take, limit)
            if take <= 0:
                self._bump(key)
                continue
            self._wait.pop(key, None)
            self.commit(take, limit)
            if kind == "new":
                ctx.admit_new(w.uid, take, limit)
            else:
                ctx.admit_round(w.uid, take, limit)

    def prefill_order(self, items: List[PrefillWork]) -> List[PrefillWork]:
        """Coverage ranking of partially-prefilled work: the difficulty
        prior (prompt length + evidence-alignment entropy — the same
        prior that ranks unobserved NewWork) plus prefill *progress*, so
        a nearly-complete prefill finishes ahead of a barely-started one
        of equal difficulty — its first decode token (the TTFT the
        chunking exists to protect) is the cheapest one to unlock.
        Arrival breaks ties, so equal-priority work never reorders."""
        def rank(w: PrefillWork) -> float:
            progress = w.prefilled / w.prompt_len if w.prompt_len else 0.0
            return self.difficulty_weight * self._difficulty(w) + progress

        return sorted(items, key=lambda w: (-rank(w), w.arrival))

    def _bump(self, key):
        self._wait[key] = self._wait.get(key, 0) + 1
        self.max_wait_seen = max(self.max_wait_seen, self._wait[key])

    def reset_stats(self) -> None:
        super().reset_stats()
        # the aging state (_wait) is POLICY state, not telemetry — the
        # no-starvation guarantee must survive a stats reset
        self.max_wait_seen = 0

    def stats(self) -> Dict[str, float]:
        s = super().stats()
        s["max_wait_seen"] = self.max_wait_seen
        return s


POLICIES = {"fifo": FifoScheduler, "coverage": CoverageScheduler}


def make_scheduler(policy, *, global_budget: int = 0, **kw) -> Scheduler:
    """``policy`` is a name from ``POLICIES`` or an instance (tests)."""
    if isinstance(policy, Scheduler):
        return policy
    if policy not in POLICIES:
        raise ValueError(f"unknown scheduler policy {policy!r}; "
                         f"choose from {sorted(POLICIES)}")
    return POLICIES[policy](global_budget=global_budget, **kw)
