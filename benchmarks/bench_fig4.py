"""Paper Figure 4 — accuracy vs token budget, on the REAL serving engine.

Model-in-the-loop: a small transformer is trained on the arithmetic-chain
oracle task (heterogeneous difficulty via chain length), then served with
greedy / best-of-N / CAMD through the actual ServeEngine. Accuracy is
oracle-checked; the token axis is real engine token accounting.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.config import CAMDConfig, ModelConfig, SamplingConfig, TrainConfig
from repro.data import ChainTask, lm_batches
from repro.models import build_model
from repro.serving import Request, ServeEngine
from repro.training import train


def _trained_model(steps=450, seed=0):
    cfg = ModelConfig(
        name="fig4-lm", family="dense", num_layers=4, d_model=256,
        num_heads=4, num_kv_heads=2, d_ff=768, vocab_size=64,
        head_dim=64, tie_embeddings=True, dtype="float32")
    model = build_model(cfg, jnp.float32)
    data = ({"tokens": jnp.asarray(b["tokens"]),
             "labels": jnp.asarray(b["labels"])}
            for b in lm_batches(cfg.vocab_size, 16, 48, seed=seed, base=16,
                                max_chain=3))
    params, _, hist = train(
        model, TrainConfig(total_steps=steps, warmup_steps=30,
                           learning_rate=3e-3, remat=False),
        data, steps=steps, log_every=max(steps - 1, 1))
    return cfg, model, params, hist


def _serve(cfg, model, params, prompts, mode, n_candidates, seed=0,
           camd_cfg=None, max_new=4):
    eng = ServeEngine(
        model, params, slots=8, cache_len=64,
        sampling=SamplingConfig(temperature=0.9, top_p=0.95,
                                repetition_penalty=1.0, max_new_tokens=max_new),
        camd=camd_cfg or CAMDConfig(),
        mode=mode, n_candidates=n_candidates, eos_id=1,
        max_new_tokens=max_new, seed=seed)
    for i, (prompt, _ans, _k) in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=prompt))
    return eng.run()


def run(n_questions: int = 48, steps: int = 450, seed: int = 0,
        verbose: bool = True):
    cfg, model, params, hist = _trained_model(steps, seed)
    if verbose:
        print(f"  trained fig4 LM: loss {hist[0]['loss']:.2f} -> "
              f"{hist[-1]['loss']:.2f}, acc {hist[-1]['accuracy']:.2f}")
    task = ChainTask(base=16)
    rng = np.random.default_rng(seed)
    # heterogeneous difficulty: chain lengths 1..8
    prompts = [task.sample(rng, chain_len=i % 4) for i in range(n_questions)]

    rows = []
    for mode, n in (("greedy", 1), ("best_of_n", 4), ("best_of_n", 8)):
        res = _serve(cfg, model, params, prompts, mode, n, seed)
        acc = np.mean([task.check(prompts[r.uid][0], r.tokens) for r in res])
        toks = np.mean([r.tokens_spent for r in res])
        rows.append({"name": f"{mode}{n if mode != 'greedy' else ''}",
                     "accuracy": float(acc), "avg_tokens": float(toks)})
    camd_cfg = CAMDConfig(samples_per_round=2, max_rounds=4, min_samples=2,
                          max_clusters=8, delta=0.05, score_scale=3.0,
                          lambda_c=0.2, guidance_strength=0.5)
    res = _serve(cfg, model, params, prompts, "camd", 8, seed, camd_cfg)
    acc = np.mean([task.check(prompts[r.uid][0], r.tokens) for r in res])
    toks = np.mean([r.tokens_spent for r in res])
    rows.append({"name": "camd", "accuracy": float(acc),
                 "avg_tokens": float(toks),
                 "avg_rounds": float(np.mean([r.rounds for r in res])),
                 "early_stop_frac": float(np.mean([r.stopped_early
                                                   for r in res]))})
    if verbose:
        for r in rows:
            print(f"  {r['name']:>10}: acc={r['accuracy']:.3f} "
                  f"tokens={r['avg_tokens']:.1f}")
    by = {r["name"]: r for r in rows}
    claim = (by["camd"]["accuracy"] >= by["best_of_n8"]["accuracy"] - 0.05
             and by["camd"]["avg_tokens"] < by["best_of_n8"]["avg_tokens"])
    if verbose:
        print(f"  claim[CAMD ~bo8 accuracy at lower real token budget]: {claim}")
    return {"rows": rows, "claims": {"engine_pareto": bool(claim)}}


if __name__ == "__main__":
    run()
