"""Slot-scheduled batched serving engine with CAMD adaptive decoding.

Execution model (DESIGN.md §3): a fixed-size decode batch of ``slots``.
Each slot holds one *candidate* generation of some request. CAMD's
adaptive allocation — more samples for hard requests, fewer for easy —
falls out of slot scheduling: when a request reaches coverage its slots
are freed and refilled from the queue, so the batch never decodes padding.

The per-token hot path is ONE jit'd ``step``: decode -> sample ->
incremental CAMD aggregates (S_gen, S_coh, S_align term-1, pooled
embedding) with O(B·d) state — no (B, L, d) trajectory buffers. The
round-level math (clustering, coverage, Dirichlet, mixture bias) runs in
``repro.core.controller`` when a request's round completes.

Modes: "camd" (adaptive), "best_of_n", "self_consistency", "greedy" —
the paper's baselines share the engine so efficiency comparisons are
apples-to-apples.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import CAMDConfig, PagedKVConfig, SamplingConfig
from repro.core import controller as ctrl
from repro.models.model import Model
from repro.sampling.samplers import sample_token
from repro.serving.page_pool import PagePool


# ---------------------------------------------------------------------------
# Requests / results
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                      # (L,) int32
    evidence: Optional[np.ndarray] = None   # (Ne, De) frontend embeddings
    max_new_tokens: int = 0                 # 0 => engine default


@dataclasses.dataclass
class Result:
    uid: int
    tokens: np.ndarray                      # best candidate's generation
    n_candidates: int
    tokens_spent: int
    rounds: int
    p_star: float
    best_score: float
    stopped_early: bool
    candidates: List[Dict[str, Any]]        # per-candidate records


# ---------------------------------------------------------------------------
# Device-side engine state
# ---------------------------------------------------------------------------

class EngineState(NamedTuple):
    cache: Any
    last_token: jax.Array      # (B,)
    token_counts: jax.Array    # (B, V)
    sum_lp: jax.Array          # (B,)
    n_tok: jax.Array           # (B,) int32
    prev_h: jax.Array          # (B, d)
    sum_coh: jax.Array         # (B,)
    sum_emb: jax.Array         # (B, d)
    align_sum: jax.Array       # (B,)
    active: jax.Array          # (B,) bool
    out_buf: jax.Array         # (B, max_new)
    bias: jax.Array            # (B, V) CAMD mixture guidance
    greedy: jax.Array          # (B,) bool


class ServeEngine:
    def __init__(self, model: Model, params, *, slots: int = 8,
                 cache_len: int = 512,
                 sampling: SamplingConfig = SamplingConfig(),
                 camd: CAMDConfig = CAMDConfig(),
                 mode: str = "camd",
                 n_candidates: int = 8,
                 eos_id: int = 1,
                 max_new_tokens: int = 64,
                 impl: str = "xla",
                 paged_kv: PagedKVConfig = PagedKVConfig(),
                 seed: int = 0):
        assert mode in ("camd", "best_of_n", "self_consistency", "greedy")
        assert impl in ("xla", "pallas", "paged", "paged_pallas")
        self.model, self.params = model, params
        self.cfg = model.cfg
        self.B = slots
        self.V = self.cfg.vocab_size
        self.d = self.cfg.d_model
        self.cache_len = cache_len
        self.sampling = sampling
        self.camd = camd
        self.mode = mode
        self.n_candidates = 1 if mode == "greedy" else n_candidates
        self.eos_id = eos_id
        self.max_new = max_new_tokens
        self.impl = impl
        # paged serving: KV lives in a shared page pool; "paged" runs the
        # gather+sdpa XLA attention (bit-identical to the dense path),
        # "paged_pallas" the block-table flash-decode kernel.
        self.paged = impl.startswith("paged")
        self._model_impl = {"paged": "xla", "paged_pallas": "pallas"}[impl] \
            if self.paged else impl
        if self.paged:
            ps = paged_kv.page_size
            assert cache_len % ps == 0, \
                f"cache_len {cache_len} must be a multiple of page_size {ps}"
            self.page_size = ps
            self.pages_per_slot = cache_len // ps
            num_pages = paged_kv.num_pages or slots * self.pages_per_slot + 1
            self.pool = PagePool(num_pages, ps)
            self._slot_pages: List[List[int]] = [[] for _ in range(slots)]
            self._slot_pos = np.zeros(slots, np.int64)
            # admission control: pages a running candidate may still
            # allocate are *reserved* at admit time, so a candidate that
            # was admitted can always finish — pool pressure surfaces as
            # queueing delay at _schedule, never as a mid-decode crash.
            self._slot_reserved = np.zeros(slots, np.int64)
            self._reserved = 0
        else:
            self.pool = None
        self.key = jax.random.PRNGKey(seed)
        self.has_evidence = bool(self.cfg.num_evidence_tokens)

        self._queue: List[Request] = []
        self._slot_req = np.full(slots, -1, np.int64)   # uid per slot
        self._slot_cand = np.full(slots, -1, np.int64)  # candidate uid per slot
        self._reqs: Dict[int, Dict[str, Any]] = {}      # uid -> bookkeeping
        self._next_cand = 0
        self._dtype = model.param_dtype

        self.state = self._blank_state()
        self._step_fn = self._build_step()
        self._prefill_fn = self._build_prefill()
        self._round_fn = jax.jit(partial(ctrl.round_update, self.camd))
        # telemetry
        self.total_steps = 0
        self.total_tokens = 0

    # ------------------------------------------------------------------
    def _blank_state(self) -> EngineState:
        B, V, d = self.B, self.V, self.d
        if self.paged:
            cache = self.model.make_paged_cache(
                B, self.cache_len, self._dtype,
                page_size=self.page_size, num_pages=self.pool.num_pages)
        else:
            cache = self.model.make_cache(B, self.cache_len, self._dtype)
        return EngineState(
            cache=cache,
            last_token=jnp.zeros((B,), jnp.int32),
            token_counts=jnp.zeros((B, V), jnp.float32),
            sum_lp=jnp.zeros((B,), jnp.float32),
            n_tok=jnp.zeros((B,), jnp.int32),
            prev_h=jnp.zeros((B, d), jnp.float32),
            sum_coh=jnp.zeros((B,), jnp.float32),
            sum_emb=jnp.zeros((B, d), jnp.float32),
            align_sum=jnp.zeros((B,), jnp.float32),
            active=jnp.zeros((B,), bool),
            out_buf=jnp.zeros((B, self.max_new), jnp.int32),
            bias=jnp.zeros((B, V), jnp.float32),
            greedy=jnp.zeros((B,), bool),
        )

    # ------------------------------------------------------------------
    def _build_prefill(self):
        model = self.model

        @jax.jit
        def prefill(params, tokens, cache_row, evidence=None):
            lg, h, cache = model.prefill(params, tokens, cache_row,
                                         evidence, impl=self._model_impl)
            return lg, h, cache

        return prefill

    def _build_step(self):
        model, sampling, eos, max_new = self.model, self.sampling, self.eos_id, self.max_new
        has_ev = self.has_evidence

        @jax.jit
        def step(params, st: EngineState, key, evid_norm):
            logits, hidden, cache = model.decode_step(
                params, st.last_token, st.cache, impl=self._model_impl)
            tok, lp = sample_token(key, logits.astype(jnp.float32), sampling,
                                   st.token_counts, st.bias, greedy=st.greedy)
            act = st.active
            actf = act.astype(jnp.float32)
            hidden32 = hidden.astype(jnp.float32)

            # --- incremental CAMD aggregates ------------------------------
            sum_lp = st.sum_lp + lp * actf
            hn = hidden32 / (jnp.linalg.norm(hidden32, axis=-1, keepdims=True) + 1e-8)
            pn = st.prev_h
            coh = jnp.sum(hn * pn, axis=-1)
            has_prev = st.n_tok > 0
            sum_coh = st.sum_coh + coh * actf * has_prev.astype(jnp.float32)
            sum_emb = st.sum_emb + hidden32 * actf[:, None]
            if has_ev:
                emb_t = jnp.take(params["embed"]["table"], tok, axis=0)
                emb_t = emb_t.astype(jnp.float32)
                emb_t = emb_t / (jnp.linalg.norm(emb_t, axis=-1, keepdims=True) + 1e-8)
                a = jnp.mean(jnp.einsum("bnd,bd->bn", evid_norm, emb_t), axis=-1)
                align_sum = st.align_sum + a * actf
            else:
                align_sum = st.align_sum

            counts = st.token_counts + jax.nn.one_hot(tok, st.token_counts.shape[1]) \
                * actf[:, None]
            out_buf = jnp.where(
                (jnp.arange(max_new)[None, :] == st.n_tok[:, None]) & act[:, None],
                tok[:, None], st.out_buf)
            n_tok = st.n_tok + act.astype(jnp.int32)
            done = act & ((tok == eos) | (n_tok >= max_new))
            new_state = EngineState(
                cache=cache, last_token=jnp.where(act, tok, st.last_token),
                token_counts=counts, sum_lp=sum_lp, n_tok=n_tok,
                prev_h=jnp.where(act[:, None], hn, st.prev_h),
                sum_coh=sum_coh, sum_emb=sum_emb, align_sum=align_sum,
                active=act & ~done, out_buf=out_buf, bias=st.bias,
                greedy=st.greedy)
            return new_state, done

        return step

    # ------------------------------------------------------------------
    # host-side scheduling
    # ------------------------------------------------------------------
    def submit(self, req: Request):
        # uids key the request table and results; a reused uid would
        # resurrect a finished request's bookkeeping (cache_row=None).
        if req.uid in self._reqs or any(r.uid == req.uid
                                        for r in self._queue):
            raise ValueError(f"duplicate request uid {req.uid}")
        self._queue.append(req)

    def _cache_batch_axis(self, path) -> int:
        for p in path:
            if isinstance(p, jax.tree_util.DictKey) and p.key in (
                    "super", "self", "cross_k", "cross_v"):
                return 1
        return 0

    @staticmethod
    def _scat_rows(big, row, idx, ax: int):
        """Scatter a 1-row cache leaf into ``idx`` slots on batch axis
        ``ax`` (0 = per-slot leaves, 1 = layer-stacked leaves)."""
        r_rep = jnp.repeat(row, idx.shape[0], axis=ax)
        if ax == 0:
            return big.at[idx].set(r_rep)
        return big.at[:, idx].set(r_rep)

    def _scatter_cache_rows(self, big, row, slot_ids: List[int]):
        idx = jnp.asarray(slot_ids)
        return jax.tree_util.tree_map_with_path(
            lambda path, b, r: self._scat_rows(
                b, r, idx, self._cache_batch_axis(path)), big, row)

    # -- paged cache plumbing ------------------------------------------
    def _seed_paged_slots(self, info, slot_ids: List[int]):
        """Point ``slot_ids`` at the request's prompt pages.

        Full prompt pages are written to the pool once per request and
        *shared* (refcounted) across its candidates; the partially-filled
        tail page — the first page any candidate will write into, i.e.
        the CoW divergence point — is copied per candidate. Dense
        (non-paged: windowed attn / SSM / RG-LRU) entries scatter as in
        the contiguous path."""
        cache = self.state.cache
        row = info["cache_row"]
        L = int(row["pos"][0])                   # prompt incl. evidence
        ps = self.page_size
        assert L + self.max_new <= self.cache_len, \
            f"prompt {L} + max_new {self.max_new} overflows paged cache " \
            f"of {self.cache_len} (paged KV does not ring-wrap)"
        full, tail_len = divmod(L, ps)
        if "prompt_pages" not in info:
            # one pool hold per request, released when the request finishes
            info["prompt_pages"] = self.pool.alloc(full)
            cache = self._write_pages(cache, row, info["prompt_pages"], 0)
        bt_rows = np.zeros((len(slot_ids), self.pages_per_slot), np.int32)
        tails = []
        for j, s in enumerate(slot_ids):
            pages = list(info["prompt_pages"])
            self.pool.share(pages)
            if tail_len:
                tail = self.pool.alloc(1)
                tails += tail
                pages += tail
            self._slot_pages[s] = pages
            self._slot_pos[s] = L
            future = self._pages_per_candidate(L) - (1 if tail_len else 0)
            self._slot_reserved[s] = future
            self._reserved += future
            bt_rows[j, :len(pages)] = pages
        if tails:
            # every candidate's tail page holds the same prompt bytes:
            # one broadcast scatter, not one full-pool copy per candidate
            cache = self._write_pages(cache, row, tails, full * ps,
                                      broadcast=True)
        idx = jnp.asarray(slot_ids)
        cache = {**cache,
                 "block_table": cache["block_table"].at[idx].set(
                     jnp.asarray(bt_rows)),
                 "pos": cache["pos"].at[idx].set(jnp.int32(L))}
        return self._scatter_dense_entries(cache, row, slot_ids)

    def _pages_per_candidate(self, prompt_len: int) -> int:
        """Pages a candidate may allocate beyond the shared prompt pages:
        its private tail copy plus every boundary crossed while decoding
        up to ``max_new`` tokens."""
        ps = self.page_size
        total = -((prompt_len + self.max_new) // -ps)        # ceil
        return total - prompt_len // ps

    def _paged_affordable(self, info, want: int) -> int:
        """How many candidates of this request fit in the pool right now
        (free pages minus reservations held by running candidates)."""
        L = int(info["cache_row"]["pos"][0])
        per_cand = self._pages_per_candidate(L)
        need_hold = 0 if "prompt_pages" in info else L // self.page_size
        avail = self.pool.free_pages - self._reserved - need_hold
        return max(0, min(want, avail // max(per_cand, 1)))

    def _write_pages(self, cache, row, pages: List[int], start: int,
                     broadcast: bool = False):
        """Copy prefill KV of the 1-row dense prefill cache into the given
        pool pages, every attention layer at once (stacked super entries +
        tail). Consecutive spans per page by default; ``broadcast=True``
        writes the single page-sized span at ``start`` into ALL pages
        (identical CoW tail copies for a round's candidates)."""
        if not pages:
            return cache
        n, ps = len(pages), self.page_size
        span = ps if broadcast else n * ps
        pg = jnp.asarray(pages)

        def seed(pool, rk):
            if pool.ndim == 5:        # stacked: (n_super, P, ps, Hkv, hd)
                seg = jax.lax.dynamic_slice_in_dim(rk[:, 0], start, span,
                                                   axis=1)
                seg = seg.reshape(pool.shape[0], -1, *pool.shape[2:])
                if broadcast:
                    seg = jnp.broadcast_to(seg, (pool.shape[0], n)
                                           + pool.shape[2:])
                return pool.at[:, pg].set(seg.astype(pool.dtype))
            seg = jax.lax.dynamic_slice_in_dim(rk[0], start, span, axis=0)
            seg = seg.reshape(-1, *pool.shape[1:])
            if broadcast:
                seg = jnp.broadcast_to(seg, (n,) + pool.shape[1:])
            return pool.at[pg].set(seg.astype(pool.dtype))

        def seed_entries(entries, row_entries):
            out = []
            for ce, re_ in zip(entries, row_entries):
                if isinstance(ce, dict) and "k_pages" in ce:
                    ce = {"k_pages": seed(ce["k_pages"], re_["k"]),
                          "v_pages": seed(ce["v_pages"], re_["v"])}
                out.append(ce)
            return tuple(out)

        return {**cache,
                "super": seed_entries(cache["super"], row["super"]),
                "tail": seed_entries(cache["tail"], row["tail"])}

    def _scatter_dense_entries(self, cache, row, slot_ids: List[int]):
        """Scatter the non-paged cache entries (windowed attn rings, SSM
        and RG-LRU states) of the prefill row into the given slots.
        Axes follow ``_cache_batch_axis``: "super" leaves are
        layer-stacked (batch at 1), tail leaves are per-slot (batch 0)."""
        idx = jnp.asarray(slot_ids)

        def scatter_entries(entries, row_entries, ax):
            out = []
            for ce, re_ in zip(entries, row_entries):
                if not (isinstance(ce, dict) and "k_pages" in ce):
                    ce = jax.tree.map(
                        lambda b, r: self._scat_rows(b, r, idx, ax), ce, re_)
                out.append(ce)
            return tuple(out)

        return {**cache,
                "super": scatter_entries(cache["super"], row["super"], 1),
                "tail": scatter_entries(cache["tail"], row["tail"], 0)}

    def _alloc_step_pages(self):
        """Before each decode step, hand a fresh page to every live slot
        whose next write crosses a page boundary, and mirror the
        allocation into the device block table."""
        rows, cols, vals = [], [], []
        for s in range(self.B):
            if self._slot_req[s] < 0:
                continue
            p = int(self._slot_pos[s])
            if p % self.page_size == 0:
                li = p // self.page_size
                if li >= self.pages_per_slot:
                    raise RuntimeError(
                        f"slot {s} ran past the paged cache "
                        f"({p} >= {self.cache_len})")
                page = self.pool.alloc(1)[0]
                self._slot_pages[s].append(page)
                if self._slot_reserved[s] > 0:
                    self._slot_reserved[s] -= 1
                    self._reserved -= 1
                rows.append(s)
                cols.append(li)
                vals.append(page)
            self._slot_pos[s] += 1
        if rows:
            cache = self.state.cache
            bt = cache["block_table"].at[
                jnp.asarray(rows), jnp.asarray(cols)].set(
                    jnp.asarray(vals, jnp.int32))
            self.state = self.state._replace(
                cache={**cache, "block_table": bt})

    def kv_stats(self) -> Dict[str, Any]:
        """Pool accounting incl. resident KV bytes vs. the dense
        worst case (slots × cache_len) the paged layout replaces."""
        assert self.paged
        stats = self.pool.stats()

        def bytes_per_page(leaf):
            P = leaf.shape[1] if leaf.ndim == 5 else leaf.shape[0]
            return leaf.size // P * leaf.dtype.itemsize

        bpp = 0
        for entries in (self.state.cache["super"], self.state.cache["tail"]):
            for e in entries:
                if isinstance(e, dict) and "k_pages" in e:
                    bpp += bytes_per_page(e["k_pages"])
                    bpp += bytes_per_page(e["v_pages"])
        stats["bytes_per_page"] = bpp
        stats["resident_kv_bytes"] = stats["in_use"] * bpp
        stats["peak_kv_bytes"] = stats["max_in_use"] * bpp
        stats["dense_equiv_bytes"] = self.B * self.pages_per_slot * bpp
        return stats

    def _admit(self, req: Request, slot_ids: List[int], bias_row=None,
               first_logits=None):
        """Seed slots with the request's prompt cache and sample the first
        token of each candidate from the prefill logits."""
        info = self._reqs[req.uid]
        st = self.state
        if self.paged:
            cache = self._seed_paged_slots(info, slot_ids)
        else:
            cache = self._scatter_cache_rows(st.cache, info["cache_row"],
                                             slot_ids)
        idx = jnp.asarray(slot_ids)
        n = len(slot_ids)

        self.key, *keys = jax.random.split(self.key, n + 1)
        lg = info["prefill_logits"]                      # (1, V) fp32
        bias = info.get("bias")
        first_toks, first_lps = [], []
        for i in range(n):
            b = bias if bias is not None else None
            greedy = jnp.asarray([self.mode == "greedy"])
            tok, lp = sample_token(keys[i], lg, self.sampling,
                                   bias=b, greedy=greedy)
            first_toks.append(int(tok[0]))
            first_lps.append(float(lp[0]))

        toks = jnp.asarray(first_toks, jnp.int32)
        lps = jnp.asarray(first_lps, jnp.float32)
        h0 = info["prefill_hidden"]                      # (1, d) fp32
        hn0 = h0 / (jnp.linalg.norm(h0, axis=-1, keepdims=True) + 1e-8)
        V, d = self.V, self.d

        emb_t = jnp.take(self.params["embed"]["table"], toks, axis=0).astype(jnp.float32)
        if self.has_evidence:
            emb_n = emb_t / (jnp.linalg.norm(emb_t, axis=-1, keepdims=True) + 1e-8)
            ev = info["evid_row"]                        # (1, Ne, d) normalized
            a0 = jnp.mean(jnp.einsum("nd,bd->bn", ev[0], emb_n), axis=-1)
        else:
            a0 = jnp.zeros((n,), jnp.float32)

        new = self.state._replace(
            cache=cache,
            last_token=st.last_token.at[idx].set(toks),
            token_counts=st.token_counts.at[idx].set(
                jax.nn.one_hot(toks, V, dtype=jnp.float32)),
            sum_lp=st.sum_lp.at[idx].set(lps),
            n_tok=st.n_tok.at[idx].set(1),
            prev_h=st.prev_h.at[idx].set(jnp.repeat(hn0, n, axis=0)),
            sum_coh=st.sum_coh.at[idx].set(0.0),
            sum_emb=st.sum_emb.at[idx].set(jnp.zeros((n, d))),
            align_sum=st.align_sum.at[idx].set(a0),
            active=st.active.at[idx].set(True),
            out_buf=st.out_buf.at[idx].set(
                jnp.zeros((n, self.max_new), jnp.int32).at[:, 0].set(toks)),
            bias=st.bias.at[idx].set(
                jnp.repeat(bias if bias is not None else jnp.zeros((1, V)), n, axis=0)),
            greedy=st.greedy.at[idx].set(self.mode == "greedy"),
        )
        self.state = new
        for s in slot_ids:
            self._slot_req[s] = req.uid
            self._slot_cand[s] = self._next_cand
            info["cand_slots"].append((self._next_cand, s))
            self._next_cand += 1

    def _prefill_request(self, req: Request):
        prompt = jnp.asarray(req.prompt, jnp.int32)[None, :]
        cache_row = self.model.make_cache(1, self.cache_len, self._dtype)
        ev = None
        if req.evidence is not None:
            ev = jnp.asarray(req.evidence, self._dtype)[None]
        lg, h, cache_row = self._prefill_fn(self.params, prompt, cache_row, ev)
        info = {
            "req": req,
            "cache_row": cache_row,
            "prefill_logits": lg.astype(jnp.float32),
            "prefill_hidden": h.astype(jnp.float32),
            "camd": ctrl.init_state(self.camd, self.d, self.V),
            "bias": None,
            "round": 0,
            "cand_slots": [],
            "records": {},
            "align_const": 0.0,
            "done": False,
        }
        if self.has_evidence and req.evidence is not None:
            evp = jnp.asarray(req.evidence, jnp.float32)
            if "evidence_proj" in self.params:
                from repro.models.layers import dense
                evp = dense(jax.tree.map(lambda x: x.astype(jnp.float32),
                                         self.params["evidence_proj"]), evp)
            evn = evp / (jnp.linalg.norm(evp, axis=-1, keepdims=True) + 1e-8)
            info["evid_row"] = evn[None]
            # Eq. 8 term 2: text-evidence ↔ visual-evidence consistency —
            # prompt token embeddings vs evidence features, constant per req.
            temb = jnp.take(self.params["embed"]["table"],
                            prompt[0], axis=0).astype(jnp.float32)
            temb = temb / (jnp.linalg.norm(temb, axis=-1, keepdims=True) + 1e-8)
            sim = temb @ evn.T                               # (L, Ne)
            info["align_const"] = float(jnp.mean(jnp.max(sim, axis=-1)))
        else:
            info["evid_row"] = jnp.zeros((1, 1, self.d), jnp.float32)
        self._reqs[req.uid] = info

    def _free_slots(self) -> List[int]:
        return [i for i in range(self.B) if self._slot_req[i] < 0]

    def _per_round(self) -> int:
        if self.mode == "greedy":
            return 1
        if self.mode == "camd":
            return self.camd.samples_per_round
        return min(self.n_candidates, self.B)

    def _schedule(self):
        """Fill free slots: queued requests first, then next rounds.

        Paged backpressure: a request is only admitted when the pool can
        cover its candidates' worst-case pages (``_paged_affordable``);
        otherwise it waits in the queue / stays pending until running
        candidates finish and return pages."""
        free = self._free_slots()
        while free and self._queue:
            req = self._queue[0]
            if req.uid not in self._reqs:
                self._prefill_request(req)
            take = min(self._per_round(), len(free))
            if self.paged:
                take = self._paged_affordable(self._reqs[req.uid], take)
                if take <= 0:
                    break             # wait for pages, keep queue order
            self._queue.pop(0)
            ids, free = free[:take], free[take:]
            self._admit(req, ids)
        # continuing requests wanting another round
        for uid, info in self._reqs.items():
            if info["done"] or info.get("pending_round") is not True:
                continue
            if not free:
                break
            take = min(self._needed(info), len(free))
            if self.paged:
                take = self._paged_affordable(info, take)
            if take <= 0:
                continue
            ids, free = free[:take], free[take:]
            info["pending_round"] = False
            self._admit(info["req"], ids)

    def _needed(self, info) -> int:
        if self.mode == "camd":
            return self.camd.samples_per_round
        done_cands = len(info["records"])
        running = sum(1 for _, s in info["cand_slots"]
                      if self._slot_req[s] == info["req"].uid)
        return max(0, self.n_candidates - done_cands - running)

    # ------------------------------------------------------------------
    def _finish_candidate(self, slot: int):
        uid = int(self._slot_req[slot])
        cand = int(self._slot_cand[slot])
        info = self._reqs[uid]
        st = self.state
        n = int(st.n_tok[slot])
        rec = {
            "uid": cand,
            "tokens": np.asarray(st.out_buf[slot])[:n],
            "sum_lp": float(st.sum_lp[slot]),
            "n": n,
            "sum_coh": float(st.sum_coh[slot]),
            "emb": np.asarray(st.sum_emb[slot]) / max(n, 1),
            "align": float(st.align_sum[slot]) / max(n, 1),
            "counts": np.asarray(st.token_counts[slot]),
        }
        # Eq. 12 evidence-weighted score from the incremental aggregates
        s_gen = rec["sum_lp"] / max(n, 1)
        s_coh = rec["sum_coh"] / max(n - 1, 1)
        s_align = 0.5 * (rec["align"] + info["align_const"]) if self.has_evidence else 0.0
        rec["score"] = s_gen + self.camd.lambda_g * s_align + self.camd.lambda_c * s_coh
        info["records"][cand] = rec
        self._slot_req[slot] = -1
        self._slot_cand[slot] = -1
        self.total_tokens += n
        if self.paged:
            # return the candidate's pages (shared prompt pages just drop
            # a holder) and quarantine the slot's block table so its dead
            # writes land on page 0.
            self.pool.free(self._slot_pages[slot])
            self._slot_pages[slot] = []
            self._reserved -= int(self._slot_reserved[slot])
            self._slot_reserved[slot] = 0
            cache = self.state.cache
            bt = cache["block_table"].at[slot].set(0)
            self.state = self.state._replace(
                cache={**cache, "block_table": bt})

        # round complete when no slots of this request remain active
        if not any(self._slot_req[s] == uid for s in range(self.B)):
            self._finish_round(uid)

    def _finish_round(self, uid: int):
        info = self._reqs[uid]
        round_recs = [info["records"][c] for c, _ in info["cand_slots"]
                      if c in info["records"] and
                      "scored" not in info["records"][c]]
        R = self._per_round()
        if not round_recs:
            return
        for r in round_recs:
            r["scored"] = True
        pad = R - len(round_recs)
        recs = round_recs + round_recs[:1] * pad if pad > 0 else round_recs[:R]

        inp = ctrl.RoundInputs(
            scores=jnp.asarray([r["score"] for r in recs], jnp.float32),
            embs=jnp.asarray(np.stack([r["emb"] for r in recs])),
            token_counts=jnp.asarray(np.stack([r["counts"] for r in recs])),
            lengths=jnp.asarray([r["n"] for r in recs], jnp.int32),
            valid=jnp.asarray([True] * len(round_recs) + [False] * max(pad, 0)),
            uids=jnp.asarray([r["uid"] for r in recs], jnp.int32),
        )
        info["camd"], bias = self._round_fn(info["camd"], inp)
        info["round"] += 1
        if self.mode == "camd":
            info["bias"] = bias[None]
            stopped = bool(info["camd"].stopped)
        else:
            info["bias"] = None
            stopped = len(info["records"]) >= self.n_candidates
        if stopped:
            info["done"] = True
            info["cache_row"] = None  # free the prompt cache
            if self.paged and "prompt_pages" in info:
                self.pool.free(info.pop("prompt_pages"))
        else:
            info["pending_round"] = True

    # ------------------------------------------------------------------
    def run(self) -> List[Result]:
        results = []
        self._schedule()
        evid = jnp.zeros((self.B, 1, self.d), jnp.float32)
        if self.has_evidence:
            evid = self._gather_evid()
        while True:
            if not bool(jnp.any(self.state.active)):
                if self._queue or any(not i["done"] and i.get("pending_round")
                                      for i in self._reqs.values()):
                    self._schedule()
                    if self.paged and not bool(jnp.any(self.state.active)):
                        # nothing running and nothing admissible: the pool
                        # cannot cover even one candidate of the waiting
                        # work (FIFO head-of-line) — a sizing error, not a
                        # transient.
                        blocked = self._queue[0].uid if self._queue else \
                            next(uid for uid, i in self._reqs.items()
                                 if not i["done"])
                        done_n = sum(1 for i in self._reqs.values()
                                     if i["done"])
                        raise RuntimeError(
                            f"paged KV pool ({self.pool.num_pages} pages of "
                            f"{self.page_size}) cannot admit request "
                            f"{blocked} ({done_n} completed results "
                            f"discarded) — raise num_pages or lower "
                            f"max_new_tokens/prompt lengths")
                    if self.has_evidence:
                        evid = self._gather_evid()
                    continue
                break
            self.key, k = jax.random.split(self.key)
            if self.paged:
                self._alloc_step_pages()
            self.state, done = self._step_fn(self.params, self.state, k, evid)
            self.total_steps += 1
            done_np = np.asarray(done)
            if done_np.any():
                for s in np.nonzero(done_np)[0]:
                    self._finish_candidate(int(s))
                self._schedule()
                if self.has_evidence:
                    evid = self._gather_evid()
        for uid, info in self._reqs.items():
            results.append(self._result(uid))
        return results

    def _gather_evid(self):
        rows = []
        for s in range(self.B):
            uid = int(self._slot_req[s])
            if uid >= 0 and "evid_row" in self._reqs[uid]:
                rows.append(self._reqs[uid]["evid_row"][0])
            else:
                rows.append(jnp.zeros_like(
                    next(iter(self._reqs.values()))["evid_row"][0])
                    if self._reqs else jnp.zeros((1, self.d)))
        # pad rows to equal Ne
        ne = max(r.shape[0] for r in rows)
        rows = [jnp.pad(r, ((0, ne - r.shape[0]), (0, 0))) for r in rows]
        return jnp.stack(rows)

    def _result(self, uid: int) -> Result:
        info = self._reqs[uid]
        cs = info["camd"]
        recs = list(info["records"].values())
        if self.mode == "self_consistency":
            # majority cluster -> best member (sizes from the cluster table)
            sizes = np.asarray(cs.table.sizes)
            best_k = int(np.argmax(sizes))
            # fall back to global best score if cluster bookkeeping is empty
            best = max(recs, key=lambda r: (0, r["score"]))
            best_uid = int(cs.best_uid) if int(cs.best_uid) >= 0 else best["uid"]
            chosen = info["records"].get(best_uid, best)
        else:
            bu = int(cs.best_uid)
            chosen = info["records"].get(bu) or max(recs, key=lambda r: r["score"])
        return Result(
            uid=uid,
            tokens=chosen["tokens"],
            n_candidates=len(recs),
            tokens_spent=int(sum(r["n"] for r in recs)),
            rounds=info["round"],
            p_star=float(cs.p_star),
            best_score=float(cs.best_score),
            stopped_early=(self.mode == "camd" and bool(cs.stopped)
                           and float(cs.p_star) >= 1.0 - self.camd.delta),
            candidates=[{k: v for k, v in r.items() if k not in ("counts", "emb")}
                        for r in recs],
        )
