"""Mixture-of-Experts MLP with GShard/Switch-style capacity dispatch.

Why capacity dispatch (vs. sort + ragged matmul): under ``pjit`` the
dispatch/combine einsums are what GSPMD turns into the expert-parallel
all-to-all when the expert dim of the weights is sharded — it is the
TPU-native SPMD formulation (GShard, Switch, MaxText's dropped path).
Tokens are processed in fixed-size groups so the one-hot dispatch tensor
stays ``O(g·k·cf)`` per token instead of quadratic in the per-device batch.

Top-k routing with renormalized gates, capacity factor with token dropping,
Switch-style load-balance auxiliary loss and router z-loss, optional shared
(always-on) experts (Kimi-K2 style).
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import dense_init, mlp, mlp_init

DEFAULT_GROUP = 256
DEFAULT_CAPACITY_FACTOR = 1.25


def _prod_axes(axes) -> int:
    """Product of mesh axis sizes for the current abstract mesh; falls back
    to 1 (constraint becomes a no-op) outside a mesh."""
    try:
        import jax
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or not mesh.shape:
            return 1
        return int(__import__("numpy").prod([mesh.shape[a] for a in axes]))
    except Exception:
        return 1


def moe_init(key, cfg: ModelConfig, dtype=jnp.float32):
    e = cfg.moe
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    d, f, E = cfg.d_model, e.expert_d_ff, e.num_experts
    scale = d ** -0.5
    p = {
        "router": dense_init(kr, d, E, jnp.float32),  # router kept in fp32
        "w_gate": (jax.random.normal(kg, (E, d, f)) * scale).astype(dtype),
        "w_up": (jax.random.normal(ku, (E, d, f)) * scale).astype(dtype),
        "w_down": (jax.random.normal(kd, (E, f, d)) * f ** -0.5).astype(dtype),
    }
    if cfg.mlp_activation != "swiglu":
        del p["w_up"]
    if e.num_shared_experts:
        p["shared"] = mlp_init(ks, d, e.num_shared_experts * f,
                               cfg.mlp_activation, dtype)
    return p


def _capacity(g: int, top_k: int, num_experts: int, cf: float) -> int:
    c = int(math.ceil(g * top_k / num_experts * cf))
    return max(8, -(-c // 8) * 8)  # >=8, rounded up to a multiple of 8


def moe_apply(params, cfg: ModelConfig, x, *, capacity_factor: float = None,
              group_size: int = None, router_key=None
              ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: (..., d). Returns (out, aux) where aux carries router losses."""
    e = cfg.moe
    capacity_factor = capacity_factor or e.capacity_factor
    group_size = group_size or e.group_size
    E, k = e.num_experts, e.top_k
    orig_shape = x.shape
    d = orig_shape[-1]
    x = x.reshape(-1, d)
    T = x.shape[0]
    g = min(group_size, T)
    pad = (-T) % g
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad, d), x.dtype)], axis=0)
    G = x.shape[0] // g
    xg = x.reshape(G, g, d)

    logits = (xg.astype(jnp.float32) @ params["router"]["kernel"])  # (G,g,E)
    if router_key is not None and e.router_noise > 0:
        logits = logits + e.router_noise * jax.random.normal(router_key, logits.shape)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)                  # (G,g,k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    C = _capacity(g, k, E, capacity_factor)
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)        # (G,g,k,E)

    # priority order: first choices of all tokens beat second choices etc.
    flat = onehot.transpose(0, 2, 1, 3).reshape(G, k * g, E)       # (G,a,E)
    pos = jnp.cumsum(flat, axis=1) * flat - flat                   # 0-based slot
    keep = (pos < C) * flat
    # Fold the k choice slots back into per-TOKEN dispatch/combine tensors
    # (GShard layout): a token's (expert, capacity) targets are distinct, so
    # the k one-hots sum to a single 0/1 tensor. This keeps the dispatch
    # einsum at O(E·C·d) per token instead of O(k·E·C·d) — measured 8×
    # fewer dispatch FLOPs on kimi-k2 (k=8).
    pos_k = pos.reshape(G, k, g, E)
    keep_k = keep.reshape(G, k, g, E)
    disp = jnp.zeros((G, g, E, C), x.dtype)
    combine = jnp.zeros((G, g, E, C), x.dtype)
    gate_k = gate_vals.transpose(0, 2, 1)                          # (G,k,g)
    for j in range(k):
        oh = jax.nn.one_hot(pos_k[:, j].astype(jnp.int32), C, dtype=x.dtype) \
            * keep_k[:, j][..., None].astype(x.dtype)              # (G,g,E,C)
        disp = disp + oh
        combine = combine + oh * gate_k[:, j][:, :, None, None].astype(x.dtype)

    expert_in = jnp.einsum("gsec,gsd->gecd", disp, xg)             # (G,E,C,d)

    # Expert parallelism: pin the dispatched activations to E-sharding on
    # the expert axes. This turns the dispatch/combine einsums into the
    # GShard all-to-all; without it GSPMD all-gathers the expert weights
    # over the data axis (measured: 14.6 TB/device/step on kimi-k2 train).
    from jax.sharding import PartitionSpec as P
    from repro.distributed.context import get_expert_axes, maybe_constrain
    ep = get_expert_axes()
    e_spec = ep if E % _prod_axes(ep) == 0 else None
    expert_in = maybe_constrain(expert_in, P(None, e_spec, None, None))

    act = {"swiglu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[cfg.mlp_activation]
    if cfg.mlp_activation == "swiglu":
        h = act(jnp.einsum("gecd,edf->gecf", expert_in, params["w_gate"])) \
            * jnp.einsum("gecd,edf->gecf", expert_in, params["w_up"])
    else:
        h = act(jnp.einsum("gecd,edf->gecf", expert_in, params["w_gate"]))
    expert_out = jnp.einsum("gecf,efd->gecd", h, params["w_down"])
    expert_out = maybe_constrain(expert_out, P(None, e_spec, None, None))

    out = jnp.einsum("gsec,gecd->gsd", combine, expert_out)        # (G,g,d)
    out = out.reshape(-1, d)
    if pad:
        out = out[:T]

    if "shared" in params:
        out = out + mlp(params["shared"], x[:T] if pad else x, cfg.mlp_activation)

    # --- auxiliary losses (Switch Transformer eq. 4-6) -----------------------
    frac_tokens = jnp.mean(jax.nn.one_hot(gate_idx[..., 0], E), axis=(0, 1))
    mean_probs = jnp.mean(probs, axis=(0, 1))
    lb_loss = E * jnp.sum(frac_tokens * mean_probs)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    dropped = 1.0 - jnp.sum(keep) / (G * g * k)
    aux = {"moe_lb_loss": lb_loss, "moe_z_loss": z_loss,
           "moe_drop_frac": dropped}
    return out.reshape(orig_shape), aux


def moe_apply_dense(params, cfg: ModelConfig, x):
    """Dropless dense oracle: computes *all* experts for every token and
    combines with the same renormalized top-k gates. Used as the reference
    in tests (must match ``moe_apply`` when capacity_factor is large)."""
    e = cfg.moe
    orig_shape = x.shape
    x = x.reshape(-1, orig_shape[-1])
    logits = x.astype(jnp.float32) @ params["router"]["kernel"]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, e.top_k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    full_gate = jnp.sum(
        jax.nn.one_hot(gate_idx, e.num_experts) * gate_vals[..., None], axis=-2)

    act = {"swiglu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[cfg.mlp_activation]
    if cfg.mlp_activation == "swiglu":
        h = act(jnp.einsum("td,edf->tef", x, params["w_gate"])) \
            * jnp.einsum("td,edf->tef", x, params["w_up"])
    else:
        h = act(jnp.einsum("td,edf->tef", x, params["w_gate"]))
    per_expert = jnp.einsum("tef,efd->ted", h, params["w_down"])
    out = jnp.einsum("te,ted->td", full_gate.astype(x.dtype), per_expert)
    if "shared" in params:
        out = out + mlp(params["shared"], x, cfg.mlp_activation)
    return out.reshape(orig_shape)


def moe_apply_sparse(params, cfg: ModelConfig, x, *,
                     capacity_factor: float = None
                     ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Sort/scatter-based MoE (einsum-free dispatch).

    The capacity-einsum path costs O(E·C) per token in dispatch compute
    and memory (EXPERIMENTS.md §Perf); this path is O(k log k) per token:
    sort assignments by expert, compute within-expert ranks, scatter token
    rows into (E, C, d) slots, gather back per (token, choice). It is the
    host-side counterpart of the ``kernels.moe_dispatch`` Pallas kernels
    and the building block for a shard_map expert-parallel deployment.

    Capacity priority differs slightly from the GShard path (token order
    within an expert instead of choice-rank order); in the dropless
    regime both match the dense oracle exactly.
    """
    e = cfg.moe
    capacity_factor = capacity_factor or e.capacity_factor
    E, k = e.num_experts, e.top_k
    orig_shape = x.shape
    d = orig_shape[-1]
    x = x.reshape(-1, d)
    T = x.shape[0]
    C = _capacity(T, k, E, capacity_factor)

    logits = x.astype(jnp.float32) @ params["router"]["kernel"]     # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)                   # (T,k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # sort the T*k assignments by expert id
    eid = gate_idx.reshape(-1)                                      # (T*k,)
    order = jnp.argsort(eid, stable=True)
    eid_sorted = eid[order]
    counts = jnp.bincount(eid, length=E)                            # (T*k -> E)
    starts = jnp.cumsum(counts) - counts                            # exclusive
    rank = jnp.arange(T * k) - starts[eid_sorted]                   # within-expert
    keep = rank < C
    slot_sorted = jnp.where(keep, eid_sorted * C + rank, -1)        # flat E*C
    # invert the permutation: slot id per original (token, choice) pair
    slot = jnp.zeros((T * k,), jnp.int32).at[order].set(
        slot_sorted.astype(jnp.int32))

    token_of_pair = jnp.arange(T * k) // k
    expert_in = jnp.zeros((E * C, d), x.dtype).at[
        jnp.maximum(slot, 0)].set(
        jnp.where((slot >= 0)[:, None], x[token_of_pair], 0.0))
    expert_in = expert_in.reshape(E, C, d)

    act = {"swiglu": jax.nn.silu, "gelu": jax.nn.gelu,
           "relu": jax.nn.relu}[cfg.mlp_activation]
    if cfg.mlp_activation == "swiglu":
        h = act(jnp.einsum("ecd,edf->ecf", expert_in, params["w_gate"])) \
            * jnp.einsum("ecd,edf->ecf", expert_in, params["w_up"])
    else:
        h = act(jnp.einsum("ecd,edf->ecf", expert_in, params["w_gate"]))
    expert_out = jnp.einsum("ecf,efd->ecd", h, params["w_down"])
    flat_out = expert_out.reshape(E * C, d)

    # gather back and combine with gates
    y_pair = jnp.where((slot >= 0)[:, None],
                       flat_out[jnp.maximum(slot, 0)], 0.0)         # (T*k,d)
    gates_pair = gate_vals.reshape(-1)
    out = jnp.sum((y_pair * gates_pair[:, None]).reshape(T, k, d), axis=1)

    if "shared" in params:
        out = out + mlp(params["shared"], x, cfg.mlp_activation)

    frac_tokens = jnp.mean(jax.nn.one_hot(gate_idx[:, 0], E), axis=0)
    mean_probs = jnp.mean(probs, axis=0)
    aux = {"moe_lb_loss": E * jnp.sum(frac_tokens * mean_probs),
           "moe_z_loss": jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2),
           "moe_drop_frac": 1.0 - jnp.mean(keep)}
    return out.reshape(orig_shape), aux
