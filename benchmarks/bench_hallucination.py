"""Table 1 hallucination columns (POPE/CHAIR analog).

The paper reports >5-pt hallucination reductions from CAMD. Mechanism:
the S_align evidence term down-weights candidates whose content is not
grounded in the visual evidence, so the coverage posterior (and the final
selection) shifts away from hallucinated clusters. We simulate exactly
that causal chain: wrong candidates are "hallucinated" with probability
h; hallucinated candidates have depressed alignment observables; we
measure the hallucination rate of the SELECTED answer with the evidence
term off (λ_g=0 — plain confidence decoding) vs on (λ_g=0.9 — CAMD).

The inner loop is the CAMD coverage rule in closed form: candidates carry
exact answer ids, so clustering-by-answer and the Eq. 14 posterior are
computed directly in numpy (equivalent to the jitted controller for this
observable model; the controller itself is exercised by bench_fig2).
"""
from __future__ import annotations

import numpy as np

from repro.data.tasks import SimulatedDecoder


class HallucinationSim(SimulatedDecoder):
    def __init__(self, lambda_g: float, h_rate: float = 0.6, **kw):
        super().__init__(**kw)
        self.lg = lambda_g
        self.h_rate = h_rate

    def trial(self, s, k=1):
        out = super().trial(s, k)
        c = out["correct"].astype(np.float64)
        halluc = (~out["correct"]) & (self.rng.random(k) < self.h_rate)
        out["halluc"] = halluc
        s_gen = 0.5 * c + 0.6 * self.rng.standard_normal(k)
        # alignment: high when grounded, strongly depressed if hallucinated
        s_align = 0.8 * c - 1.4 * halluc + 0.6 * self.rng.standard_normal(k)
        out["score"] = s_gen + self.lg * s_align
        return out


def _run(lambda_g: float, n: int, seed: int, *, delta=0.05, scale=1.2,
         max_samples=24, R=2):
    sim = HallucinationSim(lambda_g, tail="heavy", alpha=0.5, seed=seed)
    diffs = np.concatenate([
        sim.rng.uniform(0.5, 0.9, n // 2),
        sim.sample_difficulty(n - n // 2)])
    chosen_halluc, acc, spent = [], [], []
    for s in diffs:
        scores, answers, hallucs, corrects = [], [], [], []
        stop = False
        while not stop and len(scores) < max_samples:
            o = sim.trial(float(s), R)
            scores += list(o["score"] * scale)
            answers += list(o["answer"])
            hallucs += list(o["halluc"])
            corrects += list(o["correct"])
            # Eq. 14 posterior over answer clusters (exact clustering)
            sc = np.asarray(scores)
            ans = np.asarray(answers)
            w = np.exp(sc - sc.max())
            mass = {a: w[ans == a].sum() for a in set(ans)}
            p_star = max(mass.values()) / w.sum()
            stop = p_star >= 1 - delta and len(scores) >= 2
        j = int(np.argmax(scores))
        chosen_halluc.append(bool(hallucs[j]))
        acc.append(bool(corrects[j]))
        spent.append(len(scores) * sim.tokens_per_sample)
    return float(np.mean(chosen_halluc)), float(np.mean(acc)), \
        float(np.mean(spent))


def run(n_instances: int = 400, seed: int = 0, verbose: bool = True):
    h_off, acc_off, t_off = _run(0.0, n_instances, seed)
    h_on, acc_on, t_on = _run(0.9, n_instances, seed)
    claims = {
        "halluc_rate_no_align": h_off,
        "halluc_rate_with_align": h_on,
        "reduction_pts": (h_off - h_on) * 100,
        "accuracy_no_align": acc_off,
        "accuracy_with_align": acc_on,
        "align_reduces_hallucination": bool(h_on < h_off - 0.02),
    }
    if verbose:
        print(f"  selected-answer hallucination: λ_g=0 → {h_off:.3f}, "
              f"λ_g=0.9 → {h_on:.3f} ({claims['reduction_pts']:.1f} pt "
              f"reduction; paper: >5 pt on POPE/CHAIR)")
        print(f"  accuracy: {acc_off:.3f} → {acc_on:.3f}; "
              f"tokens {t_off:.0f} → {t_on:.0f}")
        print(f"  claim[evidence weighting reduces hallucination]: "
              f"{claims['align_reduces_hallucination']}")
    return claims


if __name__ == "__main__":
    run()
