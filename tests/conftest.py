import os

# Tests run on the single real CPU device (the dry-run alone forces 512
# placeholder devices). Cap compilation parallelism noise.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_enable_x64", False)


# ---------------------------------------------------------------------------
# Shared serving-test setup: one reduced real config ("small") and one
# hand-rolled 2-layer dense LM ("tiny"), plus engine/submit helpers —
# previously duplicated across test_engine*.py / test_prefill_bucketed.py.
# ---------------------------------------------------------------------------

@pytest.fixture(scope="session")
def small_model():
    """qwen3-0.6b reduced to CPU size — the 'real config' engine tests."""
    from repro.configs import get_config
    from repro.models import build_model
    cfg = get_config("qwen3-0.6b").reduced().with_overrides(dtype="float32")
    model = build_model(cfg, jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="session")
def tiny_model():
    """2-layer dense 64-dim LM — the fast engine-mechanics tests."""
    from repro.config import ModelConfig
    from repro.models import build_model
    cfg = ModelConfig(
        name="tiny-lm", family="dense", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=64,
        head_dim=16, tie_embeddings=True, dtype="float32")
    model = build_model(cfg, jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _mk_engine(model, params, **kw):
    """ServeEngine with the shared test defaults; kwargs override."""
    from repro.config import CAMDConfig, SamplingConfig
    from repro.serving import ServeEngine
    max_new = kw.pop("max_new", 8)
    defaults = dict(
        slots=6, cache_len=64,
        sampling=SamplingConfig(max_new_tokens=max_new, temperature=0.8),
        camd=CAMDConfig(samples_per_round=2, max_rounds=2, min_samples=2,
                        max_clusters=8),
        max_new_tokens=max_new, eos_id=1, seed=0)
    defaults.update(kw)
    return ServeEngine(model, params, **defaults)


def _submit(engine, cfg, n, seed=0, plen=6, uid0=0):
    rng = np.random.default_rng(seed)
    for i in range(n):
        engine.submit(_request(
            uid0 + i, rng.integers(2, cfg.vocab_size, plen).astype(np.int32)))


def _request(uid, prompt, evidence=None):
    from repro.serving import Request
    return Request(uid=uid, prompt=prompt, evidence=evidence)


@pytest.fixture(scope="session")
def mk_engine():
    return _mk_engine


@pytest.fixture(scope="session")
def submit_prompts():
    return _submit
