"""Evidence-weighted scoring (paper §4.2.1, Eq. 7-12).

All functions are batched, masked (padded tokens excluded), and pure jnp —
they run on-device inside the serving round step. The cross-modal alignment
term has a fused Pallas kernel (``repro.kernels.xmodal_score``) selected via
``impl="pallas"``; the jnp path here doubles as its oracle.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def _l2norm(x, eps=1e-8):
    return x / (jnp.linalg.norm(x, axis=-1, keepdims=True) + eps)


def generation_confidence(token_logprobs, mask):
    """Eq. 7: length-normalized sequence log-likelihood.

    token_logprobs: (..., L) log p(y_t | y_<t, x); mask: (..., L) 1=real.
    """
    m = mask.astype(jnp.float32)
    tot = jnp.sum(token_logprobs * m, axis=-1)
    n = jnp.maximum(jnp.sum(m, axis=-1), 1.0)
    return tot / n


def cross_modal_consistency(token_embs, mask, visual_feats, text_feats,
                            *, impl: str = "xla"):
    """Eq. 8-9: S_align.

    token_embs: (..., L, d) embeddings of generated tokens f_t(y_t);
    mask: (..., L); visual_feats: (Nv, d) or (..., Nv, d);
    text_feats: (Nt, d) or (..., Nt, d) — prompt-text evidence.

    G(y_t|x) = 1/2 [ mean_j cos(v_j, f(y_t)) + mean_r max_j cos(t_r, v_j) ]
    S_align   = mean_t G(y_t | x).
    (The second term is candidate-independent input consistency; it shifts
    all candidates of a request equally, exactly as in the paper.)
    """
    if impl == "pallas":
        from repro.kernels import ops
        return ops.xmodal_score(token_embs, mask, visual_feats, text_feats)
    tok = _l2norm(token_embs.astype(jnp.float32))
    vis = _l2norm(visual_feats.astype(jnp.float32))
    txt = _l2norm(text_feats.astype(jnp.float32))
    # term 1: mean over visual evidence of cos(v_j, f(y_t)), then mean over t
    sim_tv = jnp.einsum("...ld,...nd->...ln", tok, vis)      # (...,L,Nv)
    term1 = jnp.mean(sim_tv, axis=-1)                        # (...,L)
    m = mask.astype(jnp.float32)
    term1 = jnp.sum(term1 * m, axis=-1) / jnp.maximum(jnp.sum(m, axis=-1), 1.0)
    # term 2: for each text evidence token, its best visual match
    sim_rt = jnp.einsum("...rd,...nd->...rn", txt, vis)      # (...,Nt,Nv)
    term2 = jnp.mean(jnp.max(sim_rt, axis=-1), axis=-1)      # (...)
    return 0.5 * (term1 + term2)


def reasoning_coherence(hidden, mask):
    """Eq. 10-11: mean cosine similarity of consecutive hidden states.

    hidden: (..., L, d); mask: (..., L).
    """
    h = _l2norm(hidden.astype(jnp.float32))
    sims = jnp.sum(h[..., :-1, :] * h[..., 1:, :], axis=-1)  # (..., L-1)
    m = (mask[..., :-1] * mask[..., 1:]).astype(jnp.float32)
    tot = jnp.sum(sims * m, axis=-1)
    n = jnp.maximum(jnp.sum(m, axis=-1), 1.0)
    return tot / n


def evidence_weighted_score(token_logprobs, mask, *, hidden=None,
                            token_embs=None, visual_feats=None,
                            text_feats=None, lambda_g: float = 0.9,
                            lambda_c: float = 0.7, impl: str = "xla"):
    """Eq. 12: S = S_gen + λ_g S_align + λ_c S_coh.

    Terms whose inputs are unavailable (e.g. no visual evidence for a
    text-only arch) contribute zero — CAMD degrades gracefully across the
    architecture pool (DESIGN.md §5).
    """
    s = generation_confidence(token_logprobs, mask)
    if visual_feats is not None and token_embs is not None:
        tf = text_feats if text_feats is not None else token_embs
        s = s + lambda_g * cross_modal_consistency(
            token_embs, mask, visual_feats, tf, impl=impl)
    if hidden is not None:
        s = s + lambda_c * reasoning_coherence(hidden, mask)
    return s


def normalized_success(scores, valid):
    """s̃_i = softmax over valid candidates (Eq. 12, last step)."""
    masked = jnp.where(valid, scores, -jnp.inf)
    return jax.nn.softmax(masked, axis=-1)
