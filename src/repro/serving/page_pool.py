"""Host-side KV page-pool allocator for the paged serving path.

The device side holds a single shared pool of KV pages per attention
layer (``models.attention.make_paged_kv_cache``); this class owns the
*ids*: which pages are free, and how many holders reference each live
page. Reference counting is what makes candidate prefill cheap — a
request's R candidates `share()` the prompt's full pages and only copy
the partially-filled tail page (copy-on-write at the first diverging
token), so prompt KV is resident once per request, not once per
candidate.

The optional **cross-request prefix cache** (``prefix_cache=True``)
generalizes that sharing across requests and across time: page-aligned
prompt prefixes are content-hashed into a chain (page i's key commits to
pages 0..i's tokens, radix-tree style), and the cache itself holds one
refcount on each registered page so finished requests' prompt KV stays
resident. A later request whose prompt starts with the same bytes
shares those pages CoW — its prefill skips them entirely. Cached-only
pages (refcount 1, held by nobody but the cache) are *evictable*:
``alloc`` reclaims them LRU-leaf-first under pool pressure, so the
cache can never starve live traffic.

Page 0 is reserved as the quarantine page: idle slots' block tables
point at it and their dead writes land there. It is never allocated and
never freed.

All methods raise on misuse (double free, free of an unallocated page,
over-allocation) rather than corrupting the table — the serving tests
lean on these invariants.
"""
from __future__ import annotations

import hashlib
from typing import Dict, Iterable, List, Optional, Sequence

import numpy as np


class PagePoolError(RuntimeError):
    pass


def prefix_page_keys(tokens, page_size: int) -> List[str]:
    """Content-hash chain over the page-aligned prefix of ``tokens``:
    key[i] = H(key[i-1] || tokens[i*ps:(i+1)*ps]), so equal keys imply
    equal prompt bytes for the whole prefix up to and including page i.
    Only *full* pages get keys (the partial tail page is per-candidate
    CoW, never shared)."""
    toks = np.ascontiguousarray(np.asarray(tokens, np.int64))
    keys: List[str] = []
    prev = b""
    for i in range(len(toks) // page_size):
        d = hashlib.sha256(
            prev + toks[i * page_size:(i + 1) * page_size].tobytes()).digest()
        keys.append(d.hex())
        prev = d
    return keys


class _Node:
    __slots__ = ("page", "parent", "children", "tick")

    def __init__(self, page: int, parent: Optional[str], tick: int):
        self.page = page
        self.parent = parent
        self.children = 0
        self.tick = tick


class PrefixCache:
    """Content-hash chain -> resident KV page map (see module docstring).

    The cache holds exactly one pool refcount per registered page; the
    pool stays the single source of truth for page liveness. Invariants
    (checked by ``PagePool.check``): every cached page has refcount >= 1,
    and every node's parent is cached (chains are prefix-closed, which
    LRU *leaf-first* eviction preserves)."""

    def __init__(self, pool: "PagePool"):
        self.pool = pool
        self._nodes: Dict[str, _Node] = {}
        self._tick = 0
        self._evictable_memo = None
        self.probes = 0        # lookup calls
        self.hits = 0          # pages reused across requests
        self.misses = 0        # lookups that fell short of a full hit
        self.hit_tokens = 0    # prefill tokens skipped
        self.insertions = 0
        self.evictions = 0

    @property
    def cached_pages(self) -> int:
        return len(self._nodes)

    def match_and_hold(self, keys: Sequence[str]) -> List[int]:
        """Pages of the longest cached prefix of ``keys``, with one
        holder added per page (the caller's request hold) and the chain
        LRU-touched. Empty list on a complete miss."""
        self._tick += 1
        self.probes += 1
        pages: List[int] = []
        for k in keys:
            node = self._nodes.get(k)
            if node is None:
                break
            pages.append(node.page)
        if len(pages) < len(keys):
            self.misses += 1
        if not pages:
            return []
        self.pool.share(pages)
        for k in keys[:len(pages)]:
            self._nodes[k].tick = self._tick
        self.hits += len(pages)
        self.hit_tokens += len(pages) * self.pool.page_size
        return pages

    def insert(self, keys: Sequence[str], pages: Sequence[int]):
        """Register ``pages`` under ``keys`` (chain order, equal length).
        New nodes take one cache hold; already-cached keys keep their
        existing page (two requests that raced the same prefix keep the
        first writer's pages — the loser's stay private to it)."""
        assert len(keys) == len(pages), (len(keys), len(pages))
        self._tick += 1
        parent: Optional[str] = None
        for k, page in zip(keys, pages):
            node = self._nodes.get(k)
            if node is None:
                self.pool.share([page])
                node = _Node(int(page), parent, self._tick)
                self._nodes[k] = node
                if parent is not None:
                    self._nodes[parent].children += 1
                self.insertions += 1
            else:
                node.tick = self._tick
            parent = k

    # -- eviction -------------------------------------------------------
    def _reclaimable_blocked(self) -> set:
        """Keys that cannot be evicted: pages some request still holds,
        plus all their ancestors (evicting an ancestor would break the
        chain under a live descendant)."""
        blocked: set = set()
        for k, node in self._nodes.items():
            if self.pool.refcount(node.page) > 1:
                p: Optional[str] = k
                while p is not None and p not in blocked:
                    blocked.add(p)
                    p = self._nodes[p].parent
        return blocked

    def evictable_pages(self) -> int:
        """Pages the cache could hand back to the pool right now.
        Memoized on the pool's mutation counter — the admission path
        calls this per decision, and the blocked-set walk is O(nodes)."""
        key = (self.pool.mutations, self._tick, len(self._nodes))
        if self._evictable_memo is not None and \
                self._evictable_memo[0] == key:
            return self._evictable_memo[1]
        val = len(self._nodes) - len(self._reclaimable_blocked())
        self._evictable_memo = (key, val)
        return val

    def evict(self, n: int) -> int:
        """Free up to ``n`` cached pages, least-recently-used leaves
        first (a leaf eviction may expose its parent as the next leaf —
        chains shrink from the deep end, staying prefix-closed)."""
        freed = 0
        while freed < n:
            victim = None
            for k, node in self._nodes.items():
                if node.children == 0 and self.pool.refcount(node.page) == 1:
                    if victim is None or node.tick < self._nodes[victim].tick:
                        victim = k
            if victim is None:
                break
            node = self._nodes.pop(victim)
            if node.parent is not None and node.parent in self._nodes:
                self._nodes[node.parent].children -= 1
            self.pool.free([node.page])
            self.evictions += 1
            freed += 1
        return freed

    def drop_all(self):
        """Release every cache hold (tests / shutdown). Pages still held
        by live requests survive with their remaining holders."""
        for node in self._nodes.values():
            self.pool.free([node.page])
        self._nodes.clear()

    def stats(self) -> dict:
        return {
            "probes": self.probes, "hits": self.hits,
            "misses": self.misses, "hit_tokens": self.hit_tokens,
            "cached_pages": self.cached_pages,
            "insertions": self.insertions, "evictions": self.evictions,
        }


class PagePool:
    def __init__(self, num_pages: int, page_size: int, *, reserved: int = 1,
                 prefix_cache: bool = False):
        if num_pages <= reserved:
            raise PagePoolError(f"pool of {num_pages} pages has no "
                                f"allocatable pages (reserved={reserved})")
        self.num_pages = num_pages
        self.page_size = page_size
        self.reserved = reserved
        # LIFO free list: recently freed pages are re-used first (their
        # contents are hot in cache and get overwritten anyway).
        self._free: List[int] = list(range(num_pages - 1, reserved - 1, -1))
        self._refs = np.zeros(num_pages, np.int64)
        self.max_in_use = 0
        # bumped on every refcount mutation (memo key for the prefix
        # cache's evictable-page computation)
        self.mutations = 0
        # frontier accounting (macro-step serving): pages handed out ahead
        # of the device loop and how many came back unconsumed.
        self.frontier_staged = 0
        self.frontier_returned = 0
        # cross-request prefix cache (None when disabled)
        self.prefix: Optional[PrefixCache] = \
            PrefixCache(self) if prefix_cache else None

    # ------------------------------------------------------------------
    @property
    def in_use(self) -> int:
        """Pages currently referenced by at least one holder."""
        return int(np.count_nonzero(self._refs))

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def refcount(self, page: int) -> int:
        return int(self._refs[page])

    def live_tokens_capacity(self) -> int:
        return self.in_use * self.page_size

    # ------------------------------------------------------------------
    def evictable(self) -> int:
        """Pages reclaimable from the prefix cache under pool pressure
        (admission-control headroom beyond the free list)."""
        return self.prefix.evictable_pages() if self.prefix is not None else 0

    def ensure_free(self, n: int):
        """Evict cached-only pages until the free list holds at least
        ``n`` pages. The serving engine calls this after every admission
        so reservations are always backed by *actually free* pages —
        evictable pages counted at admission time could otherwise be
        re-pinned by a later prefix-cache hit, turning reservation-backed
        frontier staging into a mid-decode failure."""
        if n <= len(self._free):
            return
        if self.prefix is not None:
            self.prefix.evict(n - len(self._free))
        if n > len(self._free):
            raise PagePoolError(
                f"cannot secure {n} free pages ({len(self._free)} free, "
                f"{self.evictable()} evictable of {self.num_pages})")

    def alloc(self, n: int = 1) -> List[int]:
        """Take ``n`` fresh pages (refcount 1 each). Under pressure,
        cached-only prefix pages are evicted LRU-first to cover the
        request before giving up."""
        if n < 0:
            raise PagePoolError(f"alloc({n})")
        if n > len(self._free) and self.prefix is not None:
            self.prefix.evict(n - len(self._free))
        if n > len(self._free):
            raise PagePoolError(
                f"out of KV pages: need {n}, have {len(self._free)} free of "
                f"{self.num_pages} (in use: {self.in_use}) — raise num_pages "
                f"or reduce slots/cache_len")
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._refs[p] = 1
        self.mutations += 1
        self.max_in_use = max(self.max_in_use, self.in_use)
        return pages

    def share(self, pages: Iterable[int]):
        """Add one holder to each page (prompt pages shared by a new
        candidate)."""
        for p in pages:
            if self._refs[p] <= 0:
                raise PagePoolError(f"share of unallocated page {p}")
            self._refs[p] += 1
        self.mutations += 1

    def free(self, pages: Iterable[int]):
        """Drop one holder from each page; pages reaching zero return to
        the free list (this is what lets an early-stopped easy request
        immediately fund a hard one)."""
        for p in pages:
            if p < self.reserved:
                raise PagePoolError(f"free of reserved page {p}")
            if self._refs[p] <= 0:
                raise PagePoolError(f"double free of page {p}")
            self._refs[p] -= 1
            if self._refs[p] == 0:
                self._free.append(p)
        self.mutations += 1

    # ------------------------------------------------------------------
    # Page frontiers (macro-step decode)
    # ------------------------------------------------------------------
    def stage_frontier(self, n: int) -> List[int]:
        """Reserve ``n`` pages for a slot's decode *frontier*: the pages
        the device-resident macro-step loop may advance into without host
        intervention. Staged pages are ordinary allocations (refcount 1) —
        the caller writes their ids into the (B, F) frontier array before
        launch and, after the macro-step returns, keeps the consumed
        prefix and hands the rest back via ``return_frontier``."""
        pages = self.alloc(n)
        self.frontier_staged += n
        return pages

    def return_frontier(self, pages: Iterable[int]):
        """Return staged-but-unconsumed frontier pages (slot finished or
        the macro-step early-exited before crossing into them)."""
        pages = list(pages)
        self.free(pages)
        self.frontier_returned += len(pages)

    # ------------------------------------------------------------------
    def check(self):
        """Conservation invariant: every non-reserved page is either on
        the free list (ref 0) or held (ref > 0), never both/neither."""
        free = set(self._free)
        if len(free) != len(self._free):
            raise PagePoolError("free list contains duplicates")
        for p in range(self.reserved, self.num_pages):
            held = self._refs[p] > 0
            if held == (p in free):
                raise PagePoolError(
                    f"page {p} violates conservation (refs={self._refs[p]}, "
                    f"on_free_list={p in free})")
        if any(p < self.reserved for p in free):
            raise PagePoolError("reserved page on the free list")
        if self.prefix is not None:
            for k, node in self.prefix._nodes.items():
                if self._refs[node.page] <= 0:
                    raise PagePoolError(
                        f"prefix cache maps {k[:8]} to dead page {node.page}")
                if node.parent is not None and \
                        node.parent not in self.prefix._nodes:
                    raise PagePoolError(
                        f"prefix chain broken at {k[:8]} (parent evicted)")

    def stats(self) -> dict:
        s = {
            "num_pages": self.num_pages,
            "page_size": self.page_size,
            "in_use": self.in_use,
            "free": self.free_pages,
            "max_in_use": self.max_in_use,
            "frontier_staged": self.frontier_staged,
            "frontier_returned": self.frontier_returned,
        }
        if self.prefix is not None:
            s["prefix_cache"] = self.prefix.stats()
        return s
