"""AdamW + schedules + global-norm clipping, on raw pytrees (no optax).

Optimizer state mirrors the parameter tree (m, v in fp32) so it shards
with the same logical-axis rules as the parameters (FSDP-friendly).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.config import TrainConfig


class OptState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def init_opt_state(params, dtype=jnp.float32) -> OptState:
    """``dtype=bfloat16`` halves optimizer memory (trillion-param configs);
    the update math still accumulates in fp32."""
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, dtype), params)
    return OptState(step=jnp.zeros((), jnp.int32), m=zeros,
                    v=jax.tree.map(jnp.copy, zeros))


def learning_rate(cfg: TrainConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    if cfg.schedule == "cosine":
        decay = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    elif cfg.schedule == "linear":
        decay = 1.0 - t
    else:
        decay = 1.0
    return cfg.learning_rate * warm * decay


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def adamw_update(cfg: TrainConfig, params, grads, state: OptState):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = learning_rate(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    new_m = jax.tree.map(
        lambda m, g: (b1 * m.astype(jnp.float32) + (1 - b1) * g).astype(m.dtype),
        state.m, grads)
    new_v = jax.tree.map(
        lambda v, g: (b2 * v.astype(jnp.float32) + (1 - b2) * g * g).astype(v.dtype),
        state.v, grads)

    def upd(p, m, v):
        mh = m.astype(jnp.float32) / bc1
        vh = v.astype(jnp.float32) / bc2
        delta = lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                      + cfg.weight_decay * p.astype(jnp.float32))
        return (p.astype(jnp.float32) - delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, new_m, new_v)
    return new_params, OptState(step, new_m, new_v), \
        {"grad_norm": gnorm, "lr": lr}
