"""Encoder–decoder transformer (SeamlessM4T-v2 backbone).

The speech frontend is stubbed: the encoder consumes precomputed frame
embeddings (``evidence``). The decoder is a causal transformer with
cross-attention to the encoder memory; cross K/V are computed once at
prefill and held constant through decode.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import attention as attn_lib
from repro.models.layers import (dense, dense_init, embed, embed_init, mlp,
                                 mlp_init, rmsnorm, rmsnorm_init)

Params = Dict[str, Any]


def _enc_block_init(key, cfg: ModelConfig, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": rmsnorm_init(cfg.d_model, dtype),
        "attn": attn_lib.attn_init(k1, cfg, dtype),
        "ln2": rmsnorm_init(cfg.d_model, dtype),
        "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.mlp_activation, dtype),
    }


def _dec_block_init(key, cfg: ModelConfig, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": rmsnorm_init(cfg.d_model, dtype),
        "attn": attn_lib.attn_init(k1, cfg, dtype),
        "lnx": rmsnorm_init(cfg.d_model, dtype),
        "xattn": attn_lib.attn_init(k2, cfg, dtype),
        "ln2": rmsnorm_init(cfg.d_model, dtype),
        "mlp": mlp_init(k3, cfg.d_model, cfg.d_ff, cfg.mlp_activation, dtype),
    }


def encdec_init(key, cfg: ModelConfig, dtype) -> Params:
    keys = jax.random.split(key, 6)

    def stack(init_fn, n, base):
        ks = jax.random.split(base, n)
        per = [init_fn(k, cfg, dtype) for k in ks]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *per)

    p: Params = {
        "embed": embed_init(keys[0], cfg.vocab_size, cfg.d_model, dtype),
        "enc_super": stack(_enc_block_init, cfg.num_encoder_layers, keys[1]),
        "dec_super": stack(_dec_block_init, cfg.num_layers, keys[2]),
        "enc_norm": rmsnorm_init(cfg.d_model, dtype),
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(keys[3], cfg.d_model, cfg.vocab_size, dtype)
    if cfg.evidence_dim and cfg.evidence_dim != cfg.d_model:
        p["evidence_proj"] = dense_init(keys[4], cfg.evidence_dim, cfg.d_model, dtype)
    return p


def encode(params: Params, cfg: ModelConfig, evidence, *,
           unroll: bool = False) -> jax.Array:
    """evidence: (B, Ne, De) stub frontend output -> memory (B, Ne, d)."""
    x = evidence
    if "evidence_proj" in params:
        x = dense(params["evidence_proj"], x)
    x = x.astype(params["embed"]["table"].dtype)
    B, L, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32), (B, L))

    def body(x, p):
        h = rmsnorm(p["ln1"], x, cfg.norm_eps)
        y, _ = attn_lib.attn_prefill(p["attn"], cfg, h, positions, window=0,
                                     causal=False)  # bidirectional encoder
        x = x + y
        h2 = rmsnorm(p["ln2"], x, cfg.norm_eps)
        x = x + mlp(p["mlp"], h2, cfg.mlp_activation)
        return x, None

    if unroll:
        for i in range(cfg.num_encoder_layers):
            x, _ = body(x, jax.tree.map(lambda a: a[i], params["enc_super"]))
    else:
        x, _ = jax.lax.scan(body, x, params["enc_super"])
    return rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def _cross_kv(params_x, cfg: ModelConfig, memory):
    """Project encoder memory to per-layer cross K/V (stacked over layers)."""
    B, Ls, _ = memory.shape
    hd = cfg.resolved_head_dim

    def one(p):
        k = dense(p["wk"], memory).reshape(B, Ls, cfg.num_kv_heads, hd)
        v = dense(p["wv"], memory).reshape(B, Ls, cfg.num_kv_heads, hd)
        return k, v

    return jax.vmap(one)(params_x)


def _dec_block(p, cfg: ModelConfig, x, positions, cross_k, cross_v, impl):
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    y, kv = attn_lib.attn_prefill(p["attn"], cfg, h, positions, impl=impl)
    x = x + y
    hx = rmsnorm(p["lnx"], x, cfg.norm_eps)
    yx, _ = attn_lib.attn_prefill(p["xattn"], cfg, hx, positions,
                                  cross_kv=(cross_k, cross_v))
    x = x + yx
    h2 = rmsnorm(p["ln2"], x, cfg.norm_eps)
    x = x + mlp(p["mlp"], h2, cfg.mlp_activation)
    return x, kv


def encdec_forward(params: Params, cfg: ModelConfig, tokens, evidence, *,
                   impl: str = "xla", remat: bool = False,
                   unroll: bool = False
                   ) -> Tuple[jax.Array, jax.Array, Dict]:
    """Training forward. tokens: (B, L) decoder inputs; evidence: (B, Ne, De).
    Returns (logits, hidden, aux)."""
    memory = encode(params, cfg, evidence, unroll=unroll)
    ck, cv = _cross_kv(params["dec_super"]["xattn"], cfg, memory)
    x = embed(params["embed"], tokens)
    B, L, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32), (B, L))

    def body(x, inp):
        p, k, v = inp
        x, _ = _dec_block(p, cfg, x, positions, k, v, impl)
        return x, None

    fn = jax.checkpoint(body) if remat else body
    if unroll:
        for i in range(cfg.num_layers):
            x, _ = fn(x, jax.tree.map(lambda a: a[i],
                                      (params["dec_super"], ck, cv)))
    else:
        x, _ = jax.lax.scan(fn, x, (params["dec_super"], ck, cv))
    h = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = h @ params["embed"]["table"].T
    else:
        logits = dense(params["unembed"], h)
    from repro.distributed.context import constrain_logits
    return constrain_logits(logits), h, {}


def encdec_make_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype,
                      src_len: int):
    hd = cfg.resolved_head_dim
    n = cfg.num_layers
    kv = attn_lib.make_kv_cache(cfg, batch, cache_len, dtype)
    return {
        "self": jax.tree.map(lambda x: jnp.broadcast_to(x, (n,) + x.shape), kv),
        "cross_k": jnp.zeros((n, batch, src_len, cfg.num_kv_heads, hd), dtype),
        "cross_v": jnp.zeros((n, batch, src_len, cfg.num_kv_heads, hd), dtype),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def encdec_prefill(params: Params, cfg: ModelConfig, tokens, cache, evidence,
                   *, impl: str = "xla", unroll: bool = False):
    memory = encode(params, cfg, evidence, unroll=unroll)
    ck, cv = _cross_kv(params["dec_super"]["xattn"], cfg, memory)
    x = embed(params["embed"], tokens)
    B, L, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32), (B, L))

    def body(x, inp):
        p, k, v, ce = inp
        x, kv = _dec_block(p, cfg, x, positions, k, v, impl)
        return x, attn_lib.prefill_into_cache(ce, kv[0], kv[1])

    xs = (params["dec_super"], ck, cv, cache["self"])
    if unroll:
        entries = []
        for i in range(cfg.num_layers):
            x, e = body(x, jax.tree.map(lambda a: a[i], xs))
            entries.append(e)
        new_self = jax.tree.map(lambda *ys: jnp.stack(ys), *entries)
    else:
        x, new_self = jax.lax.scan(body, x, xs)
    h = rmsnorm(params["final_norm"], x[:, -1:], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = h @ params["embed"]["table"].T
    else:
        logits = dense(params["unembed"], h)
    new_cache = {"self": new_self,
                 "cross_k": ck.astype(cache["cross_k"].dtype),
                 "cross_v": cv.astype(cache["cross_v"].dtype),
                 "pos": jnp.full((B,), L, jnp.int32)}
    return logits[:, 0], h[:, 0], new_cache


def encdec_decode(params: Params, cfg: ModelConfig, token, cache, *,
                  impl: str = "xla", unroll: bool = False):
    if token.ndim == 1:
        token = token[:, None]
    pos = cache["pos"]
    x = embed(params["embed"], token)

    def body(x, inp):
        p, ce, k, v = inp
        h = rmsnorm(p["ln1"], x, cfg.norm_eps)
        y, entry = attn_lib.attn_decode(p["attn"], cfg, h, ce, pos, impl=impl)
        x = x + y
        hx = rmsnorm(p["lnx"], x, cfg.norm_eps)
        yx, _ = attn_lib.attn_decode(p["xattn"], cfg, hx, None, pos,
                                     cross_kv=(k, v))
        x = x + yx
        h2 = rmsnorm(p["ln2"], x, cfg.norm_eps)
        x = x + mlp(p["mlp"], h2, cfg.mlp_activation)
        return x, entry

    xs = (params["dec_super"], cache["self"],
          cache["cross_k"], cache["cross_v"])
    if unroll:
        entries = []
        for i in range(cfg.num_layers):
            x, e = body(x, jax.tree.map(lambda a: a[i], xs))
            entries.append(e)
        new_self = jax.tree.map(lambda *ys: jnp.stack(ys), *entries)
    else:
        x, new_self = jax.lax.scan(body, x, xs)
    h = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = h @ params["embed"]["table"].T
    else:
        logits = dense(params["unembed"], h)
    new_cache = dict(cache, self=new_self, pos=pos + 1)
    return logits[:, 0], h[:, 0], new_cache
