"""Property-based invariants of the traffic schedulers and the page
pool / prefix cache, driven by a model-free fake engine so hypothesis
can hammer thousands of traffic shapes without touching jax.

Invariants pinned (the issue's acceptance bar):
  * no slot leaks — free slots stay within [0, slots] and return to
    ``slots`` when the stream drains
  * page conservation — staged == consumed + returned frontier pages,
    the pool drains to zero (or to exactly the cached pages), and
    ``PagePool.check()`` holds after every step, including preemption
    (random early candidate finishes) and prefix-cache eviction
  * the global token budget is NEVER exceeded, under any traffic
  * aging — every submitted request is eventually admitted (coverage
    policy never starves queued work), given a fundable budget
  * sharded serving — with slots and page subpools partitioned across
    data shards (mesh-parallel serving), per-shard slot/page/frontier
    conservation holds, no shard is ever overdrawn, and the global
    budget invariant survives shard-local affordability
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need hypothesis;
# a bare interpreter must still collect the suite (module-level skip)
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.serving.page_pool import PagePool, PagePoolError, prefix_page_keys
from repro.serving.scheduler import (CoverageScheduler, FifoScheduler,
                                     NewWork, RoundWork, SchedulerContext,
                                     make_scheduler)


# ---------------------------------------------------------------------------
# A model-free engine: slots, queue, rounds, candidate lifetimes — the
# same control flow ServeEngine drives, minus the model and the KV.
# ---------------------------------------------------------------------------

class FakeEngine(SchedulerContext):
    def __init__(self, rng, *, slots, max_new, n_reqs, rounds_per_req,
                 want, afford_cap=None):
        self.rng = rng
        self.slots = slots
        self.max_new = max_new
        self.free = slots
        self.queue = [NewWork(uid=i, arrival=i, want=want)
                      for i in range(n_reqs)]
        self.rounds_left = {i: rounds_per_req[i] for i in range(n_reqs)}
        self.pending = {}            # uid -> RoundWork
        self.live = []               # (uid, steps_left, limit)
        self.admitted = []           # admission order (uids, with repeats)
        self.first_admit = set()
        self.tokens_emitted = 0
        self.afford_cap = afford_cap # simulated pool pressure

    # -- SchedulerContext ----------------------------------------------
    def free_slots(self):
        return self.free

    def queued_new(self):
        return list(self.queue)

    def pending_rounds(self):
        return list(self.pending.values())

    def affordable(self, uid, want, limit):
        if self.afford_cap is None:
            return want
        return min(want, self.afford_cap)

    def _spawn(self, uid, take, limit):
        assert take >= 1 and take <= self.free, (take, self.free)
        assert 1 <= limit <= self.max_new
        self.free -= take
        self.admitted.extend([uid] * take)
        self.first_admit.add(uid)
        for _ in range(take):
            # actual emitted length <= limit (early EOS possible); the
            # admission-time first token means at least 1
            n = int(self.rng.integers(1, limit + 1))
            self.live.append([uid, int(self.rng.integers(1, 4)), limit, n])

    def admit_new(self, uid, take, limit):
        self.queue = [w for w in self.queue if w.uid != uid]
        self._spawn(uid, take, limit)

    def admit_round(self, uid, take, limit):
        self.pending.pop(uid)
        self._spawn(uid, take, limit)

    def finish_request(self, uid):
        self.pending.pop(uid, None)
        self.rounds_left[uid] = 0

    # -- simulation -----------------------------------------------------
    def tick(self, sched):
        """Advance live candidates one step; finished ones release their
        slot and report to the scheduler (as _finish_candidates does)."""
        done_uids = set()
        still = []
        for cand in self.live:
            cand[1] -= 1
            if cand[1] <= 0:
                uid, _, limit, n = cand
                self.free += 1
                self.tokens_emitted += n
                sched.on_finish(uid, n, limit)
                done_uids.add(uid)
            else:
                still.append(cand)
        self.live = still
        for uid in done_uids:
            if any(c[0] == uid for c in self.live):
                continue             # round completes when no slots live
            self.rounds_left[uid] -= 1
            if self.rounds_left[uid] > 0:
                self.pending[uid] = RoundWork(
                    uid=uid, arrival=uid, want=2,
                    rounds=1, p_star=float(self.rng.uniform(0, 1)),
                    delta=0.05, best_score=1.0,
                    scores=[float(self.rng.normal()) for _ in range(3)],
                    mean_len=float(self.max_new))

    def drained(self):
        return not self.queue and not self.pending and not self.live


def _run_stream(sched, eng, max_ticks=10_000):
    budget = sched.global_budget
    for _ in range(max_ticks):
        eng.tick(sched)
        sched.schedule(eng)
        assert 0 <= eng.free <= eng.slots, "slot leak"
        if budget:
            assert sched.spent + sched.committed <= budget
            assert eng.tokens_emitted <= budget, "budget exceeded"
        if eng.drained():
            break
        if not eng.live and not eng.queue and eng.pending and \
                sched.exhausted():
            break                    # terminal starvation (engine drains)
    assert eng.free + len(eng.live) == eng.slots
    return eng


@settings(max_examples=60, deadline=None)
@given(seed=st.integers(0, 10**6), slots=st.integers(1, 8),
       n_reqs=st.integers(1, 12), want=st.integers(1, 4),
       policy=st.sampled_from(["fifo", "coverage"]),
       afford_cap=st.sampled_from([None, 1, 2]))
def test_no_slot_leaks_and_stream_drains(seed, slots, n_reqs, want, policy,
                                         afford_cap):
    rng = np.random.default_rng(seed)
    eng = FakeEngine(rng, slots=slots, max_new=6, n_reqs=n_reqs,
                     rounds_per_req=rng.integers(1, 4, n_reqs), want=want,
                     afford_cap=afford_cap)
    sched = make_scheduler(policy)
    eng = _run_stream(sched, eng)
    assert eng.drained()
    assert eng.free == eng.slots
    assert eng.first_admit == set(range(n_reqs))


@settings(max_examples=60, deadline=None)
@given(seed=st.integers(0, 10**6), slots=st.integers(1, 6),
       n_reqs=st.integers(1, 10), budget=st.integers(2, 80),
       policy=st.sampled_from(["fifo", "coverage"]))
def test_global_budget_never_exceeded(seed, slots, n_reqs, budget, policy):
    """Worst-case commitment accounting: total emitted tokens never pass
    the budget, whatever the traffic shape — and when the budget can
    fund everyone (aging property), everyone is eventually admitted."""
    rng = np.random.default_rng(seed)
    eng = FakeEngine(rng, slots=slots, max_new=6, n_reqs=n_reqs,
                     rounds_per_req=np.ones(n_reqs, int), want=2)
    sched = make_scheduler(policy, global_budget=budget)
    eng = _run_stream(sched, eng)
    assert eng.tokens_emitted <= budget
    if budget >= n_reqs * 2 * 6 * 2:     # plenty for everyone
        assert eng.first_admit == set(range(n_reqs))


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 10**6), n_reqs=st.integers(2, 10))
def test_fifo_admits_new_requests_in_arrival_order(seed, n_reqs):
    rng = np.random.default_rng(seed)
    eng = FakeEngine(rng, slots=3, max_new=4, n_reqs=n_reqs,
                     rounds_per_req=np.ones(n_reqs, int), want=2)
    sched = FifoScheduler()
    _run_stream(sched, eng)
    firsts = list(dict.fromkeys(eng.admitted))
    assert firsts == sorted(firsts)


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 10**6))
def test_coverage_prioritizes_high_deficit_rounds(seed):
    """With one free slot and two pending rounds, the harder request
    (larger coverage deficit) is admitted first — the paper's
    compute-to-difficulty allocation at traffic level."""
    rng = np.random.default_rng(seed)
    eng = FakeEngine(rng, slots=1, max_new=4, n_reqs=0,
                     rounds_per_req={}, want=1)
    mk = lambda uid, p: RoundWork(
        uid=uid, arrival=uid, want=1, rounds=1, p_star=p, delta=0.05,
        best_score=1.0, scores=[0.0, 1.0, 2.0], mean_len=4.0)
    easy_first = bool(rng.integers(0, 2))
    rounds = [mk(0, 0.96), mk(1, 0.10)] if easy_first else \
        [mk(1, 0.10), mk(0, 0.96)]
    for r in rounds:
        eng.pending[r.uid] = r
    sched = CoverageScheduler(decline_low_gain=False)
    sched.schedule(eng)
    assert eng.admitted[0] == 1          # the hard one wins the slot


def test_coverage_declines_zero_gain_rounds():
    """Perfect score agreement (std == 0 => EI == 0 < any token cost)
    triggers the rule-(iii) decline: the request finalizes instead of
    burning another round."""
    rng = np.random.default_rng(0)
    eng = FakeEngine(rng, slots=4, max_new=4, n_reqs=0,
                     rounds_per_req={7: 3}, want=1)
    eng.pending[7] = RoundWork(uid=7, arrival=0, want=2, rounds=1,
                               p_star=0.5, delta=0.05, best_score=1.0,
                               scores=[1.0, 1.0, 1.0], mean_len=4.0)
    sched = CoverageScheduler()
    sched.schedule(eng)
    assert not eng.pending and not eng.live
    assert sched.declined_rounds == 1


# ---------------------------------------------------------------------------
# Sharded serving: per-shard slot + page accounting (mesh-parallel)
# ---------------------------------------------------------------------------

class ShardedFakeEngine(FakeEngine):
    """FakeEngine with the sharded engine's placement rules: slots
    partition contiguously across ``num_shards`` data shards, admission
    fills free slots in ascending order, and every candidate must be
    funded with ``per_cand`` pages from its own slot's shard — the same
    walk ``ServeEngine._paged_affordable`` performs."""

    def __init__(self, rng, *, num_shards, pages_per_shard, per_cand,
                 slots, **kw):
        super().__init__(rng, slots=slots, **kw)
        assert slots % num_shards == 0
        self.num_shards = num_shards
        self.sps = slots // num_shards
        self.pages_per_shard = pages_per_shard
        self.page_free = [pages_per_shard] * num_shards
        self.per_cand = per_cand
        self.free_ids = list(range(slots))

    def shard_of(self, slot):
        return slot // self.sps

    def affordable(self, uid, want, limit):
        avail = list(self.page_free)
        take = 0
        for slot in sorted(self.free_ids)[:want]:
            sh = self.shard_of(slot)
            if avail[sh] < self.per_cand:
                break
            avail[sh] -= self.per_cand
            take += 1
        return take

    def _spawn(self, uid, take, limit):
        assert take >= 1 and take <= self.free, (take, self.free)
        assert 1 <= limit <= self.max_new
        self.admitted.extend([uid] * take)
        self.first_admit.add(uid)
        self.free_ids.sort()
        for _ in range(take):
            slot = self.free_ids.pop(0)        # ascending, like the engine
            sh = self.shard_of(slot)
            self.page_free[sh] -= self.per_cand
            assert self.page_free[sh] >= 0, "shard page overdraft"
            n = int(self.rng.integers(1, limit + 1))
            self.live.append([uid, int(self.rng.integers(1, 4)), limit, n,
                              slot])
        self.free = len(self.free_ids)

    def tick(self, sched):
        done_uids = set()
        still = []
        for cand in self.live:
            cand[1] -= 1
            if cand[1] <= 0:
                uid, _, limit, n, slot = cand
                self.free_ids.append(slot)
                sh = self.shard_of(slot)
                self.page_free[sh] += self.per_cand
                assert self.page_free[sh] <= self.pages_per_shard, \
                    "shard page over-release"
                self.tokens_emitted += n
                sched.on_finish(uid, n, limit)
                done_uids.add(uid)
            else:
                still.append(cand)
        self.live = still
        self.free = len(self.free_ids)
        for uid in done_uids:
            if any(c[0] == uid for c in self.live):
                continue
            self.rounds_left[uid] -= 1
            if self.rounds_left[uid] > 0:
                self.pending[uid] = RoundWork(
                    uid=uid, arrival=uid, want=2,
                    rounds=1, p_star=float(self.rng.uniform(0, 1)),
                    delta=0.05, best_score=1.0,
                    scores=[float(self.rng.normal()) for _ in range(3)],
                    mean_len=float(self.max_new))


@settings(max_examples=50, deadline=None)
@given(seed=st.integers(0, 10**6), num_shards=st.sampled_from([2, 4]),
       sps=st.integers(1, 3), n_reqs=st.integers(1, 10),
       want=st.integers(1, 4), pages=st.integers(2, 10),
       policy=st.sampled_from(["fifo", "coverage"]),
       budget=st.sampled_from([0, 40]))
def test_sharded_slot_page_conservation_and_budget(seed, num_shards, sps,
                                                   n_reqs, want, pages,
                                                   policy, budget):
    """Per-shard slot + page conservation under arbitrary traffic and
    shard-local affordability: no shard overdraft, free lists drain back
    to capacity, the global budget holds, and (when everything is
    fundable) nobody starves."""
    rng = np.random.default_rng(seed)
    eng = ShardedFakeEngine(
        rng, num_shards=num_shards, slots=num_shards * sps,
        pages_per_shard=pages, per_cand=2, max_new=6, n_reqs=n_reqs,
        rounds_per_req=rng.integers(1, 3, n_reqs), want=want)
    sched = make_scheduler(policy, global_budget=budget)
    _run_stream(sched, eng)
    assert sorted(eng.free_ids) == sorted(
        s for s in range(eng.slots)
        if s not in [c[4] for c in eng.live])
    if budget:
        assert eng.tokens_emitted <= budget
    else:
        assert eng.drained()
        assert eng.page_free == [pages] * num_shards
        assert eng.first_admit == set(range(n_reqs))


# ---------------------------------------------------------------------------
# PagePool + prefix cache conservation under random op streams
# ---------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(seed=st.integers(0, 10**6), num_pages=st.integers(4, 24),
       steps=st.integers(1, 60))
def test_pool_conservation_random_ops(seed, num_pages, steps):
    """Random alloc/share/free/stage/return streams: ``check()`` holds
    after every op and staged == consumed(kept) + returned."""
    rng = np.random.default_rng(seed)
    pool = PagePool(num_pages, 8)
    held = []                      # pages with a plain hold
    staged = []                    # frontier pages not yet resolved
    kept = 0
    for _ in range(steps):
        op = rng.integers(0, 5)
        try:
            if op == 0:
                held += pool.alloc(int(rng.integers(1, 3)))
            elif op == 1 and held:
                pages = [held[int(rng.integers(0, len(held)))]]
                pool.share(pages)
                held += pages
            elif op == 2 and held:
                i = int(rng.integers(0, len(held)))
                pool.free([held.pop(i)])
            elif op == 3:
                pages = pool.stage_frontier(int(rng.integers(1, 3)))
                staged += pages
            elif op == 4 and staged:
                # resolve a staged page: keep (consumed by the device
                # loop => becomes a plain hold) or return it
                i = int(rng.integers(0, len(staged)))
                page = staged.pop(i)
                if rng.integers(0, 2):
                    pool.return_frontier([page])
                else:
                    held.append(page)
                    kept += 1
        except PagePoolError:
            pass                   # over-allocation is allowed to fail
        pool.check()
    assert pool.stats()["frontier_staged"] == \
        kept + len(staged) + pool.stats()["frontier_returned"]
    for p in held + staged:
        pool.free([p])
    pool.check()
    assert pool.in_use == 0


@settings(max_examples=50, deadline=None)
@given(seed=st.integers(0, 10**6), num_shards=st.sampled_from([2, 4]),
       steps=st.integers(1, 60))
def test_sharded_pool_conservation_random_ops(seed, num_shards, steps):
    """Random shard-routed alloc/free/stage/return streams: ``check()``
    holds after every op (free lists never hold foreign pages), frontier
    accounting balances PER SHARD, and capacity is shard-local (an
    exhausted shard raises even while others have pages)."""
    rng = np.random.default_rng(seed)
    per_shard = int(rng.integers(3, 8))
    pool = PagePool(num_shards * per_shard, 8, num_shards=num_shards)
    held, staged, kept = [], [], np.zeros(num_shards, np.int64)
    for _ in range(steps):
        op = rng.integers(0, 4)
        sh = int(rng.integers(0, num_shards))
        try:
            if op == 0:
                pages = pool.alloc(int(rng.integers(1, 3)), sh)
                assert all(pool.shard_of(p) == sh for p in pages)
                held += pages
            elif op == 1 and held:
                pool.free([held.pop(int(rng.integers(0, len(held))))])
            elif op == 2:
                staged += pool.stage_frontier(int(rng.integers(1, 3)), sh)
            elif op == 3 and staged:
                page = staged.pop(int(rng.integers(0, len(staged))))
                if rng.integers(0, 2):
                    pool.return_frontier([page])
                else:
                    held.append(page)
                    kept[pool.shard_of(page)] += 1
        except PagePoolError:
            pass                       # shard exhaustion is allowed to fail
        pool.check()
    stats = pool.stats()
    for s in range(num_shards):
        staged_s = sum(1 for p in staged if pool.shard_of(p) == s)
        assert stats["shards"][s]["frontier_staged"] == \
            int(kept[s]) + staged_s + stats["shards"][s]["frontier_returned"]
    # shard isolation: drain one shard completely, it raises while a
    # sibling still allocates
    full = pool.alloc(pool.free_pages_in(0), 0)
    try:
        with pytest.raises(PagePoolError):
            pool.alloc(1, 0)
        if any(pool.free_pages_in(s) for s in range(1, num_shards)):
            nxt = next(s for s in range(1, num_shards)
                       if pool.free_pages_in(s))
            pool.free(pool.alloc(1, nxt))
    finally:
        pool.free(full)
    for p in held + staged:
        pool.free([p])
    pool.check()
    assert pool.in_use == 0


@settings(max_examples=50, deadline=None)
@given(seed=st.integers(0, 10**6), ps=st.sampled_from([4, 8]),
       n_prompts=st.integers(1, 6))
def test_prefix_cache_conservation_and_determinism(seed, ps, n_prompts):
    """Random prompt mixes with shared prefixes: inserts/matches/evictions
    keep the pool conserved, chains prefix-closed, and a match always
    returns pages whose keys chain-hash the same content."""
    rng = np.random.default_rng(seed)
    pool = PagePool(64, ps, prefix_cache=True)
    base = rng.integers(2, 50, 4 * ps)
    reqs = []
    for _ in range(n_prompts):
        cut = int(rng.integers(0, 4)) * ps
        prompt = np.concatenate([base[:cut],
                                 rng.integers(2, 50, int(rng.integers(1, 12)))])
        keys = prefix_page_keys(prompt, ps)
        usable = (len(prompt) - 1) // ps
        hit = pool.prefix.match_and_hold(keys[:usable])
        full = len(prompt) // ps
        fresh = pool.alloc(full - len(hit))
        pages = hit + fresh
        pool.prefix.insert(keys, pages)
        pool.check()
        reqs.append(pages)
    # same content => same pages for the shared prefix
    k1 = prefix_page_keys(base, ps)
    again = pool.prefix.match_and_hold(k1[:2])
    if again:
        assert again == [pool.prefix._nodes[k].page for k in k1[:len(again)]]
        pool.free(again)
    for pages in reqs:
        pool.free(pages)
        pool.check()
    # only cache holds remain; evicting everything drains the pool
    pool.prefix.evict(pool.num_pages)
    pool.check()
    assert pool.in_use == 0


def test_prefix_cache_eviction_under_pressure():
    """alloc() reclaims cached-only pages LRU-leaf-first instead of
    failing, but never evicts pages a live request still holds."""
    pool = PagePool(9, 4, prefix_cache=True)     # 8 allocatable
    a = np.arange(2, 10)                         # 2 full pages
    b = np.arange(20, 28)
    ka, kb = prefix_page_keys(a, 4), prefix_page_keys(b, 4)
    pa = pool.alloc(2)
    pool.prefix.insert(ka, pa)
    pb = pool.alloc(2)
    pool.prefix.insert(kb, pb)
    pool.free(pa)
    pool.free(pb)                                # cache-only now
    assert pool.free_pages == 4 and pool.evictable() == 4
    got = pool.alloc(6)                          # forces 2 evictions
    assert len(got) == 6
    assert pool.prefix.evictions == 2
    pool.check()
    # chains stay prefix-closed: any surviving node's parent survives
    for k, node in pool.prefix._nodes.items():
        assert node.parent is None or node.parent in pool.prefix._nodes
    # pages held by a request are never evicted
    pool.free(got)
    held = pool.prefix.match_and_hold(prefix_page_keys(
        np.concatenate([a[:4], [99]]), 4)[:1])
    if held:
        with_hold = held[0]
        pool.alloc(pool.free_pages + pool.evictable())
        assert pool.refcount(with_hold) >= 1     # still alive
    pool.check()
