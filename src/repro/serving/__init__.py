from repro.serving.engine import EngineState, Request, Result, ServeEngine  # noqa: F401
from repro.serving.page_pool import PagePool, PagePoolError  # noqa: F401
