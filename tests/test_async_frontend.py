"""Async streaming front-end: streams must equal the synchronous path.

The contract: routing requests through ``AsyncServeFrontend`` (token
streams, interleaved submits, cancels) changes *when* tokens are
delivered, never *what* tokens — greedy streams are byte-identical to a
synchronous ``run()`` of the same prompts, and multi-candidate (camd)
results match candidate-for-candidate. Golden streams come from one
engine run per module; each test drives a fresh engine through the
front-end and compares by prompt index.
"""
import asyncio

import numpy as np
import pytest

from conftest import _mk_engine
from repro.config import PagedKVConfig
from repro.serving import AsyncServeFrontend, Request

MAX_NEW = 8
N_REQ = 6


def _prompts(cfg, n=N_REQ, seed=0, plen=6):
    rng = np.random.default_rng(seed)
    return [rng.integers(2, cfg.vocab_size, plen).astype(np.int32)
            for _ in range(n)]


def _greedy(tiny_model, **kw):
    cfg, model, params = tiny_model
    return _mk_engine(model, params, mode="greedy", macro_steps=2, slots=3,
                      max_new=MAX_NEW, eos_id=cfg.vocab_size, impl="paged",
                      paged_kv=PagedKVConfig(page_size=8), **kw)


@pytest.fixture(scope="module")
def golden(tiny_model):
    """Synchronous greedy reference streams, by prompt index."""
    cfg, _model, _params = tiny_model
    eng = _greedy(tiny_model)
    prompts = _prompts(cfg)
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p))
    return prompts, {r.uid: [int(t) for t in r.tokens] for r in eng.run()}


def _consume(fe, uid, cancel_after=None):
    async def inner():
        toks = []
        async for t in fe.stream(uid):
            toks.append(int(t))
            if cancel_after is not None and len(toks) >= cancel_after:
                await fe.cancel(uid)
        res = await fe.result(uid)
        return toks, res
    return inner()


def test_greedy_stream_byte_identity(tiny_model, golden):
    prompts, ref = golden
    eng = _greedy(tiny_model)

    async def main():
        out = {}
        async with AsyncServeFrontend(eng) as fe:
            async def one(i):
                await fe.submit(Request(uid=i, prompt=prompts[i]))
                out[i] = await _consume(fe, i)
            await asyncio.gather(*[one(i) for i in range(len(prompts))])
        return out

    out = asyncio.run(main())
    for i, (stream, res) in out.items():
        assert stream == ref[i], f"stream diverged for request {i}"
        assert [int(t) for t in res.tokens] == ref[i]
        assert not res.cancelled
    # incremental delivery actually happened: max_new spans several
    # macro launches, so tokens cannot all have arrived in one event
    assert eng.macro_launches > 1


def test_cancel_mid_stream_frees_everything(tiny_model, golden):
    prompts, ref = golden
    eng = _greedy(tiny_model)
    cancel = {0, 3}

    async def main():
        out = {}
        async with AsyncServeFrontend(eng) as fe:
            async def one(i):
                await fe.submit(Request(uid=i, prompt=prompts[i]))
                out[i] = await _consume(
                    fe, i, cancel_after=1 if i in cancel else None)
            await asyncio.gather(*[one(i) for i in range(len(prompts))])
        return out

    out = asyncio.run(main())
    for i, (stream, res) in out.items():
        if i in cancel:
            assert res.cancelled
            # delivered tokens are a prefix of the golden stream
            assert stream == ref[i][:len(stream)]
        else:
            assert not res.cancelled and stream == ref[i]
    # conservation: the aborts returned every page, slot, and token of
    # worst-case commitment
    eng.pool.check()
    assert eng.pool.in_use == 0
    assert all(int(eng._slot_req[s]) == -1 for s in range(eng.B))
    assert eng.scheduler.committed == 0
    assert eng.cancelled_requests == len(cancel)


def test_submit_while_running(tiny_model, golden):
    """A request submitted mid-decode of another is admitted between
    macro launches and still reproduces the golden stream."""
    prompts, ref = golden
    eng = _greedy(tiny_model)

    async def main():
        async with AsyncServeFrontend(eng) as fe:
            await fe.submit(Request(uid=0, prompt=prompts[0]))
            first = []
            async for t in fe.stream(0):
                first.append(int(t))
                if len(first) == 2:       # mid-stream: inject request 1
                    await fe.submit(Request(uid=1, prompt=prompts[1]))
            second, res1 = await _consume(fe, 1)
            res0 = await fe.result(0)
            return first, second, res0, res1

    first, second, res0, res1 = asyncio.run(main())
    assert first == ref[0] and second == ref[1]
    assert not res0.cancelled and not res1.cancelled


def test_camd_results_match_sync(tiny_model):
    """Multi-candidate modes stream the chosen candidate at completion;
    results match the synchronous path field-for-field."""
    cfg, model, params = tiny_model

    def mk():
        return _mk_engine(model, params, mode="camd", macro_steps=2,
                          slots=4, max_new=4)

    prompts = _prompts(cfg, n=4, seed=3)
    sync = mk()
    for i, p in enumerate(prompts):
        sync.submit(Request(uid=i, prompt=p))
    ref = {r.uid: r for r in sync.run()}

    eng = mk()

    async def main():
        out = {}
        async with AsyncServeFrontend(eng) as fe:
            async def one(i):
                await fe.submit(Request(uid=i, prompt=prompts[i]))
                out[i] = await _consume(fe, i)
            await asyncio.gather(*[one(i) for i in range(len(prompts))])
        return out

    out = asyncio.run(main())
    for i, (stream, res) in out.items():
        assert stream == [int(t) for t in ref[i].tokens]
        assert [int(t) for t in res.tokens] == [int(t) for t in ref[i].tokens]
        assert res.n_candidates == ref[i].n_candidates
        assert res.tokens_spent == ref[i].tokens_spent


def test_frontend_requires_macro_loop(tiny_model):
    cfg, model, params = tiny_model
    eng = _mk_engine(model, params, mode="greedy", macro_steps=0)
    with pytest.raises(ValueError, match="macro"):
        AsyncServeFrontend(eng)


def test_submit_before_start_raises(tiny_model):
    eng = _greedy(tiny_model)
    fe = AsyncServeFrontend(eng)

    async def main():
        with pytest.raises(RuntimeError, match="not started"):
            await fe.submit(Request(uid=0, prompt=np.arange(2, 8,
                                                            dtype=np.int32)))
    asyncio.run(main())
