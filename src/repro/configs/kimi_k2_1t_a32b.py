"""kimi-k2-1t-a32b — Moonshot Kimi K2, trillion-parameter MoE.

[arXiv:2501.kimi2 paper table]: 61L, d_model=7168, 64 q heads, GQA kv=8,
per-expert d_ff=2048, vocab 163840, 384 experts top-8 (+1 shared expert).
"""
from repro.config import ATTN, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    d_ff=2048,                    # per-expert hidden width
    vocab_size=163840,
    block_pattern=(ATTN,),
    mlp_activation="swiglu",
    moe=MoEConfig(num_experts=384, top_k=8, expert_d_ff=2048,
                  num_shared_experts=1),
    source="arXiv:2501.kimi2",
)
