"""End-to-end serving driver (the paper's kind: inference).

Trains a small LM on the heterogeneous-difficulty oracle task, then
serves a batch of requests through the production ServeEngine under
greedy / best-of-N / CAMD, reporting oracle-checked accuracy, token
spend, and CAMD's per-difficulty sample allocation.

    PYTHONPATH=src python examples/serve_camd.py --steps 600 --questions 32
"""
import argparse

import numpy as np

import jax
import jax.numpy as jnp

from repro.config import (CAMDConfig, ModelConfig, SamplingConfig,
                          TrainConfig)
from repro.data import ChainTask, lm_batches
from repro.models import build_model
from repro.serving import Request, ServeEngine
from repro.training import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=600)
    ap.add_argument("--questions", type=int, default=32)
    ap.add_argument("--base", type=int, default=16)
    args = ap.parse_args()

    cfg = ModelConfig(
        name="serve-lm", family="dense", num_layers=4, d_model=256,
        num_heads=4, num_kv_heads=2, d_ff=768, vocab_size=64, head_dim=64,
        tie_embeddings=True, dtype="float32")
    model = build_model(cfg, jnp.float32)
    data = ({"tokens": jnp.asarray(b["tokens"]),
             "labels": jnp.asarray(b["labels"])}
            for b in lm_batches(cfg.vocab_size, 16, 48, seed=0,
                                base=args.base, max_chain=3))
    print(f"training {cfg.num_params()/1e6:.1f}M-param LM for "
          f"{args.steps} steps on the chain task...")
    params, _, hist = train(
        model, TrainConfig(total_steps=args.steps, warmup_steps=40,
                           learning_rate=3e-3, remat=False),
        data, steps=args.steps, log_every=max(args.steps // 4, 1),
        callback=lambda m: print(f"  step {m['step']}: loss {m['loss']:.3f}"))

    task = ChainTask(base=args.base)
    rng = np.random.default_rng(1)
    prompts = [task.sample(rng, chain_len=i % 4)
               for i in range(args.questions)]

    def serve(mode, n_candidates):
        eng = ServeEngine(
            model, params, slots=8, cache_len=64,
            sampling=SamplingConfig(temperature=1.0, top_p=0.95,
                                    repetition_penalty=1.0,
                                    max_new_tokens=3),
            camd=CAMDConfig(samples_per_round=2, max_rounds=4,
                            min_samples=2, delta=0.05, score_scale=3.0,
                            lambda_c=0.2, guidance_strength=0.5),
            mode=mode, n_candidates=n_candidates, eos_id=1,
            max_new_tokens=3, seed=0)
        for i, (p, _a, _k) in enumerate(prompts):
            eng.submit(Request(uid=i, prompt=p))
        res = eng.run()
        acc = np.mean([task.check(prompts[r.uid][0], r.tokens) for r in res])
        toks = np.mean([r.tokens_spent for r in res])
        return res, acc, toks

    print("\nmode         accuracy  avg_tokens")
    for mode, n in (("greedy", 1), ("best_of_n", 8), ("camd", 8)):
        res, acc, toks = serve(mode, n)
        print(f"{mode:<12} {acc:8.3f}  {toks:9.1f}")
        if mode == "camd":
            by_k = {}
            for r in res:
                k = prompts[r.uid][2]
                by_k.setdefault(k, []).append(r.n_candidates)
            alloc = {k: float(np.mean(v)) for k, v in sorted(by_k.items())}
            print(f"  CAMD samples by chain difficulty: {alloc}")


if __name__ == "__main__":
    main()
