"""Train a ~100M-parameter model on the synthetic pipeline.

Defaults are CPU-sized (a ~7M model for a quick demo); pass --full for
the ~100M-parameter qwen3-family configuration used on real hardware
(the config is the same class the dry-run lowers onto the 256-chip mesh).

    PYTHONPATH=src python examples/train_small.py --steps 100
    PYTHONPATH=src python examples/train_small.py --full --steps 300
"""
import argparse

import jax.numpy as jnp

from repro.config import ModelConfig, TrainConfig
from repro.configs import get_config
from repro.data import lm_batches
from repro.models import build_model
from repro.training import save_checkpoint, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true",
                    help="~100M params (slow on CPU)")
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt/train_small")
    args = ap.parse_args()

    if args.full:
        cfg = get_config("qwen3-0.6b").with_overrides(
            num_layers=8, d_model=768, num_heads=12, num_kv_heads=4,
            d_ff=2048, vocab_size=32768, head_dim=64, dtype="float32")
    else:
        cfg = get_config("qwen3-0.6b").reduced().with_overrides(
            num_layers=4, dtype="float32")
    model = build_model(cfg, jnp.float32)
    print(f"arch={cfg.name} params={cfg.num_params()/1e6:.1f}M")

    data = ({"tokens": jnp.asarray(b["tokens"]),
             "labels": jnp.asarray(b["labels"])}
            for b in lm_batches(cfg.vocab_size, args.batch, args.seq, seed=0))
    params, opt, hist = train(
        model,
        TrainConfig(total_steps=args.steps, warmup_steps=args.steps // 10,
                    learning_rate=1e-3, remat=True),
        data, steps=args.steps, log_every=max(args.steps // 10, 1),
        callback=lambda m: print(
            f"  step {m['step']:>4}: loss={m['loss']:.3f} "
            f"acc={m['accuracy']:.3f} gnorm={m['grad_norm']:.2f} "
            f"lr={m['lr']:.2e}"))
    save_checkpoint(args.ckpt, params, step=args.steps)
    print(f"checkpoint written to {args.ckpt}.npz")


if __name__ == "__main__":
    main()
