"""Public jit'd entry points for the Pallas kernels.

On a real TPU these dispatch compiled Mosaic kernels; everywhere else
(including this CPU container and the multi-pod dry-run) they run the
kernels in interpret mode or fall back to the jnp oracle — selectable via
``REPRO_KERNEL_MODE`` in {"auto", "interpret", "ref"}.
"""
from __future__ import annotations

import os

import jax

from repro.kernels import decode_attention as _dec
from repro.kernels import flash_attention as _fa
from repro.kernels import paged_decode_attention as _pdec
from repro.kernels import ref as _ref
from repro.kernels import xmodal_score as _xm


def _mode() -> str:
    m = os.environ.get("REPRO_KERNEL_MODE", "auto")
    if m == "auto":
        plat = jax.devices()[0].platform
        return "tpu" if plat == "tpu" else "ref"
    return m


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    blk_q: int = 128, blk_k: int = 128):
    m = _mode()
    if m == "ref":
        return _ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               blk_q=blk_q, blk_k=blk_k,
                               interpret=(m == "interpret"))


def decode_attention(q, k, v, kv_mask, *, blk_s: int = 256):
    m = _mode()
    if m == "ref":
        return _ref.decode_attention_ref(q, k, v, kv_mask)
    return _dec.decode_attention(q, k, v, kv_mask, blk_s=blk_s,
                                 interpret=(m == "interpret"))


def paged_decode_attention(q, k_pages, v_pages, block_table, lengths, *,
                           k_scale=None, v_scale=None,
                           debug_validate: bool = False):
    """``k_scale``/``v_scale``: per-row scales of quantized (int8/fp8)
    pools — both paths dequantize with them. ``debug_validate`` raises
    on out-of-range live page ids instead of silently clipping them
    (host-side — concrete inputs only, see ``validate_block_table``)."""
    if debug_validate:
        _pdec.validate_block_table(block_table, lengths,
                                   k_pages.shape[0], k_pages.shape[1])
    m = _mode()
    if m == "ref":
        return _ref.paged_decode_attention_ref(q, k_pages, v_pages,
                                               block_table, lengths,
                                               k_scale=k_scale,
                                               v_scale=v_scale)
    return _pdec.paged_decode_attention(q, k_pages, v_pages, block_table,
                                        lengths, k_scale=k_scale,
                                        v_scale=v_scale,
                                        interpret=(m == "interpret"))


def xmodal_score(token_embs, mask, visual_feats, text_feats, *, blk: int = 128):
    m = _mode()
    if m == "ref":
        return _ref.xmodal_score_ref(token_embs, mask, visual_feats, text_feats)
    return _xm.xmodal_score(token_embs, mask, visual_feats, text_feats,
                            blk=blk, interpret=(m == "interpret"))
