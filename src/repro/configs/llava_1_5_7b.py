"""llava-1.5-7b — the paper's primary evaluation backbone (LM side of
LLaVA-1.5: Vicuna-7B + CLIP ViT-L/14 projector).

[arXiv:2310.03744 / paper §5.1]: 32L, d_model=4096, 32 heads MHA, d_ff=11008,
vocab 32000; 576 patch embeddings per 336x336 image (ViT-L/14 grid:
(336/14)^2 = 576), encoded by the in-repo vision tower (a CLIP-shaped
stand-in: same patch grid and token count, far fewer layers).
"""
from repro.config import ATTN, ModelConfig, VisionConfig

CONFIG = ModelConfig(
    name="llava-1.5-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    d_ff=11008,
    vocab_size=32000,
    block_pattern=(ATTN,),
    mlp_activation="swiglu",
    num_evidence_tokens=576,
    evidence_dim=4096,
    vision=VisionConfig(image_h=336, image_w=336, patch=14,
                        num_layers=4, d_model=1024, num_heads=16, d_ff=4096),
    source="arXiv:2310.03744",
)
