"""Slot-scheduled batched serving engine with CAMD adaptive decoding.

Execution model (DESIGN.md §3): a fixed-size decode batch of ``slots``.
Each slot holds one *candidate* generation of some request. CAMD's
adaptive allocation — more samples for hard requests, fewer for easy —
falls out of slot scheduling: when a request reaches coverage its slots
are freed and refilled from the queue, so the batch never decodes padding.

The decode hot path is a device-resident **macro-step**: one jitted call
runs up to ``macro_steps`` decode+sample+CAMD-aggregate steps inside a
``jax.lax.while_loop`` (the "outer while" serving idiom), early-exiting
the moment any slot finishes so the host can fold the round. The host
regains control only at candidate-completion / round boundaries — host
synchronizations per generated token drop from ~1 (per-token loop) to
O(1/macro_steps), which is what keeps dispatch latency off the hot path.

Paged KV works inside the fused loop through *pre-staged page frontiers*:
before each launch the host reserves every live slot's next
⌈K/page_size⌉+1 pages from the ``PagePool`` into a ``(B, F)`` frontier
array, and the device advances ``block_table`` itself as slots cross page
boundaries. Unconsumed frontier pages are returned after the macro-step,
so pool accounting stays exact.

Per-step sampling keys are *folded* from one base key and the global step
index (``samplers.decode_step_key``), so the token stream is independent
of how many steps each launch covers — ``macro_steps=1`` and
``macro_steps=32`` decode bit-identical tokens. ``macro_steps=0``
preserves the legacy per-token host loop for benchmarking.

Prefill is length-bucketed: queued prompts are right-padded to
power-of-two buckets and prefilled in one batched call per bucket
(attention-only architectures; recurrent archs fall back to per-request
prefill because pads would leak into their state).

Modes: "camd" (adaptive), "best_of_n", "self_consistency", "greedy" —
the paper's baselines share the engine so efficiency comparisons are
apples-to-apples.

The engine scales past one device by sharding over a
``jax.sharding.Mesh`` (``mesh=``): the decode batch and every per-slot
``EngineState`` leaf shard on the mesh's "data" axis, the paged KV pool
shards on the *page* axis with shard boundaries matching the host
allocator's per-shard page-id ranges (``PagePool(num_shards=dp)``), and
params replicate (or reuse the training tensor-parallel rules when the
mesh carries a real "model" axis). Slots partition contiguously across
data shards; a slot's tail, frontier, and decode pages always come from
its own shard's subpool, so the fused macro-step's block-table advance
and KV scatter/gather stay shard-local. Admission control is therefore
shard-local too: ``_paged_affordable`` walks the exact slots an
admission would occupy and funds each candidate from its slot's shard.
Decode numerics and sampling are sharding-invariant, so token streams
are bit-identical to the single-device engine whenever pool capacity
does not bind (pinned by ``tests/test_serving_sharded.py`` under forced
host devices); under pool pressure, shard-local capacity can queue a
request a single global pool would have admitted — deliberate: that is
the accounting the page-axis sharding requires — which reorders
admissions rather than corrupting any stream.

Traffic-level decisions (which queued request or pending round gets the
free slots, with how many candidates and what per-candidate token limit)
are delegated to a pluggable scheduler (``serving/scheduler.py``):
``fifo`` reproduces the historical loop bit-exactly; ``coverage`` ranks
work by posterior coverage deficit + expected marginal gain under an
optional stream-wide token budget. The paged path can additionally
share page-aligned prompt prefixes across requests (``prefix_cache=True``,
``PagePool``'s content-hash chain): hits skip the shared pages' prefill
entirely via ``Model.prefill_suffix`` against the cached pages' KV.
"""
from __future__ import annotations

import dataclasses
import hashlib as _hashlib
from functools import partial
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import (ATTN, LOCAL_ATTN, CAMDConfig, PagedKVConfig,
                          SamplingConfig)
from repro.core import controller as ctrl
from repro.models import attention as attn_lib
from repro.models.model import Model
from repro.sampling.samplers import (decode_step_key, sample_token,
                                     sample_token_batch, speculative_accept)
from repro.serving.page_pool import PagePool, prefix_page_keys
from repro.serving.scheduler import (NewWork, PrefillWork, RoundWork,
                                     SchedulerContext, make_scheduler)
from repro.serving.state_arena import StateArena


# ---------------------------------------------------------------------------
# Requests / results
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                      # (L,) int32
    evidence: Optional[np.ndarray] = None   # (Ne, De) frontend embeddings
    max_new_tokens: int = 0                 # 0 => engine default
    image: Optional[np.ndarray] = None      # (H, W, C) raw image; the
                                            # engine's vision tower encodes
                                            # it into evidence at submit
                                            # (content-hash memoized)


@dataclasses.dataclass
class Result:
    uid: int
    tokens: np.ndarray                      # best candidate's generation
    n_candidates: int
    tokens_spent: int
    rounds: int
    p_star: float
    best_score: float
    stopped_early: bool
    candidates: List[Dict[str, Any]]        # per-candidate records
    cancelled: bool = False                 # aborted via ServeEngine.cancel


# ---------------------------------------------------------------------------
# Device-side engine state
# ---------------------------------------------------------------------------

class EngineState(NamedTuple):
    cache: Any
    last_token: jax.Array      # (B,)
    token_counts: jax.Array    # (B, V)
    sum_lp: jax.Array          # (B,)
    n_tok: jax.Array           # (B,) int32
    prev_h: jax.Array          # (B, d)
    sum_coh: jax.Array         # (B,)
    sum_emb: jax.Array         # (B, d)
    align_sum: jax.Array       # (B,)
    active: jax.Array          # (B,) bool
    out_buf: jax.Array         # (B, max_new)
    bias: jax.Array            # (B, V) CAMD mixture guidance
    greedy: jax.Array          # (B,) bool
    limit: jax.Array           # (B,) int32 per-candidate token limit
                               # (= max_new unless the scheduler granted a
                               # tighter budget-constrained limit)
    hist: jax.Array            # (B, H) int32 fed-token history per cache
                               # position (-1 = none/evidence) — the
                               # device-resident n-gram draft table.
                               # H = cache_len when speculation is on,
                               # 1 (dummy) otherwise
    spec_k: jax.Array          # (B,) int32 per-slot draft block length
                               # (coverage-aware; 1 = no drafting)


def _next_pow2(n: int) -> int:
    return 1 << max(0, (n - 1).bit_length())


class ServeEngine:
    def __init__(self, model: Model, params, *, slots: int = 8,
                 cache_len: int = 512,
                 sampling: SamplingConfig = SamplingConfig(),
                 camd: CAMDConfig = CAMDConfig(),
                 mode: str = "camd",
                 n_candidates: int = 8,
                 eos_id: int = 1,
                 max_new_tokens: int = 64,
                 impl: str = "xla",
                 paged_kv: PagedKVConfig = PagedKVConfig(),
                 macro_steps: int = 8,
                 bucket_prefill: bool = True,
                 prefill_bucket_min: int = 16,
                 sched_policy="fifo",
                 global_budget: int = 0,
                 sched_kwargs: Optional[Dict[str, Any]] = None,
                 prefix_cache: bool = False,
                 prefill_chunk: int = 0,
                 prefill_chunk_budget: int = 0,
                 prefill_shards: int = 0,
                 mesh=None,
                 spec_k: int = 0,
                 spec_mode: str = "coverage",
                 spec_ngram: int = 2,
                 xmodal_rescore: bool = False,
                 seed: int = 0):
        assert mode in ("camd", "best_of_n", "self_consistency", "greedy")
        assert impl in ("xla", "pallas", "paged", "paged_pallas")
        assert macro_steps >= 0
        # speculative decoding: draft up to spec_k-1 tokens per slot from
        # the device-resident n-gram table, verify them with ONE batched
        # block forward per loop iteration. spec_k <= 1 keeps the plain
        # one-token-per-step loop.
        assert spec_mode in ("coverage", "fixed")
        assert spec_ngram >= 1
        self.spec = spec_k > 1
        self.spec_k = spec_k if self.spec else 0
        self.spec_mode = spec_mode
        self.spec_ngram = spec_ngram
        if self.spec:
            assert macro_steps >= 1, \
                "speculative decoding runs inside the fused macro-step " \
                "loop (macro_steps >= 1)"
            assert model.supports_speculative, \
                "speculative block verification needs an all-attention " \
                "full-context decoder-only model"
        self.model, self.params = model, params
        # mesh-parallel serving: dp = product of the mesh's data axes.
        # Slots partition contiguously across the dp shards; all
        # device-side placement happens in _install_mesh below.
        self.mesh = mesh
        self.dp = 1
        if mesh is not None:
            from repro.distributed.sharding import dp_axes
            self.dp = max(1, int(np.prod(
                [mesh.shape[a] for a in dp_axes(mesh)], dtype=np.int64)))
            assert slots % self.dp == 0, \
                f"slots {slots} must divide across {self.dp} data shards"
        self.slots_per_shard = slots // self.dp
        self.cfg = model.cfg
        self.B = slots
        self.V = self.cfg.vocab_size
        self.d = self.cfg.d_model
        self.cache_len = cache_len
        self.sampling = sampling
        self.camd = camd
        self.mode = mode
        self.n_candidates = 1 if mode == "greedy" else n_candidates
        self.eos_id = eos_id
        self.max_new = max_new_tokens
        self.impl = impl
        # macro_steps K: device steps per lax.while_loop launch. 0 keeps
        # the legacy per-token host loop (one dispatch + one sync per
        # token) for A/B benchmarking against the fused path.
        self.macro_steps = macro_steps
        # paged serving: KV lives in a shared page pool; "paged" runs the
        # gather+sdpa XLA attention (bit-identical to the dense path),
        # "paged_pallas" the block-table flash-decode kernel.
        self.paged = impl.startswith("paged")
        # slot-state kind: "kv" slots own pageable KV only, "recurrent"
        # slots own fixed-size state only (SSD/RG-LRU rows), "hybrid"
        # both. Paged impls need at least one full-context attention
        # layer to page; recurrent/hybrid state is fixed-stride and is
        # managed by the StateArena below instead.
        self.state_kind = model.state_kind
        if self.paged and not model.has_pageable_layers:
            raise ValueError(
                f"impl={impl!r} pages full-context attention KV, but "
                f"{model.cfg.name} ({self.state_kind}) has no pageable "
                "layers — serve it with impl='xla'/'pallas' (fixed-stride "
                "state rows are arena-managed, not paged)")
        self._model_impl = {"paged": "xla", "paged_pallas": "pallas"}[impl] \
            if self.paged else impl
        # cross-request prefix cache: paged engines on all-attention
        # decoders only (cached pages must cover every layer's prompt KV).
        self.prefix_cache = bool(prefix_cache) and self.paged and \
            model.supports_prefix_cache
        # KV storage dtype for the paged pool. "auto" keeps the engine's
        # param dtype (historical behavior, byte-identical streams);
        # int8/fp8 pools carry per-(page, slot, kv-head) scales and
        # dequantize inside the attention kernels.
        self.kv_dtype = paged_kv.kv_dtype
        if not self.paged:
            assert self.kv_dtype == "auto", \
                f"kv_dtype={self.kv_dtype!r} needs a paged impl " \
                "(dense caches always store the param dtype)"
        if self.paged:
            # fail fast on unknown names / fp8-less jax builds
            _, self.kv_quantized = attn_lib.kv_storage_dtype(
                self.kv_dtype, model.param_dtype)
            ps = paged_kv.page_size
            assert cache_len % ps == 0, \
                f"cache_len {cache_len} must be a multiple of page_size {ps}"
            self.page_size = ps
            self.pages_per_slot = cache_len // ps
            # one quarantine page per data shard; a caller-given pool
            # size is rounded up to a shard multiple (page-axis sharding
            # needs equal subpools)
            num_pages = paged_kv.num_pages or \
                slots * self.pages_per_slot + self.dp
            if num_pages % self.dp:
                num_pages += self.dp - num_pages % self.dp
            self.pool = PagePool(num_pages, ps,
                                 prefix_cache=self.prefix_cache,
                                 num_shards=self.dp,
                                 kv_byte_budget=paged_kv.kv_byte_budget)
            self._slot_pages: List[List[int]] = [[] for _ in range(slots)]
            self._slot_pos = np.zeros(slots, np.int64)
            self._slot_limit = np.zeros(slots, np.int64)  # L + max_new
            # admission control: pages a running candidate may still
            # allocate are *reserved* at admit time, so a candidate that
            # was admitted can always finish — pool pressure surfaces as
            # queueing delay at _schedule, never as a mid-decode crash.
            # Reservations are tracked per data shard (a slot's future
            # pages can only come from its own shard's subpool).
            self._slot_reserved = np.zeros(slots, np.int64)
            self._reserved_sh = np.zeros(self.dp, np.int64)
            # frontier width: the most page boundaries one slot can cross
            # in K device steps, plus one for the boundary the first step
            # may land on. With speculation each step may commit up to
            # spec_k tokens, so the worst-case advance is K * spec_k.
            adv = max(macro_steps, 1) * max(spec_k, 1)
            self._frontier_width = min(max(1, -(-adv // ps) + 1),
                                       self.pages_per_slot)
            # chunked prefill: long prompts stream into the pool in
            # page-aligned chunks through the suffix path, interleaved
            # with decode launches so decode-bound slots keep streaming
            # behind a long prompt. Needs the suffix machinery (paged,
            # all-attention full-context decoder); any other engine
            # silently degrades to whole-prompt prefill.
            self.chunked = prefill_chunk > 0 and model.supports_prefix_cache
            self.chunk = -(-int(prefill_chunk) // ps) * ps \
                if self.chunked else 0
            self.chunk_budget = int(prefill_chunk_budget) or self.chunk
            # prefill/decode disaggregation: prompt/chunk pages are
            # placed on the first ``prefill_shards`` shards of the page
            # axis; decode slots elsewhere reference them cross-shard
            # (pages are the transfer currency — GSPMD gathers, no KV
            # copies). Tail + frontier pages stay slot-local.
            self.prefill_shards = int(prefill_shards)
            assert 0 <= self.prefill_shards <= self.dp, \
                f"prefill_shards {prefill_shards} must be in [0, dp={self.dp}]"
        else:
            self.pool = None
            self.chunked = False
            self.chunk = 0
            self.chunk_budget = 0
            assert prefill_shards == 0, \
                "prefill/decode disaggregation needs a paged impl"
            self.prefill_shards = 0
        self.key = jax.random.PRNGKey(seed)
        # decode-loop keys are folded from a dedicated base key and the
        # global step index (not split per step), so the sampled stream is
        # invariant to macro-step partitioning; self.key keeps feeding the
        # admission-time first-token sampling.
        self._decode_key = jax.random.fold_in(jax.random.PRNGKey(seed),
                                              0x6d6163)
        self._t = 0                      # global decode step counter
        self.has_evidence = bool(self.cfg.num_evidence_tokens)
        # image frontend: submit-time vision-tower encode, memoized by
        # image content hash (bounded FIFO); the digest also keys the
        # image's pseudo-token prefix-cache stream.
        self._vision_fn = None
        self._image_feats: Dict[bytes, np.ndarray] = {}
        self._image_digest: Dict[int, bytes] = {}
        self.image_encodes = 0
        self.image_feat_hits = 0
        # evidence-weighted candidate rescoring through the fused
        # xmodal_score kernel (Eq. 8-9) instead of the running host-side
        # alignment aggregate — opt-in, recorded per candidate.
        self.xmodal_rescore = bool(xmodal_rescore) and self.has_evidence
        self._xmodal_jit = None

        self._queue: List[Request] = []
        self._slot_req = np.full(slots, -1, np.int64)   # uid per slot
        self._slot_cand = np.full(slots, -1, np.int64)  # candidate uid per slot
        self._slot_lim = np.full(slots, max_new_tokens, np.int64)
        # host mirror of per-slot draft length (frontier staging sizes
        # the worst-case advance with it)
        self._slot_spec = np.ones(slots, np.int64)
        self._reqs: Dict[int, Dict[str, Any]] = {}      # uid -> bookkeeping
        self._next_cand = 0
        self._dtype = model.param_dtype

        # traffic-level policy: every admission / round decision is
        # delegated to the scheduler (serving/scheduler.py). "fifo"
        # reproduces the pre-scheduler engine decision for decision.
        self.scheduler = make_scheduler(sched_policy,
                                        global_budget=global_budget,
                                        **(sched_kwargs or {}))
        self._arrival: Dict[int, int] = {}              # uid -> submit order
        self._submit_seq = 0
        self.starved_uids: List[int] = []               # budget-starved
        # prefill telemetry (the prefix cache exists to shrink these)
        self.prefill_calls = 0
        self.prefill_tokens = 0
        # chunked-prefill ledger: uid -> in-flight job ({"req", "pos",
        # "pages", "shard"}); requests stay queued until their final
        # chunk promotes them to _reqs, so _has_pending/cancel/starved
        # paths see them through the queue. The per-turn chunk-token
        # budget (_chunk_left) resets each _step.
        self._chunking: Dict[int, Dict[str, Any]] = {}
        self._chunk_progress = False
        self._chunk_left = self.chunk_budget
        self.chunk_calls = 0
        self.chunk_tokens = 0

        # bucketed prefill: only exact for attention-only decoders, and
        # only when the padded bucket fits every attention ring without
        # wrapping (_bucket_fits).
        self.bucket_prefill = bool(bucket_prefill) and \
            model.supports_bucketed_prefill
        self.prefill_bucket_min = prefill_bucket_min
        rings = []
        for kind in self.cfg.layer_kinds:
            if kind == ATTN:
                rings.append(cache_len if self.cfg.attn_window == 0
                             else min(cache_len, self.cfg.attn_window))
            elif kind == LOCAL_ATTN:
                rings.append(min(cache_len, self.cfg.local_window))
        self._min_ring = min(rings) if rings else cache_len

        self.state = self._blank_state()
        # fixed-stride state arena: recurrent/hybrid prompt rows live in
        # a bounded device-side buffer (model.make_cache over arena
        # rows) managed with PagePool's disciplines — per-shard free
        # lists, refcounts, conservation, telemetry — instead of the
        # unbounded per-request host dict the kv path never needed.
        self.arena = None
        self._arena_buf = None
        if self.state_kind != "kv" and not self.paged:
            per_shard = 2 * self.slots_per_shard + 4
            rows = per_shard * self.dp
            self.arena = StateArena(rows, num_shards=self.dp)
            self._arena_buf = self.model.make_cache(
                rows, self.cache_len, dtype=self._dtype)
        if self.paged:
            # the pool enforces the resident-KV byte budget itself; give
            # it the engine's bytes-per-page (values + quant scales)
            self.pool.set_bytes_per_page(self._bytes_per_page())
        self._state_sharding = None
        self._evid_sharding = None
        self._frontier_sharding = None
        if mesh is not None:
            self._install_mesh(mesh)
        self._step_body = self._make_step_body()
        # the engine state is donated into every decode launch: the host
        # always rebinds self.state to the launch's output, so XLA may
        # reuse the input buffers in place instead of copying the whole
        # KV cache + aggregates each dispatch (the paged-K8 bench
        # regression: ~4 MB of state copied per macro launch).
        self._step_fn = jax.jit(self._step_body, donate_argnums=(1,))
        self._macro_fn = self._build_macro_step_spec() if self.spec \
            else self._build_macro_step()
        self._prefill_fn = self._build_prefill()
        self._bucket_fn = self._build_bucket_prefill()
        self._first_fn = self._build_first_tokens()
        self._suffix_fn = self._build_suffix_prefill() \
            if (self.prefix_cache or self.chunked) else None
        self._greedy_row = jnp.asarray([self.mode == "greedy"])
        self._round_fn = jax.jit(ctrl.batched_round_update_assign(self.camd))
        self._dummy_frontier = jnp.zeros((slots, 1), jnp.int32)
        # telemetry: total_steps counts device decode steps;
        # macro_launches counts while_loop dispatches; host_syncs counts
        # decode-loop host<->device synchronizations (the quantity the
        # macro-step refactor exists to amortize).
        self.total_steps = 0
        self.total_tokens = 0
        self.macro_launches = 0
        self.host_syncs = 0
        # speculation telemetry: drafts proposed / drafts accepted
        self.spec_drafted = 0
        self.spec_accepted = 0
        # async front-end plumbing: opt-in per-launch token streaming
        # (readbacks ride the launch sync — no extra host syncs), a
        # completion feed the front-end drains between launches, and
        # request-level cancellation applied at step boundaries.
        self.stream_tokens = False
        self.stream_events: List[Tuple[int, int, np.ndarray]] = []
        self._slot_streamed = np.zeros(self.B, np.int64)
        self._newly_done: List[int] = []
        self._cancels: set = set()
        self.cancelled_requests = 0
        # evidence rows staged for the next launch (set by _begin)
        self._evid = None

    # ------------------------------------------------------------------
    # mesh placement
    # ------------------------------------------------------------------
    def _install_mesh(self, mesh):
        """Place params and engine state on the serving mesh: the decode
        batch and every per-slot state leaf shard over the data axis,
        paged KV pools over the page axis (boundaries matching the host
        allocator's per-shard page-id ranges), params replicated — or
        tensor-parallel via the training sharding rules when the mesh
        has a real "model" axis."""
        from jax.sharding import NamedSharding
        from repro.distributed.sharding import (batch_leading_spec,
                                                cache_specs,
                                                engine_state_specs,
                                                serve_param_specs,
                                                to_shardings)
        specs = engine_state_specs(self.cfg, self.state, mesh)
        self._state_sharding = to_shardings(mesh, specs)
        self.state = jax.device_put(self.state, self._state_sharding)
        if self._arena_buf is not None:
            # arena rows partition over the data axis exactly like slot
            # rows: shard s's row range [s*rows_per_shard, ...) lands on
            # shard s, matching the host allocator's per-shard free lists
            self._arena_buf = jax.device_put(
                self._arena_buf,
                to_shardings(mesh, cache_specs(self.cfg, self._arena_buf,
                                               mesh)))
        self.params = jax.device_put(
            self.params,
            to_shardings(mesh, serve_param_specs(self.cfg, self.params,
                                                 mesh)))
        self._evid_sharding = NamedSharding(
            mesh, batch_leading_spec(mesh, (self.B, 1, self.d)))
        self._frontier_sharding = NamedSharding(
            mesh, batch_leading_spec(mesh, (self.B, 1)))

    def _reshard(self):
        """Pin the state back onto its canonical mesh placement before a
        decode launch. Host-side admission/bookkeeping scatters run
        eagerly and may leave leaves with drifted shardings; re-placing
        is a no-op for already-correct leaves and guarantees the jitted
        decode fns always see ONE input sharding (no per-pattern
        recompiles, and the macro-step loop stays device-resident)."""
        if self._state_sharding is not None:
            self.state = jax.device_put(self.state, self._state_sharding)

    def _slot_shard(self, s: int) -> int:
        """Data shard owning slot ``s`` (contiguous partition)."""
        return s // self.slots_per_shard

    def _quarantine(self, s: int) -> int:
        """Quarantine page idle slot ``s`` points its block table at —
        its own shard's reserved page, so dead writes stay local."""
        return self.pool.quarantine_page(self._slot_shard(s)) \
            if self.paged else 0

    @property
    def _reserved(self) -> int:
        """Total page reservations held by running candidates — derived
        from the per-shard ledger so the two can never drift."""
        return int(self._reserved_sh.sum())

    def _shard_headroom(self, s: int) -> int:
        """Pages shard ``s`` could fund right now: free + cache-evictable
        minus reservations already charged to it — THE admission-headroom
        definition, shared by seeding, placement, and affordability."""
        return self.pool.free_pages_in(s) + self.pool.evictable(s) \
            - int(self._reserved_sh[s])

    # ------------------------------------------------------------------
    def _sync(self, tree):
        """Decode-loop host readback: one counted synchronization."""
        self.host_syncs += 1
        return jax.device_get(tree)

    def _any_live(self) -> bool:
        """Host-side activity check — live slots mirror device ``active``
        exactly (slots are freed the moment their candidate finishes), so
        the per-iteration ``jnp.any(state.active)`` device round-trip of
        the old loop is free."""
        return bool((self._slot_req >= 0).any())

    # ------------------------------------------------------------------
    def _blank_state(self) -> EngineState:
        B, V, d = self.B, self.V, self.d
        if self.paged:
            cache = self.model.make_paged_cache(
                B, self.cache_len, self._dtype,
                page_size=self.page_size, num_pages=self.pool.num_pages,
                kv_dtype=self.kv_dtype)
            if self.dp > 1:
                # idle slots quarantine into their OWN shard's reserved
                # page (page 0 of each shard's id range) so dead writes
                # never cross shards
                q = np.asarray([[self._quarantine(s)] * self.pages_per_slot
                                for s in range(B)], np.int32)
                cache = {**cache, "block_table": jnp.asarray(q)}
        else:
            cache = self.model.make_cache(B, self.cache_len, self._dtype)
        return EngineState(
            cache=cache,
            last_token=jnp.zeros((B,), jnp.int32),
            token_counts=jnp.zeros((B, V), jnp.float32),
            sum_lp=jnp.zeros((B,), jnp.float32),
            n_tok=jnp.zeros((B,), jnp.int32),
            prev_h=jnp.zeros((B, d), jnp.float32),
            sum_coh=jnp.zeros((B,), jnp.float32),
            sum_emb=jnp.zeros((B, d), jnp.float32),
            align_sum=jnp.zeros((B,), jnp.float32),
            active=jnp.zeros((B,), bool),
            out_buf=jnp.zeros((B, self.max_new), jnp.int32),
            bias=jnp.zeros((B, V), jnp.float32),
            greedy=jnp.zeros((B,), bool),
            limit=jnp.full((B,), self.max_new, jnp.int32),
            hist=jnp.full((B, self.cache_len if self.spec else 1), -1,
                          jnp.int32),
            spec_k=jnp.ones((B,), jnp.int32),
        )

    # ------------------------------------------------------------------
    def _build_prefill(self):
        model = self.model

        @jax.jit
        def prefill(params, tokens, cache_row, evidence=None):
            lg, h, cache = model.prefill(params, tokens, cache_row,
                                         evidence, impl=self._model_impl)
            return lg, h, cache

        return prefill

    def _build_bucket_prefill(self):
        model, impl = self.model, self._model_impl

        @jax.jit
        def prefill(params, tokens, lengths, cache, evidence=None):
            return model.prefill(params, tokens, cache, evidence,
                                 impl=impl, lengths=lengths)

        return prefill

    def _build_first_tokens(self):
        sampling = self.sampling

        @jax.jit
        def first(keys, logits, bias, greedy):
            return sample_token_batch(keys, logits, sampling, bias=bias,
                                      greedy=greedy)

        return first

    def _build_suffix_prefill(self):
        """Continuation prefill for prefix-cache hits: only the prompt
        *suffix* runs, attending to the cached pages' K/V as context.
        Compiles once per (suffix_len, prefix_pages) shape pair."""
        model, impl = self.model, self._model_impl

        @jax.jit
        def suffix(params, tokens, cache_row, ctx, start):
            return model.prefill_suffix(params, tokens, cache_row, ctx,
                                        start, impl=impl)

        return suffix

    def _make_step_body(self):
        """One decode+sample+aggregate step — the body shared by the
        legacy jitted per-token step and the macro-step while_loop."""
        model, sampling, eos, max_new = self.model, self.sampling, \
            self.eos_id, self.max_new
        has_ev = self.has_evidence

        def step(params, st: EngineState, key, evid_norm):
            logits, hidden, cache = model.decode_step(
                params, st.last_token, st.cache, impl=self._model_impl)
            tok, lp = sample_token(key, logits.astype(jnp.float32), sampling,
                                   st.token_counts, st.bias, greedy=st.greedy)
            act = st.active
            actf = act.astype(jnp.float32)
            hidden32 = hidden.astype(jnp.float32)

            # --- incremental CAMD aggregates ------------------------------
            sum_lp = st.sum_lp + lp * actf
            hn = hidden32 / (jnp.linalg.norm(hidden32, axis=-1, keepdims=True) + 1e-8)
            pn = st.prev_h
            coh = jnp.sum(hn * pn, axis=-1)
            has_prev = st.n_tok > 0
            sum_coh = st.sum_coh + coh * actf * has_prev.astype(jnp.float32)
            sum_emb = st.sum_emb + hidden32 * actf[:, None]
            if has_ev:
                emb_t = jnp.take(params["embed"]["table"], tok, axis=0)
                emb_t = emb_t.astype(jnp.float32)
                emb_t = emb_t / (jnp.linalg.norm(emb_t, axis=-1, keepdims=True) + 1e-8)
                a = jnp.mean(jnp.einsum("bnd,bd->bn", evid_norm, emb_t), axis=-1)
                align_sum = st.align_sum + a * actf
            else:
                align_sum = st.align_sum

            counts = st.token_counts + jax.nn.one_hot(tok, st.token_counts.shape[1]) \
                * actf[:, None]
            out_buf = jnp.where(
                (jnp.arange(max_new)[None, :] == st.n_tok[:, None]) & act[:, None],
                tok[:, None], st.out_buf)
            n_tok = st.n_tok + act.astype(jnp.int32)
            # per-slot limit (== max_new unless the scheduler granted a
            # tighter budget-constrained one) ends the candidate exactly
            # where the budget accounting assumed it would.
            done = act & ((tok == eos) | (n_tok >= st.limit))
            new_state = EngineState(
                cache=cache, last_token=jnp.where(act, tok, st.last_token),
                token_counts=counts, sum_lp=sum_lp, n_tok=n_tok,
                prev_h=jnp.where(act[:, None], hn, st.prev_h),
                sum_coh=sum_coh, sum_emb=sum_emb, align_sum=align_sum,
                active=act & ~done, out_buf=out_buf, bias=st.bias,
                greedy=st.greedy, limit=st.limit, hist=st.hist,
                spec_k=st.spec_k)
            return new_state, done

        return step

    def _build_macro_step(self):
        """Fused decode loop: up to K steps of ``_step_body`` inside
        ``lax.while_loop``, exiting early when every slot goes inactive or
        any slot finishes (the host must fold the candidate / round).

        The paged block-table advance is inverted relative to the legacy
        host loop: instead of the host scattering a freshly-allocated page
        before every step, the device pulls the next page from the
        pre-staged ``frontier`` row whenever a slot's write position
        crosses a page boundary.
        """
        K = max(self.macro_steps, 1)
        paged = self.paged
        ps = self.page_size if paged else 0
        step_body = self._step_body
        B = self.B

        @partial(jax.jit, donate_argnums=(1,))
        def macro(params, st: EngineState, base_key, t0, evid_norm, frontier):
            F = frontier.shape[1]

            def cond(carry):
                st, fidx, done, i = carry
                return (i < K) & jnp.any(st.active) & ~jnp.any(done)

            def body(carry):
                st, fidx, done, i = carry
                if paged:
                    pos = st.cache["pos"]
                    bt = st.cache["block_table"]
                    need = st.active & (jnp.mod(pos, ps) == 0)
                    li = jnp.clip(pos // ps, 0, bt.shape[1] - 1)
                    page = jnp.take_along_axis(
                        frontier, jnp.clip(fidx, 0, F - 1)[:, None],
                        axis=1)[:, 0]
                    hit = jnp.arange(bt.shape[1])[None, :] == li[:, None]
                    bt = jnp.where(need[:, None] & hit, page[:, None], bt)
                    st = st._replace(cache={**st.cache, "block_table": bt})
                    fidx = fidx + need.astype(jnp.int32)
                key = decode_step_key(base_key, t0 + i)
                st, done = step_body(params, st, key, evid_norm)
                return st, fidx, done, i + jnp.int32(1)

            carry = (st, jnp.zeros((B,), jnp.int32),
                     jnp.zeros((B,), bool), jnp.int32(0))
            st, fidx, done, i = jax.lax.while_loop(cond, body, carry)
            return st, done, i

        return macro

    def _coverage_k(self, p_star) -> int:
        """Per-candidate speculative verify width (1..spec_k).

        ``coverage`` mode shrinks the draft length toward 1 as the
        request's posterior coverage deficit closes — verify-compute
        follows the residual risk, mirroring the CAMD stopping rule.
        ``p_star`` is the request's current posterior coverage (None
        before the first round's rescore, which grants the full budget).
        """
        if not self.spec:
            return 1
        if self.spec_mode != "coverage":
            return self.spec_k
        deficit = max(0.0, (1.0 - self.camd.delta) - (p_star or 0.0))
        frac = min(1.0, deficit / max(1e-9, 1.0 - self.camd.delta))
        return 1 + int(round((self.spec_k - 1) * frac))

    def _ngram_draft(self, hist, pos, last):
        """Device-side n-gram draft proposal, vectorized over slots.

        ``hist[b, p]`` is the token fed at cache position p (prompt +
        committed decode tokens; -1 for evidence/unfed). The proposer
        finds an earlier position j whose context-gram ending at
        ``hist[j]`` matches the current suffix ending at the pending
        token ``last`` — deepest context first (``spec_ngram``-gram),
        backing off one token at a time to a plain 1-gram match — and
        proposes the spec_k-1 tokens that followed it. Within a context
        depth it prefers the most recent match with all spec_k-1
        followers known over a fresher partial match. Returns
        (B, spec_k-1) int32, -1 where no match / out of range — an
        unmatched draft position is simply never accepted, so a bad
        proposal costs nothing but wasted verify width."""
        B, H = hist.shape
        n_draft = self.spec_k - 1
        idx = jnp.arange(H)
        # j < pos-1: a match at the latest fed position has no known
        # followers (nothing to propose), and taking the max would shadow
        # an older match that does
        m = (hist == last[:, None]) & (idx[None, :] < pos[:, None] - 1)
        full = idx[None, :] + n_draft < pos[:, None]

        def pick(m):
            # most recent full-width match, else most recent partial
            # (periodic generations put the nearest match right at the
            # tail, where it can only seed a 1-token draft; an older
            # full match proposes the same continuation at full width)
            j_full = jnp.max(jnp.where(m & full, idx[None, :], -1), axis=1)
            j_any = jnp.max(jnp.where(m, idx[None, :], -1), axis=1)
            return jnp.where(j_full >= 0, j_full, j_any)

        j = pick(m)                                   # 1-gram fallback
        for g in range(1, self.spec_ngram):
            # context token g steps back from the pending token
            ctx = jnp.take_along_axis(
                hist, jnp.clip(pos[:, None] - g, 0, H - 1), axis=1)[:, 0]
            prev = jnp.pad(hist, ((0, 0), (g, 0)),
                           constant_values=-2)[:, :H]     # hist[j-g]
            m &= (idx[None, :] >= g) & (pos[:, None] >= g) & \
                (prev == ctx[:, None]) & (ctx[:, None] >= 0)
            jg = pick(m)
            j = jnp.where(jg >= 0, jg, j)             # deeper match wins
        src = j[:, None] + jnp.arange(1, n_draft + 1)[None, :]   # (B, n-1)
        ok = (j >= 0)[:, None] & (src < pos[:, None])
        d = jnp.take_along_axis(hist, jnp.clip(src, 0, H - 1), axis=1)
        return jnp.where(ok, d, -1)

    def _build_macro_step_spec(self):
        """Speculative macro-step loop: each iteration drafts up to
        spec_k-1 tokens per slot from the n-gram table, verifies the
        whole block with ONE batched target forward
        (``model.decode_block``), and commits the accepted prefix via
        ``samplers.speculative_accept`` — greedy rows byte-identical to
        the sequential loop, sampled rows distribution-preserving.

        The paged block-table advance is a pure function of the slot's
        position: logical page li maps to ``frontier[s, li - li0]`` with
        li0 fixed at launch start, so partial acceptance (pos advancing
        less than the mapped extent) is self-correcting — the next
        iteration simply re-maps the same frontier entries.
        """
        K = max(self.macro_steps, 1)
        Kb = self.spec_k
        paged = self.paged
        ps = self.page_size if paged else 0
        model, sampling, eos, max_new = self.model, self.sampling, \
            self.eos_id, self.max_new
        has_ev = self.has_evidence
        impl = self._model_impl
        B, V = self.B, self.V
        # every admitted row is greedy iff the engine mode is — a static
        # fact, so the accept kernel can take its vectorized greedy path
        all_greedy = self.mode == "greedy"

        @partial(jax.jit, donate_argnums=(1,))
        def macro(params, st: EngineState, base_key, t0, evid_norm,
                  frontier):
            F = frontier.shape[1]
            # first logical page the frontier row maps to (fixed at
            # launch start — frontier entries are indexed by logical
            # page offset relative to this)
            li0 = -(-st.cache["pos"] // ps) if paged else None

            def cond(carry):
                st, done, i, nd, na = carry
                return (i < K) & jnp.any(st.active) & ~jnp.any(done)

            def body(carry):
                st, done, i, n_drafted, n_accepted = carry
                pos = st.cache["pos"]
                if paged:
                    bt = st.cache["block_table"]
                    nlog = bt.shape[1]
                    li = jnp.arange(nlog)[None, :]
                    fr_idx = li - li0[:, None]                 # (B, nlog)
                    need = st.active[:, None] & \
                        (li >= (pos // ps)[:, None]) & \
                        (li <= ((pos + Kb - 1) // ps)[:, None]) & \
                        (fr_idx >= 0) & (fr_idx < F)
                    page = jnp.take_along_axis(
                        frontier, jnp.clip(fr_idx, 0, F - 1), axis=1)
                    bt = jnp.where(need, page, bt)
                    st = st._replace(cache={**st.cache, "block_table": bt})

                draft = self._ngram_draft(st.hist, pos, st.last_token)
                # coverage-aware per-slot draft length: mask positions
                # beyond the slot's spec_k
                draft = jnp.where(
                    jnp.arange(Kb - 1)[None, :] < (st.spec_k - 1)[:, None],
                    draft, -1)
                blk = jnp.concatenate(
                    [st.last_token[:, None], jnp.maximum(draft, 0)], axis=1)
                # feedable positions: at most limit - n_tok more tokens
                # may be emitted, so later block positions never need KV
                valid = st.active[:, None] & \
                    (jnp.arange(Kb)[None, :] < (st.limit - st.n_tok)[:, None])
                logits, hidden, cache = model.decode_block(
                    params, blk, st.cache, valid, impl=impl)
                toks, lps, emit, counts, n_new, stopped = speculative_accept(
                    base_key, t0 + i * Kb, logits.astype(jnp.float32),
                    draft, sampling, token_counts=st.token_counts,
                    bias=st.bias, greedy=st.greedy, eos_id=eos,
                    n_tok=st.n_tok, limit=st.limit, active=st.active,
                    greedy_static=all_greedy)
                act = st.active
                emitf = emit.astype(jnp.float32)           # (B, Kb)
                n_emit = jnp.sum(emit, axis=1).astype(jnp.int32)
                last_i = jnp.maximum(n_emit - 1, 0)[:, None]

                # --- incremental CAMD aggregates over the block -------
                sum_lp = st.sum_lp + jnp.sum(lps * emitf, axis=1)
                hidden32 = hidden.astype(jnp.float32)      # (B, Kb, d)
                hn = hidden32 / (jnp.linalg.norm(
                    hidden32, axis=-1, keepdims=True) + 1e-8)
                prev_chain = jnp.concatenate(
                    [st.prev_h[:, None], hn[:, :-1]], axis=1)
                coh = jnp.sum(hn * prev_chain, axis=-1)    # (B, Kb)
                coh_w = emitf.at[:, 0].mul(
                    (st.n_tok > 0).astype(jnp.float32))
                sum_coh = st.sum_coh + jnp.sum(coh * coh_w, axis=1)
                sum_emb = st.sum_emb + jnp.sum(
                    hidden32 * emitf[:, :, None], axis=1)
                if has_ev:
                    emb_t = jnp.take(params["embed"]["table"], toks,
                                     axis=0).astype(jnp.float32)
                    emb_t = emb_t / (jnp.linalg.norm(
                        emb_t, axis=-1, keepdims=True) + 1e-8)
                    a = jnp.mean(jnp.einsum("bnd,bkd->bkn", evid_norm,
                                            emb_t), axis=-1)
                    align_sum = st.align_sum + jnp.sum(a * emitf, axis=1)
                else:
                    align_sum = st.align_sum

                # emitted tokens land at out_buf[n_tok .. n_tok+n_emit)
                tgt = st.n_tok[:, None] + jnp.arange(Kb)[None, :]
                out_buf = st.out_buf.at[
                    jnp.arange(B)[:, None],
                    jnp.where(emit, tgt, max_new)].set(toks, mode="drop")
                # fed tokens [last, toks[:-1]] enter the n-gram table at
                # positions pos .. pos+n_emit
                fed = jnp.concatenate(
                    [st.last_token[:, None], toks[:, :-1]], axis=1)
                hpos = pos[:, None] + jnp.arange(Kb)[None, :]
                hist = st.hist.at[
                    jnp.arange(B)[:, None],
                    jnp.where(emit, hpos, st.hist.shape[1])].set(
                        fed, mode="drop")

                last_tok = jnp.take_along_axis(toks, last_i, axis=1)[:, 0]
                prev_h = jnp.take_along_axis(
                    hn, last_i[:, :, None], axis=1)[:, 0]
                new_done = act & stopped
                cache = {**cache, "pos": pos + n_emit * act}
                st = EngineState(
                    cache=cache,
                    last_token=jnp.where(act, last_tok, st.last_token),
                    token_counts=counts, sum_lp=sum_lp, n_tok=n_new,
                    prev_h=jnp.where(act[:, None], prev_h, st.prev_h),
                    sum_coh=sum_coh, sum_emb=sum_emb, align_sum=align_sum,
                    active=act & ~new_done, out_buf=out_buf, bias=st.bias,
                    greedy=st.greedy, limit=st.limit, hist=hist,
                    spec_k=st.spec_k)
                n_drafted = n_drafted + jnp.sum(
                    (draft >= 0) & act[:, None]).astype(jnp.int32)
                n_accepted = n_accepted + jnp.sum(
                    jnp.maximum(n_emit - 1, 0) * act).astype(jnp.int32)
                return st, new_done, i + jnp.int32(1), n_drafted, n_accepted

            carry = (st, jnp.zeros((B,), bool), jnp.int32(0),
                     jnp.int32(0), jnp.int32(0))
            st, done, i, nd, na = jax.lax.while_loop(cond, body, carry)
            return st, done, i, nd, na

        return macro

    # ------------------------------------------------------------------
    # host-side scheduling
    # ------------------------------------------------------------------
    def submit(self, req: Request):
        # uids key the request table and results; a reused uid would
        # resurrect a finished request's bookkeeping (cache_row=None).
        if req.uid in self._reqs or any(r.uid == req.uid
                                        for r in self._queue):
            raise ValueError(f"duplicate request uid {req.uid}")
        if req.image is not None and req.evidence is None:
            self._encode_image(req)
        self._arrival[req.uid] = self._submit_seq
        self._submit_seq += 1
        self._queue.append(req)

    # -- image frontend ------------------------------------------------
    def _encode_image(self, req: Request) -> None:
        """Vision-tower encode at submit time: the image becomes the
        request's evidence embeddings — downstream prefill/scoring is
        unchanged. Features are memoized by content hash, so a repeated
        image (the multi-turn / shared-asset pattern) costs one dict
        lookup, and the same hash keys the cross-request prefix cache
        (``_prefix_token_stream``) so repeated images skip their pages'
        prefill entirely."""
        if self.cfg.vision is None:
            raise ValueError(
                f"request {req.uid} carries an image but {self.cfg.name} "
                "has no vision tower (cfg.vision is None)")
        img = np.ascontiguousarray(np.asarray(req.image, np.float32))
        digest = _hashlib.sha256(img.tobytes()).digest()
        self._image_digest[req.uid] = digest
        feats = self._image_feats.get(digest)
        if feats is None:
            if self._vision_fn is None:
                self._vision_fn = jax.jit(self.model.encode_image)
            feats = np.asarray(self._vision_fn(self.params, img[None])[0],
                               np.float32)
            self.image_encodes += 1
            self._image_feats[digest] = feats
            while len(self._image_feats) > 64:   # bounded FIFO memo
                self._image_feats.pop(next(iter(self._image_feats)))
        else:
            self.image_feat_hits += 1
        req.evidence = feats

    def _prefix_token_stream(self, req: Request) -> Optional[np.ndarray]:
        """The request's cache-position key stream for the prefix cache:
        one int64 per cache position. Text-only prompts are the prompt
        itself. Image requests prepend ``ne`` pseudo-tokens derived from
        the image content hash — two requests sharing image bytes and a
        prompt prefix then share page keys, so the image's KV pages hit
        across requests. Raw precomputed-evidence requests have no
        stable content key and stay uncacheable (None)."""
        if req.evidence is None:
            return np.asarray(req.prompt, np.int64)
        digest = self._image_digest.get(req.uid)
        if digest is None:
            return None
        ne = self.cfg.num_evidence_tokens
        rep = (digest * (ne * 8 // len(digest) + 1))[:ne * 8]
        pseudo = np.frombuffer(rep, np.int64).copy()
        return np.concatenate(
            [pseudo, np.asarray(req.prompt, np.int64)])

    def _cache_batch_axis(self, path) -> int:
        for p in path:
            if isinstance(p, jax.tree_util.DictKey) and p.key in (
                    "super", "self", "cross_k", "cross_v"):
                return 1
        return 0

    @staticmethod
    def _scat_rows(big, row, idx, ax: int):
        """Scatter a 1-row cache leaf into ``idx`` slots on batch axis
        ``ax`` (0 = per-slot leaves, 1 = layer-stacked leaves)."""
        r_rep = jnp.repeat(row, idx.shape[0], axis=ax)
        if ax == 0:
            return big.at[idx].set(r_rep)
        return big.at[:, idx].set(r_rep)

    def _scatter_cache_rows(self, big, row, slot_ids: List[int]):
        idx = jnp.asarray(slot_ids)
        return jax.tree_util.tree_map_with_path(
            lambda path, b, r: self._scat_rows(
                b, r, idx, self._cache_batch_axis(path)), big, row)

    def _slice_cache_row(self, cache, i: int):
        """A 1-row view of a batched prefill cache (row ``i``), matching
        the shapes ``_scatter_cache_rows`` / ``_write_pages`` expect."""
        return jax.tree_util.tree_map_with_path(
            lambda path, leaf: leaf[:, i:i + 1]
            if self._cache_batch_axis(path) == 1 else leaf[i:i + 1], cache)

    # -- fixed-stride state arena (recurrent / hybrid slots) -----------
    def _arena_put(self, info) -> None:
        """Move a freshly prefilled prompt row into the state arena: one
        refcounted row hold (released at ``_finish_request``), so
        prefilled-but-unadmitted recurrent state is bounded and
        accounted instead of pinning anonymous per-request device
        buffers the way the dense kv path does."""
        if self.arena is None or info.get("cache_row") is None:
            return
        r = self.arena.alloc(1, self.arena.best_shard())[0]
        self._arena_buf = self._scatter_cache_rows(
            self._arena_buf, info["cache_row"], [r])
        info["cache_row"] = None
        info["arena_row"] = r

    def _request_row(self, info):
        """The request's 1-row prompt cache: an arena view for
        recurrent/hybrid engines, the per-request dense row otherwise."""
        r = info.get("arena_row")
        if r is not None:
            return self._slice_cache_row(self._arena_buf, r)
        return info["cache_row"]

    # -- paged cache plumbing ------------------------------------------
    def _page_shard_of(self, info, fallback: Optional[int] = None) -> int:
        """The shard a request's prompt pages live on (chosen once):
        prefix-cache holds pin it to the cached pages' shard; otherwise
        the caller's ``fallback`` (the first admitted slot's shard) or,
        at early-seed time, the least-loaded shard. Disaggregated
        engines (``prefill_shards`` set) ignore the fallback and place
        every prompt page on the least-loaded *prefill* shard — decode
        shards read those pages cross-shard, tail/frontier pages stay
        slot-local."""
        if "page_shard" not in info:
            held = info.get("prompt_pages")
            if held:
                info["page_shard"] = self.pool.shard_of(held[0])
            elif fallback is not None and not self.prefill_shards:
                info["page_shard"] = fallback
            else:
                info["page_shard"] = self._prefill_shard_pick()
        return info["page_shard"]

    def _prefill_shard_pick(self) -> int:
        """Least-loaded shard eligible to host prompt/chunk pages: the
        first ``prefill_shards`` shards when disaggregated, any shard
        otherwise."""
        k = self.prefill_shards or self.dp
        return int(np.argmax([self._shard_headroom(s) for s in range(k)]))

    def _seed_prompt_pages(self, info, shard: Optional[int] = None):
        """Allocate + write the request's full prompt pages (once per
        request — one pool hold each, released when the request
        finishes) and register them in the prefix cache. Prefix-cache
        hits arrive here already holding the cached prefix pages; only
        the remainder is written, from the suffix row (row positions =
        prompt positions - prefix_len). Under mesh sharding the pages
        come from ONE shard's subpool (``_page_shard_of``) — candidates
        on other shards reference them cross-shard, which GSPMD handles;
        tail/frontier pages stay shard-local."""
        if info.get("prompt_seeded"):
            return
        ps = self.page_size
        full = info["prompt_len"] // ps
        held = info.setdefault("prompt_pages", [])
        assert len(held) * ps == info.get("prefix_len", 0), \
            (len(held), info.get("prefix_len", 0))
        new_full = self.pool.alloc(full - len(held),
                                   self._page_shard_of(info, shard))
        if new_full:
            self.state = self.state._replace(cache=self._write_pages(
                self.state.cache, info["cache_row"], new_full, 0))
        info["prompt_pages"] = held + new_full
        if self.prefix_cache and info.get("cacheable"):
            self.pool.prefix.insert(info["page_keys"], info["prompt_pages"])
        info["prompt_seeded"] = True

    def _maybe_seed_early(self, req: Request):
        """Prefix-cache mode: seed + register prompt pages at *prefill*
        time (not first admission) so same-prefix requests later in the
        same batch already hit. Skipped when the pool lacks headroom —
        seeding then happens at admission, under admission control."""
        info = self._reqs[req.uid]
        if not info.get("cacheable") or info.get("prompt_seeded"):
            return
        L = info["prompt_len"]
        need = L // self.page_size - len(info.get("prompt_pages", ()))
        shard = self._page_shard_of(info)
        headroom = self._shard_headroom(shard)
        # keep at least one worst-case candidate fundable after seeding
        if headroom - need < self._pages_per_candidate(L):
            return
        self._seed_prompt_pages(info, shard)
        # early seeding must not eat into pages backing live reservations
        self._ensure_reserved_free()

    def _seed_paged_slots(self, info, slot_ids: List[int], lim: int):
        """Point ``slot_ids`` at the request's prompt pages.

        Full prompt pages are written to the pool once per request and
        *shared* (refcounted) across its candidates; the partially-filled
        tail page — the first page any candidate will write into, i.e.
        the CoW divergence point — is copied per candidate. Dense
        (non-paged: windowed attn / SSM / RG-LRU) entries scatter as in
        the contiguous path.

        With the cross-request prefix cache, ``info["prompt_pages"]`` may
        already hold the cached page-aligned prefix (request hold taken
        at prefill time); only the remaining full pages are allocated and
        written here, from the *suffix* prefill row (row positions are
        prompt positions minus ``info["prefix_len"]``). Newly written
        full pages are registered in the cache for future requests."""
        row = info["cache_row"]
        L = info["prompt_len"]                   # prompt incl. evidence
        ps = self.page_size
        assert L + lim <= self.cache_len, \
            f"prompt {L} + limit {lim} overflows paged cache " \
            f"of {self.cache_len} (paged KV does not ring-wrap)"
        full, tail_len = divmod(L, ps)
        row_off = info.get("prefix_len", 0)      # cache row starts here
        self._seed_prompt_pages(info, self._slot_shard(slot_ids[0]))
        cache = self.state.cache
        bt_rows = np.zeros((len(slot_ids), self.pages_per_slot), np.int32)
        tails = []
        for j, s in enumerate(slot_ids):
            sh = self._slot_shard(s)
            pages = list(info["prompt_pages"])
            self.pool.share(pages)
            if tail_len:
                # CoW tail + all future decode pages come from the
                # slot's own shard — the shard-locality invariant the
                # page-axis sharding leans on
                tail = self.pool.alloc(1, sh)
                tails += tail
                pages += tail
            self._slot_pages[s] = pages
            self._slot_pos[s] = L
            self._slot_limit[s] = L + lim
            future = self._pages_per_candidate(L, lim) - (1 if tail_len else 0)
            self._slot_reserved[s] = future
            self._reserved_sh[sh] += future
            bt_rows[j, :len(pages)] = pages
        if tails:
            # every candidate's tail page holds the same prompt bytes:
            # one broadcast scatter, not one full-pool copy per candidate
            cache = self._write_pages(cache, row, tails, full * ps - row_off,
                                      broadcast=True)
        if self.prefix_cache:
            # admission counted cache-evictable pages as headroom; convert
            # that headroom into ACTUALLY free pages now, before a later
            # prefix hit can re-pin them — reservations must always be
            # backed by the free list or frontier staging could fail
            # mid-decode
            self._ensure_reserved_free()
        idx = jnp.asarray(slot_ids)
        cache = {**cache,
                 "block_table": cache["block_table"].at[idx].set(
                     jnp.asarray(bt_rows)),
                 "pos": cache["pos"].at[idx].set(jnp.int32(L))}
        return self._scatter_dense_entries(cache, row, slot_ids)

    def _pages_per_candidate(self, prompt_len: int,
                             lim: Optional[int] = None) -> int:
        """Pages a candidate may allocate beyond the shared prompt pages:
        its private tail copy plus every boundary crossed while decoding
        up to ``lim`` (default ``max_new``) tokens."""
        ps = self.page_size
        lim = self.max_new if lim is None else lim
        total = -((prompt_len + lim) // -ps)                 # ceil
        return total - prompt_len // ps

    def _ensure_reserved_free(self):
        """Back every live reservation with ACTUALLY free pages of its
        own shard (evicting cached-only prefix pages if needed)."""
        if self.dp == 1:
            self.pool.ensure_free(self._reserved)
        else:
            for s in range(self.dp):
                self.pool.ensure_free(int(self._reserved_sh[s]), s)

    def _paged_affordable(self, info, want: int,
                          lim: Optional[int] = None) -> int:
        """How many candidates of this request fit in the pool right now
        (free + cache-evictable pages minus reservations held by running
        candidates and the request's unseeded prompt-page hold).

        Mesh-sharded pools make this shard-local: admission fills free
        slots in ascending order, so walk exactly those slots and fund
        each candidate's worst-case pages (CoW tail + decode frontier)
        from its slot's OWN shard; the shared prompt-page hold charges
        the request's page shard (the first admitted slot's, unless a
        prefix-cache hold already pinned one)."""
        L = info["prompt_len"]
        per_cand = self._pages_per_candidate(L, lim)
        need_hold = 0 if info.get("prompt_seeded") else \
            L // self.page_size - len(info.get("prompt_pages", ()))
        if self.dp == 1:
            avail = self.pool.free_pages + self.pool.evictable() \
                - self._reserved - need_hold
            return max(0, min(want, avail // max(per_cand, 1)))
        free = self._free_slots()[:want]
        if not free:
            return 0
        avail = [self._shard_headroom(s) for s in range(self.dp)]
        held = info.get("prompt_pages")
        if "page_shard" in info:
            hold_shard = info["page_shard"]
        elif held:
            hold_shard = self.pool.shard_of(held[0])
        elif self.prefill_shards:
            hold_shard = self._prefill_shard_pick()
        else:
            hold_shard = self._slot_shard(free[0])
        avail[hold_shard] -= need_hold
        if avail[hold_shard] < 0:
            # the shard pinned to hold the shared prompt pages cannot
            # fund them — admitting would crash _seed_prompt_pages
            # mid-admission instead of surfacing as queueing delay
            return 0
        take = 0
        for slot in free:
            sh = self._slot_shard(slot)
            if avail[sh] < per_cand:
                break
            avail[sh] -= per_cand
            take += 1
        return take

    def _write_pages(self, cache, row, pages: List[int], start: int,
                     broadcast: bool = False):
        """Copy prefill KV of the 1-row dense prefill cache into the given
        pool pages, every attention layer at once (stacked super entries +
        tail). Consecutive spans per page by default; ``broadcast=True``
        writes the single page-sized span at ``start`` into ALL pages
        (identical CoW tail copies for a round's candidates)."""
        if not pages:
            return cache
        n, ps = len(pages), self.page_size
        span = ps if broadcast else n * ps
        pg = jnp.asarray(pages)

        def seed(pool, spool, rk):
            """Scatter the row's span into value pages; quantized pools
            (``spool`` is the scale pool) quantize the span once and
            scatter values + scales — broadcasting after quantization
            keeps CoW copies bit-identical for free."""
            stacked = pool.ndim == 5  # (n_super, P, ps, Hkv, hd)
            if stacked:
                seg = jax.lax.dynamic_slice_in_dim(rk[:, 0], start, span,
                                                   axis=1)
                seg = seg.reshape(pool.shape[0], -1, *pool.shape[2:])
            else:
                seg = jax.lax.dynamic_slice_in_dim(rk[0], start, span,
                                                   axis=0)
                seg = seg.reshape(-1, *pool.shape[1:])
            sseg = None
            if spool is not None:
                seg, sseg = attn_lib.kv_quantize(seg, pool.dtype)
            if broadcast:
                seg = jnp.broadcast_to(
                    seg, (pool.shape[0], n) + pool.shape[2:] if stacked
                    else (n,) + pool.shape[1:])
                if sseg is not None:
                    sseg = jnp.broadcast_to(
                        sseg, (spool.shape[0], n) + spool.shape[2:]
                        if stacked else (n,) + spool.shape[1:])
            if stacked:
                pool = pool.at[:, pg].set(seg.astype(pool.dtype))
                if sseg is not None:
                    spool = spool.at[:, pg].set(sseg)
            else:
                pool = pool.at[pg].set(seg.astype(pool.dtype))
                if sseg is not None:
                    spool = spool.at[pg].set(sseg)
            return pool, spool

        def seed_entries(entries, row_entries):
            out = []
            for ce, re_ in zip(entries, row_entries):
                if isinstance(ce, dict) and "k_pages" in ce:
                    kp, ks = seed(ce["k_pages"], ce.get("k_scale"),
                                  re_["k"])
                    vp, vs = seed(ce["v_pages"], ce.get("v_scale"),
                                  re_["v"])
                    ce = {"k_pages": kp, "v_pages": vp}
                    if ks is not None:
                        ce = {**ce, "k_scale": ks, "v_scale": vs}
                out.append(ce)
            return tuple(out)

        return {**cache,
                "super": seed_entries(cache["super"], row["super"]),
                "tail": seed_entries(cache["tail"], row["tail"])}

    def _scatter_dense_entries(self, cache, row, slot_ids: List[int]):
        """Scatter the non-paged cache entries (windowed attn rings, SSM
        and RG-LRU states) of the prefill row into the given slots.
        Axes follow ``_cache_batch_axis``: "super" leaves are
        layer-stacked (batch at 1), tail leaves are per-slot (batch 0)."""
        idx = jnp.asarray(slot_ids)

        def scatter_entries(entries, row_entries, ax):
            out = []
            for ce, re_ in zip(entries, row_entries):
                if not (isinstance(ce, dict) and "k_pages" in ce):
                    ce = jax.tree.map(
                        lambda b, r: self._scat_rows(b, r, idx, ax), ce, re_)
                out.append(ce)
            return tuple(out)

        return {**cache,
                "super": scatter_entries(cache["super"], row["super"], 1),
                "tail": scatter_entries(cache["tail"], row["tail"], 0)}

    # -- page frontiers (macro-step paged decode) ----------------------
    @staticmethod
    def _page_crossings(lo: int, hi: int, ps: int) -> int:
        """Number of page boundaries (multiples of ``ps``) a slot's write
        position crosses over the half-open span [lo, hi)."""
        return -(-hi // ps) - (-(-lo // ps))

    def _stage_frontier(self) -> Tuple[Dict[int, Tuple[int, List[int]]],
                                       jax.Array]:
        """Reserve each live slot's next pages for one macro-step launch.

        Staged pages come out of the slot's admission-time reservation, so
        staging can never fail nor starve queued work: free-minus-reserved
        is invariant. Returns ({slot: (start_pos, pages)}, (B, F) frontier
        array; idle rows point at the quarantine page 0)."""
        F = self._frontier_width
        fr = np.zeros((self.B, F), np.int32)
        staged: Dict[int, Tuple[int, List[int]]] = {}
        ps = self.page_size
        for s in range(self.B):
            if self._slot_req[s] < 0:
                continue
            p = int(self._slot_pos[s])
            # worst-case advance: K iterations × the slot's (coverage-
            # aware) speculative block length
            adv = max(self.macro_steps, 1) * \
                (int(self._slot_spec[s]) if self.spec else 1)
            hi = min(p + adv, int(self._slot_limit[s]))
            need = self._page_crossings(p, hi, ps)
            if need > 0:
                assert need <= self._slot_reserved[s], \
                    (s, need, self._slot_reserved[s])
                pages = self.pool.stage_frontier(need, self._slot_shard(s))
                self._slot_reserved[s] -= need
                self._reserved_sh[self._slot_shard(s)] -= need
                fr[s, :need] = pages
            else:
                pages = []
            staged[s] = (p, pages)
        return staged, jnp.asarray(fr)

    def _reclaim_frontier(self, staged, pos_np):
        """After a macro-step: keep the consumed frontier prefix as slot
        pages (the device advanced the block table through them, in
        order), return the rest to the pool and to the slot's
        reservation."""
        for s, (p0, pages) in staged.items():
            p1 = int(pos_np[s])
            used = self._page_crossings(p0, p1, self.page_size)
            assert used <= len(pages), (s, p0, p1, used, len(pages))
            self._slot_pages[s] += pages[:used]
            unused = pages[used:]
            if unused:
                self.pool.return_frontier(unused)
                self._slot_reserved[s] += len(unused)
                self._reserved_sh[self._slot_shard(s)] += len(unused)
            self._slot_pos[s] = p1

    def _alloc_step_pages(self):
        """Legacy per-token loop only: before each decode step, hand a
        fresh page to every live slot whose next write crosses a page
        boundary, and mirror the allocation into the device block
        table."""
        rows, cols, vals = [], [], []
        for s in range(self.B):
            if self._slot_req[s] < 0:
                continue
            p = int(self._slot_pos[s])
            if p % self.page_size == 0:
                li = p // self.page_size
                if li >= self.pages_per_slot:
                    raise RuntimeError(
                        f"slot {s} ran past the paged cache "
                        f"({p} >= {self.cache_len})")
                page = self.pool.alloc(1, self._slot_shard(s))[0]
                self._slot_pages[s].append(page)
                if self._slot_reserved[s] > 0:
                    self._slot_reserved[s] -= 1
                    self._reserved_sh[self._slot_shard(s)] -= 1
                rows.append(s)
                cols.append(li)
                vals.append(page)
            self._slot_pos[s] += 1
        if rows:
            cache = self.state.cache
            bt = cache["block_table"].at[
                jnp.asarray(rows), jnp.asarray(cols)].set(
                    jnp.asarray(vals, jnp.int32))
            self.state = self.state._replace(
                cache={**cache, "block_table": bt})

    def _bytes_per_page(self) -> int:
        """True resident bytes per pool page across every attention
        layer: quantized values + their scale tensors (CoW-shared pages
        share both). Feeds both telemetry and the pool's byte budget."""

        def per_leaf(leaf):
            # every paged leaf — values and quantization scales alike —
            # carries a num_pages axis (position depends on stacking)
            return leaf.size // self.pool.num_pages * leaf.dtype.itemsize

        bpp = 0
        for entries in (self.state.cache["super"], self.state.cache["tail"]):
            for e in entries:
                if isinstance(e, dict) and "k_pages" in e:
                    bpp += sum(per_leaf(leaf) for leaf in e.values())
        return bpp

    def kv_stats(self) -> Dict[str, Any]:
        """Pool accounting incl. resident KV bytes vs. the dense
        worst case (slots × cache_len) the paged layout replaces."""
        assert self.paged
        stats = self.pool.stats()
        bpp = self._bytes_per_page()
        stats["kv_dtype"] = self.kv_dtype
        stats["bytes_per_page"] = bpp
        stats["resident_kv_bytes"] = stats["in_use"] * bpp
        stats["peak_kv_bytes"] = stats["max_in_use"] * bpp
        stats["dense_equiv_bytes"] = self.B * self.pages_per_slot * bpp
        if self.pool.prefix is not None:
            pc = self.pool.prefix
            stats["prefix_cache"] = {
                "probes": pc.probes,
                "hits": pc.hits,                    # pages reused
                "misses": pc.misses,                # probes short of full hit
                "hit_tokens": pc.hit_tokens,        # prefill tokens skipped
                "bytes_saved": pc.hits * bpp,       # KV bytes not re-written
                "cached_pages": pc.cached_pages,
                "insertions": pc.insertions,
                "evictions": pc.evictions,
            }
        return stats

    def sched_stats(self) -> Dict[str, Any]:
        """Traffic-policy telemetry: budget accounting, admissions,
        declined rounds, starvation, cancellations."""
        s = dict(self.scheduler.stats())
        s["starved"] = len(self.starved_uids)
        s["prefill_calls"] = self.prefill_calls
        s["prefill_tokens"] = self.prefill_tokens
        s["chunk_calls"] = self.chunk_calls
        s["chunk_tokens"] = self.chunk_tokens
        s["cancelled_requests"] = self.cancelled_requests
        s["image_encodes"] = self.image_encodes
        s["image_feat_hits"] = self.image_feat_hits
        return s

    def arena_stats(self) -> Dict[str, Any]:
        """Fixed-stride state-arena telemetry (recurrent/hybrid
        engines); ``{}`` on kv engines, mirroring ``kv_stats`` for the
        paged pool."""
        if self.arena is None:
            return {}
        s: Dict[str, Any] = dict(self.arena.stats())
        s["state_kind"] = self.state_kind
        bpr = sum(leaf.size // self.arena.num_rows * leaf.dtype.itemsize
                  for leaf in jax.tree.leaves(self._arena_buf))
        s["bytes_per_row"] = int(bpr)
        s["resident_state_bytes"] = int(bpr) * self.arena.num_rows
        return s

    def reset_stats(self) -> None:
        """Zero telemetry for engine reuse across bench cells/scenarios
        — without this, ``sched_stats``/``kv_stats`` counters (prefix
        hits, host syncs, spec telemetry, frontier peaks) accumulate
        across runs and pollute later cells. Serving state — request
        table, budget ledgers (``spent``/``committed``), prefix-cache
        contents, the decode-key position ``_t`` — is untouched: this
        resets what the engine *reports*, never what it *decides*."""
        self.total_steps = 0
        self.total_tokens = 0
        self.macro_launches = 0
        self.host_syncs = 0
        self.spec_drafted = 0
        self.spec_accepted = 0
        self.prefill_calls = 0
        self.prefill_tokens = 0
        self.chunk_calls = 0
        self.chunk_tokens = 0
        self.cancelled_requests = 0
        self.image_encodes = 0
        self.image_feat_hits = 0
        self.starved_uids.clear()
        self.scheduler.reset_stats()
        if self.paged:
            self.pool.reset_stats()
        if self.arena is not None:
            self.arena.reset_stats()

    # -- async front-end hooks -----------------------------------------
    def has_work(self) -> bool:
        """Anything live, queued, or pending a round."""
        return self._any_live() or self._has_pending()

    def drain_stream_events(self) -> List[Tuple[int, int, np.ndarray]]:
        """Token deltas ``(uid, cand_uid, tokens)`` emitted since the
        last drain (requires ``stream_tokens = True``)."""
        ev, self.stream_events = self.stream_events, []
        return ev

    def pop_finished(self) -> List[int]:
        """Uids finalized since the last call (completion + cancel)."""
        done, self._newly_done = self._newly_done, []
        return done

    def result(self, uid: int) -> Result:
        """Public per-request result accessor (the async front-end's
        completion path; ``run`` returns the same objects in bulk)."""
        return self._result(uid)

    def _admit(self, req: Request, slot_ids: List[int],
               limit: Optional[int] = None):
        """Seed slots with the request's prompt cache and sample the first
        token of each candidate from the prefill logits — one batched
        ``sample_token_batch`` dispatch over the round's split keys, not a
        Python loop of single-row samples. ``limit`` is the scheduler's
        per-candidate token grant (``None`` = the engine-wide max)."""
        lim = self.max_new if limit is None else min(int(limit), self.max_new)
        assert lim >= 1
        info = self._reqs[req.uid]
        st = self.state
        if self.spec and not self.paged:
            # speculative block writes must not ring-wrap (a block write
            # past cache_len would alias a live earlier position)
            assert info["prompt_len"] + lim <= self.cache_len, \
                f"prompt {info['prompt_len']} + limit {lim} overflows " \
                f"cache {self.cache_len} (speculation does not ring-wrap)"
        if self.paged:
            cache = self._seed_paged_slots(info, slot_ids, lim)
        else:
            cache = self._scatter_cache_rows(st.cache,
                                             self._request_row(info),
                                             slot_ids)
        idx = jnp.asarray(slot_ids)
        n = len(slot_ids)

        self.key, *keys = jax.random.split(self.key, n + 1)
        lg = info["prefill_logits"]                      # (1, V) fp32
        bias = info.get("bias")
        toks, lps = self._first_fn(jnp.stack(keys), lg, bias,
                                   self._greedy_row)
        h0 = info["prefill_hidden"]                      # (1, d) fp32
        hn0 = h0 / (jnp.linalg.norm(h0, axis=-1, keepdims=True) + 1e-8)
        V, d = self.V, self.d

        if self.has_evidence:
            emb_t = jnp.take(self.params["embed"]["table"], toks,
                             axis=0).astype(jnp.float32)
            emb_n = emb_t / (jnp.linalg.norm(emb_t, axis=-1, keepdims=True) + 1e-8)
            ev = info["evid_row"]                        # (1, Ne, d) normalized
            a0 = jnp.mean(jnp.einsum("nd,bd->bn", ev[0], emb_n), axis=-1)
        else:
            a0 = jnp.zeros((n,), jnp.float32)

        if self.spec:
            # n-gram table: prompt tokens at their cache positions
            # (evidence rows stay -1 and never match); the first sampled
            # token is *pending* (it is fed by the first verify block)
            H = self.cache_len
            ne = info["prompt_len"] - len(req.prompt)
            hrow = np.full(H, -1, np.int32)
            hrow[ne:info["prompt_len"]] = np.asarray(req.prompt, np.int32)
            hist_rows = jnp.asarray(np.tile(hrow, (n, 1)))
            k_eff = self._coverage_k(info.get("p_star"))
        else:
            hist_rows = None
            k_eff = 1

        new = self.state._replace(
            cache=cache,
            last_token=st.last_token.at[idx].set(toks),
            token_counts=st.token_counts.at[idx].set(
                jax.nn.one_hot(toks, V, dtype=jnp.float32)),
            sum_lp=st.sum_lp.at[idx].set(lps),
            n_tok=st.n_tok.at[idx].set(1),
            prev_h=st.prev_h.at[idx].set(jnp.repeat(hn0, n, axis=0)),
            sum_coh=st.sum_coh.at[idx].set(0.0),
            sum_emb=st.sum_emb.at[idx].set(jnp.zeros((n, d))),
            align_sum=st.align_sum.at[idx].set(a0),
            active=st.active.at[idx].set(True),
            out_buf=st.out_buf.at[idx].set(
                jnp.zeros((n, self.max_new), jnp.int32).at[:, 0].set(toks)),
            bias=st.bias.at[idx].set(
                jnp.repeat(bias if bias is not None else jnp.zeros((1, V)), n, axis=0)),
            greedy=st.greedy.at[idx].set(self.mode == "greedy"),
            limit=st.limit.at[idx].set(lim),
            hist=st.hist.at[idx].set(hist_rows) if self.spec else st.hist,
            spec_k=st.spec_k.at[idx].set(k_eff) if self.spec else st.spec_k,
        )
        self.state = new
        for s in slot_ids:
            self._slot_req[s] = req.uid
            self._slot_cand[s] = self._next_cand
            self._slot_lim[s] = lim
            self._slot_spec[s] = k_eff
            self._slot_streamed[s] = 0
            info["cand_slots"].append((self._next_cand, s))
            self._next_cand += 1
        if self.dp > 1:
            self.scheduler.note_shard_admission(
                self._slot_shard(s) for s in slot_ids)

    # -- prefill -------------------------------------------------------
    def _prompt_span(self, req: Request) -> int:
        """Cache positions the prompt occupies, incl. prepended evidence
        (decoder-only; enc-dec evidence feeds the encoder instead)."""
        ne = self.cfg.num_evidence_tokens \
            if (req.evidence is not None and
                not self.cfg.is_encoder_decoder) else 0
        return len(req.prompt) + ne

    def _init_info(self, req: Request, cache_row, lg, h, prompt_len: int):
        info = {
            "req": req,
            "cache_row": cache_row,
            "prefill_logits": lg.astype(jnp.float32),
            "prefill_hidden": h.astype(jnp.float32),
            "prompt_len": prompt_len,
            "camd": ctrl.init_state(self.camd, self.d, self.V),
            "bias": None,
            "round": 0,
            "cand_slots": [],
            "records": {},
            "align_const": 0.0,
            "done": False,
        }
        if self.has_evidence and req.evidence is not None:
            evp = jnp.asarray(req.evidence, jnp.float32)
            if "evidence_proj" in self.params:
                from repro.models.layers import dense
                evp = dense(jax.tree.map(lambda x: x.astype(jnp.float32),
                                         self.params["evidence_proj"]), evp)
            evn = evp / (jnp.linalg.norm(evp, axis=-1, keepdims=True) + 1e-8)
            info["evid_row"] = evn[None]
            # Eq. 8 term 2: text-evidence ↔ visual-evidence consistency —
            # prompt token embeddings vs evidence features, constant per req.
            temb = jnp.take(self.params["embed"]["table"],
                            jnp.asarray(req.prompt, jnp.int32),
                            axis=0).astype(jnp.float32)
            temb = temb / (jnp.linalg.norm(temb, axis=-1, keepdims=True) + 1e-8)
            if self.xmodal_rescore:
                # prompt-token rows for the fused kernel's term-2 max
                # reduction (already normalized; kernel renorm is a no-op)
                info["text_row"] = temb[None]                # (1, L, d)
            sim = temb @ evn.T                               # (L, Ne)
            info["align_const"] = float(jnp.mean(jnp.max(sim, axis=-1)))
            # difficulty prior for the traffic scheduler: normalized
            # entropy of each prompt token's evidence attachment. A
            # peaked attachment (every token clearly grounded in one
            # evidence item) reads easy; a diffuse one marks grounding
            # ambiguity — the kind of instance CAMD's heavy tail is made
            # of. Costs one host float beside align_const, at prefill.
            ne_ev = int(evn.shape[0])
            if ne_ev > 1:
                p_att = jax.nn.softmax(sim, axis=-1)
                ent = -jnp.sum(p_att * jnp.log(p_att + 1e-9), axis=-1)
                info["evidence_entropy"] = \
                    float(jnp.mean(ent)) / float(np.log(ne_ev))
            else:
                info["evidence_entropy"] = 0.0
        else:
            info["evid_row"] = jnp.zeros((1, 1, self.d), jnp.float32)
        self._reqs[req.uid] = info
        self._arena_put(info)

    def _prefill_request(self, req: Request):
        """Unbucketed fallback: one prefill call per request (recompiles
        per distinct prompt length)."""
        prompt = jnp.asarray(req.prompt, jnp.int32)[None, :]
        cache_row = self.model.make_cache(1, self.cache_len, self._dtype)
        ev = None
        if req.evidence is not None:
            ev = jnp.asarray(req.evidence, self._dtype)[None]
        lg, h, cache_row = self._prefill_fn(self.params, prompt, cache_row, ev)
        self.prefill_calls += 1
        self.prefill_tokens += self._prompt_span(req)
        self._init_info(req, cache_row, lg, h, self._prompt_span(req))

    # -- cross-request prefix cache ------------------------------------
    def _mark_cacheable(self, req: Request):
        """Record the request's page-key chain so its prompt pages get
        registered in the prefix cache at seed time."""
        if not self.prefix_cache:
            return
        stream = self._prefix_token_stream(req)
        if stream is None:
            return
        info = self._reqs[req.uid]
        info["page_keys"] = prefix_page_keys(stream, self.page_size)
        info["cacheable"] = True

    def _try_prefill_suffix(self, req: Request) -> bool:
        """Prefix-cache fast path: if a page-aligned prefix of the key
        stream (image pseudo-tokens + prompt, or the prompt alone) is
        cached, take a request hold on those pages and prefill only the
        *suffix*, attending to the cached pages' KV as context — the
        shared pages' prefill is skipped entirely. The hit is capped at
        ``(L-1)//page_size`` pages so at least one prompt token remains
        to produce last-token logits. An image request's hit must cover
        the whole image span (positions below ``ne`` hold embeddings,
        not tokens — no suffix forward can resume inside it)."""
        if not self.prefix_cache:
            return False
        stream = self._prefix_token_stream(req)
        if stream is None:
            return False
        usable = (len(stream) - 1) // self.page_size
        if usable <= 0:
            return False
        keys = prefix_page_keys(stream, self.page_size)
        pages = self.pool.prefix.match_and_hold(keys[:usable])
        if not pages:
            return False
        start = len(pages) * self.page_size
        ne = len(stream) - len(req.prompt)
        if start < ne:
            self.pool.free(pages)        # partial image hit: re-prefill
            return False
        suffix = jnp.asarray(stream[start:], jnp.int32)[None, :]
        ctx = self._gather_prefix_ctx(pages)
        cache_row = self.model.make_cache(1, self.cache_len, self._dtype)
        lg, h, cache_row = self._suffix_fn(
            self.params, suffix, cache_row, ctx, jnp.int32(start))
        self.prefill_calls += 1
        self.prefill_tokens += len(stream) - start          # suffix only
        self._init_info(req, cache_row, lg, h, len(stream))
        info = self._reqs[req.uid]
        info["prompt_pages"] = pages         # request hold already taken
        info["prefix_len"] = start
        info["page_keys"] = keys
        info["cacheable"] = True
        return True

    def _gather_prefix_ctx(self, pages: List[int]):
        """Assemble per-layer context K/V from cached pool pages:
        (n_super, 1, h*ps, Hkv, hd) per stacked super entry (batch axis
        inserted), (1, h*ps, Hkv, hd) per tail entry."""
        idx = jnp.asarray(pages, jnp.int32)

        def gather(entries):
            out = []
            for e in entries:
                assert isinstance(e, dict) and "k_pages" in e, \
                    "prefix cache requires all-attention paged layers"
                kp, vp = e["k_pages"], e["v_pages"]
                ks, vs = e.get("k_scale"), e.get("v_scale")
                if kp.ndim == 5:            # stacked: (n_super, P, ps, ..)
                    k = kp[:, idx].reshape(kp.shape[0], 1, -1, *kp.shape[3:])
                    v = vp[:, idx].reshape(vp.shape[0], 1, -1, *vp.shape[3:])
                    if ks is not None:      # dequantize int8/fp8 pages
                        k = attn_lib.kv_dequantize(
                            k, ks[:, idx].reshape(ks.shape[0], 1, -1,
                                                  *ks.shape[3:]))
                        v = attn_lib.kv_dequantize(
                            v, vs[:, idx].reshape(vs.shape[0], 1, -1,
                                                  *vs.shape[3:]))
                else:
                    k = kp[idx].reshape(1, -1, *kp.shape[2:])
                    v = vp[idx].reshape(1, -1, *vp.shape[2:])
                    if ks is not None:
                        k = attn_lib.kv_dequantize(
                            k, ks[idx].reshape(1, -1, *ks.shape[2:]))
                        v = attn_lib.kv_dequantize(
                            v, vs[idx].reshape(1, -1, *vs.shape[2:]))
                out.append((k, v))
            return tuple(out)

        cache = self.state.cache
        return {"super": gather(cache["super"]),
                "tail": gather(cache["tail"])}

    # -- chunked prefill -----------------------------------------------
    def _start_chunk_job(self, req: Request) -> None:
        """Open a chunked-prefill job for a long prompt: probe the
        prefix cache for a page-aligned head (the hit pages are the
        job's first chunks, already resident), pick the page shard the
        whole prompt will live on, and register the cursor. If the
        cached head leaves at most one chunk of work, the one-shot
        suffix/whole paths are strictly better — no job is opened.
        Image requests chunk over their key stream (image pseudo-tokens
        + prompt): the first chunk carries the whole image span, and a
        cached head that ends inside the image span is unusable (those
        positions hold embeddings, not resumable tokens)."""
        stream = self._prefix_token_stream(req)
        assert stream is not None
        ne = len(stream) - len(req.prompt)
        pages: List[int] = []
        cur = 0
        if self.prefix_cache:
            usable = (len(stream) - 1) // self.page_size
            if usable > 0:
                keys = prefix_page_keys(stream, self.page_size)
                pages = self.pool.prefix.match_and_hold(keys[:usable]) or []
                cur = len(pages) * self.page_size
                if pages and cur < ne:
                    self.pool.free(pages)   # partial image hit
                    pages, cur = [], 0
        if len(stream) - cur <= self.chunk:
            if pages:
                self.pool.free(pages)    # release the probe hold
            return
        shard = self.pool.shard_of(pages[0]) if pages \
            else self._prefill_shard_pick()
        self._chunking[req.uid] = {"req": req, "pos": cur, "pages": pages,
                                   "shard": shard}

    def _run_chunk(self, uid: int, job: Dict[str, Any]) -> int:
        """Advance one job by one chunk; returns chunk tokens consumed
        (0 when the job's shard cannot fund the chunk's pages yet).

        Non-final chunks run the suffix forward against the job's pages
        as context and write their K/V into freshly allocated pool pages
        (page-aligned by construction). The FINAL chunk instead keeps
        its dense prefill row and promotes the job to a normal request
        record — ``info`` is indistinguishable from a prefix-cache
        suffix prefill (prompt_pages = chunk pages, prefix_len =
        cursor), so admission, seeding and teardown are unchanged."""
        req = job["req"]
        stream = self._prefix_token_stream(req)
        ne = len(stream) - len(req.prompt)
        L, cur, ps = len(stream), job["pos"], self.page_size
        final = L - cur <= self.chunk
        take = L - cur if final else self.chunk
        if not final:
            # keep one worst-case candidate fundable after this chunk —
            # chunk pages must never starve admission into deadlock
            need = take // ps
            if self._shard_headroom(job["shard"]) - need < \
                    self._pages_per_candidate(L):
                return 0
        cache_row = self.model.make_cache(1, self.cache_len, self._dtype)
        if cur == 0:
            # the first chunk carries the whole image span (pseudo-token
            # positions [0, ne) are evidence embeddings, not tokens):
            # feed the evidence through the normal prefill frontend and
            # only the chunk's real-token remainder as tokens
            ev = None
            if ne:
                assert take > ne, \
                    f"prefill_chunk {self.chunk} must exceed the image " \
                    f"span ({ne} evidence tokens)"
                ev = jnp.asarray(req.evidence, self._dtype)[None]
            toks = jnp.asarray(np.asarray(req.prompt)[:take - ne],
                               jnp.int32)[None, :]
            lg, h, cache_row = self._prefill_fn(self.params, toks,
                                                cache_row, ev)
        else:
            toks = jnp.asarray(stream[cur:cur + take], jnp.int32)[None, :]
            ctx = self._gather_prefix_ctx(job["pages"])
            lg, h, cache_row = self._suffix_fn(self.params, toks, cache_row,
                                               ctx, jnp.int32(cur))
        self.chunk_calls += 1
        self.chunk_tokens += take
        if not final:
            new_pages = self.pool.alloc(need, job["shard"])
            # the chunk row holds K/V for [cur, cur+take) at row
            # positions [0, take)
            self.state = self.state._replace(cache=self._write_pages(
                self.state.cache, cache_row, new_pages, 0))
            job["pages"] = job["pages"] + new_pages
            job["pos"] = cur + take
            return take
        del self._chunking[uid]
        self.prefill_calls += 1
        self.prefill_tokens += take
        self._init_info(req, cache_row, lg, h, L)
        info = self._reqs[uid]
        info["prompt_pages"] = job["pages"]     # request hold carried over
        info["prefix_len"] = cur
        info["page_shard"] = job["shard"]
        if self.prefix_cache:
            info["page_keys"] = prefix_page_keys(stream, ps)
            info["cacheable"] = True
            self._maybe_seed_early(req)
        return take

    def _prefill_chunks(self) -> None:
        """One chunked-prefill pass: open jobs for long prompts in the
        admission window, then spend the per-turn chunk-token budget on
        the policy-ranked jobs. When no slot is decoding there is
        nothing to protect — the budget is ignored, but the pass stops
        as soon as a job completes so the request admits immediately
        (cold-start TTFT)."""
        if not self.chunked:
            return
        ahead = max(self.B, 4)
        ne = self.cfg.num_evidence_tokens
        for r in self._queue[:ahead]:
            if r.uid in self._reqs or r.uid in self._chunking:
                continue
            stream = self._prefix_token_stream(r)
            if stream is None or len(stream) <= self.chunk:
                continue
            if len(stream) > len(r.prompt) and self.chunk <= ne:
                continue    # image span doesn't fit one chunk: one-shot
            self._start_chunk_job(r)
        if not self._chunking:
            return
        items = [PrefillWork(uid=uid, arrival=self._arrival[uid],
                             prompt_len=len(job["req"].prompt),
                             prefilled=job["pos"])
                 for uid, job in self._chunking.items()]
        idle = not self._any_live()
        for w in self.scheduler.prefill_order(items):
            while True:
                job = self._chunking.get(w.uid)
                if job is None:
                    if idle:
                        return       # a request just became admissible
                    break
                if not idle and self._chunk_left <= 0:
                    return
                took = self._run_chunk(w.uid, job)
                if took == 0:
                    break            # shard can't fund the chunk yet
                self._chunk_left -= took
                self._chunk_progress = True

    def _bucket_len(self, prompt_len: int) -> int:
        return _next_pow2(max(prompt_len, self.prefill_bucket_min))

    def _prefill_pending(self):
        """Prefill queued requests that have no cache yet, batching
        same-bucket prompts (right-padded to power-of-two lengths) into
        one prefill call each — instead of one recompile-per-length call
        per request. Only a bounded queue prefix is prefilled (admission
        is FIFO, so a prefix is always the next work): each prefilled
        request pins a dense cache row until admission, and an unbounded
        queue must not pin O(queue) rows of KV."""
        self._prefill_chunks()
        ahead = max(self.B, 4)
        pending = [r for r in self._queue[:ahead]
                   if r.uid not in self._reqs and
                   r.uid not in self._chunking]
        if self.arena is not None and len(pending) > self.arena.free_rows:
            # arena-bounded prefill-ahead: defer the overflow to the next
            # pass instead of letting prompt rows outgrow the arena
            self.arena.sizing_stalls += 1
            pending = pending[:self.arena.free_rows]
        if not pending:
            return
        # prefix-cache hits take the suffix path (skipping the shared
        # pages' prefill). Cacheable misses are prefilled one by one with
        # their pages seeded immediately, so same-prefix requests later
        # in the SAME batch hit too (the trade against bucketed batching
        # applies only when the prefix cache is on). Image requests are
        # cacheable through their content-hash pseudo-token stream.
        if self.prefix_cache:
            misses = []
            for r in pending:
                if self._try_prefill_suffix(r):
                    self._maybe_seed_early(r)
                elif self._prefix_token_stream(r) is not None:
                    self._prefill_request(r)
                    self._mark_cacheable(r)
                    self._maybe_seed_early(r)
                else:
                    misses.append(r)
            pending = misses
            if not pending:
                return
        if not self.bucket_prefill:
            for r in pending:
                self._prefill_request(r)
                self._mark_cacheable(r)
            return
        groups: Dict[Tuple[int, int], List[Request]] = {}
        for r in pending:
            ne = self.cfg.num_evidence_tokens if r.evidence is not None else 0
            groups.setdefault((self._bucket_len(len(r.prompt)), ne),
                              []).append(r)
        for (Lb, ne), reqs in sorted(groups.items()):
            if Lb + ne > min(self._min_ring, self.cache_len):
                # padded bucket would wrap an attention ring — the padded
                # tail analysis no longer holds, take the exact 1-row path
                for r in reqs:
                    self._prefill_request(r)
            else:
                self._prefill_bucket(Lb, ne, reqs)
            for r in reqs:
                self._mark_cacheable(r)

    def _prefill_bucket(self, Lb: int, ne: int, reqs: List[Request]):
        n = len(reqs)
        nb = _next_pow2(n)          # row count buckets too: bounded recompiles
        toks = np.zeros((nb, Lb), np.int32)
        lens = np.full((nb,), Lb + ne, np.int32)   # dummy rows: full length
        for i, r in enumerate(reqs):
            toks[i, :len(r.prompt)] = r.prompt
            lens[i] = len(r.prompt) + ne
        ev = None
        if ne:
            De = self.cfg.evidence_dim or self.d
            ev_np = np.zeros((nb, ne, De), np.float32)
            for i, r in enumerate(reqs):
                ev_np[i] = r.evidence
            ev = jnp.asarray(ev_np, self._dtype)
        cache = self.model.make_cache(nb, self.cache_len, self._dtype)
        lg, h, cache = self._bucket_fn(self.params, jnp.asarray(toks),
                                       jnp.asarray(lens), cache, ev)
        self.prefill_calls += 1
        self.prefill_tokens += int(sum(lens[:n]))
        for i, r in enumerate(reqs):
            self._init_info(r, self._slice_cache_row(cache, i),
                            lg[i:i + 1], h[i:i + 1], int(lens[i]))

    def _free_slots(self) -> List[int]:
        return [i for i in range(self.B) if self._slot_req[i] < 0]

    def _per_round(self) -> int:
        if self.mode == "greedy":
            return 1
        if self.mode == "camd":
            return self.camd.samples_per_round
        return min(self.n_candidates, self.B)

    def _schedule(self):
        """Fill free slots — every admission/round decision is delegated
        to the traffic policy (``self.scheduler``) through the
        ``SchedulerContext`` facade.

        Paged backpressure: a request is only admitted when the pool can
        cover its candidates' worst-case pages (``_paged_affordable``);
        otherwise it waits in the queue / stays pending until running
        candidates finish and return pages."""
        self._prefill_pending()
        self.scheduler.schedule(_EngineSchedContext(self))

    def _needed(self, info) -> int:
        if self.mode == "camd":
            return self.camd.samples_per_round
        done_cands = len(info["records"])
        running = sum(1 for _, s in info["cand_slots"]
                      if self._slot_req[s] == info["req"].uid)
        return max(0, self.n_candidates - done_cands - running)

    # ------------------------------------------------------------------
    def _xmodal_fn(self, tokens: np.ndarray, evid_row, text_row):
        """S_align for one finished candidate via the fused Eq. 8-9
        kernel (``kernels.ops`` picks mosaic/interpret/ref per
        platform). Tokens pad to ``max_new`` so the call compiles once
        per prompt length, not per generation length."""
        if self._xmodal_jit is None:
            from repro.kernels import ops as kops

            def fn(params, toks, mask, evid, text):
                emb = jnp.take(params["embed"]["table"], toks,
                               axis=0).astype(jnp.float32)
                emb = emb / (jnp.linalg.norm(emb, axis=-1,
                                             keepdims=True) + 1e-8)
                return kops.xmodal_score(emb[None], mask[None], evid,
                                         text)[0]

            self._xmodal_jit = jax.jit(fn)
        n = len(tokens)
        toks = np.zeros(self.max_new, np.int32)
        toks[:n] = tokens
        mask = (np.arange(self.max_new) < n).astype(np.float32)
        return self._xmodal_jit(self.params, jnp.asarray(toks),
                                jnp.asarray(mask), evid_row, text_row)

    def _finish_candidates(self, slots: List[int]):
        """Fold finished slots into candidate records: ONE batched
        ``device_get`` of the finished rows (the legacy loop issued ~7
        scalar readbacks per slot), then host bookkeeping."""
        st = self.state
        idx = jnp.asarray(slots)
        out_buf, sum_lp, n_tok, sum_coh, sum_emb, align_sum, counts = \
            self._sync((st.out_buf[idx], st.sum_lp[idx], st.n_tok[idx],
                        st.sum_coh[idx], st.sum_emb[idx], st.align_sum[idx],
                        st.token_counts[idx]))
        uids: List[int] = []
        for j, slot in enumerate(slots):
            uid = int(self._slot_req[slot])
            cand = int(self._slot_cand[slot])
            info = self._reqs[uid]
            n = int(n_tok[j])
            rec = {
                "uid": cand,
                "tokens": np.asarray(out_buf[j])[:n],
                "sum_lp": float(sum_lp[j]),
                "n": n,
                "sum_coh": float(sum_coh[j]),
                "emb": np.asarray(sum_emb[j]) / max(n, 1),
                "align": float(align_sum[j]) / max(n, 1),
                "counts": np.asarray(counts[j]),
            }
            # Eq. 12 evidence-weighted score from incremental aggregates
            s_gen = rec["sum_lp"] / max(n, 1)
            s_coh = rec["sum_coh"] / max(n - 1, 1)
            s_align = 0.5 * (rec["align"] + info["align_const"]) \
                if self.has_evidence else 0.0
            if self.xmodal_rescore and "text_row" in info and n > 0:
                # recompute S_align through the fused Eq. 8-9 kernel
                # over the candidate's generated-token embeddings — the
                # block-reduced equivalent of the incremental aggregate
                # (same math, kernel-verified), recorded per candidate
                s_align = float(self._xmodal_fn(
                    rec["tokens"], info["evid_row"], info["text_row"]))
                rec["s_align_xmodal"] = s_align
            rec["score"] = s_gen + self.camd.lambda_g * s_align \
                + self.camd.lambda_c * s_coh
            info["records"][cand] = rec
            self._slot_req[slot] = -1
            self._slot_cand[slot] = -1
            self._slot_spec[slot] = 1
            self.total_tokens += n
            # release the candidate's worst-case token commitment; its
            # unspent remainder immediately funds queued work
            self.scheduler.on_finish(uid, n, int(self._slot_lim[slot]))
            self._slot_lim[slot] = self.max_new
            if self.paged:
                # return the candidate's pages (shared prompt pages just
                # drop a holder)
                self.pool.free(self._slot_pages[slot])
                self._slot_pages[slot] = []
                self._reserved_sh[self._slot_shard(slot)] -= \
                    int(self._slot_reserved[slot])
                self._slot_reserved[slot] = 0
            if uid not in uids:
                uids.append(uid)
        if self.paged:
            # quarantine the freed slots' block tables in one scatter so
            # their dead writes land on their shard's reserved page
            cache = self.state.cache
            quar = jnp.asarray([self._quarantine(s) for s in slots],
                               jnp.int32)
            bt = cache["block_table"].at[idx].set(quar[:, None])
            self.state = self.state._replace(
                cache={**cache, "block_table": bt})
        # rounds complete when no slots of the request remain live
        due = [u for u in uids
               if not any(self._slot_req[s] == u for s in range(self.B))]
        if due:
            self._finish_rounds(due)

    def _finish_rounds(self, uids: List[int]):
        """Fold completed rounds — ALL of them in one call to the vmapped
        ``batched_round_update_assign`` (a macro-step often retires several
        requests' rounds at once; the legacy loop dispatched one round
        update per request)."""
        R = self._per_round()
        batch = []
        for uid in uids:
            info = self._reqs[uid]
            round_recs = [info["records"][c] for c, _ in info["cand_slots"]
                          if c in info["records"] and
                          "scored" not in info["records"][c]]
            if not round_recs:
                continue
            for r in round_recs:
                r["scored"] = True
            assert len(round_recs) <= R, \
                (len(round_recs), R)   # scheduler admits ≤ per_round/round
            pad = R - len(round_recs)
            recs = round_recs + round_recs[:1] * pad
            inp = ctrl.RoundInputs(
                scores=np.asarray([r["score"] for r in recs], np.float32),
                embs=np.stack([r["emb"] for r in recs]).astype(np.float32),
                token_counts=np.stack([r["counts"] for r in recs]
                                      ).astype(np.float32),
                lengths=np.asarray([r["n"] for r in recs], np.int32),
                valid=np.asarray([True] * len(round_recs) + [False] * pad),
                uids=np.asarray([r["uid"] for r in recs], np.int32),
            )
            batch.append((uid, round_recs, inp))
        if not batch:
            return
        states = jax.tree.map(lambda *xs: jnp.stack(xs),
                              *[self._reqs[u]["camd"] for u, _, _ in batch])
        inps = jax.tree.map(lambda *xs: jnp.stack(xs),
                            *[b[2] for b in batch])
        if self.mode != "camd":
            # the coverage/max_rounds stop rule is CAMD's token-budget
            # policy; the fixed-budget baselines must keep folding every
            # round into the cluster table (a frozen table would orphan
            # late candidates from self-consistency's majority vote and
            # freeze best_of_n's best-candidate tracking).
            states = states._replace(stopped=jnp.zeros_like(states.stopped))
        # pad the batch to a power of two (repeat row 0, discard results)
        # so the vmapped round update compiles for O(log B) shapes, not
        # one per distinct simultaneous-completion count
        n = len(batch)
        nb = _next_pow2(n)
        if nb > n:
            states, inps = jax.tree.map(
                lambda x: jnp.concatenate(
                    [x, jnp.repeat(x[:1], nb - n, axis=0)]), (states, inps))
        new_states, biases, clusters = self._round_fn(states, inps)
        stopped_np, clusters_np, pstar_np, best_np = self._sync(
            (new_states.stopped, clusters, new_states.p_star,
             new_states.best_score))
        for i, (uid, round_recs, _) in enumerate(batch):
            info = self._reqs[uid]
            info["camd"] = jax.tree.map(lambda x, i=i: x[i], new_states)
            # host copies the traffic scheduler ranks by (folded into the
            # round sync above — no extra device round-trip)
            info["p_star"] = float(pstar_np[i])
            info["best_score_host"] = float(best_np[i])
            for j, r in enumerate(round_recs[:R]):
                r["cluster"] = int(clusters_np[i, j])
            info["round"] += 1
            if self.mode == "camd":
                info["bias"] = biases[i][None]
                stopped = bool(stopped_np[i])
            else:
                info["bias"] = None
                stopped = len(info["records"]) >= self.n_candidates
            if stopped:
                self._finish_request(uid)
            else:
                info["pending_round"] = True

    def _finish_request(self, uid: int):
        """Finalize a request with the candidates it has: free its prompt
        cache row and paged prompt-page holds. Used when the stop rule
        trips, when the coverage policy declines further rounds, and by
        the budget-exhaustion drain."""
        info = self._reqs[uid]
        info["done"] = True
        info["pending_round"] = False
        info["cache_row"] = None          # free the prompt cache
        r = info.pop("arena_row", None)
        if r is not None:
            self.arena.free([r])
        if self.paged and info.get("prompt_pages"):
            self.pool.free(info.pop("prompt_pages"))
        # completion feed for the async front-end (drained via
        # pop_finished; harmless growth under synchronous run)
        self._newly_done.append(uid)

    # ------------------------------------------------------------------
    def _has_pending(self) -> bool:
        return bool(self._queue) or any(
            not i["done"] and i.get("pending_round")
            for i in self._reqs.values())

    def _raise_pool_sizing(self):
        # nothing running and nothing admissible: the pool cannot cover
        # even one candidate of the waiting work (FIFO head-of-line) — a
        # sizing error, not a transient.
        blocked = self._queue[0].uid if self._queue else \
            next(uid for uid, i in self._reqs.items() if not i["done"])
        done_n = sum(1 for i in self._reqs.values() if i["done"])
        raise RuntimeError(
            f"paged KV pool ({self.pool.num_pages} pages of "
            f"{self.page_size}) cannot admit request "
            f"{blocked} ({done_n} completed results "
            f"discarded) — raise num_pages or lower "
            f"max_new_tokens/prompt lengths")

    def _finalize_starved(self):
        """Terminal drain under an exhausted global token budget: pending
        work that can never be funded again finalizes with whatever
        candidates it already has (possibly none — ``Result.tokens``
        empty, recorded in ``starved_uids``). The budget invariant
        (total tokens <= budget) is preserved; nothing hangs."""
        for job in self._chunking.values():
            # half-prefilled chunk pages can never be used again
            if job["pages"]:
                self.pool.free(job["pages"])
        self._chunking.clear()
        for req in self._queue:
            if req.uid not in self._reqs:
                self._reqs[req.uid] = {
                    "req": req, "cache_row": None,
                    "camd": ctrl.init_state(self.camd, self.d, self.V),
                    "bias": None, "round": 0, "cand_slots": [],
                    "records": {}, "align_const": 0.0, "done": False}
        self._queue.clear()
        for uid, info in self._reqs.items():
            if not info["done"]:
                if not info["records"]:
                    self.starved_uids.append(uid)
                self._finish_request(uid)

    def _refill_idle(self) -> bool:
        """No slot is live: drain the queue / pending rounds back into
        slots. Returns True when all work is complete (caller breaks)."""
        if not self._has_pending():
            return True
        self._chunk_progress = False
        self._schedule()
        if not self._any_live():
            if self.scheduler.exhausted():
                # global token budget spent: nothing can ever be admitted
                # again — finalize instead of spinning
                self._finalize_starved()
                return True
            if self._chunk_progress:
                # chunked prefill advanced — not a sizing error, the
                # caller loops and the next pass continues the job
                return False
            if self.paged:
                self._raise_pool_sizing()
            if self.arena is not None:
                # defensively unreachable: a full arena means held rows,
                # and held rows mean live or admissible work — fail fast
                # instead of spinning if that invariant ever breaks
                raise RuntimeError(
                    f"state arena ({self.arena.num_rows} rows, "
                    f"{self.arena.free_rows} free) cannot admit pending "
                    "work — arena sizing invariant violated")
        return False

    def run(self) -> List[Result]:
        if self.macro_steps <= 0:
            return self._run_legacy()
        self._begin()
        while self._step():
            pass
        return [self._result(uid) for uid in self._reqs]

    def _begin(self):
        """Admission pass + evidence-row staging before stepping."""
        self._schedule()
        evid = jnp.zeros((self.B, 1, self.d), jnp.float32)
        if self._evid_sharding is not None:
            evid = jax.device_put(evid, self._evid_sharding)
        if self.has_evidence:
            evid = self._gather_evid()
        self._evid = evid

    def _step(self) -> bool:
        """One fused-loop serving iteration: refill when idle, otherwise
        stage the frontier, run one macro launch and fold its results
        (cancellations first, then token streaming, frontier reclaim,
        finished candidates). Returns False when all work is drained —
        this is the old ``run`` loop body verbatim, extracted so the
        async front-end can drive the engine launch-by-launch."""
        self._chunk_left = self.chunk_budget     # per-turn chunk budget
        if not self._any_live():
            if self._refill_idle():
                return False
            if self.has_evidence:
                self._evid = self._gather_evid()
            return True
        staged, frontier = (self._stage_frontier() if self.paged
                            else (None, self._dummy_frontier))
        if self._frontier_sharding is not None:
            frontier = jax.device_put(frontier, self._frontier_sharding)
        self._reshard()
        if self.spec:
            self.state, done, steps, nd, na = self._macro_fn(
                self.params, self.state, self._decode_key,
                jnp.int32(self._t), self._evid, frontier)
        else:
            self.state, done, steps = self._macro_fn(
                self.params, self.state, self._decode_key,
                jnp.int32(self._t), self._evid, frontier)
        self.macro_launches += 1
        # ONE host sync per launch: cancellation emission counts and
        # streaming readbacks ride the tuple the fold already needs
        tree = [done, self.state.cache["pos"], steps]
        if self.spec:
            tree += [nd, na]
        want_ntok = self.stream_tokens or bool(self._cancels)
        if want_ntok:
            tree.append(self.state.n_tok)
        if self.stream_tokens:
            tree.append(self.state.out_buf)
        vals = self._sync(tuple(tree))
        done_np, pos_np, steps_np = vals[0], vals[1], vals[2]
        k = 3
        if self.spec:
            self.spec_drafted += int(vals[3])
            self.spec_accepted += int(vals[4])
            k = 5
        ntok_np = vals[k] if want_ntok else None
        out_np = vals[k + 1] if self.stream_tokens else None
        steps_n = int(steps_np)
        self.total_steps += steps_n
        # each speculative iteration consumes spec_k fold-in keys
        self._t += steps_n * (self.spec_k if self.spec else 1)
        cancelled = self._apply_cancels(staged, ntok_np) \
            if self._cancels else False
        if self.stream_tokens:
            self._emit_stream(ntok_np, out_np)
        if self.paged:
            self._reclaim_frontier(staged, pos_np)
        done_slots = [int(s) for s in np.nonzero(done_np)[0]
                      if self._slot_req[s] >= 0]
        if done_slots or cancelled:
            if done_slots:
                self._finish_candidates(done_slots)
            self._schedule()
            if self.has_evidence:
                self._evid = self._gather_evid()
        elif self.chunked and (self._chunking or
                               (self._queue and self._free_slots())):
            # no completions this launch, but prefill work is waiting:
            # spend this turn's chunk budget between decode launches —
            # the stall-free interleaving the chunking exists for
            self._schedule()
        return True

    def pump(self) -> bool:
        """Drive ONE serving iteration (the async front-end's hook).

        Unlike ``run`` — which only admits at completion boundaries —
        ``pump`` also runs an admission pass when new work arrived
        between launches, since an open-loop arrival process delivers
        requests mid-flight. Returns False once the engine is drained
        (call again after the next ``submit``)."""
        if self.macro_steps <= 0:
            raise RuntimeError(
                "pump() drives the fused macro-step loop; construct the "
                "engine with macro_steps >= 1 for async serving")
        if self._evid is None:
            self._begin()
        elif (self._queue and self._free_slots()) or self._chunking:
            self._schedule()
            if self.has_evidence and self._any_live():
                self._evid = self._gather_evid()
        return self._step()

    def _emit_stream(self, ntok_np, out_np):
        """Queue per-slot token deltas for the async front-end. Deltas
        are emitted before finished slots fold, so a candidate's final
        tokens are never lost; the concatenation of one candidate's
        deltas is byte-identical to its finished ``tokens`` record."""
        for s in range(self.B):
            uid = int(self._slot_req[s])
            if uid < 0:
                continue
            n = int(ntok_np[s])
            if n > self._slot_streamed[s]:
                self.stream_events.append(
                    (uid, int(self._slot_cand[s]),
                     np.asarray(out_np[s][int(self._slot_streamed[s]):n])))
                self._slot_streamed[s] = n

    # ------------------------------------------------------------------
    # cancellation (the abort path)
    # ------------------------------------------------------------------
    def cancel(self, uid: int) -> bool:
        """Abort a request: queued/pending work is dropped immediately;
        running candidates are torn down at the next step boundary —
        staged frontier pages return to the pool, slots free, and the
        scheduler's worst-case commitment is refunded (see
        ``_apply_cancels``). Returns False for unknown or already-
        finished uids. A cancelled request still yields a ``Result``
        (``cancelled=True``) with whatever candidates it completed."""
        info = self._reqs.get(uid)
        if info is None:
            # mid chunked prefill: return every chunk page to the pool
            # (the job's hold) before dropping the queued request
            job = self._chunking.pop(uid, None)
            if job is not None and job["pages"]:
                self.pool.free(job["pages"])
            # queued but never prefilled: drop from the queue, with a
            # stub record so results stay uniform
            for i, r in enumerate(self._queue):
                if r.uid == uid:
                    self._queue.pop(i)
                    self._reqs[uid] = {
                        "req": r, "cache_row": None,
                        "camd": ctrl.init_state(self.camd, self.d, self.V),
                        "bias": None, "round": 0, "cand_slots": [],
                        "records": {}, "align_const": 0.0, "done": False,
                        "cancelled": True}
                    self._finish_request(uid)
                    self.cancelled_requests += 1
                    return True
            return False
        if info["done"]:
            return False
        if any(int(self._slot_req[s]) == uid for s in range(self.B)):
            # live candidates: fold the teardown into the next launch's
            # sync — the emission counts spent-accounting needs ride the
            # readback the step already pays for
            self._cancels.add(uid)
            return True
        # prefilled but not running (queued or pending a round): release
        # its prompt-cache row and page holds now
        self._queue = [r for r in self._queue if r.uid != uid]
        info["cancelled"] = True
        self._finish_request(uid)
        self.cancelled_requests += 1
        return True

    def _apply_cancels(self, staged, ntok_np) -> bool:
        """Tear down cancel-marked requests' live slots after a launch.

        Runs BEFORE ``_reclaim_frontier``: a cancelled slot's staged
        frontier pages are returned wholesale (``PagePool.return_
        frontier``) and its entry dropped from ``staged``; its
        pre-launch pages are freed, its shard's reservation released,
        and the scheduler refunds the candidate's worst-case commitment
        (tokens it did emit count as spent — the compute is burned).
        Pages/slots/budget all return to their pre-admission accounting;
        the hypothesis conservation suite pins this."""
        uids = set(self._cancels)
        self._cancels.clear()
        slots = [s for s in range(self.B)
                 if int(self._slot_req[s]) in uids]
        if not slots:
            return False
        for s in slots:
            uid = int(self._slot_req[s])
            n = int(ntok_np[s])
            self.total_tokens += n
            self.scheduler.on_cancel(uid, n, int(self._slot_lim[s]))
            self._slot_req[s] = -1
            self._slot_cand[s] = -1
            self._slot_spec[s] = 1
            self._slot_lim[s] = self.max_new
            self._slot_streamed[s] = 0
            if self.paged:
                if staged is not None and s in staged:
                    _p0, pages = staged.pop(s)
                    if pages:
                        self.pool.return_frontier(pages)
                self.pool.free(self._slot_pages[s])
                self._slot_pages[s] = []
                self._reserved_sh[self._slot_shard(s)] -= \
                    int(self._slot_reserved[s])
                self._slot_reserved[s] = 0
        # deactivate on device so later launches neither decode into the
        # dead slots nor early-exit on their stale done flags
        idx = jnp.asarray(slots)
        st = self.state
        cache = st.cache
        if self.paged:
            quar = jnp.asarray([self._quarantine(s) for s in slots],
                               jnp.int32)
            cache = {**cache,
                     "block_table": cache["block_table"].at[idx].set(
                         quar[:, None])}
        self.state = st._replace(active=st.active.at[idx].set(False),
                                 cache=cache)
        for uid in sorted(uids):
            info = self._reqs.get(uid)
            if info is not None and not info["done"]:
                info["cancelled"] = True
                self._finish_request(uid)
                self.cancelled_requests += 1
        return True

    def _run_legacy(self) -> List[Result]:
        """Pre-macro-step per-token host loop (macro_steps=0): one jitted
        step, one host sync, and one block-table scatter per generated
        token. Kept as the benchmarking baseline the fused loop is
        measured against."""
        self._schedule()
        evid = jnp.zeros((self.B, 1, self.d), jnp.float32)
        if self._evid_sharding is not None:
            evid = jax.device_put(evid, self._evid_sharding)
        if self.has_evidence:
            evid = self._gather_evid()
        while True:
            if not self._any_live():
                if self._refill_idle():
                    break
                if self.has_evidence:
                    evid = self._gather_evid()
                continue
            self.key, k = jax.random.split(self.key)
            if self.paged:
                self._alloc_step_pages()
            self._reshard()
            self.state, done = self._step_fn(self.params, self.state, k, evid)
            self.total_steps += 1
            self._t += 1
            done_np = self._sync(done)
            cancelled = self._apply_cancels(
                None, self._sync(self.state.n_tok)) \
                if self._cancels else False
            if done_np.any() or cancelled:
                # per-slot finishes, as the pre-refactor loop did — this
                # is the readback pattern the macro path amortizes away
                for s in np.nonzero(done_np)[0]:
                    if self._slot_req[int(s)] >= 0:
                        self._finish_candidates([int(s)])
                self._schedule()
                if self.has_evidence:
                    evid = self._gather_evid()
        return [self._result(uid) for uid in self._reqs]

    def _gather_evid(self):
        rows = []
        for s in range(self.B):
            uid = int(self._slot_req[s])
            if uid >= 0 and "evid_row" in self._reqs[uid]:
                rows.append(self._reqs[uid]["evid_row"][0])
            else:
                rows.append(jnp.zeros_like(
                    next(iter(self._reqs.values()))["evid_row"][0])
                    if self._reqs else jnp.zeros((1, self.d)))
        # pad rows to equal Ne
        ne = max(r.shape[0] for r in rows)
        rows = [jnp.pad(r, ((0, ne - r.shape[0]), (0, 0))) for r in rows]
        ev = jnp.stack(rows)
        if self._evid_sharding is not None:
            ev = jax.device_put(ev, self._evid_sharding)
        return ev

    def _result(self, uid: int) -> Result:
        info = self._reqs[uid]
        cs = info["camd"]
        recs = list(info["records"].values())
        if not recs:
            # budget-starved: never admitted before the stream's global
            # token budget ran out
            return Result(
                uid=uid, tokens=np.zeros((0,), np.int32), n_candidates=0,
                tokens_spent=0, rounds=info["round"],
                p_star=float(cs.p_star), best_score=float(cs.best_score),
                stopped_early=False, candidates=[],
                cancelled=info.get("cancelled", False))
        if self.mode == "self_consistency":
            # majority vote: the largest cluster wins, then its
            # best-scoring member is the answer (falling back to the
            # global best score only when cluster bookkeeping is empty)
            n_cl = int(cs.table.n_clusters)
            members: List[Dict[str, Any]] = []
            if n_cl > 0:
                sizes = np.asarray(cs.table.sizes)[:n_cl]
                best_k = int(np.argmax(sizes))
                members = [r for r in recs if r.get("cluster", -1) == best_k]
            chosen = max(members or recs, key=lambda r: r["score"])
        else:
            bu = int(cs.best_uid)
            chosen = info["records"].get(bu) or max(recs, key=lambda r: r["score"])
        return Result(
            uid=uid,
            tokens=chosen["tokens"],
            n_candidates=len(recs),
            tokens_spent=int(sum(r["n"] for r in recs)),
            rounds=info["round"],
            p_star=float(cs.p_star),
            best_score=float(cs.best_score),
            stopped_early=(self.mode == "camd" and bool(cs.stopped)
                           and float(cs.p_star) >= 1.0 - self.camd.delta),
            candidates=[{k: v for k, v in r.items() if k not in ("counts", "emb")}
                        for r in recs],
            cancelled=info.get("cancelled", False),
        )


class _EngineSchedContext(SchedulerContext):
    """The engine-side implementation of the scheduler facade. Slot ids
    are handed out in ascending order (``_free_slots``) exactly as the
    pre-scheduler loop did, so the fifo policy's slot assignment — and
    therefore its token streams — stay bit-identical."""

    def __init__(self, eng: ServeEngine):
        self.eng = eng
        self.max_new = eng.max_new
        self.num_shards = eng.dp

    def free_slots(self) -> int:
        return len(self.eng._free_slots())

    def queued_new(self) -> List[NewWork]:
        eng = self.eng
        out = []
        for r in eng._queue:
            if r.uid in eng._chunking:
                continue                 # mid chunked prefill: not yet
                                         # admissible, but later short
                                         # requests must keep streaming
            if r.uid not in eng._reqs:
                break                    # prefill covers a queue prefix
            info = eng._reqs[r.uid]
            out.append(NewWork(uid=r.uid, arrival=eng._arrival[r.uid],
                               want=eng._per_round(),
                               prompt_len=info.get("prompt_len", 0),
                               evidence_entropy=info.get(
                                   "evidence_entropy", 0.0)))
        return out

    def pending_rounds(self) -> List[RoundWork]:
        eng = self.eng
        out = []
        for uid, info in eng._reqs.items():
            if info["done"] or info.get("pending_round") is not True:
                continue
            recs = list(info["records"].values())
            scores = [r["score"] for r in recs]
            out.append(RoundWork(
                uid=uid, arrival=eng._arrival.get(uid, 0),
                want=eng._needed(info), rounds=info["round"],
                p_star=info.get("p_star", 0.0), delta=eng.camd.delta,
                best_score=info.get("best_score_host",
                                    max(scores, default=0.0)),
                scores=scores,
                mean_len=float(np.mean([r["n"] for r in recs]))
                if recs else 0.0))
        return out

    def affordable(self, uid: int, want: int, limit: int) -> int:
        eng = self.eng
        if not eng.paged:
            return want
        return eng._paged_affordable(eng._reqs[uid], want, limit)

    def admit_new(self, uid: int, take: int, limit: int) -> None:
        eng = self.eng
        i = next(i for i, r in enumerate(eng._queue) if r.uid == uid)
        req = eng._queue.pop(i)
        eng._admit(req, eng._free_slots()[:take], limit=limit)

    def admit_round(self, uid: int, take: int, limit: int) -> None:
        eng = self.eng
        info = eng._reqs[uid]
        info["pending_round"] = False
        eng._admit(info["req"], eng._free_slots()[:take], limit=limit)

    def finish_request(self, uid: int) -> None:
        self.eng._finish_request(uid)
