"""internvl2-2b — InternVL2 2B VLM (InternViT-300M + InternLM2-1.8B).

[arXiv:2404.16821]: language backbone 24L, d_model=2048, 16 q heads,
GQA kv=8, d_ff=8192, vocab 92553. 256 patch tokens per 448x448 image
tile (InternViT's post-pixel-shuffle grid: (448/28)^2 = 256), encoded
by the in-repo vision tower (an InternViT-shaped stand-in: same grid
and token count, far fewer layers).
"""
from repro.config import ATTN, ModelConfig, VisionConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=92553,
    block_pattern=(ATTN,),
    mlp_activation="swiglu",
    num_evidence_tokens=256,      # ViT patch embeddings per image tile
    evidence_dim=2048,
    vision=VisionConfig(image_h=448, image_w=448, patch=28,
                        num_layers=4, d_model=768, num_heads=12, d_ff=3072),
    source="arXiv:2404.16821",
)
