"""Differential tests: the scheduler refactor is provably
behavior-preserving for ``sched_policy="fifo"``.

Two independent oracles:

1. **Pinned golden streams** (``tests/data/golden_fifo_streams.json``),
   generated from the pre-refactor engine (commit 656a8ea) across all
   4 modes x {xla, paged} x macro_steps in {0, 8}. Bit-identity of CPU
   float ops is only stable within a jax version, so this test
   soft-skips when the runtime jax differs from the recorded one.

2. **Live legacy loop**: an engine subclass whose ``_schedule`` is the
   verbatim pre-refactor scheduling loop (no policy object). Runs on
   any jax version — the refactored fifo engine must emit bit-identical
   streams to it on the same workload.
"""
import importlib.util
import json
import os

import jax
import pytest

from repro.serving import ServeEngine

_spec = importlib.util.spec_from_file_location(
    "make_golden_fifo",
    os.path.join(os.path.dirname(__file__), "data", "make_golden_fifo.py"))
_gold_mod = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(_gold_mod)
IMPLS, KS, MODES = _gold_mod.IMPLS, _gold_mod.KS, _gold_mod.MODES
make_engine, submit, tiny_model = (_gold_mod.make_engine, _gold_mod.submit,
                                   _gold_mod.tiny_model)

GOLDEN = os.path.join(os.path.dirname(__file__), "data",
                      "golden_fifo_streams.json")


@pytest.fixture(scope="module")
def golden_model():
    return tiny_model()


def _streams(res):
    return [{
        "uid": r.uid,
        "tokens": r.tokens.tolist(),
        "tokens_spent": r.tokens_spent,
        "rounds": r.rounds,
        "n_candidates": r.n_candidates,
        "candidates": sorted(c["tokens"].tolist() for c in r.candidates),
    } for r in sorted(res, key=lambda r: r.uid)]


# ---------------------------------------------------------------------------
# oracle 1: pinned pre-refactor streams
# ---------------------------------------------------------------------------

with open(GOLDEN) as f:
    _GOLD = json.load(f)


@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("k", KS)
def test_fifo_matches_pre_refactor_golden(golden_model, mode, impl, k):
    """Acceptance bar: fifo token streams are bit-identical to the
    pre-refactor engine in every mode x impl x macro-step cell."""
    if _GOLD["jax_version"] != jax.__version__:
        pytest.skip(f"goldens pinned under jax {_GOLD['jax_version']}, "
                    f"running {jax.__version__} (live differential below "
                    f"still covers the refactor)")
    cfg, model, params = golden_model
    eng = make_engine(model, params, mode=mode, impl=impl, macro_steps=k,
                      sched_policy="fifo")
    submit(eng, cfg)
    assert _streams(eng.run()) == _GOLD["cells"][f"{mode}/{impl}/K{k}"]


# ---------------------------------------------------------------------------
# oracle 2: live legacy scheduling loop
# ---------------------------------------------------------------------------

class _LegacyScheduleEngine(ServeEngine):
    """The pre-refactor ``_schedule`` body, verbatim (modulo the helper
    signatures' backward-compatible defaults). No Scheduler object — the
    loop below IS what FifoScheduler must reproduce decision for
    decision."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        # admissions bypass the policy object here, so silence its
        # commitment accounting (budget=0: accounting is telemetry only)
        self.scheduler.on_finish = lambda uid, n, limit: None

    def _schedule(self):
        self._prefill_pending()
        free = self._free_slots()
        while free and self._queue:
            req = self._queue[0]
            take = min(self._per_round(), len(free))
            if self.paged:
                take = self._paged_affordable(self._reqs[req.uid], take)
                if take <= 0:
                    break             # wait for pages, keep queue order
            self._queue.pop(0)
            ids, free = free[:take], free[take:]
            self._admit(req, ids)
        for uid, info in self._reqs.items():
            if info["done"] or info.get("pending_round") is not True:
                continue
            if not free:
                break
            take = min(self._needed(info), len(free))
            if self.paged:
                take = self._paged_affordable(info, take)
            if take <= 0:
                continue
            ids, free = free[:take], free[take:]
            info["pending_round"] = False
            self._admit(info["req"], ids)


@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("k", KS)
def test_fifo_matches_live_legacy_loop(golden_model, mode, impl, k):
    cfg, model, params = golden_model
    legacy = _LegacyScheduleEngine(
        model, params, **_engine_kw(mode, impl, k))
    submit(legacy, cfg)
    ref = _streams(legacy.run())

    eng = make_engine(model, params, mode=mode, impl=impl, macro_steps=k,
                      sched_policy="fifo")
    submit(eng, cfg)
    assert _streams(eng.run()) == ref


def _engine_kw(mode, impl, k):
    from repro.config import CAMDConfig, PagedKVConfig, SamplingConfig
    return dict(
        slots=4, cache_len=32,
        sampling=SamplingConfig(max_new_tokens=6, temperature=0.8),
        camd=CAMDConfig(samples_per_round=2, max_rounds=2, min_samples=2,
                        max_clusters=8),
        n_candidates=3, max_new_tokens=6, eos_id=1, seed=0,
        paged_kv=PagedKVConfig(page_size=8),
        mode=mode, impl=impl, macro_steps=k)


def test_fifo_under_slot_pressure_matches_legacy(golden_model):
    """More requests than slots + small pool: the queue/round interleaving
    and paged backpressure decisions must also match exactly."""
    from repro.config import PagedKVConfig
    cfg, model, params = golden_model
    kw = _engine_kw("camd", "paged", 8)
    kw["paged_kv"] = PagedKVConfig(page_size=8, num_pages=9)
    legacy = _LegacyScheduleEngine(model, params, **kw)
    submit(legacy, cfg, n=5)
    ref = _streams(legacy.run())
    eng = ServeEngine(model, params, sched_policy="fifo", **kw)
    submit(eng, cfg, n=5)
    assert _streams(eng.run()) == ref
    eng.pool.check()
    assert eng.pool.in_use == 0
