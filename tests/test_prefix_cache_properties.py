"""Property tests for the PrefixCache lazy-deletion victim heaps.

The heaps are an optimization over a full leaf scan; these tests pin the
equivalence: under arbitrary insert/touch/hold interleavings the heap
must evict exactly the node a brute-force scan of ``_nodes`` would pick
(least tick, then key), compaction must never change the victim order,
and the per-shard heaps must agree with the brute-force scan restricted
to their shard.
"""
import copy

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.serving.page_pool import PagePool  # noqa: E402

# (op, chain id, prefix length): chains share prefixes by construction,
# "hold" pins a chain's pages (refcount > 1) until released
OPS = st.lists(
    st.tuples(st.sampled_from(["insert", "touch", "hold"]),
              st.integers(0, 3), st.integers(1, 6)),
    min_size=1, max_size=25)


def _chain_keys(cid, n):
    return [f"c{cid}/{i}" for i in range(n)]


def _apply(pool, ops):
    """Drive the cache like the engine does: match first, allocate the
    uncached suffix, register, drop the request hold. Returns pages the
    'hold' ops left pinned."""
    cache = pool.prefix
    held = []
    for op, cid, ln in ops:
        keys = _chain_keys(cid, ln)
        pages = cache.match_and_hold(keys)
        if op == "insert":
            n_new = ln - len(pages)
            shard = cid % pool.num_shards
            if pool.free_pages_in(shard) < n_new:
                pool.free(pages)
                continue
            pages = pages + pool.alloc(n_new, shard)
            cache.insert(keys, pages)
            pool.free(pages)
        elif op == "touch" or not pages:
            pool.free(pages)
        else:                                   # hold: keep the request hold
            held.append(pages)
    return held


def _true_victim(cache, shard=None):
    """Brute-force reference: the evictable node with the least
    (tick, key) — leaves only, no live request holders, shard-filtered
    when asked. None when nothing is evictable."""
    best = None
    for k, node in cache._nodes.items():
        if node.children > 0 or cache.pool.refcount(node.page) > 1:
            continue
        if shard is not None and cache.pool.shard_of(node.page) != shard:
            continue
        if best is None or (node.tick, k) < best:
            best = (node.tick, k)
    return best


def _evict_one(cache):
    before = set(cache._nodes)
    freed = cache.evict(1)
    gone = before - set(cache._nodes)
    assert freed == len(gone)
    return gone.pop() if gone else None


@pytest.mark.parametrize("num_shards", [1, 2])
@given(ops=OPS)
@settings(max_examples=40, deadline=None)
def test_eviction_follows_true_lru(num_shards, ops):
    pool = PagePool(64, 4, prefix_cache=True, num_shards=num_shards)
    cache = pool.prefix
    held = _apply(pool, ops)
    while True:
        want = _true_victim(cache)
        got = _evict_one(cache)
        if want is None:
            assert got is None
            break
        assert got == want[1]
        pool.check()
    # releasing the pinned chains exposes them (and their ancestors,
    # leaf-first) as victims — drain to empty in true LRU order too
    for pages in held:
        pool.free(pages)
    while cache._nodes:
        want = _true_victim(cache)
        assert want is not None
        assert _evict_one(cache) == want[1]
    pool.check()
    assert pool.in_use == 0


@given(ops=OPS)
@settings(max_examples=40, deadline=None)
def test_compaction_never_changes_victim_order(ops):
    pool_a = PagePool(64, 4, prefix_cache=True)
    for pages in _apply(pool_a, ops):
        pool_a.free(pages)
    pool_b = copy.deepcopy(pool_a)
    pool_b.prefix._compact()
    order_a, order_b = ([], [])
    for pool, order in ((pool_a, order_a), (pool_b, order_b)):
        while pool.prefix._nodes:
            order.append(_evict_one(pool.prefix))
    assert order_a == order_b


@given(ops=OPS, shard=st.integers(0, 1))
@settings(max_examples=40, deadline=None)
def test_shard_filtered_eviction_follows_true_lru(ops, shard):
    pool = PagePool(64, 4, prefix_cache=True, num_shards=2)
    cache = pool.prefix
    for pages in _apply(pool, ops):
        pool.free(pages)
    while True:
        want = _true_victim(cache, shard=shard)
        before = set(cache._nodes)
        freed = cache.evict(1, shard=shard)
        if want is None:
            assert freed == 0
            break
        assert freed == 1
        assert (before - set(cache._nodes)).pop() == want[1]
        pool.check()
    # the other shard's nodes are untouched by shard-filtered pressure
    for k, node in cache._nodes.items():
        assert pool.shard_of(node.page) != shard or \
            node.children > 0 or pool.refcount(node.page) > 1
