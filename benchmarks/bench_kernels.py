"""Kernel micro-benchmarks — name,us_per_call,derived CSV.

On CPU the Pallas kernels run against the jnp-reference path (interpret
mode is a correctness harness, not a perf one), so the numbers here time
the XLA oracle path; derived column reports achieved GFLOP/s. On a TPU
backend the same rows time the compiled kernels at the block sizes a
committed ``BENCH_autotune.json`` selected (``autotune.load_tuned``).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

try:
    from benchmarks.autotune import load_tuned
except ImportError:          # invoked as a script: benchmarks/ is sys.path[0]
    from autotune import load_tuned
from repro.kernels import ops, ref
from repro.models.attention import kv_quantize


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def run(verbose: bool = True):
    rows = []
    key = jax.random.PRNGKey(0)
    tuned = load_tuned()

    B, L, H, hd = 1, 1024, 4, 64
    q = jax.random.normal(key, (B, L, H, hd), jnp.float32)
    t_fa = tuned["flash_attention"]
    fa = jax.jit(lambda q: ops.flash_attention(
        q, q, q, causal=True, blk_q=t_fa["blk_q"], blk_k=t_fa["blk_k"]))
    us = _time(fa, q)
    flops = 4 * B * H * L * L * hd / 2  # causal half
    rows.append(("flash_attention_ref_1k", us, f"{flops/us/1e3:.1f}GFLOPs"))

    S, Hkv = 8192, 2
    qd = jax.random.normal(key, (B, 1, H, hd), jnp.float32)
    kd = jax.random.normal(key, (B, S, Hkv, hd), jnp.float32)
    mask = jnp.ones((B, S), bool)
    da = jax.jit(lambda q, k, m: ops.decode_attention(
        q, k, k, m, blk_s=tuned["decode_attention"]["blk_s"]))
    us = _time(da, qd, kd, mask)
    bytes_moved = 2 * B * S * Hkv * hd * 4
    rows.append(("decode_attention_ref_8k", us,
                 f"{bytes_moved/us/1e3:.1f}GBps"))

    # paged decode on the same 8k context, but only half the pages live —
    # the µs/token and bytes columns show paged traffic scaling with live
    # tokens where the contiguous row above pays slots × cache_len.
    ps = 128
    P = S // ps + 1
    live = S // 2
    n_pages = live // ps
    kp = jax.random.normal(key, (P, ps, Hkv, hd), jnp.float32)
    bt = (1 + jnp.arange(B * n_pages, dtype=jnp.int32)).reshape(B, n_pages)
    lengths = jnp.full((B,), live, jnp.int32)
    pda = jax.jit(lambda q, k, t, ln: ops.paged_decode_attention(
        q, k, k, t, ln))
    us = _time(pda, qd, kp, bt, lengths)
    bytes_moved = 2 * B * live * Hkv * hd * 4
    rows.append(("paged_decode_ref_8k_half_live", us,
                 f"{bytes_moved/us/1e3:.1f}GBps"))

    # same shape, int8 pool with in-kernel dequant: the bytes column is
    # what quantization buys — ~0.27x the fp32 traffic per live token.
    kq, ks = kv_quantize(kp, jnp.int8)
    pdq = jax.jit(lambda q, k, s, t, ln: ops.paged_decode_attention(
        q, k, k, t, ln, k_scale=s, v_scale=s))
    us = _time(pdq, qd, kq, ks, bt, lengths)
    bytes_moved = 2 * B * live * Hkv * (hd * 1 + 4)   # int8 values + scale
    rows.append(("paged_decode_int8_8k_half_live", us,
                 f"{bytes_moved/us/1e3:.1f}GBps"))

    Lx, Nv, Nt, d = 512, 256, 128, 256
    tok = jax.random.normal(key, (B, Lx, d))
    vis = jax.random.normal(key, (B, Nv, d))
    txt = jax.random.normal(key, (B, Nt, d))
    m = jnp.ones((B, Lx))
    xm = jax.jit(lambda t, m, v, x: ref.xmodal_score_ref(t, m, v, x))
    us = _time(xm, tok, m, vis, txt)
    flops = 2 * B * (Lx * Nv + Nt * Nv) * d
    rows.append(("xmodal_score_ref", us, f"{flops/us/1e3:.1f}GFLOPs"))

    if verbose:
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}")
    return rows


if __name__ == "__main__":
    run()
