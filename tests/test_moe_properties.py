"""Property-based MoE tests: the capacity-dispatch path must agree with
the dropless dense oracle whenever capacity is not binding, across
shapes, expert counts, and top-k."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need hypothesis;
# a bare interpreter must still collect the suite (module-level skip)
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.config import ATTN, ModelConfig, MoEConfig
from repro.models.moe import moe_apply, moe_apply_dense, moe_init


def _cfg(E, k, d, f, shared=0, act="swiglu"):
    return ModelConfig(
        name="t", family="moe", num_layers=1, d_model=d, num_heads=2,
        num_kv_heads=1, d_ff=f, vocab_size=64, head_dim=32,
        block_pattern=(ATTN,), mlp_activation=act,
        moe=MoEConfig(num_experts=E, top_k=k, expert_d_ff=f,
                      num_shared_experts=shared, capacity_factor=16.0),
        dtype="float32")


@settings(max_examples=15, deadline=None)
@given(E=st.sampled_from([4, 6, 8]), k=st.integers(1, 3),
       T=st.integers(3, 70), seed=st.integers(0, 10**6),
       shared=st.integers(0, 1))
def test_capacity_dispatch_matches_dense_oracle(E, k, T, seed, shared):
    cfg = _cfg(E, k, 32, 48, shared)
    p = moe_init(jax.random.PRNGKey(seed), cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(jax.random.PRNGKey(seed), 1),
                          (T, 32))
    out, aux = moe_apply(p, cfg, x)
    ref = moe_apply_dense(p, cfg, x)
    assert float(aux["moe_drop_frac"]) == 0.0
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_capacity_dropping_degrades_gracefully():
    """With capacity_factor 0+, outputs shrink toward zero but stay finite
    (dropped tokens pass through the residual only)."""
    cfg = _cfg(4, 2, 32, 48).with_overrides(
        moe=MoEConfig(num_experts=4, top_k=2, expert_d_ff=48,
                      capacity_factor=0.25))
    p = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (256, 32))
    out, aux = moe_apply(p, cfg, x)
    assert float(aux["moe_drop_frac"]) > 0.1
    assert np.isfinite(np.asarray(out)).all()
    # dropped rows produce zeros (residual-only), not garbage
    norms = np.linalg.norm(np.asarray(out), axis=-1)
    assert (norms < 1e-6).sum() > 0 or float(aux["moe_drop_frac"]) < 1.0


def test_group_invariance_without_drops():
    """Token grouping must not change results when capacity is ample."""
    cfg = _cfg(4, 2, 32, 48)
    p = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (128, 32))
    a, _ = moe_apply(p, cfg, x, group_size=32)
    b, _ = moe_apply(p, cfg, x, group_size=128)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-5, atol=2e-5)


def test_load_balance_loss_minimized_when_uniform():
    """Switch aux loss is E·Σ f_e·P_e ≥ 1, = 1 at perfect balance."""
    E = 8
    cfg = _cfg(E, 1, 32, 48)
    p = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    # many random tokens: roughly balanced router at init
    x = 0.01 * jax.random.normal(jax.random.PRNGKey(2), (4096, 32))
    _, aux = moe_apply(p, cfg, x)
    assert float(aux["moe_lb_loss"]) >= 1.0 - 1e-3
    assert float(aux["moe_lb_loss"]) < 2.0


def test_sparse_path_matches_dense_and_capacity():
    """The sort/scatter MoE path must match both oracles when dropless."""
    from repro.models.moe import moe_apply_sparse
    cfg = _cfg(6, 2, 32, 48, shared=1)
    p = moe_init(jax.random.PRNGKey(3), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(4), (80, 32))
    dense = moe_apply_dense(p, cfg, x)
    sparse, aux = moe_apply_sparse(p, cfg, x, capacity_factor=8.0)
    assert float(aux["moe_drop_frac"]) == 0.0
    np.testing.assert_allclose(np.asarray(sparse), np.asarray(dense),
                               rtol=2e-4, atol=2e-4)


def test_sparse_path_capacity_dropping():
    from repro.models.moe import moe_apply_sparse
    cfg = _cfg(4, 2, 32, 48)
    p = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (512, 32))
    out, aux = moe_apply_sparse(p, cfg, x, capacity_factor=0.25)
    assert 0.0 < float(aux["moe_drop_frac"]) < 1.0
    assert np.isfinite(np.asarray(out)).all()
