"""ViT vision tower for image-prefill serving.

Images arrive as (B, H, W, C) float arrays; the tower patchifies,
adds a learned position table, runs ``cfg.vision.num_layers``
bidirectional pre-LN attention blocks, and projects to the LM's
evidence embedding dim. The output is shaped exactly like the stub
frontend's precomputed evidence — (B, num_evidence_tokens,
evidence_dim) — so downstream prefill, CAMD scoring, and the serving
engine's page accounting are unchanged: an encoded image IS evidence.

Kept deliberately simple (plain jnp, no flash path): vision encode is a
one-shot submit-time cost amortized by the engine's content-hash
memoization, not a decode-loop hot path.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import dense, dense_init, mlp, mlp_init, rmsnorm, \
    rmsnorm_init

Params = Dict[str, Any]


def _patchify(images, patch: int):
    """(B, H, W, C) -> (B, n_patches, patch*patch*C), row-major grid."""
    B, H, W, C = images.shape
    gh, gw = H // patch, W // patch
    x = images.reshape(B, gh, patch, gw, patch, C)
    x = x.transpose(0, 1, 3, 2, 4, 5).reshape(B, gh * gw, patch * patch * C)
    return x


def vision_init(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    v = cfg.vision
    assert v is not None
    assert v.n_patches == cfg.num_evidence_tokens, (
        f"vision tower yields {v.n_patches} patches but the LM expects "
        f"{cfg.num_evidence_tokens} evidence tokens")
    out_dim = cfg.evidence_dim or cfg.d_model
    keys = jax.random.split(key, 4 + v.num_layers)
    blocks = []
    for i in range(v.num_layers):
        ks = jax.random.split(keys[4 + i], 5)
        blocks.append({
            "ln1": rmsnorm_init(v.d_model, dtype),
            "wq": dense_init(ks[0], v.d_model, v.d_model, dtype),
            "wk": dense_init(ks[1], v.d_model, v.d_model, dtype),
            "wv": dense_init(ks[2], v.d_model, v.d_model, dtype),
            "wo": dense_init(ks[3], v.d_model, v.d_model, dtype),
            "ln2": rmsnorm_init(v.d_model, dtype),
            "mlp": mlp_init(ks[4], v.d_model, v.d_ff, "gelu", dtype),
        })
    return {
        "patch_proj": dense_init(keys[0], v.patch * v.patch * v.channels,
                                 v.d_model, dtype),
        "pos_emb": (jax.random.normal(keys[1], (v.n_patches, v.d_model))
                    * 0.02).astype(dtype),
        "blocks": tuple(blocks),
        "final_norm": rmsnorm_init(v.d_model, dtype),
        "out_proj": dense_init(keys[2], v.d_model, out_dim, dtype),
    }


def _mha(p: Params, num_heads: int, x):
    """Bidirectional multi-head attention (no mask — patches all see
    each other)."""
    B, N, d = x.shape
    hd = d // num_heads
    q = dense(p["wq"], x).reshape(B, N, num_heads, hd)
    k = dense(p["wk"], x).reshape(B, N, num_heads, hd)
    v = dense(p["wv"], x).reshape(B, N, num_heads, hd)
    att = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    att = jax.nn.softmax(att * (hd ** -0.5), axis=-1).astype(x.dtype)
    o = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(B, N, d)
    return dense(p["wo"], o)


def vision_encode(params: Params, cfg: ModelConfig, images) -> jax.Array:
    """(B, H, W, C) float images -> (B, n_patches, evidence_dim)."""
    v = cfg.vision
    x = _patchify(images, v.patch)
    x = dense(params["patch_proj"], x) + params["pos_emb"][None]
    for blk in params["blocks"]:
        x = x + _mha(blk, v.num_heads, rmsnorm(blk["ln1"], x, cfg.norm_eps))
        x = x + mlp(blk["mlp"], rmsnorm(blk["ln2"], x, cfg.norm_eps), "gelu")
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return dense(params["out_proj"], x)
