"""Serving launcher: load (or train) a model and serve requests with CAMD.

    python -m repro.launch.serve --arch qwen3-0.6b --reduced --mode camd \
        --requests 8 --impl paged --page-size 16

``--open-loop`` serves the same requests through the async streaming
front-end as a timed arrival process (``--arrival poisson|bursty`` at
``--arrival-rate`` rps) and prints SLO metrics — TTFT/TPOT percentiles
and goodput at the ``--slo-ms`` TTFT SLO — instead of batch results.
"""
import argparse

import numpy as np

import jax
import jax.numpy as jnp

from repro.config import (CAMDConfig, PagedKVConfig, SamplingConfig,
                          VisionConfig)
from repro.configs import get_config
from repro.models import build_model
from repro.serving import Request, ServeEngine
from repro.training import load_checkpoint


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", "--config", default="qwen3-0.6b",
                    help="arch id ('llava-1.5-7b') or config module name "
                         "('llava_1_5_7b') — both spellings resolve")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--image-tokens", type=int, default=0,
                    help="multimodal serving: encode synthetic images "
                         "through the config's vision tower into N "
                         "image tokens per request (overrides the "
                         "config's evidence-token count; vision configs "
                         "only)")
    ap.add_argument("--image-pool", type=int, default=2,
                    help="distinct images the synthetic requests draw "
                         "from: repeats hit the submit-time feature "
                         "memo and, with --prefix-cache, the image-page "
                         "prefix cache")
    ap.add_argument("--xmodal-rescore", action="store_true",
                    help="rescore finished candidates' S_align through "
                         "the fused xmodal_score kernel (Eq. 8-9) "
                         "instead of the incremental aggregate")
    ap.add_argument("--mode", default="camd",
                    choices=["camd", "best_of_n", "self_consistency",
                             "greedy"])
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8,
                    help="synthetic prompt length in tokens (long prompts "
                         "exercise chunked prefill)")
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--impl", default="xla",
                    choices=["xla", "pallas", "paged", "paged_pallas"])
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--num-pages", type=int, default=0,
                    help="KV pool size; 0 = dense-equivalent worst case")
    ap.add_argument("--kv-dtype", default="auto",
                    choices=["auto", "fp32", "bf16", "int8", "fp8"],
                    help="paged KV pool storage dtype: auto = engine "
                         "param dtype; int8/fp8 store quantized pages "
                         "with per-(page, slot, kv-head) scales, "
                         "dequantized inside the attention kernels "
                         "(fp8 needs a jax build with float8_e4m3fn)")
    ap.add_argument("--macro-steps", type=int, default=8,
                    help="device decode steps per lax.while_loop launch; "
                         "0 = legacy per-token host loop")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative block length: draft up to K-1 "
                         "tokens per slot from the n-gram table and "
                         "verify them in one target forward (0/1 = off; "
                         "requires --macro-steps >= 1 and an "
                         "all-attention decoder)")
    ap.add_argument("--spec-mode", default="coverage",
                    choices=["coverage", "fixed"],
                    help="coverage: per-slot draft length shrinks toward "
                         "1 as the request's posterior coverage deficit "
                         "closes; fixed: always draft spec-k - 1 tokens")
    ap.add_argument("--sched-policy", default="fifo",
                    choices=["fifo", "coverage"],
                    help="traffic policy: fifo (arrival order) or coverage "
                         "(rank pending work by posterior coverage deficit "
                         "+ expected marginal gain, with aging)")
    ap.add_argument("--global-budget", type=int, default=0,
                    help="hard token budget across the whole request "
                         "stream (0 = unlimited)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="cross-request prompt-prefix KV reuse (paged "
                         "impls on all-attention decoders)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked prefill: split long prompts into "
                         "page-aligned chunks of this many tokens and "
                         "interleave them with decode launches (0 = "
                         "whole-prompt prefill; paged all-attention "
                         "decoders only, others degrade gracefully)")
    ap.add_argument("--prefill-chunk-budget", type=int, default=0,
                    help="max chunk tokens prefilled per engine turn "
                         "(0 = one chunk per turn)")
    ap.add_argument("--prefill-shards", type=int, default=0,
                    help="prefill/decode disaggregation: place prompt/"
                         "chunk pages on the first N data shards of the "
                         "page axis; decode shards read them cross-shard "
                         "(0 = prompt pages follow the admitting slot)")
    ap.add_argument("--kv-byte-budget", type=int, default=0,
                    help="resident-KV byte ceiling for the cross-request "
                         "prefix cache: cached-only pages are evicted "
                         "until resident KV bytes (incl. quant scales) "
                         "fall under it (0 = unbounded)")
    ap.add_argument("--serve-dp", type=int, default=0,
                    help="shard the decode batch + KV page pools across "
                         "N data-parallel devices (0 = single device; "
                         "on CPU combine with XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N)")
    ap.add_argument("--mesh", default="",
                    help="explicit serving mesh as 'dp,model' (e.g. "
                         "'4,2' = 4 data shards x 2-way tensor "
                         "parallel); overrides --serve-dp")
    ap.add_argument("--no-bucket-prefill", action="store_true",
                    help="disable length-bucketed batched prefill")
    ap.add_argument("--prefill-bucket-min", type=int, default=16,
                    help="smallest power-of-two prompt bucket")
    ap.add_argument("--open-loop", action="store_true",
                    help="serve through the async streaming front-end "
                         "with timed arrivals instead of a pre-staged "
                         "batch, and report SLO metrics (TTFT/TPOT "
                         "percentiles, goodput); needs --macro-steps >= 1")
    ap.add_argument("--arrival", default="poisson",
                    choices=["poisson", "bursty"],
                    help="open-loop arrival process")
    ap.add_argument("--arrival-rate", type=float, default=8.0,
                    help="open-loop offered load, requests/s")
    ap.add_argument("--slo-ms", type=float, default=500.0,
                    help="TTFT SLO for the goodput metric, milliseconds")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    cfg = cfg.with_overrides(dtype="float32")
    if args.image_tokens:
        if cfg.vision is None:
            raise SystemExit(f"--image-tokens needs a vision config; "
                             f"{cfg.name} has no vision tower")
        v = cfg.vision
        cfg = cfg.with_overrides(
            num_evidence_tokens=args.image_tokens,
            vision=VisionConfig.for_tokens(
                args.image_tokens, patch=v.patch, num_layers=v.num_layers,
                d_model=v.d_model, num_heads=v.num_heads, d_ff=v.d_ff))
    model = build_model(cfg, jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    if args.ckpt:
        params, _ = load_checkpoint(args.ckpt, params)

    mesh = None
    if args.mesh or args.serve_dp > 1:
        from repro.launch.mesh import make_serve_mesh
        if args.mesh:
            dp, mp = (list(map(int, args.mesh.split(","))) + [1])[:2]
        else:
            dp, mp = args.serve_dp, 1
        mesh = make_serve_mesh(dp, model=mp)
        print(f"serving mesh: {dict(mesh.shape)} over "
              f"{len(jax.devices())} {jax.default_backend()} devices")

    eng = ServeEngine(
        model, params, slots=args.slots, cache_len=128,
        sampling=SamplingConfig(max_new_tokens=args.max_new),
        camd=CAMDConfig(),
        mode=args.mode, max_new_tokens=args.max_new, eos_id=1,
        impl=args.impl,
        paged_kv=PagedKVConfig(page_size=args.page_size,
                               num_pages=args.num_pages,
                               kv_dtype=args.kv_dtype,
                               kv_byte_budget=args.kv_byte_budget),
        macro_steps=args.macro_steps,
        bucket_prefill=not args.no_bucket_prefill,
        prefill_bucket_min=args.prefill_bucket_min,
        sched_policy=args.sched_policy,
        global_budget=args.global_budget,
        prefix_cache=args.prefix_cache,
        prefill_chunk=args.prefill_chunk,
        prefill_chunk_budget=args.prefill_chunk_budget,
        prefill_shards=args.prefill_shards,
        mesh=mesh,
        spec_k=args.spec_k,
        spec_mode=args.spec_mode,
        xmodal_rescore=args.xmodal_rescore,
        seed=args.seed)
    rng = np.random.default_rng(args.seed)

    images = []
    if cfg.num_evidence_tokens and cfg.vision is not None:
        v = cfg.vision
        images = [rng.standard_normal(
            (v.image_h, v.image_w, v.channels)).astype(np.float32)
            for _ in range(max(1, args.image_pool))]

    def mk_request(i):
        prompt = rng.integers(2, cfg.vocab_size,
                              size=args.prompt_len).astype(np.int32)
        if images:
            # draw from a small shared pool: repeated images exercise
            # the submit-time feature memo and the image prefix cache
            return Request(uid=i, prompt=prompt,
                           image=images[int(rng.integers(len(images)))])
        ev = None
        if cfg.num_evidence_tokens:
            ev = rng.standard_normal(
                (cfg.num_evidence_tokens, cfg.evidence_dim)).astype(np.float32)
        return Request(uid=i, prompt=prompt, evidence=ev)

    if args.open_loop:
        from repro.serving.traffic import ARRIVALS, run_open_loop
        if args.macro_steps < 1:
            raise SystemExit("--open-loop drives the fused macro-step "
                             "loop; use --macro-steps >= 1")
        reqs = [mk_request(i) for i in range(args.requests)]
        arrivals = ARRIVALS[args.arrival](
            args.arrival_rate, args.requests, seed=args.seed)
        traces, metrics = run_open_loop(eng, reqs, arrivals,
                                        slo_ttft_ms=args.slo_ms)
        for tr in traces:
            print(f"req {tr.uid}: arrival {tr.t_arrival * 1e3:7.1f}ms  "
                  f"ttft {(tr.t_first - tr.t_arrival) * 1e3:7.1f}ms  "
                  f"tokens={tr.n_tokens}")
        print(f"open loop [{args.arrival} @ {args.arrival_rate:.1f} rps]: "
              f"{metrics['completed']} completed over "
              f"{metrics['span_s']:.2f}s")
        print(f"  ttft p50/p99 {metrics['ttft_p50_ms']:.1f}/"
              f"{metrics['ttft_p99_ms']:.1f} ms   "
              f"tpot p50/p99 {metrics['tpot_p50_ms']:.1f}/"
              f"{metrics['tpot_p99_ms']:.1f} ms")
        print(f"  goodput {metrics['goodput_rps']:.2f} rps at "
              f"{args.slo_ms:.0f}ms TTFT SLO "
              f"({metrics['good_requests']}/{metrics['completed']}), "
              f"{metrics['tokens_per_s']:.1f} tok/s")
        results = []
    else:
        for i in range(args.requests):
            eng.submit(mk_request(i))
        results = eng.run()
    for r in results:
        print(f"req {r.uid}: candidates={r.n_candidates} rounds={r.rounds} "
              f"tokens={r.tokens_spent} p*={r.p_star:.3f} "
              f"early={r.stopped_early} out={r.tokens[:8].tolist()}")
    print(f"engine: {eng.total_steps} steps, {eng.total_tokens} tokens, "
          f"{eng.total_tokens / max(eng.total_steps * eng.B, 1):.2f} "
          f"slot-efficiency")
    print(f"macro-step: K={eng.macro_steps}, {eng.macro_launches} launches, "
          f"{eng.host_syncs} host syncs "
          f"({eng.host_syncs / max(eng.total_tokens, 1):.3f} per token)")
    if eng.spec:
        print(f"speculative: K={eng.spec_k} ({eng.spec_mode}), "
              f"{eng.spec_drafted} drafted, {eng.spec_accepted} accepted "
              f"({eng.spec_accepted / max(eng.spec_drafted, 1):.0%})")
    ss = eng.sched_stats()
    print(f"scheduler: {ss['policy']} admitted={ss['admitted_candidates']} "
          f"spent={ss['spent']}/{ss['global_budget'] or 'inf'} "
          f"declined={ss['declined_rounds']} starved={ss['starved']}")
    if eng.chunked:
        print(f"chunked prefill: chunk={eng.chunk} budget="
              f"{eng.chunk_budget} tok/turn, {ss['chunk_calls']} chunk "
              f"calls over {ss['chunk_tokens']} tokens"
              + (f", prefill shards 0..{eng.prefill_shards - 1} of "
                 f"{eng.dp}" if eng.prefill_shards else ""))
    if eng.paged:
        s = eng.kv_stats()
        print(f"paged kv [{s['kv_dtype']}]: peak {s['max_in_use']}/"
              f"{s['num_pages']} pages "
              f"({s['peak_kv_bytes'] / 1e6:.2f} MB resident at peak vs "
              f"{s['dense_equiv_bytes'] / 1e6:.2f} MB dense-equivalent)")
        if "prefix_cache" in s:
            pc = s["prefix_cache"]
            print(f"prefix cache: {pc['hits']} page hits, "
                  f"{pc['hit_tokens']} prefill tokens skipped, "
                  f"{pc['bytes_saved'] / 1e6:.2f} MB KV writes saved")
        if s.get("kv_byte_budget"):
            print(f"kv byte budget: {s['kv_byte_budget'] / 1e6:.2f} MB "
                  f"ceiling, {s['budget_evictions']} budget evictions")
    if eng.arena is not None:
        a = eng.arena_stats()
        print(f"state arena [{a['state_kind']}]: peak {a['max_in_use']}/"
              f"{a['num_rows']} rows of {a['bytes_per_row'] / 1e3:.1f} kB "
              f"({a['alloc_count']} allocs, {a['sizing_stalls']} stalls)")
    if eng.image_encodes or eng.image_feat_hits:
        print(f"vision frontend: {eng.image_encodes} tower encodes, "
              f"{eng.image_feat_hits} feature-memo hits")


if __name__ == "__main__":
    main()
