"""Oracle tasks for validating the paper's claims without proprietary
benchmarks (DESIGN.md §6.5).

``ChainTask``      — model-in-the-loop: arithmetic-chain VQA-style prompts
                     whose compositional depth controls real per-trial
                     success probability; answers are oracle-checkable.
``SimulatedDecoder`` — pure simulation: instances draw a per-trial success
                     probability s ~ G (heavy / stretched / light tail per
                     Theorem 4.2) and candidates are correct w.p. s. This
                     reproduces the paper's Fig. 2 / Fig. 4 sweeps at scale
                     (thousands of instances) at negligible cost.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.data.synthetic import BOS, OFF, QRY, SEP


@dataclasses.dataclass
class ChainTask:
    """Arithmetic-chain QA with oracle answers."""
    base: int = 32
    max_chain: int = 8

    def sample(self, rng: np.random.Generator, chain_len: Optional[int] = None
               ) -> Tuple[np.ndarray, int, int]:
        """Returns (prompt tokens ending in QRY, answer_token, chain_len).
        chain_len=0 is a pure copy (easy)."""
        k = chain_len if chain_len is not None \
            else int(rng.integers(0, self.max_chain + 1))
        x = int(rng.integers(0, self.base))
        toks = [BOS, OFF + x]
        for _ in range(k):
            a = int(rng.integers(0, self.base))
            toks.append(OFF + self.base + a)
            x = (x + a) % self.base
        toks.append(QRY)
        return np.asarray(toks, np.int32), OFF + x, k

    def check(self, prompt: np.ndarray, generated: np.ndarray) -> bool:
        """Oracle: first generated token must be the chain result."""
        x = int(prompt[1]) - OFF
        for t in prompt[2:-1]:
            x = (x + (int(t) - OFF - self.base)) % self.base
        return len(generated) > 0 and int(generated[0]) == OFF + x


class SimulatedDecoder:
    """Simulates the (MLLM + sampler) pair as seen by CAMD.

    Per instance i: s_i ~ G (tail class configurable). Each trial emits a
    candidate that is correct w.p. s_i; wrong candidates pick one of
    ``n_wrong`` failure modes with Zipf weights (hard instances have
    *consistent* wrong modes — the regime where self-consistency fails and
    evidence-weighted scoring matters). Observable score = evidence quality
    correlated with correctness via ``score_gap``; embeddings cluster by
    emitted answer.
    """

    def __init__(self, *, tail: str = "heavy", alpha: float = 0.5,
                 n_wrong: int = 6, emb_dim: int = 16, score_gap: float = 1.0,
                 score_noise: float = 0.5, tokens_per_sample: int = 64,
                 seed: int = 0):
        self.tail, self.alpha = tail, alpha
        self.n_wrong = n_wrong
        self.emb_dim = emb_dim
        self.score_gap = score_gap
        self.score_noise = score_noise
        self.tokens_per_sample = tokens_per_sample
        self.rng = np.random.default_rng(seed)
        # answer prototypes in embedding space: index 0 = correct answer
        self._proto = self.rng.standard_normal((n_wrong + 1, emb_dim))
        self._proto /= np.linalg.norm(self._proto, axis=-1, keepdims=True)

    def sample_difficulty(self, n: int) -> np.ndarray:
        u = self.rng.uniform(1e-12, 1.0, size=n)
        if self.tail == "heavy":
            return u ** (1.0 / self.alpha)
        if self.tail == "stretched":
            z = np.exp(-1.0)
            return np.clip((-np.log(u * z)) ** -1.0, 0.0, 1.0)
        if self.tail == "light":
            return 0.2 + 0.7 * u
        raise ValueError(self.tail)

    def trial(self, s: float, k: int = 1) -> Dict[str, np.ndarray]:
        """k candidates for an instance of difficulty s."""
        correct = self.rng.random(k) < s
        wrong_mode = 1 + self.rng.zipf(2.0, size=k).clip(1, self.n_wrong) - 1
        answer = np.where(correct, 0, wrong_mode)
        emb = self._proto[answer] + 0.05 * self.rng.standard_normal(
            (k, self.emb_dim))
        score = (self.score_gap * correct.astype(np.float64)
                 + self.score_noise * self.rng.standard_normal(k))
        lengths = np.full(k, self.tokens_per_sample, np.int32)
        return {"correct": correct, "answer": answer, "emb": emb,
                "score": score, "lengths": lengths}
