"""Unified model facade: one API over decoder-only and encoder-decoder
architectures, plus dry-run ``input_specs`` (ShapeDtypeStruct stand-ins,
no allocation).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, ShapeConfig
from repro.models import encdec as encdec_lib
from repro.models import transformer as tf_lib

Params = Dict[str, Any]


def _dtype(cfg: ModelConfig):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[cfg.dtype]


class Model:
    """Stateless functional model: all methods are pure and jit-able."""

    def __init__(self, cfg: ModelConfig, param_dtype=None):
        self.cfg = cfg
        self.param_dtype = param_dtype or _dtype(cfg)

    # -- init ---------------------------------------------------------------
    def init(self, key) -> Params:
        if self.cfg.is_encoder_decoder:
            return encdec_lib.encdec_init(key, self.cfg, self.param_dtype)
        return tf_lib.transformer_init(key, self.cfg, self.param_dtype)

    # -- full-sequence forward (training / scoring) --------------------------
    def forward(self, params: Params, tokens, evidence=None, *,
                impl: str = "xla", remat: bool = False, unroll: bool = False
                ) -> Tuple[jax.Array, jax.Array, Dict]:
        if self.cfg.is_encoder_decoder:
            assert evidence is not None, "enc-dec needs encoder inputs"
            return encdec_lib.encdec_forward(params, self.cfg, tokens, evidence,
                                             impl=impl, remat=remat,
                                             unroll=unroll)
        return tf_lib.transformer_forward(params, self.cfg, tokens, evidence,
                                          impl=impl, remat=remat,
                                          unroll=unroll)

    # -- serving -------------------------------------------------------------
    def make_cache(self, batch: int, cache_len: int, dtype=None):
        dtype = dtype or self.param_dtype
        if self.cfg.is_encoder_decoder:
            src = self.cfg.num_evidence_tokens or 64
            return encdec_lib.encdec_make_cache(self.cfg, batch, cache_len,
                                                dtype, src)
        return tf_lib.make_cache(self.cfg, batch, cache_len, dtype)

    def make_paged_cache(self, batch: int, cache_len: int, dtype=None, *,
                         page_size: int, num_pages: int,
                         kv_dtype: str = "auto"):
        """Decode cache whose full-attention KV is a shared page pool
        (see ``transformer.make_paged_cache``). Decoder-only archs only —
        the enc-dec cross-KV is per-request constant, not paged.
        ``kv_dtype`` selects the pool storage mode (fp32/bf16/int8/fp8)."""
        dtype = dtype or self.param_dtype
        if self.cfg.is_encoder_decoder:
            raise NotImplementedError(
                "paged KV cache is decoder-only for now")
        return tf_lib.make_paged_cache(self.cfg, batch, cache_len, dtype,
                                       page_size, num_pages,
                                       kv_dtype=kv_dtype)

    def prefill(self, params: Params, tokens, cache, evidence=None, *,
                impl: str = "xla", unroll: bool = False, lengths=None):
        """``lengths``: optional (B,) int32 true per-row lengths (counting
        evidence tokens) for length-bucketed batched prefill over
        right-padded rows — see ``transformer_prefill``. Byte-exact for
        all-attention stacks (``supports_bucketed_prefill``); recurrent
        layers (SSM/RG-LRU) mask pads out of their state transition,
        which is allclose- but NOT byte-exact (chunk/scan shapes change
        with the padded length), so the serving engine keeps bucketing
        gated on ``supports_bucketed_prefill``."""
        if self.cfg.is_encoder_decoder:
            assert evidence is not None
            assert lengths is None, "bucketed prefill is decoder-only"
            return encdec_lib.encdec_prefill(params, self.cfg, tokens, cache,
                                             evidence, impl=impl,
                                             unroll=unroll)
        return tf_lib.transformer_prefill(params, self.cfg, tokens, cache,
                                          evidence, impl=impl, unroll=unroll,
                                          lengths=lengths)

    @property
    def state_kind(self) -> str:
        """What a serving slot owns for this architecture:

        - ``"kv"``        — every layer caches attention KV (possibly
          windowed); encoder-decoder stacks are also ``"kv"`` (decoder
          self/cross caches are attention KV).
        - ``"recurrent"`` — every layer carries fixed-size recurrent
          state (SSD state + conv tails, RG-LRU h + conv).
        - ``"hybrid"``    — both (e.g. RG-LRU + local-attention stacks).

        The serving engine dispatches slot-state management on this:
        kv slots may page, recurrent/hybrid slots hold their prompt
        state in the fixed-stride ``StateArena``.
        """
        from repro.config import ATTN, LOCAL_ATTN
        if self.cfg.is_encoder_decoder:
            return "kv"
        kinds = set(self.cfg.layer_kinds)
        attn = bool(kinds & {ATTN, LOCAL_ATTN})
        recurrent = bool(kinds - {ATTN, LOCAL_ATTN})
        if attn and recurrent:
            return "hybrid"
        return "recurrent" if recurrent else "kv"

    @property
    def has_pageable_layers(self) -> bool:
        """True when at least one layer's decode KV can live in the
        shared page pool (full-context full attention, decoder-only —
        the layers ``make_paged_cache`` actually pages)."""
        from repro.config import ATTN
        return (not self.cfg.is_encoder_decoder and
                self.cfg.attn_window == 0 and
                any(k == ATTN for k in self.cfg.layer_kinds))

    def capabilities(self) -> Dict[str, Any]:
        """Structured capability report: what the serving stack may
        enable for this architecture. The config-zoo smoke test asserts
        these flags stay mutually consistent for every shipped config."""
        return {
            "state_kind": self.state_kind,
            "is_encoder_decoder": self.cfg.is_encoder_decoder,
            "has_pageable_layers": self.has_pageable_layers,
            "supports_bucketed_prefill": self.supports_bucketed_prefill,
            "supports_prefix_cache": self.supports_prefix_cache,
            "supports_speculative": self.supports_speculative,
            "has_vision_tower": self.cfg.vision is not None,
            "num_evidence_tokens": self.cfg.num_evidence_tokens,
        }

    def encode_image(self, params: Params, images):
        """Vision-tower encode: images (B, H, W, C) float -> evidence
        embeddings (B, num_evidence_tokens, evidence_dim), ready to
        prefill exactly like precomputed evidence. Requires
        ``cfg.vision``."""
        from repro.models import vision as vision_lib
        if self.cfg.vision is None:
            raise ValueError(f"{self.cfg.name} has no vision tower "
                             "(cfg.vision is None)")
        return vision_lib.vision_encode(params["vision"], self.cfg, images)

    @property
    def supports_bucketed_prefill(self) -> bool:
        """Right-padded bucketed prefill is exact only when every layer is
        attention (causal masking makes pads invisible to real positions);
        recurrent layers (SSM/RG-LRU) fold pads into their state."""
        from repro.config import ATTN, LOCAL_ATTN
        return (not self.cfg.is_encoder_decoder and
                all(k in (ATTN, LOCAL_ATTN) for k in self.cfg.layer_kinds))

    def prefill_suffix(self, params: Params, tokens, cache, ctx_kv, start,
                       *, impl: str = "xla"):
        """Continuation prefill for cross-request prefix-cache hits: run
        only the suffix ``tokens`` (absolute positions start..), attending
        to ``ctx_kv`` — the cached pages' K/V for positions [0, start).
        Requires ``supports_prefix_cache``."""
        return tf_lib.transformer_prefill_suffix(params, self.cfg, tokens,
                                                 cache, ctx_kv, start,
                                                 impl=impl)

    def prefill_chunked(self, params: Params, tokens, cache, chunk: int,
                        *, impl: str = "xla"):
        """Reference fixed-size chunked prefill: process the prompt in
        ``chunk``-token pieces through the suffix path, byte-identical
        to whole-prompt ``prefill``. Falls back to whole prefill when
        ``chunk`` is 0 or covers the prompt. Requires
        ``supports_prefix_cache`` (unless falling back). The serving
        engine runs its own paged version of this loop — this entry
        pins the chunking math without an engine in the loop."""
        return tf_lib.transformer_prefill_chunked(params, self.cfg, tokens,
                                                  cache, chunk, impl=impl)

    @property
    def supports_prefix_cache(self) -> bool:
        """Cross-request prompt-prefix KV reuse needs every layer's
        prompt state to live in the shared KV pages: all-attention,
        full-context (no windows — windowed rings are dense per-slot
        state), decoder-only."""
        from repro.config import ATTN
        return (not self.cfg.is_encoder_decoder and
                self.cfg.attn_window == 0 and
                all(k == ATTN for k in self.cfg.layer_kinds))

    def decode_step(self, params: Params, token, cache, *, impl: str = "xla",
                    unroll: bool = False):
        if self.cfg.is_encoder_decoder:
            return encdec_lib.encdec_decode(params, self.cfg, token, cache,
                                            impl=impl, unroll=unroll)
        return tf_lib.transformer_decode(params, self.cfg, token, cache,
                                         impl=impl, unroll=unroll)

    def decode_block(self, params: Params, tokens, cache, valid=None, *,
                     impl: str = "xla"):
        """Speculative block verification: feed S tokens per row at
        positions ``cache["pos"] + [0..S)`` and return per-position
        next-token (logits (B,S,V), hidden (B,S,d), cache) WITHOUT
        advancing ``cache["pos"]`` — the caller commits the accepted
        prefix. Requires ``supports_speculative``."""
        return tf_lib.transformer_decode_block(params, self.cfg, tokens,
                                               cache, valid, impl=impl)

    @property
    def supports_speculative(self) -> bool:
        """Speculative block verification rewinds rejected positions by
        not committing them — only stateless-per-position KV layers can
        do that (recurrent state can't be partially rolled back, and
        windowed rings shorter than a block could alias inside it), so
        the predicate matches the prefix cache: all-attention,
        full-context, decoder-only."""
        return self.supports_prefix_cache

    # -- dry-run specs ---------------------------------------------------------
    def input_specs(self, shape: ShapeConfig) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for every model input of a step.

        train/prefill: {tokens, (evidence), (labels)}.
        decode: {token, cache} — one new token against a seq_len-deep cache.
        """
        cfg = self.cfg
        B, L = shape.global_batch, shape.seq_len
        tok = jnp.int32
        specs: Dict[str, Any] = {}
        ne = cfg.num_evidence_tokens
        if shape.mode in ("train", "prefill"):
            text_len = L - ne if (ne and not cfg.is_encoder_decoder) else L
            specs["tokens"] = jax.ShapeDtypeStruct((B, text_len), tok)
            if ne:
                specs["evidence"] = jax.ShapeDtypeStruct(
                    (B, ne, cfg.evidence_dim or cfg.d_model), jnp.bfloat16)
            if shape.mode == "train":
                specs["labels"] = jax.ShapeDtypeStruct((B, text_len), tok)
        else:  # decode
            specs["token"] = jax.ShapeDtypeStruct((B,), tok)
            cache = jax.eval_shape(
                lambda: self.make_cache(B, self.cache_len(L), _dtype(cfg)))
            specs["cache"] = cache
        return specs

    def cache_len(self, seq_len: int) -> int:
        """Decode cache depth for a nominal context of ``seq_len``.

        Full-attention archs hold the whole context; windowed/SSM archs are
        sub-quadratic and their per-layer caches are bounded by the
        window/state size (handled inside make_cache) — the nominal length
        still sizes full-attention layers' caches.
        """
        cfg = self.cfg
        if cfg.attn_window > 0:
            return min(seq_len, cfg.attn_window)
        return seq_len


def build_model(cfg: ModelConfig, param_dtype=None) -> Model:
    return Model(cfg, param_dtype)


def init_params(cfg: ModelConfig, seed: int = 0, param_dtype=None) -> Params:
    return Model(cfg, param_dtype).init(jax.random.PRNGKey(seed))
