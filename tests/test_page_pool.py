"""PagePool invariants: alloc/free conservation, refcounted sharing
(the CoW prompt-page mechanism), and misuse detection."""
import pytest

from repro.serving.page_pool import PagePool, PagePoolError


def test_alloc_free_conservation():
    pool = PagePool(17, 16)
    a = pool.alloc(5)
    b = pool.alloc(3)
    assert len(set(a) | set(b)) == 8          # all distinct
    assert 0 not in a + b                      # quarantine never handed out
    assert pool.in_use == 8 and pool.free_pages == 8
    pool.check()
    pool.free(a)
    assert pool.in_use == 3 and pool.free_pages == 13
    pool.check()
    pool.free(b)
    assert pool.in_use == 0 and pool.free_pages == 16
    pool.check()


def test_freed_pages_are_reusable():
    pool = PagePool(5, 16)                     # 4 allocatable
    a = pool.alloc(4)
    with pytest.raises(PagePoolError):
        pool.alloc(1)                          # exhausted
    pool.free(a[:2])
    assert sorted(pool.alloc(2)) == sorted(a[:2])
    pool.check()


def test_share_refcounts():
    """Prompt pages shared across R candidates survive R-1 frees — the
    conservation CoW relies on."""
    pool = PagePool(10, 16)
    prompt = pool.alloc(2)                     # request hold
    for _ in range(3):                         # 3 candidates share
        pool.share(prompt)
    assert all(pool.refcount(p) == 4 for p in prompt)
    for _ in range(3):
        pool.free(prompt)                      # candidates finish
    assert pool.in_use == 2                    # request hold keeps them live
    pool.check()
    pool.free(prompt)                          # request done
    assert pool.in_use == 0
    pool.check()


def test_double_free_raises():
    pool = PagePool(10, 16)
    a = pool.alloc(1)
    pool.free(a)
    with pytest.raises(PagePoolError):
        pool.free(a)
    pool.check()


def test_share_unallocated_raises():
    pool = PagePool(10, 16)
    with pytest.raises(PagePoolError):
        pool.share([3])


def test_free_reserved_raises():
    pool = PagePool(10, 16)
    with pytest.raises(PagePoolError):
        pool.free([0])


def test_max_in_use_high_water():
    pool = PagePool(10, 16)
    a = pool.alloc(6)
    pool.free(a)
    pool.alloc(2)
    assert pool.max_in_use == 6
    assert pool.live_tokens_capacity() == 2 * 16
