"""Quantized paged serving: storage-mode equivalences, memory accounting,
and stream invariants the kv_dtype plumbing must preserve.

- kv_dtype="fp32" on an fp32 engine is BYTE-IDENTICAL to "auto" (the
  historical pool) — zero-tolerance modes change nothing;
- int8 serving is macro-step- and speculation-invariant (the same
  quantized pool state deterministically feeds every partitioning);
- kv_stats() reports true resident bytes (values + scales) and int8
  lands under the 0.55x-of-fp32 gate the regression harness enforces;
- misuse fails fast (quantized dense engine, unknown names, fp8 without
  hardware dtype support).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import _mk_engine, _submit
from repro.config import PagedKVConfig
from repro.models.attention import FP8_DTYPE


def _run(model, params, cfg, *, kv_dtype, n=3, **kw):
    kw.setdefault("impl", "paged")
    eng = _mk_engine(model, params,
                     paged_kv=PagedKVConfig(page_size=16, kv_dtype=kv_dtype),
                     **kw)
    _submit(eng, cfg, n)
    res = sorted(eng.run(), key=lambda r: r.uid)
    return eng, res


def _tokens(res):
    return [np.asarray(r.tokens) for r in res]


def test_fp32_mode_byte_identical_to_auto(small_model):
    """On an fp32 engine, "fp32" and "auto" resolve to the same storage —
    the entire serve trace must be byte-identical."""
    cfg, model, params = small_model
    _, auto = _run(model, params, cfg, kv_dtype="auto")
    _, fp32 = _run(model, params, cfg, kv_dtype="fp32")
    for a, b in zip(auto, fp32):
        np.testing.assert_array_equal(a.tokens, b.tokens)
        assert (a.tokens_spent, a.rounds, a.n_candidates) == \
            (b.tokens_spent, b.rounds, b.n_candidates)


def test_int8_macro_step_invariant(small_model):
    """Sampled streams must not depend on macro-step partitioning under
    quantized storage: K=1, K=4, K=16 all decode the same tokens from
    the same int8 pool (the repo-wide fused-loop invariance, which must
    survive quantize-on-write inside the loop body)."""
    cfg, model, params = small_model
    outs = [_tokens(_run(model, params, cfg, kv_dtype="int8",
                         macro_steps=k)[1]) for k in (1, 4, 16)]
    for other in outs[1:]:
        for a, b in zip(outs[0], other):
            np.testing.assert_array_equal(a, b)


def test_int8_end_to_end_completes_and_accounts(small_model):
    cfg, model, params = small_model
    eng, res = _run(model, params, cfg, kv_dtype="int8", mode="greedy")
    assert len(res) == 3 and all(len(r.tokens) for r in res)
    eng.pool.check()
    assert eng.pool.in_use == 0
    s = eng.kv_stats()
    assert s["kv_dtype"] == "int8"
    # scale leaves exist on-device
    e = eng.state.cache["super"][0]
    assert "k_scale" in e and e["k_pages"].dtype == jnp.int8


def test_int8_resident_bytes_under_gate(small_model):
    """The reason to quantize: true resident KV bytes (values + scale
    tensors) at identical config must be <= 0.55x fp32 — the same bound
    check_regression enforces on the benchmark report."""
    cfg, model, params = small_model
    bpp = {}
    for kvd in ("fp32", "int8"):
        eng, _ = _run(model, params, cfg, kv_dtype=kvd, mode="greedy", n=1)
        bpp[kvd] = eng.kv_stats()["bytes_per_page"]
    ratio = bpp["int8"] / bpp["fp32"]
    # hd=64: int8 is (64 + 4 scale bytes) vs 256 fp32 bytes per token-head
    assert ratio <= 0.55, f"int8/fp32 bytes ratio {ratio:.3f}"
    np.testing.assert_allclose(ratio, (64 + 4) / 256, rtol=1e-6)


def test_int8_speculative_invariant(small_model):
    """Speculative drafting only ever commits verifier-approved tokens,
    so spec on/off must emit identical streams — including when the
    verifier reads a quantized pool."""
    cfg, model, params = small_model
    base = _tokens(_run(model, params, cfg, kv_dtype="int8", mode="greedy",
                        macro_steps=4)[1])
    spec = _tokens(_run(model, params, cfg, kv_dtype="int8", mode="greedy",
                        macro_steps=4, spec_k=3)[1])
    for a, b in zip(base, spec):
        np.testing.assert_array_equal(a, b)


def test_int8_prefix_cache_serves(small_model):
    """Prefix-cache hits under int8: cached quantized pages are shared
    and the suffix prefill dequantizes them for context attention."""
    cfg, model, params = small_model
    eng = _mk_engine(model, params, impl="paged",
                     paged_kv=PagedKVConfig(page_size=16, kv_dtype="int8"),
                     prefix_cache=True, mode="greedy")
    rng = np.random.default_rng(0)
    prompt = rng.integers(2, cfg.vocab_size, 40).astype(np.int32)
    from repro.serving import Request
    eng.submit(Request(uid=0, prompt=prompt))
    eng.submit(Request(uid=1, prompt=prompt.copy()))   # full-prefix repeat
    res = sorted(eng.run(), key=lambda r: r.uid)
    assert len(res) == 2
    pc = eng.kv_stats()["prefix_cache"]
    assert pc["hits"] > 0 and pc["hit_tokens"] > 0
    # same prompt + greedy -> same continuation through the shared pages
    np.testing.assert_array_equal(res[0].tokens, res[1].tokens)


def test_bf16_mode_byte_identical_on_bf16_engine():
    """The other tolerance-0 mode: on a bf16 engine, kv_dtype="bf16"
    resolves to the same storage as "auto" — byte-identical streams."""
    from repro.config import ModelConfig
    from repro.models import build_model
    cfg = ModelConfig(name="tiny-bf16", family="dense", num_layers=2,
                      d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                      vocab_size=64, head_dim=16, tie_embeddings=True,
                      dtype="bfloat16")
    model = build_model(cfg, jnp.bfloat16)
    params = model.init(jax.random.PRNGKey(0))
    outs = {}
    for kvd in ("auto", "bf16"):
        eng, res = _run(model, params, cfg, kv_dtype=kvd, n=2)
        assert eng.state.cache["super"][0]["k_pages"].dtype == jnp.bfloat16
        outs[kvd] = _tokens(res)
    for a, b in zip(outs["auto"], outs["bf16"]):
        np.testing.assert_array_equal(a, b)


def test_quantized_requires_paged():
    from repro.config import ModelConfig
    from repro.models import build_model
    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=64,
                      head_dim=16, tie_embeddings=True, dtype="float32")
    model = build_model(cfg, jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    with pytest.raises(AssertionError, match="paged"):
        _mk_engine(model, params, impl="xla",
                   paged_kv=PagedKVConfig(kv_dtype="int8"))
    with pytest.raises(ValueError, match="kv_dtype"):
        _mk_engine(model, params, impl="paged",
                   paged_kv=PagedKVConfig(kv_dtype="int4"))
    if FP8_DTYPE is None:
        with pytest.raises(ValueError, match="fp8"):
            _mk_engine(model, params, impl="paged",
                       paged_kv=PagedKVConfig(kv_dtype="fp8"))
