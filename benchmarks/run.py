"""Benchmark orchestrator — one entry per paper table/figure.

Prints a ``name,us_per_call,derived`` CSV line per benchmark (suite-level
timing + the headline derived metric), then the detailed per-benchmark
output above it.

  python -m benchmarks.run            # all
  python -m benchmarks.run fig2 fig4  # subset
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    import benchmarks.bench_ablation as ablation
    import benchmarks.bench_fig2 as fig2
    import benchmarks.bench_hallucination as halluc
    import benchmarks.bench_fig4 as fig4
    import benchmarks.bench_kernels as kernels
    import benchmarks.bench_serve as serve
    import benchmarks.bench_table1 as table1
    import benchmarks.bench_theory as theory
    import benchmarks.roofline as roofline

    suites = {
        "theory": (theory.run, lambda r: "thm4.2-verified"),
        "fig2": (fig2.run, lambda r: f"pareto={r['claims']['pareto']}"),
        "table1": (table1.run,
                   lambda r: f"+{r['claims']['avg_gain_vs_greedy']*100:.1f}pts_vs_greedy"),
        "fig4": (fig4.run, lambda r: f"engine_pareto={r['claims']['engine_pareto']}"),
        "ablation": (ablation.run,
                     lambda r: f"best_lambda={r['best']}"),
        "hallucination": (halluc.run,
                          lambda r: f"-{r['reduction_pts']:.1f}pts_halluc"),
        "kernels": (kernels.run, lambda r: f"{len(r)}kernels"),
        "serve": (serve.run,
                  lambda r: "max_speedup={:.2f}x".format(
                      max(s["speedup"] for s in r["speedups"].values()))),
        "roofline": (roofline.run,
                     lambda r: f"{r.get('summary', {}).get('fits', 0)}/{r.get('summary', {}).get('n', 0)}fit16GB"
                     if r.get("summary") else "no-dryrun-data"),
    }
    want = sys.argv[1:] or list(suites)
    csv = []
    for name in want:
        fn, derive = suites[name]
        print(f"=== {name} ===")
        t0 = time.perf_counter()
        result = fn()
        us = (time.perf_counter() - t0) * 1e6
        csv.append(f"{name},{us:.0f},{derive(result)}")
    print("\nname,us_per_call,derived")
    for line in csv:
        print(line)


if __name__ == "__main__":
    main()
