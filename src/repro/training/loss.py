"""Cross-entropy loss with z-loss and MoE auxiliary terms."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cross_entropy(logits, labels, mask=None, z_loss_coef: float = 1e-4):
    """logits: (B, L, V), labels: (B, L). Returns (loss, metrics).

    The label logit is extracted with an iota-compare-select reduction
    (not take_along_axis): it fuses into the reduce loop and — crucially —
    stays partitionable when the vocab dim is model-sharded (a gather
    would force GSPMD to all-gather the full logits).
    """
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          logits.ndim - 1)
    ll = jnp.sum(jnp.where(vocab_iota == labels[..., None], logits, 0.0),
                 axis=-1)
    nll = lse - ll
    z = z_loss_coef * jnp.square(lse)
    if mask is None:
        mask = jnp.ones_like(nll)
    m = mask.astype(jnp.float32)
    n = jnp.maximum(jnp.sum(m), 1.0)
    loss = jnp.sum((nll + z) * m) / n
    acc = jnp.sum((jnp.argmax(logits, -1) == labels) * m) / n
    return loss, {"nll": jnp.sum(nll * m) / n, "accuracy": acc,
                  "perplexity": jnp.exp(jnp.clip(jnp.sum(nll * m) / n, 0, 20))}


def total_loss(logits, labels, aux, mask=None, moe_aux_weight: float = 0.01,
               moe_z_weight: float = 1e-3):
    loss, metrics = cross_entropy(logits, labels, mask)
    if "moe_lb_loss" in aux:
        loss = loss + moe_aux_weight * aux["moe_lb_loss"] \
            + moe_z_weight * aux["moe_z_loss"]
        metrics["moe_lb_loss"] = aux["moe_lb_loss"]
        metrics["moe_drop_frac"] = aux.get("moe_drop_frac", 0.0)
    metrics["loss"] = loss
    return loss, metrics
