import os

# Tests run on the single real CPU device (the dry-run alone forces 512
# placeholder devices). Cap compilation parallelism noise.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
