"""Length-bucketed batched prefill: equivalence with the per-request
(unbucketed) path at the model level and end-to-end, plus the
architecture gating that keeps right-padding sound.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import _mk_engine as _mk_base
from repro.config import LOCAL_ATTN, RGLRU, ModelConfig, \
    RGLRUConfig, SamplingConfig
from repro.models import build_model
from repro.models.transformer import transformer_prefill
from repro.serving import Request


@pytest.fixture(scope="module")
def tiny_vlm():
    cfg = ModelConfig(
        name="bucket-vlm", family="vlm", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=64,
        head_dim=16, tie_embeddings=True, dtype="float32",
        num_evidence_tokens=4, evidence_dim=16)
    model = build_model(cfg, jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_padded_batched_prefill_matches_per_row(tiny_model):
    """Right-padded rows with true ``lengths`` must reproduce each row's
    unbucketed last-token logits/hidden and per-row cache pos."""
    cfg, model, params = tiny_model
    rng = np.random.default_rng(0)
    lens = [3, 5, 8, 8]
    Lb = 8
    toks = np.zeros((len(lens), Lb), np.int32)
    prompts = []
    for i, L in enumerate(lens):
        p = rng.integers(2, cfg.vocab_size, L).astype(np.int32)
        prompts.append(p)
        toks[i, :L] = p
    cache = model.make_cache(len(lens), 32, jnp.float32)
    lg_b, h_b, cache_b = transformer_prefill(
        params, cfg, jnp.asarray(toks), cache,
        lengths=jnp.asarray(lens, jnp.int32))
    assert np.asarray(cache_b["pos"]).tolist() == lens
    for i, p in enumerate(prompts):
        row = model.make_cache(1, 32, jnp.float32)
        lg_1, h_1, row = transformer_prefill(params, cfg, jnp.asarray(p)[None],
                                             row)
        np.testing.assert_allclose(np.asarray(lg_b[i]), np.asarray(lg_1[0]),
                                   rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(np.asarray(h_b[i]), np.asarray(h_1[0]),
                                   rtol=2e-5, atol=2e-5)
        assert int(jnp.argmax(lg_b[i])) == int(jnp.argmax(lg_1[0]))
        # prompt-span KV must match; the padded tail beyond pos is free
        for e_b, e_1 in zip(cache_b["super"], row["super"]):
            np.testing.assert_allclose(
                np.asarray(e_b["k"][:, i, :len(p)]),
                np.asarray(e_1["k"][:, 0, :len(p)]), rtol=2e-5, atol=2e-5)


def test_padded_prefill_with_evidence(tiny_vlm):
    """Evidence tokens prepend to every row; ``lengths`` count them."""
    cfg, model, params = tiny_vlm
    rng = np.random.default_rng(1)
    ne = cfg.num_evidence_tokens
    lens = [4, 7]
    Lb = 8
    toks = np.zeros((2, Lb), np.int32)
    evs = rng.standard_normal((2, ne, cfg.evidence_dim)).astype(np.float32)
    prompts = []
    for i, L in enumerate(lens):
        p = rng.integers(2, cfg.vocab_size, L).astype(np.int32)
        prompts.append(p)
        toks[i, :L] = p
    cache = model.make_cache(2, 32, jnp.float32)
    lg_b, h_b, cache_b = transformer_prefill(
        params, cfg, jnp.asarray(toks), cache, jnp.asarray(evs),
        lengths=jnp.asarray([L + ne for L in lens], jnp.int32))
    assert np.asarray(cache_b["pos"]).tolist() == [L + ne for L in lens]
    for i, p in enumerate(prompts):
        row = model.make_cache(1, 32, jnp.float32)
        lg_1, _, _ = transformer_prefill(params, cfg, jnp.asarray(p)[None],
                                         row, jnp.asarray(evs[i:i + 1]))
        np.testing.assert_allclose(np.asarray(lg_b[i]), np.asarray(lg_1[0]),
                                   rtol=2e-5, atol=2e-5)


def _mk_engine(model, params, **kw):
    defaults = dict(slots=4, cache_len=32, max_new=6, n_candidates=2,
                    prefill_bucket_min=8)
    defaults.update(kw)
    return _mk_base(model, params, **defaults)


def test_engine_bucketed_equals_unbucketed_greedy(tiny_model):
    """Greedy end-to-end with mixed prompt lengths: bucketed prefill must
    emit exactly the tokens the per-request path emits (argmax is robust
    to the padded batch's fp noise)."""
    cfg, model, params = tiny_model
    rng = np.random.default_rng(2)
    prompts = [rng.integers(2, cfg.vocab_size, L).astype(np.int32)
               for L in (3, 5, 9, 12)]
    outs = {}
    for bucket in (True, False):
        eng = _mk_engine(model, params, mode="greedy", bucket_prefill=bucket)
        for i, p in enumerate(prompts):
            eng.submit(Request(uid=i, prompt=p))
        outs[bucket] = [r.tokens.tolist()
                        for r in sorted(eng.run(), key=lambda r: r.uid)]
    assert outs[True] == outs[False]


def test_engine_bucketed_sampled_modes_complete(tiny_model):
    """Sampled modes across mixed lengths: identical accounting
    invariants with bucketing on (streams may differ from unbucketed only
    through fp noise in prefill logits, so we pin bookkeeping)."""
    cfg, model, params = tiny_model
    rng = np.random.default_rng(3)
    eng = _mk_engine(model, params, mode="camd", macro_steps=16)
    for i, L in enumerate((3, 6, 11, 4, 9)):
        eng.submit(Request(
            uid=i, prompt=rng.integers(2, cfg.vocab_size, L).astype(np.int32)))
    res = eng.run()
    assert sorted(r.uid for r in res) == list(range(5))
    for r in res:
        assert r.tokens_spent == sum(c["n"] for c in r.candidates)


def test_bucket_gating_recurrent_arch():
    """Architectures with recurrent layers must refuse bucketed prefill
    (pads would contaminate SSM/RG-LRU state) and still serve correctly
    through the per-request path."""
    cfg = ModelConfig(
        name="bucket-rglru", family="hybrid", num_layers=3, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=64,
        head_dim=16, tie_embeddings=True, dtype="float32",
        block_pattern=(RGLRU, RGLRU, LOCAL_ATTN), local_window=16,
        rglru=RGLRUConfig(lru_width=64))
    model = build_model(cfg, jnp.float32)
    assert not model.supports_bucketed_prefill
    params = model.init(jax.random.PRNGKey(0))
    eng = _mk_engine(model, params, mode="greedy", bucket_prefill=True)
    assert eng.bucket_prefill is False          # gated off by architecture
    rng = np.random.default_rng(4)
    for i, L in enumerate((3, 7)):
        eng.submit(Request(
            uid=i, prompt=rng.integers(2, cfg.vocab_size, L).astype(np.int32)))
    res = eng.run()
    assert len(res) == 2


def test_oversized_bucket_falls_back(tiny_model):
    """A bucket longer than the attention ring would wrap during seeding;
    such groups take the exact per-request path but still complete."""
    cfg, model, params = tiny_model
    eng = _mk_engine(model, params, mode="greedy", cache_len=24,
                     max_new_tokens=4,
                     sampling=SamplingConfig(max_new_tokens=4,
                                             temperature=0.8))
    rng = np.random.default_rng(5)
    # prompt 17 buckets to 32 > ring (cache_len 24) → per-request path
    eng.submit(Request(uid=0, prompt=rng.integers(
        2, cfg.vocab_size, 17).astype(np.int32)))
    res = eng.run()
    assert len(res) == 1 and len(res[0].tokens) >= 1
