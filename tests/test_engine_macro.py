"""Macro-step fused decode loop: bit-exact parity with the per-token
schedule across every mode × impl, host-sync amortization, page-frontier
conservation (incl. early EOS), and the batched-admission /
self-consistency regressions that rode along with the refactor.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import _mk_engine as _mk_base, _submit as _submit_base
from repro.config import CAMDConfig, PagedKVConfig, SamplingConfig
from repro.sampling.samplers import sample_token, sample_token_batch

MODES = ["camd", "best_of_n", "self_consistency", "greedy"]
IMPLS = ["xla", "pallas", "paged", "paged_pallas"]
PAGE = PagedKVConfig(page_size=8)


def _mk_engine(model, params, **kw):
    defaults = dict(slots=4, cache_len=32, max_new=6, n_candidates=3,
                    paged_kv=PAGE)
    defaults.update(kw)
    return _mk_base(model, params, **defaults)


def _submit(engine, cfg, n, seed=0, plen=5):
    _submit_base(engine, cfg, n, seed=seed, plen=plen)


def _run(model, params, cfg, *, mode, impl, macro_steps, n=2):
    eng = _mk_engine(model, params, mode=mode, impl=impl,
                     macro_steps=macro_steps)
    _submit(eng, cfg, n)
    res = sorted(eng.run(), key=lambda r: r.uid)
    if eng.paged:
        eng.pool.check()
        assert eng.pool.in_use == 0
        assert eng._reserved == 0
    return eng, res


@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("mode", MODES)
def test_macro_step_count_invariance(tiny_model, mode, impl):
    """Acceptance bar: decoded tokens are bit-identical between
    macro_steps=1 and macro_steps=32 under a fixed seed, for every
    mode × impl — the device loop partitions the step schedule without
    changing it (fold-in keys + early exit at the same boundaries)."""
    cfg, model, params = tiny_model
    _, res1 = _run(model, params, cfg, mode=mode, impl=impl, macro_steps=1)
    _, res32 = _run(model, params, cfg, mode=mode, impl=impl, macro_steps=32)
    assert len(res1) == len(res32) == 2
    for a, b in zip(res1, res32):
        np.testing.assert_array_equal(a.tokens, b.tokens)
        assert a.tokens_spent == b.tokens_spent
        assert a.rounds == b.rounds
        assert a.n_candidates == b.n_candidates
        for ca, cb in zip(a.candidates, b.candidates):
            assert ca["tokens"].tolist() == cb["tokens"].tolist()


def test_macro_equals_paged_equals_dense(tiny_model):
    """Cross-impl and cross-K at once: the paged engine inside the fused
    loop still emits byte-identical tokens to the dense engine."""
    cfg, model, params = tiny_model
    outs = {}
    for impl in ("xla", "paged"):
        for K in (1, 16):
            _, res = _run(model, params, cfg, mode="camd", impl=impl,
                          macro_steps=K, n=3)
            outs[(impl, K)] = [r.tokens.tolist() for r in res]
    base = outs[("xla", 1)]
    for key, val in outs.items():
        assert val == base, key


def test_host_syncs_amortized(tiny_model):
    """Acceptance bar: with macro_steps=32 the engine performs ≤ 1/16
    host synchronizations per generated token (the per-token loop does
    ≥ 1). eos_id=-1 keeps candidates full-length so the denominator is
    deterministic."""
    cfg, model, params = tiny_model
    eng = _mk_engine(model, params, mode="camd", macro_steps=32,
                     slots=4, cache_len=64, max_new_tokens=48, eos_id=-1,
                     sampling=SamplingConfig(max_new_tokens=48,
                                             temperature=0.8),
                     camd=CAMDConfig(samples_per_round=2, max_rounds=2,
                                     min_samples=2, max_clusters=8))
    _submit(eng, cfg, 2)
    eng.run()
    assert eng.total_tokens > 0
    assert eng.host_syncs * 16 <= eng.total_tokens, \
        (eng.host_syncs, eng.total_tokens)
    # the legacy loop on the same workload syncs at least once per step
    leg = _mk_engine(model, params, mode="camd", macro_steps=0,
                     slots=4, cache_len=64, max_new_tokens=48, eos_id=-1,
                     sampling=SamplingConfig(max_new_tokens=48,
                                             temperature=0.8),
                     camd=CAMDConfig(samples_per_round=2, max_rounds=2,
                                     min_samples=2, max_clusters=8))
    _submit(leg, cfg, 2)
    leg.run()
    assert leg.host_syncs >= leg.total_steps
    assert eng.host_syncs < leg.host_syncs / 4


def test_frontier_conservation_under_early_eos(tiny_model):
    """Pre-staged frontier pages that the device never consumed (slots
    finishing early on EOS) must flow back: staged == consumed + returned
    and the pool drains to zero."""
    cfg, model, params = tiny_model
    kw = dict(mode="camd", impl="paged", macro_steps=32, cache_len=64,
              max_new_tokens=24, paged_kv=PAGE,
              sampling=SamplingConfig(max_new_tokens=24, temperature=0.8))
    ref = _mk_engine(model, params, eos_id=-1, **kw)
    _submit(ref, cfg, 2)
    res = ref.run()
    # pick a token the run actually emits mid-candidate; rerunning with it
    # as EOS forces early finishes at the same (seed-identical) stream
    tok = int(res[0].candidates[0]["tokens"][1])
    eng = _mk_engine(model, params, eos_id=tok, **kw)
    _submit(eng, cfg, 2)
    res2 = eng.run()
    assert any(len(c["tokens"]) < 24 for r in res2 for c in r.candidates), \
        "expected at least one early-EOS candidate"
    eng.pool.check()
    assert eng.pool.in_use == 0
    assert eng._reserved == 0
    s = eng.pool.stats()
    assert s["frontier_staged"] >= s["frontier_returned"] >= 0
    assert eng.total_tokens < ref.total_tokens     # EOS actually cut work


def test_macro_zero_matches_macro_on_accounting(tiny_model):
    """Legacy (macro_steps=0) and fused engines run the same workload to
    completion with identical token accounting invariants (streams differ
    — the legacy loop predates fold-in keys — but bookkeeping must not)."""
    cfg, model, params = tiny_model
    for K in (0, 8):
        eng = _mk_engine(model, params, mode="best_of_n", macro_steps=K)
        _submit(eng, cfg, 3)
        res = eng.run()
        assert sorted(r.uid for r in res) == [0, 1, 2]
        for r in res:
            assert r.n_candidates == 3
            assert r.tokens_spent == sum(c["n"] for c in r.candidates)
        assert eng.total_tokens == sum(r.tokens_spent for r in res)
        assert all(eng._slot_req[s] == -1 for s in range(eng.B))


# ---------------------------------------------------------------------------
# satellite regressions
# ---------------------------------------------------------------------------

def test_self_consistency_picks_majority_cluster_best(tiny_model):
    """Regression for the dead `best_k` in `_result`: the winner must be
    the best-scoring member of the LARGEST cluster, not the globally
    best-scoring candidate."""
    cfg, model, params = tiny_model
    eng = _mk_engine(model, params, mode="self_consistency")
    from repro.core import controller as ctrl
    cs = ctrl.init_state(eng.camd, eng.d, eng.V)
    cs = cs._replace(
        table=cs.table._replace(
            sizes=cs.table.sizes.at[0].set(1.0).at[1].set(2.0),
            n_clusters=jnp.int32(2)),
        best_uid=jnp.int32(0), best_score=jnp.float32(5.0))
    recs = {
        0: {"uid": 0, "tokens": np.array([10]), "n": 1, "score": 5.0,
            "cluster": 0},                      # global best, minority
        1: {"uid": 1, "tokens": np.array([11]), "n": 1, "score": 1.0,
            "cluster": 1},
        2: {"uid": 2, "tokens": np.array([12]), "n": 1, "score": 2.0,
            "cluster": 1},                      # best of majority cluster
    }
    eng._reqs[99] = {"camd": cs, "records": recs, "round": 1}
    res = eng._result(99)
    assert res.tokens.tolist() == [12]


def test_self_consistency_end_to_end_majority(tiny_model):
    """End-to-end: the chosen answer is a member of the majority cluster
    whenever cluster bookkeeping is populated."""
    cfg, model, params = tiny_model
    eng = _mk_engine(model, params, mode="self_consistency", n_candidates=4,
                     macro_steps=16)
    _submit(eng, cfg, 2)
    for r in eng.run():
        clusters = [c.get("cluster", -1) for c in r.candidates]
        assert any(k >= 0 for k in clusters)
        counts = {}
        for k in clusters:
            if k >= 0:
                counts[k] = counts.get(k, 0) + 1
        majority = max(counts.values())
        winners = {k for k, v in counts.items() if v == majority}
        chosen = next(c for c in r.candidates
                      if c["tokens"].tolist() == r.tokens.tolist())
        assert chosen.get("cluster") in winners


def test_self_consistency_clusters_every_candidate(tiny_model):
    """Regression: candidates produced after CAMD's coverage/max_rounds
    stop rule would trip (a CAMD-only budget policy) must still be folded
    into the cluster table — otherwise the majority vote silently ignores
    late candidates. n_candidates=5 with 2 slots forces 3 rounds against
    max_rounds=2."""
    cfg, model, params = tiny_model
    eng = _mk_engine(model, params, mode="self_consistency", slots=2,
                     n_candidates=5, macro_steps=16)
    _submit(eng, cfg, 1)
    (r,) = eng.run()
    assert r.n_candidates == 5
    assert all(c.get("cluster", -1) >= 0 for c in r.candidates), \
        [c.get("cluster") for c in r.candidates]


def test_batched_first_token_bitwise_matches_single():
    """Regression for the vectorized `_admit`: `sample_token_batch` with
    one key must be bit-identical to `sample_token` — including greedy
    (n=1 greedy is the pre-refactor admission path)."""
    key = jax.random.PRNGKey(3)
    logits = jax.random.normal(jax.random.PRNGKey(4), (1, 37))
    cfg = SamplingConfig(temperature=0.7, top_k=11)
    for greedy in (jnp.asarray([True]), jnp.asarray([False])):
        t1, l1 = sample_token(key, logits, cfg, greedy=greedy)
        tb, lb = sample_token_batch(key[None], logits, cfg, greedy=greedy)
        assert int(tb[0]) == int(t1[0])
        np.testing.assert_array_equal(np.asarray(lb[0]), np.asarray(l1[0]))
    # n>1: distinct keys give per-key results identical to separate calls
    keys = jax.random.split(jax.random.PRNGKey(5), 3)
    tb, lb = sample_token_batch(keys, logits, cfg)
    for i in range(3):
        ti, li = sample_token(keys[i], logits, cfg)
        assert int(tb[i]) == int(ti[0])


def test_greedy_invariant_to_macro_steps_and_seed(tiny_model):
    """Greedy decoding must not depend on sampler rng nor on K."""
    cfg, model, params = tiny_model
    outs = []
    for seed, K in ((0, 1), (1, 32), (2, 8)):
        eng = _mk_engine(model, params, mode="greedy", seed=seed,
                         macro_steps=K)
        _submit(eng, cfg, 2, seed=7)
        outs.append([r.tokens.tolist()
                     for r in sorted(eng.run(), key=lambda r: r.uid)])
    assert outs[0] == outs[1] == outs[2]
