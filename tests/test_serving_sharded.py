"""Mesh-parallel serving differential suite.

The acceptance bar for sharded serving: with N forced host devices
(``XLA_FLAGS=--xla_force_host_platform_device_count=N``, the CI
``test-multidevice`` lane), an engine sharded over a data-parallel mesh
must emit token streams BYTE-IDENTICAL to the single-device engine —
across impls, modes, macro-step settings, traffic policies, and the
prefix cache. Sharding is a placement decision, never a numerics or
scheduling decision.

Reuses the golden-stream harness from the scheduler-refactor
differential (``tests/data/make_golden_fifo.py``): same tiny model, same
workload, same stream digest.

On a single-device runtime the whole module skips — the CI lane is
where these run on every push.
"""
import importlib.util
import os

import numpy as np
import pytest

import jax

if jax.device_count() < 2:
    pytest.skip(
        "mesh-parallel serving needs >= 2 devices (set XLA_FLAGS="
        "--xla_force_host_platform_device_count=8 on CPU)",
        allow_module_level=True)

from repro.launch.mesh import make_serve_mesh
from repro.serving import Request

_spec = importlib.util.spec_from_file_location(
    "make_golden_fifo",
    os.path.join(os.path.dirname(__file__), "data", "make_golden_fifo.py"))
_gold_mod = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(_gold_mod)
make_engine, submit, tiny_model = (_gold_mod.make_engine, _gold_mod.submit,
                                   _gold_mod.tiny_model)

DP = 4 if jax.device_count() >= 4 else 2    # harness uses 4 slots


@pytest.fixture(scope="module")
def model3():
    return tiny_model()


@pytest.fixture(scope="module")
def mesh():
    return make_serve_mesh(DP)


def _streams(res):
    return [{
        "uid": r.uid,
        "tokens": r.tokens.tolist(),
        "tokens_spent": r.tokens_spent,
        "rounds": r.rounds,
        "n_candidates": r.n_candidates,
        "candidates": sorted(c["tokens"].tolist() for c in r.candidates),
    } for r in sorted(res, key=lambda r: r.uid)]


def _run(model3, mesh=None, n=2, **kw):
    cfg, model, params = model3
    eng = make_engine(model, params, mesh=mesh, **kw)
    submit(eng, cfg, n=n)
    res = _streams(eng.run())
    return eng, res


# ---------------------------------------------------------------------------
# the differential grid: {xla, paged} x {camd, best_of_n} x K in {0, 8}
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ["xla", "paged"])
@pytest.mark.parametrize("mode", ["camd", "best_of_n"])
@pytest.mark.parametrize("k", [0, 8])
def test_sharded_streams_byte_identical(model3, mesh, impl, mode, k):
    _, ref = _run(model3, mesh=None, mode=mode, impl=impl, macro_steps=k)
    eng, got = _run(model3, mesh=mesh, mode=mode, impl=impl, macro_steps=k)
    assert got == ref, f"{mode}/{impl}/K{k} diverged under {DP}-way mesh"
    if eng.paged:
        eng.pool.check()
        assert eng.pool.in_use == 0
        assert eng._reserved == 0 and not eng._reserved_sh.any()


@pytest.mark.parametrize("policy", ["fifo", "coverage"])
def test_sharded_streams_identical_per_policy(model3, mesh, policy):
    """Traffic policies decide identically under sharding: shard-local
    affordability must not bind on an adequately-sized pool."""
    kw = dict(mode="camd", impl="paged", macro_steps=8, sched_policy=policy)
    _, ref = _run(model3, mesh=None, n=4, **kw)
    eng, got = _run(model3, mesh=mesh, n=4, **kw)
    assert got == ref, f"policy={policy} diverged under {DP}-way mesh"
    ss = eng.sched_stats()
    if policy == "fifo":
        # every data shard actually served candidates (the decode batch
        # really is spread across the mesh, not packed on shard 0)
        assert len(ss.get("admitted_per_shard", {})) > 1, ss


def test_sharded_prefix_cache_identical(model3, mesh):
    """Prefix-cache hits across requests stay byte-identical when the
    cached pages live on one shard and hitting candidates on others."""
    cfg, model, params = model3
    rng = np.random.default_rng(3)
    prompts = [rng.integers(2, cfg.vocab_size, 19).astype(np.int32)
               for _ in range(4)]
    for p in prompts[1:]:
        p[:17] = prompts[0][:17]        # 2 shared full pages at ps=8
    outs = {}
    for m in (None, mesh):
        eng = make_engine(model, params, mode="camd", impl="paged",
                          macro_steps=8, mesh=m, cache_len=64,
                          prefix_cache=True)
        for i, p in enumerate(prompts):
            eng.submit(Request(uid=i, prompt=p))
        outs[m is not None] = _streams(eng.run())
        assert eng.kv_stats()["prefix_cache"]["hits"] > 0
        eng.pool.check()
    assert outs[False] == outs[True]


# ---------------------------------------------------------------------------
# shard-local conservation
# ---------------------------------------------------------------------------

def test_shard_local_pages_and_frontiers(model3, mesh):
    """Every page a slot ever writes (CoW tail + consumed frontier)
    comes from its own shard's subpool, frontier accounting balances
    per shard, and the drained pool is conserved per shard."""
    cfg, model, params = model3
    eng = make_engine(model, params, mode="best_of_n", impl="paged",
                      macro_steps=8, mesh=mesh)
    submit(eng, cfg, n=3)

    orig = eng._reclaim_frontier
    seen = []

    def spy(staged, pos_np):
        for s, (_p0, pages) in staged.items():
            seen.append((s, list(pages)))
        return orig(staged, pos_np)

    eng._reclaim_frontier = spy
    eng.run()
    assert seen, "paged macro-step run staged no frontiers"
    for s, pages in seen:
        for p in pages:
            assert eng.pool.shard_of(p) == eng._slot_shard(s), \
                (s, p, "frontier page crossed shards")
    st = eng.pool.stats()
    assert st["frontier_staged"] == sum(
        sh["frontier_staged"] for sh in st["shards"])
    eng.pool.check()
    assert eng.pool.in_use == 0
    assert not eng._reserved_sh.any()


def test_quarantine_is_shard_local(model3, mesh):
    """Idle slots' block tables point at their OWN shard's quarantine
    page, at init and after candidates retire."""
    cfg, model, params = model3
    eng = make_engine(model, params, mode="greedy", impl="paged",
                      macro_steps=8, mesh=mesh)
    bt0 = np.asarray(eng.state.cache["block_table"])
    for s in range(eng.B):
        assert bt0[s, 0] == eng.pool.quarantine_page(eng._slot_shard(s))
    submit(eng, cfg, n=2)
    eng.run()
    bt1 = np.asarray(eng.state.cache["block_table"])
    for s in range(eng.B):
        assert eng.pool.shard_of(int(bt1[s, 0])) == eng._slot_shard(s)


def test_affordable_refuses_unfundable_prompt_hold(model3, mesh):
    """A request whose prompt pages are pinned to an exhausted shard
    must NOT be admitted on other shards' capacity — admitting would
    crash prompt seeding mid-admission instead of queueing."""
    cfg, model, params = model3
    eng = make_engine(model, params, mode="camd", impl="paged",
                      macro_steps=8, mesh=mesh)
    info = {"prompt_len": 19, "page_shard": 0,          # 2 full pages @8
            "prompt_pages": [], "prefix_len": 0}
    drained = eng.pool.alloc(eng.pool.free_pages_in(0), 0)
    assert eng._paged_affordable(info, 2, 4) == 0
    eng.pool.free(drained)
    assert eng._paged_affordable(info, 2, 4) > 0
    eng.pool.check()


def test_state_actually_sharded(model3, mesh):
    """The decode batch and the page pool really live sharded on the
    mesh (not silently replicated): batch leaves split on the data
    axis, pool leaves on the page axis."""
    from jax.sharding import PartitionSpec as P
    cfg, model, params = model3
    eng = make_engine(model, params, mode="greedy", impl="paged",
                      macro_steps=8, mesh=mesh)
    spec = eng.state.last_token.sharding.spec
    assert spec == P("data"), spec
    kp = eng.state.cache["super"][0]["k_pages"]
    assert kp.sharding.spec[1] == "data", kp.sharding.spec
    assert eng.state.cache["block_table"].sharding.spec[0] == "data"
