"""Flash-decode Pallas TPU kernel: one query token vs. a long KV cache.

Decode attention is pure HBM bandwidth — the kernel streams KV blocks
through VMEM once. GQA-aware: the query heads of one kv head form the
sublane dim of the score matmul (G × blk_s), so each kv block is read
ONCE per group instead of once per query head (cuts HBM traffic by
H/Hkv — the roofline term that dominates decode_32k).

Grid (B, Hkv, nS) with the cache axis minor-most; running max/sum/acc in
VMEM scratch. ``kv_mask`` carries ring-buffer validity + window masking
computed by the caller.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, m_scr, l_scr,
                   acc_scr, *, scale: float, ns: int):
    isb = pl.program_id(2)

    @pl.when(isb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale            # (G, hd)
    k = k_ref[0, :, 0, :].astype(jnp.float32)              # (blk_s, hd)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    valid = mask_ref[0, :] > 0                             # (blk_s,)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (G, blk_s)
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc = acc_scr[...] * alpha + jax.lax.dot(p, v)
    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(isb == ns - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-20)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("blk_s", "interpret"))
def decode_attention(q, k, v, kv_mask, *, blk_s: int = 256,
                     interpret: bool = False):
    """q: (B, 1, H, hd); k/v: (B, S, Hkv, hd); kv_mask: (B, S) bool.

    Returns (B, 1, H, hd).
    """
    B, _, H, hd = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = hd ** -0.5
    pad = (-S) % blk_s
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_mask = jnp.pad(kv_mask, ((0, 0), (0, pad)))
    Sp = k.shape[1]
    ns = Sp // blk_s
    # group query heads by kv head: (B, Hkv, G, hd)
    qg = q[:, 0].reshape(B, Hkv, G, hd)
    maskf = kv_mask.astype(jnp.float32)

    kernel = functools.partial(_decode_kernel, scale=scale, ns=ns)
    out = pl.pallas_call(
        kernel,
        grid=(B, Hkv, ns),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, blk_s, 1, hd), lambda b, h, s: (b, s, h, 0)),
            pl.BlockSpec((1, blk_s, 1, hd), lambda b, h, s: (b, s, h, 0)),
            pl.BlockSpec((1, blk_s), lambda b, h, s: (b, s)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, s: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qg, k, v, maskf)
    return out.reshape(B, 1, H, hd)
