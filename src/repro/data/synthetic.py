"""Synthetic data pipeline.

``lm_batches`` produces a learnable autoregressive stream (arithmetic-chain
compositions mixed with token-copy spans) so the example drivers train a
~100M model whose loss actually falls. ``evidence_batch`` supplies the
stubbed modality-frontend embeddings for VLM/audio architectures.
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import numpy as np

# token layout inside the synthetic vocab:
#   0 PAD, 1 EOS, 2 BOS, 3 SEP, 4 QRY; digits start at OFF.
PAD, EOS, BOS, SEP, QRY = 0, 1, 2, 3, 4
OFF = 8


def _chain_example(rng: np.random.Generator, seq: int, base: int,
                   max_chain: int = 3) -> np.ndarray:
    """BOS x0 [op a1 op a2 ...] QRY answer SEP ... repeated to fill seq.

    Each link applies (x + a) mod base. chain_len=0 is pure copy (easy);
    longer chains are compositionally harder — the difficulty gradient the
    CAMD experiments rely on.
    """
    out = []
    while len(out) < seq + 1:
        k = int(rng.integers(0, max_chain + 1))
        x = int(rng.integers(0, base))
        toks = [BOS, OFF + x]
        for _ in range(k):
            a = int(rng.integers(0, base))
            toks.append(OFF + base + a)       # operand tokens live in a 2nd band
            x = (x + a) % base
        toks += [QRY, OFF + x, SEP]
        out.extend(toks)
    return np.asarray(out[:seq + 1], np.int32)


def _copy_example(rng: np.random.Generator, seq: int, vocab: int) -> np.ndarray:
    span = rng.integers(OFF, vocab, size=max(seq // 4, 4))
    reps = int(np.ceil((seq + 1) / len(span)))
    return np.tile(span, reps)[:seq + 1].astype(np.int32)


def lm_batches(vocab: int, batch: int, seq: int, *, seed: int = 0,
               base: Optional[int] = None, max_chain: int = 3,
               evidence: Optional[Dict] = None) -> Iterator[Dict]:
    """Infinite iterator of {tokens, labels(, evidence)} numpy batches."""
    rng = np.random.default_rng(seed)
    base = base or min(32, (vocab - OFF) // 2)
    while True:
        rows = []
        for b in range(batch):
            if rng.random() < 0.7:
                rows.append(_chain_example(rng, seq, base, max_chain))
            else:
                rows.append(_copy_example(rng, seq, vocab))
        arr = np.stack(rows)
        out = {"tokens": arr[:, :-1], "labels": arr[:, 1:]}
        if evidence is not None:
            out["evidence"] = evidence_batch(
                rng, batch, evidence["num_tokens"], evidence["dim"])
        yield out


def evidence_batch(rng: np.random.Generator, batch: int, num_tokens: int,
                   dim: int) -> np.ndarray:
    """Stub modality frontend: unit-norm 'patch/frame' embeddings."""
    ev = rng.standard_normal((batch, num_tokens, dim)).astype(np.float32)
    return ev / (np.linalg.norm(ev, axis=-1, keepdims=True) + 1e-8)
