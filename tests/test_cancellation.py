"""Cancellation: mid-stream aborts must leak nothing.

``ServeEngine.cancel`` tears a request down at the next macro-step
boundary: staged frontier pages go back via ``PagePool.return_frontier``
(wholesale, before the per-token reclaim), held pages and the slot are
freed, and the scheduler's worst-case commitment is refunded. These
tests pin each cancel timing class (queued-unprefilled, prefilled-
pending, partially-prefilled mid-chunking, running, finished, unknown)
and a hypothesis property that
fires cancels at random pump boundaries and checks the conservation
invariant — no page, slot, or budget token leaks — plus the difficulty
priors and the telemetry-reset contract that ride the same PR.
"""
import itertools

import numpy as np
import pytest

from conftest import _mk_engine, _request
from repro.config import PagedKVConfig
from repro.serving.scheduler import (CoverageScheduler, FifoScheduler,
                                     NewWork)

MAX_NEW = 6
_UIDS = itertools.count(0)


def _uids(n):
    return [next(_UIDS) for _ in range(n)]


@pytest.fixture(scope="module")
def greedy_eng(tiny_model):
    cfg, model, params = tiny_model
    eng = _mk_engine(model, params, mode="greedy", macro_steps=2, slots=3,
                     max_new=MAX_NEW, eos_id=cfg.vocab_size, impl="paged",
                     paged_kv=PagedKVConfig(page_size=8))
    return cfg, eng


def _submit(eng, cfg, uids):
    for uid in uids:
        rng = np.random.default_rng(uid)
        eng.submit(_request(
            uid, rng.integers(2, cfg.vocab_size, 6).astype(np.int32)))


def _drain(eng, cancels=None):
    """Pump to completion, firing ``cancels[i]`` (uids) after pump i."""
    cancels, i = cancels or {}, 0
    while True:
        more = eng.pump()
        for uid in cancels.get(i, ()):
            eng.cancel(uid)
        i += 1
        if not more:
            return i


def _assert_conserved(eng):
    """Nothing outlives a drained engine: every page is back on a free
    list (prefix-cache residents aside), every slot is idle, and the
    scheduler's worst-case commitment is fully refunded."""
    eng.pool.check()
    resident = len(eng.pool.prefix._nodes) if eng.pool.prefix else 0
    assert eng.pool.in_use == resident
    assert all(int(eng._slot_req[s]) == -1 for s in range(eng.B))
    assert eng.scheduler.committed == 0


# ---------------------------------------------------------------------------
# deterministic timing classes
# ---------------------------------------------------------------------------

def test_cancel_unknown_and_finished(greedy_eng):
    cfg, eng = greedy_eng
    (uid,) = _uids(1)
    assert not eng.cancel(10**9)          # never submitted
    _submit(eng, cfg, [uid])
    eng.run()
    assert not eng.cancel(uid)            # already finished
    assert not eng.result(uid).cancelled
    _assert_conserved(eng)


def test_cancel_queued_before_any_pump(greedy_eng):
    cfg, eng = greedy_eng
    uids = _uids(3)
    _submit(eng, cfg, uids)
    assert eng.cancel(uids[1])            # queued-unprefilled: immediate
    assert not eng.cancel(uids[1])        # idempotent: already finalized
    res = {r.uid: r for r in eng.run()}
    assert res[uids[1]].cancelled and len(res[uids[1]].tokens) == 0
    for uid in (uids[0], uids[2]):
        assert not res[uid].cancelled
        assert len(res[uid].tokens) == MAX_NEW   # eos out-of-vocab
    _assert_conserved(eng)


def test_cancel_running_at_pump_boundary(greedy_eng):
    cfg, eng = greedy_eng
    uids = _uids(3)
    _submit(eng, cfg, uids)
    # after the first pump every slot is live; the cancel defers to the
    # next boundary and must return the staged frontier wholesale
    _drain(eng, cancels={0: [uids[0]]})
    res0 = eng.result(uids[0])
    assert res0.cancelled
    assert len(res0.tokens) == 0          # torn down without a record
    for uid in uids[1:]:
        r = eng.result(uid)
        assert not r.cancelled and len(r.tokens) == MAX_NEW
    assert eng.cancelled_requests >= 1
    assert eng.sched_stats()["cancelled_candidates"] >= 1
    _assert_conserved(eng)


def test_cancel_partially_prefilled_returns_chunk_pages(tiny_model):
    """The timing class chunked prefill adds: the cancel lands while
    the request is mid-chunking — pages held by the job, no slot, no
    request record yet — and must free every chunk page via the job
    teardown path. The long prompt is submitted while shorts decode
    with one slot free (``pump`` only runs admission passes when a
    slot is free), so its job is budget-paced to one chunk per turn."""
    cfg, model, params = tiny_model
    eng = _mk_engine(model, params, mode="greedy", macro_steps=2, slots=3,
                     max_new=MAX_NEW, eos_id=cfg.vocab_size, impl="paged",
                     paged_kv=PagedKVConfig(page_size=8), cache_len=128,
                     prefill_chunk=16, prefill_chunk_budget=16)
    uids = _uids(3)
    _submit(eng, cfg, uids[:2])
    eng.pump()                            # shorts admitted and live
    rng = np.random.default_rng(uids[2])
    eng.submit(_request(
        uids[2], rng.integers(2, cfg.vocab_size, 96).astype(np.int32)))
    eng.pump()                            # job opens, one 16-token chunk
    assert uids[2] in eng._chunking, "long prompt should be mid-chunking"
    held = list(eng._chunking[uids[2]]["pages"])
    assert held
    assert eng.cancel(uids[2])
    _drain(eng)
    assert eng.result(uids[2]).cancelled
    for uid in uids[:2]:
        assert len(eng.result(uid).tokens) == MAX_NEW
    assert all(eng.pool.refcount(p) == 0 for p in held)
    _assert_conserved(eng)


def test_cancelled_tokens_count_as_spent(greedy_eng):
    cfg, eng = greedy_eng
    uids = _uids(2)
    spent0 = eng.scheduler.spent
    _submit(eng, cfg, uids)
    _drain(eng, cancels={0: [uids[0]]})
    # the aborted candidate's emitted tokens burned real compute: they
    # stay on the spent ledger alongside the survivor's full run
    assert eng.scheduler.spent >= spent0 + MAX_NEW
    _assert_conserved(eng)


def test_budget_refund_exact():
    s = FifoScheduler(global_budget=100)
    take, limit = s.grant(2, 10)
    assert (take, limit) == (2, 10)
    s.commit(take, limit)
    assert s.committed == 20
    s.on_cancel(0, 3, limit)              # aborted after 3 tokens
    s.on_finish(1, 10, limit)
    assert s.committed == 0
    assert s.spent == 13
    assert s.stats()["cancelled_candidates"] == 1
    # refunded headroom is grantable again, minus what was spent
    assert s.remaining() == 100 - 13


# ---------------------------------------------------------------------------
# difficulty priors (CoverageScheduler ranks unobserved work)
# ---------------------------------------------------------------------------

def test_difficulty_prior_ranks_harder_new_work_first():
    cs = CoverageScheduler()
    hard = NewWork(uid=0, arrival=0, want=1, prompt_len=256,
                   evidence_entropy=0.8)
    easy = NewWork(uid=1, arrival=1, want=1, prompt_len=4,
                   evidence_entropy=0.0)
    assert cs._priority("new", hard) > cs._priority("new", easy)
    # prompt length alone separates text-only requests
    long_p = NewWork(uid=2, arrival=2, want=1, prompt_len=512)
    short_p = NewWork(uid=3, arrival=3, want=1, prompt_len=8)
    assert cs._priority("new", long_p) > cs._priority("new", short_p)
    # default-prior work keeps the legacy base priority exactly, so
    # fakes and old callers rank as before
    legacy = NewWork(uid=4, arrival=4, want=1)
    assert cs._priority("new", legacy) == pytest.approx(
        cs.new_request_priority)
    # the prior saturates: it can never dominate an unbounded amount
    assert cs._difficulty(hard) <= 1.0


# ---------------------------------------------------------------------------
# telemetry reset: counters zero, ledgers survive
# ---------------------------------------------------------------------------

def test_reset_stats_zeroes_counters_but_keeps_ledgers(greedy_eng):
    cfg, eng = greedy_eng
    uids = _uids(2)
    _submit(eng, cfg, uids)
    _drain(eng, cancels={0: [uids[0]]})
    assert eng.total_tokens > 0 and eng.macro_launches > 0
    spent = eng.scheduler.spent
    eng.reset_stats()
    assert eng.total_tokens == eng.total_steps == 0
    assert eng.macro_launches == eng.host_syncs == 0
    assert eng.cancelled_requests == 0
    s = eng.sched_stats()
    assert s["admitted_candidates"] == 0 and s["prefill_calls"] == 0
    assert s["cancelled_candidates"] == 0
    k = eng.kv_stats()
    assert k["frontier_staged"] == k["frontier_returned"] == 0
    assert k["frontier_peak_stage"] == 0
    # budget ledgers are accounting state, not telemetry
    assert eng.scheduler.spent == spent
    _assert_conserved(eng)


# ---------------------------------------------------------------------------
# property: random cancel timing conserves pages/slots/budget
# ---------------------------------------------------------------------------

def _check_conservation(greedy_eng, plan):
    """Whatever subset of 6 requests is cancelled at whatever pump
    boundary (requests outnumber slots, so the plan hits queued,
    running, and already-finished targets), the drained engine holds
    zero pages, zero busy slots, zero commitment — and every request
    still resolves to a Result."""
    cfg, eng = greedy_eng
    uids = _uids(6)
    cancels = {}
    for idx, at in plan:
        cancels.setdefault(at, []).append(uids[idx])
    _submit(eng, cfg, uids)
    _drain(eng, cancels=cancels)
    planned = {uids[idx] for idx, _at in plan}
    for uid in uids:
        r = eng.result(uid)
        if r.cancelled:
            assert uid in planned
        else:
            assert len(r.tokens) == MAX_NEW
    _assert_conserved(eng)


try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # the no-hypothesis lane still
    st = None                             # runs a fixed-plan fallback

if st is not None:
    @settings(max_examples=6, deadline=None)
    @given(plan=st.lists(st.tuples(st.integers(0, 5), st.integers(0, 3)),
                         min_size=0, max_size=4,
                         unique_by=lambda t: t[0]))
    def test_conservation_under_random_cancel_timing(greedy_eng, plan):
        _check_conservation(greedy_eng, plan)
else:
    @pytest.mark.parametrize("plan", [
        [],                               # pure completion
        [(0, 0)],                         # running head-of-line
        [(0, 0), (3, 1), (5, 2)],         # running + queued + late
        [(1, 3), (2, 0), (4, 0)],         # mixed same-boundary pair
    ])
    def test_conservation_under_random_cancel_timing(greedy_eng, plan):
        _check_conservation(greedy_eng, plan)
