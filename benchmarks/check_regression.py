"""Bench regression gate: fail CI when the serving bench degrades.

Compares a fresh ``BENCH_serve.json`` (the bench-smoke step's output)
against the committed ``BENCH_baseline.json`` and exits non-zero when:

  * smoke throughput drops more than ``--tol`` (default 20%) in any
    (impl, mode, macro_steps) cell present in both files — absolute
    tokens/sec, so the baseline is recorded on deliberately modest
    hardware (2-vCPU container) and hosted runners only ever look
    faster; a drop past the tolerance means a real hot-path regression;
  * the fused macro-step loop stops amortizing host syncs
    (``syncs_per_token`` is deterministic, so this is exact);
  * the paged macro-step loop stops beating its own per-token loop
    (the ``speedups`` section's paged rows must have ``best_k > 0`` —
    a fused loop that loses to the legacy loop is a fusion regression,
    however fast the legacy loop is);
  * the speculative scenario's greedy streams diverge between spec-on
    and spec-off, or its decode speedup falls below 1.5x on either
    impl (the speedup is a within-run ratio, so it is gated even when
    a jax version skew disables the absolute-throughput checks);
  * the scheduler scenario's coverage-vs-fifo win disappears: at equal
    budget, coverage must match-or-beat fifo accuracy (one request of
    sampling slack, as the bench asserts) while spending strictly fewer
    tokens per served easy request;
  * the quantized scenario regresses: kv_dtype=fp32 must stay
    byte-identical to auto, int8 resident KV bytes must stay <= 0.55x
    fp32 at equal config (``resident_kv_bytes`` gate), and int8 greedy
    oracle accuracy must stay within one request of fp32 — all
    within-run and deterministic, so never version-skew-skipped;
  * the sharded scenario ran (multi-device lane) and the single-device
    vs mesh token streams were not byte-identical;
  * the open-loop scenario's deterministic invariants break — open-loop
    token streams must match the closed-loop reference byte-for-byte,
    every offered request must complete, and the cancellation cell must
    leak zero pages/slots/commitment — or (wall-clock, skippable) its
    saturation tokens/s drops more than ``--tol`` vs baseline;
  * the chunked-prefill scenario breaks its contract: greedy streams
    must stay byte-identical with chunking on (deterministic), the
    chunk machinery must actually run, and (wall-clock, skippable)
    short-prompt p99 TTFT must improve >= 1.2x over the unchunked
    engine with the long-prompt p99 within 1.5x, decode tokens/s within
    ``--tol``, and the chunked long-prompt p99 within ``--tol`` of the
    committed baseline;
  * the multimodal scenario breaks its (all-deterministic) contract:
    dense, paged, and paged+image-prefix-cache greedy streams must stay
    byte-identical, the cold vision tower must encode each distinct
    image exactly once (everything else feature-memoized), the shared
    hot image must actually hit the prefix cache, and the reuse cell
    must compute strictly fewer prefill tokens than the no-reuse cell —
    TTFT with/without image reuse is recorded but never wall-clock
    gated.

``--skip-throughput`` drops the wall-clock checks — used by the forced
multi-device CI lane, whose 8 host devices oversubscribe the runner's
cores (its job is the identity + conservation gate, not perf).
``--sections a,b`` restricts the gate to named sections (a lane that
only ran ``bench_serve --sections grid,open_loop`` gates only those);
by default the gate covers whatever sections the current report
declares it ran, or all known sections for pre-section reports.

A section the gate expects but the report lacks is an actionable error
(naming the section and the regeneration command), not a KeyError.

  python benchmarks/check_regression.py [current] [baseline]
"""
from __future__ import annotations

import argparse
import json
import sys

ALL_SECTIONS = ("grid", "speculative", "scheduler", "quantized", "sharded",
                "open_loop", "chunked_prefill", "multimodal")

REGEN = ("PYTHONPATH=src python -m benchmarks.bench_serve --smoke && "
         "cp BENCH_serve.json BENCH_baseline.json")


def _cells(report):
    return {(r["impl"], r["mode"], r["macro_steps"]): r
            for r in report.get("rows", [])}


def _missing(which, what):
    return (f"{what} missing from the {which} report — stale or partial "
            f"benchmark file; regenerate with: {REGEN}")


def _section(report, name, which, errors):
    """The named section, or None after recording an actionable error
    (replaces the bare KeyError a stale baseline used to raise)."""
    sec = report.get(name)
    if not isinstance(sec, dict):
        errors.append(_missing(which, f"'{name}' section"))
        return None
    return sec


def _head(report, name, which, errors):
    sec = _section(report, name, which, errors)
    if sec is None or "skipped" in sec:
        return sec
    head = sec.get("headline")
    if not isinstance(head, dict):
        errors.append(_missing(which, f"'{name}' section headline"))
        return None
    return head


def _key(d, key, where, errors, default=None):
    if key not in d:
        errors.append(_missing("current", f"'{key}' in the {where}"))
        return default
    return d[key]


def check(cur: dict, base: dict, *, tol: float, skip_throughput: bool,
          sections=None) -> list:
    errors = []
    if sections is None:
        sections = tuple(cur.get("config", {}).get("sections")
                         or ALL_SECTIONS)

    # wall-clock comparisons only mean something within one jax/XLA
    # generation — the matrix's floor lane matches the baseline's
    # recorded version, the latest-jax lane keeps the deterministic
    # gates (syncs, scheduler win, sharded identity) only
    # an explicit --skip-throughput (oversubscribed forced-multi-device
    # lane) also drops within-run wall-clock ratios; a jax version skew
    # only drops cross-run absolute comparisons
    skip_ratios = skip_throughput
    cur_v = cur.get("config", {}).get("jax_version")
    base_v = base.get("config", {}).get("jax_version")
    if not skip_throughput and cur_v != base_v:
        print(f"throughput gate skipped: jax {cur_v} vs baseline's "
              f"{base_v} (deterministic gates still apply)")
        skip_throughput = True

    if "grid" in sections:
        if "rows" not in cur:
            errors.append(_missing("current", "'rows' grid section"))
        cur_cells, base_cells = _cells(cur), _cells(base)
        for key in sorted(set(cur_cells) & set(base_cells)):
            c, b = cur_cells[key], base_cells[key]
            if not skip_throughput and \
                    c["tokens_per_s"] < (1.0 - tol) * b["tokens_per_s"]:
                errors.append(
                    f"throughput regression in {key}: "
                    f"{c['tokens_per_s']:.1f} tok/s vs baseline "
                    f"{b['tokens_per_s']:.1f} (tolerance {tol:.0%})")
            # sync amortization is near-deterministic (token streams —
            # and so completion-boundary syncs — shift slightly across
            # jax versions); 1.5x headroom still catches de-fusing
            if c["macro_steps"] >= 8 and \
                    c["syncs_per_token"] > b["syncs_per_token"] * 1.5 + 1e-9:
                errors.append(
                    f"host-sync regression in {key}: "
                    f"{c['syncs_per_token']:.4f} syncs/token vs baseline "
                    f"{b['syncs_per_token']:.4f}")

        # the fused macro-step loop must win over the per-token loop on
        # the paged path: best_k == 0 means the core claim regressed
        for name, sp in sorted(cur.get("speedups", {}).items()):
            if skip_ratios:
                break
            if name.startswith("paged/") and sp.get("best_k", 0) == 0:
                errors.append(
                    f"paged macro-step loop lost to the per-token loop in "
                    f"{name}: best_k == 0 "
                    f"({sp.get('tokens_per_s_best', 0.0):.1f} tok/s "
                    f"fused-best vs "
                    f"{sp.get('tokens_per_s_legacy', 0.0):.1f} legacy)")

    if "speculative" in sections:
        spec_head = _head(cur, "speculative", "current", errors)
        if spec_head is not None:
            if not spec_head.get("equal_outputs", False):
                errors.append("speculative greedy streams diverged from "
                              "spec-off streams")
            for impl in ("xla", "paged"):
                s = spec_head.get(f"speedup_{impl}")
                if s is None:
                    errors.append(f"speculative section has no {impl} row")
                elif not skip_ratios and s < 1.5:
                    errors.append(
                        f"speculative decode speedup below 1.5x on {impl}: "
                        f"{s:.2f}x")

    if "scheduler" in sections:
        sched = cur.get("scheduler", {})
        head = _head(cur, "scheduler", "current", errors)
        if head is not None:
            slack = 1.0 / max(sched.get("n_requests", 1), 1)
            acc_cov = _key(head, "accuracy_coverage",
                           "scheduler headline", errors, 0.0)
            acc_fifo = _key(head, "accuracy_fifo",
                            "scheduler headline", errors, 0.0)
            eps_cov = _key(head, "easy_per_served_coverage",
                           "scheduler headline", errors, 0.0)
            eps_fifo = _key(head, "easy_per_served_fifo",
                            "scheduler headline", errors, 0.0)
            if acc_cov + slack < acc_fifo:
                errors.append(
                    f"coverage-vs-fifo accuracy win disappeared: "
                    f"{acc_cov:.3f} + {slack:.3f} slack < {acc_fifo:.3f}")
            if eps_cov >= eps_fifo:
                errors.append(
                    "coverage no longer spends fewer tokens per served "
                    f"easy request ({eps_cov:.2f} >= {eps_fifo:.2f})")

    if "quantized" in sections:
        quant = cur.get("quantized", {})
        q_head = _head(cur, "quantized", "current", errors)
        if q_head is not None:
            # all three gates are within-run and deterministic, so they
            # apply regardless of jax version skew or --skip-throughput
            if not q_head.get("fp32_identical_to_auto", False):
                errors.append("kv_dtype=fp32 is no longer byte-identical "
                              "to auto on the fp32 bench engine")
            ratio = q_head.get("bytes_ratio_int8", 1.0)
            if ratio > 0.55:
                errors.append(
                    f"resident_kv_bytes gate: int8 pages cost {ratio:.3f}x "
                    f"fp32 at equal config (gate: <= 0.55x)")
            q_slack = 1.0 / max(quant.get("n_requests", 1), 1)
            delta = q_head.get("accuracy_delta_int8", 1.0)
            if delta > q_slack:
                errors.append(
                    f"int8 KV quantization costs oracle accuracy: "
                    f"fp32 {q_head.get('accuracy_fp32', 0.0):.3f} -> int8 "
                    f"{q_head.get('accuracy_int8', 0.0):.3f} "
                    f"(delta {delta:.3f} > {q_slack:.3f} slack)")

    if "sharded" in sections:
        sharded = _section(cur, "sharded", "current", errors)
        if sharded is not None:
            if "skipped" in sharded:
                print(f"sharded scenario skipped: {sharded['skipped']}")
            elif not sharded.get("streams_identical", False):
                errors.append("sharded serving diverged from single-device "
                              "token streams")

    if "open_loop" in sections:
        o_head = _head(cur, "open_loop", "current", errors)
        if o_head is not None:
            # deterministic invariants: greedy streams are schedule-
            # invariant, so open-loop admission order must not change a
            # single token; cancels must refund everything
            if not o_head.get("streams_match_closed_loop", False):
                errors.append("open-loop token streams diverged from the "
                              "closed-loop reference")
            if not o_head.get("completed_all", False):
                errors.append("open-loop run did not complete every "
                              "offered request")
            if not o_head.get("no_leaks_after_cancel", False):
                errors.append("open-loop cancellation cell leaked pages, "
                              "slots, or scheduler commitment")
            b_head = base.get("open_loop", {}).get("headline")
            if not skip_throughput and b_head is not None:
                c_sat = _key(o_head, "tokens_per_s_saturation",
                             "open_loop headline", errors, 0.0)
                b_sat = b_head.get("tokens_per_s_saturation", 0.0)
                if c_sat < (1.0 - tol) * b_sat:
                    errors.append(
                        f"open-loop saturation throughput regression: "
                        f"{c_sat:.1f} tok/s vs baseline {b_sat:.1f} "
                        f"(tolerance {tol:.0%})")

    if "chunked_prefill" in sections:
        c_head = _head(cur, "chunked_prefill", "current", errors)
        if c_head is not None:
            # deterministic: chunking must not change a single greedy
            # token, and the chunk machinery must actually have run
            if not c_head.get("streams_identical", False):
                errors.append("chunked prefill changed greedy token "
                              "streams vs the unchunked engine")
            if c_head.get("chunk_calls", 0) <= 0:
                errors.append("chunked-prefill cell ran zero chunk calls "
                              "— chunking silently disabled")
            if not skip_ratios:
                # within-run wall-clock A/B: shorts must stop queueing
                # behind whole-prompt prefills, the tail long prompt may
                # pay a bounded pacing cost, decode throughput holds
                imp = c_head.get("ttft_short_improvement", 0.0)
                if imp < 1.2:
                    errors.append(
                        f"chunked prefill no longer improves short-prompt "
                        f"p99 TTFT: {imp:.2f}x (gate: >= 1.2x)")
                lr = c_head.get("ttft_long_p99_ratio", 10.0)
                if lr > 1.5:
                    errors.append(
                        f"chunked prefill long-prompt p99 TTFT ratio "
                        f"{lr:.2f}x vs unchunked (gate: <= 1.5x)")
                dr = c_head.get("decode_ratio", 0.0)
                if dr < 1.0 - tol:
                    errors.append(
                        f"chunked prefill decode throughput ratio "
                        f"{dr:.2f}x vs unchunked (tolerance {tol:.0%})")
            b_head = base.get("chunked_prefill", {}).get("headline")
            if not skip_throughput and b_head is not None:
                c_long = c_head.get("ttft_p99_long_on_ms", 0.0)
                b_long = b_head.get("ttft_p99_long_on_ms", 0.0)
                if b_long and c_long > (1.0 + tol) * b_long:
                    errors.append(
                        f"chunked long-prompt p99 TTFT regression: "
                        f"{c_long:.1f}ms vs baseline {b_long:.1f}ms "
                        f"(tolerance {tol:.0%})")

    if "multimodal" in sections:
        m_head = _head(cur, "multimodal", "current", errors)
        if m_head is not None:
            # every multimodal gate is within-run and deterministic —
            # never skipped for jax version skew or --skip-throughput
            if not m_head.get("streams_identical", False):
                errors.append("multimodal greedy streams diverged across "
                              "dense / paged / image-prefix-cache cells")
            enc = m_head.get("image_encodes_cold", -1)
            distinct = m_head.get("distinct_images", 0)
            if enc != distinct:
                errors.append(
                    f"vision-tower encode memoization broke: {enc} cold "
                    f"encodes for {distinct} distinct images")
            if m_head.get("image_prefix_hit_tokens", 0) <= 0:
                errors.append("shared hot image never hit the image "
                              "prefix cache (hit_tokens == 0)")
            reuse = m_head.get("prefill_tokens_reuse", 1 << 30)
            no_reuse = m_head.get("prefill_tokens_no_reuse", 0)
            if reuse >= no_reuse:
                errors.append(
                    f"image-prefix reuse no longer skips prefill work: "
                    f"{reuse} prefill tokens with the cache vs {no_reuse} "
                    f"without")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("current", nargs="?", default="BENCH_serve.json")
    ap.add_argument("baseline", nargs="?", default="BENCH_baseline.json")
    ap.add_argument("--tol", type=float, default=0.20,
                    help="allowed fractional throughput drop (default 0.20)")
    ap.add_argument("--skip-throughput", action="store_true",
                    help="skip wall-clock gates (forced-multi-device lane)")
    ap.add_argument("--sections", default=None,
                    help="comma list of sections to gate (default: the "
                         "sections the current report declares it ran)")
    args = ap.parse_args(argv)

    with open(args.current) as f:
        cur = json.load(f)
    with open(args.baseline) as f:
        base = json.load(f)

    sections = tuple(args.sections.split(",")) if args.sections else None
    if sections:
        unknown = set(sections) - set(ALL_SECTIONS)
        if unknown:
            print(f"unknown sections {sorted(unknown)}; "
                  f"choose from {ALL_SECTIONS}")
            return 2

    errors = check(cur, base, tol=args.tol,
                   skip_throughput=args.skip_throughput, sections=sections)
    if errors:
        print("BENCH REGRESSION GATE FAILED:")
        for e in errors:
            print(f"  - {e}")
        return 1
    print(f"bench regression gate passed "
          f"({len(_cells(cur))} cells, tol {args.tol:.0%}"
          f"{', throughput skipped' if args.skip_throughput else ''})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
