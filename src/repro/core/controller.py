"""CAMD round controller — the adaptive decoding state machine.

One ``CAMDState`` per in-flight request; all fields are fixed-shape so the
whole state batches into a pytree and the round update runs as a single
vmapped jit on device. The serving engine owns the loop; this module owns
the math:

    round_update:  score -> cluster -> coverage test -> Dirichlet update
                   -> mixture guidance bias for the next round (Eq. 7-16).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.config import CAMDConfig
from repro.core import clustering, posterior, scoring


class CAMDState(NamedTuple):
    table: clustering.ClusterTable
    alpha: jax.Array          # (M,) Dirichlet params
    hist: jax.Array           # (M, V) cluster token histograms (guidance)
    k_t: jax.Array            # () int32 — cumulative samples
    rounds: jax.Array         # () int32
    stopped: jax.Array        # () bool
    p_star: jax.Array         # () float32 — latest coverage estimate
    best_score: jax.Array     # () float32
    best_uid: jax.Array       # () int32 — engine-side id of best candidate
    best_cluster: jax.Array   # () int32
    tokens_spent: jax.Array   # () int32


def init_state(cfg: CAMDConfig, emb_dim: int, vocab: int) -> CAMDState:
    M = cfg.max_clusters
    return CAMDState(
        table=clustering.make_table(M, emb_dim),
        alpha=jnp.full((M,), cfg.dirichlet_prior, jnp.float32),
        hist=jnp.zeros((M, vocab), jnp.float32),
        k_t=jnp.zeros((), jnp.int32),
        rounds=jnp.zeros((), jnp.int32),
        stopped=jnp.zeros((), bool),
        p_star=jnp.zeros((), jnp.float32),
        best_score=jnp.full((), -jnp.inf, jnp.float32),
        best_uid=jnp.full((), -1, jnp.int32),
        best_cluster=jnp.full((), -1, jnp.int32),
        tokens_spent=jnp.zeros((), jnp.int32),
    )


class RoundInputs(NamedTuple):
    """One round of R candidates for a single request."""
    scores: jax.Array        # (R,) evidence-weighted scores S(y_i|x)
    embs: jax.Array          # (R, d) mean-pooled candidate embeddings
    token_counts: jax.Array  # (R, V) token count vectors (for guidance)
    lengths: jax.Array       # (R,) generated lengths
    valid: jax.Array         # (R,) bool — real candidates this round
    uids: jax.Array          # (R,) int32 engine-side candidate ids


def round_update(cfg: CAMDConfig, state: CAMDState, inp: RoundInputs
                 ) -> Tuple[CAMDState, jax.Array]:
    """Fold one round of candidates into the state.

    Returns (new_state, guidance_bias (V,)) — the Eq. 16 mixture bias to
    apply to the next round's logits (zeros once stopped).
    """
    state, bias, _ = round_update_assign(cfg, state, inp)
    return state, bias


def round_update_assign(cfg: CAMDConfig, state: CAMDState, inp: RoundInputs
                        ) -> Tuple[CAMDState, jax.Array, jax.Array]:
    """``round_update`` that also returns the per-candidate cluster
    assignment (R,) int32 (-1 for invalid rows) — the serving engine
    records it so self-consistency can vote by majority cluster."""
    valid = inp.valid & ~state.stopped
    scores = inp.scores * cfg.score_scale
    table, cluster_idx = clustering.assign_batch(
        state.table, inp.embs, scores, valid, cfg.cluster_threshold)

    # cluster token histograms for the mixture distribution
    M = state.alpha.shape[0]
    one = jax.nn.one_hot(jnp.maximum(cluster_idx, 0), M) \
        * valid[:, None].astype(jnp.float32)                    # (R, M)
    hist = state.hist + jnp.einsum("rm,rv->mv", one, inp.token_counts)

    k_t = state.k_t + jnp.sum(valid).astype(jnp.int32)
    tokens = state.tokens_spent + jnp.sum(
        jnp.where(valid, inp.lengths, 0)).astype(jnp.int32)

    # best-candidate tracking
    masked_scores = jnp.where(valid, scores, -jnp.inf)
    r_best = jnp.argmax(masked_scores)
    improved = masked_scores[r_best] > state.best_score
    best_score = jnp.where(improved, masked_scores[r_best], state.best_score)
    best_uid = jnp.where(improved, inp.uids[r_best], state.best_uid)
    best_cluster = jnp.where(improved, cluster_idx[r_best], state.best_cluster)

    stop, p_star = posterior.coverage_reached(
        table, k_t, delta=cfg.delta, min_samples=cfg.min_samples)
    rounds = state.rounds + jnp.where(state.stopped, 0, 1)
    stopped = state.stopped | stop | (rounds >= cfg.max_rounds)

    alpha, pi_bar = posterior.dirichlet_update(state.alpha, table)
    bias = posterior.mixture_logit_bias(
        pi_bar, hist, strength=cfg.guidance_strength)
    bias = jnp.where(stopped, jnp.zeros_like(bias), bias)

    new_state = CAMDState(
        table=table, alpha=alpha, hist=hist, k_t=k_t, rounds=rounds,
        stopped=stopped, p_star=p_star, best_score=best_score,
        best_uid=best_uid, best_cluster=best_cluster, tokens_spent=tokens)
    return new_state, bias, cluster_idx


def batched_round_update(cfg: CAMDConfig):
    """vmapped round_update over a batch of requests (engine hot path)."""
    return jax.vmap(lambda s, i: round_update(cfg, s, i))


def batched_round_update_assign(cfg: CAMDConfig):
    """vmapped ``round_update_assign`` over a batch of requests.

    This is the serving engine's round entry point: when a macro-step
    returns several simultaneously-completed rounds, they all fold in one
    jit call instead of one dispatch per request."""
    return jax.vmap(lambda s, i: round_update_assign(cfg, s, i))


def batched_init(cfg: CAMDConfig, n: int, emb_dim: int, vocab: int) -> CAMDState:
    one = init_state(cfg, emb_dim, vocab)
    return jax.tree.map(lambda x: jnp.broadcast_to(x, (n,) + x.shape).copy(), one)


def score_candidates(cfg: CAMDConfig, token_logprobs, mask, *, hidden=None,
                     token_embs=None, visual_feats=None, text_feats=None,
                     impl: str = "xla"):
    """Convenience wrapper: Eq. 12 with this config's λ weights."""
    return scoring.evidence_weighted_score(
        token_logprobs, mask, hidden=hidden, token_embs=token_embs,
        visual_feats=visual_feats, text_feats=text_feats,
        lambda_g=cfg.lambda_g, lambda_c=cfg.lambda_c, impl=impl)
