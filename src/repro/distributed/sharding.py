"""Logical-axis sharding rules with divisibility fallback.

One rule table maps every parameter / cache / batch leaf to a
PartitionSpec given the mesh. A dim is sharded on a mesh axis ONLY if its
size is divisible by the axis size — otherwise that dim stays replicated
(e.g. yi-34b's 56 query heads are not divisible by model=16, so the head
dim replicates and the QKV matmuls shard on d_model via FSDP instead).
This keeps every (arch × shape × mesh) combination lowering without
per-arch special cases; per-arch overrides remain possible via
``ShardingRules``.

Axis roles:
  "model"          tensor parallelism — MLP hidden, attention heads,
                   per-expert FFN width, vocab
  "data" (+"pod")  batch/data parallelism; also FSDP parameter sharding
                   and MoE expert parallelism (experts live with data
                   shards; dispatch/combine einsums become all-to-alls)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.config import ModelConfig, ShapeConfig

# parameters whose *contracting* dim is model-sharded (Megatron row-parallel)
ROW_PARALLEL = {"wo", "w_down", "out_proj"}
# parameters that stay replicated regardless of shape
ALWAYS_REPLICATED = {"router", "lam", "A_log", "D", "dt_bias", "norm",
                     "scale", "bias", "conv_b", "q_norm", "k_norm",
                     "pos_emb"}


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    model_axis: str = "model"
    fsdp: bool = True           # shard params' non-model dim over data axes
    expert_axis: str = "data"   # MoE expert-parallel axis


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Data-parallel axes: ("pod", "data") on the multi-pod mesh."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _axsize(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def _maybe(mesh: Mesh, dim_size: int, axes) -> Optional[Any]:
    """axes if the mesh has them all and dim_size is divisible by their
    product, else None (replicate). Missing axes happen on purpose:
    serving meshes may carry only a "data" axis."""
    if axes is not None:
        named = (axes,) if isinstance(axes, str) else axes
        if any(a not in mesh.axis_names for a in named):
            return None
    return axes if dim_size % _axsize(mesh, axes) == 0 else None


def _norm(ax):
    """jax<=0.4 PartitionSpec treats ("data",) != "data"; normalize
    singleton axis tuples so specs compare equal across jax versions."""
    if isinstance(ax, tuple) and len(ax) == 1:
        return ax[0]
    return ax


def _leaf_name(path) -> str:
    for p in reversed(path):
        if isinstance(p, jax.tree_util.DictKey):
            return p.key
    return ""


def _path_names(path):
    return [p.key for p in path if isinstance(p, jax.tree_util.DictKey)]


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def _param_spec(mesh: Mesh, rules: ShardingRules, path, shape) -> P:
    names = _path_names(path)
    # rule lookups must see the *parameter* name, not the 'kernel' leaf
    # inside a dense-params dict (wo = {"kernel": ...}).
    name = names[-1] if names[-1] != "kernel" or len(names) < 2 else names[-2]
    dp = dp_axes(mesh)
    model = rules.model_axis
    nd = len(shape)
    if nd <= 1 or name in ALWAYS_REPLICATED or \
            set(names) & ALWAYS_REPLICATED:
        return P()

    # stacked-over-layers params have a leading layer dim — never sharded
    stacked = any(n in ("super", "dec_super", "enc_super") for n in names)
    off = 1 if stacked and nd >= 3 else 0
    eff = shape[off:]
    if len(eff) == 1:
        return P()

    def build(dims):
        return P(*([None] * off + [_norm(d) for d in dims]))

    # MoE expert weights: (E, d, f) / (E, f, d)
    if name in ("w_gate", "w_up", "w_down") and len(eff) == 3:
        e_ax = _maybe(mesh, eff[0], dp if len(dp) > 1 else rules.expert_axis)
        if name == "w_down":   # (E, f, d): f is contracting/model dim
            f_ax = _maybe(mesh, eff[1], model)
            return build([e_ax, f_ax, None])
        f_ax = _maybe(mesh, eff[2], model)
        return build([e_ax, None, f_ax])

    # embedding / unembedding: (V, d) or (d, V). Vocab over "model" ONLY —
    # FSDP-sharding d here makes the unembed matmul's contracting dim
    # conflict with the batch's "data" sharding and GSPMD resolves it by
    # all-gathering the global batch of logits (measured: 40 GB/dev on
    # qwen3 train_4k). Vocab/16 already bounds the table per device.
    if name == "table":
        v_ax = _maybe(mesh, eff[0], model)
        return build([v_ax, None])
    if "unembed" in names:
        v_ax = _maybe(mesh, eff[1], model)
        return build([None, v_ax])

    # conv weights (W, ch): channel dim over model
    if name == "conv_w":
        return build([None, _maybe(mesh, eff[1], model)])

    if len(eff) == 2:
        if name in ROW_PARALLEL:
            # (contract=model_dim, out=d_model): FSDP-sharding the OUTPUT
            # dim over "data" propagates a d-over-data activation sharding
            # that conflicts with the batch's data sharding — GSPMD then
            # batch-gathers the residual stream (90 GB/dev measured,
            # §Perf iteration 12). Row-parallel keeps d replicated.
            m_ax = _maybe(mesh, eff[0], model)
            return build([m_ax, None])
        m_ax = _maybe(mesh, eff[1], model)
        d_ax = _maybe(mesh, eff[0], dp) if rules.fsdp else None
        return build([d_ax, m_ax])
    return P()


def param_specs(cfg: ModelConfig, params_shapes, mesh: Mesh,
                rules: ShardingRules = ShardingRules()):
    """params_shapes: pytree of ShapeDtypeStruct (from jax.eval_shape)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _param_spec(mesh, rules, path, leaf.shape),
        params_shapes)


def opt_state_specs(cfg: ModelConfig, opt_shapes, mesh: Mesh,
                    rules: ShardingRules = ShardingRules()):
    """m/v mirror params; step is replicated."""

    def spec(path, leaf):
        if len(leaf.shape) == 0:
            return P()
        # strip the leading OptState field from the path for rule lookup
        return _param_spec(mesh, rules, path, leaf.shape)

    return jax.tree_util.tree_map_with_path(spec, opt_shapes)


# ---------------------------------------------------------------------------
# Cache / batch
# ---------------------------------------------------------------------------

def _cache_spec(mesh: Mesh, rules: ShardingRules, path, shape) -> P:
    name = _leaf_name(path)
    names = _path_names(path)
    dp = dp_axes(mesh)
    model = rules.model_axis
    stacked = any(n in ("super", "self") for n in names) or \
        name in ("cross_k", "cross_v")
    off = 1 if stacked else 0
    eff = shape[off:]

    def build(dims):
        return P(*([None] * off + [_norm(d) for d in dims]))

    if name in ("k_pages", "v_pages"):
        # Serving page pool: batchless (P, ps, Hkv, hd), layer-stacked
        # to (n_super, P, ...). Sharded on the PAGE axis over the data
        # shards — the host allocator's per-shard page-id ranges match
        # these boundaries, so slots referencing their own shard's pages
        # keep the decode gather/scatter local.
        from repro.models.attention import paged_pool_page_axis
        pg = paged_pool_page_axis(len(shape))
        p_ax = _maybe(mesh, shape[pg], dp)
        dims = [None] * len(shape)
        dims[pg] = _norm(p_ax)
        return P(*dims)
    if name in ("k_scale", "v_scale"):
        # Quantized-pool absmax scales: (P, ps, Hkv), layer-stacked to
        # (n_super, P, ps, Hkv). Sharded on the same page axis as their
        # value pools so dequant never crosses shards.
        pg = len(shape) - 3
        p_ax = _maybe(mesh, shape[pg], dp)
        dims = [None] * len(shape)
        dims[pg] = _norm(p_ax)
        return P(*dims)
    if name == "block_table":
        return P(_norm(_maybe(mesh, shape[0], dp)), None)
    if name == "pos":
        return P(_norm(_maybe(mesh, shape[0], dp)))
    if name in ("k", "v") or name in ("cross_k", "cross_v"):
        # (B, S, Hkv, hd). Prefer head sharding; when Hkv is not divisible
        # (MQA / small GQA) fall back to *context parallelism*: shard the
        # sequence dim over "model" — decode attention then runs as
        # sharded flash-decode partials combined by GSPMD collectives.
        b_ax = _maybe(mesh, eff[0], dp)
        h_ax = _maybe(mesh, eff[2], model)
        if h_ax is not None:
            return build([b_ax, None, h_ax, None])
        s_ax = _maybe(mesh, eff[1], model)
        return build([b_ax, s_ax, None, None])
    if name == "ssd":        # (B, H, P, N)
        return build([_maybe(mesh, eff[0], dp), _maybe(mesh, eff[1], model),
                      None, None])
    if name == "conv":       # (B, W-1, ch)
        return build([_maybe(mesh, eff[0], dp), None,
                      _maybe(mesh, eff[2], model)])
    if name == "h":          # (B, w)
        return build([_maybe(mesh, eff[0], dp), _maybe(mesh, eff[1], model)])
    return P()


def cache_specs(cfg: ModelConfig, cache_shapes, mesh: Mesh,
                rules: ShardingRules = ShardingRules()):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _cache_spec(mesh, rules, path, leaf.shape),
        cache_shapes)


def batch_specs(shape_cfg: ShapeConfig, batch_shapes, mesh: Mesh):
    dp = dp_axes(mesh)

    def spec(path, leaf):
        name = _leaf_name(path)
        if name in ("tokens", "labels", "evidence", "token"):
            b_ax = dp if leaf.shape[0] % _axsize(mesh, dp) == 0 else None
            return P(*([_norm(b_ax)] + [None] * (len(leaf.shape) - 1)))
        return P()

    return jax.tree_util.tree_map_with_path(spec, batch_shapes)


def to_shardings(mesh: Mesh, specs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Serving (mesh-parallel decode batch + page-axis-sharded KV pools)
# ---------------------------------------------------------------------------

def batch_leading_spec(mesh: Mesh, shape) -> P:
    """Shard a serving-state leaf on its leading (decode-batch) dim over
    the data axes, everything else replicated."""
    if len(shape) == 0:
        return P()
    dp = dp_axes(mesh)
    b_ax = _maybe(mesh, shape[0], dp)
    return P(*([_norm(b_ax)] + [None] * (len(shape) - 1)))


def engine_state_specs(cfg: ModelConfig, state, mesh: Mesh,
                       rules: ShardingRules = ShardingRules()):
    """PartitionSpec tree for a ``ServeEngine`` ``EngineState``.

    The decode batch (every per-slot leaf: tokens, aggregates, out
    buffers, active masks, limits, cache ``pos``/``block_table`` and
    dense per-slot cache entries) shards on its leading dim over the
    data axes; paged KV pools shard on the page axis with the same
    shard count, so a slot's block-table lookups resolve to its own
    shard's pages (see ``models.attention.paged_pool_page_axis``).
    Works on a live state or a ShapeDtypeStruct tree; ``state`` must be
    a NamedTuple whose first field is the cache pytree.
    """
    cache = cache_specs(cfg, state.cache, mesh, rules)
    rest = {f: batch_leading_spec(mesh, getattr(state, f).shape)
            for f in state._fields if f != "cache"}
    return type(state)(cache=cache, **rest)


def prefill_shard_ids(dp: int, prefill_shards: int) -> Tuple[int, ...]:
    """Data-shard ids eligible to host prompt/chunk pages under
    prefill/decode disaggregation: the FIRST ``prefill_shards`` shards
    of the page axis (0 = no disaggregation — every shard hosts its own
    slots' prompt pages). Decode slots on the remaining shards read the
    prompt pages cross-shard through the block table — pages are the
    transfer currency, GSPMD inserts the gather; tail and frontier
    pages always stay on the slot's own shard."""
    assert 0 <= prefill_shards <= dp, (prefill_shards, dp)
    return tuple(range(prefill_shards or dp))


def serve_param_specs(cfg: ModelConfig, params, mesh: Mesh,
                      rules: ShardingRules = ShardingRules()):
    """Parameter placement for serving: replicate when the mesh has no
    real model axis; otherwise reuse the training tensor-parallel rules
    (without FSDP — decode batches are small and a gather per step
    would dominate)."""
    if rules.model_axis not in mesh.axis_names or \
            mesh.shape[rules.model_axis] <= 1:
        return jax.tree.map(lambda _: P(), params)
    shapes = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                          params)
    return param_specs(cfg, shapes, mesh,
                       dataclasses.replace(rules, fsdp=False))
