"""recurrentgemma-2b — Google RecurrentGemma (Griffin), RG-LRU + local attn.

[arXiv:2402.19427]: 26L, d_model=2560, 10 q heads, MQA kv=1, d_ff=7680,
vocab 256000. Block pattern: 2 recurrent (RG-LRU) blocks then 1 local
attention block (1:2 ratio), local window 2048.
"""
from repro.config import LOCAL_ATTN, RGLRU, ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    d_ff=7680,
    vocab_size=256000,
    head_dim=256,
    local_window=2048,
    block_pattern=(RGLRU, RGLRU, LOCAL_ATTN),
    mlp_activation="gelu",
    rglru=RGLRUConfig(lru_width=2560),
    tie_embeddings=True,
    source="arXiv:2402.19427",
)
