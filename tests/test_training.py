"""Training substrate tests: optimizer math, schedules, loss, checkpoint,
end-to-end convergence on the synthetic task."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import TrainConfig
from repro.configs import get_config
from repro.data import lm_batches
from repro.models import build_model
from repro.training import (init_opt_state, learning_rate, load_checkpoint,
                            make_train_step, save_checkpoint, train)
from repro.training.loss import cross_entropy
from repro.training.optimizer import adamw_update, clip_by_global_norm


def test_adamw_matches_reference_scalar():
    """One AdamW step on a scalar vs. hand-computed update."""
    cfg = TrainConfig(learning_rate=0.1, weight_decay=0.0, warmup_steps=0,
                      total_steps=10, schedule="constant", grad_clip=1e9)
    params = {"w": jnp.asarray(1.0)}
    grads = {"w": jnp.asarray(0.5)}
    state = init_opt_state(params)
    new, state, _ = adamw_update(cfg, params, grads, state)
    # bias-corrected m̂ = g, v̂ = g² on step 1 ⇒ Δ = lr * g/(|g|+eps) ≈ lr
    np.testing.assert_allclose(float(new["w"]), 1.0 - 0.1, rtol=1e-4)


def test_weight_decay_pulls_to_zero():
    cfg = TrainConfig(learning_rate=0.1, weight_decay=0.5, warmup_steps=0,
                      schedule="constant", grad_clip=1e9)
    params = {"w": jnp.asarray(2.0)}
    state = init_opt_state(params)
    new, _, _ = adamw_update(cfg, params, {"w": jnp.asarray(0.0)}, state)
    assert float(new["w"]) < 2.0


def test_grad_clip():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(norm), 20.0, rtol=1e-5)
    np.testing.assert_allclose(
        float(jnp.linalg.norm(clipped["a"])), 1.0, rtol=1e-5)


def test_lr_schedule_shapes():
    cfg = TrainConfig(learning_rate=1.0, warmup_steps=10, total_steps=110,
                      schedule="cosine")
    lrs = [float(learning_rate(cfg, jnp.asarray(s))) for s in
           (0, 5, 10, 60, 110)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5, rel=1e-5)
    assert lrs[2] == pytest.approx(1.0, rel=1e-5)
    assert 0 < lrs[3] < 1.0
    assert lrs[4] == pytest.approx(0.0, abs=1e-6)


def test_cross_entropy_uniform():
    V = 7
    logits = jnp.zeros((2, 3, V))
    labels = jnp.zeros((2, 3), jnp.int32)
    loss, metrics = cross_entropy(logits, labels, z_loss_coef=0.0)
    np.testing.assert_allclose(float(loss), np.log(V), rtol=1e-5)


def test_cross_entropy_mask():
    logits = jnp.zeros((1, 2, 4)).at[0, 0, 1].set(100.0)
    labels = jnp.asarray([[1, 2]])
    mask = jnp.asarray([[1.0, 0.0]])
    loss, _ = cross_entropy(logits, labels, mask, z_loss_coef=0.0)
    assert float(loss) < 1e-3  # masked position ignored


def test_bf16_opt_state_trains():
    cfg = get_config("qwen3-0.6b").reduced().with_overrides(dtype="float32")
    model = build_model(cfg, jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    opt = init_opt_state(params, jnp.bfloat16)
    step = jax.jit(make_train_step(model, TrainConfig()))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    p2, opt2, m = step(params, opt, batch)
    assert np.isfinite(float(m["loss"]))
    assert jax.tree.leaves(opt2.m)[0].dtype == jnp.bfloat16


def test_loss_decreases_on_synthetic_task():
    cfg = get_config("qwen3-0.6b").reduced().with_overrides(dtype="float32")
    model = build_model(cfg, jnp.float32)
    data = ({"tokens": jnp.asarray(b["tokens"]),
             "labels": jnp.asarray(b["labels"])}
            for b in lm_batches(cfg.vocab_size, 8, 64, seed=0))
    _, _, hist = train(model, TrainConfig(total_steps=25, warmup_steps=5,
                                          learning_rate=1e-3),
                       data, steps=25, log_every=24)
    assert hist[-1]["loss"] < hist[0]["loss"] * 0.8


def test_checkpoint_roundtrip_bf16(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.float32),
                  "d": jnp.asarray(3, jnp.int32)}}
    path = os.path.join(tmp_path, "ck")
    save_checkpoint(path, tree, step=42)
    restored, step = load_checkpoint(path, tree)
    assert step == 42
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert x.dtype == y.dtype
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))
