"""Engine regression tests: token-accounting invariants across all four
modes, CAMD's budget advantage on easy batches, slot-recycle leak
checks, and determinism under a fixed seed.

These pin the *bookkeeping* of the serving engine — `tokens_spent` is
the quantity every efficiency claim in the paper is denominated in, so
it must exactly match what was emitted.
"""
import pytest

from conftest import _mk_engine as _mk_base, _submit
from repro.config import CAMDConfig

MODES = ["camd", "best_of_n", "self_consistency", "greedy"]


def _mk_engine(model, params, **kw):
    kw.setdefault("n_candidates", 4)
    return _mk_base(model, params, **kw)


@pytest.mark.parametrize("mode", MODES)
def test_tokens_spent_matches_emitted(small_model, mode):
    """tokens_spent == sum of candidate lengths == emitted token arrays,
    and the engine-wide counter equals the sum over requests."""
    cfg, model, params = small_model
    eng = _mk_engine(model, params, mode=mode)
    _submit(eng, cfg, 4)
    res = eng.run()
    assert len(res) == 4
    for r in res:
        assert r.tokens_spent == sum(c["n"] for c in r.candidates)
        for c in r.candidates:
            assert c["n"] == len(c["tokens"])
            assert 1 <= c["n"] <= eng.max_new
    assert eng.total_tokens == sum(r.tokens_spent for r in res)


def test_camd_within_best_of_n_budget_on_easy(small_model):
    """On easy synthetic batches (everything clusters), CAMD must spend
    no more than the fixed best-of-N budget, per request."""
    cfg, model, params = small_model
    camd_kw = dict(camd=CAMDConfig(samples_per_round=2, max_rounds=4,
                                   min_samples=2, max_clusters=8,
                                   cluster_threshold=0.0))
    eng_a = _mk_engine(model, params, mode="camd", **camd_kw)
    _submit(eng_a, cfg, 3)
    res_a = {r.uid: r for r in eng_a.run()}
    eng_f = _mk_engine(model, params, mode="best_of_n", n_candidates=8)
    _submit(eng_f, cfg, 3)
    res_f = {r.uid: r for r in eng_f.run()}
    for uid in res_a:
        assert res_a[uid].tokens_spent <= res_f[uid].tokens_spent
    assert sum(r.tokens_spent for r in res_a.values()) < \
        sum(r.tokens_spent for r in res_f.values())


@pytest.mark.parametrize("mode", MODES)
def test_slot_recycle_never_leaks(small_model, mode):
    """More requests than slots: every request completes, every slot is
    returned, and no request is double-finished."""
    cfg, model, params = small_model
    eng = _mk_engine(model, params, mode=mode, slots=4)
    _submit(eng, cfg, 7)
    res = eng.run()
    assert sorted(r.uid for r in res) == list(range(7))
    assert all(eng._slot_req[s] == -1 for s in range(eng.B))
    assert not eng._queue
    assert all(i["done"] for i in eng._reqs.values())


def test_seeded_determinism(small_model):
    """Two engines with identical seeds must emit identical tokens and
    identical accounting — the property every paged-vs-contiguous and
    ablation comparison in this repo rests on."""
    cfg, model, params = small_model
    outs = []
    for _ in range(2):
        eng = _mk_engine(model, params, mode="camd")
        _submit(eng, cfg, 3)
        outs.append(sorted(eng.run(), key=lambda r: r.uid))
    for a, b in zip(*outs):
        assert a.tokens.tolist() == b.tokens.tolist()
        assert a.tokens_spent == b.tokens_spent
        assert a.rounds == b.rounds
