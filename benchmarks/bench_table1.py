"""Paper Table 1 / Table 2 — benchmark-suite comparison.

The proprietary checkpoints/datasets are simulated (DESIGN.md §6.5): each
"benchmark" is a difficulty population with its own tail profile
(comprehensive / general-VQA / hallucination-style), each "base model" is
a SimulatedDecoder with its own score calibration. We compare the same
decoding strategies the paper does — greedy, best-of-N, self-consistency
(≈ the paper's fixed baselines) and CAMD — and report accuracy plus token
cost per suite. The paper's claim reproduced here: CAMD matches or beats
every fixed strategy on accuracy while spending fewer tokens, across
suites and "models".
"""
from __future__ import annotations

import numpy as np

from benchmarks.camd_sim import run_camd, run_fixed_n
from repro.config import CAMDConfig
from repro.data.tasks import SimulatedDecoder

SUITES = {
    # name: (tail, alpha, easy_frac)  — difficulty profile of the benchmark
    "comprehensive": ("heavy", 0.45, 0.45),
    "general_vqa": ("heavy", 0.6, 0.6),
    "hallucination": ("stretched", 0.5, 0.3),
}
MODELS = {
    # "base MLLM" calibrations: (score_gap, score_noise)
    "llava-like": (2.5, 0.5),
    "instructblip-like": (1.8, 0.6),
    "video-llava-like": (2.2, 0.55),
}


def _population(sim, n, easy_frac):
    n_easy = int(n * easy_frac)
    easy = sim.rng.uniform(0.55, 0.95, size=n_easy)
    hard = sim.sample_difficulty(n - n_easy)
    return np.concatenate([easy, hard])


def run(n_instances: int = 400, seed: int = 0, verbose: bool = True):
    camd_cfg = CAMDConfig(samples_per_round=2, max_rounds=16, min_samples=2,
                          max_clusters=8, delta=0.03, score_scale=1.5)
    table = []
    for suite, (tail, alpha, easy_frac) in SUITES.items():
        for model, (gap, noise) in MODELS.items():
            sim = SimulatedDecoder(tail=tail, alpha=alpha, seed=seed,
                                   score_gap=gap, score_noise=noise)
            diffs = _population(sim, n_instances, easy_frac)
            row = {"suite": suite, "model": model}
            greedy = run_fixed_n(sim, diffs, 1)
            bon = run_fixed_n(sim, diffs, 8, select="best")
            sc = run_fixed_n(sim, diffs, 8, select="majority")
            camd = run_camd(sim, diffs, camd_cfg, seed=seed)
            for name, out in (("greedy", greedy), ("bo8", bon),
                              ("sc8", sc), ("camd", camd)):
                row[f"{name}_acc"] = float(np.mean(out["accuracy"]))
                row[f"{name}_tokens"] = float(np.mean(out["tokens"]))
            row["camd_gain_vs_greedy"] = row["camd_acc"] - row["greedy_acc"]
            row["camd_vs_bo8_tokens"] = row["camd_tokens"] / row["bo8_tokens"]
            table.append(row)
            if verbose:
                print(f"  {suite:>14}/{model:<18} greedy={row['greedy_acc']:.3f} "
                      f"bo8={row['bo8_acc']:.3f} sc8={row['sc8_acc']:.3f} "
                      f"camd={row['camd_acc']:.3f} "
                      f"(+{row['camd_gain_vs_greedy']*100:.1f} vs greedy, "
                      f"{row['camd_vs_bo8_tokens']*100:.0f}% of bo8 tokens)")

    gains = [r["camd_gain_vs_greedy"] for r in table]
    beats_sc = [r["camd_acc"] > r["sc8_acc"] for r in table]
    near_bon = [r["camd_acc"] >= r["bo8_acc"] - 0.035 for r in table]
    ratios = [r["camd_vs_bo8_tokens"] for r in table]
    claims = {
        "avg_gain_vs_greedy": float(np.mean(gains)),
        "beats_self_consistency_everywhere": bool(all(beats_sc)),
        "within_3.5pts_of_bo8_everywhere": bool(all(near_bon)),
        "avg_token_ratio_vs_bo8": float(np.mean(ratios)),
        "cheaper_than_bo8_on_average": bool(np.mean(ratios) < 1.0),
    }
    if verbose:
        print(f"  avg CAMD gain vs greedy: +{claims['avg_gain_vs_greedy']*100:.1f}pts "
              f"(paper: +3.5 on real ckpts); beats SC everywhere: "
              f"{claims['beats_self_consistency_everywhere']}; within 3.5pts of "
              f"bo8: {claims['within_3.5pts_of_bo8_everywhere']} at "
              f"{claims['avg_token_ratio_vs_bo8']*100:.0f}% of its tokens. "
              f"Residual bo8 gap = false-consensus stops (see EXPERIMENTS.md).")
    return {"table": table, "claims": claims}


if __name__ == "__main__":
    run()
