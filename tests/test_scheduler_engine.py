"""Real-engine scheduler tests (no hypothesis needed): global-budget
hard invariants, graceful budget starvation, coverage policy across
modes/impls, and per-slot limit bookkeeping.
"""
import pytest

from conftest import _mk_engine as _mk_base, _submit
from repro.config import PagedKVConfig


def _mk(model, params, **kw):
    defaults = dict(slots=4, cache_len=32, max_new=6, n_candidates=3,
                    paged_kv=PagedKVConfig(page_size=8))
    defaults.update(kw)
    return _mk_base(model, params, **defaults)


@pytest.mark.parametrize("policy", ["fifo", "coverage"])
@pytest.mark.parametrize("budget", [7, 13, 24, 50])
def test_budget_never_exceeded_real_engine(tiny_model, policy, budget):
    """Hard invariant on the real engine, odd budgets included (a budget
    of 7 can only fund 3 candidates of >= 2 tokens): emitted tokens
    never pass the budget and the engine terminates (no spin when the
    remainder is unfundable)."""
    cfg, model, params = tiny_model
    eng = _mk(model, params, mode="camd", sched_policy=policy,
              global_budget=budget)
    _submit(eng, cfg, 4, plen=5)
    res = eng.run()
    assert len(res) == 4                     # starved uids still report
    assert eng.total_tokens <= budget
    assert sum(r.tokens_spent for r in res) == eng.total_tokens
    assert all(eng._slot_req[s] == -1 for s in range(eng.B))
    sched = eng.sched_stats()
    assert sched["spent"] == eng.total_tokens
    assert sched["committed"] == 0


def test_budget_starved_results_are_explicit(tiny_model):
    """A budget too small for everyone: served requests report real
    candidates, starved ones come back empty and are listed."""
    cfg, model, params = tiny_model
    eng = _mk(model, params, mode="best_of_n", sched_policy="coverage",
              global_budget=12, macro_steps=8)
    _submit(eng, cfg, 5, plen=5)
    res = {r.uid: r for r in eng.run()}
    assert len(res) == 5
    served = [u for u, r in res.items() if r.n_candidates > 0]
    starved = [u for u, r in res.items() if r.n_candidates == 0]
    assert served and starved
    assert sorted(starved) == sorted(eng.starved_uids)
    for u in starved:
        assert res[u].tokens.size == 0 and res[u].tokens_spent == 0


@pytest.mark.parametrize("mode", ["camd", "best_of_n", "self_consistency",
                                  "greedy"])
def test_coverage_policy_completes_all_modes(tiny_model, mode):
    cfg, model, params = tiny_model
    eng = _mk(model, params, mode=mode, sched_policy="coverage",
              sched_kwargs=dict(decline_low_gain=False))
    _submit(eng, cfg, 5, plen=5)
    res = eng.run()
    assert sorted(r.uid for r in res) == list(range(5))
    assert all(r.n_candidates >= 1 for r in res)


def test_coverage_paged_pool_conservation_under_budget(tiny_model):
    """Budget-limited paged serving with slot recycling: page
    conservation and reservation accounting survive tight limits."""
    cfg, model, params = tiny_model
    eng = _mk(model, params, mode="camd", impl="paged",
              sched_policy="coverage", global_budget=30, macro_steps=8,
              paged_kv=PagedKVConfig(page_size=8, num_pages=11))
    _submit(eng, cfg, 5, plen=5)
    res = eng.run()
    assert len(res) == 5
    assert eng.total_tokens <= 30
    eng.pool.check()
    assert eng.pool.in_use == 0
    assert eng._reserved == 0


def test_scheduler_limit_caps_candidate_length(tiny_model):
    """A granted limit below max_new ends candidates on device exactly
    at the limit (eos_id=-1 so nothing ends early)."""
    cfg, model, params = tiny_model
    eng = _mk(model, params, mode="best_of_n", n_candidates=2,
              sched_policy="fifo", global_budget=8, eos_id=-1,
              macro_steps=8)
    _submit(eng, cfg, 1, plen=5)
    (r,) = eng.run()
    # budget 8, want 2 => take 2, limit 4 each
    assert r.n_candidates == 2
    assert all(c["n"] == 4 for c in r.candidates)
    assert eng.total_tokens == 8


def test_coverage_fair_shares_depth_not_just_width(tiny_model):
    """Regression: with want=1 items (greedy traffic) width cannot be
    shrunk, so the coverage policy must fair-share the per-candidate
    token LIMIT — budget 20 across 4 greedy requests serves all four at
    5 tokens each instead of 8/8/4/starved."""
    cfg, model, params = tiny_model
    eng = _mk(model, params, mode="greedy", sched_policy="coverage",
              global_budget=20, eos_id=-1, macro_steps=8, cache_len=32)
    _submit(eng, cfg, 4, plen=5)
    res = sorted(eng.run(), key=lambda r: r.uid)
    assert [r.tokens_spent for r in res] == [5, 5, 5, 5]
    assert not eng.starved_uids
    assert eng.total_tokens == 20


def test_fifo_budget_zero_is_default(tiny_model):
    """global_budget=0 disables budgeting entirely — identical streams
    to an engine that never heard of budgets."""
    cfg, model, params = tiny_model
    outs = []
    for kw in (dict(), dict(sched_policy="fifo", global_budget=0)):
        eng = _mk(model, params, mode="camd", **kw)
        _submit(eng, cfg, 3, plen=5)
        outs.append([r.tokens.tolist()
                     for r in sorted(eng.run(), key=lambda r: r.uid)])
    assert outs[0] == outs[1]
