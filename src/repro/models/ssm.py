"""Mamba-2 block (SSD — state-space duality, arXiv:2405.21060).

TPU adaptation notes: the SSD *chunked* form is used for train/prefill —
within-chunk terms are dense matmuls (MXU-friendly, chunk_size aligned to
the 128 lane width when possible) and the inter-chunk recurrence is a
`lax.scan` over chunk states (nc = L / Q steps, O(L/Q) sequential depth).
Decode is the O(1) recurrent update on a (B, H, P, N) state — no KV cache,
which is what makes `long_500k` natural for this family.

Single B/C group (G=1) as in the 780m reference config.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import dense, dense_init


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    inner = s.expand * cfg.d_model
    heads = inner // s.head_dim
    return inner, heads, s.head_dim, s.state_dim, s.conv_width


def ssm_init(key, cfg: ModelConfig, dtype=jnp.float32):
    inner, H, P, N, W = _dims(cfg)
    d = cfg.d_model
    k1, k2, k3, k4 = jax.random.split(key, 4)
    conv_ch = inner + 2 * N
    return {
        "in_proj": dense_init(k1, d, 2 * inner + 2 * N + H, dtype),
        "conv_w": (jax.random.normal(k2, (W, conv_ch)) * W ** -0.5).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype=dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), dtype=jnp.float32),
        "dt_bias": jnp.zeros((H,), dtype=jnp.float32),
        "norm": jnp.ones((inner,), dtype=dtype),
        "out_proj": dense_init(k3, inner, d, dtype),
    }


def _segsum(a):
    """a: (..., Q). Returns (..., Q, Q) with L[i,j] = sum_{k=j+1..i} a_k
    for i >= j, -inf above the diagonal."""
    Q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), dtype=bool))
    return jnp.where(mask, seg, -jnp.inf)


def _split_proj(params, cfg: ModelConfig, u):
    inner, H, P, N, W = _dims(cfg)
    zxbcdt = dense(params["in_proj"], u)
    z, xbc, dt = jnp.split(zxbcdt, [inner, 2 * inner + 2 * N], axis=-1)
    return z, xbc, dt  # xbc holds [x, B, C] pre-conv


def _gated_norm(params, y, z, eps):
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = y.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * params["norm"].astype(jnp.float32)).astype(z.dtype)


def ssm_prefill(params, cfg: ModelConfig, u, lengths=None) -> Tuple[jax.Array, Dict]:
    """u: (B, L, d). Returns (y (B,L,d), state for decode seeding).

    ``lengths``: optional (B,) int32 true per-row lengths for
    right-padded batched prefill. Padded steps get dt=0 (identity
    transition, zero contribution) so each row's final state matches a
    per-row prefill at its true length up to float accumulation order
    (the chunk/cumsum shapes still depend on the padded L, so this is
    allclose-, not byte-, exact); per-row outputs beyond lengths-1 are
    garbage and must be ignored by the caller.
    """
    inner, H, P, N, W = _dims(cfg)
    Bsz, Lreal, _ = u.shape
    Q = min(cfg.ssm.chunk_size, Lreal)
    # pad to a chunk multiple; padded steps get dt=0 => identity transition,
    # zero contribution, so outputs and the final state are exact.
    Lpad = (-Lreal) % Q
    L = Lreal + Lpad

    z, xbc, dt = _split_proj(params, cfg, u)
    if lengths is None:
        conv_tail = xbc[:, max(0, Lreal - (W - 1)):, :]  # real inputs for decode seed
        if Lreal < W - 1:  # short prompt: left-pad the conv window with zeros
            conv_tail = jnp.concatenate(
                [jnp.zeros((Bsz, W - 1 - Lreal, xbc.shape[-1]), xbc.dtype),
                 conv_tail], axis=1)
    if Lpad:
        zpad = jnp.zeros((Bsz, Lpad, xbc.shape[-1]), xbc.dtype)
        xbc = jnp.concatenate([xbc, zpad], axis=1)
        dt = jnp.concatenate([dt, jnp.zeros((Bsz, Lpad, H), dt.dtype)], axis=1)
    nc = L // Q
    # causal depthwise conv over [x, B, C]
    pad = jnp.zeros((Bsz, W - 1, xbc.shape[-1]), xbc.dtype)
    xbc_pad = jnp.concatenate([pad, xbc], axis=1)
    if lengths is not None:
        # per-row decode seed: the last W-1 real inputs of each row, in
        # xbc_pad coordinates (input j sits at pad position j + W - 1,
        # so rows shorter than W-1 pick up the left zero-pad exactly).
        idx = lengths[:, None] + jnp.arange(W - 1)[None, :]
        conv_tail = jnp.take_along_axis(xbc_pad, idx[:, :, None], axis=1)
    conv = sum(xbc_pad[:, i:i + L] * params["conv_w"][i] for i in range(W))
    conv = jax.nn.silu(conv + params["conv_b"])
    x, B_in, C_in = jnp.split(conv, [inner, inner + N], axis=-1)

    x = x.reshape(Bsz, L, H, P)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])   # (B,L,H)
    if lengths is not None:
        valid = (jnp.arange(L)[None, :] < lengths[:, None])[..., None]
        dt = jnp.where(valid, dt, 0.0)
    elif Lpad:
        valid = (jnp.arange(L) < Lreal)[None, :, None]
        dt = jnp.where(valid, dt, 0.0)
    A = -jnp.exp(params["A_log"])                                      # (H,)
    dA = dt * A                                                        # (B,L,H)
    xbar = x.astype(jnp.float32) * dt[..., None]                       # (B,L,H,P)
    Bc = B_in.astype(jnp.float32).reshape(Bsz, L, N)
    Cc = C_in.astype(jnp.float32).reshape(Bsz, L, N)

    # chunk
    def chunked(t, shape):
        return t.reshape((Bsz, nc, Q) + shape)
    dA_c = chunked(dA, (H,)).transpose(0, 3, 1, 2)                     # (B,H,nc,Q)
    x_c = chunked(xbar, (H, P))                                        # (B,nc,Q,H,P)
    B_c = chunked(Bc, (N,))                                            # (B,nc,Q,N)
    C_c = chunked(Cc, (N,))

    dA_cumsum = jnp.cumsum(dA_c, axis=-1)                              # (B,H,nc,Q)
    Lmat = jnp.exp(_segsum(dA_c))                                      # (B,H,nc,Q,Q)
    # within-chunk (diagonal blocks)
    y_diag = jnp.einsum("bcln,bcsn,bhcls,bcshp->bclhp",
                        C_c, B_c, Lmat, x_c)
    # per-chunk end states
    decay_states = jnp.exp(dA_cumsum[..., -1:] - dA_cumsum)            # (B,H,nc,Q)
    states = jnp.einsum("bcln,bhcl,bclhp->bchpn", B_c, decay_states, x_c)
    chunk_decay = jnp.exp(dA_cumsum[..., -1])                          # (B,H,nc)

    # inter-chunk recurrence: scan over chunks
    def body(prev, inp):
        st, dec = inp                                                  # (B,H,P,N),(B,H)
        new = prev * dec[..., None, None] + st
        return new, prev                                               # emit state *entering* the chunk

    init = jnp.zeros((Bsz, H, P, N), jnp.float32)
    final_state, states_in = jax.lax.scan(
        body, init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(2, 0, 1)))
    states_in = states_in.transpose(1, 0, 2, 3, 4)                     # (B,nc,H,P,N)

    state_decay_out = jnp.exp(dA_cumsum)                               # (B,H,nc,Q)
    y_off = jnp.einsum("bcln,bchpn,bhcl->bclhp", C_c, states_in, state_decay_out)

    y = (y_diag + y_off).reshape(Bsz, L, H, P)
    y = y + x.astype(jnp.float32) * params["D"][None, None, :, None]
    y = y.reshape(Bsz, L, inner)[:, :Lreal].astype(u.dtype)
    y = _gated_norm(params, y, z, cfg.norm_eps)
    out = dense(params["out_proj"], y)
    state = {"ssd": final_state, "conv": conv_tail}
    return out, state


def make_ssm_state(cfg: ModelConfig, batch: int, dtype):
    inner, H, P, N, W = _dims(cfg)
    return {
        "ssd": jnp.zeros((batch, H, P, N), jnp.float32),
        "conv": jnp.zeros((batch, W - 1, inner + 2 * N), dtype),
    }


def ssm_decode(params, cfg: ModelConfig, u, state) -> Tuple[jax.Array, Dict]:
    """u: (B, 1, d). O(1) recurrent step."""
    inner, H, P, N, W = _dims(cfg)
    Bsz = u.shape[0]
    z, xbc, dt = _split_proj(params, cfg, u)                           # (B,1,·)
    window = jnp.concatenate([state["conv"], xbc], axis=1)             # (B,W,ch)
    conv = jnp.einsum("bwc,wc->bc", window, params["conv_w"]) + params["conv_b"]
    conv = jax.nn.silu(conv)                                           # (B,ch)
    x, B_in, C_in = jnp.split(conv, [inner, inner + N], axis=-1)
    x = x.reshape(Bsz, H, P).astype(jnp.float32)
    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])  # (B,H)
    A = -jnp.exp(params["A_log"])
    dA = jnp.exp(dt1 * A)                                              # (B,H)
    Bc = B_in.astype(jnp.float32)                                      # (B,N)
    Cc = C_in.astype(jnp.float32)
    ssd = state["ssd"] * dA[..., None, None] + \
        jnp.einsum("bhp,bn->bhpn", x * dt1[..., None], Bc)
    y = jnp.einsum("bhpn,bn->bhp", ssd, Cc) + x * params["D"][None, :, None]
    y = y.reshape(Bsz, 1, inner).astype(u.dtype)
    y = _gated_norm(params, y, z, cfg.norm_eps)
    out = dense(params["out_proj"], y)
    return out, {"ssd": ssd, "conv": window[:, 1:, :]}
