"""Shared simulation harness: CAMD + baselines on the heavy-tailed oracle.

Drives ``repro.core.controller`` (the real CAMD math, jit+vmap over all
instances in lockstep) against ``SimulatedDecoder`` trials — the
large-scale stand-in for the paper's MathVista motivating experiment
(DESIGN.md §6.5). All rules see the same per-candidate observables
(score, embedding, answer id); the oracle label is used only for final
accuracy accounting.
"""
from __future__ import annotations

import math
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import CAMDConfig
from repro.core import controller as ctrl
from repro.data.tasks import SimulatedDecoder


def run_camd(sim: SimulatedDecoder, difficulties: np.ndarray,
             cfg: CAMDConfig, seed: int = 0) -> Dict[str, np.ndarray]:
    """Batched CAMD over n instances. Returns accuracy/tokens/samples."""
    n = len(difficulties)
    R = cfg.samples_per_round
    vocab = sim.n_wrong + 1
    states = ctrl.batched_init(cfg, n, sim.emb_dim, vocab)
    update = ctrl.batched_round_update(cfg)
    correct_by_uid = np.zeros((n, cfg.max_rounds * R), bool)

    for rnd in range(cfg.max_rounds):
        stopped = np.asarray(states.stopped)
        if stopped.all():
            break
        scores = np.zeros((n, R), np.float32)
        embs = np.zeros((n, R, sim.emb_dim), np.float32)
        counts = np.zeros((n, R, vocab), np.float32)
        lengths = np.full((n, R), sim.tokens_per_sample, np.int32)
        valid = np.zeros((n, R), bool)
        uids = np.tile(np.arange(rnd * R, (rnd + 1) * R), (n, 1)).astype(np.int32)
        for i in range(n):
            if stopped[i]:
                continue
            out = sim.trial(float(difficulties[i]), R)
            scores[i] = out["score"]
            embs[i] = out["emb"]
            counts[i, np.arange(R), out["answer"]] = 1.0
            valid[i] = True
            correct_by_uid[i, uids[i]] = out["correct"]
        inp = ctrl.RoundInputs(
            scores=jnp.asarray(scores), embs=jnp.asarray(embs),
            token_counts=jnp.asarray(counts), lengths=jnp.asarray(lengths),
            valid=jnp.asarray(valid), uids=jnp.asarray(uids))
        states, _bias = update(states, inp)

    best_uid = np.asarray(states.best_uid)
    acc = correct_by_uid[np.arange(n), np.clip(best_uid, 0, None)]
    return {
        "accuracy": acc.astype(np.float64),
        "tokens": np.asarray(states.tokens_spent, np.float64),
        "samples": np.asarray(states.k_t, np.float64),
        "p_star": np.asarray(states.p_star, np.float64),
        "stopped_early": np.asarray(states.p_star) >= 1 - cfg.delta,
    }


def run_fixed_n(sim: SimulatedDecoder, difficulties: np.ndarray, N: int,
                select: str = "best") -> Dict[str, np.ndarray]:
    """Fixed best-of-N / self-consistency baselines."""
    n = len(difficulties)
    acc = np.zeros(n, bool)
    for i, s in enumerate(difficulties):
        out = sim.trial(float(s), N)
        if select == "best":
            j = int(np.argmax(out["score"]))
            acc[i] = out["correct"][j]
        elif select == "majority":
            ans, cnt = np.unique(out["answer"], return_counts=True)
            top = ans[np.argmax(cnt)]
            members = np.nonzero(out["answer"] == top)[0]
            j = members[np.argmax(out["score"][members])]
            acc[i] = out["correct"][j]
        else:  # oracle upper bound: pass@N
            acc[i] = out["correct"].any()
    tokens = np.full(n, N * sim.tokens_per_sample, np.float64)
    return {"accuracy": acc.astype(np.float64), "tokens": tokens,
            "samples": np.full(n, N, np.float64)}


def run_adaptive_rule(sim: SimulatedDecoder, difficulties: np.ndarray,
                      rule: str, *, max_samples: int = 32,
                      tau: float = 0.9, patience: int = 3,
                      delta: float = 0.25,
                      cost_per_token: float = 2e-4) -> Dict[str, np.ndarray]:
    """§3.2 sequential stopping rules (threshold / bayes / EI) — one sample
    at a time, stop decision from model-derived proxies only."""
    n = len(difficulties)
    acc = np.zeros(n, bool)
    samples = np.zeros(n, np.float64)
    for i, s in enumerate(difficulties):
        best, best_correct = -np.inf, False
        seen: List[float] = []
        no_improve = 0
        succ = 0
        k = 0
        while k < max_samples:
            out = sim.trial(float(s), 1)
            k += 1
            sc = float(out["score"][0])
            seen.append(sc)
            # confidence proxy in [0,1] (logistic of evidence score)
            conf = 1.0 / (1.0 + np.exp(-sc))
            succ += conf > 0.6
            if sc > best + 1e-9:
                best, best_correct = sc, bool(out["correct"][0])
                no_improve = 0
            else:
                no_improve += 1
            bconf = 1.0 / (1.0 + np.exp(-best))
            if rule == "threshold":
                if bconf >= tau or no_improve >= patience:
                    break
            elif rule == "bayes":
                a, b = 1 + succ, 1 + k - succ
                if (b / (a + b)) < delta and k >= 2:
                    break
            elif rule == "ei":
                if k >= 3:
                    mu, sd = np.mean(seen), np.std(seen) + 1e-6
                    z = (mu - best) / sd
                    phi = np.exp(-0.5 * z * z) / np.sqrt(2 * np.pi)
                    Phi = 0.5 * (1 + math.erf(z / np.sqrt(2)))
                    ei = sd * (z * Phi + phi)
                    if ei < cost_per_token * sim.tokens_per_sample:
                        break
        acc[i] = best_correct
        samples[i] = k
    return {"accuracy": acc.astype(np.float64),
            "tokens": samples * sim.tokens_per_sample, "samples": samples}
