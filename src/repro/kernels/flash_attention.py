"""Flash attention (prefill) Pallas TPU kernel.

Online-softmax blockwise attention: grid (B, H, nQ, nK) with the kv axis
minor-most so each (b, h, q-block) accumulates across kv blocks through
VMEM scratch (running max / sum / output accumulator). Block shapes are
MXU-aligned (multiples of 128 on the lane dim, head_dim native).
Supports causal masking and sliding windows.

Target: TPU v5e. Validated against ``ref.flash_attention_ref`` in
interpret mode (CPU) across shape/dtype sweeps — see tests.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  causal: bool, window: int, blk_q: int, blk_k: int,
                  scale: float, nk: int, seq_len: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, :, 0, :].astype(jnp.float32) * scale      # (blk_q, hd)
    k = k_ref[0, :, 0, :].astype(jnp.float32)              # (blk_k, hd)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (blk_q, blk_k)

    q_pos = iq * blk_q + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 0)
    k_pos = ik * blk_k + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 1)
    rel = q_pos - k_pos
    mask = k_pos < seq_len                                  # kv padding
    if causal:
        mask &= rel >= 0
    if window > 0:
        mask &= rel < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                     # (blk_q, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc = acc_scr[...] * alpha + jax.lax.dot(p, v)
    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(ik == nk - 1)
    def _finish():
        o_ref[0, :, 0, :] = (acc_scr[...]
                             / jnp.maximum(l_scr[...], 1e-20)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "blk_q",
                                             "blk_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    blk_q: int = 128, blk_k: int = 128,
                    interpret: bool = False):
    """q/k/v: (B, L, H, hd), heads already GQA-expanded. Returns (B, L, H, hd)."""
    B, L, H, hd = q.shape
    scale = hd ** -0.5
    pad = (-L) % blk_q
    padk = (-L) % blk_k
    if pad or padk:
        # pad q to blk_q and kv to blk_k multiples; padded kv masked in-kernel
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, padk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, padk), (0, 0), (0, 0)))
    Lq, Lk = q.shape[1], k.shape[1]
    nq, nk = Lq // blk_q, Lk // blk_k

    kernel = functools.partial(
        _flash_kernel, causal=causal, window=window, blk_q=blk_q,
        blk_k=blk_k, scale=scale, nk=nk, seq_len=L)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, blk_q, 1, hd), lambda b, h, iq, ik: (b, iq, h, 0)),
            pl.BlockSpec((1, blk_k, 1, hd), lambda b, h, iq, ik: (b, ik, h, 0)),
            pl.BlockSpec((1, blk_k, 1, hd), lambda b, h, iq, ik: (b, ik, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_q, 1, hd),
                               lambda b, h, iq, ik: (b, iq, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Lq, H, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q, 1), jnp.float32),
            pltpu.VMEM((blk_q, 1), jnp.float32),
            pltpu.VMEM((blk_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :L]
