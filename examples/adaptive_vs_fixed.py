"""Reproduce the paper's Fig. 2 motivating experiment in one minute.

Runs the CAMD controller (the real Eq. 7-16 math) against the simulated
heavy-tailed decoder population and prints the accuracy/token Pareto
table vs fixed best-of-N and the §3.2 adaptive stopping rules.

    PYTHONPATH=src:. python examples/adaptive_vs_fixed.py
"""
from benchmarks import bench_fig2


def main():
    out = bench_fig2.run(n_instances=400)
    print("\nclaims:", out["claims"])


if __name__ == "__main__":
    main()
