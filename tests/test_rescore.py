"""Tests for the plug-and-play CAMD rescoring wrapper (paper §5.1 mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import CAMDConfig
from repro.configs import get_config
from repro.core import rescore
from repro.models import build_model


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("internvl2-2b").reduced().with_overrides(dtype="float32")
    model = build_model(cfg, jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_teacher_forced_logprobs_match_decode(setup):
    """Teacher-forced per-token logprobs must equal step-by-step decode
    logprobs of the same sequence."""
    cfg, model, params = setup
    prompt = jax.random.randint(jax.random.PRNGKey(1), (6,), 2,
                                cfg.vocab_size)
    cand = jax.random.randint(jax.random.PRNGKey(2), (1, 4), 2,
                              cfg.vocab_size)
    mask = jnp.ones((1, 4))
    tlp, hidden, embs = rescore.teacher_forced_stats(
        model, params, prompt, cand, mask)
    # manual decode
    cache = model.make_cache(1, 16 + cfg.num_evidence_tokens, jnp.float32)
    lg, _, cache = model.prefill(params, prompt[None], cache)
    lps = []
    cur = lg
    for t in range(4):
        lp = jax.nn.log_softmax(cur.astype(jnp.float32), -1)[0, cand[0, t]]
        lps.append(float(lp))
        cur, _, cache = model.decode_step(params, cand[:, t], cache)
    np.testing.assert_allclose(np.asarray(tlp[0]), lps, rtol=2e-4, atol=2e-4)


def test_rescore_terms_finite_and_weighted(setup):
    cfg, model, params = setup
    camd = CAMDConfig(lambda_g=0.9, lambda_c=0.7)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (5,), 2,
                                cfg.vocab_size)
    cands = jax.random.randint(jax.random.PRNGKey(2), (3, 6), 2,
                               cfg.vocab_size)
    mask = jnp.ones((3, 6)).at[2, 4:].set(0)
    ev = jax.random.normal(jax.random.PRNGKey(3),
                           (cfg.num_evidence_tokens, cfg.evidence_dim))
    res = rescore.rescore_candidates(model, params, camd, prompt, cands,
                                     mask, ev)
    for k in ("score", "s_gen", "s_align", "s_coh"):
        assert np.isfinite(np.asarray(res[k])).all(), k
    np.testing.assert_allclose(
        np.asarray(res["score"]),
        np.asarray(res["s_gen"] + 0.9 * res["s_align"] + 0.7 * res["s_coh"]),
        rtol=1e-5)
    # alignment actually used the evidence (differs from zero-evidence run)
    res0 = rescore.rescore_candidates(model, params, camd, prompt, cands,
                                      mask, None)
    assert float(jnp.abs(res0["s_align"]).max()) == 0.0
    assert float(jnp.abs(res["s_align"]).max()) > 0.0


def test_camd_wrap_round_decision(setup):
    """Identical candidates ⇒ one cluster ⇒ coverage stop; the best uid is
    a real candidate index."""
    cfg, model, params = setup
    camd = CAMDConfig(min_samples=2, delta=0.2, max_clusters=4)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (5,), 2,
                                cfg.vocab_size)
    one = jax.random.randint(jax.random.PRNGKey(2), (1, 5), 2,
                             cfg.vocab_size)
    cands = jnp.tile(one, (3, 1))
    mask = jnp.ones((3, 5))
    state, dec = rescore.camd_wrap(model, params, camd, prompt, cands, mask)
    assert bool(dec["stop"])
    assert float(dec["p_star"]) > 0.8
    assert 0 <= int(dec["best_uid"]) < 3
    assert dec["bias"].shape == (cfg.vocab_size,)
