"""MoE dispatch/combine kernel sweeps (interpret mode vs gather oracle)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.moe_dispatch import moe_combine, moe_dispatch


@pytest.mark.parametrize("G,g,E,C", [(2, 8, 4, 4), (1, 32, 8, 8),
                                     (3, 16, 6, 5)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_moe_dispatch_sweep(G, g, E, C, dtype):
    key = jax.random.PRNGKey(G * 100 + E)
    x = jax.random.normal(key, (G, g, 16)).astype(dtype)
    idx = jax.random.randint(jax.random.fold_in(key, 1), (G, E, C), -1, g)
    out = moe_dispatch(idx, x, interpret=True)
    exp = ref.moe_dispatch_ref(idx, x)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), rtol=1e-6)


@pytest.mark.parametrize("G,g,E,C,k", [(2, 8, 4, 4, 2), (1, 16, 6, 3, 3)])
def test_moe_combine_sweep(G, g, E, C, k):
    key = jax.random.PRNGKey(G + k)
    slot = jax.random.randint(key, (G, g, k), -1, E * C)
    gates = jax.random.uniform(jax.random.fold_in(key, 1), (G, g, k))
    eo = jax.random.normal(jax.random.fold_in(key, 2), (G, E, C, 16))
    out = moe_combine(slot, gates, eo, interpret=True)
    exp = ref.moe_combine_ref(slot, gates, eo)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-5, atol=1e-6)


def test_dispatch_combine_roundtrip_identity():
    """dispatch then combine with unit gates reconstructs routed tokens."""
    G, g, d, E, C = 1, 8, 4, 4, 2
    x = jnp.arange(G * g * d, dtype=jnp.float32).reshape(G, g, d)
    # each token t -> expert t % E, capacity slot t // E (fits: g <= E*C)
    idx = -jnp.ones((G, E, C), jnp.int32)
    slot = -jnp.ones((G, g, 1), jnp.int32)
    for t in range(g):
        e, c = t % E, t // E
        idx = idx.at[0, e, c].set(t)
        slot = slot.at[0, t, 0].set(e * C + c)
    expert_in = moe_dispatch(idx, x, interpret=True)
    back = moe_combine(slot, jnp.ones((G, g, 1)), expert_in, interpret=True)
    np.testing.assert_allclose(np.asarray(back), np.asarray(x), rtol=1e-6)
