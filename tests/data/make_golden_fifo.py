"""Regenerate the pinned FIFO golden token streams.

Run this against a KNOWN-GOOD engine (originally: the pre-scheduler-refactor
engine at commit 656a8ea) to pin the token streams the ``policy="fifo"``
differential test (`tests/test_scheduler_differential.py`) asserts
bit-identity against:

    PYTHONPATH=src python tests/data/make_golden_fifo.py

Cells: every mode x impl in {xla, paged} x macro_steps in {0, 8}. The
JSON records the jax version the goldens were generated under; the test
soft-skips on a different jax version (CPU float behavior is only pinned
within a version), falling back to the live legacy-vs-scheduler
differential which runs everywhere.
"""
import json
import os
import sys

os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import CAMDConfig, ModelConfig, PagedKVConfig, SamplingConfig
from repro.models import build_model
from repro.serving import Request, ServeEngine

MODES = ["camd", "best_of_n", "self_consistency", "greedy"]
IMPLS = ["xla", "paged"]
KS = [0, 8]


def tiny_model():
    cfg = ModelConfig(
        name="golden-lm", family="dense", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=64,
        head_dim=16, tie_embeddings=True, dtype="float32")
    model = build_model(cfg, jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def make_engine(model, params, *, mode, impl, macro_steps, **kw):
    defaults = dict(
        slots=4, cache_len=32,
        sampling=SamplingConfig(max_new_tokens=6, temperature=0.8),
        camd=CAMDConfig(samples_per_round=2, max_rounds=2, min_samples=2,
                        max_clusters=8),
        n_candidates=3, max_new_tokens=6, eos_id=1, seed=0,
        paged_kv=PagedKVConfig(page_size=8),
        mode=mode, impl=impl, macro_steps=macro_steps)
    defaults.update(kw)
    return ServeEngine(model, params, **defaults)


def submit(engine, cfg, n=2, seed=0, plen=5):
    rng = np.random.default_rng(seed)
    for i in range(n):
        engine.submit(Request(uid=i, prompt=rng.integers(
            2, cfg.vocab_size, plen).astype(np.int32)))


def run_cell(model, params, cfg, mode, impl, macro_steps):
    eng = make_engine(model, params, mode=mode, impl=impl,
                      macro_steps=macro_steps)
    submit(eng, cfg)
    res = sorted(eng.run(), key=lambda r: r.uid)
    return [{
        "uid": r.uid,
        "tokens": r.tokens.tolist(),
        "tokens_spent": r.tokens_spent,
        "rounds": r.rounds,
        "n_candidates": r.n_candidates,
        "candidates": sorted([c["tokens"].tolist() for c in r.candidates]),
    } for r in res]


def main():
    cfg, model, params = tiny_model()
    cells = {}
    for mode in MODES:
        for impl in IMPLS:
            for k in KS:
                key = f"{mode}/{impl}/K{k}"
                cells[key] = run_cell(model, params, cfg, mode, impl, k)
                print("pinned", key)
    out = {
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "model": "golden-lm 2L d64 v64 seed0",
        "requests": 2,
        "cells": cells,
    }
    path = os.path.join(os.path.dirname(__file__), "golden_fifo_streams.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1, sort_keys=True)
    print("wrote", path, f"({len(cells)} cells)")


if __name__ == "__main__":
    sys.exit(main())
