"""Fake-clock unit tests for the open-loop traffic math.

``slo_metrics``/``percentile`` are pure trace -> number functions, so
every quantity the bench gates on (TTFT/TPOT percentiles, goodput at an
SLO, tokens/s) is pinned here against hand-built timelines — no engine,
no wall clock, no jax.
"""
import numpy as np
import pytest

from repro.serving.traffic import (RequestTrace, bursty_arrivals,
                                   percentile, poisson_arrivals,
                                   slo_metrics)


def _tr(uid, arrival, first, done, n, cancelled=False, prompt_len=0):
    return RequestTrace(uid=uid, t_arrival=arrival, t_submit=arrival,
                        t_first=first, t_done=done, n_tokens=n,
                        cancelled=cancelled, prompt_len=prompt_len)


# ---------------------------------------------------------------------------
# percentile
# ---------------------------------------------------------------------------

def test_percentile_linear_interpolation():
    xs = [1.0, 2.0, 3.0, 4.0]
    assert percentile(xs, 0) == 1.0
    assert percentile(xs, 100) == 4.0
    assert percentile(xs, 50) == pytest.approx(2.5)
    assert percentile([5.0, 1.0, 3.0], 50) == 3.0     # order-free
    # matches numpy's default 'linear' method by construction
    for q in (1, 25, 50, 75, 99):
        assert percentile(xs, q) == pytest.approx(np.percentile(xs, q))


def test_percentile_edges():
    assert np.isnan(percentile([], 50))
    assert percentile([7.0], 99) == 7.0


# ---------------------------------------------------------------------------
# slo_metrics on a hand-built fake-clock run
# ---------------------------------------------------------------------------

def test_slo_metrics_fake_clock():
    traces = [
        _tr(0, 0.0, 0.1, 0.5, 5),    # ttft 100ms, tpot 400/4 = 100ms
        _tr(1, 1.0, 1.3, 1.3, 1),    # ttft 300ms, no tpot (1 token)
        _tr(2, 2.0, 2.2, 3.0, 9),    # ttft 200ms, tpot 800/8 = 100ms
        _tr(3, 0.5, 0.6, None, 2, cancelled=True),
    ]
    m = slo_metrics(traces, slo_ttft_ms=250.0)
    assert m["completed"] == 3 and m["cancelled"] == 1
    # span defaults to last completion minus earliest scheduled arrival
    assert m["span_s"] == pytest.approx(3.0)
    assert m["ttft_p50_ms"] == pytest.approx(200.0)
    assert m["ttft_p99_ms"] == pytest.approx(
        percentile([100.0, 200.0, 300.0], 99))
    assert m["tpot_p50_ms"] == pytest.approx(100.0)
    assert m["tpot_p99_ms"] == pytest.approx(100.0)
    # uid 1 misses the 250ms SLO; cancelled uid 3 never counts
    assert m["good_requests"] == 2
    assert m["goodput_rps"] == pytest.approx(2 / 3.0)
    assert m["tokens_per_s"] == pytest.approx((5 + 1 + 9) / 3.0)


def test_goodput_counts_exact_slo_boundary():
    traces = [_tr(0, 0.0, 0.25, 1.0, 4)]          # ttft == SLO exactly
    m = slo_metrics(traces, slo_ttft_ms=250.0, span_s=1.0)
    assert m["good_requests"] == 1
    m = slo_metrics(traces, slo_ttft_ms=249.9, span_s=1.0)
    assert m["good_requests"] == 0


def test_span_override_scales_rates():
    traces = [_tr(0, 0.0, 0.1, 0.2, 10)]
    m = slo_metrics(traces, slo_ttft_ms=1e3, span_s=2.0)
    assert m["tokens_per_s"] == pytest.approx(5.0)
    assert m["goodput_rps"] == pytest.approx(0.5)


# ---------------------------------------------------------------------------
# per-prompt-length-bucket TTFT (chunked prefill's headline metric)
# ---------------------------------------------------------------------------

def test_ttft_by_bucket_labels_counts_and_percentiles():
    traces = [
        _tr(0, 0.0, 0.1, 0.5, 4, prompt_len=10),    # lt64: 100ms
        _tr(1, 0.0, 0.3, 0.6, 4, prompt_len=63),    # lt64: 300ms
        _tr(2, 0.0, 0.2, 0.7, 4, prompt_len=64),    # 64to256 boundary
        _tr(3, 0.0, 0.4, 0.8, 4, prompt_len=255),   # 64to256: 400ms
        _tr(4, 0.0, 0.9, 1.0, 4, prompt_len=256),   # ge256 boundary
        _tr(5, 0.0, 0.5, None, 2, cancelled=True, prompt_len=10),
    ]
    m = slo_metrics(traces, slo_ttft_ms=1e3, length_buckets=(64, 256))
    by = m["ttft_by_bucket"]
    assert set(by) == {"lt64", "64to256", "ge256"}
    # cancelled uid 5 is excluded; every completed trace lands somewhere
    assert sum(b["n"] for b in by.values()) == m["completed"] == 5
    assert by["lt64"]["n"] == 2
    assert by["lt64"]["p50_ms"] == pytest.approx(200.0)
    assert by["lt64"]["p99_ms"] == pytest.approx(
        percentile([100.0, 300.0], 99))
    assert by["64to256"] == {"n": 2,
                             "p50_ms": pytest.approx(300.0),
                             "p99_ms": pytest.approx(
                                 percentile([200.0, 400.0], 99))}
    assert by["ge256"]["n"] == 1
    assert by["ge256"]["p50_ms"] == pytest.approx(900.0)


def test_ttft_by_bucket_single_bound_and_empty_bucket():
    # one bound -> two labels; a bucket nobody lands in is absent, not
    # reported as NaN (consumers iterate what exists)
    traces = [_tr(0, 0.0, 0.1, 0.2, 2, prompt_len=5)]
    m = slo_metrics(traces, slo_ttft_ms=1e3, length_buckets=(18,))
    assert set(m["ttft_by_bucket"]) == {"lt18"}
    assert m["ttft_by_bucket"]["lt18"]["n"] == 1


def test_ttft_by_bucket_off_by_default_and_validates_bounds():
    traces = [_tr(0, 0.0, 0.1, 0.2, 2, prompt_len=5)]
    assert "ttft_by_bucket" not in slo_metrics(traces, slo_ttft_ms=1e3)
    for bad in ((256, 64), (64, 64)):
        with pytest.raises(AssertionError):
            slo_metrics(traces, slo_ttft_ms=1e3, length_buckets=bad)


# ---------------------------------------------------------------------------
# arrival processes
# ---------------------------------------------------------------------------

def test_arrivals_deterministic_and_monotone():
    for gen in (poisson_arrivals, bursty_arrivals):
        a = gen(5.0, 200, seed=3)
        b = gen(5.0, 200, seed=3)
        assert np.array_equal(a, b)
        assert np.all(np.diff(a) >= 0) and a[0] >= 0
        assert not np.array_equal(a, gen(5.0, 200, seed=4))


def test_poisson_mean_rate():
    a = poisson_arrivals(8.0, 4000, seed=0)
    rate = len(a) / a[-1]
    assert rate == pytest.approx(8.0, rel=0.1)


def test_bursty_same_offered_load_but_burstier():
    n = 4000
    p = poisson_arrivals(8.0, n, seed=1)
    b = bursty_arrivals(8.0, n, seed=1)
    # identical long-run offered load...
    assert n / b[-1] == pytest.approx(n / p[-1], rel=0.25)
    # ...but far more dispersed inter-arrivals (the point of the bursty
    # cell: same mean rate, concentrated into on-windows)
    cv = lambda xs: np.std(xs) / np.mean(xs)          # noqa: E731
    assert cv(np.diff(b)) > 1.5 * cv(np.diff(p))


def test_zero_rate_degenerates_to_t0():
    assert np.array_equal(poisson_arrivals(0.0, 3), np.zeros(3))
    assert np.array_equal(bursty_arrivals(0.0, 3), np.zeros(3))
