from repro.serving.engine import EngineState, Request, Result, ServeEngine  # noqa: F401
from repro.serving.page_pool import (PagePool, PagePoolError,  # noqa: F401
                                     PrefixCache, prefix_page_keys)
from repro.serving.scheduler import (CoverageScheduler,  # noqa: F401
                                     FifoScheduler, NewWork, RoundWork,
                                     Scheduler, SchedulerContext,
                                     make_scheduler)
