from repro.serving.engine import EngineState, Request, Result, ServeEngine  # noqa: F401
