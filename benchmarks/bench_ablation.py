"""Paper Figure 6 — ablation of the evidence-score weights λ_g, λ_c.

Sweeps the two weighting terms on the simulated multimodal scorer: the
alignment/coherence observables are informative-but-noisy correlates of
correctness (as in real MLLMs), so accuracy peaks at intermediate λ and
degrades at 0 (term off) — reproducing the paper's bowl shape with the
optimum near (0.9, 0.7).
"""
from __future__ import annotations

import numpy as np

from benchmarks.camd_sim import run_camd
from repro.config import CAMDConfig
from repro.data.tasks import SimulatedDecoder


class AblationSim(SimulatedDecoder):
    """Adds align/coherence observables and composes the evidence score
    with the λ weights under test (Eq. 12)."""

    def __init__(self, lambda_g: float, lambda_c: float, **kw):
        super().__init__(**kw)
        self.lg, self.lc = lambda_g, lambda_c

    def trial(self, s, k=1):
        out = super().trial(s, k)
        c = out["correct"].astype(np.float64)
        # S_gen: weak signal; S_align/S_coh: complementary noisy signals
        s_gen = 0.6 * c + 0.55 * self.rng.standard_normal(k)
        s_align = 1.0 * c + 0.8 * self.rng.standard_normal(k)
        s_coh = 0.8 * c + 0.9 * self.rng.standard_normal(k)
        out["score"] = s_gen + self.lg * s_align + self.lc * s_coh
        return out


def run(n_instances: int = 300, seed: int = 0, verbose: bool = True):
    cfg = CAMDConfig(samples_per_round=2, max_rounds=12, min_samples=2,
                     max_clusters=8, delta=0.05, score_scale=1.2)
    grid = [0.0, 0.3, 0.5, 0.7, 0.9, 1.2]
    results = {}
    for lg in grid:
        for lc in grid:
            sim = AblationSim(lg, lc, tail="heavy", alpha=0.5, seed=seed)
            diffs = np.concatenate([
                sim.rng.uniform(0.55, 0.95, n_instances // 2),
                sim.sample_difficulty(n_instances - n_instances // 2)])
            out = run_camd(sim, diffs, cfg, seed=seed)
            results[(lg, lc)] = float(np.mean(out["accuracy"]))
    best = max(results, key=results.get)
    base = results[(0.0, 0.0)]
    if verbose:
        print("  acc grid (rows λ_g, cols λ_c):")
        header = "        " + " ".join(f"{c:5.2f}" for c in grid)
        print(header)
        for lg in grid:
            print(f"  λg={lg:4.2f} " + " ".join(
                f"{results[(lg, lc)]:.3f}" for lc in grid))
        print(f"  best (λ_g, λ_c) = {best} acc={results[best]:.3f} "
              f"(terms-off acc={base:.3f})")
    claims = {
        "both_terms_help": bool(results[best] > base + 0.01),
        "best_interior": bool(best[0] > 0.0 and best[1] > 0.0),
    }
    if verbose:
        print(f"  claim[align+coherence terms improve accuracy]: "
              f"{claims['both_terms_help']}")
    return {"grid": {str(k): v for k, v in results.items()},
            "best": best, "claims": claims}


if __name__ == "__main__":
    run()
