"""Posterior coverage estimation + Bayesian adaptive sampling
(paper §4.2.2-§4.2.3, Eq. 14-16).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.clustering import ClusterTable, posterior_weights


def coverage_reached(table: ClusterTable, k_t, *, delta: float,
                     min_samples: int):
    """§4.2.2 stop rule: stop when p̂* = max_k p̂_k >= 1-δ (and at least
    min_samples candidates were drawn). Returns (stop, p_star)."""
    p = posterior_weights(table)
    p_star = jnp.max(p)
    stop = (p_star >= 1.0 - delta) & (k_t >= min_samples)
    return stop, p_star


def dirichlet_update(alpha, table: ClusterTable):
    """Eq. 15: α' = α + n, with soft counts n_k = Σ_{i∈C_k} s̃_i.

    Because s̃ is the softmax of member scores, n_k equals the Eq. 14
    posterior weight p̂_k — the paper's construction makes them coincide.
    Returns (alpha', π̄ = E[π | D_t])."""
    M = alpha.shape[0]
    active = jnp.arange(M) < table.n_clusters
    n = posterior_weights(table)
    new_alpha = alpha + n
    masked = jnp.where(active, new_alpha, 0.0)
    pi_bar = masked / jnp.maximum(jnp.sum(masked), 1e-9)
    return new_alpha, pi_bar


def mixture_logit_bias(pi_bar, cluster_hist, *, strength: float = 1.0,
                       eps: float = 1e-6):
    """Eq. 16 as a decoding bias: p'(y) = Σ_k π̄_k q_k(y) with q_k the
    empirical token distribution of cluster k (smoothed).

    cluster_hist: (M, V) token counts per cluster. Returns a (V,) additive
    logit bias ``strength * log p'`` (uniform ⇒ constant ⇒ no-op).
    Clusters with empty histograms fall back to uniform so the mixture
    never zeroes out unseen tokens (global diversity is preserved, as the
    paper requires).
    """
    V = cluster_hist.shape[-1]
    totals = jnp.sum(cluster_hist, axis=-1, keepdims=True)           # (M,1)
    q = (cluster_hist + eps) / (totals + eps * V)                    # (M,V)
    p_mix = jnp.einsum("m,mv->v", pi_bar, q)
    p_mix = p_mix + (1.0 - jnp.sum(pi_bar)) / V                      # inactive mass -> uniform
    bias = strength * jnp.log(p_mix + 1e-20)
    return bias - jnp.mean(bias)                                     # zero-mean: pure reweighting


# ---------------------------------------------------------------------------
# §3.2 adaptive stopping baselines (motivation experiment rules)
# ---------------------------------------------------------------------------

def threshold_stop(best_score, prev_best, no_improve_rounds, *, tau: float,
                   patience: int):
    """Rule (i): stop once a satisfactory score is reached, or after
    `patience` rounds with no improvement."""
    improved = best_score > prev_best + 1e-9
    rounds = jnp.where(improved, 0, no_improve_rounds + 1)
    stop = (best_score >= tau) | (rounds >= patience)
    return stop, rounds


def beta_bernoulli_stop(successes, trials, *, delta: float,
                        prior_a: float = 1.0, prior_b: float = 1.0):
    """Rule (ii): Beta-Bernoulli posterior on per-trial success; stop when
    expected residual failure of one more trial is below δ:
    E[(1-s)] ** remaining-budget heuristic — here the one-step version:
    posterior mean failure < δ."""
    a = prior_a + successes
    b = prior_b + trials - successes
    mean_fail = b / (a + b)
    return mean_fail < delta, mean_fail


def expected_improvement_stop(best_score, score_mean, score_std, tokens_per_sample,
                              *, cost_per_token: float):
    """Rule (iii): stop when the expected marginal gain of one more sample
    (normal approximation of the score distribution) is below its token
    cost."""
    z = (score_mean - best_score) / jnp.maximum(score_std, 1e-6)
    phi = jnp.exp(-0.5 * z * z) / jnp.sqrt(2.0 * jnp.pi)
    Phi = 0.5 * (1.0 + jax.lax.erf(z / jnp.sqrt(2.0)))
    ei = score_std * (z * Phi + phi)
    return ei < cost_per_token * tokens_per_sample, ei
