"""qwen3-0.6b — Qwen3 0.6B dense with qk-norm.

[hf:Qwen/Qwen3-8B family card]: 28L, d_model=1024, 16 q heads, GQA kv=8,
d_ff=3072, vocab 151936, qk_norm.
"""
from repro.config import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b",
    family="dense",
    num_layers=28,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    d_ff=3072,
    vocab_size=151936,
    qk_norm=True,
    head_dim=128,                 # qwen3 uses head_dim 128 (> d_model/heads)
    rope_theta=1e6,
    block_pattern=(ATTN,),
    mlp_activation="swiglu",
    tie_embeddings=True,
    source="hf:Qwen/Qwen3-8B",
)
