"""Cross-request prefix cache, engine level: CoW/refcount correctness,
hit-rate accounting in ``kv_stats()``, prefill-skip verification, and
byte-identical outputs with the cache on vs off.
"""
import numpy as np

from conftest import _mk_engine as _mk_base
from repro.config import PagedKVConfig
from repro.serving import Request

PAGE = PagedKVConfig(page_size=8)


def _mk(model, params, **kw):
    defaults = dict(slots=4, cache_len=64, max_new=8, n_candidates=3,
                    impl="paged", paged_kv=PAGE, bucket_prefill=False)
    defaults.update(kw)
    return _mk_base(model, params, **defaults)


def _shared_prefix_prompts(cfg, n=4, shared=17, total=21, seed=0):
    """n prompts sharing their first ``shared`` tokens (2 full pages at
    page_size 8), diverging after."""
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(2, cfg.vocab_size, total).astype(np.int32)
               for _ in range(n)]
    for p in prompts[1:]:
        p[:shared] = prompts[0][:shared]
    return prompts


def _submit_all(eng, prompts, uid0=0):
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=uid0 + i, prompt=p))


def test_cache_on_off_byte_identical(tiny_model):
    """Suffix prefill against cached page KV must reproduce the full
    prefill bit-for-bit: every candidate stream identical on/off."""
    cfg, model, params = tiny_model
    prompts = _shared_prefix_prompts(cfg)
    outs = {}
    for pc in (False, True):
        eng = _mk(model, params, mode="camd", prefix_cache=pc)
        _submit_all(eng, prompts)
        res = sorted(eng.run(), key=lambda r: r.uid)
        outs[pc] = [(r.tokens.tolist(),
                     sorted(c["tokens"].tolist() for c in r.candidates))
                    for r in res]
        eng.pool.check()
    assert outs[False] == outs[True]


def test_hits_skip_prefill_and_account(tiny_model):
    """The pool/kv_stats accounting and the prefill-call/token counters
    must show the shared pages were NOT re-prefilled."""
    cfg, model, params = tiny_model
    prompts = _shared_prefix_prompts(cfg)        # 2 shared full pages each
    off = _mk(model, params, mode="camd", prefix_cache=False)
    _submit_all(off, prompts)
    off.run()

    on = _mk(model, params, mode="camd", prefix_cache=True)
    _submit_all(on, prompts)
    on.run()
    pc = on.kv_stats()["prefix_cache"]
    # 3 of 4 requests hit the 2 shared pages seeded by request 0
    assert pc["hits"] == 6
    assert pc["hit_tokens"] == 6 * PAGE.page_size
    assert pc["bytes_saved"] == 6 * on.kv_stats()["bytes_per_page"]
    assert pc["probes"] == 4
    # prefill work shrinks by exactly the hit tokens
    assert on.prefill_tokens == off.prefill_tokens - pc["hit_tokens"]
    assert on.prefill_calls == off.prefill_calls      # 1 per request here

    # second wave of identical prompts: every request now hits
    t0, h0 = on.prefill_tokens, on.kv_stats()["prefix_cache"]["hit_tokens"]
    _submit_all(on, prompts, uid0=100)
    on.run()
    pc2 = on.kv_stats()["prefix_cache"]
    assert pc2["hit_tokens"] - h0 == 4 * 2 * PAGE.page_size
    assert on.prefill_tokens - t0 == sum(
        len(p) - 2 * PAGE.page_size for p in prompts)
    on.pool.check()


def test_refcounts_and_residency(tiny_model):
    """Cached pages carry exactly one cache hold after the stream drains;
    during a hit request's run the shared pages carry cache + request +
    per-candidate holds. drop_all() returns the pool to empty."""
    cfg, model, params = tiny_model
    prompts = _shared_prefix_prompts(cfg, n=2)
    eng = _mk(model, params, mode="best_of_n", n_candidates=3,
              prefix_cache=True)
    _submit_all(eng, [prompts[0]])
    eng.run()
    shared_pages = [n.page for n in eng.pool.prefix._nodes.values()]
    assert len(shared_pages) == 2
    assert all(eng.pool.refcount(p) == 1 for p in shared_pages)

    # admit the second (hitting) request without stepping
    eng.submit(Request(uid=1, prompt=prompts[1]))
    eng._schedule()
    info = eng._reqs[1]
    assert info["prefix_len"] == 2 * PAGE.page_size
    n_live = sum(1 for s in range(eng.B) if eng._slot_req[s] >= 0)
    assert n_live == 3
    for p in shared_pages:
        # cache hold + request hold + one per live candidate
        assert eng.pool.refcount(p) == 2 + n_live
    eng.pool.check()
    # drain; only the cache holds remain, then none
    eng.run()
    assert all(eng.pool.refcount(p) == 1 for p in shared_pages)
    eng.pool.prefix.drop_all()
    assert eng.pool.in_use == 0
    eng.pool.check()


def test_macro_and_legacy_loops_with_cache(tiny_model):
    """The prefix cache composes with both decode loops (macro_steps 0
    and 16) and stays byte-identical to cache-off in each."""
    cfg, model, params = tiny_model
    prompts = _shared_prefix_prompts(cfg, n=3)
    for k in (0, 16):
        outs = {}
        for pc in (False, True):
            eng = _mk(model, params, mode="camd", macro_steps=k,
                      prefix_cache=pc)
            _submit_all(eng, prompts)
            outs[pc] = [r.tokens.tolist()
                        for r in sorted(eng.run(), key=lambda r: r.uid)]
            eng.pool.check()
        assert outs[False] == outs[True], f"macro_steps={k}"


def test_gating_unsupported_configs(tiny_model):
    """Prefix caching silently gates off for non-paged engines and for
    requests with evidence; nothing breaks."""
    cfg, model, params = tiny_model
    eng = _mk(model, params, mode="greedy", impl="xla", prefix_cache=True)
    assert eng.prefix_cache is False             # needs paged KV
    prompts = _shared_prefix_prompts(cfg, n=2)
    _submit_all(eng, prompts)
    assert len(eng.run()) == 2

    from repro.configs import get_config
    import jax
    import jax.numpy as jnp
    from repro.models import build_model
    vcfg = get_config("internvl2-2b").reduced().with_overrides(
        dtype="float32")
    vmodel = build_model(vcfg, jnp.float32)
    assert vmodel.supports_prefix_cache          # all-ATTN decoder
    vparams = vmodel.init(jax.random.PRNGKey(0))
    veng = _mk(vmodel, vparams, mode="greedy", prefix_cache=True,
               cache_len=64, slots=2)
    rng = np.random.default_rng(0)
    for i in range(2):
        ev = rng.standard_normal((vcfg.num_evidence_tokens,
                                  vcfg.evidence_dim)).astype(np.float32)
        veng.submit(Request(uid=i, prompt=rng.integers(
            2, vcfg.vocab_size, 20).astype(np.int32), evidence=ev))
    veng.run()
    # evidence-bearing requests never probe the cache
    assert veng.kv_stats()["prefix_cache"]["probes"] == 0
    veng.pool.check()


def test_reservations_backed_by_free_pages(tiny_model):
    """Regression: admission may count cache-evictable pages as headroom,
    but right after every admission the engine converts that headroom
    into actually-free pages (``ensure_free``) — a later prefix hit
    re-pinning cached pages must never be able to strand a live slot's
    reservation (frontier staging would raise mid-decode)."""
    cfg, model, params = tiny_model
    rng = np.random.default_rng(5)
    shared = rng.integers(2, cfg.vocab_size, 17).astype(np.int32)
    waves = []
    for w in range(3):
        ps = []
        for _ in range(2):
            p = rng.integers(2, cfg.vocab_size, 19).astype(np.int32)
            p[:17] = shared
            ps.append(p)
        waves.append(ps)
    # pool tight enough that cached pages are the margin
    eng = _mk(model, params, mode="camd", prefix_cache=True, slots=2,
              cache_len=32, max_new=6, macro_steps=8,
              paged_kv=PagedKVConfig(page_size=8, num_pages=13))
    uid = 0
    for ps in waves:
        for p in ps:
            eng.submit(Request(uid=uid, prompt=p))
            uid += 1
        eng.run()                                # interleaves hits + decode
        assert eng.pool.free_pages >= eng._reserved
        eng.pool.check()
    assert eng.kv_stats()["prefix_cache"]["hits"] > 0


def test_pool_pressure_evicts_instead_of_failing(tiny_model):
    """A pool sized so cached pages must be reclaimed: traffic still
    completes, and evictions are recorded."""
    cfg, model, params = tiny_model
    rng = np.random.default_rng(3)
    # distinct prompts (no sharing) so the cache only costs pages
    prompts = [rng.integers(2, cfg.vocab_size, 17).astype(np.int32)
               for _ in range(4)]
    eng = _mk(model, params, mode="greedy", prefix_cache=True, slots=2,
              cache_len=32, max_new=4,
              paged_kv=PagedKVConfig(page_size=8, num_pages=9))
    _submit_all(eng, prompts)
    res = eng.run()
    assert sorted(r.uid for r in res) == [0, 1, 2, 3]
    assert eng.kv_stats()["prefix_cache"]["evictions"] > 0
    eng.pool.check()
