"""Autotuner for kernel block sizes and serving-loop shape parameters.

Two sweeps, one artifact:

- **kernel block sizes** — ``flash_attention`` (blk_q x blk_k) and
  ``decode_attention`` (blk_s) candidate grids, timed on the compiled
  Pallas path. Block sizes only exist on a real TPU backend: everywhere
  else the public ops dispatch the jnp oracle (interpret mode is a
  correctness harness, ~1000x slow), so non-TPU runs record the builtin
  defaults with ``"source": "default"`` instead of fabricating numbers.
- **serve shape** — page_size then macro-step K, timed end-to-end on the
  real ``ServeEngine`` equal-work grid cell (``bench_serve._run_cell``),
  then the prefill chunk size on the head-of-line latency cell
  (``bench_serve._run_chunked_cell``). These are genuine wall-clock
  measurements on every backend. The paged decode kernel has no
  independent block knob — its grid IS (batch, kv_head, page), so
  page_size doubles as its block size and this sweep covers it.

Writes ``BENCH_autotune.json``. ``load_tuned()`` merges that file over
the builtin defaults; ``bench_serve`` / ``bench_kernels`` call it so a
committed tuning run changes what the benchmarks exercise by default.

  python -m benchmarks.autotune [--smoke]
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp

DEFAULTS = {
    "flash_attention": {"blk_q": 128, "blk_k": 128},
    "decode_attention": {"blk_s": 256},
    "serve": {"page_size": 16, "macro_steps": 8, "prefill_chunk": 256},
}

_ARTIFACT = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_autotune.json")


def load_tuned(path: str | None = None) -> dict:
    """Tuned parameter defaults: BENCH_autotune.json merged over the
    builtins. Unknown sections/keys in the file are ignored, so an old
    artifact can never inject junk into a newer benchmark."""
    out = {k: dict(v) for k, v in DEFAULTS.items()}
    try:
        with open(path or _ARTIFACT) as f:
            data = json.load(f)
    except (OSError, ValueError):
        return out
    for sect, vals in data.items():
        if sect in out and isinstance(vals, dict):
            out[sect].update(
                {k: v for k, v in vals.items() if k in out[sect]})
    return out


def _time_call(fn, *args, iters: int = 5) -> float:
    jax.block_until_ready(fn(*args))            # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6     # us


def tune_kernels(smoke: bool = False) -> dict:
    """Sweep Pallas block-size grids on the compiled kernel path.

    Returns one section per kernel. On non-TPU backends the ops layer
    runs the jnp oracle where block sizes are meaningless, so the
    builtin defaults are recorded untimed (``source: default``)."""
    from repro.kernels import ops
    mode = ops._mode()
    if mode != "tpu":
        note = (f"kernel mode {mode!r} dispatches the jnp oracle; "
                "block sizes only exist on the compiled TPU path")
        return {name: {**DEFAULTS[name], "source": "default", "note": note}
                for name in ("flash_attention", "decode_attention")}

    key = jax.random.PRNGKey(0)
    out = {}

    B, L, H, hd = (1, 1024, 4, 64) if smoke else (2, 4096, 8, 64)
    q = jax.random.normal(key, (B, L, H, hd), jnp.bfloat16)
    cands, best = [], None
    for blk_q in (64, 128, 256):
        for blk_k in (64, 128, 256):
            fn = jax.jit(lambda x, bq=blk_q, bk=blk_k: ops.flash_attention(
                x, x, x, causal=True, blk_q=bq, blk_k=bk))
            us = _time_call(fn, q)
            cands.append({"blk_q": blk_q, "blk_k": blk_k, "us": us})
            if best is None or us < best["us"]:
                best = cands[-1]
    out["flash_attention"] = {"blk_q": best["blk_q"], "blk_k": best["blk_k"],
                              "source": "measured", "candidates": cands}

    S, Hkv = (2048, 2) if smoke else (8192, 2)
    qd = jax.random.normal(key, (B, 1, H, hd), jnp.bfloat16)
    kd = jax.random.normal(key, (B, S, Hkv, hd), jnp.bfloat16)
    mask = jnp.ones((B, S), bool)
    cands, best = [], None
    for blk_s in (128, 256, 512):
        fn = jax.jit(lambda a, b, m, bs=blk_s: ops.decode_attention(
            a, b, b, m, blk_s=bs))
        us = _time_call(fn, qd, kd, mask)
        cands.append({"blk_s": blk_s, "us": us})
        if best is None or us < best["us"]:
            best = cands[-1]
    out["decode_attention"] = {"blk_s": best["blk_s"],
                               "source": "measured", "candidates": cands}
    return out


def tune_serve(smoke: bool = False) -> dict:
    """Three-stage serving sweep: page_size at the default K, macro-step
    K at the winning page_size (both on the equal-work throughput cell —
    near-separable knobs: page_size moves KV scatter and pool pressure,
    K moves dispatch amortization), then the prefill chunk size on the
    head-of-line latency cell (``bench_serve._run_chunked_cell``), where
    the objective is short-prompt p99 TTFT subject to the long-prompt
    p99 staying within 1.5x of the unchunked reference — the chunk knob
    trades head-of-line blocking against per-chunk dispatch overhead,
    which only a wall-clock measurement can balance."""
    from benchmarks.bench_serve import _bench_model, _run_cell
    cfg, model, params = _bench_model()
    requests, max_new, reps = (2, 16, 2) if smoke else (4, 32, 3)
    page_sizes = (16, 32) if smoke else (8, 16, 32)
    ks = (8, 32) if smoke else (1, 8, 32)
    cells = []

    def cell(ps, k):
        row = _run_cell(cfg, model, params, impl="paged", mode="camd",
                        macro_steps=k, requests=requests, max_new=max_new,
                        reps=reps, page_size=ps)
        row["page_size"] = ps
        cells.append(row)
        print(f"autotune serve ps={ps:<3d} K={k:<3d} "
              f"{row['tokens_per_s']:9.1f} tok/s")
        return row

    k0 = DEFAULTS["serve"]["macro_steps"]
    best_ps = max((cell(ps, k0) for ps in page_sizes),
                  key=lambda r: r["tokens_per_s"])["page_size"]
    k_rows = [next(r for r in cells if r["page_size"] == best_ps)]
    k_rows += [cell(best_ps, k) for k in ks if k != k0]
    best_k = max(k_rows, key=lambda r: r["tokens_per_s"])["macro_steps"]
    best_chunk, chunk_cells = _tune_prefill_chunk(smoke)
    return {"page_size": best_ps, "macro_steps": best_k,
            "prefill_chunk": best_chunk, "source": "measured",
            "cells": cells, "chunk_cells": chunk_cells}


def _tune_prefill_chunk(smoke: bool = False):
    """Prefill-chunk-size sweep on the head-of-line latency workload.

    Each candidate is scored against an unchunked reference run on the
    same prompts: minimize short-prompt p99 TTFT among candidates whose
    long-prompt p99 stays within 1.5x of the reference (a tiny chunk
    frees shorts fastest but drip-feeds the tail long prompt through
    too many budget turns)."""
    from benchmarks.bench_serve import (_mixed_length_prompts,
                                        _run_chunked_cell, _spec_model)
    cfg, model, params = _spec_model()
    n_long, n_short, long_len, max_new = \
        (2, 4, 512, 8) if smoke else (2, 4, 1024, 16)
    prompts = _mixed_length_prompts(n_long, n_short, vocab=cfg.vocab_size,
                                    long_len=long_len)
    candidates = (128, 256) if smoke else (64, 128, 256, 512)
    ref, _ = _run_chunked_cell(model, params, prompts, chunk=0,
                               max_new=max_new, uid0=0)
    long_cap = 1.5 * ref["ttft_by_bucket"]["ge96"]["p99_ms"]
    rows = [ref]
    best = None
    for i, c in enumerate(candidates):
        row, _ = _run_chunked_cell(model, params, prompts, chunk=c,
                                   max_new=max_new, uid0=(i + 1) * 100_000)
        rows.append(row)
        short_p99 = row["ttft_by_bucket"]["lt32"]["p99_ms"]
        long_p99 = row["ttft_by_bucket"]["ge96"]["p99_ms"]
        ok = long_p99 <= long_cap
        print(f"autotune chunk={c:<4d} short p99 {short_p99:7.1f}ms  "
              f"long p99 {long_p99:7.1f}ms{'' if ok else '  (long cap)'}")
        if ok and (best is None or short_p99 < best[1]):
            best = (c, short_p99)
    # every candidate blowing the long cap means chunking overhead
    # dominates on this backend — fall back to the builtin default
    return (best[0] if best else DEFAULTS["serve"]["prefill_chunk"]), rows


def run(smoke: bool = False) -> dict:
    out = {"config": {"smoke": smoke, "backend": jax.default_backend(),
                      "jax_version": jax.__version__}}
    out.update(tune_kernels(smoke))
    out["serve"] = tune_serve(smoke)
    with open("BENCH_autotune.json", "w") as f:
        json.dump(out, f, indent=2)
    tuned = load_tuned("BENCH_autotune.json")
    print("wrote BENCH_autotune.json; tuned defaults:", tuned)
    return out


if __name__ == "__main__":
    import sys
    run(smoke="--smoke" in sys.argv)
