"""mamba2-780m — Mamba-2 (SSD, state-space duality), attention-free.

[arXiv:2405.21060]: 48L, d_model=1536, no attention, vocab 50280,
ssm_state=128.
"""
from repro.config import SSM, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    num_layers=48,
    d_model=1536,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,                       # mamba2 blocks have no separate MLP
    vocab_size=50280,
    block_pattern=(SSM,),
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, chunk_size=64),
    tie_embeddings=True,
    source="arXiv:2405.21060",
)
