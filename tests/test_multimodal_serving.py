"""Multimodal (vision-language) family serving: the vision tower,
image prefill through the engine, content-hash image prefix caching,
and paged-vs-dense byte identity — the same differential discipline the
attention family's paged suite pins, now with an image frontend in the
loop."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serving import Request, ServeEngine


@pytest.fixture(scope="session")
def vlm_model():
    cfg = get_config("llava_1_5_7b").reduced().with_overrides(dtype="float32")
    model = build_model(cfg, jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _image(cfg, seed=0):
    v = cfg.vision
    rng = np.random.default_rng(seed)
    return rng.standard_normal(
        (v.image_h, v.image_w, v.channels)).astype(np.float32)


def _engine(model, params, **kw):
    defaults = dict(slots=4, cache_len=128, mode="greedy",
                    max_new_tokens=8, impl="xla", macro_steps=4, seed=0)
    defaults.update(kw)
    return ServeEngine(model, params, **defaults)


# ---------------------------------------------------------------------------
# vision tower
# ---------------------------------------------------------------------------

def test_vision_config_grid(vlm_model):
    cfg, model, params = vlm_model
    v = cfg.vision
    assert v.n_patches == cfg.num_evidence_tokens
    assert model.capabilities()["has_vision_tower"]
    # full-size configs keep the published grids
    for arch, want in (("llava_1_5_7b", 576), ("internvl2_2b", 256)):
        full = get_config(arch)
        assert full.vision.n_patches == want == full.num_evidence_tokens


def test_vision_encode_shapes(vlm_model):
    cfg, model, params = vlm_model
    imgs = np.stack([_image(cfg, 0), _image(cfg, 1)])
    feats = model.encode_image(params, imgs)
    De = cfg.evidence_dim or cfg.d_model
    assert feats.shape == (2, cfg.num_evidence_tokens, De)
    assert np.isfinite(np.asarray(feats)).all()
    # deterministic, batch-order equivariant
    f0 = model.encode_image(params, imgs[:1])
    np.testing.assert_allclose(np.asarray(feats[0]), np.asarray(f0[0]),
                               rtol=1e-5, atol=1e-5)


def test_encode_image_without_tower_raises():
    cfg = get_config("qwen3_0_6b").reduced()
    model = build_model(cfg, jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="vision"):
        model.encode_image(params, np.zeros((1, 8, 8, 3), np.float32))


# ---------------------------------------------------------------------------
# image prefill through the engine
# ---------------------------------------------------------------------------

def _image_requests(cfg, n=4, shared=True, seed=0, plen=(4, 10)):
    rng = np.random.default_rng(seed)
    imgs = [_image(cfg, 0), _image(cfg, 1)]
    reqs = []
    for i in range(n):
        p = rng.integers(2, cfg.vocab_size,
                         size=int(rng.integers(*plen))).astype(np.int32)
        img = imgs[i % 2] if shared else _image(cfg, 10 + i)
        reqs.append((i, p, img))
    return reqs


def test_image_serving_and_memoization(vlm_model):
    cfg, model, params = vlm_model
    eng = _engine(model, params)
    for uid, p, img in _image_requests(cfg, n=6):
        eng.submit(Request(uid=uid, prompt=p, image=img))
    res = eng.run()
    assert len(res) == 6
    assert all(r.tokens.size > 0 for r in res)
    # 2 distinct images, 6 requests: 2 tower encodes, 4 memo hits
    assert eng.image_encodes == 2
    assert eng.image_feat_hits == 4


def test_image_on_visionless_config_raises():
    cfg = get_config("qwen3_0_6b").reduced()
    model = build_model(cfg, jnp.float32)
    eng = _engine(model, model.init(jax.random.PRNGKey(0)), cache_len=64)
    with pytest.raises(ValueError, match="vision"):
        eng.submit(Request(uid=0, prompt=np.arange(2, 6, dtype=np.int32),
                           image=np.zeros((8, 8, 3), np.float32)))


def test_paged_vs_dense_identity_with_images(vlm_model):
    """Image prefill into pool pages must stream byte-identically to
    the dense cache path — the multimodal arm of the paged differential
    suite."""
    cfg, model, params = vlm_model
    reqs = _image_requests(cfg, n=4, seed=1)

    def run(impl):
        eng = _engine(model, params, impl=impl)
        for uid, p, img in reqs:
            eng.submit(Request(uid=uid, prompt=p, image=img))
        return {r.uid: r.tokens for r in eng.run()}

    a, b = run("xla"), run("paged")
    for uid in a:
        np.testing.assert_array_equal(a[uid], b[uid])


def test_image_prefix_cache_hits_and_identity(vlm_model):
    """Repeated image + shared prompt prefix must hit the cross-request
    prefix cache (content-hash pseudo-token keys over the image span),
    skip prefill tokens, and leave the streams byte-identical."""
    cfg, model, params = vlm_model
    rng = np.random.default_rng(2)
    img = _image(cfg, 3)
    base = rng.integers(2, cfg.vocab_size, size=24).astype(np.int32)
    prompts = [np.concatenate([base, rng.integers(
        2, cfg.vocab_size, size=3).astype(np.int32)]) for _ in range(3)]

    def run(prefix_cache):
        eng = _engine(model, params, impl="paged",
                      prefix_cache=prefix_cache)
        for i, p in enumerate(prompts):
            eng.submit(Request(uid=i, prompt=p, image=img))
        return {r.uid: r.tokens for r in eng.run()}, eng

    a, _ = run(False)
    b, eng = run(True)
    for uid in a:
        np.testing.assert_array_equal(a[uid], b[uid])
    pc = eng.kv_stats()["prefix_cache"]
    assert pc["hits"] > 0 and pc["hit_tokens"] > 0
    assert eng.prefill_tokens < sum(
        len(p) + cfg.num_evidence_tokens for p in prompts)


def test_distinct_images_never_cross_hit(vlm_model):
    """Different image bytes produce different pseudo-token keys: no
    prefix-cache hit even under identical prompts."""
    cfg, model, params = vlm_model
    prompt = np.arange(2, 26, dtype=np.int32)
    eng = _engine(model, params, impl="paged", prefix_cache=True)
    for i in range(3):
        eng.submit(Request(uid=i, prompt=prompt.copy(),
                           image=_image(cfg, 20 + i)))
    eng.run()
    pc = eng.kv_stats()["prefix_cache"]
    assert pc["hit_tokens"] == 0


def test_raw_evidence_stays_uncacheable(vlm_model):
    """Precomputed-evidence requests have no stable content key: they
    must not enter the prefix cache."""
    cfg, model, params = vlm_model
    rng = np.random.default_rng(5)
    De = cfg.evidence_dim or cfg.d_model
    ev = rng.standard_normal(
        (cfg.num_evidence_tokens, De)).astype(np.float32)
    prompt = np.arange(2, 26, dtype=np.int32)
    eng = _engine(model, params, impl="paged", prefix_cache=True)
    for i in range(2):
        eng.submit(Request(uid=i, prompt=prompt.copy(), evidence=ev.copy()))
    eng.run()
    pc = eng.kv_stats()["prefix_cache"]
    assert pc["insertions"] == 0 and pc["hits"] == 0


def test_chunked_image_prefill_identity(vlm_model):
    """Long image prompts stream through chunked prefill (first chunk
    carries the whole image span) byte-identically to whole-prompt
    prefill."""
    cfg, model, params = vlm_model
    rng = np.random.default_rng(6)
    img = _image(cfg, 7)
    prompts = [rng.integers(2, cfg.vocab_size,
                            size=n).astype(np.int32) for n in (70, 40, 9)]

    def run(chunk):
        eng = _engine(model, params, impl="paged", prefix_cache=True,
                      prefill_chunk=chunk)
        for i, p in enumerate(prompts):
            eng.submit(Request(uid=i, prompt=p, image=img))
        return {r.uid: r.tokens for r in eng.run()}, eng

    a, _ = run(0)
    b, eng = run(32)
    for uid in a:
        np.testing.assert_array_equal(a[uid], b[uid])
    assert eng.chunk_calls > 0


def test_xmodal_rescore_matches_aggregate(vlm_model):
    """The fused Eq. 8-9 kernel rescoring must agree with the engine's
    incremental alignment aggregate (same math, block-reduced)."""
    cfg, model, params = vlm_model
    reqs = _image_requests(cfg, n=3, seed=8)
    eng = _engine(model, params, mode="camd", xmodal_rescore=True)
    for uid, p, img in reqs:
        eng.submit(Request(uid=uid, prompt=p, image=img))
    res = eng.run()
    checked = 0
    for r in res:
        for c in r.candidates:
            if "s_align_xmodal" in c and c["n"] > 0:
                info = eng._reqs[r.uid]
                agg = 0.5 * (c["align"] + info["align_const"])
                np.testing.assert_allclose(c["s_align_xmodal"], agg,
                                           rtol=1e-4, atol=1e-4)
                checked += 1
    assert checked > 0
