"""Speculative decoding: n-gram drafter, rejection-sampling accept
kernel (vectorized greedy path == sequential general path), the sampler
bugfixes that rode along (exact-k top-k ties, hoisted batch sampling),
and engine-level byte-identity between spec-on and spec-off greedy
streams across impls and scheduler policies.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import _mk_engine as _mk_base, _submit as _submit_base
from repro.config import PagedKVConfig, SamplingConfig
from repro.sampling import samplers
from repro.sampling.samplers import (sample_token, sample_token_batch,
                                     speculative_accept)
from repro.serving import Request

PAGE = PagedKVConfig(page_size=8)


# ---------------------------------------------------------------------------
# Sampler bugfixes
# ---------------------------------------------------------------------------

def test_top_k_exact_k_on_ties():
    """Duplicated kth value must not let extra tokens survive: lax.top_k
    breaks ties toward lower ids, so exactly k logits stay finite."""
    logits = jnp.array([[1.0, 3.0, 2.0, 2.0, 2.0, 0.0]])
    out = samplers.apply_top_k(logits, 3)
    kept = out > samplers.NEG_INF / 2
    assert int(kept.sum()) == 3
    # top-1 always survives; ties at the cutoff resolve to lower ids
    assert bool(kept[0, 1]) and bool(kept[0, 2]) and bool(kept[0, 3])
    assert not bool(kept[0, 4])


def test_top_k_batch_rows_independent():
    logits = jnp.array([[5.0, 4.0, 3.0, 2.0],
                        [2.0, 3.0, 4.0, 5.0]])
    out = samplers.apply_top_k(logits, 2)
    kept = out > samplers.NEG_INF / 2
    assert kept.tolist() == [[True, True, False, False],
                             [False, False, True, True]]


def test_sample_token_batch_matches_single_calls():
    """The hoisted shared-row processing must keep per-key draws
    identical to n separate sample_token calls."""
    cfg = SamplingConfig(temperature=0.8, top_p=0.9, top_k=7,
                         repetition_penalty=1.0)
    logits = jax.random.normal(jax.random.PRNGKey(1), (1, 32))
    bias = 0.3 * jax.random.normal(jax.random.PRNGKey(2), (1, 32))
    keys = jax.random.split(jax.random.PRNGKey(0), 5)
    tb, lb = sample_token_batch(keys, logits, cfg, bias=bias)
    for i in range(5):
        t, lp = sample_token(keys[i], logits, cfg, bias=bias)
        assert int(tb[i]) == int(t[0])
        np.testing.assert_array_equal(np.asarray(lb[i]), np.asarray(lp[0]))


# ---------------------------------------------------------------------------
# Rejection-sampling accept kernel
# ---------------------------------------------------------------------------

def _accept_args(B, V, *, n0=0, limit=100):
    return dict(token_counts=jnp.zeros((B, V), jnp.float32), bias=None,
                eos_id=V - 1, n_tok=jnp.full((B,), n0, jnp.int32),
                limit=jnp.full((B,), limit, jnp.int32),
                active=jnp.ones((B,), bool))


def test_greedy_accepts_matching_prefix_only():
    """Greedy rows emit argmaxes while the draft keeps predicting them,
    then stop at the first mismatch (the mismatch position still emits
    the corrected token)."""
    B, K, V = 2, 4, 8
    logits = jnp.zeros((B, K, V)).at[:, :, 2].set(5.0)   # argmax = 2 always
    draft = jnp.array([[2, 2, 2],       # perfect draft: full block emits
                       [2, 6, 2]],      # wrong at position 1
                      jnp.int32)
    toks, _, emit, counts, n, stopped = speculative_accept(
        jax.random.PRNGKey(0), 0, logits, draft,
        SamplingConfig(temperature=0.0, repetition_penalty=1.0),
        greedy=jnp.ones((B,), bool), greedy_static=False,
        **_accept_args(B, V))
    assert emit.tolist() == [[True] * 4, [True, True, False, False]]
    assert n.tolist() == [4, 2]
    assert not bool(stopped.any())
    assert jnp.where(emit, toks, -1).tolist() == [[2, 2, 2, 2],
                                                  [2, 2, -1, -1]]
    np.testing.assert_array_equal(np.asarray(counts).sum(axis=1), [4.0, 2.0])


def test_limit_and_eos_truncate_block():
    """Over-drafted tokens past the per-slot limit (or EOS) never emit —
    the device-side truncation the scheduler's worst-case commitment
    accounting relies on."""
    B, K, V = 2, 4, 8
    logits = jnp.zeros((B, K, V)).at[0, :, 2].set(5.0)
    logits = logits.at[1, :, V - 1].set(5.0)             # row 1 argmax = EOS
    draft = jnp.full((B, K - 1), 2, jnp.int32)
    args = _accept_args(B, V)
    args["n_tok"] = jnp.array([1, 0], jnp.int32)
    args["limit"] = jnp.array([3, 10], jnp.int32)        # row 0: 2 tokens left
    toks, _, emit, _, n, stopped = speculative_accept(
        jax.random.PRNGKey(0), 0, logits, draft,
        SamplingConfig(temperature=0.0, repetition_penalty=1.0),
        greedy=jnp.ones((B,), bool), greedy_static=False, **args)
    assert emit.tolist()[0] == [True, True, False, False]
    assert int(n[0]) == 3                                 # capped at limit
    assert emit.tolist()[1] == [True, False, False, False]  # EOS stops row 1
    assert stopped.tolist() == [True, True]


@pytest.mark.parametrize("rep_penalty", [1.0, 1.3])
def test_greedy_static_matches_sequential_path(rep_penalty):
    """The vectorized all-greedy path must emit byte-identical tokens,
    logprobs, counts, and stop flags to the sequential general path."""
    B, K, V = 4, 5, 16
    key = jax.random.PRNGKey(3)
    logits = jax.random.normal(key, (B, K, V))
    toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    draft = toks[:, 1:]                                   # perfect draft...
    draft = draft.at[1, 2].set((draft[1, 2] + 1) % V)     # ...mismatch row 1
    draft = draft.at[2, 0].set(-1)                        # ...no draft row 2
    cfg = SamplingConfig(temperature=0.7, top_p=0.9, top_k=5,
                         repetition_penalty=rep_penalty)
    args = _accept_args(B, V, n0=1, limit=4)              # row limits bite
    outs = []
    for static in (False, True):
        outs.append(speculative_accept(
            jax.random.PRNGKey(0), 0, logits, draft, cfg,
            greedy=jnp.ones((B,), bool), greedy_static=static, **args))
    (t0, l0, e0, c0, n0_, s0), (t1, l1, e1, c1, n1_, s1) = outs
    np.testing.assert_array_equal(np.asarray(e0), np.asarray(e1))
    np.testing.assert_array_equal(np.where(e0, t0, -1), np.where(e1, t1, -1))
    np.testing.assert_allclose(np.where(e0, l0, 0.0), np.where(e1, l1, 0.0),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(c0), np.asarray(c1))
    np.testing.assert_array_equal(np.asarray(n0_), np.asarray(n1_))
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))


@pytest.mark.slow
def test_rejection_sampling_preserves_target_distribution():
    """Leviathan guarantee: with a deterministic draft, the emitted
    marginal at a position equals the processed target distribution
    exactly — accepted-draft mass plus residual resamples reassemble p."""
    B, K, V = 8192, 2, 8
    row = jnp.array([2.0, 0.5, 1.0, 1.5, -1.0, 0.0, 0.3, -0.5])
    logits = jnp.broadcast_to(row, (B, K, V))
    draft = jnp.full((B, K - 1), 3, jnp.int32)            # always propose 3
    cfg = SamplingConfig(temperature=1.0, top_p=1.0, top_k=0,
                         repetition_penalty=1.0)
    toks, _, emit, _, _, _ = speculative_accept(
        jax.random.PRNGKey(7), 0, logits, draft, cfg,
        greedy=jnp.zeros((B,), bool), greedy_static=False,
        **_accept_args(B, V))
    assert bool(emit[:, 0].all())
    freq = np.bincount(np.asarray(toks[:, 0]), minlength=V) / B
    p = np.asarray(jax.nn.softmax(row))
    np.testing.assert_allclose(freq, p, atol=0.02)


# ---------------------------------------------------------------------------
# N-gram drafter
# ---------------------------------------------------------------------------

def _spec_engine(model, params, **kw):
    defaults = dict(slots=4, cache_len=64, max_new=16, n_candidates=1,
                    mode="greedy", macro_steps=8, paged_kv=PAGE,
                    spec_k=4, spec_ngram=2)
    defaults.update(kw)
    return _mk_base(model, params, **defaults)


def test_ngram_draft_prefers_deep_full_match(tiny_model):
    """On a periodic history the drafter must back off past the trivial
    tail self-match to the most recent occurrence with ALL followers
    known, and propose the continuation."""
    cfg, model, params = tiny_model
    eng = _spec_engine(model, params, impl="xla")
    H = eng.cache_len
    hist = np.full((1, H), -1, np.int32)
    hist[0, :8] = [1, 2, 3, 1, 2, 3, 1, 2]
    d = eng._ngram_draft(jnp.asarray(hist), jnp.array([8]), jnp.array([2]))
    assert np.asarray(d)[0].tolist() == [3, 1, 2]


def test_ngram_draft_no_match_no_proposal(tiny_model):
    cfg, model, params = tiny_model
    eng = _spec_engine(model, params, impl="xla")
    H = eng.cache_len
    hist = np.full((1, H), -1, np.int32)
    hist[0, :5] = [5, 6, 7, 8, 9]                         # all distinct
    d = eng._ngram_draft(jnp.asarray(hist), jnp.array([5]), jnp.array([9]))
    assert np.asarray(d)[0].tolist() == [-1, -1, -1]


# ---------------------------------------------------------------------------
# Engine-level byte-identity and acceleration
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", ["fifo", "coverage"])
@pytest.mark.parametrize("impl", ["xla", "paged"])
def test_greedy_streams_identical_spec_on_off(tiny_model, impl, policy):
    """Acceptance bar: greedy token streams are byte-identical with
    speculation on and off, for both KV impls and both scheduler
    policies — rejection of a mismatched draft replays exactly the
    sequential argmax."""
    cfg, model, params = tiny_model
    outs = {}
    for k in (0, 4):
        eng = _spec_engine(model, params, impl=impl, sched_policy=policy,
                           spec_k=k)
        _submit_base(eng, cfg, 3)
        res = sorted(eng.run(), key=lambda r: r.uid)
        if eng.paged:
            eng.pool.check()
            assert eng.pool.in_use == 0
        outs[k] = [[int(t) for t in r.tokens] for r in res]
    assert outs[0] == outs[4]


def test_spec_accepts_and_saves_steps_on_repetitive_prompt(tiny_model):
    """A prompt the model continues periodically must actually exercise
    the drafter: accepted tokens > 0 and fewer device steps than the
    non-speculative run for the same (identical) output."""
    cfg, model, params = tiny_model
    prompt = np.tile(np.array([3, 4, 5], np.int32), 6)
    steps, toks = {}, {}
    for k in (0, 4):
        eng = _spec_engine(model, params, impl="paged", spec_k=k, max_new=24)
        eng.submit(Request(uid=0, prompt=prompt))
        res = list(eng.run())
        toks[k] = [int(t) for t in res[0].tokens]
        steps[k] = eng.total_steps
        if k:
            assert eng.spec_drafted > 0
            assert eng.spec_accepted > 0
            assert eng.spec_accepted <= eng.spec_drafted
    assert toks[0] == toks[4]
    assert steps[4] < steps[0]


def test_coverage_mode_shrinks_draft_budget(tiny_model):
    """spec_mode='coverage': once a request's posterior coverage deficit
    closes, freshly admitted candidates get k_eff < spec_k; first
    admissions (no p* yet) always get the full budget."""
    cfg, model, params = tiny_model
    eng = _spec_engine(model, params, impl="xla", spec_k=4,
                       spec_mode="coverage")
    assert eng._coverage_k(None) == 4                     # no posterior yet
    assert eng._coverage_k(1.0) == 1                      # deficit closed
    assert 1 <= eng._coverage_k(0.5) <= 4
    fixed = _spec_engine(model, params, impl="xla", spec_k=4,
                         spec_mode="fixed")
    assert fixed._coverage_k(1.0) == 4                    # fixed never shrinks
