"""Trace-time sharding context for model-internal constraints.

Model code (e.g. the MoE dispatch) sometimes must pin activation
shardings that GSPMD cannot infer profitably on its own. The launcher
sets the axis names here before tracing; outside any mesh the constraints
become no-ops so the same model code runs single-device.
"""
from __future__ import annotations

from contextvars import ContextVar
from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

# Default DISABLED: measured on kimi-k2 train_4k, pinning expert-sharding
# produced 13.4 TB/dev collectives vs 12.4 TB for GSPMD's own propagation
# (EXPERIMENTS.md §Perf, iteration "expert-constraint"). Launchers can
# opt in via set_expert_axes(("data",)).
_EP_AXES: ContextVar[Tuple[str, ...]] = ContextVar(
    "ep_axes", default=("__disabled__",))


def set_expert_axes(axes: Tuple[str, ...]) -> None:
    _EP_AXES.set(tuple(axes))


def get_expert_axes() -> Tuple[str, ...]:
    return _EP_AXES.get()


def maybe_constrain(x, spec: P):
    """with_sharding_constraint that degrades to identity outside a mesh."""
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:  # no mesh context / unknown axis names
        return x


_BATCH_AXES: ContextVar[Tuple[str, ...]] = ContextVar(
    "batch_axes", default=("data",))


def set_batch_axes(axes: Tuple[str, ...]) -> None:
    _BATCH_AXES.set(tuple(axes))


def get_batch_axes() -> Tuple[str, ...]:
    return _BATCH_AXES.get()


def _axes_size(mesh, axes) -> int:
    try:
        import numpy as np
        return int(np.prod([mesh.shape[a] for a in axes]))
    except Exception:
        return 0


def _physical_mesh():
    """The mesh installed by ``with mesh:`` (Auto axis types leave the
    abstract mesh empty, so read the physical thread resource)."""
    try:
        from jax._src.mesh import thread_resources
        m = thread_resources.env.physical_mesh
        return m if m.shape else None
    except Exception:
        return None


def constrain_logits(logits, model_axis: str = "model"):
    """Pin (B, ..., V) logits to batch-over-dp, vocab-over-model sharding.

    On the 3-axis multi-pod mesh GSPMD resolves the unembed matmul by
    replicating the batch (a 40 GB/device logits buffer — §Perf iteration
    11); this one constraint keeps the batch on ("pod","data").
    No-op outside a mesh.
    """
    mesh = _physical_mesh()
    if mesh is None:
        return logits
    try:
        sizes = dict(mesh.shape)
        bp = tuple(a for a in get_batch_axes() if a in sizes)
        import numpy as np
        if not bp or logits.shape[0] % int(np.prod([sizes[a] for a in bp])):
            return logits
        # vocab over "model" (GSPMD pads uneven shards), batch over dp.
        v_ax = model_axis if model_axis in sizes else None
        spec = P(bp, *([None] * (logits.ndim - 2)), v_ax)
        return jax.lax.with_sharding_constraint(logits, spec)
    except Exception:
        return logits
