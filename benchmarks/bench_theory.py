"""Theorem 4.2 numerics — the paper's tail-class decay rates, measured.

For each tail class of G(s): draw a large population, measure Δ(K)
empirically, fit the predicted functional form, and report the fitted
vs predicted parameters.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import theory


def run(n: int = 400_000, verbose: bool = True):
    Ks = np.array([2, 4, 8, 16, 32, 64, 128, 256])
    out = {}

    for alpha in (0.4, 0.6, 0.8):
        s = theory.sample_heavy_tail(jax.random.PRNGKey(0), n, alpha)
        d = np.asarray(theory.residual_risk(jnp.asarray(Ks), s))
        fit, _ = theory.fit_power_law(Ks[1:], d[1:])
        out[f"heavy_alpha{alpha}"] = {"fitted_exponent": float(fit),
                                      "predicted": alpha,
                                      "delta_at_64": float(d[Ks == 64][0])}
        if verbose:
            print(f"  heavy tail α={alpha}: Δ(K)~K^-{fit:.3f} "
                  f"(theory: K^-{alpha})")

    s = theory.sample_light_tail(jax.random.PRNGKey(1), n)
    d = np.asarray(theory.residual_risk(jnp.asarray(Ks), s))
    c, _ = theory.fit_exponential(Ks[:5], d[:5])
    out["light"] = {"fitted_rate": float(c), "delta_at_64": float(d[Ks == 64][0])}
    if verbose:
        print(f"  light tail: Δ(K)~e^(-{c:.3f}K) (exponential ✓)")

    s = theory.sample_stretched_exp(jax.random.PRNGKey(2), n)
    d = np.asarray(theory.residual_risk(jnp.asarray(Ks), s))
    # log Δ ~ -C K^(θ/(θ+1)) with θ=1 ⇒ slope 0.5 in log(-logΔ) vs logK
    y = np.log(-np.log(np.maximum(d, 1e-12)))
    slope = np.polyfit(np.log(Ks[2:]), y[2:], 1)[0]
    out["stretched"] = {"fitted_k_exponent": float(slope), "predicted": 0.5}
    if verbose:
        print(f"  stretched-exp: log Δ ~ -C·K^{slope:.2f} (theory: K^0.5)")

    # K*(ε) budget rule (Eq. 6)
    out["k_star"] = {
        "heavy_eps0.05": theory.k_star(0.05, 0.0, "heavy", alpha=0.5),
        "light_eps0.05": theory.k_star(0.05, 0.0, "light"),
    }
    if verbose:
        print(f"  K*(0.05): heavy={out['k_star']['heavy_eps0.05']:.0f}, "
              f"light={out['k_star']['light_eps0.05']:.1f}")
    return out


if __name__ == "__main__":
    run()
