"""Fused cross-modal consistency scoring kernel (paper Eq. 8-9).

S_align needs two reductions over cosine-similarity matrices that are
never worth materializing at serving scale (L generated tokens × Nv
visual-evidence features, and Nt prompt tokens × Nv):

  term1 = mean_t mean_j cos(v_j, f(y_t))      (token ↔ visual grounding)
  term2 = mean_r max_j  cos(t_r, v_j)         (prompt ↔ visual consistency)

The kernel fuses L2 normalization, the block matmul, and the row
mean/max reductions; each (token-block × evidence-block) tile lives only
in VMEM. Outputs are per-batch scalar accumulators; the wrapper applies
the final 1/(L·Nv) and 1/Nt normalizations.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _norm_rows(x, eps=1e-8):
    n = jnp.sqrt(jnp.sum(x * x, axis=-1, keepdims=True))
    return x / jnp.maximum(n, eps)


def _mean_kernel(tok_ref, tmask_ref, vis_ref, vmask_ref, o_ref, acc_scr, *,
                 nl: int, nv: int):
    """Accumulates sum_t sum_j cos(tok_t, vis_j) over valid pairs."""
    il = pl.program_id(1)
    iv = pl.program_id(2)

    @pl.when((il == 0) & (iv == 0))
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    tok = _norm_rows(tok_ref[0].astype(jnp.float32))        # (blk_l, d)
    vis = _norm_rows(vis_ref[0].astype(jnp.float32))        # (blk_v, d)
    tm = tmask_ref[0]                                       # (blk_l,)
    vm = vmask_ref[0]                                       # (blk_v,)
    sims = jax.lax.dot_general(tok, vis, (((1,), (1,)), ((), ())))
    sims = sims * tm[:, None] * vm[None, :]
    acc_scr[0, 0] += jnp.sum(sims)

    @pl.when((il == nl - 1) & (iv == nv - 1))
    def _finish():
        o_ref[0, 0] = acc_scr[0, 0]


def _max_kernel(txt_ref, tmask_ref, vis_ref, vmask_ref, o_ref, max_scr,
                acc_scr, *, nv: int, nt: int):
    """Accumulates sum_r max_j cos(txt_r, vis_j) over valid rows."""
    it = pl.program_id(1)
    iv = pl.program_id(2)

    @pl.when((it == 0) & (iv == 0))
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(iv == 0)
    def _row_init():
        max_scr[...] = jnp.full_like(max_scr, NEG_INF)

    txt = _norm_rows(txt_ref[0].astype(jnp.float32))        # (blk_t, d)
    vis = _norm_rows(vis_ref[0].astype(jnp.float32))        # (blk_v, d)
    vm = vmask_ref[0] > 0
    sims = jax.lax.dot_general(txt, vis, (((1,), (1,)), ((), ())))
    sims = jnp.where(vm[None, :], sims, NEG_INF)
    max_scr[...] = jnp.maximum(max_scr[...],
                               jnp.max(sims, axis=-1, keepdims=True))

    @pl.when(iv == nv - 1)
    def _row_finish():
        tm = tmask_ref[0]
        acc_scr[0, 0] += jnp.sum(max_scr[:, 0] * tm)

    @pl.when((it == nt - 1) & (iv == nv - 1))
    def _finish():
        o_ref[0, 0] = acc_scr[0, 0]


def _pad_to(x, n, axis):
    pad = (-x.shape[axis]) % n
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("blk", "interpret"))
def xmodal_score(token_embs, mask, visual_feats, text_feats, *,
                 blk: int = 128, interpret: bool = False):
    """token_embs: (B, L, d); mask: (B, L); visual_feats: (B, Nv, d);
    text_feats: (B, Nt, d). Returns S_align (B,) per Eq. 9."""
    B, L, d = token_embs.shape
    Nv = visual_feats.shape[1]
    Nt = text_feats.shape[1]
    tok = _pad_to(token_embs, blk, 1)
    tm = _pad_to(mask.astype(jnp.float32), blk, 1)
    vis = _pad_to(visual_feats, blk, 1)
    vm = _pad_to(jnp.ones((B, Nv), jnp.float32), blk, 1)
    txt = _pad_to(text_feats, blk, 1)
    xm = _pad_to(jnp.ones((B, Nt), jnp.float32), blk, 1)
    nl, nv, nt = tok.shape[1] // blk, vis.shape[1] // blk, txt.shape[1] // blk

    sum1 = pl.pallas_call(
        functools.partial(_mean_kernel, nl=nl, nv=nv),
        grid=(B, nl, nv),
        in_specs=[
            pl.BlockSpec((1, blk, d), lambda b, il, iv: (b, il, 0)),
            pl.BlockSpec((1, blk), lambda b, il, iv: (b, il)),
            pl.BlockSpec((1, blk, d), lambda b, il, iv: (b, iv, 0)),
            pl.BlockSpec((1, blk), lambda b, il, iv: (b, iv)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda b, il, iv: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((B, 1), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, 1), jnp.float32)],
        interpret=interpret,
    )(tok, tm, vis, vm)

    sum2 = pl.pallas_call(
        functools.partial(_max_kernel, nv=nv, nt=nt),
        grid=(B, nt, nv),
        in_specs=[
            pl.BlockSpec((1, blk, d), lambda b, it, iv: (b, it, 0)),
            pl.BlockSpec((1, blk), lambda b, it, iv: (b, it)),
            pl.BlockSpec((1, blk, d), lambda b, it, iv: (b, iv, 0)),
            pl.BlockSpec((1, blk), lambda b, it, iv: (b, iv)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda b, it, iv: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((B, 1), jnp.float32),
        scratch_shapes=[pltpu.VMEM((blk, 1), jnp.float32),
                        pltpu.VMEM((1, 1), jnp.float32)],
        interpret=interpret,
    )(txt, xm, vis, vm)

    n_tok = jnp.maximum(jnp.sum(mask.astype(jnp.float32), axis=-1), 1.0)
    term1 = sum1[:, 0] / (n_tok * Nv)
    term2 = sum2[:, 0] / Nt
    return 0.5 * (term1 + term2)
