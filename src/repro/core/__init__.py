"""CAMD core — the paper's contribution as composable JAX modules.

scoring     Eq. 7-12  evidence-weighted scoring
clustering  Eq. 13    online semantic clustering (fixed-M, jit/vmap-able)
posterior   Eq. 14-16 coverage estimation, Dirichlet update, mixture bias
rescore     §5.1       plug-and-play wrapper: score/stop external candidates
controller             per-request round state machine (engine hot path)
theory      §4.1       coverage/residual-risk numerics, Theorem 4.2 checks
"""
from repro.core import clustering, posterior, rescore, scoring, theory  # noqa: F401
from repro.core.controller import (  # noqa: F401
    CAMDState,
    RoundInputs,
    batched_init,
    batched_round_update,
    batched_round_update_assign,
    init_state,
    round_update,
    round_update_assign,
    score_candidates,
)
