"""Quickstart: build a model, train briefly, decode with CAMD.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.config import CAMDConfig, SamplingConfig, TrainConfig
from repro.configs import get_config, list_configs
from repro.data import lm_batches
from repro.models import build_model
from repro.serving import Request, ServeEngine
from repro.training import train


def main():
    print("assigned architectures:", ", ".join(list_configs()))

    # 1) any assigned architecture is selectable; reduce for CPU.
    cfg = get_config("qwen3-0.6b").reduced().with_overrides(dtype="float32")
    model = build_model(cfg, jnp.float32)

    # 2) short training run on the synthetic pipeline.
    data = ({"tokens": jnp.asarray(b["tokens"]),
             "labels": jnp.asarray(b["labels"])}
            for b in lm_batches(cfg.vocab_size, 8, 64, seed=0))
    params, _, hist = train(
        model, TrainConfig(total_steps=40, warmup_steps=8,
                           learning_rate=1e-3), data, steps=40, log_every=10)
    print(f"loss {hist[0]['loss']:.2f} -> {hist[-1]['loss']:.2f}")

    # 3) serve a few prompts with Coverage-Aware Multimodal Decoding.
    eng = ServeEngine(
        model, params, slots=6, cache_len=64,
        sampling=SamplingConfig(max_new_tokens=12, temperature=0.8),
        camd=CAMDConfig(samples_per_round=2, max_rounds=3, min_samples=2),
        mode="camd", max_new_tokens=12, eos_id=1)
    rng = np.random.default_rng(0)
    for i in range(3):
        eng.submit(Request(uid=i, prompt=rng.integers(
            2, cfg.vocab_size, 8).astype(np.int32)))
    for r in eng.run():
        print(f"req {r.uid}: {r.n_candidates} candidates in {r.rounds} "
              f"rounds, {r.tokens_spent} tokens, p*={r.p_star:.2f}, "
              f"answer tokens {r.tokens[:6].tolist()}...")


if __name__ == "__main__":
    main()
