"""Decoder-only multimodal LM assembled from heterogeneous blocks.

Layers are executed as a `lax.scan` over *super-blocks* (one tile of the
config's ``block_pattern``), with per-pattern-position stacked parameters —
HLO size and compile time are O(1) in depth, which is what makes the
88-layer granite-34b × 80 dry-run compiles tractable and is the production
idiom (MaxText et al.). Layers left over when ``num_layers`` is not a
multiple of the pattern length run as unstacked "tail" layers.

Multimodal inputs: ``evidence`` (precomputed frame/patch embeddings from the
stubbed modality frontend) is projected and *prepended* to the token
embeddings; positions are shared across the concatenated sequence.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ATTN, LOCAL_ATTN, RGLRU, SSM, ModelConfig
from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models import rglru as rglru_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import (dense, dense_init, embed, embed_init, mlp,
                                 mlp_init, rmsnorm, rmsnorm_init)

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Block init / apply
# ---------------------------------------------------------------------------

def _has_mlp(cfg: ModelConfig, kind: str) -> bool:
    return kind in (ATTN, LOCAL_ATTN, RGLRU) and (cfg.d_ff > 0 or cfg.moe is not None)


def block_init(key, cfg: ModelConfig, kind: str, dtype) -> Params:
    keys = jax.random.split(key, 4)
    p: Params = {"ln1": rmsnorm_init(cfg.d_model, dtype)}
    if kind in (ATTN, LOCAL_ATTN):
        p["attn"] = attn_lib.attn_init(keys[0], cfg, dtype)
    elif kind == SSM:
        p["ssm"] = ssm_lib.ssm_init(keys[0], cfg, dtype)
    elif kind == RGLRU:
        p["rglru"] = rglru_lib.rglru_init(keys[0], cfg, dtype)
    else:
        raise ValueError(kind)
    if _has_mlp(cfg, kind):
        p["ln2"] = rmsnorm_init(cfg.d_model, dtype)
        if cfg.moe is not None:
            p["moe"] = moe_lib.moe_init(keys[1], cfg, dtype)
        else:
            p["mlp"] = mlp_init(keys[1], cfg.d_model, cfg.d_ff,
                                cfg.mlp_activation, dtype)
    return p


def _mlp_part(params: Params, cfg: ModelConfig, x):
    aux: Dict[str, jax.Array] = {}
    h = rmsnorm(params["ln2"], x, cfg.norm_eps)
    if "moe" in params:
        y, aux = moe_lib.moe_apply(params["moe"], cfg, h)
    else:
        y = mlp(params["mlp"], h, cfg.mlp_activation)
    return x + y, aux


def _window_for(cfg: ModelConfig, kind: str) -> int:
    return cfg.attn_window if kind == ATTN else cfg.local_window


def block_prefill(params: Params, cfg: ModelConfig, kind: str, x, positions,
                  impl: str, kv_mask=None, ctx_kv=None, q_offset=0,
                  lengths=None) -> Tuple[jax.Array, Any, Dict]:
    aux: Dict[str, jax.Array] = {}
    h = rmsnorm(params["ln1"], x, cfg.norm_eps)
    if kind in (ATTN, LOCAL_ATTN):
        y, (k, v) = attn_lib.attn_prefill(params["attn"], cfg, h, positions,
                                          window=_window_for(cfg, kind),
                                          impl=impl, kv_mask=kv_mask,
                                          ctx_kv=ctx_kv, q_offset=q_offset)
        x = x + y
        if _has_mlp(cfg, kind):
            x, aux = _mlp_part(params, cfg, x)
        entry = {"k": k, "v": v}
    elif kind == SSM:
        y, entry = ssm_lib.ssm_prefill(params["ssm"], cfg, h, lengths=lengths)
        x = x + y
    else:  # RGLRU
        y, entry = rglru_lib.rglru_prefill(params["rglru"], cfg, h,
                                           lengths=lengths)
        x = x + y
        if _has_mlp(cfg, kind):
            x, aux = _mlp_part(params, cfg, x)
    return x, entry, aux


def block_decode(params: Params, cfg: ModelConfig, kind: str, x, cache_entry,
                 pos, impl: str, block_table=None) -> Tuple[jax.Array, Any]:
    h = rmsnorm(params["ln1"], x, cfg.norm_eps)
    if kind in (ATTN, LOCAL_ATTN):
        y, entry = attn_lib.attn_decode(params["attn"], cfg, h, cache_entry,
                                        pos, window=_window_for(cfg, kind),
                                        impl=impl, block_table=block_table)
        x = x + y
        if _has_mlp(cfg, kind):
            x, _ = _mlp_part(params, cfg, x)
    elif kind == SSM:
        y, entry = ssm_lib.ssm_decode(params["ssm"], cfg, h, cache_entry)
        x = x + y
    else:
        y, entry = rglru_lib.rglru_decode(params["rglru"], cfg, h, cache_entry)
        x = x + y
        if _has_mlp(cfg, kind):
            x, _ = _mlp_part(params, cfg, x)
    return x, entry


def block_cache(cfg: ModelConfig, kind: str, batch: int, cache_len: int, dtype):
    if kind in (ATTN, LOCAL_ATTN):
        n = cache_len if kind == ATTN and cfg.attn_window == 0 else \
            min(cache_len, _window_for(cfg, kind))
        return attn_lib.make_kv_cache(cfg, batch, n, dtype)
    if kind == SSM:
        return ssm_lib.make_ssm_state(cfg, batch, dtype)
    return rglru_lib.make_rglru_state(cfg, batch, dtype)


# ---------------------------------------------------------------------------
# Whole-model init
# ---------------------------------------------------------------------------

def _pattern_split(cfg: ModelConfig):
    pat = cfg.block_pattern
    n_super = cfg.num_layers // len(pat)
    tail = cfg.layer_kinds[n_super * len(pat):]
    return pat, n_super, tail


def transformer_init(key, cfg: ModelConfig, dtype) -> Params:
    pat, n_super, tail = _pattern_split(cfg)
    keys = jax.random.split(key, 8)
    params: Params = {"embed": embed_init(keys[0], cfg.vocab_size, cfg.d_model, dtype)}

    def stacked_init(kind: str, base_key):
        ks = jax.random.split(base_key, n_super)
        per_layer = [block_init(k, cfg, kind, dtype) for k in ks]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *per_layer)

    params["super"] = tuple(
        stacked_init(kind, jax.random.fold_in(keys[1], i))
        for i, kind in enumerate(pat))
    params["tail"] = tuple(
        block_init(jax.random.fold_in(keys[2], i), cfg, kind, dtype)
        for i, kind in enumerate(tail))
    params["final_norm"] = rmsnorm_init(cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(keys[3], cfg.d_model, cfg.vocab_size, dtype)
    if cfg.num_evidence_tokens and cfg.evidence_dim != cfg.d_model:
        params["evidence_proj"] = dense_init(keys[4], cfg.evidence_dim,
                                             cfg.d_model, dtype)
    if cfg.vision is not None:
        from repro.models import vision as vision_lib
        params["vision"] = vision_lib.vision_init(keys[5], cfg, dtype)
    return params


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def embed_inputs(params: Params, cfg: ModelConfig, tokens, evidence=None):
    x = embed(params["embed"], tokens)
    if evidence is not None:
        ev = evidence.astype(x.dtype)
        if "evidence_proj" in params:
            ev = dense(params["evidence_proj"], evidence).astype(x.dtype)
        x = jnp.concatenate([ev, x], axis=1)
    return x


def _logits(params: Params, cfg: ModelConfig, h):
    from repro.distributed.context import constrain_logits
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = h @ params["embed"]["table"].T
    else:
        logits = dense(params["unembed"], h)
    return constrain_logits(logits), h


def _sum_aux(aux_list):
    out: Dict[str, jax.Array] = {}
    for aux in aux_list:
        for k, v in aux.items():
            out[k] = out.get(k, 0.0) + jnp.mean(v)
    return out


def transformer_forward(params: Params, cfg: ModelConfig, tokens,
                        evidence=None, *, impl: str = "xla",
                        remat: bool = False, unroll: bool = False
                        ) -> Tuple[jax.Array, jax.Array, Dict]:
    """Full-sequence forward (training / scoring). Returns
    (logits (B, L, V), hidden (B, L, d), aux).

    ``unroll=True`` replaces the layer scan with a python loop — used by
    the dry-run cost model (XLA's cost_analysis counts a scan body once,
    so per-layer costs are measured on shallow unrolled variants and
    extrapolated; see launch/dryrun.py)."""
    pat, n_super, tail = _pattern_split(cfg)
    x = embed_inputs(params, cfg, tokens, evidence)
    B, L, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32), (B, L))

    def superblock(x, layer_params):
        aux_acc = []
        for p, kind in zip(layer_params, pat):
            x, _, aux = block_prefill(p, cfg, kind, x, positions, impl)
            aux_acc.append(aux)
        return x, _sum_aux(aux_acc)

    body = jax.checkpoint(superblock) if remat else superblock

    if unroll:
        aux_list = []
        for i in range(n_super):
            lp = jax.tree.map(lambda a: a[i], params["super"])
            x, aux = body(x, lp)
            aux_list.append(aux)
        auxs = {k: jnp.stack([a[k] for a in aux_list])
                for k in (aux_list[0] if aux_list else {})}
    else:
        x, auxs = jax.lax.scan(lambda c, lp: body(c, lp), x, params["super"])
    # auxs values are stacked per-super-block scalars -> mean over depth.
    aux_out = {k: jnp.mean(v) for k, v in auxs.items()}
    for p, kind in zip(params["tail"], tail):
        x, _, aux = block_prefill(p, cfg, kind, x, positions, impl)
        for k, v in aux.items():
            aux_out[k] = aux_out.get(k, 0.0) + jnp.mean(v)
    logits, hidden = _logits(params, cfg, x)
    return logits, hidden, aux_out


def make_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype):
    pat, n_super, tail = _pattern_split(cfg)

    def stack_entries(kind):
        e = block_cache(cfg, kind, batch, cache_len, dtype)
        return jax.tree.map(lambda x: jnp.broadcast_to(x, (n_super,) + x.shape), e)

    return {
        "super": tuple(stack_entries(k) for k in pat),
        "tail": tuple(block_cache(cfg, k, batch, cache_len, dtype) for k in tail),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def make_paged_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype,
                     page_size: int, num_pages: int,
                     kv_dtype: str = "auto"):
    """Decode cache with full-attention KV held as a shared page pool.

    Full-attention entries become batchless (num_pages, page_size, Hkv,
    hd) pools addressed through ``cache["block_table"]`` (B, n_pages);
    windowed attention / SSM / RG-LRU entries keep their dense per-slot
    state (they are already O(window/state), not O(cache_len)).

    ``kv_dtype`` selects the pool storage mode (fp32/bf16/int8/fp8 —
    see ``attention.make_paged_kv_cache``); it applies to the paged
    pools only, dense entries stay in ``dtype``.
    """
    assert cache_len % page_size == 0, (cache_len, page_size)
    pat, n_super, tail = _pattern_split(cfg)

    def entry(kind):
        if kind == ATTN and cfg.attn_window == 0:
            return attn_lib.make_paged_kv_cache(cfg, num_pages, page_size,
                                                dtype, kv_dtype=kv_dtype)
        return block_cache(cfg, kind, batch, cache_len, dtype)

    def stack_entries(kind):
        e = entry(kind)
        return jax.tree.map(lambda x: jnp.broadcast_to(x, (n_super,) + x.shape), e)

    return {
        "super": tuple(stack_entries(k) for k in pat),
        "tail": tuple(entry(k) for k in tail),
        "pos": jnp.zeros((batch,), jnp.int32),
        "block_table": jnp.zeros((batch, cache_len // page_size), jnp.int32),
    }


def transformer_prefill(params: Params, cfg: ModelConfig, tokens, cache,
                        evidence=None, *, impl: str = "xla",
                        unroll: bool = False, lengths=None):
    """Prefill: run the full prompt, seed the cache.

    Without ``lengths``, every row of the batch shares the same prompt
    length L (the per-request serving path). With ``lengths`` ((B,) int32,
    counting evidence tokens), rows are right-padded to a common bucket
    length: last-token logits/hidden are gathered at each row's true last
    position and the cache ``pos`` is seeded per row. Right-padding is
    sound for attention layers because causal masking means a real
    position never attends a pad; the pad K/V written beyond ``pos`` are
    exactly the ring slots the decode validity mask rejects until they
    are overwritten. Recurrent layers (SSM/RG-LRU) mask pad steps out of
    their state transition (dt=0 / identity recurrence) and gather their
    decode seed at each row's true length — allclose- but NOT byte-exact
    vs per-row prefill (chunk/scan shapes track the padded L), which is
    why the serving engine still gates byte-exact bucketing on
    ``supports_bucketed_prefill``. Returns (logits_last (B,V),
    hidden_last (B,d), cache).
    """
    pat, n_super, tail = _pattern_split(cfg)
    x = embed_inputs(params, cfg, tokens, evidence)
    B, L, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32), (B, L))
    kv_mask = None
    if lengths is not None and impl == "xla":
        kv_mask = jnp.arange(L)[None, :] < lengths[:, None]

    def scan_body(x, inp):
        layer_params, cache_entries = inp
        new_entries = []
        for p, kind, ce in zip(layer_params, pat, cache_entries):
            x, entry, _ = block_prefill(p, cfg, kind, x, positions, impl,
                                        kv_mask=kv_mask, lengths=lengths)
            new_entries.append(_seed_entry(cfg, kind, ce, entry))
        return x, tuple(new_entries)

    if unroll:
        outs = []
        for i in range(n_super):
            inp_i = jax.tree.map(lambda a: a[i], (params["super"], cache["super"]))
            x, entry = scan_body(x, inp_i)
            outs.append(entry)
        new_super = jax.tree.map(lambda *xs: jnp.stack(xs), *outs) if outs \
            else cache["super"]
    else:
        x, new_super = jax.lax.scan(scan_body, x,
                                    (params["super"], cache["super"]))
    new_tail = []
    for p, kind, ce in zip(params["tail"], tail, cache["tail"]):
        x, entry, _ = block_prefill(p, cfg, kind, x, positions, impl,
                                    kv_mask=kv_mask, lengths=lengths)
        new_tail.append(_seed_entry(cfg, kind, ce, entry))
    if lengths is None:
        x_last = x[:, -1:]
        pos = jnp.full((B,), L, jnp.int32)
    else:
        x_last = jnp.take_along_axis(
            x, (lengths - 1)[:, None, None].astype(jnp.int32), axis=1)
        pos = lengths.astype(jnp.int32)
    logits, hidden = _logits(params, cfg, x_last)
    new_cache = {"super": new_super, "tail": tuple(new_tail), "pos": pos}
    return logits[:, 0], hidden[:, 0], new_cache


def transformer_prefill_suffix(params: Params, cfg: ModelConfig, tokens,
                               cache, ctx_kv, start, *, impl: str = "xla"):
    """Continuation prefill: run only the prompt *suffix* whose first
    ``start`` absolute positions' KV already exist (the cross-request
    prefix cache), attending to the supplied context K/V.

    ``tokens``: (B, s) suffix tokens occupying absolute positions
    [start, start+s). ``ctx_kv``: {"super": tuple of per-pattern-entry
    (k, v) stacked (n_super, B, start, Hkv, hd), "tail": tuple of
    (B, start, Hkv, hd) pairs} gathered from the cached pages. ``start``
    may be a traced int32 scalar (no recompile per prefix length; the
    suffix length s and the context length are shape-specializing).

    All-attention full-context decoders only — every layer's prompt
    state must live in the (cached) KV pages; recurrent or windowed
    layers would need their private prompt state replayed. The cache
    is seeded with the SUFFIX K/V at row positions [0, s) — callers
    track the ``start`` offset (engine: ``info["prefix_len"]``).
    Returns (logits_last (B, V), hidden_last (B, d), cache).
    """
    assert not cfg.is_encoder_decoder and cfg.attn_window == 0 and \
        all(k == ATTN for k in cfg.layer_kinds), \
        "prefix-cache continuation prefill needs an all-attention decoder"
    pat, n_super, tail = _pattern_split(cfg)
    x = embed_inputs(params, cfg, tokens)
    B, s, _ = x.shape
    positions = start + jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32),
                                         (B, s))

    def scan_body(x, inp):
        layer_params, cache_entries, ctx_entries = inp
        new_entries = []
        for p, kind, ce, cx in zip(layer_params, pat, cache_entries,
                                   ctx_entries):
            x, entry, _ = block_prefill(p, cfg, kind, x, positions, impl,
                                        ctx_kv=cx, q_offset=start)
            new_entries.append(_seed_entry(cfg, kind, ce, entry))
        return x, tuple(new_entries)

    x, new_super = jax.lax.scan(
        scan_body, x, (params["super"], cache["super"], ctx_kv["super"]))
    new_tail = []
    for p, kind, ce, cx in zip(params["tail"], tail, cache["tail"],
                               ctx_kv["tail"]):
        x, entry, _ = block_prefill(p, cfg, kind, x, positions, impl,
                                    ctx_kv=cx, q_offset=start)
        new_tail.append(_seed_entry(cfg, kind, ce, entry))
    logits, hidden = _logits(params, cfg, x[:, -1:])
    pos = jnp.full((B,), s, jnp.int32) + start
    return logits[:, 0], hidden[:, 0], \
        {"super": new_super, "tail": tuple(new_tail), "pos": pos}


def transformer_prefill_chunked(params: Params, cfg: ModelConfig, tokens,
                                cache, chunk: int, *, impl: str = "xla"):
    """Reference fixed-size chunked prefill: the prompt is processed in
    ``chunk``-token pieces, each attending to the K/V of every earlier
    piece through the suffix path, so the result is byte-identical to a
    whole-prompt ``transformer_prefill`` (causal masking zeroes the
    missing *future* keys in both). The serving engine has its own paged
    incarnation of this loop; this entry exists so the chunking math can
    be pinned against the whole-prompt path without an engine in the
    loop. Compiles once per distinct (chunk length, context length)
    shape pair instead of once per prompt length. Returns
    (logits_last (B, V), hidden_last (B, d), cache).
    """
    B, L = tokens.shape
    if chunk <= 0 or chunk >= L:
        return transformer_prefill(params, cfg, tokens, cache, impl=impl)
    assert not cfg.is_encoder_decoder and cfg.attn_window == 0 and \
        all(k == ATTN for k in cfg.layer_kinds), \
        "chunked prefill needs an all-attention decoder (suffix path)"

    def chunk_kv(ch_cache, s):
        # Both prefill entries seed the chunk's K/V at rows [0, s).
        sup = tuple((e["k"][:, :, :s], e["v"][:, :, :s])
                    for e in ch_cache["super"])
        tl = tuple((e["k"][:, :s], e["v"][:, :s])
                   for e in ch_cache["tail"])
        return sup, tl

    logits = hidden = ctx_sup = ctx_tl = None
    pos = 0
    while pos < L:
        s = min(chunk, L - pos)
        piece = tokens[:, pos:pos + s]
        if pos == 0:
            logits, hidden, ch_cache = transformer_prefill(
                params, cfg, piece, cache, impl=impl)
        else:
            ctx = {"super": ctx_sup, "tail": ctx_tl}
            logits, hidden, ch_cache = transformer_prefill_suffix(
                params, cfg, piece, cache, ctx, jnp.int32(pos), impl=impl)
        sup, tl = chunk_kv(ch_cache, s)
        ctx_sup = sup if ctx_sup is None else tuple(
            (jnp.concatenate([a[0], b[0]], axis=2),
             jnp.concatenate([a[1], b[1]], axis=2))
            for a, b in zip(ctx_sup, sup))
        ctx_tl = tl if ctx_tl is None else tuple(
            (jnp.concatenate([a[0], b[0]], axis=1),
             jnp.concatenate([a[1], b[1]], axis=1))
            for a, b in zip(ctx_tl, tl))
        pos += s

    def seed(ce, kv):
        k, v = kv
        return {
            "k": jax.lax.dynamic_update_slice(
                ce["k"], k.astype(ce["k"].dtype), (0,) * ce["k"].ndim),
            "v": jax.lax.dynamic_update_slice(
                ce["v"], v.astype(ce["v"].dtype), (0,) * ce["v"].ndim),
        }

    new_cache = {
        "super": tuple(seed(ce, kv)
                       for ce, kv in zip(cache["super"], ctx_sup)),
        "tail": tuple(seed(ce, kv)
                      for ce, kv in zip(cache["tail"], ctx_tl)),
        "pos": jnp.full((B,), L, jnp.int32),
    }
    return logits, hidden, new_cache


def _seed_entry(cfg: ModelConfig, kind: str, cache_entry, prefill_entry):
    if kind in (ATTN, LOCAL_ATTN):
        return attn_lib.prefill_into_cache(cache_entry, prefill_entry["k"],
                                           prefill_entry["v"])
    return jax.tree.map(lambda a, b: b.astype(a.dtype), cache_entry, prefill_entry)


def transformer_decode(params: Params, cfg: ModelConfig, token, cache, *,
                       impl: str = "xla", unroll: bool = False):
    """One decode step. token: (B,) or (B,1) int32. Returns
    (logits (B,V), hidden (B,d), new_cache)."""
    pat, n_super, tail = _pattern_split(cfg)
    if token.ndim == 1:
        token = token[:, None]
    pos = cache["pos"]
    bt = cache.get("block_table")
    x = embed(params["embed"], token)                  # (B,1,d)

    def scan_body(x, inp):
        layer_params, entries = inp
        new_entries = []
        for p, kind, ce in zip(layer_params, pat, entries):
            x, e = block_decode(p, cfg, kind, x, ce, pos, impl,
                                block_table=bt)
            new_entries.append(e)
        return x, tuple(new_entries)

    if unroll:
        outs = []
        for i in range(n_super):
            inp_i = jax.tree.map(lambda a: a[i], (params["super"], cache["super"]))
            x, entry = scan_body(x, inp_i)
            outs.append(entry)
        new_super = jax.tree.map(lambda *xs: jnp.stack(xs), *outs) if outs \
            else cache["super"]
    else:
        x, new_super = jax.lax.scan(scan_body, x,
                                    (params["super"], cache["super"]))
    new_tail = []
    for p, kind, ce in zip(params["tail"], tail, cache["tail"]):
        x, e = block_decode(p, cfg, kind, x, ce, pos, impl, block_table=bt)
        new_tail.append(e)
    logits, hidden = _logits(params, cfg, x)
    new_cache = {"super": new_super, "tail": tuple(new_tail), "pos": pos + 1}
    if bt is not None:
        new_cache["block_table"] = bt
    return logits[:, 0], hidden[:, 0], new_cache


def transformer_decode_block(params: Params, cfg: ModelConfig, tokens, cache,
                             valid=None, *, impl: str = "xla"):
    """Speculative block verification: feed S tokens per row at positions
    ``cache["pos"] + [0..S)`` and return per-position next-token logits.

    tokens: (B, S) int32 — token 0 is the pending last token, tokens
    1..S-1 the drafted continuation. ``valid``: optional (B, S) — invalid
    positions' KV writes are dropped (see ``attn_decode_block``).
    ``cache["pos"]`` is NOT advanced: the caller commits the accepted
    prefix length itself (speculative decoding "rewinds" rejected
    positions by simply not advancing pos — their stale KV is overwritten
    the next time the position is legitimately fed, before anything can
    attend to it).

    All-attention full-context decoders only (same predicate as the
    prefix cache): recurrent layers carry state that a partial rewind
    cannot restore, and windowed rings shorter than the block could
    alias within it. Returns (logits (B, S, V), hidden (B, S, d),
    new_cache).
    """
    assert not cfg.is_encoder_decoder and cfg.attn_window == 0 and \
        all(k == ATTN for k in cfg.layer_kinds), \
        "speculative block decode needs an all-attention decoder"
    pat, n_super, tail = _pattern_split(cfg)
    del pat, n_super, tail  # all-ATTN asserted above
    pos = cache["pos"]
    bt = cache.get("block_table")
    x = embed(params["embed"], tokens)                 # (B,S,d)

    def one_layer(x, p, ce):
        h = rmsnorm(p["ln1"], x, cfg.norm_eps)
        y, e = attn_lib.attn_decode_block(p["attn"], cfg, h, ce, pos,
                                          impl=impl, block_table=bt,
                                          valid=valid)
        x = x + y
        if _has_mlp(cfg, ATTN):
            x, _ = _mlp_part(p, cfg, x)
        return x, e

    def scan_body(x, inp):
        layer_params, entries = inp
        new_entries = []
        for p, ce in zip(layer_params, entries):
            x, e = one_layer(x, p, ce)
            new_entries.append(e)
        return x, tuple(new_entries)

    x, new_super = jax.lax.scan(scan_body, x,
                                (params["super"], cache["super"]))
    new_tail = []
    for p, ce in zip(params["tail"], cache["tail"]):
        x, e = one_layer(x, p, ce)
        new_tail.append(e)
    logits, hidden = _logits(params, cfg, x)
    new_cache = {"super": new_super, "tail": tuple(new_tail), "pos": pos}
    if bt is not None:
        new_cache["block_table"] = bt
    return logits, hidden, new_cache
