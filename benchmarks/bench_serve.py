"""Serving throughput — macro-step fused decode vs the per-token loop.

Measures the engine-level win of the device-resident decode loop
(``ServeEngine(macro_steps=K)``, a ``lax.while_loop`` over K
decode+sample+CAMD steps with pre-staged page frontiers) against the
legacy host loop (``macro_steps=0``): tokens/sec, wall-clock, and —
the quantity the refactor exists to shrink — host synchronizations per
generated token.

Grid: macro-step K ∈ {0 (per-token loop), 1, 8, 32} × impl ∈ {xla, paged}
× mode ∈ {camd, best_of_n}. Each cell warms up once (jit compile +
first-run allocation on a throwaway request batch), then times a fresh
request batch on the same engine so compiled functions are reused.

Writes ``BENCH_serve.json``; ``--smoke`` runs a reduced grid for CI.

  python -m benchmarks.bench_serve [--smoke]
"""
from __future__ import annotations

import json
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.config import CAMDConfig, ModelConfig, PagedKVConfig, SamplingConfig
from repro.models import build_model
from repro.serving import Request, ServeEngine


def _bench_model():
    cfg = ModelConfig(
        name="bench-serve-lm", family="dense", num_layers=4, d_model=256,
        num_heads=4, num_kv_heads=2, d_ff=768, vocab_size=512,
        head_dim=64, tie_embeddings=True, dtype="float32")
    model = build_model(cfg, jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _submit(eng, cfg, n, uid0=0, seed=0, plen=12):
    rng = np.random.default_rng(seed)
    for i in range(n):
        eng.submit(Request(uid=uid0 + i, prompt=rng.integers(
            2, cfg.vocab_size, plen).astype(np.int32)))


def _run_cell(cfg, model, params, *, impl, mode, macro_steps, requests,
              max_new):
    eng = ServeEngine(
        model, params, slots=8, cache_len=128,
        sampling=SamplingConfig(max_new_tokens=max_new, temperature=0.8),
        camd=CAMDConfig(samples_per_round=4, max_rounds=2, min_samples=4),
        mode=mode, n_candidates=4, max_new_tokens=max_new, eos_id=1,
        impl=impl, paged_kv=PagedKVConfig(page_size=16),
        macro_steps=macro_steps,
        # the pre-refactor loop also predates bucketed prefill
        bucket_prefill=macro_steps > 0,
        seed=0)
    # warmup: compile every jitted fn on a throwaway batch of the SAME
    # size as the timed one (prefill buckets / admission widths are
    # shape-specialized — a mismatch would put recompiles on the clock)
    _submit(eng, cfg, requests, uid0=10_000, seed=1)
    eng.run()
    eng.total_steps = eng.total_tokens = 0
    eng.macro_launches = eng.host_syncs = 0
    _submit(eng, cfg, requests, uid0=0, seed=2)
    t0 = time.perf_counter()
    eng.run()
    wall = time.perf_counter() - t0
    return {
        "impl": impl,
        "mode": mode,
        "macro_steps": macro_steps,
        "wall_s": wall,
        "tokens": eng.total_tokens,
        "device_steps": eng.total_steps,
        "tokens_per_s": eng.total_tokens / max(wall, 1e-9),
        "host_syncs": eng.host_syncs,
        "syncs_per_token": eng.host_syncs / max(eng.total_tokens, 1),
        "macro_launches": eng.macro_launches,
    }


def run(smoke: bool = False) -> dict:
    cfg, model, params = _bench_model()
    if smoke:
        impls, modes, ks = ["xla", "paged"], ["camd"], [0, 8]
        requests, max_new = 3, 16
    else:
        impls, modes, ks = ["xla", "paged"], ["camd", "best_of_n"], \
            [0, 1, 8, 32]
        requests, max_new = 6, 32
    rows = []
    for impl in impls:
        for mode in modes:
            for k in ks:
                row = _run_cell(cfg, model, params, impl=impl, mode=mode,
                                macro_steps=k, requests=requests,
                                max_new=max_new)
                rows.append(row)
                print(f"{impl:6s} {mode:10s} K={k:<3d} "
                      f"{row['tokens_per_s']:9.1f} tok/s  "
                      f"{row['syncs_per_token']:.4f} syncs/tok  "
                      f"wall {row['wall_s']:.2f}s")
    # headline: fused-vs-legacy speedup per (impl, mode)
    speedups = {}
    for impl in impls:
        for mode in modes:
            base = next(r for r in rows if r["impl"] == impl
                        and r["mode"] == mode and r["macro_steps"] == ks[0])
            best = max((r for r in rows if r["impl"] == impl
                        and r["mode"] == mode), key=lambda r: r["tokens_per_s"])
            speedups[f"{impl}/{mode}"] = {
                "best_k": best["macro_steps"],
                "tokens_per_s_legacy": base["tokens_per_s"],
                "tokens_per_s_best": best["tokens_per_s"],
                "speedup": best["tokens_per_s"] / max(base["tokens_per_s"],
                                                      1e-9),
                "sync_reduction":
                    base["syncs_per_token"] / max(best["syncs_per_token"],
                                                  1e-9),
            }
    out = {"config": {"smoke": smoke, "requests": requests,
                      "max_new": max_new, "slots": 8,
                      "backend": jax.default_backend()},
           "rows": rows, "speedups": speedups}
    with open("BENCH_serve.json", "w") as f:
        json.dump(out, f, indent=2)
    print("wrote BENCH_serve.json")
    if smoke:
        # CI sanity: the fused path must actually amortize host syncs
        fused = [r for r in rows if r["macro_steps"] >= 8]
        legacy = [r for r in rows if r["macro_steps"] == 0]
        assert all(r["tokens"] > 0 for r in rows)
        assert min(f["syncs_per_token"] for f in fused) < \
            min(l["syncs_per_token"] for l in legacy), \
            "macro-step loop did not reduce host syncs per token"
    return out


if __name__ == "__main__":
    import sys
    run(smoke="--smoke" in sys.argv)
