"""Sampler / logit-processor tests incl. hypothesis properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need hypothesis;
# a bare interpreter must still collect the suite (module-level skip)
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.config import SamplingConfig
from repro.sampling import samplers


def test_top_k_keeps_k():
    logits = jnp.asarray([[1.0, 5.0, 3.0, 2.0, 4.0]])
    out = samplers.apply_top_k(logits, 2)
    kept = np.asarray(out[0] > samplers.NEG_INF / 2)
    assert kept.sum() == 2 and kept[1] and kept[4]


def test_top_p_keeps_minimal_nucleus():
    logits = jnp.log(jnp.asarray([[0.5, 0.3, 0.15, 0.05]]))
    out = samplers.apply_top_p(logits, 0.7)
    kept = np.asarray(out[0] > samplers.NEG_INF / 2)
    assert kept.tolist() == [True, True, False, False]


def test_top_p_always_keeps_top1():
    logits = jnp.asarray([[10.0, -10.0, -10.0]])
    out = samplers.apply_top_p(logits, 0.01)
    assert float(out[0, 0]) == 10.0


def test_min_p():
    logits = jnp.log(jnp.asarray([[0.6, 0.3, 0.001]]))
    out = samplers.apply_min_p(logits, 0.1)
    kept = np.asarray(out[0] > samplers.NEG_INF / 2)
    assert kept.tolist() == [True, True, False]


def test_repetition_penalty_direction():
    logits = jnp.asarray([[2.0, -2.0, 1.0]])
    counts = jnp.asarray([[1.0, 1.0, 0.0]])
    out = samplers.apply_repetition_penalty(logits, counts, 1.25)
    assert float(out[0, 0]) == pytest.approx(2.0 / 1.25, rel=1e-6)
    assert float(out[0, 1]) == pytest.approx(-2.0 * 1.25, rel=1e-6)
    assert float(out[0, 2]) == pytest.approx(1.0, rel=1e-6)


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10**6), st.floats(0.1, 1.0), st.integers(2, 40))
def test_processors_preserve_argmax(seed, p, v):
    """No processor chain may change the most-likely token."""
    logits = jax.random.normal(jax.random.PRNGKey(seed), (3, v)) * 3
    cfg = SamplingConfig(temperature=0.7, top_p=p, top_k=max(2, v // 3),
                         min_p=0.05, repetition_penalty=1.0)
    out = samplers.process_logits(logits, cfg)
    np.testing.assert_array_equal(np.asarray(jnp.argmax(out, -1)),
                                  np.asarray(jnp.argmax(logits, -1)))


def test_sample_token_greedy_rows():
    logits = jnp.asarray([[0.0, 5.0, 1.0], [4.0, 0.0, 1.0]])
    cfg = SamplingConfig(temperature=1.0, top_p=1.0, repetition_penalty=1.0)
    tok, lp = samplers.sample_token(jax.random.PRNGKey(0), logits, cfg,
                                    greedy=jnp.asarray([True, True]))
    assert tok.tolist() == [1, 0]
    assert bool(jnp.all(lp <= 0))


def test_sampling_respects_bias():
    """A strong CAMD mixture bias must dominate token choice."""
    logits = jnp.zeros((1, 8))
    bias = jnp.zeros((1, 8)).at[0, 5].set(50.0)
    cfg = SamplingConfig(temperature=1.0, top_p=1.0, repetition_penalty=1.0)
    tok, _ = samplers.sample_token(jax.random.PRNGKey(1), logits, cfg,
                                   bias=bias)
    assert int(tok[0]) == 5
