"""granite-moe-3b-a800m — IBM Granite 3.0 MoE.

[hf:ibm-granite/granite-3.0-1b-a400m-base] (family card; assigned 3b-a800m
variant): 32L, d_model=1536, 24 q heads with GQA kv=8, per-expert d_ff=512,
vocab 49155, 40 experts top-8.
"""
from repro.config import ATTN, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    d_ff=512,                    # per-expert hidden width
    vocab_size=49155,
    block_pattern=(ATTN,),
    mlp_activation="swiglu",
    moe=MoEConfig(num_experts=40, top_k=8, expert_d_ff=512),
    tie_embeddings=True,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
)
