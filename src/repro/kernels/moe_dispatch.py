"""MoE dispatch/combine Pallas TPU kernels.

The GShard capacity-dispatch einsum multiplies a (g × E·C) one-hot matrix
per token group — O(E·C) work and memory per token (3072 slots/token at
kimi-k2 dims; §Perf backlog). On TPU the dispatch is really a GATHER:
slot (e, c) copies token row ``idx[e, c]``. These kernels implement that
directly: the dispatch gathers token rows into expert slots via VMEM
dynamic slices, and the combine gathers expert outputs back per (token,
choice) pair and accumulates with the gate weights — O(k) per token.

Grid: one program per (group, expert-block); rows move HBM→VMEM once.
Validated in interpret mode against the einsum reference (ref.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _dispatch_kernel(idx_ref, x_ref, out_ref, *, C: int):
    """idx: (1, blkE, C) int32 token ids (-1 = empty slot);
    x: (1, g, d); out: (1, blkE, C, d)."""
    blkE = idx_ref.shape[1]
    d = x_ref.shape[-1]
    for e in range(blkE):          # static unroll: blkE × C dynamic slices
        for c in range(C):
            t = idx_ref[0, e, c]
            valid = t >= 0
            # all-dslice index tuple: a bare int here breaks the jax 0.4.x
            # interpret-mode load discharge rule
            row = pl.load(x_ref, (pl.dslice(0, 1),
                                  pl.dslice(jnp.maximum(t, 0), 1),
                                  pl.dslice(0, d)))
            out_ref[0, e, c, :] = jnp.where(valid, row[0, 0],
                                            jnp.zeros((d,), out_ref.dtype))


def _combine_kernel(idx_ref, gates_ref, eout_ref, out_ref, *, k: int):
    """idx: (1, g, k) int32 flat slot ids into (E*C); gates: (1, g, k);
    eout: (1, E, C, d) expert outputs; out: (1, g, d)."""
    g = idx_ref.shape[1]
    E, C, d = eout_ref.shape[1], eout_ref.shape[2], eout_ref.shape[3]
    flat = eout_ref[0].reshape(E * C, d)
    for t in range(g):             # static unroll over tokens in the group
        acc = jnp.zeros((d,), jnp.float32)
        for j in range(k):
            s = idx_ref[0, t, j]
            valid = s >= 0
            row = jax.lax.dynamic_slice(flat, (jnp.maximum(s, 0), 0), (1, d))
            acc = acc + jnp.where(valid,
                                  gates_ref[0, t, j] * row[0].astype(jnp.float32),
                                  0.0)
        out_ref[0, t, :] = acc.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def moe_dispatch(idx, x, *, interpret: bool = False):
    """idx: (G, E, C) int32 token index per slot (-1 empty); x: (G, g, d).
    Returns expert inputs (G, E, C, d)."""
    G, E, C = idx.shape
    g, d = x.shape[1], x.shape[2]
    kernel = functools.partial(_dispatch_kernel, C=C)
    return pl.pallas_call(
        kernel,
        grid=(G,),
        in_specs=[
            pl.BlockSpec((1, E, C), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, g, d), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, E, C, d), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((G, E, C, d), x.dtype),
        interpret=interpret,
    )(idx, x)


@functools.partial(jax.jit, static_argnames=("interpret",))
def moe_combine(slot_idx, gates, expert_out, *, interpret: bool = False):
    """slot_idx: (G, g, k) flat (E*C) slot per (token, choice), -1 dropped;
    gates: (G, g, k) combine weights; expert_out: (G, E, C, d).
    Returns (G, g, d)."""
    G, g, k = slot_idx.shape
    E, C, d = expert_out.shape[1], expert_out.shape[2], expert_out.shape[3]
    kernel = functools.partial(_combine_kernel, k=k)
    return pl.pallas_call(
        kernel,
        grid=(G,),
        in_specs=[
            pl.BlockSpec((1, g, k), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, g, k), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, E, C, d), lambda i: (i, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((G, g, d), jnp.float32),
        interpret=interpret,
    )(slot_idx, gates, expert_out)
