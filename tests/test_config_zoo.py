"""Config-zoo smoke: EVERY module shipped in ``repro.configs`` —
including the ones no other suite imports — must resolve through the
registry under both spellings, build a reduced model, report a
consistent capability surface, and survive one forward step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ATTN, LOCAL_ATTN
from repro.configs import MODULE_NAMES, get_config, list_configs
from repro.models import build_model


@pytest.mark.parametrize("module", MODULE_NAMES)
def test_registry_resolves_both_spellings(module):
    import importlib
    m = importlib.import_module(f"repro.configs.{module}")
    cfg = m.CONFIG
    assert get_config(module) is cfg          # module-name spelling
    assert get_config(cfg.name) is cfg        # arch-id spelling
    assert cfg.name in list_configs()


@pytest.mark.parametrize("module", MODULE_NAMES)
def test_capabilities_consistent(module):
    cfg = get_config(module).reduced()
    model = build_model(cfg, jnp.float32)
    caps = model.capabilities()
    kinds = set(cfg.layer_kinds)
    attn_kinds = {ATTN, LOCAL_ATTN}
    # state kind partitions the layer stack
    if cfg.is_encoder_decoder:
        assert caps["state_kind"] == "kv"
    elif kinds <= attn_kinds:
        assert caps["state_kind"] == "kv"
    elif kinds & attn_kinds:
        assert caps["state_kind"] == "hybrid"
    else:
        assert caps["state_kind"] == "recurrent"
    # implications between capability flags
    if caps["supports_speculative"] or caps["supports_prefix_cache"]:
        assert caps["state_kind"] == "kv"
        assert caps["has_pageable_layers"]
    if caps["has_pageable_layers"]:
        assert not caps["is_encoder_decoder"]
        assert ATTN in kinds
    if caps["supports_bucketed_prefill"]:
        assert kinds <= attn_kinds
    assert caps["has_vision_tower"] == (cfg.vision is not None)
    if cfg.vision is not None:
        assert cfg.vision.n_patches == cfg.num_evidence_tokens
        assert caps["num_evidence_tokens"] > 0
    assert caps["num_evidence_tokens"] == cfg.num_evidence_tokens


@pytest.mark.parametrize("module", MODULE_NAMES)
def test_reduced_forward_step(module):
    cfg = get_config(module).reduced().with_overrides(dtype="float32")
    model = build_model(cfg, jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    B, L = 2, 8
    kt, ke = jax.random.split(jax.random.PRNGKey(1))
    toks = jax.random.randint(kt, (B, L), 0, cfg.vocab_size)
    ev = None
    if cfg.num_evidence_tokens:
        ev = jax.random.normal(ke, (B, cfg.num_evidence_tokens,
                                    cfg.evidence_dim or cfg.d_model))
    logits, hidden, aux = model.forward(params, toks, ev)
    offs = cfg.num_evidence_tokens if (cfg.num_evidence_tokens and
                                       not cfg.is_encoder_decoder) else 0
    assert logits.shape == (B, L + offs, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    for v in aux.values():
        assert np.isfinite(np.asarray(v)).all()
