"""Configuration system for the repro framework.

Every architecture in the assigned pool is expressed as a ``ModelConfig``.
Configs are plain frozen dataclasses so they hash, compare, and serialize
cleanly, and can be passed through jit as static arguments.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Block kinds understood by the model builder.
# ---------------------------------------------------------------------------
ATTN = "attn"            # full (optionally windowed) self-attention block
LOCAL_ATTN = "local"     # sliding-window-only self-attention block
SSM = "ssm"              # Mamba2 SSD block
RGLRU = "rglru"          # RecurrentGemma RG-LRU recurrent block


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts settings for the MLP sub-block."""
    num_experts: int
    top_k: int
    # d_ff of each expert (per-expert hidden width).
    expert_d_ff: int
    # weight of the auxiliary load-balance loss during training.
    aux_loss_weight: float = 0.01
    # expert capacity factor (GShard); tokens beyond capacity are dropped.
    capacity_factor: float = 1.25
    # token group size for the dispatch einsum (bounds the one-hot temp).
    group_size: int = 256
    # router jitter noise (training only)
    router_noise: float = 0.0
    # number of shared (always-on) experts, e.g. DeepSeek/Kimi style.
    num_shared_experts: int = 0


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) settings."""
    state_dim: int = 128          # N — SSM state size
    head_dim: int = 64            # P — channels per SSD head
    expand: int = 2               # inner dim = expand * d_model
    chunk_size: int = 64          # SSD block-diagonal chunk length
    conv_width: int = 4           # depthwise causal conv width


@dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma RG-LRU settings."""
    lru_width: int = 0            # 0 => same as d_model
    conv_width: int = 4
    block_pattern: Tuple[str, ...] = (RGLRU, RGLRU, LOCAL_ATTN)  # 1:2 attn:rglru


@dataclass(frozen=True)
class VisionConfig:
    """ViT vision tower for the image-prefill serving path.

    ``(image_h // patch) * (image_w // patch)`` patch embeddings come out
    of the tower; the model builder asserts that product equals the LM's
    ``num_evidence_tokens`` so an encoded image drops into the evidence
    slots one-to-one, and the serving engine can treat image tokens
    exactly like prompt tokens (page-aligned, chunkable, prefix-cached
    on the image's content hash).
    """
    image_h: int = 336
    image_w: int = 336
    patch: int = 14
    channels: int = 3
    num_layers: int = 4
    d_model: int = 256
    num_heads: int = 4
    d_ff: int = 512

    @property
    def n_patches(self) -> int:
        return (self.image_h // self.patch) * (self.image_w // self.patch)

    @staticmethod
    def for_tokens(n: int, patch: int = 4, **kw) -> "VisionConfig":
        """A tower whose patch grid yields exactly ``n`` tokens (square
        grid when ``n`` is a perfect square, else ``n``x1)."""
        r = int(round(n ** 0.5))
        gh, gw = (r, r) if r * r == n else (n, 1)
        return VisionConfig(image_h=gh * patch, image_w=gw * patch,
                            patch=patch, **kw)


@dataclass(frozen=True)
class ModelConfig:
    """A single architecture. All assigned archs + the paper's own models."""
    name: str
    family: str                   # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int                # query heads (0 for attn-free archs)
    num_kv_heads: int             # kv heads (GQA); 1 => MQA
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 => d_model // num_heads
    # --- attention options -------------------------------------------------
    qkv_bias: bool = False        # qwen2.5-style QKV bias
    qk_norm: bool = False         # qwen3-style RMSNorm on q/k
    rope_theta: float = 10000.0
    attn_window: int = 0          # 0 => full causal; >0 => sliding window
    local_window: int = 2048      # window of LOCAL_ATTN blocks (hybrids)
    # --- block structure ----------------------------------------------------
    block_pattern: Tuple[str, ...] = (ATTN,)   # tiled over num_layers
    mlp_activation: str = "swiglu"             # swiglu | gelu | relu
    tie_embeddings: bool = False
    # --- optional sub-configs ------------------------------------------------
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    # --- encoder-decoder ------------------------------------------------------
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    # --- multimodal frontend ----------------------------------------------------
    # number of evidence (patch/frame) embeddings prepended to the sequence;
    # 0 for text-only models. Embeddings arrive precomputed (stub frontend)
    # or, when ``vision`` is set, from the in-repo vision tower.
    num_evidence_tokens: int = 0
    evidence_dim: int = 0         # dim of incoming evidence embeddings
    vision: Optional[VisionConfig] = None  # None => precomputed evidence only
    # --- misc -------------------------------------------------------------------
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    source: str = ""              # citation for the config

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        if self.num_heads == 0:
            return 0
        return self.d_model // self.num_heads

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        """The block kind of each of the num_layers layers."""
        pat = self.block_pattern
        return tuple(pat[i % len(pat)] for i in range(self.num_layers))

    def with_overrides(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """A CPU-smoke-test-sized variant of the same family.

        2 layers (enough to tile the block pattern at least once per kind for
        hybrids), d_model <= 512, <= 4 experts.
        """
        kw = dict(
            num_layers=max(2, min(len(self.block_pattern), 3)),
            d_model=256,
            d_ff=512,
            vocab_size=512,
            head_dim=64,
        )
        if self.num_heads:
            kw["num_heads"] = 4
            kw["num_kv_heads"] = min(self.num_kv_heads, 2) if self.num_kv_heads > 1 else 1
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe, num_experts=4, top_k=2, expert_d_ff=128,
                num_shared_experts=min(self.moe.num_shared_experts, 1),
                capacity_factor=4.0)  # dropless in practice at smoke scale
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(
                self.ssm, state_dim=16, head_dim=32, chunk_size=16)
        if self.rglru is not None:
            kw["rglru"] = dataclasses.replace(self.rglru, lru_width=256)
        if self.is_encoder_decoder:
            kw["num_encoder_layers"] = 2
        if self.num_evidence_tokens:
            kw["num_evidence_tokens"] = 8
            kw["evidence_dim"] = min(self.evidence_dim, 256) or 256
            if self.vision is not None:
                kw["vision"] = VisionConfig.for_tokens(
                    8, patch=4, num_layers=2, d_model=64, num_heads=2,
                    d_ff=128)
        if self.attn_window:
            kw["attn_window"] = 64
        kw["local_window"] = 64
        return self.with_overrides(**kw)

    def num_params(self) -> int:
        """Analytic parameter count (embedding + per-layer blocks)."""
        d, v = self.d_model, self.vocab_size
        n = v * d                              # embed
        if not self.tie_embeddings:
            n += v * d                         # unembed
        hd = self.resolved_head_dim
        for kind in self.layer_kinds:
            n += 2 * d                         # two norms
            if kind in (ATTN, LOCAL_ATTN):
                q = self.num_heads * hd
                kv = self.num_kv_heads * hd
                n += d * q + 2 * d * kv + q * d
            elif kind == SSM:
                s = self.ssm
                inner = s.expand * d
                heads = inner // s.head_dim
                n += d * (2 * inner + 2 * s.state_dim + heads) + inner * d
                n += s.conv_width * (inner + 2 * s.state_dim)
            elif kind == RGLRU:
                r = self.rglru
                w = r.lru_width or d
                n += 2 * d * w + w * d + 2 * w  # in/out proj + gates
            # MLP
            if kind in (ATTN, LOCAL_ATTN, RGLRU):
                if self.moe is not None:
                    e = self.moe
                    per = 3 * d * e.expert_d_ff if self.mlp_activation == "swiglu" \
                        else 2 * d * e.expert_d_ff
                    n += e.num_experts * per + d * e.num_experts
                    n += e.num_shared_experts * per
                else:
                    n += (3 if self.mlp_activation == "swiglu" else 2) * d * self.d_ff
        if self.is_encoder_decoder:
            # encoder layers: self-attn + mlp (approximate symmetric to decoder)
            q = self.num_heads * hd
            kv = self.num_kv_heads * hd
            per = d * q + 2 * d * kv + q * d + \
                (3 if self.mlp_activation == "swiglu" else 2) * d * self.d_ff + 2 * d
            n += self.num_encoder_layers * per
            # decoder cross-attention
            n += self.num_layers * (d * q + 2 * d * kv + q * d + d)
        return n

    def active_params(self) -> int:
        """Activated parameters per token (MoE: only top-k experts count)."""
        if self.moe is None:
            return self.num_params()
        d = self.d_model
        e = self.moe
        per = (3 if self.mlp_activation == "swiglu" else 2) * d * e.expert_d_ff
        dense_like = self.num_params() - len(self.layer_kinds) * e.num_experts * per
        return dense_like + len(self.layer_kinds) * (e.top_k + e.num_shared_experts) * per

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), indent=2, default=str)


@dataclass(frozen=True)
class ShapeConfig:
    """An assigned input shape."""
    name: str
    seq_len: int
    global_batch: int
    mode: str            # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class CAMDConfig:
    """Coverage-Aware Multimodal Decoding hyper-parameters (paper §5.1)."""
    lambda_g: float = 0.9          # weight of S_align (paper ablation best)
    lambda_c: float = 0.7          # weight of S_coh
    delta: float = 0.05            # target residual risk (1-delta coverage)
    tau: float = 0.90              # score threshold (threshold-stop rule)
    cluster_threshold: float = 0.85  # cosine sim for same-cluster
    max_clusters: int = 16         # fixed M for jit-ability
    max_rounds: int = 8            # outer adaptive rounds
    samples_per_round: int = 4     # K added per round
    min_samples: int = 2           # never stop before this many
    dirichlet_prior: float = 0.5   # symmetric alpha^(0)
    score_scale: float = 1.0       # evidence-score temperature for Eq. 14
                                   # (the paper normalizes score terms on a
                                   # validation set; this is that knob)
    guidance_strength: float = 1.0  # mixture token-bias strength (Eq. 16)
    patience: int = 3              # no-improvement patience (threshold rule)
    ei_cost_per_token: float = 1e-4  # EI stop rule: cost per generated token


@dataclass(frozen=True)
class PagedKVConfig:
    """Paged KV-cache settings for the serving engine (``impl="paged"``).

    ``num_pages=0`` sizes the pool to the dense worst case
    (slots * cache_len / page_size, + 1 quarantine page). Deployments
    cap it below that and rely on CAMD's early stopping to return
    pages: the engine reserves a candidate's worst-case pages at
    admission, so an undersized pool shows up as queueing delay (or a
    sizing error when even one candidate can never fit), never as a
    mid-decode failure.
    """
    page_size: int = 16            # tokens per KV page
    num_pages: int = 0             # 0 => dense-equivalent worst case
    # KV page storage dtype: "auto" (= engine param dtype), "fp32",
    # "bf16", or quantized "int8"/"fp8" (fp8-e4m3 where the jax build
    # has it). Quantized pools carry per-(page, slot, kv-head) absmax
    # scales and are dequantized inside the attention kernels; see
    # models.attention.KV_DTYPES.
    kv_dtype: str = "auto"
    # Resident-KV byte ceiling for the cross-request prefix cache
    # (0 = unbounded). Counted against TRUE resident bytes — quantized
    # values plus their scale tensors, the same bytes-per-page the
    # engine's kv_stats() reports. When total resident KV would exceed
    # the ceiling, cached-only prefix pages are evicted LRU-leaf-first
    # until it fits (or nothing cached remains evictable — live holds
    # may legitimately exceed the budget; the ceiling bounds the CACHE,
    # never live traffic).
    kv_byte_budget: int = 0


@dataclass(frozen=True)
class SamplingConfig:
    temperature: float = 0.7
    top_p: float = 0.9
    top_k: int = 0                 # 0 = off
    min_p: float = 0.0             # 0 = off
    repetition_penalty: float = 1.05
    max_new_tokens: int = 64


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 1000
    schedule: str = "cosine"       # cosine | linear | constant
    remat: bool = True             # activation checkpointing over layers
    unroll: bool = False           # python-loop layers (dry-run cost model)
    microbatches: int = 1          # gradient-accumulation splits of the
                                   # global batch (bounds activation memory)
    seed: int = 0
